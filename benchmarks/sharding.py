"""Sharding benchmark — bucket-sharded probe/self-join + SPMD waves vs
``n_shards=1``, on XLA-forced host devices (or a real mesh).

Acceptance criteria of the bucket-partition substrate:

* the sharded self-join's pair set must be bit-identical to the
  single-shard join, and the SPMD wave scores bit-identical to the
  single-device wave (asserted here, not just in tests);
* with forced host devices, sharded self-join + multi-device waves must
  beat ``n_shards=1`` end-to-end (self-join + scoring) in wall-clock —
  there is no dense-sweep fallback left to hide behind: `ShardedIndex`
  only has the bucket-probe ring, and the self-join takes the shard_map
  path whenever the process has ``n_shards`` devices (asserted).

Emits ``BENCH_shard.json`` (probe + self-join + wave wall-clock vs
``n_shards``, speedups) which the nightly CI job uploads, so the scaling
trajectory is tracked across PRs.

  PYTHONPATH=src python -m benchmarks.sharding --smoke      # CI (4 devices)
  PYTHONPATH=src python -m benchmarks.sharding --n-seqs 2048 --shards 4

(XLA_FLAGS is set before the first jax import; pass --shards to change
the forced host device count.)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time


def _run(args):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.allpairs import WaveConfig, lsh_self_join, score_pairs
    from repro.core import LSHConfig, ScalLoPS
    from repro.data import FamilyCorpusConfig, make_family_corpus
    from repro.index import ShardedIndex, SignatureIndex
    from repro.index.service import topk_probe

    S = args.shards
    assert jax.device_count() >= S, (
        f"need {S} devices for the shard_map paths (no silent fallback), "
        f"got {jax.devices()}")
    csv = print
    csv("bench,n_seqs,n_shards,metric,value")
    n = args.n_seqs
    n_fam = n // 8
    corpus = make_family_corpus(FamilyCorpusConfig(
        n_families=n_fam, family_size=4, n_singletons=n - 4 * n_fam,
        len_mean=150, len_std=25, sub_rate=0.03, seed=42))
    ids, lens = corpus["ids"], corpus["lens"]
    lsh = LSHConfig(k=3, T=13, f=32, d=1)
    index = SignatureIndex.build(lsh, ids, lens)
    index._ensure_built()

    def timed(fn, reps=args.reps):
        fn()                            # warm (compile + caches)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            ts.append(time.perf_counter() - t0)
        return min(ts), out

    results = {"bench": "sharding", "n_seqs": n, "n_shards": S,
               "devices": jax.device_count()}

    # ---- probe serving: bucket-probe ring vs n_shards -------------------
    q_sigs = ScalLoPS(lsh).signatures(ids[:args.n_queries],
                                      lens[:args.n_queries])
    t_probe1, base = timed(lambda: topk_probe(index, q_sigs, k=8, cap=64))
    csv(f"sharding,{n},1,probe_batch_s,{t_probe1:.4f}")
    probe = {"1": round(t_probe1, 4)}
    for s in (2, S) if S != 2 else (S,):
        sh = ShardedIndex(index, Mesh(np.array(jax.devices()[:s]),
                                      ("data",)))
        t_probe, got = timed(lambda sh=sh: sh.topk(q_sigs, k=8, cap=64))
        np.testing.assert_array_equal(np.asarray(base[0]), got[0])
        np.testing.assert_array_equal(np.asarray(base[1]), got[1])
        csv(f"sharding,{n},{s},probe_batch_s,{t_probe:.4f}")
        probe[str(s)] = round(t_probe, 4)
    results["probe_batch_s"] = probe
    csv(f"sharding,{n},{S},probe_bitexact,1")

    # ---- self-join: shard_map bucket emission vs n_shards ---------------
    t_join1, join1 = timed(lambda: lsh_self_join(index, max_pairs=1 << 14))
    csv(f"sharding,{n},1,selfjoin_s,{t_join1:.4f}")
    csv(f"sharding,{n},1,candidates,{join1.n_candidates}")
    t_joinS, joinS = timed(
        lambda: lsh_self_join(index, max_pairs=1 << 14, n_shards=S))
    np.testing.assert_array_equal(join1.pairs, joinS.pairs)
    csv(f"sharding,{n},{S},selfjoin_s,{t_joinS:.4f}")
    csv(f"sharding,{n},{S},selfjoin_bitexact,1")
    results["selfjoin_s"] = {"1": round(t_join1, 4), str(S): round(t_joinS, 4)}
    results["candidates"] = int(join1.n_candidates)

    # ---- SW waves: SPMD split vs single device --------------------------
    # full-SW waves (no prefilter): the DP-bound phase whose scaling the
    # split exists for — the prefiltered pipeline is benchmarked in
    # benchmarks/allpairs.py and its ungapped scans split the same way
    wave = WaveConfig(wave_batch=64, device_gather=True, inflight=2)
    wave1 = dataclasses.replace(wave, n_devices=1)
    waveS = dataclasses.replace(wave, n_devices=S)
    t_score1, s1 = timed(lambda: score_pairs(ids, lens, join1.pairs, wave1))
    t_scoreS, sS = timed(lambda: score_pairs(ids, lens, join1.pairs, waveS))
    np.testing.assert_array_equal(s1.scores, sS.scores)
    np.testing.assert_array_equal(s1.kept, sS.kept)
    csv(f"sharding,{n},1,score_s,{t_score1:.4f}")
    csv(f"sharding,{n},{S},score_s,{t_scoreS:.4f}")
    csv(f"sharding,{n},{S},score_bitexact,1")
    csv(f"sharding,{n},{S},speedup_score,{t_score1 / t_scoreS:.2f}")
    results["score_s"] = {"1": round(t_score1, 4), str(S): round(t_scoreS, 4)}

    # ---- end-to-end: self-join + scoring --------------------------------
    t1 = t_join1 + t_score1
    tS = t_joinS + t_scoreS
    speedup = t1 / tS
    csv(f"sharding,{n},1,e2e_s,{t1:.4f}")
    csv(f"sharding,{n},{S},e2e_s,{tS:.4f}")
    csv(f"sharding,{n},{S},speedup_e2e,{speedup:.2f}")
    results["e2e_s"] = {"1": round(t1, 4), str(S): round(tS, 4)}
    results["speedup"] = {"score": round(t_score1 / t_scoreS, 2),
                          "e2e": round(speedup, 2)}
    results["exactness"] = {"probe_bitexact": True,
                            "selfjoin_bitexact": True,
                            "score_bitexact": True}

    with open(args.json, "w") as fh:
        json.dump(results, fh, indent=2)
    csv(f"sharding,{n},{S},json_written,{args.json}")

    assert speedup > 1.0, (
        f"sharded self-join + multi-device waves must beat n_shards=1 "
        f"end-to-end (got {speedup:.2f}x at n_shards={S} on "
        f"{jax.device_count()} devices)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (writes BENCH_shard.json)")
    ap.add_argument("--n-seqs", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default="BENCH_shard.json")
    args = ap.parse_args(argv)
    args.n_seqs = args.n_seqs or (512 if args.smoke else 2048)
    if args.shards < 2:
        ap.error("--shards must be >= 2 (the benchmark compares against "
                 "n_shards=1)")

    if "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"
        if "jax" in sys.modules:
            raise RuntimeError("jax imported before XLA_FLAGS was set; "
                               "run benchmarks.sharding as the entry point")
    _run(args)


if __name__ == "__main__":
    main()
