"""All-pairs benchmark — device-resident wave pipeline vs the PR 2 host
path vs naive pairwise.

Acceptance criteria of the `repro.allpairs` subsystem, measured on a
2048-sequence synthetic corpus:

* the self-join's candidate pair set must EXACTLY match brute-force
  enumeration of LSH band collisions;
* the device-resident pipeline (fused on-device gathers + ungapped X-drop
  prefilter + async drain ring) must beat the PR 2 pipeline (host copy
  loop, synchronous, no prefilter) by >= 3x end-to-end (index build +
  self-join + scoring), with survivor SW scores bit-exact against the PR 2
  path and prefilter recall >= 99% at the family score threshold;
* the wavefront DP (anti-diagonal sweep, `repro.align.gotoh`) must
  deliver >= 2x the row wave's pairs/s at the acceptance shape B=64,
  Lq=Lr=192 (the ``--dp-kernel``/``--gap-mode`` sweep, asserted);
* candidate emission through the fused SpGEMM join (``join_impl="spgemm"``)
  must beat the legacy orchestration (host merge + grow-and-retry) by
  >= 2x warmed steady-state at the FIXED 2048-sequence acceptance corpus —
  the ``--join-impl`` sweep, asserted (like the DP sweep, it runs at the
  acceptance shape even under ``--smoke``), with both impls' pair arrays
  bit-identical;
* the tiled pipeline must beat naive all-pairs per-pair Smith-Waterman by
  >= 10x wall-clock (timed on a sample, extrapolated). The naive baseline
  deliberately pays the per-shape jit retrace on every ragged pair — that
  cache-thrash IS the modeled cost of shipping unpadded per-pair DP calls,
  exactly what the padded-ladder scheduler exists to remove.

CSV: bench,n_seqs,method,metric,value.  ``--json`` (implied by ``--smoke``)
additionally writes BENCH_allpairs.json — pairs/sec, waves, prefilter
reject rate, wall-clock — which the nightly CI job uploads so the perf
trajectory is tracked across PRs.  ``--profile`` reports the host-gather
vs device-DP time split of both pipelines, making the win attributable.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.align import gotoh
from repro.align.smith_waterman import sw_score, sw_scores_device
from repro.allpairs import (brute_force_collisions, lsh_self_join,
                            score_pairs, wave_plan, WaveConfig)
from repro.core import LSHConfig
from repro.data import FamilyCorpusConfig, make_family_corpus
from repro.index import SignatureIndex

# Family score threshold for the recall criterion, calibrated on the
# planted-family corpus (len_mean=150, sub_rate=0.03): true family pairs
# score >= ~390 while band-collision noise tops out at ~105 — 150 separates
# them with margin on both sides (see tests/test_allpairs.py recall test).
FAMILY_SCORE_T = 150

# (dp_kernel, gap_mode) pairs of the score-phase sweep; rowwave+affine is
# rejected by the router and so not a sweep point
DP_SWEEP = (("rowwave", "linear"), ("wavefront", "linear"),
            ("wavefront", "affine"))

PR2_WAVE = WaveConfig(wave_batch=64, device_gather=False, prefilter=False,
                      inflight=0, dp_kernel="rowwave")
DEVICE_WAVE = WaveConfig(wave_batch=64, device_gather=True, prefilter=True,
                         prefilter_min=40, inflight=2)

# the emission sweep's fixed acceptance corpus — the full-size corpus of
# run(); like the DP sweep's fixed (B, L) shape, it does NOT shrink under
# --smoke, because the >= 2x emission criterion is defined at this size
EMISSION_N = 2048


def _warm(ids, lens, pairs, cfg: WaveConfig):
    """Compile every wave shape of ``cfg`` ahead of the timed run: one pair
    per (Lq, Lr) ladder bucket, with the prefilter threshold floored so the
    full-SW shapes compile too."""
    sample = np.array(sorted({int(idx[0]) for idx, _, _ in
                              wave_plan(pairs, lens, cfg)}))
    if len(sample) == 0:
        return
    wc = dataclasses.replace(cfg, prefilter_min=-(1 << 30)) \
        if cfg.prefilter else cfg
    score_pairs(ids, lens, pairs[sample], wc)


def dp_kernel_sweep(csv=print, *, n: int, B: int = 64, L: int = 192,
                    reps: int = 20, dp_kernel: str = "all",
                    gap_mode: str = "all", seed: int = 17) -> dict:
    """Score-phase microbenchmark at the acceptance shape (B=64,
    Lq=Lr=192): warmed steady-state pairs/s of each (dp_kernel, gap_mode)
    sweep point on one device-resident block. The wavefront's win over the
    row wave is an acceptance criterion (>= 2x pairs/s), asserted whenever
    both linear sweep points run."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    qs = jnp.asarray(rng.integers(0, 20, (B, L), dtype=np.int8))
    rs = jnp.asarray(rng.integers(0, 20, (B, L), dtype=np.int8))
    fns = {("rowwave", "linear"): lambda: sw_scores_device(qs, rs),
           ("wavefront", "linear"): lambda: gotoh.sw_wave_linear(qs, rs),
           ("wavefront", "affine"): lambda: gotoh.sw_wave_affine(qs, rs)}
    out = {"shape": {"B": B, "Lq": L, "Lr": L}}
    for kernel, mode in DP_SWEEP:
        if dp_kernel != "all" and kernel != dp_kernel:
            continue
        if gap_mode != "all" and mode != gap_mode:
            continue
        fn = fns[(kernel, mode)]
        fn().block_until_ready()                        # warm the shape
        t0 = time.perf_counter()
        for _ in range(reps):
            fn().block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        key = f"{kernel}_{mode}"
        out[key] = {"wave_ms": round(dt * 1e3, 3),
                    "pairs_per_sec": round(B / dt, 1)}
        csv(f"allpairs,{n},dp_{key},wave_ms,{dt * 1e3:.3f}")
        csv(f"allpairs,{n},dp_{key},pairs_per_sec,{B / dt:.0f}")
    row, wav = out.get("rowwave_linear"), out.get("wavefront_linear")
    if row and wav:
        speedup = wav["pairs_per_sec"] / row["pairs_per_sec"]
        out["speedup_wavefront_vs_rowwave"] = round(speedup, 2)
        csv(f"allpairs,{n},dp_wavefront_linear,speedup_vs_rowwave,"
            f"{speedup:.2f}")
        assert speedup >= 2.0, (
            f"wavefront must deliver >= 2x row-wave pairs/s at B={B}, "
            f"Lq=Lr={L} (got {speedup:.2f}x)")
    return out


def emission_sweep(csv=print, *, n: int, reps: int = 10,
                   join_impl: str = "all",
                   max_pairs: int = 1 << 14, seed: int = 42) -> dict:
    """Candidate-emission microbenchmark at the FIXED acceptance corpus
    (:data:`EMISSION_N` planted-family sequences — the corpus ``run()``
    uses at full size): warmed steady-state self-join wall time per
    ``join_impl``. ``max_pairs`` is deliberately a typical *starting*
    capacity well below the true pair count, so the legacy orchestration
    pays its documented grow-and-retry cost — eliminating that retry (and
    the host merge) is exactly what the fused SpGEMM join is for. The
    >= 2x criterion is asserted whenever both impls run, after checking
    their pair arrays are bit-identical."""
    n_fam = EMISSION_N // 8
    corpus = make_family_corpus(FamilyCorpusConfig(
        n_families=n_fam, family_size=4,
        n_singletons=EMISSION_N - 4 * n_fam,
        len_mean=150, len_std=25, sub_rate=0.03, seed=seed))
    cfg = LSHConfig(k=3, T=13, f=32, d=1)
    index = SignatureIndex.build(cfg, corpus["ids"], corpus["lens"])
    index._ensure_built()
    out = {"n_seqs": EMISSION_N, "max_pairs": max_pairs, "reps": reps}
    impls = [i for i in ("legacy", "spgemm") if join_impl in ("all", i)]
    pairs_ref = None
    for impl in impls:
        for _ in range(2):                              # warm both programs
            join = lsh_self_join(index, max_pairs=max_pairs, join_impl=impl)
        if pairs_ref is None:
            pairs_ref = join.pairs
        else:
            np.testing.assert_array_equal(pairs_ref, join.pairs)
        t0 = time.perf_counter()
        for _ in range(reps):
            join = lsh_self_join(index, max_pairs=max_pairs, join_impl=impl)
        dt = (time.perf_counter() - t0) / reps
        out[impl] = {"join_ms": round(dt * 1e3, 3),
                     "cands_per_sec": round(join.n_candidates / dt, 1)}
        csv(f"allpairs,{n},emission_{impl},join_ms,{dt * 1e3:.3f}")
        csv(f"allpairs,{n},emission_{impl},cands_per_sec,"
            f"{join.n_candidates / dt:.0f}")
    out["candidates"] = int(join.n_candidates)
    if "legacy" in out and "spgemm" in out:
        speedup = out["legacy"]["join_ms"] / out["spgemm"]["join_ms"]
        out["speedup_spgemm_vs_legacy"] = round(speedup, 2)
        out["bitexact_vs_legacy"] = True
        csv(f"allpairs,{n},emission_spgemm,speedup_vs_legacy,{speedup:.2f}")
        assert speedup >= 2.0, (
            f"fused SpGEMM emission must beat the legacy orchestration "
            f">= 2x at the n={EMISSION_N} acceptance corpus "
            f"(got {speedup:.2f}x)")
    return out


def run(csv=print, n_seqs: int = 2048, naive_sample: int = 192,
        use_pallas: bool = False, profile: bool = False,
        json_path: str | None = None, dp_kernel: str = "all",
        gap_mode: str = "all", join_impl: str = "all"):
    csv("bench,n_seqs,method,metric,value")
    n_fam = n_seqs // 8                    # 4-member families, half singletons
    corpus = make_family_corpus(FamilyCorpusConfig(
        n_families=n_fam, family_size=4, n_singletons=n_seqs - 4 * n_fam,
        len_mean=150, len_std=25, sub_rate=0.03, seed=42))
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    cfg = LSHConfig(k=3, T=13, f=32, d=1)

    # ---- self-join: exactness vs brute-force collision enumeration ------
    t0 = time.time()
    index = SignatureIndex.build(cfg, ids, lens)
    index._ensure_built()
    t_build = time.time() - t0
    csv(f"allpairs,{n},tiled,index_build_s,{t_build:.3f}")

    t0 = time.time()
    join = lsh_self_join(index, max_pairs=1 << 14)   # raw band collisions
    t_join = time.time() - t0
    csv(f"allpairs,{n},tiled,selfjoin_s,{t_join:.3f}")
    csv(f"allpairs,{n},tiled,candidates,{join.n_candidates}")

    want = brute_force_collisions(index)
    got = {tuple(p) for p in join.pairs}
    exact = got == want
    csv(f"allpairs,{n},tiled,collision_exact,{int(exact)}")
    assert exact, (f"self-join diverged from brute-force collisions: "
                   f"{len(got)} vs {len(want)} pairs")

    # ---- PR 2 pipeline: host gather, synchronous, no prefilter -----------
    # pinned bool, not None/auto: PR 2's default was use_pallas=False, and
    # the baseline must stay PR 2 behavior even on a TPU backend
    pr2 = dataclasses.replace(PR2_WAVE, use_pallas=bool(use_pallas))
    _warm(ids, lens, join.pairs, pr2)
    t0 = time.time()
    s_pr2 = score_pairs(ids, lens, join.pairs, pr2)
    t_pr2 = time.time() - t0
    csv(f"allpairs,{n},pr2,score_s,{t_pr2:.3f}")
    csv(f"allpairs,{n},pr2,waves,{s_pr2.n_waves}")
    csv(f"allpairs,{n},pr2,pairs_per_sec,{join.n_candidates / t_pr2:.0f}")

    # ---- device-resident pipeline: fused gather + prefilter + ring -------
    devw = dataclasses.replace(DEVICE_WAVE, use_pallas=use_pallas or None)
    _warm(ids, lens, join.pairs, devw)
    t0 = time.time()
    s_dev = score_pairs(ids, lens, join.pairs, devw)
    t_dev = time.time() - t0
    reject_rate = s_dev.n_prefiltered / max(join.n_candidates, 1)
    csv(f"allpairs,{n},device,score_s,{t_dev:.3f}")
    csv(f"allpairs,{n},device,waves,{s_dev.n_waves}")
    csv(f"allpairs,{n},device,wave_shapes,{s_dev.n_shapes}")
    csv(f"allpairs,{n},device,pairs_per_sec,{join.n_candidates / t_dev:.0f}")
    csv(f"allpairs,{n},device,prefilter_reject_rate,{reject_rate:.4f}")

    # survivors bit-exact with the PR 2 path
    np.testing.assert_array_equal(s_dev.scores[s_dev.kept],
                                  s_pr2.scores[s_dev.kept])
    csv(f"allpairs,{n},device,survivor_bitexact,1")

    # prefilter recall at the family score threshold
    high = s_pr2.scores >= FAMILY_SCORE_T
    recall = float(s_dev.kept[high].mean()) if high.any() else 1.0
    csv(f"allpairs,{n},device,recall_at_S{FAMILY_SCORE_T},{recall:.4f}")
    assert recall >= 0.99, (
        f"X-drop prefilter lost {(1 - recall):.1%} of pairs with SW score "
        f">= {FAMILY_SCORE_T} (need >= 99% recall)")

    speedup_score = t_pr2 / t_dev
    t_e2e_pr2 = t_build + t_join + t_pr2
    t_e2e_dev = t_build + t_join + t_dev
    speedup_e2e = t_e2e_pr2 / t_e2e_dev
    csv(f"allpairs,{n},device,speedup_score_vs_pr2,{speedup_score:.2f}")
    csv(f"allpairs,{n},device,speedup_e2e_vs_pr2,{speedup_e2e:.2f}")
    if n >= 2048:
        assert speedup_e2e >= 3, (
            f"device-resident pipeline must beat the PR 2 pipeline >= 3x "
            f"end-to-end (got {speedup_e2e:.2f}x)")

    # ---- naive baseline: per-pair SW over ALL pairs (sampled) ------------
    total_pairs = n * (n - 1) // 2
    rng = np.random.default_rng(7)
    ii = rng.integers(0, n, naive_sample)
    jj = rng.integers(0, n, naive_sample)
    sw_score(ids[0][: lens[0]], ids[1][: lens[1]])     # warm one shape
    t0 = time.time()
    for a, b in zip(ii, jj):
        sw_score(ids[a][: lens[a]], ids[b][: lens[b]])
    t_naive_sample = time.time() - t0
    per_pair = t_naive_sample / naive_sample
    t_naive = per_pair * total_pairs
    csv(f"allpairs,{n},naive,per_pair_ms,{per_pair * 1e3:.3f}")
    csv(f"allpairs,{n},naive,total_pairs,{total_pairs}")
    csv(f"allpairs,{n},naive,total_s_extrapolated,{t_naive:.1f}")

    speedup_naive = t_naive / t_e2e_dev
    csv(f"allpairs,{n},device,speedup_vs_naive,{speedup_naive:.1f}")
    assert speedup_naive >= 10, (
        f"tiled all-pairs must beat naive per-pair SW by >= 10x "
        f"(got {speedup_naive:.1f}x)")

    # ---- parity: wave scores == per-pair scores on a random slice --------
    check = join.pairs[rng.permutation(join.n_candidates)[:32]]
    wave_sc = score_pairs(ids, lens, check, pr2).scores
    for row, (a, b) in enumerate(check):
        assert wave_sc[row] == sw_score(ids[a][: lens[a]], ids[b][: lens[b]])
    csv(f"allpairs,{n},pr2,wave_score_parity,1")

    # ---- score-phase DP sweep: rowwave vs wavefront, linear vs affine ----
    dp = dp_kernel_sweep(csv, n=n, dp_kernel=dp_kernel, gap_mode=gap_mode)

    # ---- emission-phase sweep: fused SpGEMM join vs legacy orchestration -
    emission = emission_sweep(csv, n=n, join_impl=join_impl)

    # ---- attribution: host-gather vs device-DP split (--profile) ---------
    if profile:
        for name, wc in (("pr2", pr2), ("device", devw)):
            sp = score_pairs(ids, lens, join.pairs,
                             dataclasses.replace(wc, profile=True))
            for k, v in sp.timings.items():
                csv(f"allpairs,{n},{name},profile_{k}_s,{v:.3f}")

    if json_path:
        payload = {
            "bench": "allpairs", "n_seqs": n,
            "candidates": int(join.n_candidates),
            "index_build_s": round(t_build, 3),
            "selfjoin_s": round(t_join, 3),
            "pr2": {"score_s": round(t_pr2, 3), "waves": s_pr2.n_waves,
                    "pairs_per_sec": round(join.n_candidates / t_pr2, 1),
                    "wall_clock_s": round(t_e2e_pr2, 3)},
            "device": {"score_s": round(t_dev, 3), "waves": s_dev.n_waves,
                       "pairs_per_sec": round(join.n_candidates / t_dev, 1),
                       "prefilter_reject_rate": round(reject_rate, 4),
                       "wall_clock_s": round(t_e2e_dev, 3)},
            "speedup": {"score_vs_pr2": round(speedup_score, 2),
                        "e2e_vs_pr2": round(speedup_e2e, 2),
                        "vs_naive_extrapolated": round(speedup_naive, 1)},
            "dp_kernels": dp,
            "emission": emission,
            "exactness": {"collision_exact": bool(exact),
                          "survivor_bitexact": True,
                          "family_threshold": FAMILY_SCORE_T,
                          "recall_at_family_threshold": round(recall, 4)},
        }
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        csv(f"allpairs,{n},device,json_written,{json_path}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (exercises every code path, "
                         "writes BENCH_allpairs.json)")
    ap.add_argument("--n-seqs", type=int, default=None)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--profile", action="store_true",
                    help="report host-gather vs device-DP time split")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable summary here")
    ap.add_argument("--dp-kernel", default="all",
                    choices=["all", "rowwave", "wavefront"],
                    help="restrict the score-phase DP sweep")
    ap.add_argument("--gap-mode", default="all",
                    choices=["all", "linear", "affine"],
                    help="restrict the score-phase DP sweep")
    ap.add_argument("--join-impl", default="all",
                    choices=["all", "spgemm", "legacy"],
                    help="restrict the candidate-emission sweep")
    args = ap.parse_args(argv)
    n = args.n_seqs or (256 if args.smoke else 2048)
    sample = 32 if args.smoke else 192
    json_path = args.json or ("BENCH_allpairs.json" if args.smoke else None)
    run(n_seqs=n, naive_sample=sample, use_pallas=args.pallas,
        profile=args.profile, json_path=json_path,
        dp_kernel=args.dp_kernel, gap_mode=args.gap_mode,
        join_impl=args.join_impl)


if __name__ == "__main__":
    main()
