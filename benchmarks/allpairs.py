"""All-pairs benchmark — tiled LSH self-join + SW waves vs naive pairwise.

Acceptance criteria of the `repro.allpairs` subsystem, measured on a
2048-sequence synthetic corpus:

* the self-join's candidate pair set must EXACTLY match brute-force
  enumeration of LSH band collisions (pigeonhole exactness preserved
  through the self-join machinery);
* the tiled pipeline (self-join + batched SW waves) must beat naive
  all-pairs per-pair Smith-Waterman by >= 10x wall-clock. The naive
  baseline scores every one of the N*(N-1)/2 pairs with per-pair DP calls;
  it is timed on a sample and extrapolated (at 2048 sequences the full
  naive run is hours — that asymmetry is the point).

CSV: bench,n_seqs,method,metric,value
"""
from __future__ import annotations

import time

import numpy as np

from repro.align.smith_waterman import sw_score
from repro.allpairs import (brute_force_collisions, lsh_self_join,
                            score_pairs, WaveConfig)
from repro.core import LSHConfig
from repro.data import FamilyCorpusConfig, make_family_corpus
from repro.index import SignatureIndex


def run(csv=print, n_seqs: int = 2048, naive_sample: int = 192,
        use_pallas: bool = False):
    csv("bench,n_seqs,method,metric,value")
    n_fam = n_seqs // 8                    # 4-member families, half singletons
    corpus = make_family_corpus(FamilyCorpusConfig(
        n_families=n_fam, family_size=4, n_singletons=n_seqs - 4 * n_fam,
        len_mean=150, len_std=25, sub_rate=0.03, seed=42))
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    cfg = LSHConfig(k=3, T=13, f=32, d=1)

    # ---- self-join: exactness vs brute-force collision enumeration ------
    t0 = time.time()
    index = SignatureIndex.build(cfg, ids, lens)
    index._ensure_built()
    t_build = time.time() - t0
    csv(f"allpairs,{n},tiled,index_build_s,{t_build:.3f}")

    t0 = time.time()
    join = lsh_self_join(index, max_pairs=1 << 14)   # raw band collisions
    t_join = time.time() - t0
    csv(f"allpairs,{n},tiled,selfjoin_s,{t_join:.3f}")
    csv(f"allpairs,{n},tiled,candidates,{join.n_candidates}")

    want = brute_force_collisions(index)
    got = {tuple(p) for p in join.pairs}
    exact = got == want
    csv(f"allpairs,{n},tiled,collision_exact,{int(exact)}")
    assert exact, (f"self-join diverged from brute-force collisions: "
                   f"{len(got)} vs {len(want)} pairs")

    # ---- tiled scoring over the candidate set ----------------------------
    wave = WaveConfig(wave_batch=64, use_pallas=use_pallas)
    # warm the jit cache so the tiled number is steady-state (the naive
    # baseline gets the same treatment: its per-pair calls re-hit the cache
    # whenever shapes repeat)
    score_pairs(ids, lens, join.pairs[: min(64, join.n_candidates)], wave)
    t0 = time.time()
    scored = score_pairs(ids, lens, join.pairs, wave)
    t_score = time.time() - t0
    t_tiled = t_build + t_join + t_score
    csv(f"allpairs,{n},tiled,score_s,{t_score:.3f}")
    csv(f"allpairs,{n},tiled,waves,{scored.n_waves}")
    csv(f"allpairs,{n},tiled,wave_shapes,{scored.n_shapes}")
    csv(f"allpairs,{n},tiled,total_s,{t_tiled:.3f}")

    # ---- naive baseline: per-pair SW over ALL pairs (sampled) ------------
    total_pairs = n * (n - 1) // 2
    rng = np.random.default_rng(7)
    ii = rng.integers(0, n, naive_sample)
    jj = rng.integers(0, n, naive_sample)
    sw_score(ids[0][: lens[0]], ids[1][: lens[1]])     # warm one shape
    t0 = time.time()
    for a, b in zip(ii, jj):
        sw_score(ids[a][: lens[a]], ids[b][: lens[b]])
    t_naive_sample = time.time() - t0
    per_pair = t_naive_sample / naive_sample
    t_naive = per_pair * total_pairs
    csv(f"allpairs,{n},naive,per_pair_ms,{per_pair * 1e3:.3f}")
    csv(f"allpairs,{n},naive,total_pairs,{total_pairs}")
    csv(f"allpairs,{n},naive,total_s_extrapolated,{t_naive:.1f}")

    speedup = t_naive / t_tiled
    csv(f"allpairs,{n},tiled,speedup_vs_naive,{speedup:.1f}")
    assert speedup >= 10, (
        f"tiled all-pairs must beat naive per-pair SW by >= 10x "
        f"(got {speedup:.1f}x)")

    # ---- parity: wave scores == per-pair scores on a random slice --------
    check = join.pairs[rng.permutation(join.n_candidates)[:32]]
    wave_sc = score_pairs(ids, lens, check, wave).scores
    for row, (a, b) in enumerate(check):
        assert wave_sc[row] == sw_score(ids[a][: lens[a]], ids[b][: lens[b]])
    csv(f"allpairs,{n},tiled,wave_score_parity,1")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (exercises every code path)")
    ap.add_argument("--n-seqs", type=int, default=None)
    ap.add_argument("--pallas", action="store_true")
    args = ap.parse_args(argv)
    n = args.n_seqs or (256 if args.smoke else 2048)
    sample = 32 if args.smoke else 192
    run(n_seqs=n, naive_sample=sample, use_pallas=args.pallas)


if __name__ == "__main__":
    main()
