"""Performance benchmark — paper Table 5.3 analogue.

Wall-clock of ScalLoPS (signature generation + signature processing) vs the
BLAST-like seed-and-extend baseline vs a brute-force Smith-Waterman scan, at
growing query-set sizes (the paper's claim C6: ScalLoPS loses on small sets,
wins as the query set grows — metagenomic regime).

Also reports the two siggen execution paths (paper-structure matmul vs the
beyond-paper contribution table) and the three join paths.

CSV: bench,n_queries,n_refs,method,seconds,pairs
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.align import SeedExtendBaseline
from repro.align.smith_waterman import sw_align_batch
from repro.core import LSHConfig, ScalLoPS
from repro.core.simhash import signatures_matmul, signatures_table
from repro.data import SyntheticProteinConfig, make_protein_sets


def _block_until(x):
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, x)


def run(csv=print):
    csv("bench,n_queries,n_refs,method,seconds,pairs")
    n_refs = 192
    for n_q in (64, 256, 1024):
        data = make_protein_sets(SyntheticProteinConfig(
            n_refs=n_refs, n_homolog_queries=n_q // 4,
            n_decoy_queries=n_q - n_q // 4, ref_len_mean=120,
            ref_len_std=20, sub_rates=(0.05,), seed=21))

        # --- ScalLoPS (k=3 T=13 d=0, paper's §5.3 point; table siggen)
        sl = ScalLoPS(LSHConfig(k=3, T=13, f=32, d=0, max_pairs=1 << 15))
        t0 = time.time()
        rs = sl.signatures(data["ref_ids"], data["ref_lens"])
        _block_until(rs)
        t_ref = time.time() - t0                    # db prep (once per ref set)
        t0 = time.time()
        qs = sl.signatures(data["query_ids"], data["query_lens"])
        pairs, count, _ov = sl.search(qs, rs)
        _block_until(pairs)
        t_sl = time.time() - t0
        csv(f"table5.3,{n_q},{n_refs},scallops_query+join,{t_sl:.3f},"
            f"{int(count)}")
        csv(f"table5.3,{n_q},{n_refs},scallops_refprep,{t_ref:.3f},-")

        # --- seed-extend baseline (BLAST-like)
        base = SeedExtendBaseline(k=3, T=11, s_min=35)
        t0 = time.time()
        base.build_index(data["ref_ids"], data["ref_lens"])
        t_idx = time.time() - t0
        t0 = time.time()
        hits = base.search(data["query_ids"], data["query_lens"])
        t_se = time.time() - t0
        csv(f"table5.3,{n_q},{n_refs},seed_extend,{t_se:.3f},{len(hits)}")
        csv(f"table5.3,{n_q},{n_refs},seed_extend_index,{t_idx:.3f},-")

        # --- brute-force SW scan (the no-heuristic floor), subsampled cost
        n_probe = min(n_q, 32)
        qs_ids = np.repeat(np.arange(n_probe), 8)
        rs_ids = np.tile(np.arange(8), n_probe)
        Lq = data["query_ids"].shape[1]
        Lr = data["ref_ids"].shape[1]
        t0 = time.time()
        sw_align_batch(data["query_ids"][qs_ids], data["ref_ids"][rs_ids])
        dt = time.time() - t0
        full = dt / (n_probe * 8) * (n_q * n_refs)
        csv(f"table5.3,{n_q},{n_refs},brute_sw_extrapolated,{full:.3f},-")

    # --- siggen path comparison (paper structure vs contribution table)
    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=512, n_homolog_queries=0, n_decoy_queries=0,
        ref_len_mean=300, ref_len_std=50, seed=22))
    ids, lens = data["ref_ids"], data["ref_lens"]
    for name, fn in (("siggen_matmul", signatures_matmul),
                     ("siggen_table", signatures_table)):
        f = jax.jit(lambda i, l, fn=fn: fn(i, l, k=3, T=13, f=32))
        _block_until(f(ids, lens))              # compile + table build
        t0 = time.time()
        for _ in range(3):
            _block_until(f(ids, lens))
        csv(f"siggen,512,-,{name},{(time.time()-t0)/3:.3f},-")
