"""Serving benchmark — indexed repeat-query search vs from-scratch ScalLoPS.

The paper's §5.3 economics, measured: the from-scratch pipeline pays
reference signature generation + join on *every* call; the index pays it
once. At >= 4k references the indexed path must win wall-clock on repeat
queries (acceptance criterion of the `repro.index` subsystem), and
save -> load -> query must reproduce the in-memory top-k exactly.

CSV: bench,n_refs,n_queries,method,metric,value
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import LSHConfig, ScalLoPS
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import QueryEngine, ServingConfig, SignatureIndex
from repro.index.service import topk_probe


def run(csv=print, n_refs: int = 4096, n_q: int = 256, batch: int = 32,
        k: int = 10, rounds: int = 4):
    csv("bench,n_refs,n_queries,method,metric,value")
    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=n_refs, n_homolog_queries=n_q // 4,
        n_decoy_queries=n_q - n_q // 4, ref_len_mean=150, ref_len_std=30,
        sub_rates=(0.05, 0.15), seed=31))
    cfg = LSHConfig(k=3, T=13, f=32, d=1, max_pairs=1 << 16)
    qids, qlens = data["query_ids"], data["query_lens"]

    # ---- build + persist (paid once) ------------------------------------
    t0 = time.time()
    index = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
    index._ensure_built()
    t_build = time.time() - t0
    csv(f"serving,{n_refs},{n_q},indexed,build_s,{t_build:.3f}")

    # ---- save -> load -> query must equal in-memory top-k exactly -------
    fd, path = tempfile.mkstemp(suffix=".npz")
    os.close(fd)
    try:
        index.save(path)
        loaded = SignatureIndex.load(path, expected_cfg=cfg)
        sl = ScalLoPS(cfg)
        q_sigs = sl.signatures(qids, qlens)
        mem_ids, mem_d, *_ = topk_probe(index, q_sigs, k=k, cap=256)
        ld_ids, ld_d, *_ = topk_probe(loaded, q_sigs, k=k, cap=256)
        exact = (np.array_equal(np.asarray(mem_ids), np.asarray(ld_ids))
                 and np.array_equal(np.asarray(mem_d), np.asarray(ld_d)))
        csv(f"serving,{n_refs},{n_q},indexed,roundtrip_exact,{int(exact)}")
        assert exact, "save->load->query must reproduce in-memory top-k"
    finally:
        os.unlink(path)

    # ---- indexed repeat-query serving -----------------------------------
    engine = QueryEngine(loaded, ServingConfig(
        k=k, max_batch=batch, mode="probe", probe_cap=64))
    engine.query_batch(qids[:batch], qlens[:batch])       # warm-up/compile
    engine._stats.batch_sizes.clear()
    engine._stats.latencies.clear()
    t0 = time.time()
    for _ in range(rounds):
        for i in range(0, n_q, batch):
            engine.query_batch(qids[i:i + batch], qlens[i:i + batch])
    t_indexed = (time.time() - t0) / rounds
    s = engine.stats()
    csv(f"serving,{n_refs},{n_q},indexed,round_s,{t_indexed:.3f}")
    csv(f"serving,{n_refs},{n_q},indexed,qps,{s['qps']:.0f}")
    csv(f"serving,{n_refs},{n_q},indexed,p50_ms,{s['p50_ms']:.2f}")
    csv(f"serving,{n_refs},{n_q},indexed,p95_ms,{s['p95_ms']:.2f}")

    # ---- from-scratch ScalLoPS: re-prepares the reference db every call -
    t0 = time.time()
    for _ in range(rounds):
        sl2 = ScalLoPS(cfg)           # fresh jit, as a cold caller would
        rs = np.asarray(sl2.signatures(data["ref_ids"], data["ref_lens"]))
        qsg = sl2.signatures(qids, qlens)
        res = sl2.search(qsg, rs)
        np.asarray(res.pairs)
    t_scratch = (time.time() - t0) / rounds
    csv(f"serving,{n_refs},{n_q},from_scratch,round_s,{t_scratch:.3f}")
    csv(f"serving,{n_refs},{n_q},from_scratch,qps,{n_q/t_scratch:.0f}")

    speedup = t_scratch / max(t_indexed, 1e-9)
    csv(f"serving,{n_refs},{n_q},indexed,speedup_vs_scratch,{speedup:.1f}")
    assert t_indexed < t_scratch, (
        f"indexed serving ({t_indexed:.3f}s/round) must beat from-scratch "
        f"({t_scratch:.3f}s/round) at {n_refs} refs")


if __name__ == "__main__":
    run()
