"""Quality benchmarks — paper Figures 5.1-5.4 on synthetic ground truth.

Paper methodology: emit (query, reference) pairs with ScalLoPS at varying
d / T / k, align emitted pairs (Smith-Waterman) and report PID quartiles +
intersection with the BLAST-like baseline. Ground truth here is *planted*
(the mutation channel), so recall is exact, not proxied.

Each figure analogue prints CSV rows:
  fig,param,value,n_pairs,recall,precision,pid_q1,pid_med,pid_q3,intersection
"""
from __future__ import annotations

import time

import numpy as np

from repro.align import SeedExtendBaseline
from repro.align.smith_waterman import batch_percent_identity
from repro.core import LSHConfig, ScalLoPS
from repro.core.join import pairs_to_set
from repro.data import SyntheticProteinConfig, make_protein_sets


def _truth_pairs(data):
    return {(qi, p) for qi, (p, _r) in enumerate(data["truth"]) if p >= 0}


def _eval(cfg: LSHConfig, data, baseline_pairs=None, max_pid_pairs=200):
    sl = ScalLoPS(cfg)
    rs = sl.signatures(data["ref_ids"], data["ref_lens"])
    qs = sl.signatures(data["query_ids"], data["query_lens"])
    # paper §5.2: only sequences with non-zero signatures are processed
    qv = np.asarray(sl.feature_counts(data["query_ids"],
                                      data["query_lens"])) > 0
    rv = np.asarray(sl.feature_counts(data["ref_ids"],
                                      data["ref_lens"])) > 0
    pairs, count, _ov = sl.search(qs, rs, q_valid=qv, r_valid=rv)
    got = pairs_to_set(pairs)
    truth = _truth_pairs(data)
    recall = len(got & truth) / max(len(truth), 1)
    precision = len(got & truth) / max(len(got), 1)
    sub = list(got)[:max_pid_pairs]
    pids = batch_percent_identity(
        [(q, r, 0) for q, r in sub], data["query_ids"], data["query_lens"],
        data["ref_ids"], data["ref_lens"])
    pids = pids[np.isfinite(pids)]
    q1, med, q3 = (np.percentile(pids, [25, 50, 75])
                   if len(pids) else (0, 0, 0))
    inter = (len(got & baseline_pairs) / max(len(got), 1)
             if baseline_pairs is not None else float("nan"))
    # recall per planted-identity tier (exact ground truth)
    by_tier = {}
    for qi, (p, rate) in enumerate(data["truth"]):
        if p >= 0:
            ok = (qi, p) in got
            a, b = by_tier.get(rate, (0, 0))
            by_tier[rate] = (a + ok, b + 1)
    tiers = " ".join(f"{1-r:.2f}:{a}/{b}"
                     for r, (a, b) in sorted(by_tier.items()))
    return dict(n_pairs=len(got), recall=recall, precision=precision,
                pid_q1=q1, pid_med=med, pid_q3=q3, intersection=inter,
                tiers=tiers)


def run(csv=print):
    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=160, n_homolog_queries=48, n_decoy_queries=48,
        ref_len_mean=150, ref_len_std=30, sub_rates=(0.03, 0.10, 0.20),
        seed=11))
    base = SeedExtendBaseline(k=3, T=11, s_min=35).build_index(
        data["ref_ids"], data["ref_lens"])
    bl = {(q, r) for q, r, s in base.search(data["query_ids"],
                                            data["query_lens"])}

    csv("fig,param,value,n_pairs,recall,precision,pid_q1,pid_med,pid_q3,"
        "intersection,recall_by_identity")

    def row(fig, param, value, m):
        csv(f"{fig},{param},{value},{m['n_pairs']},{m['recall']:.3f},"
            f"{m['precision']:.3f},{m['pid_q1']:.1f},{m['pid_med']:.1f},"
            f"{m['pid_q3']:.1f},{m['intersection']:.3f},{m['tiers']}")

    # Fig 5.1: vary Hamming distance d (k=3, T=13)
    for d in (0, 1, 2):
        m = _eval(LSHConfig(k=3, T=13, f=32, d=d, max_pairs=1 << 15),
                  data, bl)
        row("5.1", "d", d, m)
    # Fig 5.2: vary neighbourhood threshold T (k=3, d=0)
    for T in (11, 13, 15, 18, 22):
        m = _eval(LSHConfig(k=3, T=T, f=32, d=0, max_pairs=1 << 15),
                  data, bl)
        row("5.2", "T", T, m)
    # Fig 5.3: vary shingle length k (T tuned per paper: k=2 -> low T)
    for k, T in ((2, 9), (3, 13)):
        m = _eval(LSHConfig(k=k, T=T, f=32, d=0, max_pairs=1 << 15),
                  data, bl)
        row("5.3", "k", k, m)
    # Fig 5.4: short queries degrade PID (length mismatch flips signs)
    short = make_protein_sets(SyntheticProteinConfig(
        n_refs=160, n_homolog_queries=48, n_decoy_queries=48,
        ref_len_mean=150, ref_len_std=30, query_len_mean=60,
        sub_rates=(0.03, 0.10, 0.20), seed=12))
    m = _eval(LSHConfig(k=3, T=13, f=32, d=2, max_pairs=1 << 15), short)
    row("5.4", "short_queries", 60, m)
    m = _eval(LSHConfig(k=3, T=13, f=32, d=2, max_pairs=1 << 15), data)
    row("5.4", "full_queries", 150, m)
    # beyond-paper: splitmix hyperplanes + wider signatures at same join cost
    # (d scales with f: 2/32 bits -> ~6/64 at matched selectivity)
    m = _eval(LSHConfig(k=3, T=13, f=64, d=6, scheme="splitmix",
                        join_method="band", max_pairs=1 << 15), data, bl)
    row("beyond", "splitmix_f64_band", 6, m)
