"""Closed-loop SLO benchmark for the async serving tier (repro.serve).

Sweeps **offered QPS** with open-loop arrivals (requests submitted on a
fixed schedule regardless of completions — the load model a real front
door sees) through an :class:`~repro.serve.AsyncEngine` over a
:class:`~repro.serve.ReplicaFleet`, and finds the **latency knee**: the
highest offered rate the tier still absorbs (achieved >= 90% of offered).
Below the knee p95 is flat; past it the queue grows without bound and
latency is just queueing delay.

Acceptance criteria (asserted in ``--smoke``, not just reported):

* async throughput at the knee must be >= the synchronous batch-1
  baseline — micro-batching via the padding ladder has to *buy*
  something, or the tier is pure overhead;
* a live ingest + major compaction mid-sweep must complete with ZERO
  failed or blocked requests (rolling refresh keeps serving live);
* the async path must be bit-exact with the synchronous probe path
  (mode="probe") on a fixed query batch;
* the recompile sentinel (repro.obs.jit) reports ZERO compiles from the
  first sweep point to the last — *including* the mid-sweep ingest and
  major compaction: a priming phase pre-pays every lifecycle shape
  (delta ring, compacted base), so steady-state serving never traces.

Emits ``BENCH_serve.json`` (sync baseline, per-point sweep stats, knee,
live-ingest accounting, per-site compile counts) which the nightly CI
job uploads alongside the other BENCH artifacts. With ``--trace-out``
structured tracing is enabled for the whole run and the exported
Chrome/Perfetto JSON is checked: every completed query's trace ID spans
submit -> dispatch -> resolve with its batch's probe spans, and the
report attributes the slowest live-ingest samples to the lifecycle
spans they overlap (ingest/compaction spikes line up, by construction
visible on one timeline).

  PYTHONPATH=src python -m benchmarks.serve_slo --smoke        # CI
  PYTHONPATH=src python -m benchmarks.serve_slo --n-refs 4096 \
      --shards 4 --replicas 2

(XLA_FLAGS is set before the first jax import; pass --shards to change
the forced host device count.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _percentiles(lat_s):
    import numpy as np
    if not lat_s:
        return dict(p50_ms=0.0, p95_ms=0.0, p99_ms=0.0, mean_ms=0.0)
    a = np.asarray(lat_s, np.float64) * 1e3
    return dict(p50_ms=float(np.percentile(a, 50)),
                p95_ms=float(np.percentile(a, 95)),
                p99_ms=float(np.percentile(a, 99)),
                mean_ms=float(a.mean()))


def _open_loop_point(eng, qids, qlens, offered_qps, n_requests,
                     on_submit=None):
    """Submit ``n_requests`` on a fixed open-loop schedule at
    ``offered_qps``; returns (achieved_qps, latency percentiles, n_shed).
    ``on_submit(i)`` fires before request i (hook for mid-sweep ingest).
    """
    period = 1.0 / offered_qps
    nq = len(qlens)
    t_start = time.monotonic()
    recs = []
    for i in range(n_requests):
        if on_submit is not None:
            on_submit(i)
        target = t_start + i * period
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        j = i % nq
        t_sub = time.monotonic()
        fut = eng.submit(qids[j][:qlens[j]])
        done = {}
        fut.add_done_callback(
            lambda f, d=done: d.setdefault("t", time.monotonic()))
        recs.append((t_sub, fut, done))
    results = [f.result(timeout=300) for _, f, _ in recs]
    t_end = max(d["t"] for _, _, d in recs)
    lat = [d["t"] - t_sub for (t_sub, _, d), r in zip(recs, results) if r.ok]
    n_ok = sum(1 for r in results if r.ok)
    n_shed = len(results) - n_ok
    achieved = n_ok / max(t_end - t_start, 1e-9)
    return achieved, _percentiles(lat), n_shed, results


def _trace_report(spans, slow_threshold_ms):
    """Reconstruct every query's path from the exported spans and
    attribute slow samples to overlapping lifecycle spans.

    Per-query latency comes from the trace itself (``resolve.ts -
    submit.ts`` for each trace ID), so the attribution never mixes
    clocks. Returns (report dict, list of broken trace IDs)."""
    submit = {}
    resolve = {}
    by_trace = {}
    lifecycle = []
    for s in spans:
        if s["cat"] == "lifecycle":
            lifecycle.append(s)
        for tid in s["args"].get("trace", ()):
            by_trace.setdefault(tid, set()).add(s["name"])
            if s["name"] == "submit":
                submit[tid] = s["ts"]
            elif s["name"] == "resolve":
                resolve[tid] = s["ts"]
    # a completed query's path: submit -> dispatch (batch) -> the serving
    # spans of its batch -> resolve. Shed queries have submit+shed only.
    need = {"submit", "dispatch", "query_batch", "probe", "resolve"}
    broken = [tid for tid in resolve
              if not need.issubset(by_trace.get(tid, set()))]
    slow = []
    for tid, t1 in resolve.items():
        t0 = submit.get(tid)
        if t0 is None:
            continue
        lat_ms = (t1 - t0) * 1e3
        if lat_ms < slow_threshold_ms:
            continue
        overlaps = [dict(name=s["name"],
                         overlap_ms=round(1e3 * (min(t1, s["ts"] + s["dur"])
                                                 - max(t0, s["ts"])), 2))
                    for s in lifecycle
                    if s["dur"] and s["ts"] < t1 and s["ts"] + s["dur"] > t0]
        slow.append(dict(trace=tid, latency_ms=round(lat_ms, 2),
                         lifecycle=overlaps))
    slow.sort(key=lambda d: -d["latency_ms"])
    n_attr = sum(1 for d in slow if d["lifecycle"])
    return dict(
        n_traced=len(by_trace), n_completed=len(resolve),
        n_path_broken=len(broken),
        lifecycle_spans=sorted({s["name"] for s in lifecycle}),
        slow_threshold_ms=slow_threshold_ms,
        n_slow=len(slow), n_slow_attributed_to_lifecycle=n_attr,
        slowest=slow[:10],
    ), broken


def _run(args):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import LSHConfig
    from repro.data import SyntheticProteinConfig, make_protein_sets
    from repro.index import (QueryEngine, ServingConfig, ShardedIndex,
                             SignatureIndex)
    from repro.obs import SENTINEL, TRACER, enable as trace_enable
    from repro.serve import AsyncEngine, ReplicaFleet

    if args.trace_out:
        trace_enable()
    S = args.shards
    assert jax.device_count() >= S, (
        f"need {S} devices, got {jax.devices()}")
    csv = print
    csv("bench,metric,value")

    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=args.n_refs, n_homolog_queries=args.n_queries // 4,
        n_decoy_queries=args.n_queries - args.n_queries // 4,
        ref_len_mean=150, ref_len_std=30, sub_rates=(0.05, 0.15), seed=13))
    qids, qlens = data["query_ids"], data["query_lens"]
    cfg = LSHConfig(k=3, T=13, f=32, d=1)
    index = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
    index._ensure_built()
    mesh = Mesh(np.array(jax.devices()[:S]), ("data",))
    # probe mode on BOTH sides: the fleet always serves the sharded probe
    # ring, so the parity baseline must not silently take the dense path
    scfg = ServingConfig(k=args.k, max_batch=args.batch, mode="probe")

    results = {"bench": "serve_slo", "n_refs": args.n_refs,
               "n_queries_per_point": args.n_per_point,
               "shards": S, "replicas": args.replicas,
               "batch": args.batch, "max_wait_ms": args.max_wait_ms,
               "devices": jax.device_count()}

    # the 32-reference batch the mid-sweep ingest will add — built up
    # front because the PRIMING phase ingests the same content first:
    # identical content -> identical pow2-quantized delta slab shapes ->
    # the delta-ring programs the live ingest needs are already compiled
    rng = np.random.default_rng(7)
    from repro.core.alphabet import ALPHABET_SIZE, PAD
    new_lens = rng.integers(100, 180, size=32).astype(np.int32)
    new_ids = np.full((32, int(new_lens.max())), PAD, np.int8)
    for r, L in enumerate(new_lens):
        new_ids[r, :L] = rng.integers(0, ALPHABET_SIZE, size=L,
                                      dtype=np.int8)

    # ---- synchronous batch-1 baseline (no micro-batching to hide behind)
    sync_sh = ShardedIndex(index, mesh)
    sync_eng = QueryEngine(index, scfg, sharded=sync_sh, name="sync")
    t_warm0 = time.monotonic()
    n_warm = sync_eng.warmup(qids, qlens)
    t0 = time.monotonic()
    n_sync = min(len(qlens), args.n_per_point)
    for i in range(n_sync):
        sync_eng.query_batch(qids[i:i + 1], qlens[i:i + 1])
    sync_qps = n_sync / (time.monotonic() - t0)
    csv(f"serve_slo,sync_batch1_qps,{sync_qps:.1f}")
    results["sync_batch1_qps"] = round(sync_qps, 2)

    # ---- the async tier under an offered-QPS sweep ----------------------
    # warmup= compiles every (rung, quantum) shape on every replica at
    # construction (the sync warmup above already compiled the rings —
    # the device-tuple program cache makes N replicas cost one compile)
    fleet = ReplicaFleet(index, scfg, n_replicas=args.replicas, mesh=mesh,
                         warmup=(qids, qlens))
    eng = AsyncEngine(fleet, max_wait_ms=args.max_wait_ms, name="slo")

    # PRIMING: pre-pay every lifecycle shape the live-ingest rerun will
    # serve — ingest the same 32-ref content (delta slabs + delta-ring
    # compile at every rung), then major-compact (pow2-quantized base
    # slabs; shapes repeat across compactions) and re-warm. After this,
    # steady-state serving must never trace again: the whole sweep AND
    # the mid-sweep ingest/compaction run under expect_no_compiles.
    fleet.ingest(new_ids, new_lens).wait(timeout=120)
    fleet.warmup(qids, qlens)           # delta-ring shapes, every rung
    fleet.compact_index()
    fleet.warmup(qids, qlens)           # compacted-base shapes
    csv(f"serve_slo,warm_shapes,{n_warm} "
        f"({time.monotonic() - t_warm0:.1f}s, primed ingest+compaction)")

    with SENTINEL.expect_no_compiles(
            message="offered-QPS sweep (post-warmup steady state)"):
        sweep = []
        knee = None
        for mult in args.multipliers:
            offered = sync_qps * mult
            achieved, pct, n_shed, _ = _open_loop_point(
                eng, qids, qlens, offered, args.n_per_point)
            point = dict(offered_qps=round(offered, 2),
                         achieved_qps=round(achieved, 2),
                         shed=n_shed,
                         **{k: round(v, 2) for k, v in pct.items()})
            sweep.append(point)
            csv(f"serve_slo,offered={offered:.1f},achieved={achieved:.1f} "
                f"p50={pct['p50_ms']:.1f}ms p95={pct['p95_ms']:.1f}ms "
                f"p99={pct['p99_ms']:.1f}ms shed={n_shed}")
            if achieved >= 0.9 * offered:
                knee = point        # highest offered the tier absorbs
        results["sweep"] = sweep
        results["knee"] = knee
        assert knee is not None, (
            "the tier absorbed NO offered rate (achieved < 0.9x offered "
            "everywhere) — dispatch is broken or the sweep floor is too "
            "high")
        csv(f"serve_slo,knee_offered_qps,{knee['offered_qps']}")
        csv(f"serve_slo,knee_achieved_qps,{knee['achieved_qps']}")

        # ---- live ingest + major compaction mid-stream ------------------
        # re-run the knee point with an ingest fired a third of the way in
        # and a major compaction two thirds in; every request must
        # complete, and (priming above) none may trigger a compile
        hooks = {}

        def on_submit(i):
            if i == args.n_per_point // 3 and "ingest" not in hooks:
                hooks["ingest"] = fleet.ingest(new_ids, new_lens)
            if i == 2 * args.n_per_point // 3 and "compact" not in hooks:
                hooks["ingest"].wait(timeout=120)
                fleet.compact_index()
                hooks["compact"] = True

        achieved, pct, n_shed, res = _open_loop_point(
            eng, qids, qlens, knee["offered_qps"], args.n_per_point,
            on_submit=on_submit)
        assert hooks.get("compact"), "mid-sweep compaction never fired"
        epochs = sorted({r.epoch for r in res if r.ok})
        assert n_shed == 0, (
            f"live ingest/compaction shed {n_shed} requests — serving did "
            f"not stay live (counters: {eng.counters.snapshot()})")
    csv(f"serve_slo,live_ingest_achieved_qps,{achieved:.1f}")
    csv(f"serve_slo,live_ingest_epochs,{epochs}")
    results["live_ingest"] = dict(
        achieved_qps=round(achieved, 2), shed=n_shed,
        epochs_served=[int(e) for e in epochs],
        **{k: round(v, 2) for k, v in pct.items()})
    assert not SENTINEL.recompiled(), (
        f"silent recompiles (same key traced twice): "
        f"{SENTINEL.recompiled()}")
    results["jit_compiles"] = SENTINEL.by_site()
    csv(f"serve_slo,jit_compiles,{sum(SENTINEL.by_site().values())} "
        f"(all pre-sweep: {SENTINEL.by_site()})")

    # ---- bit-exactness: async answers == synchronous probe answers ------
    sync_eng2 = QueryEngine(index, scfg, sharded=ShardedIndex(index, mesh))
    nb = min(len(qlens), args.batch)
    want_id, want_d = sync_eng2.query_batch(qids[:nb], qlens[:nb])
    futs = [eng.submit(qids[j][:qlens[j]]) for j in range(nb)]
    got = [f.result(timeout=300) for f in futs]
    assert all(r.ok for r in got)
    np.testing.assert_array_equal(np.stack([r.ids for r in got]), want_id)
    np.testing.assert_array_equal(np.stack([r.dists for r in got]), want_d)
    csv("serve_slo,async_bitexact,1")
    results["async_bitexact"] = True

    eng.close()
    fleet.close()

    if args.trace_out:
        n_ev = TRACER.export(args.trace_out)
        # slow = past 2x the knee median: the tail the report must explain
        thresh = max(2.0 * knee["p50_ms"], 1.0)
        report, broken = _trace_report(TRACER.spans(), thresh)
        assert report["n_path_broken"] == 0, (
            f"{report['n_path_broken']} completed queries have broken "
            f"trace paths (first: {broken[:5]}) — a span on the "
            f"submit->dispatch->probe->resolve chain lost its trace ID")
        for name in ("ingest", "major_compaction"):
            assert name in report["lifecycle_spans"], (
                f"no {name!r} lifecycle span in the trace — the mid-sweep "
                f"event ran untraced (spans: {report['lifecycle_spans']})")
        results["trace"] = report
        csv(f"serve_slo,trace_events,{n_ev} -> {args.trace_out}")
        csv(f"serve_slo,trace_paths,{report['n_completed']} complete, "
            f"0 broken; {report['n_slow']} slow (>{thresh:.1f}ms), "
            f"{report['n_slow_attributed_to_lifecycle']} overlap lifecycle")

    with open(args.json, "w") as fh:
        json.dump(results, fh, indent=2)
    csv(f"serve_slo,json_written,{args.json}")

    assert knee["achieved_qps"] >= sync_qps, (
        f"async throughput at the knee ({knee['achieved_qps']:.1f} q/s) "
        f"must beat the synchronous batch-1 baseline ({sync_qps:.1f} q/s) "
        f"— micro-batching bought nothing")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (writes BENCH_serve.json)")
    ap.add_argument("--n-refs", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--n-per-point", type=int, default=None,
                    help="requests submitted per offered-QPS point")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--multipliers", type=float, nargs="+",
                    default=[0.25, 0.5, 1.0, 2.0, 4.0],
                    help="offered-QPS sweep points as multiples of the "
                         "sync batch-1 baseline")
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable structured tracing for the whole run and "
                         "export Chrome/Perfetto trace JSON here (adds a "
                         "per-query path-completeness check and a slow-"
                         "sample lifecycle attribution report)")
    args = ap.parse_args(argv)
    args.n_refs = args.n_refs or (512 if args.smoke else 4096)
    args.n_per_point = args.n_per_point or (48 if args.smoke else 256)

    if "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"
        if "jax" in sys.modules:
            raise RuntimeError("jax imported before XLA_FLAGS was set; "
                               "run benchmarks.serve_slo as the entry point")
    _run(args)


if __name__ == "__main__":
    main()
