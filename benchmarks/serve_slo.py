"""Closed-loop SLO benchmark for the async serving tier (repro.serve).

Sweeps **offered QPS** with open-loop arrivals (requests submitted on a
fixed schedule regardless of completions — the load model a real front
door sees) through an :class:`~repro.serve.AsyncEngine` over a
:class:`~repro.serve.ReplicaFleet`, and finds the **latency knee**: the
highest offered rate the tier still absorbs (achieved >= 90% of offered).
Below the knee p95 is flat; past it the queue grows without bound and
latency is just queueing delay.

Acceptance criteria (asserted in ``--smoke``, not just reported):

* async throughput at the knee must be >= the synchronous batch-1
  baseline — micro-batching via the padding ladder has to *buy*
  something, or the tier is pure overhead;
* a live ingest + major compaction mid-sweep must complete with ZERO
  failed or blocked requests (rolling refresh keeps serving live);
* the async path must be bit-exact with the synchronous probe path
  (mode="probe") on a fixed query batch.

Emits ``BENCH_serve.json`` (sync baseline, per-point sweep stats, knee,
live-ingest accounting) which the nightly CI job uploads alongside the
other BENCH artifacts.

  PYTHONPATH=src python -m benchmarks.serve_slo --smoke        # CI
  PYTHONPATH=src python -m benchmarks.serve_slo --n-refs 4096 \
      --shards 4 --replicas 2

(XLA_FLAGS is set before the first jax import; pass --shards to change
the forced host device count.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _percentiles(lat_s):
    import numpy as np
    if not lat_s:
        return dict(p50_ms=0.0, p95_ms=0.0, p99_ms=0.0, mean_ms=0.0)
    a = np.asarray(lat_s, np.float64) * 1e3
    return dict(p50_ms=float(np.percentile(a, 50)),
                p95_ms=float(np.percentile(a, 95)),
                p99_ms=float(np.percentile(a, 99)),
                mean_ms=float(a.mean()))


def _warm_rungs(backend, qids, qlens, scfg):
    """Compile every (batch-rung, length-quantum) serving shape the sweep
    can land on — a real tier pre-warms its ladder; without this, the
    open-loop points measure XLA compiles instead of serving."""
    import numpy as np
    quanta = {}
    for j, L in enumerate(np.asarray(qlens)):
        q = int(-(-int(L) // scfg.len_quantum) * scfg.len_quantum)
        if q not in quanta or L > qlens[quanta[q]]:
            quanta[q] = j
    rungs = [b for b in scfg.batch_ladder if b <= scfg.max_batch]
    for b in rungs:
        for j in quanta.values():
            # slice to the true length: the padded width (what the jit
            # cache keys on) is quantized from the ARRAY width
            row = qids[j:j + 1, :int(qlens[j])]
            backend.query_batch(np.repeat(row, b, axis=0),
                                np.repeat(qlens[j:j + 1], b))
    return len(rungs) * len(quanta)


def _open_loop_point(eng, qids, qlens, offered_qps, n_requests,
                     on_submit=None):
    """Submit ``n_requests`` on a fixed open-loop schedule at
    ``offered_qps``; returns (achieved_qps, latency percentiles, n_shed).
    ``on_submit(i)`` fires before request i (hook for mid-sweep ingest).
    """
    period = 1.0 / offered_qps
    nq = len(qlens)
    t_start = time.monotonic()
    recs = []
    for i in range(n_requests):
        if on_submit is not None:
            on_submit(i)
        target = t_start + i * period
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        j = i % nq
        t_sub = time.monotonic()
        fut = eng.submit(qids[j][:qlens[j]])
        done = {}
        fut.add_done_callback(
            lambda f, d=done: d.setdefault("t", time.monotonic()))
        recs.append((t_sub, fut, done))
    results = [f.result(timeout=300) for _, f, _ in recs]
    t_end = max(d["t"] for _, _, d in recs)
    lat = [d["t"] - t_sub for (t_sub, _, d), r in zip(recs, results) if r.ok]
    n_ok = sum(1 for r in results if r.ok)
    n_shed = len(results) - n_ok
    achieved = n_ok / max(t_end - t_start, 1e-9)
    return achieved, _percentiles(lat), n_shed, results


def _run(args):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import LSHConfig
    from repro.data import SyntheticProteinConfig, make_protein_sets
    from repro.index import (QueryEngine, ServingConfig, ShardedIndex,
                             SignatureIndex)
    from repro.serve import AsyncEngine, ReplicaFleet

    S = args.shards
    assert jax.device_count() >= S, (
        f"need {S} devices, got {jax.devices()}")
    csv = print
    csv("bench,metric,value")

    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=args.n_refs, n_homolog_queries=args.n_queries // 4,
        n_decoy_queries=args.n_queries - args.n_queries // 4,
        ref_len_mean=150, ref_len_std=30, sub_rates=(0.05, 0.15), seed=13))
    qids, qlens = data["query_ids"], data["query_lens"]
    cfg = LSHConfig(k=3, T=13, f=32, d=1)
    index = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
    index._ensure_built()
    mesh = Mesh(np.array(jax.devices()[:S]), ("data",))
    # probe mode on BOTH sides: the fleet always serves the sharded probe
    # ring, so the parity baseline must not silently take the dense path
    scfg = ServingConfig(k=args.k, max_batch=args.batch, mode="probe")

    results = {"bench": "serve_slo", "n_refs": args.n_refs,
               "n_queries_per_point": args.n_per_point,
               "shards": S, "replicas": args.replicas,
               "batch": args.batch, "max_wait_ms": args.max_wait_ms,
               "devices": jax.device_count()}

    # ---- synchronous batch-1 baseline (no micro-batching to hide behind)
    sync_sh = ShardedIndex(index, mesh)
    sync_eng = QueryEngine(index, scfg, sharded=sync_sh)
    t_warm0 = time.monotonic()
    n_warm = _warm_rungs(sync_eng, qids, qlens, scfg)
    t0 = time.monotonic()
    n_sync = min(len(qlens), args.n_per_point)
    for i in range(n_sync):
        sync_eng.query_batch(qids[i:i + 1], qlens[i:i + 1])
    sync_qps = n_sync / (time.monotonic() - t0)
    csv(f"serve_slo,sync_batch1_qps,{sync_qps:.1f}")
    results["sync_batch1_qps"] = round(sync_qps, 2)

    # ---- the async tier under an offered-QPS sweep ----------------------
    fleet = ReplicaFleet(index, scfg, n_replicas=args.replicas, mesh=mesh)
    eng = AsyncEngine(fleet, max_wait_ms=args.max_wait_ms)
    # the module-level device-tuple program cache means the sync warmup
    # above already compiled every ring; this pass warms the fleet's
    # per-replica host paths (signatures etc.) without new compiles
    _warm_rungs(fleet, qids, qlens, scfg)
    csv(f"serve_slo,warm_shapes,{n_warm} "
        f"({time.monotonic() - t_warm0:.1f}s)")

    sweep = []
    knee = None
    for mult in args.multipliers:
        offered = sync_qps * mult
        achieved, pct, n_shed, _ = _open_loop_point(
            eng, qids, qlens, offered, args.n_per_point)
        point = dict(offered_qps=round(offered, 2),
                     achieved_qps=round(achieved, 2),
                     shed=n_shed, **{k: round(v, 2) for k, v in pct.items()})
        sweep.append(point)
        csv(f"serve_slo,offered={offered:.1f},achieved={achieved:.1f} "
            f"p50={pct['p50_ms']:.1f}ms p95={pct['p95_ms']:.1f}ms "
            f"p99={pct['p99_ms']:.1f}ms shed={n_shed}")
        if achieved >= 0.9 * offered:
            knee = point            # highest offered the tier absorbs
    results["sweep"] = sweep
    results["knee"] = knee
    assert knee is not None, (
        "the tier absorbed NO offered rate (achieved < 0.9x offered "
        "everywhere) — dispatch is broken or the sweep floor is too high")
    csv(f"serve_slo,knee_offered_qps,{knee['offered_qps']}")
    csv(f"serve_slo,knee_achieved_qps,{knee['achieved_qps']}")

    # ---- live ingest + major compaction mid-stream ----------------------
    # re-run the knee point with an ingest fired a third of the way in and
    # a major compaction two thirds in; every request must complete
    rng = np.random.default_rng(7)
    from repro.core.alphabet import ALPHABET_SIZE, PAD
    new_lens = rng.integers(100, 180, size=32).astype(np.int32)
    new_ids = np.full((32, int(new_lens.max())), PAD, np.int8)
    for r, L in enumerate(new_lens):
        new_ids[r, :L] = rng.integers(0, ALPHABET_SIZE, size=L,
                                      dtype=np.int8)
    hooks = {}

    def on_submit(i):
        if i == args.n_per_point // 3 and "ingest" not in hooks:
            hooks["ingest"] = fleet.ingest(new_ids, new_lens)
        if i == 2 * args.n_per_point // 3 and "compact" not in hooks:
            hooks["ingest"].wait(timeout=120)
            fleet.compact_index()
            hooks["compact"] = True

    achieved, pct, n_shed, res = _open_loop_point(
        eng, qids, qlens, knee["offered_qps"], args.n_per_point,
        on_submit=on_submit)
    assert hooks.get("compact"), "mid-sweep compaction never fired"
    epochs = sorted({r.epoch for r in res if r.ok})
    assert n_shed == 0, (
        f"live ingest/compaction shed {n_shed} requests — serving did "
        f"not stay live (counters: {eng.counters.snapshot()})")
    csv(f"serve_slo,live_ingest_achieved_qps,{achieved:.1f}")
    csv(f"serve_slo,live_ingest_epochs,{epochs}")
    results["live_ingest"] = dict(
        achieved_qps=round(achieved, 2), shed=n_shed,
        epochs_served=[int(e) for e in epochs],
        **{k: round(v, 2) for k, v in pct.items()})

    # ---- bit-exactness: async answers == synchronous probe answers ------
    sync_eng2 = QueryEngine(index, scfg, sharded=ShardedIndex(index, mesh))
    nb = min(len(qlens), args.batch)
    want_id, want_d = sync_eng2.query_batch(qids[:nb], qlens[:nb])
    futs = [eng.submit(qids[j][:qlens[j]]) for j in range(nb)]
    got = [f.result(timeout=300) for f in futs]
    assert all(r.ok for r in got)
    np.testing.assert_array_equal(np.stack([r.ids for r in got]), want_id)
    np.testing.assert_array_equal(np.stack([r.dists for r in got]), want_d)
    csv("serve_slo,async_bitexact,1")
    results["async_bitexact"] = True

    eng.close()
    fleet.close()

    with open(args.json, "w") as fh:
        json.dump(results, fh, indent=2)
    csv(f"serve_slo,json_written,{args.json}")

    assert knee["achieved_qps"] >= sync_qps, (
        f"async throughput at the knee ({knee['achieved_qps']:.1f} q/s) "
        f"must beat the synchronous batch-1 baseline ({sync_qps:.1f} q/s) "
        f"— micro-batching bought nothing")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (writes BENCH_serve.json)")
    ap.add_argument("--n-refs", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--n-per-point", type=int, default=None,
                    help="requests submitted per offered-QPS point")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--multipliers", type=float, nargs="+",
                    default=[0.25, 0.5, 1.0, 2.0, 4.0],
                    help="offered-QPS sweep points as multiples of the "
                         "sync batch-1 baseline")
    ap.add_argument("--json", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    args.n_refs = args.n_refs or (512 if args.smoke else 4096)
    args.n_per_point = args.n_per_point or (48 if args.smoke else 256)

    if "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"
        if "jax" in sys.modules:
            raise RuntimeError("jax imported before XLA_FLAGS was set; "
                               "run benchmarks.serve_slo as the entry point")
    _run(args)


if __name__ == "__main__":
    main()
