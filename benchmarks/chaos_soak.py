"""Chaos soak: the serving closed loop under a scripted fault plan.

``serve_slo.py`` proves the tier holds its SLO when nothing goes wrong;
this benchmark proves what happens when things DO go wrong is exactly
what the design says. A deterministic :class:`~repro.faults.FaultPlan`
scripts faults at named sites by per-site call number, the phases below
drive traffic through the same AsyncEngine + ReplicaFleet stack, and
every assertion is **exact** — the script says which calls fail, so the
retry/quarantine/degraded/shed counters are a deterministic function of
the script, not a distribution to eyeball:

* **transient**  — two isolated replica failures; both batches must be
  retried on the other replica and complete (zero user-visible errors).
* **blackout**   — four adjacent failures take both replicas down:
  exactly 2 quarantines, 3 typed degraded batches (never an exception),
  then — after the quarantine expires — exactly 2 half-open probes and
  2 readmissions bring the fleet back.
* **dispatch kill** — the dispatch thread dies mid-batch: the in-flight
  future resolves ``Rejected("internal", detail=...)`` and the
  supervisor restarts the loop (the next query completes normally).
* **soak**       — an open-loop run with a killed-then-retried ingest
  (the ticket resolves WITH the error; the supervisor restarts; the
  re-ingest advances the epoch) and a scripted latency spike; zero
  sheds, zero stranded futures.
* **bit-exact**  — every completed query from every phase is replayed
  against a from-scratch rebuild of the index at the epoch it was
  answered at; ids and distances must match bit-for-bit (the PR 5
  epoch contract survives retries, restarts, and degradation).
* **torn write + recovery** — the plan tears one segment write on a
  saved copy; ``load()`` raises a typed :class:`CorruptSegment` naming
  the file, ``load(recover=True)`` quarantines the tail and serves the
  longest valid prefix — bit-exact with a rebuild of that prefix.

Emits ``BENCH_chaos.json`` whose ``fault_counters`` block is fully
deterministic — ``bench_delta.py`` flags ANY drift against the committed
baseline (a changed fault count means the failure semantics changed).

  PYTHONPATH=src python -m benchmarks.chaos_soak --smoke        # CI
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _run(args):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import LSHConfig
    from repro.core.alphabet import ALPHABET_SIZE, PAD
    from repro.data import SyntheticProteinConfig, make_protein_sets
    from repro.faults import FaultPlan, InjectedFault
    from repro.index import (QueryEngine, ServingConfig, ShardedIndex,
                             SignatureIndex)
    from repro.index.segments import CorruptSegment
    from repro.serve import AsyncEngine, ReplicaFleet

    from benchmarks.serve_slo import _open_loop_point

    S = args.shards
    assert jax.device_count() >= S, f"need {S} devices, got {jax.devices()}"
    csv = print
    csv("bench,metric,value")

    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=args.n_refs, n_homolog_queries=args.n_queries // 4,
        n_decoy_queries=args.n_queries - args.n_queries // 4,
        ref_len_mean=150, ref_len_std=30, sub_rates=(0.05, 0.15), seed=13))
    qids, qlens = data["query_ids"], data["query_lens"]
    nq = len(qlens)
    cfg = LSHConfig(k=3, T=13, f=32, d=1)
    index = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
    index._ensure_built()
    mesh = Mesh(np.array(jax.devices()[:S]), ("data",))
    scfg = ServingConfig(k=args.k, max_batch=args.batch, mode="probe")

    # the mid-soak ingest batch (same recipe as serve_slo's)
    rng = np.random.default_rng(7)
    new_lens = rng.integers(100, 180, size=32).astype(np.int32)
    new_ids = np.full((32, int(new_lens.max())), PAD, np.int8)
    for r, L in enumerate(new_lens):
        new_ids[r, :L] = rng.integers(0, ALPHABET_SIZE, size=L,
                                      dtype=np.int8)

    fleet = ReplicaFleet(index, scfg, n_replicas=2, mesh=mesh,
                         fail_threshold=2, quarantine_s=args.quarantine_s,
                         max_retries=1, warmup=(qids, qlens))
    eng = AsyncEngine(fleet, max_wait_ms=2.0, name="chaos")
    epoch0 = index.epoch

    results = {"bench": "chaos_soak", "n_refs": args.n_refs,
               "shards": S, "quarantine_s": args.quarantine_s,
               "devices": jax.device_count()}
    all_futs = []            # EVERY future this run creates (none may strand)
    completed = []           # (query_j, outcome) for the bit-exact replay

    def serial(j):
        """Submit query j and wait: one batch, one dispatch call."""
        fut = eng.submit(qids[j][:qlens[j]])
        all_futs.append(fut)
        out = fut.result(timeout=120)
        if out.ok:
            completed.append((j, out))
        return out

    def snap():
        c = fleet.counters
        return {k: c[k] for k in
                ("batches", "retries", "retry_success", "replica_failures",
                 "replica_quarantines", "replica_probes",
                 "replica_readmissions", "degraded_batches",
                 "ingests", "ingest_failures")}

    plan = FaultPlan()
    # -- transient: two isolated replica faults, absorbed by retry
    plan.add("replica.query", "raise", on={3, 8})
    # -- blackout: four adjacent faults -> both replicas quarantined
    plan.add("replica.query", "raise", on={13, 14, 15, 16})
    # -- dispatch kill: engine.dispatch calls are 1/batch; phases below
    #    make calls 1..15 (transient 10, blackout 3 + 2 recovery), so the
    #    16th dispatched batch is the scripted thread death
    plan.add("engine.dispatch", "kill", on=16)
    # -- soak: first ingest apply dies; a later query batch runs slow
    plan.add("ingest.apply", "kill", on=1)
    plan.add("replica.query", "latency", on=20, delay_s=0.05)

    with plan:
        # ---- phase 1: transient replica faults --------------------------
        base = snap()
        outs = [serial(i % nq) for i in range(10)]
        assert all(o.ok for o in outs), \
            f"transient faults leaked to callers: {outs}"
        d = {k: snap()[k] - base[k] for k in base}
        assert (d["retries"], d["retry_success"]) == (2, 2), d
        assert d["replica_failures"] == 2 and \
            d["replica_quarantines"] == 0 and d["degraded_batches"] == 0, d
        csv("chaos,transient_retries,2/2 recovered")
        results["transient"] = d

        # ---- phase 2: blackout -> degraded -> probe -> readmit ----------
        base = snap()
        deg = [serial(i % nq) for i in range(3)]
        assert all((not o.ok) and getattr(o, "degraded", False)
                   for o in deg), f"blackout must degrade, got {deg}"
        assert all(o.coverage == 0.0 for o in deg[1:]), deg
        time.sleep(args.quarantine_s + 0.5)     # let quarantine expire
        back = [serial(i % nq) for i in range(2)]
        assert all(o.ok for o in back), f"readmission failed: {back}"
        d = {k: snap()[k] - base[k] for k in base}
        assert d["degraded_batches"] == 3 and \
            d["replica_quarantines"] == 2 and \
            d["replica_probes"] == 2 and d["replica_readmissions"] == 2, d
        csv("chaos,blackout,3 degraded (typed), 2 quarantined, "
            "2 probed, 2 readmitted")
        results["blackout"] = d

        # ---- phase 3: dispatch-thread death -----------------------------
        out = serial(0)
        assert (not out.ok) and out.reason == "internal" \
            and "injected" in out.detail, out
        out = serial(1)     # supervisor restarted the loop: next one serves
        assert out.ok, f"dispatch never came back: {out}"
        ds = eng.stats()["dispatch"]
        assert ds["crashes"] == 1 and ds["alive"] and not ds["degraded"], ds
        assert eng.counters["shed_internal"] == 1
        csv("chaos,dispatch_kill,1 typed internal rejection, restarted")
        results["dispatch_kill"] = dict(crashes=ds["crashes"],
                                        shed_internal=1)

        # ---- phase 4: open-loop soak with ingest kill + latency spike ---
        base = snap()
        tickets = {}

        def on_submit(i):
            if i == 8 and "killed" not in tickets:
                tickets["killed"] = fleet.ingest(new_ids, new_lens)
            if i == 20 and "retried" not in tickets:
                t1 = tickets["killed"]
                t1.wait(timeout=60)     # resolves WITH the error attached
                assert t1.error is not None and "injected" in t1.error, \
                    f"killed ingest ticket: set={t1.is_set()} err={t1.error}"
                tickets["retried"] = fleet.ingest(new_ids, new_lens)

        achieved, pct, n_shed, res = _open_loop_point(
            eng, qids, qlens, args.soak_qps, args.soak_requests,
            on_submit=on_submit)
        assert tickets["retried"].wait(timeout=120) \
            and tickets["retried"].ok, tickets["retried"].error
        assert n_shed == 0, f"soak shed {n_shed} requests"
        # _open_loop_point already proved every future resolved (result()
        # with a timeout); fold its completions into the replay set
        for i, r in enumerate(res):
            completed.append((i % nq, r))
        d = {k: snap()[k] - base[k] for k in base}
        assert d["ingest_failures"] == 1 and d["ingests"] == 1, d
        ing = fleet.stats()["ingest"]
        assert ing["crashes"] == 1 and ing["alive"] and \
            not ing["degraded"], ing
        epochs = sorted({r.epoch for r in res})
        assert epochs == [epoch0, epoch0 + 1], (
            f"soak must straddle the re-ingest epoch: {epochs}")
        csv(f"chaos,soak,{achieved:.1f} q/s achieved, 0 shed, "
            f"p95={pct['p95_ms']:.1f}ms, epochs={epochs}")
        results["soak"] = dict(achieved_qps=round(achieved, 2), shed=0,
                               epochs=[int(e) for e in epochs],
                               ingest_crashes=1,
                               **{k: round(v, 2) for k, v in pct.items()})

        # ---- every scripted fault fired; nothing is unresolved ----------
        assert not plan.unfired(), f"scripted faults never ran: " \
            f"{plan.unfired()} (calls: {plan.summary()['calls']})"
        assert all(f is None or f.done() for f in all_futs), \
            "stranded futures after the soak"

        # ---- phase 5: per-epoch bit-exactness of EVERY completed query --
        # rebuild the index from scratch at each epoch served and replay
        combined_ids, combined_lens = _concat_refs(
            np.asarray(data["ref_ids"]), np.asarray(data["ref_lens"]),
            new_ids, new_lens)
        rows_at = {epoch0: args.n_refs, epoch0 + 1: args.n_refs + 32}
        n_checked = 0
        for epoch in sorted({o.epoch for _j, o in completed}):
            rebuild = SignatureIndex.build(
                cfg, combined_ids[:rows_at[epoch]],
                combined_lens[:rows_at[epoch]])
            ref_eng = QueryEngine(rebuild, scfg,
                                  sharded=ShardedIndex(rebuild, mesh))
            js = sorted({j for j, o in completed if o.epoch == epoch})
            want = {j: ref_eng.query_batch(qids[j:j + 1], qlens[j:j + 1])
                    for j in js}
            for j, o in completed:
                if o.epoch != epoch:
                    continue
                np.testing.assert_array_equal(o.ids, want[j][0][0])
                np.testing.assert_array_equal(o.dists, want[j][1][0])
                n_checked += 1
        assert n_checked == len(completed)
        csv(f"chaos,bitexact,{n_checked} completed queries match "
            f"per-epoch rebuilds exactly")
        results["bitexact_queries"] = n_checked

        # ---- phase 6: torn write -> typed load error -> recovery --------
        idx_dir = os.path.join(args.workdir, "chaos_idx")
        index.save(idx_dir)
        with open(os.path.join(idx_dir, "manifest.json")) as fh:
            manifest = json.load(fh)
        victim = manifest["segments"][-1]["file"]
        vpath = os.path.join(idx_dir, victim)
        with open(vpath, "rb") as fh:
            orig = fh.read()
        # schedule the tear for the very next store.write call, then
        # re-write the segment through the one blessed write path — the
        # plan makes it behave like the non-atomic writer of old
        from repro.faults import atomic_write
        plan.add("store.write", "torn",
                 on=plan.calls("store.write") + 1, frac=0.4)
        try:
            atomic_write(vpath, lambda fh: fh.write(orig))
            raise AssertionError("torn write did not raise")
        except InjectedFault as e:
            assert e.kind == "torn"
        assert os.path.getsize(vpath) < len(orig), "file was not torn"
        try:
            SignatureIndex.load(idx_dir, expected_cfg=cfg)
            raise AssertionError("load() served a torn segment")
        except CorruptSegment as e:
            assert victim in e.file, e.file
        recovered = SignatureIndex.load(idx_dir, expected_cfg=cfg,
                                        recover=True)
        rec = recovered.recovery
        assert rec is not None and victim in rec["file"], rec
        assert rec["n_rows_served"] == recovered.size
        assert os.path.exists(os.path.join(idx_dir, "quarantine", victim))
        # the served prefix is bit-exact with a rebuild of those rows
        prefix = SignatureIndex.build(
            cfg, combined_ids[:rec["n_rows_served"]],
            combined_lens[:rec["n_rows_served"]])
        pe = QueryEngine(prefix, scfg, sharded=ShardedIndex(prefix, mesh))
        re_ = QueryEngine(recovered, scfg,
                          sharded=ShardedIndex(recovered, mesh))
        nb = min(nq, args.batch)
        want_id, want_d = pe.query_batch(qids[:nb], qlens[:nb])
        got_id, got_d = re_.query_batch(qids[:nb], qlens[:nb])
        np.testing.assert_array_equal(got_id, want_id)
        np.testing.assert_array_equal(got_d, want_d)
        # after recovery the rewritten manifest loads clean
        clean = SignatureIndex.load(idx_dir, expected_cfg=cfg)
        assert clean.recovery is None and clean.size == rec["n_rows_served"]
        csv(f"chaos,recovery,quarantined {rec['quarantined']} -> served "
            f"{rec['n_rows_served']} rows bit-exact")
        results["recovery"] = {k: rec[k] for k in
                               ("reason", "n_segments_dropped",
                                "n_rows_dropped", "n_rows_served")}
        results["recovery"]["file"] = victim

        results["fault_plan"] = plan.summary()

    assert eng.close(timeout=30), "dispatch thread wedged at close"
    assert fleet.close(timeout=30), "ingest thread wedged at close"

    # the block bench_delta diffs EXACTLY (deterministic by construction)
    results["fault_counters"] = dict(
        injected=plan.fired(),
        injected_by_kind={k: plan.fired(kind=k)
                          for k in ("raise", "kill", "latency", "torn")},
        retries=fleet.counters["retries"],
        retry_success=fleet.counters["retry_success"],
        replica_failures=fleet.counters["replica_failures"],
        replica_quarantines=fleet.counters["replica_quarantines"],
        replica_probes=fleet.counters["replica_probes"],
        replica_readmissions=fleet.counters["replica_readmissions"],
        degraded_batches=fleet.counters["degraded_batches"],
        ingest_failures=fleet.counters["ingest_failures"],
        shed_internal=eng.counters["shed_internal"],
        engine_degraded=eng.counters["degraded"],
        dispatch_crashes=1,
        ingest_crashes=1,
    )
    csv(f"chaos,fault_counters,{results['fault_counters']}")

    with open(args.json, "w") as fh:
        json.dump(results, fh, indent=2)
    csv(f"chaos,json_written,{args.json}")


def _concat_refs(ref_ids, ref_lens, new_ids, new_lens):
    """Concatenate two padded ref batches into one (widths may differ)."""
    import numpy as np
    from repro.core.alphabet import PAD
    W = max(ref_ids.shape[1], new_ids.shape[1])
    out = np.full((len(ref_lens) + len(new_lens), W), PAD, np.int8)
    out[:len(ref_lens), :ref_ids.shape[1]] = ref_ids
    out[len(ref_lens):, :new_ids.shape[1]] = new_ids
    return out, np.concatenate([ref_lens, new_lens]).astype(np.int32)


def main(argv=None):
    import tempfile

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (writes BENCH_chaos.json)")
    ap.add_argument("--n-refs", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=32)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--quarantine-s", type=float, default=1.5)
    ap.add_argument("--soak-qps", type=float, default=None,
                    help="offered rate for the open-loop soak phase")
    ap.add_argument("--soak-requests", type=int, default=None)
    ap.add_argument("--json", default="BENCH_chaos.json")
    ap.add_argument("--workdir", default=None,
                    help="scratch dir for the torn-write/recovery phase")
    args = ap.parse_args(argv)
    args.n_refs = args.n_refs or (512 if args.smoke else 4096)
    args.soak_requests = args.soak_requests or (40 if args.smoke else 256)
    args.soak_qps = args.soak_qps or (60.0 if args.smoke else 200.0)

    if "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"
        if "jax" in sys.modules:
            raise RuntimeError("jax imported before XLA_FLAGS was set; "
                               "run benchmarks.chaos_soak as the entry point")
    if args.workdir is None:
        with tempfile.TemporaryDirectory(prefix="chaos_soak_") as td:
            args.workdir = td
            _run(args)
    else:
        _run(args)


if __name__ == "__main__":
    main()
