"""Scalability benchmark — paper Figure 5.5 analogue.

The paper measures wall-clock vs #cores on EMR. This container has one core,
so scaling is measured structurally: the sharded MapReduce pipeline runs in
a subprocess with n host devices (n in 1,2,4,8); per-shard work and shuffle
volume decrease as 1/n while results stay exact (verified). Wall-clock on
one physical core cannot drop, so the reported metric is per-shard op counts
+ the roofline-style shuffle bytes, plus the kernel-level throughput of the
hamming sweep (the compute the shards run).

CSV: bench,shards,metric,value
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops


_SHARD_PROBE = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp, time
    from repro.core import encode_batch
    from repro.core.alphabet import AMINO_ACIDS
    from repro.core.simhash import signatures_table
    from repro.core.mapreduce import distributed_flip_join, MapReduceConfig
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ('data',))
    rng = np.random.default_rng(0)
    N = 512
    seqs = [''.join(rng.choice(list(AMINO_ACIDS), 80)) for _ in range(N)]
    ids, lens = encode_batch(seqs, 96)
    sigs = signatures_table(ids, lens, k=3, T=13, f=32)
    qid = jnp.arange(N, dtype=jnp.int32); rid = jnp.arange(N, dtype=jnp.int32)
    # capacity per (src,dst) pair: src holds ~N*34/n records spread over n
    # destinations; 4x headroom for key skew (drops are counted and must be 0)
    cap = max(N * 34 // (n * n) * 4, 1024)
    cfg = MapReduceConfig(n_shards=n, shuffle_capacity=cap,
                          max_pairs_per_shard=65536)
    t0 = time.time()
    pairs, counts, dropped = distributed_flip_join(
        sigs, sigs, qid, rid, f=32, d=1, mesh=mesh, cfg=cfg)
    jax.block_until_ready(pairs)
    t = time.time() - t0
    n_pairs = int((np.asarray(pairs)[..., 0] >= 0).sum())
    # per-shard record volume: (queries + refs*flips) / n
    per_shard = N * (1 + 33) // n
    print(f'RESULT,{n},{t:.3f},{n_pairs},{per_shard},{int(np.asarray(dropped).sum())}')
""")


def run(csv=print):
    csv("bench,shards,metric,value")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = src
        out = subprocess.run([sys.executable, "-c", _SHARD_PROBE], env=env,
                             capture_output=True, text=True, timeout=900)
        line = [l for l in out.stdout.splitlines()
                if l.startswith("RESULT")]
        if not line:
            csv(f"fig5.5,{n},ERROR,{out.stderr[-200:]!r}")
            continue
        _, shards, t, pairs, per_shard, dropped = line[0].split(",")
        csv(f"fig5.5,{shards},join_wallclock_1core_s,{t}")
        csv(f"fig5.5,{shards},records_per_shard,{per_shard}")
        csv(f"fig5.5,{shards},pairs,{pairs}")
        csv(f"fig5.5,{shards},dropped,{dropped}")

    # kernel throughput: blocked hamming sweep (the per-shard hot loop)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.integers(0, 2**32, (1024, 2), dtype=np.uint32))
    r = jnp.asarray(rng.integers(0, 2**32, (4096, 2), dtype=np.uint32))
    f = jax.jit(lambda a, b: ops.all_pairs_hamming(a, b, prefer_ref=True))
    f(q, r).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        f(q, r).block_until_ready()
    dt = (time.time() - t0) / 5
    csv(f"kernel,1,hamming_pairs_per_s,{1024*4096/dt:.3e}")
    csv(f"kernel,1,hamming_us_per_call,{dt*1e6:.1f}")
