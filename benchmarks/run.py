"""Benchmark driver — one section per paper table/figure.

  python -m benchmarks.run [--only quality|performance|scalability]

Prints CSV blocks; EXPERIMENTS.md cites these outputs.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["quality", "performance", "scalability",
                             "serving"])
    args = ap.parse_args(argv)

    from . import performance, quality, scalability, serving
    sections = {"quality": quality.run, "performance": performance.run,
                "scalability": scalability.run, "serving": serving.run}
    if args.only:
        sections = {args.only: sections[args.only]}
    for name, fn in sections.items():
        print(f"\n==== {name} ====")
        t0 = time.time()
        fn()
        print(f"==== {name} done in {time.time()-t0:.1f}s ====")


if __name__ == "__main__":
    main()
