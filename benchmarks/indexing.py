"""Indexing lifecycle benchmark — segmented add()+refresh vs full rebuild.

The acceptance metric of the append-only segment lifecycle (PR 5): growing
a served index by a delta must beat rebuilding it, on both axes —

* **add() throughput**: sealing a segment (signatures + buckets for the
  NEW rows only) + the serving replica's ``refresh()`` (delta partition +
  delta slab upload) + one served batch, vs the from-scratch path
  (recompute every signature, rebuild every bucket, re-place every slab,
  serve) and vs the PR 4-era mutation path (keep signatures, but re-bucket
  and re-place the whole table);
* **refresh latency**: ``ShardedIndex.refresh()`` alone vs a full
  ``_place()`` of the merged table.

Both paths must produce bit-exact top-k results (asserted). Emits
``BENCH_index.json`` for the nightly CI artifact trail.

  PYTHONPATH=src python -m benchmarks.indexing --smoke        # CI
  PYTHONPATH=src python -m benchmarks.indexing --n-base 8192 --n-delta 512
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _run(args):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import LSHConfig, ScalLoPS
    from repro.data import SyntheticProteinConfig, make_protein_sets
    from repro.index import ShardedIndex, SignatureIndex

    S = args.shards
    assert jax.device_count() >= S, (
        f"need {S} devices for the serving ring, got {jax.devices()}")
    mesh = Mesh(np.array(jax.devices()[:S]), ("data",))
    csv = print
    csv("bench,n_base,n_delta,metric,value")
    nb, nd = args.n_base, args.n_delta
    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=nb + nd, n_homolog_queries=args.n_queries // 2,
        n_decoy_queries=args.n_queries - args.n_queries // 2,
        ref_len_mean=150, ref_len_std=25, sub_rates=(0.05, 0.12), seed=7))
    ids, lens = data["ref_ids"], data["ref_lens"]
    cfg = LSHConfig(k=3, T=13, f=32, d=1)
    sl = ScalLoPS(cfg)
    q_sigs = sl.signatures(data["query_ids"], data["query_lens"])

    def job1(i0, i1):
        """Job 1 over rows [i0:i1) — the signature work every mutation
        path pays for the rows it (re)computes."""
        s = np.asarray(sl.signatures(ids[i0:i1], lens[i0:i1]))
        v = np.asarray(sl.feature_counts(ids[i0:i1], lens[i0:i1])) > 0
        return s, v

    # ONE warmed pipeline serves every path (the signature program jits per
    # ScalLoPS instance; sharing it keeps compile time out of the
    # steady-state comparison for all contenders equally)
    sigs_full, valid_full = job1(0, nb + nd)
    job1(nb, nb + nd)                       # warm the delta batch shape
    sigs_base, valid_base = sigs_full[:nb], valid_full[:nb]

    def fresh_base():
        idx = SignatureIndex(cfg, sigs_base, valid_base)
        idx._pipeline = sl                  # add() reuses the warm program
        sh = ShardedIndex(idx, mesh)
        sh.topk(q_sigs, k=8, cap=64)        # warm: compile + base placement
        return idx, sh

    t_seg, t_refresh, t_save_delta, t_serve_delta = [], [], [], []
    seg_result = None
    for _ in range(args.reps):
        idx, sh = fresh_base()
        t0 = time.perf_counter()            # the ingest: new-row signatures
        idx.add(ids[nb:], lens[nb:])        # + segment seal + delta refresh
        sh.refresh()
        t_seg.append(time.perf_counter() - t0)
        assert sh._delta is not None, "delta must ride along, not re-place"
        seg_result = sh.topk(q_sigs, k=8, cap=64)
        t0 = time.perf_counter()            # steady-state serve through the
        sh.topk(q_sigs, k=8, cap=64)        # base+delta ring
        t_serve_delta.append(time.perf_counter() - t0)
        # refresh() alone (fresh replica, same grown index)
        idx2, sh2 = fresh_base()
        idx2.add(ids[nb:], lens[nb:])
        idx2.seal()
        t0 = time.perf_counter()
        sh2.refresh()
        t_refresh.append(time.perf_counter() - t0)

    # O(delta) persistence: append one segment vs rewrite everything
    import tempfile
    d = tempfile.mkdtemp(prefix="bench_idx_")
    idx, _ = fresh_base()
    idx.save(os.path.join(d, "idx"))
    idx.add(ids[nb:], lens[nb:])
    t0 = time.perf_counter()
    n_written = idx.save(os.path.join(d, "idx"))
    t_save_delta.append(time.perf_counter() - t0)
    assert n_written == 1, "append-only save must write only the delta"

    # ---- rebuild paths ---------------------------------------------------
    t_rebuild, t_pr4, t_place, t_save_full, t_serve_base = [], [], [], [], []
    rebuild_result = None
    for _ in range(args.reps):
        t0 = time.perf_counter()            # from-scratch: EVERY signature
        s, v = job1(0, nb + nd)             # recomputed + full bucket sort
        full = SignatureIndex(cfg, s, v)
        full.seal()
        sh_full = ShardedIndex(full, mesh)  # full placement
        t_rebuild.append(time.perf_counter() - t0)
        rebuild_result = sh_full.topk(q_sigs, k=8, cap=64)
        t0 = time.perf_counter()            # steady-state serve, base-only
        sh_full.topk(q_sigs, k=8, cap=64)   # ring (the delta ring's
        t_serve_base.append(time.perf_counter() - t0)   # comparator)
        # PR 4-era add(): new-row signatures appended, but then the WHOLE
        # table re-bucketed and re-placed (the invalidate-and-rebuild path
        # this PR deleted)
        t0 = time.perf_counter()
        ds, dv = job1(nb, nb + nd)
        pr4 = SignatureIndex(cfg, np.concatenate([sigs_base, ds]),
                             np.concatenate([valid_base, dv]))
        pr4.seal()
        ShardedIndex(pr4, mesh)             # full re-bucket + full re-place
        t_pr4.append(time.perf_counter() - t0)
        # full placement alone (the refresh comparator)
        full2 = SignatureIndex(cfg, s, v)
        full2.seal()
        t0 = time.perf_counter()
        ShardedIndex(full2, mesh)
        t_place.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    full.save(os.path.join(d, "full"))
    t_save_full.append(time.perf_counter() - t0)

    # ---- bit-exactness + report -----------------------------------------
    np.testing.assert_array_equal(seg_result[0], rebuild_result[0])
    np.testing.assert_array_equal(seg_result[1], rebuild_result[1])
    csv(f"indexing,{nb},{nd},bitexact,1")

    def best(ts):
        return min(ts)

    results = {
        "bench": "indexing", "n_base": nb, "n_delta": nd, "n_shards": S,
        "segmented_add_refresh_s": round(best(t_seg), 4),
        "rebuild_s": round(best(t_rebuild), 4),
        "pr4_add_s": round(best(t_pr4), 4),
        "refresh_s": round(best(t_refresh), 4),
        "place_s": round(best(t_place), 4),
        "save_delta_s": round(best(t_save_delta), 4),
        "save_full_s": round(best(t_save_full), 4),
        "serve_batch_s": {      # steady-state serving cost per placement
            "base_ring": round(best(t_serve_base), 4),
            "delta_ring": round(best(t_serve_delta), 4),
        },
        "add_rows_per_s": {
            "segmented": round(nd / best(t_seg), 1),
            "rebuild": round(nd / best(t_rebuild), 1),
            "pr4_add": round(nd / best(t_pr4), 1),
        },
        "speedup": {
            "vs_rebuild": round(best(t_rebuild) / best(t_seg), 2),
            "vs_pr4_add": round(best(t_pr4) / best(t_seg), 2),
            "refresh_vs_place": round(best(t_place) / best(t_refresh), 2),
        },
        "bitexact": True,
    }
    for k in ("segmented_add_refresh_s", "rebuild_s", "pr4_add_s",
              "refresh_s", "place_s", "save_delta_s", "save_full_s"):
        csv(f"indexing,{nb},{nd},{k},{results[k]}")
    for k, v in results["speedup"].items():
        csv(f"indexing,{nb},{nd},speedup_{k},{v}")

    with open(args.json, "w") as fh:
        json.dump(results, fh, indent=2)
    csv(f"indexing,{nb},{nd},json_written,{args.json}")

    assert results["speedup"]["vs_rebuild"] > 1.0, (
        f"segmented add()+refresh must beat the full rebuild "
        f"(got {results['speedup']['vs_rebuild']}x at n_base={nb}, "
        f"n_delta={nd})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (writes BENCH_index.json)")
    ap.add_argument("--n-base", type=int, default=None)
    ap.add_argument("--n-delta", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default="BENCH_index.json")
    args = ap.parse_args(argv)
    args.n_base = args.n_base or (1024 if args.smoke else 4096)
    args.n_delta = args.n_delta or (128 if args.smoke else 512)

    if "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"
        if "jax" in sys.modules:
            raise RuntimeError("jax imported before XLA_FLAGS was set; "
                               "run benchmarks.indexing as the entry point")
    _run(args)


if __name__ == "__main__":
    main()
