"""Non-blocking bench regression check: fresh BENCH json vs committed baseline.

Compares the latency knee of a just-produced ``BENCH_serve.json`` against
the committed baseline (``benchmarks/baselines/BENCH_serve.json``) and
prints a GitHub Actions ``::warning::`` annotation when the knee regressed
by more than the threshold — achieved QPS down >20% or knee p95 up >20%.

ALWAYS exits 0: nightly hardware is shared and noisy, so a knee delta is
a signal to look at, not a gate to flake on. The trace artifact uploaded
next to the bench json is the first thing to look *at* — the slow-sample
lifecycle attribution says whether the regression is serving or lifecycle.

  python benchmarks/bench_delta.py BENCH_serve.json \
      --baseline benchmarks/baselines/BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path):
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as e:
        print(f"bench_delta: cannot read {path}: {e}")
        return None


def compare_chaos(fresh: dict, base: dict) -> list[str]:
    """Chaos artifacts: the fault counters are DETERMINISTIC (the plan
    scripts every fault by call number), so any drift at all — one more
    retry, one fewer quarantine — means the failure semantics changed
    and is flagged; there is no noise threshold to hide behind."""
    fc, bc = fresh.get("fault_counters"), base.get("fault_counters")
    if not fc or not bc:
        return [f"fault_counters missing (fresh={bool(fc)}, "
                f"baseline={bool(bc)})"]
    warnings = []
    for key in sorted(set(fc) | set(bc)):
        fv, bv = fc.get(key), bc.get(key)
        if fv != bv:
            warnings.append(
                f"fault-path count {key!r} changed: {bv} -> {fv} "
                f"(deterministic — this is a semantics change, not noise)")
    return warnings


def compare_allpairs(fresh: dict, base: dict,
                     threshold: float = 0.20) -> list[str]:
    """All-pairs artifacts: score-phase throughput (device + per-DP-kernel
    pairs/s) and emission-phase candidate throughput (per join_impl) down
    more than the threshold are flagged; so are the wavefront and SpGEMM
    emission speedups slipping under their 2x acceptance floors."""
    warnings = []
    for sect in ("pr2", "device"):
        fv = (fresh.get(sect) or {}).get("pairs_per_sec", 0.0)
        bv = (base.get(sect) or {}).get("pairs_per_sec", 0.0)
        if bv > 0 and fv < (1 - threshold) * bv:
            warnings.append(
                f"{sect} score-phase pairs/s regressed "
                f"{100 * (1 - fv / bv):.0f}%: {fv:.0f} vs baseline {bv:.0f}")
    fd, bd = fresh.get("dp_kernels") or {}, base.get("dp_kernels") or {}
    for key in sorted(set(fd) | set(bd)):
        fv, bv = fd.get(key), bd.get(key)
        if not (isinstance(fv, dict) and isinstance(bv, dict)):
            continue
        fp, bp = fv.get("pairs_per_sec", 0.0), bv.get("pairs_per_sec", 0.0)
        if bp > 0 and fp < (1 - threshold) * bp:
            warnings.append(
                f"dp kernel {key} pairs/s regressed "
                f"{100 * (1 - fp / bp):.0f}%: {fp:.0f} vs baseline {bp:.0f}")
    sp = fd.get("speedup_wavefront_vs_rowwave")
    if sp is not None and sp < 2.0:
        warnings.append(
            f"wavefront speedup vs rowwave at {sp:.2f}x — under the 2x "
            f"acceptance floor")
    fe, be = fresh.get("emission") or {}, base.get("emission") or {}
    for impl in ("legacy", "spgemm"):
        fv = (fe.get(impl) or {}).get("cands_per_sec", 0.0)
        bv = (be.get(impl) or {}).get("cands_per_sec", 0.0)
        if bv > 0 and fv < (1 - threshold) * bv:
            warnings.append(
                f"emission ({impl}) candidates/s regressed "
                f"{100 * (1 - fv / bv):.0f}%: {fv:.0f} vs baseline {bv:.0f}")
    esp = fe.get("speedup_spgemm_vs_legacy")
    if esp is not None and esp < 2.0:
        warnings.append(
            f"SpGEMM emission speedup vs legacy at {esp:.2f}x — under the "
            f"2x acceptance floor")
    return warnings


def compare(fresh: dict, base: dict, threshold: float = 0.20) -> list[str]:
    """Return warning strings for every knee metric past the threshold."""
    if fresh.get("bench") == "chaos_soak" or "fault_counters" in fresh:
        return compare_chaos(fresh, base)
    if fresh.get("bench") == "allpairs":
        return compare_allpairs(fresh, base, threshold)
    warnings = []
    fk, bk = fresh.get("knee"), base.get("knee")
    if not fk or not bk:
        return [f"knee missing (fresh={bool(fk)}, baseline={bool(bk)}) — "
                f"the sweep found no absorbed rate"]
    qps_f, qps_b = fk.get("achieved_qps", 0.0), bk.get("achieved_qps", 0.0)
    if qps_b > 0 and qps_f < (1 - threshold) * qps_b:
        warnings.append(
            f"knee achieved QPS regressed {100 * (1 - qps_f / qps_b):.0f}%: "
            f"{qps_f:.1f} vs baseline {qps_b:.1f}")
    p95_f, p95_b = fk.get("p95_ms", 0.0), bk.get("p95_ms", 0.0)
    if p95_b > 0 and p95_f > (1 + threshold) * p95_b:
        warnings.append(
            f"knee p95 regressed {100 * (p95_f / p95_b - 1):.0f}%: "
            f"{p95_f:.1f}ms vs baseline {p95_b:.1f}ms")
    # compiles are deterministic (no noise excuse): ANY growth is a flag
    nc_f = sum(fresh.get("jit_compiles", {}).values())
    nc_b = sum(base.get("jit_compiles", {}).values())
    if nc_b and nc_f > nc_b:
        warnings.append(
            f"pre-sweep compile count grew {nc_b} -> {nc_f} "
            f"({fresh.get('jit_compiles')}) — a shape or cache key changed")
    return warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="BENCH json produced by this run")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_serve.json")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="relative knee regression that triggers a warning")
    args = ap.parse_args(argv)

    fresh, base = _load(args.fresh), _load(args.baseline)
    if fresh is None or base is None:
        return 0    # missing artifact: nothing to compare, never block
    warnings = compare(fresh, base, args.threshold)
    chaos = fresh.get("bench") == "chaos_soak" or "fault_counters" in fresh
    allpairs = fresh.get("bench") == "allpairs"
    title = ("chaos fault-count drift" if chaos
             else "allpairs throughput regression" if allpairs
             else "serve_slo knee regression")
    for w in warnings:
        print(f"::warning title={title}::{w}")
    if not warnings and chaos:
        print(f"bench_delta: chaos fault counters identical to baseline "
              f"({len(fresh.get('fault_counters', {}))} counters)")
    elif not warnings and allpairs:
        dv = (fresh.get("device") or {}).get("pairs_per_sec", 0.0)
        sp = (fresh.get("dp_kernels") or {}).get(
            "speedup_wavefront_vs_rowwave")
        print(f"bench_delta: allpairs throughput within "
              f"{args.threshold:.0%} of baseline (device {dv:.0f} pairs/s"
              + (f", wavefront {sp:.2f}x rowwave" if sp else "") + ")")
    elif not warnings:
        fk, bk = fresh["knee"], base["knee"]
        print(f"bench_delta: knee within {args.threshold:.0%} of baseline "
              f"(achieved {fk['achieved_qps']:.1f} vs {bk['achieved_qps']:.1f}"
              f" q/s, p95 {fk['p95_ms']:.1f} vs {bk['p95_ms']:.1f} ms)")
    return 0        # non-blocking by design (see module docstring)


if __name__ == "__main__":
    sys.exit(main())
