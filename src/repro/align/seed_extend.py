"""BLAST-like seed-and-extend baseline (paper §2.1, Algorithm 1).

The paper's quality methodology compares ScalLoPS' emitted pairs against the
pairs BLAST finds ("intersection pairs", §5.2) and its performance against
BLAST's seed-and-extend scan (Table 5.3). This module implements that
baseline faithfully in structure:

  1. tokenize queries into k-shingles;
  2. expand each shingle to its BLOSUM62 neighbourhood (score >= T) — reusing
     the core's neighbour matmul;
  3. probe an inverted index word_id -> (ref, pos) for exact seed matches;
  4. ungapped extension: best-scoring segment through a seeded diagonal
     (Kadane on the diagonal's substitution scores — the maximal HSP);
  5. report pairs whose best HSP score >= S_min.

Indexing/bookkeeping is numpy (hash-join territory); the substitution-score
diagonals come from the same BLOSUM tensors the core uses.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.alphabet import BLOSUM62_PADDED
from ..core.neighbors import neighbor_scores
from ..core.shingle import extract_shingles, shingle_ids


def _kadane(x: np.ndarray) -> int:
    """Max-subarray sum (the maximal ungapped HSP score on a diagonal)."""
    best = cur = 0
    for v in x:
        cur = max(0, cur + int(v))
        best = max(best, cur)
    return best


@dataclass
class SeedExtendBaseline:
    k: int = 3
    T: int = 11       # BLAST's protein default neighbourhood threshold
    s_min: int = 25   # minimal HSP score to report a pair

    def build_index(self, ref_ids: np.ndarray, ref_lens: np.ndarray):
        """Inverted index over reference shingle word ids."""
        import jax.numpy as jnp
        sh, mask = extract_shingles(jnp.asarray(ref_ids),
                                    jnp.asarray(ref_lens), self.k)
        wid = np.asarray(shingle_ids(sh))          # (R, S)
        index: dict[int, list[tuple[int, int]]] = {}
        R, S = wid.shape
        for r in range(R):
            for p in range(S):
                w = int(wid[r, p])
                if w >= 0:
                    index.setdefault(w, []).append((r, p))
        self._index = {w: np.asarray(v, np.int32) for w, v in index.items()}
        self._refs = (np.asarray(ref_ids), np.asarray(ref_lens))
        return self

    def search(self, q_ids: np.ndarray, q_lens: np.ndarray):
        """Returns list of (query_idx, ref_idx, hsp_score)."""
        import jax.numpy as jnp
        ref_ids, ref_lens = self._refs
        B = BLOSUM62_PADDED
        sh, mask = extract_shingles(jnp.asarray(q_ids),
                                    jnp.asarray(q_lens), self.k)
        # neighbourhood expansion: (N, S, W) >= T — evaluated per query to
        # bound memory (W = 20^k).
        results = []
        N = q_ids.shape[0]
        for qi in range(N):
            scores = np.asarray(neighbor_scores(sh[qi], self.k))  # (S, W)
            valid = np.asarray(mask[qi])
            pos_list, word_list = np.nonzero((scores >= self.T)
                                             & valid[:, None])
            # seed probe: group candidate (ref, diag) pairs
            diag_hits: dict[tuple[int, int], bool] = {}
            for p, w in zip(pos_list.tolist(), word_list.tolist()):
                entries = self._index.get(int(w))
                if entries is None:
                    continue
                for r, rp in entries:
                    diag_hits[(int(r), int(rp) - int(p))] = True
            # ungapped extension per seeded (ref, diagonal)
            q = np.asarray(q_ids[qi])[: int(q_lens[qi])].astype(np.int64)
            best_per_ref: dict[int, int] = {}
            for (r, dg) in diag_hits:
                ref = ref_ids[r][: int(ref_lens[r])].astype(np.int64)
                i0 = max(0, -dg)
                j0 = i0 + dg
                L = min(len(q) - i0, len(ref) - j0)
                if L < self.k:
                    continue
                diag_scores = B[q[i0:i0 + L], ref[j0:j0 + L]]
                s = _kadane(diag_scores)
                if s > best_per_ref.get(r, -1):
                    best_per_ref[r] = s
            for r, s in best_per_ref.items():
                if s >= self.s_min:
                    results.append((qi, r, int(s)))
        return results
