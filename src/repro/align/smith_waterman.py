"""Smith-Waterman local alignment in JAX + host-side traceback for PID.

The paper evaluates result quality by the *percent identity* (PID) of the
alignment of each emitted (query, reference) pair (§5.2). The DP recurrence
runs on-device (scan over query rows, vectorized over the reference axis and
over pairs via vmap); the O(L) traceback that extracts matched positions runs
host-side in numpy (pairs to score are few; the DP is the hot part).

Linear gap penalty (the paper's quality analysis uses ungapped/simple-gap
BLAST alignments; gap open == extend keeps the DP a 3-way max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alphabet import BLOSUM62_PADDED, PAD

GAP = -4  # linear gap penalty (BLOSUM62-compatible default)


@functools.partial(jax.jit, static_argnames=("return_matrix",))
def _sw_dp(q, r, return_matrix: bool = False):
    """One pair: q (Lq,) int8, r (Lr,) int8 (PAD-padded).

    Returns (best_score, H) where H is the (Lq+1, Lr+1) DP matrix if
    requested (int32), else a dummy scalar.
    """
    B = jnp.asarray(BLOSUM62_PADDED)
    Lq, Lr = q.shape[0], r.shape[0]
    sub = B[q.astype(jnp.int32)][:, r.astype(jnp.int32)]       # (Lq, Lr)
    # padded positions never improve the local score
    valid = (q[:, None] != PAD) & (r[None, :] != PAD)
    sub = jnp.where(valid, sub, -10**6)

    def row_step(prev_row, sub_row):
        # prev_row: H[i-1, :] (Lr+1,)
        def col_step(diag_and_left, inputs):
            h_diag, h_left = diag_and_left
            s, h_up = inputs
            h = jnp.maximum(0, jnp.maximum(h_diag + s,
                                           jnp.maximum(h_up + GAP,
                                                       h_left + GAP)))
            return (h_up, h), h

        (_, _), row_tail = jax.lax.scan(
            col_step, (prev_row[0], jnp.int32(0)),
            (sub_row, prev_row[1:]))
        row = jnp.concatenate([jnp.zeros(1, jnp.int32), row_tail])
        return row, row

    H0 = jnp.zeros(Lr + 1, jnp.int32)
    _, rows = jax.lax.scan(row_step, H0, sub)
    H = jnp.concatenate([H0[None], rows], axis=0)               # (Lq+1, Lr+1)
    best = jnp.max(H)
    return (best, H) if return_matrix else (best, jnp.int32(0))


def sw_score(q, r) -> int:
    """Best local alignment score of one encoded pair."""
    s, _ = _sw_dp(jnp.asarray(q), jnp.asarray(r))
    return int(s)


@functools.partial(jax.jit)
def _sw_scores_batch(qs, rs):
    return jax.vmap(lambda a, b: _sw_dp(a, b)[0])(qs, rs)


def sw_align_batch(qs, rs) -> np.ndarray:
    """Batched best-scores: (N, Lq) x (N, Lr) -> (N,) int32."""
    return np.asarray(_sw_scores_batch(jnp.asarray(qs), jnp.asarray(rs)))


def _traceback_pid(H: np.ndarray, q: np.ndarray, r: np.ndarray,
                   sub: np.ndarray) -> tuple[float, int]:
    """Host traceback from argmax(H): returns (PID %, alignment length)."""
    i, j = np.unravel_index(np.argmax(H), H.shape)
    ident = 0
    length = 0
    while i > 0 and j > 0 and H[i, j] > 0:
        h = H[i, j]
        if h == H[i - 1, j - 1] + sub[i - 1, j - 1]:
            ident += int(q[i - 1] == r[j - 1])
            length += 1
            i, j = i - 1, j - 1
        elif h == H[i - 1, j] + GAP:
            length += 1
            i -= 1
        else:
            length += 1
            j -= 1
    return (100.0 * ident / max(length, 1), length)


def percent_identity(q, r) -> tuple[float, int, int]:
    """PID of the best local alignment of one encoded pair.

    Returns (pid_percent, alignment_length, score).
    """
    qj, rj = jnp.asarray(q), jnp.asarray(r)
    score, H = _sw_dp(qj, rj, return_matrix=True)
    B = BLOSUM62_PADDED
    qn, rn = np.asarray(q), np.asarray(r)
    sub = B[qn.astype(np.int64)][:, rn.astype(np.int64)]
    pid, length = _traceback_pid(np.asarray(H), qn, rn, sub)
    return pid, length, int(score)


def batch_percent_identity(pairs, q_ids, q_lens, r_ids, r_lens) -> np.ndarray:
    """PID for each (qi, ri) row of a pair buffer; invalid rows -> nan."""
    out = np.full(len(pairs), np.nan)
    for n, (qi, ri, *_) in enumerate(np.asarray(pairs)):
        if qi < 0:
            continue
        q = q_ids[qi][: int(q_lens[qi])]
        r = r_ids[ri][: int(r_lens[ri])]
        out[n] = percent_identity(q, r)[0]
    return out
