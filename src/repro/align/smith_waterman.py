"""Smith-Waterman local alignment in JAX + host-side traceback for PID.

The paper evaluates result quality by the *percent identity* (PID) of the
alignment of each emitted (query, reference) pair (§5.2). The DP runs
on-device as a *row wave*: with a linear gap penalty the within-row
dependency

    H[i,j] = max(A[j], H[i,j-1] + GAP),
    A[j]   = max(0, H[i-1,j-1] + s[i,j], H[i-1,j] + GAP)

has the closed form  H[i,j] = max_{t<=j} (A[t] + GAP*(j-t)), a max-plus
prefix scan:  H[i,1:] = cummax(A + c*t) - c*t  with c = -GAP.  (A >= 0 makes
the max(0, .) clamp automatic.)  Each row is therefore one vectorized cummax
over the reference axis instead of a sequential column scan — the whole DP
is a single `lax.scan` over query rows, vmapped over pairs, so a (B, Lq, Lr)
pair block scores in one jitted program (the "SW wave" the all-pairs tiler
dispatches).  Cell values are integer and identical to the classic
recurrence, so scores, DP matrices, and tracebacks are bit-exact with the
per-pair path.

The O(L) traceback that extracts matched positions runs host-side in numpy
(pairs to trace are few; the DP is the hot part).

Linear gap penalty (the paper's quality analysis uses ungapped/simple-gap
BLAST alignments; gap open == extend keeps the DP a 3-way max).

The anti-diagonal *wavefront* sweep (:mod:`repro.align.gotoh`) has since
superseded this row wave as the default score-only kernel
(``dp_kernel="wavefront"``, ~2.8x on CPU, affine gaps supported); the row
wave remains the ``"rowwave"`` fallback and the PID/matrix path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alphabet import BLOSUM62_PADDED, PAD
from ..obs import trace_sentinel

GAP = -4     # linear gap penalty (BLOSUM62-compatible default)
NEG = -10**6  # masked-substitution sentinel (padded positions never win)


def _sub_matrix(q, r):
    """(Lq,) x (Lr,) int8 -> (Lq, Lr) int32 substitution scores,
    PAD-masked (a masked cell can never win the 3-way max)."""
    B = jnp.asarray(BLOSUM62_PADDED)
    sub = B[q.astype(jnp.int32)][:, r.astype(jnp.int32)]
    valid = (q[:, None] != PAD) & (r[None, :] != PAD)
    return jnp.where(valid, sub, NEG)


def _wave_row(prev_row, sub_row):
    """One DP row via the max-plus prefix scan (see module docstring).

    prev_row: H[i-1, :] (Lr+1,);  sub_row: s[i, :] (Lr,), both int32.
    Returns H[i, :] (Lr+1,) int32, cell-exact with the classic recurrence.
    """
    c = jnp.int32(-GAP)
    a = jnp.maximum(0, jnp.maximum(prev_row[:-1] + sub_row,
                                   prev_row[1:] + GAP))
    t = jnp.arange(1, a.shape[0] + 1, dtype=jnp.int32)
    p = jax.lax.cummax(a + c * t)
    return jnp.concatenate([jnp.zeros(1, jnp.int32), p - c * t])


@functools.partial(jax.jit, static_argnames=("return_matrix",))
def _sw_dp(q, r, return_matrix: bool = False):
    """One pair: q (Lq,) int8, r (Lr,) int8 (PAD-padded).

    Returns (best_score, H) where H is the (Lq+1, Lr+1) DP matrix if
    requested (int32), else a dummy scalar.

    Both paths are plain int32 scans. The row wave is the *fallback* DP
    (``dp_kernel="rowwave"``); the int16-carry + unrolled-scan variant it
    once had is retired — the anti-diagonal wavefront (`repro.align.gotoh`)
    replaced it as the fast path and the narrowing bought nothing on top
    of the int32 row wave worth its guard plumbing (1.13x, vs 2.8x for
    the wavefront; see ROADMAP "Perf ledger").
    """
    if return_matrix:
        sub = _sub_matrix(q, r)
        H0 = jnp.zeros(r.shape[0] + 1, jnp.int32)
        _, rows = jax.lax.scan(
            lambda prev, s: (lambda row: (row, row))(
                _wave_row(prev, s)),
            H0, sub)
        H = jnp.concatenate([H0[None], rows], axis=0)   # (Lq+1, Lr+1)
        return jnp.max(H), H
    # score-only: carry a running max instead of materializing H
    sub = _sub_matrix(q, r)
    H0 = jnp.zeros(r.shape[0] + 1, jnp.int32)

    def step(carry, s):
        prev, best = carry
        row = _wave_row(prev, s)
        return (row, jnp.maximum(best, jnp.max(row))), None

    (_, best), _ = jax.lax.scan(step, (H0, jnp.zeros((), jnp.int32)), sub)
    return best, jnp.int32(0)


def sw_score(q, r) -> int:
    """Best local alignment score of one encoded pair."""
    s, _ = _sw_dp(jnp.asarray(q), jnp.asarray(r))
    return int(s)


@jax.jit
def _sw_scores_batch(qs, rs):
    return jax.vmap(lambda a, b: _sw_dp(a, b)[0])(qs, rs)


def sw_scores_device(qs, rs) -> jax.Array:
    """Device-resident wave entry: (B, Lq) x (B, Lr) int8 device (or host)
    arrays -> (B,) int32 best scores, returned *on device* without a host
    sync — the all-pairs scheduler chains this behind its fused gather and
    drains results through an async ring (`repro.allpairs.tiles`)."""
    return _sw_scores_batch(qs, rs)


# ------------------------------------------------------------ device gather
def gather_rows(ids_dev, lens_dev, idx, L: int):
    """Fused wave gather: (N, Lmax) device corpus -> (B, L) PAD-masked block
    for row indices ``idx`` (idx < 0 marks padding slots -> all-PAD rows).
    The corpus is uploaded once; per-wave H2D traffic is just ``idx``."""
    safe = jnp.maximum(idx, 0)
    rows = ids_dev[safe, :min(L, ids_dev.shape[1])]
    if rows.shape[1] < L:       # padded ladder exceeds the corpus width
        rows = jnp.pad(rows, ((0, 0), (0, L - rows.shape[1])),
                       constant_values=PAD)
    ln = jnp.where(idx >= 0, lens_dev[safe], 0)
    pos = jnp.arange(L, dtype=jnp.int32)[None, :]
    return jnp.where(pos < ln[:, None], rows, PAD)


def dp_scores_block(qm, rm, *, dp_kernel: str = "wavefront",
                    gap_mode: str = "linear", gap_open: int | None = None,
                    gap_extend: int | None = None) -> jax.Array:
    """Route a gathered (B, Lq) x (B, Lr) pair block to a DP sweep.

    ``dp_kernel`` picks the sweep order — ``"wavefront"`` (anti-diagonal,
    `repro.align.gotoh`, the fast default) or ``"rowwave"`` (the int32
    row-wave fallback, linear-gap only). ``gap_mode`` picks the penalty
    model — ``"linear"`` (scores identical under both kernels) or
    ``"affine"`` (Gotoh; wavefront-only). Traceable: safe to call under an
    enclosing jit with the knobs static.
    """
    if gap_mode not in ("linear", "affine"):
        raise ValueError(f"unknown gap_mode {gap_mode!r}")
    if dp_kernel not in ("wavefront", "rowwave"):
        raise ValueError(f"unknown dp_kernel {dp_kernel!r}")
    from .gotoh import GAP_EXTEND, GAP_OPEN, _wave_affine_impl, \
        _wave_linear_impl
    if gap_mode == "affine":
        if dp_kernel == "rowwave":
            raise ValueError("affine gaps need dp_kernel='wavefront' "
                             "(the row wave's prefix-scan closed form "
                             "only holds for linear penalties)")
        return _wave_affine_impl(
            qm, rm, GAP_OPEN if gap_open is None else gap_open,
            GAP_EXTEND if gap_extend is None else gap_extend)
    if dp_kernel == "rowwave":
        return _sw_scores_batch(qm, rm)
    return _wave_linear_impl(qm, rm, GAP if gap_open is None else gap_open)


@functools.partial(jax.jit, static_argnames=(
    "Lq", "Lr", "dp_kernel", "gap_mode", "gap_open", "gap_extend"))
@trace_sentinel("sw_gather")
def sw_gather_scores(q_ids, q_lens, r_ids, r_lens, qi, ri, *,
                     Lq: int, Lr: int, dp_kernel: str = "wavefront",
                     gap_mode: str = "linear", gap_open: int | None = None,
                     gap_extend: int | None = None) -> jax.Array:
    """ONE jitted program: gather both pair sides from device-resident
    corpora and run the full SW wave. (qi, ri) (B,) int32 with -1 padding;
    padding slots score 0. Used by the all-pairs scheduler (q_ids is r_ids)
    and the serving re-rank (queries vs the reference store). DP routing
    knobs are static (see :func:`dp_scores_block`); defaults — wavefront
    sweep, linear gaps — keep scores bit-exact with the historical
    row-wave path."""
    qm = gather_rows(q_ids, q_lens, qi, Lq)
    rm = gather_rows(r_ids, r_lens, ri, Lr)
    return dp_scores_block(qm, rm, dp_kernel=dp_kernel, gap_mode=gap_mode,
                           gap_open=gap_open, gap_extend=gap_extend)


# ------------------------------------------------------------ ungapped X-drop
_UNROLL = 16       # scan unroll: amortizes CPU per-step dispatch overhead
_INT16_MAX_L = 1024  # int16 carries are exact while 11*L + margins < 2^15


def _ungapped_pair(q, r, x: int | None, dtype):
    """Best X-drop-terminated ungapped diagonal run of one padded pair.

    Cell (i, j) extends the run of (i-1, j-1) on its diagonal:

        c[i,j] = cur[i-1,j-1] + s[i,j]

    and the run *restarts* (c -> 0, run-best -> 0) when it goes non-positive
    (Kadane's reset — local alignments never keep negative prefixes) or,
    with finite ``x``, when it X-drops: the run fell more than ``x`` below
    its own running best (BLAST's ungapped-extension termination rule). The
    returned score is the max of c over all cells; ``x=None`` is the x->inf
    limit — exactly the best ungapped local segment score (max-subarray per
    diagonal) — and drops the run-best carry from the recurrence.

    Indexing the carries by reference column j makes the diagonal
    predecessor a right-shift of the carry row, so each DP row is
    elementwise — no prefix scan — which (plus int16 lanes for short waves
    and an unrolled scan) is what makes this a cheap prefilter for the
    gapped wave.
    """
    # masked cells: any run is killed, yet cur + neg can't underflow dtype
    neg = dtype(-(1 << 14)) if dtype == jnp.int16 else jnp.int32(NEG)
    B = jnp.asarray(BLOSUM62_PADDED, dtype)
    sub = B[q.astype(jnp.int32)][:, r.astype(jnp.int32)]
    valid = (q[:, None] != PAD) & (r[None, :] != PAD)
    sub = jnp.where(valid, sub, neg)
    Lr = sub.shape[1]
    z = jnp.zeros(Lr, dtype)

    if x is None:
        def row(carry, s_row):
            cur, gbest = carry
            cur_s = jnp.concatenate([jnp.zeros(1, dtype), cur[:-1]])
            c = jnp.maximum(cur_s + s_row, 0)
            return (c, jnp.maximum(gbest, jnp.max(c))), None

        (_, best), _ = jax.lax.scan(row, (z, jnp.zeros((), dtype)), sub,
                                    unroll=_UNROLL)
    else:
        # any x above the max possible run score (11 * L) never triggers a
        # drop, so clamping keeps huge margins exact AND inside the dtype
        cap = (1 << 14) if dtype == jnp.int16 else (1 << 30)
        xv = dtype(min(int(x), cap))

        def row(carry, s_row):
            cur, rbest, gbest = carry
            cur_s = jnp.concatenate([jnp.zeros(1, dtype), cur[:-1]])
            rb_s = jnp.concatenate([jnp.zeros(1, dtype), rbest[:-1]])
            c = cur_s + s_row
            drop = (c <= 0) | (rb_s - c > xv)
            c = jnp.where(drop, 0, c).astype(dtype)
            rb = jnp.where(drop, 0, jnp.maximum(rb_s, c)).astype(dtype)
            return (c, rb, jnp.maximum(gbest, jnp.max(c))), None

        (_, _, best), _ = jax.lax.scan(
            row, (z, z, jnp.zeros((), dtype)), sub, unroll=_UNROLL)
    return best.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("x",))
def _ungapped_batch(qs, rs, x: int | None = None):
    small = max(qs.shape[1], rs.shape[1]) <= _INT16_MAX_L
    dtype = jnp.int16 if small else jnp.int32
    return jax.vmap(lambda q, r: _ungapped_pair(q, r, x, dtype))(qs, rs)


def ungapped_xdrop_scores(qs, rs, *, x: int | None = None) -> jax.Array:
    """Batched ungapped X-drop scores: (B, Lq) x (B, Lr) int8 -> (B,) int32,
    on device (no host sync). ``x=None`` disables the drop test (plain best
    ungapped segment, the max-recall and fastest setting). Always a lower
    bound of the gapped SW score, so thresholding on it never *adds* pairs —
    the all-pairs prefilter contract.
    """
    return _ungapped_batch(jnp.asarray(qs), jnp.asarray(rs), x)


@jax.jit
def _sw_batch_with_matrix(qs, rs):
    def one(q, r):
        best, H = _sw_dp(q, r, return_matrix=True)
        return best, H
    return jax.vmap(one)(qs, rs)


def sw_align_batch(qs, rs) -> np.ndarray:
    """Batched best-scores: (N, Lq) x (N, Lr) -> (N,) int32 (one jit call)."""
    return np.asarray(_sw_scores_batch(jnp.asarray(qs), jnp.asarray(rs)))


def _traceback_pid(H: np.ndarray, q: np.ndarray, r: np.ndarray,
                   sub: np.ndarray) -> tuple[float, int]:
    """Host traceback from argmax(H): returns (PID %, alignment length)."""
    i, j = np.unravel_index(np.argmax(H), H.shape)
    ident = 0
    length = 0
    while i > 0 and j > 0 and H[i, j] > 0:
        h = H[i, j]
        if h == H[i - 1, j - 1] + sub[i - 1, j - 1]:
            ident += int(q[i - 1] == r[j - 1])
            length += 1
            i, j = i - 1, j - 1
        elif h == H[i - 1, j] + GAP:
            length += 1
            i -= 1
        else:
            length += 1
            j -= 1
    return (100.0 * ident / max(length, 1), length)


def percent_identity(q, r) -> tuple[float, int, int]:
    """PID of the best local alignment of one encoded pair.

    Returns (pid_percent, alignment_length, score).
    """
    qj, rj = jnp.asarray(q), jnp.asarray(r)
    score, H = _sw_dp(qj, rj, return_matrix=True)
    B = BLOSUM62_PADDED
    qn, rn = np.asarray(q), np.asarray(r)
    sub = B[qn.astype(np.int64)][:, rn.astype(np.int64)]
    pid, length = _traceback_pid(np.asarray(H), qn, rn, sub)
    return pid, length, int(score)


def sw_wave_pid(qs, rs, *, chunk: int = 32):
    """Batched scores + PID: one jitted DP wave per chunk of pairs, then the
    host traceback per pair.

    qs (N, Lq) x rs (N, Lr) int8, PAD-padded (padding only ever suffixes a
    sequence, so the real subgrid of each padded DP matrix — and its argmax
    cell in row-major order — is identical to the unpadded one; results are
    bit-exact with :func:`percent_identity` on the unpadded pair).

    Returns (pid (N,) float64, length (N,) int64, score (N,) int64).
    All-PAD rows (wave padding) score 0 with pid 0, length 0.
    """
    qs = np.asarray(qs, np.int8)
    rs = np.asarray(rs, np.int8)
    N = qs.shape[0]
    pid = np.zeros(N)
    length = np.zeros(N, np.int64)
    score = np.zeros(N, np.int64)
    B = BLOSUM62_PADDED
    for i in range(0, N, chunk):
        qc, rc = qs[i:i + chunk], rs[i:i + chunk]
        sc, H = _sw_batch_with_matrix(jnp.asarray(qc), jnp.asarray(rc))
        Hn = np.asarray(H)
        sc = np.asarray(sc)
        for n in range(len(qc)):
            sub = B[qc[n].astype(np.int64)][:, rc[n].astype(np.int64)]
            p, l = _traceback_pid(Hn[n], qc[n], rc[n], sub)
            pid[i + n] = p
            length[i + n] = l
            score[i + n] = int(sc[n])
    return pid, length, score


def batch_percent_identity(pairs, q_ids, q_lens, r_ids, r_lens) -> np.ndarray:
    """PID for each (qi, ri) row of a pair buffer; invalid rows -> nan.

    Valid rows are gathered into padded blocks and scored as one DP wave per
    chunk (bit-exact with the per-pair path, just batched).
    """
    pairs = np.asarray(pairs)
    out = np.full(len(pairs), np.nan)
    rows = [(n, int(qi), int(ri)) for n, (qi, ri, *_) in enumerate(pairs)
            if qi >= 0]
    if not rows:
        return out
    Lq = int(max(q_lens[qi] for _, qi, _ in rows))
    Lr = int(max(r_lens[ri] for _, _, ri in rows))
    qm = np.full((len(rows), max(Lq, 1)), PAD, np.int8)
    rm = np.full((len(rows), max(Lr, 1)), PAD, np.int8)
    for n, (_, qi, ri) in enumerate(rows):
        qm[n, :int(q_lens[qi])] = q_ids[qi][:int(q_lens[qi])]
        rm[n, :int(r_lens[ri])] = r_ids[ri][:int(r_lens[ri])]
    pid, _, _ = sw_wave_pid(qm, rm)
    for n, (slot, _, _) in enumerate(rows):
        out[slot] = pid[n]
    return out
