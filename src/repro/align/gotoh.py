"""Anti-diagonal ("wavefront") Smith-Waterman with affine (Gotoh) gaps.

The row wave of :mod:`repro.align.smith_waterman` resolves the within-row
gap dependency with a max-plus prefix scan (`lax.cummax`) per query row —
O(log L) depth per row, but a *scan* op per row that XLA:CPU executes as a
sequential pass. Sweeping the DP by **anti-diagonals** removes the prefix
scan entirely: every cell on diagonal c depends only on diagonals c-1 and
c-2, so one diagonal step is pure elementwise arithmetic over (Lq, B)
lanes. With lanes indexed by query row i (j = c - i), the three
predecessors of H[i, j] are

    H[i, j-1]   -> same lane, previous diagonal        (h1)
    H[i-1, j]   -> shifted lane, previous diagonal     (h1s = shift(h1))
    H[i-1, j-1] -> shifted lane, diagonal c-2          (h2s)

and a lane shift is a contiguous-axis concatenate. Affine gaps (Gotoh)
add the E/F gap lanes with the same structure:

    E_c = max(E_{c-1} + extend, H_{c-1} + open)           (gap along j)
    F_c = max(shift(F_{c-1}) + extend, shift(H_{c-1}) + open)
    H_c = max(0, shift(H_{c-2}) + s_c, E_c, F_c)

Convention: ``open`` is the cost of the FIRST gap residue, ``extend`` of
each further one — ``open == extend`` degenerates bit-exactly to the
linear-gap recurrence (H[i, j-1] >= E[i, j-1] at every cell, so the E/F
lanes never beat the direct 3-way max).

Three CPU-focused tricks make this beat the row wave (~2.8x measured at
B=64, L=192; see benchmarks/allpairs.py):

* **Sentinel-baked int8 table.** The substitution table is int8 with the
  PAD row/col overwritten by ``SENT8`` (-100), so PAD masking costs no
  compare/select pass. Along any DP path i and j are monotone, so a path
  that enters a sentinel region (PAD tail, or the out-of-matrix cells the
  skew introduces) never leaves it; each sentinel cell contributes <= -100
  while every H stays >= 0, so sentinel-region cells never exceed the best
  valid cell — *scores* are bit-exact with the masked row wave (cell
  values inside PAD regions may differ; nothing reads them).
* **Pad-reshape skew.** The (Lq, Lr, B) substitution block is re-laid to
  (nd, Lq, B) with skew[c, i] = sub[i, c-i] by padding the j axis to
  nd+1 with SENT8 and reshaping — no gather; out-of-range j land in the
  pad cells automatically.
* **Chunked minimal-carry scan.** The diagonal sweep is a `lax.scan`
  carrying only (h1, h2s) (+ (e1, f1) for affine) in int16 lanes when the
  score bound allows, processing ``_DIAG_CHUNK`` diagonals per step to
  amortize XLA:CPU's per-step dispatch overhead. (k=2 is a measured
  optimum: k>=3 crosses an XLA:CPU fusion cliff and regresses 3-6x, as
  does `scan(unroll>1)`.)

All entries return device arrays without a host sync, matching the
`sw_scores_device` contract the all-pairs scheduler relies on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alphabet import ALPHABET_SIZE, BLOSUM62_PADDED, PAD
from ..obs import trace_sentinel
from .smith_waterman import GAP

GAP_OPEN = -11   # BLOSUM62 companion defaults (BLAST -11/-1)
GAP_EXTEND = -1
SENT8 = -100     # sentinel substitution score baked into the int8 table

# int8 BLOSUM62 with the PAD row/col at SENT8: masking by table lookup.
_BSENT = BLOSUM62_PADDED.astype(np.int8).copy()
_BSENT[PAD, :] = SENT8
_BSENT[:, PAD] = SENT8

_DIAG_CHUNK = 2  # diagonals per scan step (measured optimum on XLA:CPU)


def lane_dtype(Lq: int, Lr: int):
    """int16 lanes while 11*L < 2^14 (H <= 11*min(Lq, Lr), the largest
    BLOSUM62 diagonal, so carries and h2s + SENT8 stay exact and far from
    the int16 rails); int32 above. Static shapes make this part of the
    jit key."""
    return jnp.int16 if 11 * max(Lq, Lr) < (1 << 14) else jnp.int32


def _sub_block(qs, rs):
    """(B, Lq) x (B, Lr) int8 -> (Lq, Lr, B) int8 substitution scores with
    SENT8 on every PAD row/col. One small gather builds the per-position
    reference profile B[:, r]; the query-symbol axis is resolved by 20
    selects (PAD falls through to the SENT8 default) — on XLA:CPU this is
    ~3x cheaper than the (Lq, Lr, B) two-axis gather."""
    table = jnp.asarray(_BSENT)
    rT = rs.T.astype(jnp.int32)                    # (Lr, B)
    rprof = table[:, rT]                           # (A+1, Lr, B) int8
    qT = qs.T                                      # (Lq, B) int8
    Lq, B = qT.shape
    Lr = rT.shape[0]
    out = jnp.broadcast_to(jnp.asarray(SENT8, jnp.int8), (Lq, Lr, B))
    for a in range(ALPHABET_SIZE):
        out = jnp.where((qT == a)[:, None, :], rprof[a][None], out)
    return out


def _skew_flat(sub):
    """(Lq, Lr, B) -> (nd, Lq, B) with out[c, i, b] = sub[i, c-i, b];
    out-of-range j = c-i read SENT8. Implemented by padding the j axis to
    nd+1 and reshaping (each row's start shifts by one slot per query
    row) — no gather."""
    Lq, Lr, B = sub.shape
    nd = Lq + Lr - 1
    w = jnp.pad(sub, ((0, 0), (0, nd + 1 - Lr), (0, 0)),
                constant_values=SENT8)             # (Lq, nd+1, B)
    sk = w.reshape(Lq * (nd + 1), B)[: Lq * nd].reshape(Lq, nd, B)
    return jnp.transpose(sk, (1, 0, 2))            # (nd, Lq, B)


def _skew(sub, k: int):
    """Chunked skew for the scan: (Lq, Lr, B) -> (ceil(nd/k), k, Lq, B),
    the tail diagonal group padded with SENT8 rows (inert, see module
    docstring)."""
    sk = _skew_flat(sub)
    nd, Lq, B = sk.shape
    pad = (-nd) % k
    if pad:
        sk = jnp.concatenate(
            [sk, jnp.full((pad, Lq, B), SENT8, jnp.int8)], axis=0)
    return sk.reshape(-1, k, Lq, B)


def _scan_linear(sk, gap: int, dt):
    """Linear-gap diagonal sweep over a skewed block; carries (h1, h2s)."""
    _, k, Lq, B = sk.shape
    z = jnp.zeros((Lq, B), dt)
    g = jnp.asarray(gap, dt)
    zrow = jnp.zeros((1, B), dt)

    def step(carry, srows):
        h1, h2s = carry
        m = None
        for t in range(k):
            h1s = jnp.concatenate([zrow, h1[:-1]], axis=0)
            h = jnp.maximum(jnp.maximum(h2s + srows[t].astype(dt), 0),
                            jnp.maximum(h1, h1s) + g)
            m = h if m is None else jnp.maximum(m, h)
            h1, h2s = h, h1s
        return (h1, h2s), jnp.max(m, axis=0)

    _, ms = jax.lax.scan(step, (z, z), sk)
    return jnp.max(ms, axis=0).astype(jnp.int32)


def _scan_affine(sk, gap_open: int, gap_extend: int, dt):
    """Gotoh diagonal sweep; carries (h1, h2s, e1, f1), all zero-init.

    The true E/F boundary is -inf; starting the gap lanes at 0 instead
    pollutes them with max(E_true, small-negative): since every H >= 0,
    E >= H + open >= open at every cell, so the polluted branch is the
    decaying chain extend*k, which is < 0 and can never win the 4-way max
    for H (H has a 0 floor). H — and therefore the score — is bit-exact
    with the -inf-boundary oracle (`kernels.ref.sw_affine_ref`).
    """
    _, k, Lq, B = sk.shape
    z = jnp.zeros((Lq, B), dt)
    go = jnp.asarray(gap_open, dt)
    ge = jnp.asarray(gap_extend, dt)
    zrow = jnp.zeros((1, B), dt)

    def shift(x):
        return jnp.concatenate([zrow, x[:-1]], axis=0)

    def step(carry, srows):
        h1, h2s, e1, f1 = carry
        m = None
        for t in range(k):
            h1s = shift(h1)
            e = jnp.maximum(e1 + ge, h1 + go)
            f = jnp.maximum(shift(f1) + ge, h1s + go)
            h = jnp.maximum(jnp.maximum(h2s + srows[t].astype(dt), 0),
                            jnp.maximum(e, f))
            m = h if m is None else jnp.maximum(m, h)
            h1, h2s, e1, f1 = h, h1s, e, f
        return (h1, h2s, e1, f1), jnp.max(m, axis=0)

    _, ms = jax.lax.scan(step, (z, z, z, z), sk)
    return jnp.max(ms, axis=0).astype(jnp.int32)


def _wave_linear_impl(qs, rs, gap: int):
    dt = lane_dtype(qs.shape[1], rs.shape[1])
    return _scan_linear(_skew(_sub_block(qs, rs), _DIAG_CHUNK), gap, dt)


def _wave_affine_impl(qs, rs, gap_open: int, gap_extend: int):
    dt = lane_dtype(qs.shape[1], rs.shape[1])
    return _scan_affine(_skew(_sub_block(qs, rs), _DIAG_CHUNK),
                        gap_open, gap_extend, dt)


@functools.partial(jax.jit, static_argnames=("gap",))
@trace_sentinel("wave_linear")
def _wave_linear(qs, rs, *, gap: int):
    return _wave_linear_impl(qs, rs, gap)


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend"))
@trace_sentinel("wave_affine")
def _wave_affine(qs, rs, *, gap_open: int, gap_extend: int):
    return _wave_affine_impl(qs, rs, gap_open, gap_extend)


def sw_wave_linear(qs, rs, *, gap: int = GAP) -> jax.Array:
    """Batched linear-gap SW scores via the wavefront sweep: (B, Lq) x
    (B, Lr) int8 (PAD-padded) -> (B,) int32 on device. Scores bit-exact
    with the row wave (`align.smith_waterman.sw_align_batch`)."""
    return _wave_linear(jnp.asarray(qs), jnp.asarray(rs), gap=gap)


def sw_wave_affine(qs, rs, *, gap_open: int = GAP_OPEN,
                   gap_extend: int = GAP_EXTEND) -> jax.Array:
    """Batched affine-gap (Gotoh) SW scores via the wavefront sweep:
    (B, Lq) x (B, Lr) int8 -> (B,) int32 on device; bit-exact with the
    numpy oracle `kernels.ref.sw_affine_ref` on the unpadded pairs."""
    return _wave_affine(jnp.asarray(qs), jnp.asarray(rs),
                        gap_open=gap_open, gap_extend=gap_extend)
