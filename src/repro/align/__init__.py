"""Alignment substrate: Smith-Waterman (JAX wavefront DP) + percent identity,
and the BLAST-like seed-and-extend baseline the paper compares against."""
from .smith_waterman import sw_align_batch, sw_score, percent_identity
from .seed_extend import SeedExtendBaseline

__all__ = ["sw_align_batch", "sw_score", "percent_identity",
           "SeedExtendBaseline"]
