"""Cross-process metric aggregation: worker registries fold into the parent.

The registry's instruments are mergeable BY DESIGN (``registry.py``:
fixed-bound histogram bucket counts add exactly; counters add; gauges are
last-write-wins) — but until now nothing carried a whole registry across a
process boundary. This module is that carrier: a worker serializes its
:data:`~repro.obs.registry.REGISTRY` with :func:`registry_state` (pure
JSON-able dict, built on ``Histogram.state()``), ships it over whatever
transport the caller has (a file, a pipe, ``multiprocessing`` queue), and
the parent folds it in with :func:`merge_registry_state` — declaring any
missing families on the fly and merging child-by-child, so N workers'
histograms aggregate into the EXACT fleet histogram (merge is associative
and commutative; the order workers report in cannot change a quantile).

Used by the all-pairs CLI (``launch/allpairs.py --metrics-merge``): worker
shards dump their registry snapshots as JSON files and the parent merges
them before rendering its own ``--metrics-out`` exposition.
"""
from __future__ import annotations

from .registry import (REGISTRY, CounterFamily, GaugeFamily, Histogram,
                       HistogramFamily, Registry)

__all__ = ["registry_state", "merge_registry_state"]

_KINDS = {"counter": CounterFamily, "gauge": GaugeFamily,
          "histogram": HistogramFamily}


def _kind_of(fam) -> str:
    if isinstance(fam, CounterFamily):
        return "counter"
    if isinstance(fam, GaugeFamily):
        return "gauge"
    return "histogram"


def registry_state(registry: Registry | None = None) -> dict:
    """Serialize a registry's full mergeable state (JSON-able).

    Every family carries its identity (kind, help, label names, histogram
    bounds) so the receiving side can DECLARE it before merging — a worker
    may have observed metrics the parent never touched.
    """
    registry = REGISTRY if registry is None else registry
    out = {}
    for name, fam in registry.families().items():
        kind = _kind_of(fam)
        children = []
        for key, child in fam.children().items():
            if isinstance(child, Histogram):
                children.append([list(key), child.state()])
            else:
                children.append([list(key), child.value])
        entry = dict(kind=kind, help=fam.help,
                     labelnames=list(fam.labelnames), children=children)
        if kind == "histogram":
            entry["bounds"] = list(fam.bounds)
        out[name] = entry
    return {"families": out}


def merge_registry_state(state: dict,
                         registry: Registry | None = None) -> Registry:
    """Fold a worker's :func:`registry_state` snapshot into ``registry``
    (default: the process-wide :data:`REGISTRY`); returns the registry.

    Exact-by-construction: histogram bucket counts add (identical fixed
    bounds are enforced by ``Histogram.merge``), counters add, gauges take
    the incoming value (last-write-wins, the gauge contract). Families the
    parent never declared are declared here with the worker's identity;
    families both sides declared must agree on kind/labelnames (the
    registry's redeclaration check) — drift raises rather than silently
    forking a metric.
    """
    registry = REGISTRY if registry is None else registry
    for name, entry in state.get("families", {}).items():
        kind = entry["kind"]
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r} for {name!r}")
        labelnames = tuple(entry.get("labelnames", ()))
        help_ = entry.get("help", "")
        if kind == "counter":
            fam = registry.counter(name, help_, labelnames)
        elif kind == "gauge":
            fam = registry.gauge(name, help_, labelnames)
        else:
            fam = registry.histogram(name, help_, labelnames,
                                     bounds=tuple(entry["bounds"]))
        for key, payload in entry.get("children", []):
            labels = dict(zip(labelnames, key))
            child = fam.labels(**labels)
            if kind == "counter":
                child.inc(int(payload))
            elif kind == "gauge":
                child.set(float(payload))
            else:
                child.merge(Histogram.from_state(payload))
    return registry
