"""Mergeable metrics: fixed-log-bucket histograms, counters, gauges.

The serving tier's first-cut percentiles (``serve/metrics.py``'s sample
windows, ``QueryEngine``'s grow-forever latency lists) share one flaw:
**samples don't merge**. Two replicas' p95s cannot be combined into the
fleet's p95, and an always-on engine cannot keep every sample. A
histogram with *fixed* log-spaced bucket bounds fixes both: bucket counts
add across replicas/shards/processes (exactly — merging is associative
and commutative), memory is O(buckets) forever, and any quantile is
recoverable to within one bucket's relative width (``2**(1/4) - 1`` ≈ 19%
worst-case at the default resolution, far inside the noise of a latency
distribution).

:class:`Registry` is the process-wide collection point: every layer
(engine, fleet, QueryEngine, the wave scheduler, the recompile sentinel)
declares its instruments here, and the registry renders one Prometheus
text exposition (``search_serve --metrics-out``) or a JSON snapshot.
Instruments are **declared-at-registration**: a counter family knows its
label names, and bumping a label set is the only way to create a child —
there is no silent "typo creates a fresh key" path (the bug
``serve/metrics.py``'s ``Counters.bump`` had; its adapter now warns).

Metric-name glossary (units in the name, Prometheus-style): see README
"Observability".
"""
from __future__ import annotations

import threading

import numpy as np

__all__ = [
    "Histogram", "Counter", "Gauge", "Registry", "REGISTRY",
    "default_bounds",
]


def default_bounds(lo: float = 1e-6, n: int = 112,
                   growth: float = 2 ** 0.25) -> tuple:
    """Fixed log-spaced bucket upper bounds: ``lo * growth**i``. The
    defaults cover 1 µs .. ~250 s in quarter-doublings — every latency
    this stack produces, at ≤ 19% worst-case quantile error. Fixed (not
    adaptive) is the point: two histograms merge iff their bounds are
    identical, so the bounds are part of the metric's identity."""
    return tuple(lo * growth ** i for i in range(n))


_DEFAULT_BOUNDS = default_bounds()


class Histogram:
    """Fixed-bucket histogram: ``observe(value)``, exact ``count``/``sum``,
    bucket-interpolated quantiles, and associative :meth:`merge`.

    Thread-safe. ``counts`` has ``len(bounds) + 1`` slots — the last is
    the overflow bucket (> bounds[-1])."""

    __slots__ = ("bounds", "_edges", "counts", "sum", "count", "_lock")

    def __init__(self, bounds: tuple | None = None):
        self.bounds = tuple(bounds) if bounds is not None else _DEFAULT_BOUNDS
        self._edges = np.asarray(self.bounds, np.float64)
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = int(np.searchsorted(self._edges, value, side="left"))
        with self._lock:
            self.counts[i] += 1
            self.sum += float(value)
            self.count += 1

    def __len__(self) -> int:
        return self.count

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into ``self`` (in place; returns self). Bounds
        must match exactly — mergeability is why they are fixed."""
        if other.bounds != self.bounds:
            raise ValueError("histogram bounds differ; only histograms "
                             "with identical fixed bounds merge exactly")
        with other._lock:
            oc, osum, ocnt = other.counts.copy(), other.sum, other.count
        with self._lock:
            self.counts += oc
            self.sum += osum
            self.count += ocnt
        return self

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0..1) by geometric interpolation
        inside the bucket holding that rank. 0 when empty; the top bound
        when the rank lands in the overflow bucket (the honest floor —
        the histogram cannot know how far past the last bound)."""
        with self._lock:
            counts = self.counts.copy()
            n = self.count
        if n == 0:
            return 0.0
        rank = q * n
        cum = np.cumsum(counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        if i >= len(self.bounds):               # overflow bucket
            return float(self.bounds[-1])
        hi = self.bounds[i]
        lo = self.bounds[i - 1] if i > 0 else hi / (self.bounds[1] /
                                                    self.bounds[0])
        below = cum[i - 1] if i > 0 else 0
        inside = counts[i]
        frac = 1.0 if inside == 0 else min(1.0, (rank - below) / inside)
        return float(lo * (hi / lo) ** frac)    # geometric: log buckets

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        """JSON-able: exact count/sum, interpolated p50/p95/p99."""
        return dict(count=self.count, sum=self.sum, mean=self.mean,
                    p50=self.quantile(0.50), p95=self.quantile(0.95),
                    p99=self.quantile(0.99))

    def state(self) -> dict:
        """Full mergeable state (bounds + bucket counts) — what crosses a
        process boundary; rebuild with :meth:`from_state` and merge."""
        with self._lock:
            return dict(bounds=list(self.bounds),
                        counts=self.counts.tolist(),
                        sum=self.sum, count=self.count)

    @classmethod
    def from_state(cls, state: dict) -> "Histogram":
        h = cls(tuple(state["bounds"]))
        h.counts[:] = np.asarray(state["counts"], np.int64)
        h.sum = float(state["sum"])
        h.count = int(state["count"])
        return h


class _Family:
    """A named metric family with declared label names; children are
    created per label-value tuple on first use."""

    def __init__(self, name: str, help: str, labelnames: tuple,
                 make_child):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._make = make_child
        self._children: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def labels(self, **labels):
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labels)}")
        key = tuple(str(labels[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make()
        return child

    def children(self) -> dict[tuple, object]:
        with self._lock:
            return dict(self._children)


class Counter:
    """Monotonic counter (one child of a counter family)."""
    __slots__ = ("_v", "_lock")

    def __init__(self):
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, by: int = 1) -> None:
        with self._lock:
            self._v += by

    @property
    def value(self) -> int:
        with self._lock:
            return self._v


class Gauge:
    """Last-write-wins gauge (one child of a gauge family)."""
    __slots__ = ("_v", "_lock")

    def __init__(self):
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._v = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class CounterFamily(_Family):
    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames, Counter)

    def inc(self, by: int = 1, **labels) -> None:
        self.labels(**labels).inc(by)


class GaugeFamily(_Family):
    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames, Gauge)

    def set(self, value: float, **labels) -> None:
        self.labels(**labels).set(value)


class HistogramFamily(_Family):
    def __init__(self, name, help="", labelnames=(), bounds=None):
        self.bounds = tuple(bounds) if bounds is not None else _DEFAULT_BOUNDS
        super().__init__(name, help, labelnames,
                         lambda: Histogram(self.bounds))

    def observe(self, value: float, **labels) -> None:
        self.labels(**labels).observe(value)

    def merged(self) -> Histogram:
        """One histogram folding every child — the fleet-wide view the
        sample windows could never produce (merge is exact)."""
        out = Histogram(self.bounds)
        for child in self.children().values():
            out.merge(child)
        return out


class Registry:
    """Named instrument collection + Prometheus/JSON rendering.

    ``counter``/``gauge``/``histogram`` are get-or-create: the first call
    declares the family (name, help, label names); later calls must agree
    on type and label names or raise — redeclaration drift is a bug, not
    a new metric."""

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(
                    name, help, tuple(labelnames), **kw)
                return fam
        if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{type(fam).__name__}{fam.labelnames}; redeclaration with "
                f"{cls.__name__}{tuple(labelnames)} is a bug")
        return fam

    def counter(self, name, help="", labelnames=()) -> CounterFamily:
        return self._get_or_create(CounterFamily, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> GaugeFamily:
        return self._get_or_create(GaugeFamily, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  bounds=None) -> HistogramFamily:
        fam = self._get_or_create(HistogramFamily, name, help, labelnames,
                                  bounds=bounds)
        if bounds is not None and fam.bounds != tuple(bounds):
            raise ValueError(f"metric {name!r} bounds differ from the "
                             f"registered family's")
        return fam

    def families(self) -> dict:
        with self._lock:
            return dict(self._families)

    # ------------------------------------------------------------ render
    @staticmethod
    def _label_str(labelnames, key) -> str:
        if not labelnames:
            return ""
        inner = ",".join(f'{n}="{v}"' for n, v in zip(labelnames, key))
        return "{" + inner + "}"

    def prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4): counters get a
        ``_total``-suffixed sample if not already suffixed; histograms
        render cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``."""
        lines = []
        for name, fam in sorted(self.families().items()):
            kind = ("counter" if isinstance(fam, CounterFamily) else
                    "gauge" if isinstance(fam, GaugeFamily) else "histogram")
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {kind}")
            for key, child in sorted(fam.children().items()):
                lab = self._label_str(fam.labelnames, key)
                if isinstance(child, Histogram):
                    cum = 0
                    with child._lock:
                        counts = child.counts.copy()
                        total, s = child.count, child.sum
                    for le, c in zip(fam.bounds, counts[:-1]):
                        cum += int(c)
                        blab = self._label_str(
                            fam.labelnames + ("le",), key + (f"{le:.6g}",))
                        lines.append(f"{name}_bucket{blab} {cum}")
                    blab = self._label_str(fam.labelnames + ("le",),
                                           key + ("+Inf",))
                    lines.append(f"{name}_bucket{blab} {total}")
                    lines.append(f"{name}_sum{lab} {s}")
                    lines.append(f"{name}_count{lab} {total}")
                else:
                    lines.append(f"{name}{lab} {child.value}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-able nested snapshot: {name: {labels_repr: value|hist}}."""
        out = {}
        for name, fam in self.families().items():
            entry = {}
            for key, child in fam.children().items():
                k = ",".join(f"{n}={v}" for n, v in
                             zip(fam.labelnames, key)) or ""
                entry[k] = (child.snapshot() if isinstance(child, Histogram)
                            else child.value)
            out[name] = entry
        return out


#: The process-wide registry every layer registers into.
REGISTRY = Registry()
