"""Recompile sentinel: count program traces per cache key, loudly.

This repo has shipped two *silent*-recompile bugs: the sharded self-join
keyed its emission cache on a fresh ``Mesh`` per call (PR 5 fix), and the
wave-pipeline cache keyed on the device *count* instead of the device
tuple (PR 6 fix). Both were invisible precisely because a recompile
looks like a slow batch, not an error. The sentinel makes compiles a
first-class observable: every instrumented program body bumps a counter
keyed by ``(site, abstract signature)`` and records a ``compile`` trace
instant, and :meth:`CompileSentinel.expect_no_compiles` turns "zero
steady-state recompiles after warmup" into an *asserted invariant* —
in tests and in the SLO benchmark.

How it counts: :func:`trace_sentinel` wraps the Python body of a
``jax.jit``-ed function. Under jit, that body only executes while JAX is
**tracing** — once per new abstract signature per compiled program — so
each execution is exactly one (re)trace/compile. The key is the abstract
signature (shapes + dtypes + static args), which means the sentinel
distinguishes the two failure modes:

* a **new key** compiling once — expected (a cold shape, a grown cap);
* the **same key** compiling twice — a silent recompile: some cache
  upstream (an lru_cache on a Mesh, a rebuilt closure) failed to reuse
  the program it already paid for. ``counts()`` makes these jump out
  (``n > 1``), and the fresh-``Mesh`` regression test in
  tests/test_obs.py pins that the sentinel fires on exactly this.
"""
from __future__ import annotations

import contextlib
import functools
import threading

from .registry import REGISTRY
from .trace import instant

__all__ = ["SENTINEL", "CompileSentinel", "trace_sentinel"]

_compiles = REGISTRY.counter(
    "jit_compiles", "program (re)traces recorded by the recompile "
    "sentinel, by instrumented site", labelnames=("site",))


def _abstract_key(args, kwargs) -> tuple:
    """Stable signature of a trace: (shape, dtype) for array-likes (incl.
    tracers), repr for static/python args. Two traces with equal keys are
    the *same* program being paid for twice."""
    def one(a):
        shape = getattr(a, "shape", None)
        dtype = getattr(a, "dtype", None)
        if shape is not None and dtype is not None:
            return ("arr", tuple(shape), str(dtype))
        return repr(a)
    return (tuple(one(a) for a in args),
            tuple((k, one(v)) for k, v in sorted(kwargs.items())))


class CompileSentinel:
    """Thread-safe compile counter keyed by (site, abstract signature)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: dict[tuple, int] = {}

    def record(self, site: str, key: tuple) -> None:
        with self._lock:
            k = (site, key)
            self._counts[k] = self._counts.get(k, 0) + 1
            n = self._counts[k]
        _compiles.inc(site=site)
        instant("compile", cat="jit", site=site, n_for_key=n)

    # ------------------------------------------------------------ read
    def counts(self, site: str | None = None) -> dict:
        """{(site, key): n}; filtered to one site when given."""
        with self._lock:
            items = dict(self._counts)
        if site is None:
            return items
        return {k: v for k, v in items.items() if k[0] == site}

    def total(self, site: str | None = None) -> int:
        return sum(self.counts(site).values())

    def by_site(self) -> dict:
        """{site: total compiles} — the summary a benchmark reports."""
        out: dict[str, int] = {}
        for (site, _key), n in self.counts().items():
            out[site] = out.get(site, 0) + n
        return out

    def recompiled(self) -> dict:
        """Keys compiled MORE than once — each one is a silent-recompile
        bug (the program was paid for, then paid for again)."""
        return {k: n for k, n in self.counts().items() if n > 1}

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()

    # ------------------------------------------------------------ assert
    @contextlib.contextmanager
    def expect_no_compiles(self, site: str | None = None, *,
                           message: str = ""):
        """Assert that the enclosed block triggers ZERO (re)compiles —
        the steady-state invariant a warmed serving tier must hold."""
        before = self.counts(site)
        yield
        after = self.counts(site)
        fresh = {k: after[k] - before.get(k, 0)
                 for k in after if after[k] != before.get(k, 0)}
        if fresh:
            rows = "\n".join(f"  {s}: +{n} (key={key!r})"
                             for (s, key), n in sorted(fresh.items()))
            raise AssertionError(
                f"{message or 'steady state violated'}: "
                f"{sum(fresh.values())} compile(s) inside a zero-compile "
                f"region —\n{rows}")


SENTINEL = CompileSentinel()


def trace_sentinel(site: str, static_key: tuple = ()):
    """Decorate a function body placed UNDER ``jax.jit`` (or built inside
    a cached program builder) so every trace of it is recorded::

        @functools.partial(jax.jit, static_argnames=("cap",))
        @trace_sentinel("probe_fused")
        def _probe_csr_fused(...): ...

    ``static_key`` is for bodies built inside a cached program *builder*
    (``_ring_program(devices, cap, ...)``): statics captured by closure
    are invisible in the call arguments, so without them in the key a
    legitimate rebuild at a new cap looks identical to a silent recompile
    of the old one — pass the builder's cache key through::

        @trace_sentinel("ring", static_key=(devices, Bl, cap, k))
        def shard_fn(...): ...

    Adds one host-side dict bump per *trace*, nothing per call — compiled
    executions never re-enter the Python body."""
    def deco(fn):
        @functools.wraps(fn)
        def inner(*args, **kwargs):
            SENTINEL.record(site, _abstract_key(args, kwargs)
                            + (("static", static_key),))
            return fn(*args, **kwargs)
        return inner
    return deco
