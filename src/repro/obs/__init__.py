"""repro.obs — one observability substrate under every layer.

ScalLoPS's core claim is *scalability across sourced computing
resources*; the paper proves it with per-phase (map/shuffle/reduce) time
attribution, and the extreme-scale follow-up (PAPERS.md) uses per-node
pipeline-phase attribution as its primary evaluation instrument. This
package is that instrument for our stack — the layer every perf PR
measures itself with:

* ``trace``    — structured spans with per-query trace IDs minted at
  ``AsyncEngine.submit()`` and propagated (contextvar) through router →
  replica → ring probe → re-rank, plus lifecycle events (seal, delta
  refresh, compactions) and the all-pairs wave pipeline; bounded
  thread-safe ring buffer; Chrome/Perfetto ``trace_event`` export;
  disabled tracing costs one branch.
* ``registry`` — mergeable metrics: fixed-log-bucket histograms (bucket
  counts add exactly across replicas/shards — sample windows never
  could), declared-at-registration counters and gauges, one process-wide
  :data:`REGISTRY`, Prometheus text exposition + JSON snapshot.
* ``aggregate`` — cross-process carrier for the registry: workers ship
  :func:`registry_state` snapshots (pure JSON) and the parent folds them
  in with :func:`merge_registry_state` — N worker histograms aggregate
  into the exact fleet histogram (used by the allpairs CLI).
* ``jit``      — the recompile sentinel: every instrumented jitted
  program body records a compile per (site, abstract signature); a key
  compiling twice is a silent-recompile bug (this repo shipped two), and
  ``SENTINEL.expect_no_compiles()`` turns "zero steady-state recompiles
  after warmup" into an asserted invariant in tests and the SLO
  benchmark.
"""
from .aggregate import merge_registry_state, registry_state
from .jit import SENTINEL, CompileSentinel, trace_sentinel
from .registry import (REGISTRY, Counter, Gauge, Histogram, Registry,
                       default_bounds)
from .trace import (TRACER, Tracer, current_trace, disable, enable, instant,
                    new_trace_id, record, span, trace_context)

__all__ = [
    "TRACER", "Tracer", "span", "instant", "record", "new_trace_id",
    "trace_context", "current_trace", "enable", "disable",
    "REGISTRY", "Registry", "Histogram", "Counter", "Gauge",
    "default_bounds", "registry_state", "merge_registry_state",
    "SENTINEL", "CompileSentinel", "trace_sentinel",
]
