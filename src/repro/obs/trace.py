"""Structured tracing: spans, per-query trace IDs, Chrome/Perfetto export.

The paper's evaluation instrument is per-phase time attribution (map /
shuffle / reduce wall-clock per node); the extreme-scale follow-up
(PAPERS.md) keeps the same discipline at thousands of nodes. This module
is that instrument for our stack: a zero-dependency span API whose
records land in a bounded, thread-safe ring buffer and export as Chrome
``trace_event`` JSON — one ``--trace-out`` file from an SLO sweep opens
directly in ``chrome://tracing`` / Perfetto with every serving thread,
lifecycle event, and compile on one timeline.

Design rules:

* **disabled tracing is one branch** — :func:`span` checks a module
  global and returns a shared no-op context manager; no allocation, no
  lock, no clock read. Tracing is off by default; the serving hot path
  pays ~a dict construction per call site (the ``**attrs``) and nothing
  else.
* **trace IDs are minted at the front door and ride a contextvar** —
  :meth:`repro.serve.engine.AsyncEngine.submit` mints one ID per query;
  the dispatch thread enters :func:`trace_context` with the IDs of the
  batch it assembled, so every span recorded beneath it (router pick,
  replica probe, ring sweep, re-rank) is automatically tagged with the
  queries it served. A batch span carries *all* its queries' IDs — that
  is the honest shape: micro-batched serving does work for many queries
  at once, and attribution must say so rather than pretend per-query
  isolation.
* **bounded buffer** — a ``deque(maxlen=capacity)``; a week of always-on
  serving cannot OOM the tier, the newest spans win.

Span taxonomy (see README "Observability" for the full glossary):

==========  ================================================================
category    spans
==========  ================================================================
serve       submit, dispatch, shed, query_batch, ladder, sig, probe, ring,
            rerank, route, resolve, warmup
lifecycle   seal, refresh, place, compact_serving, ingest, minor_compaction,
            major_compaction, compact_index
allpairs    emission, delta_emission, wave, host_gather, score_pairs
jit         compile (instant; one per traced program body — see
            repro.obs.jit)
==========  ================================================================
"""
from __future__ import annotations

import contextlib
import contextvars
import itertools
import json
import os
import threading
import time
from collections import deque

__all__ = [
    "TRACER", "Tracer", "span", "instant", "record", "new_trace_id",
    "trace_context", "current_trace", "enable", "disable",
]

#: trace IDs of the queries the current thread is doing work for
#: (a tuple: a dispatch batch serves many queries at once).
_TRACE_CTX: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_trace", default=())

_ids = itertools.count(1)       # CPython next() is atomic


def new_trace_id() -> int:
    """Mint a process-unique trace ID (one per submitted query)."""
    return next(_ids)


@contextlib.contextmanager
def trace_context(ids: tuple):
    """Tag every span recorded in this context with ``ids`` (the queries
    the enclosed work serves). Nesting replaces, not extends — the inner
    scope knows best which queries it serves."""
    tok = _TRACE_CTX.set(tuple(ids))
    try:
        yield
    finally:
        _TRACE_CTX.reset(tok)


def current_trace() -> tuple:
    return _TRACE_CTX.get()


class Tracer:
    """Bounded thread-safe span buffer + Chrome trace_event export."""

    def __init__(self, capacity: int = 65536):
        self.enabled = False
        self._buf: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()      # trace epoch (ts are relative)
        self._dropped = 0

    # -------------------------------------------------------------- control
    def enable(self, capacity: int | None = None) -> None:
        with self._lock:
            if capacity is not None:
                self._buf = deque(self._buf, maxlen=int(capacity))
            self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._dropped = 0
            self._t0 = time.perf_counter()

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    # -------------------------------------------------------------- record
    def record(self, name: str, cat: str, t0: float, t1: float | None,
               attrs: dict | None = None) -> None:
        """Append one span (t0/t1 are ``perf_counter`` seconds; ``t1=None``
        records an instant event). Auto-tags the current trace context."""
        args = dict(attrs) if attrs else {}
        if "trace" not in args:
            trace = _TRACE_CTX.get()
            if trace:
                args["trace"] = list(trace)
        ev = (name, cat, t0 - self._t0, None if t1 is None else t1 - t0,
              threading.get_ident(), threading.current_thread().name, args)
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self._dropped += 1
            self._buf.append(ev)

    # -------------------------------------------------------------- read
    def spans(self) -> list[dict]:
        """Snapshot as dicts: {name, cat, ts (s), dur (s or None), tid,
        thread, args} — ``args["trace"]`` holds the query trace IDs."""
        with self._lock:
            evs = list(self._buf)
        return [dict(name=n, cat=c, ts=ts, dur=dur, tid=tid, thread=thr,
                     args=args) for n, c, ts, dur, tid, thr, args in evs]

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON object (open in ``chrome://tracing``
        or https://ui.perfetto.dev). Durations are complete ("X") events in
        microseconds; instants are "i" events; thread names ride metadata
        ("M") events so Perfetto labels the serving threads."""
        pid = os.getpid()
        events = []
        threads = {}
        with self._lock:
            evs = list(self._buf)
            dropped = self._dropped
        for name, cat, ts, dur, tid, thread, args in evs:
            threads.setdefault(tid, thread)
            ev = {"name": name, "cat": cat, "pid": pid, "tid": tid,
                  "ts": ts * 1e6, "args": args}
            if dur is None:
                ev.update(ph="i", s="t")
            else:
                ev.update(ph="X", dur=dur * 1e6)
            events.append(ev)
        meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                 "args": {"name": thread}} for tid, thread in threads.items()]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": dropped}}

    def export(self, path) -> int:
        """Write the Chrome trace JSON; returns the number of span events."""
        obj = self.chrome_trace()
        with open(path, "w") as fh:
            json.dump(obj, fh)
        return len(obj["traceEvents"])


TRACER = Tracer()


def enable(capacity: int | None = None) -> None:
    TRACER.enable(capacity)


def disable() -> None:
    TRACER.disable()


class _NopSpan:
    """Shared do-nothing context manager: the disabled-tracing fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOP = _NopSpan()


class _Span:
    __slots__ = ("name", "cat", "attrs", "t0")

    def __init__(self, name: str, cat: str, attrs: dict):
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        TRACER.record(self.name, self.cat, self.t0, time.perf_counter(),
                      self.attrs)
        return False


def span(name: str, cat: str = "serve", **attrs):
    """``with span("probe", shard=s): ...`` — records a complete event when
    tracing is enabled; a shared no-op otherwise (one branch)."""
    if not TRACER.enabled:
        return _NOP
    return _Span(name, cat, attrs)


def instant(name: str, cat: str = "serve", **attrs) -> None:
    """Record a zero-duration marker (submit/resolve/shed/compile)."""
    if TRACER.enabled:
        TRACER.record(name, cat, time.perf_counter(), None, attrs)


def record(name: str, t0: float, t1: float, cat: str = "serve",
           **attrs) -> None:
    """Record a span from timestamps already measured (for call sites that
    keep their own ``perf_counter`` bookkeeping, e.g. the engine's stage
    timers — no double clock reads on the hot path)."""
    if TRACER.enabled:
        TRACER.record(name, cat, t0, t1, attrs)
