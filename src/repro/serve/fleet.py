"""Replica fleet: N sharded serving replicas behind a router + live ingest.

One :class:`~repro.index.store.SignatureIndex` (the corpus is one
artifact), N :class:`~repro.index.shard.ShardedIndex` replicas over it,
each wrapped in its own :class:`~repro.index.service.QueryEngine` (every
replica keeps its own grow-and-retry cap and serving stats). Replicas
over equal meshes share every compiled ring program — the module-level
device-tuple cache from PR 5 is what makes an N-replica fleet cost one
compile, not N.

**Router** — ``query_batch`` picks the replica with the fewest
outstanding batches (ties broken least-recently-used), *skipping* any
replica whose lock is held (mid-refresh/compaction) when a free one
exists — a replica is never taken out of rotation unserved: while the
ingest thread swaps one replica's slabs, traffic flows to the others, and
if literally every replica is busy the request waits on the best one
rather than failing.

**Ingest loop** — a background thread drains ``ingest()`` batches:
``index.add()`` (+ seal) under the shared lifecycle lock, then a rolling
per-replica delta ``refresh()`` under each replica's serving lock, then —
every ``minor_compact_every`` ingests — a rolling serving-side minor
compaction (``ShardedIndex.compact()``: the delta slab folds into the
base so steady-state serving returns to the cheap single-slab ring;
the index's segment files are untouched). Every handoff is epoch-tagged:
``query_batch`` returns the delta epoch the serving replica answered at,
so a result is always attributable to a specific index state — the PR 5
bit-exactness contract ("identical to a compacted rebuild at that
epoch") extended across threads.

Thread-safety invariants (tests/test_serve.py races them):

* one **lifecycle lock** (installed as every replica's
  ``ShardedIndex.refresh_lock``) serializes all index mutation —
  ``add``/``seal``/merge/partition — against every replica's staleness
  check and refresh, so a probe can never see half-sealed segments;
* one **serving lock per replica** serializes that replica's slab swaps
  against its probes, so a ring never runs on half-swapped slabs;
* lock order is always replica-lock → lifecycle-lock (the inline
  ``_refresh_if_stale`` inside ``topk`` takes them in that order, and so
  does the ingest loop), so the pair cannot deadlock.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from ..index.service import QueryEngine, ServingConfig
from ..index.shard import ShardedIndex
from ..obs import span
from .metrics import Counters


class _Replica:
    __slots__ = ("name", "engine", "sharded", "lock", "outstanding",
                 "last_used")

    def __init__(self, name: str, engine: QueryEngine,
                 sharded: ShardedIndex):
        self.name = name
        self.engine = engine
        self.sharded = sharded
        self.lock = threading.Lock()    # serving lock: probes vs slab swaps
        self.outstanding = 0
        self.last_used = 0


class ReplicaFleet:
    """N serving replicas over one index, with live background ingest.

    Exposes the async-engine backend protocol: ``cfg`` and
    ``query_batch(ids, lens) -> (nid, nd, epoch)`` — plug a fleet
    straight into :class:`~repro.serve.engine.AsyncEngine`.
    """

    def __init__(self, index, cfg: ServingConfig | None = None, *,
                 n_replicas: int = 2, mesh=None, ref_seqs=None,
                 minor_compact_every: int = 4, warmup=None,
                 start_ingest: bool = True):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.index = index
        self.cfg = cfg or ServingConfig()
        self.minor_compact_every = int(minor_compact_every)
        # ONE lifecycle lock shared by every replica and the ingest
        # thread (see module docstring); RLock because refresh() both
        # takes it and runs under it from _refresh_if_stale.
        self._lifecycle = threading.RLock()
        self._replicas: list[_Replica] = []
        for i in range(n_replicas):
            sharded = ShardedIndex(index, mesh)
            sharded.refresh_lock = self._lifecycle
            engine = QueryEngine(index, self.cfg, sharded=sharded,
                                 ref_seqs=ref_seqs, name=f"replica{i}")
            self._replicas.append(_Replica(f"replica{i}", engine, sharded))
        self._pick_lock = threading.Lock()
        self._ticket = 0
        self.counters = Counters("batches", "ingests", "minor_compactions",
                                 "major_compactions", "waited_busy")
        self._ingest_q: queue.Queue = queue.Queue()
        self._closed = threading.Event()
        self._ingest_thread = None
        if warmup is not None:      # compile every serving shape pre-traffic
            if isinstance(warmup, tuple):
                self.warmup(*warmup)
            else:
                self.warmup()
        if start_ingest:
            self._ingest_thread = threading.Thread(
                target=self._ingest_loop, name="serve-ingest", daemon=True)
            self._ingest_thread.start()

    @property
    def n_replicas(self) -> int:
        return len(self._replicas)

    # ------------------------------------------------------------ routing
    def _pick(self) -> _Replica:
        """Least-outstanding replica, skipping locked ones when possible;
        ACQUIRES the winner's serving lock (caller releases)."""
        with self._pick_lock:
            self._ticket += 1
            order = sorted(self._replicas,
                           key=lambda r: (r.outstanding, r.last_used))
        for rep in order:
            if rep.lock.acquire(blocking=False):
                return rep
        # every replica busy (all mid-batch or mid-refresh): wait on the
        # least-loaded one — requests queue behind it, they never fail
        self.counters.bump("waited_busy")
        rep = order[0]
        rep.lock.acquire()
        return rep

    def query_batch(self, ids, lens):
        """Serve one batch on the best replica: (nid, nd, epoch) with
        ``epoch`` the delta epoch (index segment count) the replica
        answered at — results are bit-exact with a synchronous
        ``topk_probe`` over the index at exactly that epoch."""
        rep = self._pick()
        try:
            with self._pick_lock:
                rep.outstanding += 1
                rep.last_used = self._ticket
            with span("route", replica=rep.name):
                nid, nd = rep.engine.query_batch(ids, lens)
            # read under rep.lock: this is exactly what the batch saw
            epoch = rep.sharded.epoch[1]
        finally:
            with self._pick_lock:
                rep.outstanding -= 1
            rep.lock.release()
        self.counters.bump("batches")
        return nid, nd, epoch

    # ------------------------------------------------------------ ingest
    def ingest(self, ref_ids, ref_lens) -> threading.Event:
        """Queue a reference batch for background ingest; returns an
        Event set once every replica serves the new segment. Serving
        never stops: replicas refresh one at a time off-rotation."""
        ev = threading.Event()
        self._ingest_q.put((np.asarray(ref_ids, np.int8),
                            np.asarray(ref_lens, np.int32), ev))
        return ev

    def _ingest_loop(self) -> None:
        while not self._closed.is_set():
            try:
                item = self._ingest_q.get(timeout=0.05)
            except queue.Empty:
                continue
            self._apply_ingest(*item)

    def _apply_ingest(self, ref_ids, ref_lens, ev) -> None:
        with span("ingest", cat="lifecycle", rows=len(ref_lens),
                  epoch=self.index.epoch):
            with self._lifecycle:
                self.index.add(ref_ids, ref_lens)
                self.index.seal()   # segments exist before replicas look
            for rep in self._replicas:  # rolling: one replica at a time
                with rep.lock:
                    rep.sharded.refresh()
        self.counters.bump("ingests")
        if self.minor_compact_every > 0 and \
                self.counters["ingests"] % self.minor_compact_every == 0:
            with span("minor_compaction", cat="lifecycle",
                      epoch=self.index.epoch):
                for rep in self._replicas:
                    with rep.lock:
                        rep.sharded.compact()
            self.counters.bump("minor_compactions")
        ev.set()

    def drain_ingest(self, timeout: float = 60.0) -> bool:
        """Block until every queued ingest has been applied."""
        import time as _t
        t0 = _t.monotonic()
        while not self._ingest_q.empty():
            if _t.monotonic() - t0 > timeout:
                return False
            _t.sleep(0.005)
        return True

    def compact_index(self) -> None:
        """Major compaction: fold the index's segments into one
        (``generation`` bump) and re-place every replica — rolling, so
        serving stays live; results are identical before and after."""
        with span("major_compaction", cat="lifecycle",
                  epoch=self.index.epoch,
                  generation=self.index.generation):
            with self._lifecycle:
                self.index.compact()
            for rep in self._replicas:
                with rep.lock:
                    rep.sharded.refresh()   # generation bump -> re-place
        self.counters.bump("major_compactions")

    # ------------------------------------------------------------ warmup
    def warmup(self, q_ids=None, q_lens=None, *,
               max_len: int | None = None) -> int:
        """Warm EVERY replica's engine directly (the router would send all
        warmup batches to whichever replica is free, leaving the others
        cold); same per-(rung, length-quantum) sweep as
        :meth:`QueryEngine.warmup`. Returns total shapes warmed. Replicas
        over equal meshes share compiled ring programs, so replicas after
        the first warm from cache — but their grow-and-retry probe caps
        still settle per replica, which is the point of warming each."""
        total = 0
        for rep in self._replicas:
            with rep.lock:
                total += rep.engine.warmup(q_ids, q_lens, max_len=max_len)
                rep.engine.reset_stats()    # warmup batches aren't traffic
        return total

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: float = 30.0) -> None:
        self._closed.set()
        if self._ingest_thread is not None:
            self._ingest_thread.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Fleet counters + per-replica serving stats and epochs."""
        reps = []
        for rep in self._replicas:
            s = rep.engine.stats()
            s["name"] = rep.name
            s["outstanding"] = rep.outstanding
            s["epoch"] = tuple(rep.sharded.epoch)
            reps.append(s)
        return dict(
            n_replicas=self.n_replicas,
            counters=self.counters.snapshot(),
            index_epoch=self.index.epoch,
            index_generation=self.index.generation,
            replicas=reps,
        )
