"""Replica fleet: N sharded serving replicas behind a router + live ingest.

One :class:`~repro.index.store.SignatureIndex` (the corpus is one
artifact), N :class:`~repro.index.shard.ShardedIndex` replicas over it,
each wrapped in its own :class:`~repro.index.service.QueryEngine` (every
replica keeps its own grow-and-retry cap and serving stats). Replicas
over equal meshes share every compiled ring program — the module-level
device-tuple cache from PR 5 is what makes an N-replica fleet cost one
compile, not N.

**Router** — ``query_batch`` picks the replica with the fewest
outstanding batches (ties broken least-recently-used), *skipping* any
replica whose lock is held (mid-refresh/compaction) when a free one
exists — a replica is never taken out of rotation unserved: while the
ingest thread swaps one replica's slabs, traffic flows to the others, and
if literally every replica is busy the request waits on the best one
rather than failing.

**Ingest loop** — a background thread drains ``ingest()`` batches:
``index.add()`` (+ seal) under the shared lifecycle lock, then a rolling
per-replica delta ``refresh()`` under each replica's serving lock, then —
every ``minor_compact_every`` ingests — a rolling serving-side minor
compaction (``ShardedIndex.compact()``: the delta slab folds into the
base so steady-state serving returns to the cheap single-slab ring;
the index's segment files are untouched). Every handoff is epoch-tagged:
``query_batch`` returns the delta epoch the serving replica answered at,
so a result is always attributable to a specific index state — the PR 5
bit-exactness contract ("identical to a compacted rebuild at that
epoch") extended across threads.

**Failure model (PR 8)** — a replica that raises out of ``query_batch``
is *tracked*: consecutive failures past ``fail_threshold`` quarantine it
(the router stops offering it traffic); after ``quarantine_s`` a single
**half-open probe** is admitted — success readmits the replica (failure
re-quarantines it for twice as long). A failed batch gets **one bounded
retry** on a different healthy replica (epoch-tagged results make the
retry safe: whichever replica answers, the result is valid at the epoch
it reports). When *no* healthy replica remains, ``query_batch`` returns
a typed :class:`DegradedBatch` — a partial result carrying a coverage
fraction — instead of raising, so the serving tier degrades instead of
erroring. The ingest loop runs under a
:class:`~repro.faults.supervisor.Supervisor`: an ingest crash resolves
the waiter's :class:`IngestTicket` with the error attached (nothing
hangs), and the loop restarts with backoff.

Thread-safety invariants (tests/test_serve.py races them):

* one **lifecycle lock** (installed as every replica's
  ``ShardedIndex.refresh_lock``) serializes all index mutation —
  ``add``/``seal``/merge/partition — against every replica's staleness
  check and refresh, so a probe can never see half-sealed segments;
* one **serving lock per replica** serializes that replica's slab swaps
  against its probes, so a ring never runs on half-swapped slabs;
* lock order is always replica-lock → lifecycle-lock (the inline
  ``_refresh_if_stale`` inside ``topk`` takes them in that order, and so
  does the ingest loop), so the pair cannot deadlock;
* replica **health fields** (fails / quarantined_until / probe_inflight)
  are read and written only under the pick lock.
"""
from __future__ import annotations

import queue
import threading
import time

import numpy as np

from ..faults import Supervisor, fault_point
from ..index.service import QueryEngine, ServingConfig
from ..obs import REGISTRY, instant, span
from ..index.shard import ShardedIndex
from .metrics import Counters

# registry-side mirrors of the fault-path counters, so bench_delta can
# flag a regression in retry/quarantine/degraded counts across runs
_M_RETRIES = REGISTRY.counter(
    "router_retries", "failed batches retried on another replica, by "
    "outcome (attempted / succeeded)", labelnames=("outcome",))
_M_QUAR = REGISTRY.counter(
    "replica_quarantine_events", "replica health transitions "
    "(quarantined / probed / readmitted)", labelnames=("event",))
_M_DEGRADED = REGISTRY.counter(
    "degraded_batches", "batches answered degraded because no healthy "
    "replica remained")


class IngestTicket(threading.Event):
    """The waitable handle :meth:`ReplicaFleet.ingest` returns. Always
    set once the batch's fate is known; ``error`` is None on success and
    a ``"Type: message"`` string when the ingest crashed or the fleet
    closed with the batch still queued — waiters MUST check it."""

    def __init__(self):
        super().__init__()
        self.error: str | None = None

    @property
    def ok(self) -> bool:
        return self.is_set() and self.error is None


class DegradedBatch:
    """The typed answer when no healthy replica could serve a batch:
    sentinel ids/dists (no neighbors), ``epoch=None`` (no index state
    answered), a ``coverage`` fraction (healthy replicas / fleet size at
    decision time — 0.0 when everything was down) and the last error.
    Duck-typed with ``degraded=True`` so the async engine detects it
    without importing this module."""

    degraded = True

    def __init__(self, n: int, k: int, coverage: float, detail: str):
        self.ids = np.full((n, k), -1, np.int32)
        self.dists = np.full((n, k), np.float32(np.inf), np.float32)
        self.epoch = None
        self.coverage = float(coverage)
        self.detail = detail

    def __repr__(self):
        return (f"DegradedBatch(n={len(self.ids)}, "
                f"coverage={self.coverage:.2f}, detail={self.detail!r})")


class _Replica:
    __slots__ = ("name", "engine", "sharded", "lock", "outstanding",
                 "last_used", "fails", "quarantined_until", "quarantine_s",
                 "probe_inflight")

    def __init__(self, name: str, engine: QueryEngine,
                 sharded: ShardedIndex):
        self.name = name
        self.engine = engine
        self.sharded = sharded
        self.lock = threading.Lock()    # serving lock: probes vs slab swaps
        self.outstanding = 0
        self.last_used = 0
        # health (guarded by the fleet's pick lock)
        self.fails = 0                  # consecutive query failures
        self.quarantined_until = 0.0    # clock() time; 0.0 = not quarantined
        self.quarantine_s = 0.0         # current quarantine span (doubles)
        self.probe_inflight = False     # half-open: one probe at a time


class ReplicaFleet:
    """N serving replicas over one index, with live background ingest.

    Exposes the async-engine backend protocol: ``cfg`` and
    ``query_batch(ids, lens) -> (nid, nd, epoch)`` — plug a fleet
    straight into :class:`~repro.serve.engine.AsyncEngine`.

    One :class:`ServingConfig` governs every replica, including the
    re-rank DP routing knobs (``dp_kernel``/``gap_mode``/``gap_open``/
    ``gap_extend``): replicas share the process-wide jit cache, so the
    gather+DP program of a given (rung, quantum, DP route) compiles once
    for the whole fleet, and ``warmup()`` through any replica warms all.
    """

    def __init__(self, index, cfg: ServingConfig | None = None, *,
                 n_replicas: int = 2, mesh=None, ref_seqs=None,
                 minor_compact_every: int = 4, warmup=None,
                 start_ingest: bool = True, fail_threshold: int = 3,
                 quarantine_s: float = 1.0, max_retries: int = 1,
                 clock=time.monotonic):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.index = index
        self.cfg = cfg or ServingConfig()
        self.minor_compact_every = int(minor_compact_every)
        self.fail_threshold = int(fail_threshold)
        self.base_quarantine_s = float(quarantine_s)
        self.max_retries = int(max_retries)
        self._clock = clock
        # ONE lifecycle lock shared by every replica and the ingest
        # thread (see module docstring); RLock because refresh() both
        # takes it and runs under it from _refresh_if_stale.
        self._lifecycle = threading.RLock()
        self._replicas: list[_Replica] = []
        for i in range(n_replicas):
            sharded = ShardedIndex(index, mesh)
            sharded.refresh_lock = self._lifecycle
            engine = QueryEngine(index, self.cfg, sharded=sharded,
                                 ref_seqs=ref_seqs, name=f"replica{i}")
            self._replicas.append(_Replica(f"replica{i}", engine, sharded))
        self._pick_lock = threading.Lock()
        self._ticket = 0
        self.counters = Counters("batches", "ingests", "minor_compactions",
                                 "major_compactions", "waited_busy",
                                 "retries", "retry_success",
                                 "replica_failures", "replica_quarantines",
                                 "replica_probes", "replica_readmissions",
                                 "degraded_batches", "ingest_failures")
        self._ingest_q: queue.Queue = queue.Queue()
        self._closed = threading.Event()
        self._ingest_sup: Supervisor | None = None
        if warmup is not None:      # compile every serving shape pre-traffic
            if isinstance(warmup, tuple):
                self.warmup(*warmup)
            else:
                self.warmup()
        if start_ingest:
            self._ingest_sup = Supervisor(
                "serve-ingest", self._ingest_once,
                idle_sleep_s=0.0).start()

    @property
    def n_replicas(self) -> int:
        return len(self._replicas)

    # ------------------------------------------------------------ routing
    def _pick(self, exclude=()) -> _Replica | None:
        """Least-outstanding *healthy* replica, skipping locked ones when
        possible; ACQUIRES the winner's serving lock (caller releases).
        Quarantined replicas are offered no traffic until their
        quarantine expires, then exactly one half-open probe at a time.
        Returns None when no eligible replica exists (all quarantined or
        excluded) — the caller degrades instead of waiting forever."""
        now = self._clock()
        with self._pick_lock:
            self._ticket += 1
            order = []
            for r in self._replicas:
                if r in exclude:
                    continue
                if r.quarantined_until > 0.0 and (
                        now < r.quarantined_until or r.probe_inflight):
                    continue        # still serving quarantine / probe out
                order.append(r)
            order.sort(key=lambda r: (r.outstanding, r.last_used))
        if not order:
            return None
        picked = None
        for rep in order:
            if rep.lock.acquire(blocking=False):
                picked = rep
                break
        if picked is None:
            # every eligible replica busy (mid-batch or mid-refresh):
            # wait on the least-loaded one — requests queue behind it
            self.counters.bump("waited_busy")
            picked = order[0]
            picked.lock.acquire()
        if picked.quarantined_until > 0.0:
            with self._pick_lock:   # half-open: this batch IS the probe
                picked.probe_inflight = True
            self.counters.bump("replica_probes")
            _M_QUAR.inc(event="probed")
        return picked

    def _record_failure(self, rep: _Replica, err: Exception) -> None:
        """Health bookkeeping after a replica raised out of a batch."""
        self.counters.bump("replica_failures")
        now = self._clock()
        with self._pick_lock:
            rep.fails += 1
            if rep.probe_inflight:
                # the half-open probe failed: back to quarantine, twice
                # as long — a flapping replica backs itself off
                rep.probe_inflight = False
                rep.quarantine_s *= 2.0
                rep.quarantined_until = now + rep.quarantine_s
                quarantined = True
            elif (rep.quarantined_until == 0.0
                  and rep.fails >= self.fail_threshold):
                rep.quarantine_s = self.base_quarantine_s
                rep.quarantined_until = now + rep.quarantine_s
                quarantined = True
            else:
                quarantined = False
        if quarantined:
            self.counters.bump("replica_quarantines")
            _M_QUAR.inc(event="quarantined")
            instant("replica_quarantined", cat="fault", replica=rep.name,
                    fails=rep.fails, quarantine_s=rep.quarantine_s,
                    error=type(err).__name__)

    def _record_success(self, rep: _Replica) -> None:
        readmitted = False
        with self._pick_lock:
            rep.fails = 0
            if rep.probe_inflight:  # half-open probe answered: readmit
                rep.probe_inflight = False
                rep.quarantined_until = 0.0
                rep.quarantine_s = 0.0
                readmitted = True
        if readmitted:
            self.counters.bump("replica_readmissions")
            _M_QUAR.inc(event="readmitted")
            instant("replica_readmitted", cat="fault", replica=rep.name)

    def _query_on(self, rep: _Replica, ids, lens):
        """One serving attempt on ``rep`` (its lock is held on entry and
        released here). ``replica.query`` is the fault site."""
        try:
            with self._pick_lock:
                rep.outstanding += 1
                rep.last_used = self._ticket
            with span("route", replica=rep.name):
                fault_point("replica.query", replica=rep.name)
                nid, nd = rep.engine.query_batch(ids, lens)
            # read under rep.lock: this is exactly what the batch saw
            epoch = rep.sharded.epoch[1]
        finally:
            with self._pick_lock:
                rep.outstanding -= 1
            rep.lock.release()
        return nid, nd, epoch

    def coverage(self) -> float:
        """Fraction of replicas currently eligible for traffic."""
        now = self._clock()
        with self._pick_lock:
            up = sum(1 for r in self._replicas
                     if r.quarantined_until == 0.0
                     or (now >= r.quarantined_until
                         and not r.probe_inflight))
        return up / len(self._replicas)

    def query_batch(self, ids, lens):
        """Serve one batch on the best healthy replica: (nid, nd, epoch)
        with ``epoch`` the delta epoch (index segment count) the replica
        answered at — results are bit-exact with a synchronous
        ``topk_probe`` over the index at exactly that epoch. A replica
        failure gets up to ``max_retries`` retries on *other* healthy
        replicas (still bit-exact: the retry's answer carries its own
        epoch). With no healthy replica left the batch resolves to a
        typed :class:`DegradedBatch` instead of raising."""
        tried: list[_Replica] = []
        last_err: Exception | None = None
        for attempt in range(1 + self.max_retries):
            rep = self._pick(exclude=tried)
            if rep is None:
                break               # nobody healthy left to try
            if attempt > 0:
                self.counters.bump("retries")
                _M_RETRIES.inc(outcome="attempted")
                instant("batch_retry", cat="fault", replica=rep.name,
                        attempt=attempt)
            try:
                out = self._query_on(rep, ids, lens)
            except Exception as e:      # noqa: BLE001 — any backend error
                last_err = e
                tried.append(rep)
                self._record_failure(rep, e)
                continue
            self._record_success(rep)
            if attempt > 0:
                self.counters.bump("retry_success")
                _M_RETRIES.inc(outcome="succeeded")
            self.counters.bump("batches")
            return out
        # graceful degradation: typed partial result, never an exception
        self.counters.bump("degraded_batches")
        _M_DEGRADED.inc()
        detail = (f"{type(last_err).__name__}: {last_err}" if last_err
                  else "no healthy replica")
        cov = self.coverage()
        instant("degraded_batch", cat="fault", coverage=cov, detail=detail)
        return DegradedBatch(len(lens), self.cfg.k, cov, detail)

    # ------------------------------------------------------------ ingest
    def ingest(self, ref_ids, ref_lens) -> IngestTicket:
        """Queue a reference batch for background ingest; returns an
        :class:`IngestTicket` set once the batch's fate is known — every
        replica serves the new segment (``ticket.ok``) or the ingest
        crashed (``ticket.error`` holds the typed error; the supervisor
        restarts the loop for later batches). Serving never stops:
        replicas refresh one at a time off-rotation."""
        ev = IngestTicket()
        self._ingest_q.put((np.asarray(ref_ids, np.int8),
                            np.asarray(ref_lens, np.int32), ev))
        return ev

    def _ingest_once(self) -> int:
        """One supervised ingest iteration (see Supervisor.run_once):
        returns items applied; an exception is a crash — the ticket was
        already resolved with the error by :meth:`_apply_ingest`."""
        try:
            item = self._ingest_q.get(timeout=0.05)
        except queue.Empty:
            return 0
        self._apply_ingest(*item)
        return 1

    def _apply_ingest(self, ref_ids, ref_lens, ev: IngestTicket) -> None:
        try:
            fault_point("ingest.apply", rows=len(ref_lens))
            with span("ingest", cat="lifecycle", rows=len(ref_lens),
                      epoch=self.index.epoch):
                with self._lifecycle:
                    self.index.add(ref_ids, ref_lens)
                    self.index.seal()   # segments exist before replicas look
                for rep in self._replicas:  # rolling: one replica at a time
                    with rep.lock:
                        rep.sharded.refresh()
            self.counters.bump("ingests")
            if self.minor_compact_every > 0 and \
                    self.counters["ingests"] % self.minor_compact_every == 0:
                with span("minor_compaction", cat="lifecycle",
                          epoch=self.index.epoch):
                    for rep in self._replicas:
                        with rep.lock:
                            rep.sharded.compact()
                self.counters.bump("minor_compactions")
        except Exception as e:          # noqa: BLE001 — resolve, then crash
            self.counters.bump("ingest_failures")
            ev.error = f"{type(e).__name__}: {e}"
            ev.set()                    # the waiter wakes WITH the error —
            raise                       # and the supervisor counts the crash
        ev.set()

    def drain_ingest(self, timeout: float = 60.0) -> bool:
        """Block until every queued ingest has been applied."""
        import time as _t
        t0 = _t.monotonic()
        while not self._ingest_q.empty():
            if _t.monotonic() - t0 > timeout:
                return False
            _t.sleep(0.005)
        return True

    def compact_index(self) -> None:
        """Major compaction: fold the index's segments into one
        (``generation`` bump) and re-place every replica — rolling, so
        serving stays live; results are identical before and after."""
        with span("major_compaction", cat="lifecycle",
                  epoch=self.index.epoch,
                  generation=self.index.generation):
            with self._lifecycle:
                self.index.compact()
            for rep in self._replicas:
                with rep.lock:
                    rep.sharded.refresh()   # generation bump -> re-place
        self.counters.bump("major_compactions")

    # ------------------------------------------------------------ warmup
    def warmup(self, q_ids=None, q_lens=None, *,
               max_len: int | None = None) -> int:
        """Warm EVERY replica's engine directly (the router would send all
        warmup batches to whichever replica is free, leaving the others
        cold); same per-(rung, length-quantum) sweep as
        :meth:`QueryEngine.warmup`. Returns total shapes warmed. Replicas
        over equal meshes share compiled ring programs, so replicas after
        the first warm from cache — but their grow-and-retry probe caps
        still settle per replica, which is the point of warming each."""
        total = 0
        for rep in self._replicas:
            with rep.lock:
                total += rep.engine.warmup(q_ids, q_lens, max_len=max_len)
                rep.engine.reset_stats()    # warmup batches aren't traffic
        return total

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: float = 30.0) -> bool:
        """Stop the ingest supervisor and resolve any still-queued
        tickets with a shutdown error (an IngestTicket from this fleet
        always resolves). Returns False when the ingest thread failed to
        join — wedged, which the caller must surface, not swallow."""
        self._closed.set()
        clean = True
        if self._ingest_sup is not None:
            clean = self._ingest_sup.stop(timeout=timeout)
        while True:
            try:
                *_ids, ev = self._ingest_q.get_nowait()
            except queue.Empty:
                break
            ev.error = "Shutdown: fleet closed before this batch applied"
            ev.set()
        return clean

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Fleet counters + per-replica serving stats, epochs, and health
        (consecutive fails / quarantine state), plus the ingest
        supervisor's crash accounting."""
        now = self._clock()
        reps = []
        for rep in self._replicas:
            s = rep.engine.stats()
            s["name"] = rep.name
            s["outstanding"] = rep.outstanding
            s["epoch"] = tuple(rep.sharded.epoch)
            with self._pick_lock:
                s["health"] = dict(
                    fails=rep.fails,
                    quarantined=(rep.quarantined_until > 0.0
                                 and now < rep.quarantined_until),
                    quarantine_s=rep.quarantine_s,
                    probe_inflight=rep.probe_inflight)
            reps.append(s)
        out = dict(
            n_replicas=self.n_replicas,
            coverage=self.coverage(),
            counters=self.counters.snapshot(),
            index_epoch=self.index.epoch,
            index_generation=self.index.generation,
            replicas=reps,
        )
        if self._ingest_sup is not None:
            out["ingest"] = self._ingest_sup.stats()
        return out
