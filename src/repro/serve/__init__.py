"""repro.serve — the asynchronous serving tier over the index substrate.

The ROADMAP north star ("heavy traffic from millions of users") needs
more than a fast probe: it needs an always-on tier that holds latency
SLOs under concurrent load while the index grows underneath it. This
package is that tier, built entirely on the PR 4/5 machinery:

* ``engine``  — :class:`AsyncEngine`: futures-based ``submit()``, a
  background dispatch thread draining a bounded queue into the
  padding-ladder micro-batcher (bit-exact with the synchronous
  ``flush()`` path), max-wait/max-batch dispatch policy, and
  deadline-aware admission control with typed :class:`Completed` /
  :class:`Rejected` outcomes.
* ``fleet``   — :class:`ReplicaFleet`: N ``ShardedIndex`` replicas behind
  a least-outstanding router, with a background ingest loop
  (``add()`` → rolling per-replica delta ``refresh()`` → periodic minor
  compaction) that never takes a replica out of rotation unserved —
  epoch-tagged handoff per batch.
* ``metrics`` — rolling p50/p95/p99 windows and shed/truncation counters
  behind ``stats()``, now thin adapters over :mod:`repro.obs` (samples
  mirror into mergeable registry histograms; counter names are declared
  and typos warn).

Observability (:mod:`repro.obs`): ``submit()`` mints a per-query trace
ID that rides a contextvar through dispatch → router → replica → ring →
rerank, so an exported trace reconstructs every request's full path;
``warmup()`` on both the engine and the fleet compiles every serving
shape pre-traffic, and the recompile sentinel asserts steady state stays
compile-free.

Fault tolerance (PR 8, :mod:`repro.faults`): dispatch and ingest loops
run supervised (crash → typed resolution of every outstanding future or
ticket → restart with backoff → visible ``degraded`` after bounded
failures); the router tracks per-replica health, quarantines failing
replicas with half-open probe readmission, retries a failed batch once
on a healthy replica, and degrades to typed :class:`Degraded` partial
results when the whole fleet is down.

The closed-loop SLO benchmark lives in ``benchmarks/serve_slo.py``
(offered-QPS sweep, latency knee, ``BENCH_serve.json``); the chaos soak
— the same closed loop under a scripted fault plan — in
``benchmarks/chaos_soak.py``.
"""
from .engine import AsyncEngine, Completed, Degraded, Rejected
from .fleet import DegradedBatch, IngestTicket, ReplicaFleet
from .metrics import Counters, Rolling

__all__ = [
    "AsyncEngine", "Completed", "Degraded", "Rejected",
    "DegradedBatch", "IngestTicket", "ReplicaFleet",
    "Counters", "Rolling",
]
