"""Futures-based asynchronous query engine with deadline admission control.

``QueryEngine.flush`` is synchronous: every caller blocks on the whole
micro-batch. This module turns the same serving path into an always-on
tier: :meth:`AsyncEngine.submit` enqueues one query into a **bounded**
request queue and returns a :class:`concurrent.futures.Future`
immediately; a background dispatch thread drains the queue into the
engine's padding-ladder micro-batcher under a **max-wait / max-batch**
policy and resolves each future with a typed outcome:

* :class:`Completed` — per-query top-k ids/dists, **bit-exact with the
  synchronous ``flush()`` path**: the dispatcher assembles exactly the
  arrays ``flush`` would, and every per-query result is independent of
  batch composition (the padding ladder serves PAD rows that can match
  nothing), so how requests happen to batch can never change an answer
  (asserted in tests/test_serve.py under interleaved submits).
* :class:`Rejected` — admission control shed the request: the queue was
  full at submit (back-pressure at the door, the submitter never blocks),
  or at dispatch time ``queue_time + predicted_batch_cost`` exceeded the
  request's deadline (the batch it would join cannot finish in time, so
  serving it would only waste device time that on-deadline requests need).
  Typed results — not exceptions — so closed-loop load generators count
  sheds without try/except in the hot loop. ``Rejected("internal")``
  (PR 8) covers the serving path itself failing: a backend exception or
  dispatch crash resolves every in-flight future typed, the supervised
  dispatch loop restarts with backoff, and exhausting the restart budget
  fails the queue and latches ``degraded`` — a future from this engine
  ALWAYS resolves.
* :class:`Degraded` — the fleet answered with no healthy replica left:
  sentinel neighbors plus the coverage fraction, so callers distinguish
  "no matches" from "nobody could look".

Batch cost is predicted per padding-ladder rung with an EWMA of measured
batch latencies — the ladder quantizes batch shapes anyway, so the rung
is the natural cost-model key. The clock is injectable (``clock=``) which
makes shedding decisions deterministic under a fake clock in tests.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass, field

import numpy as np

from ..core.alphabet import PAD, encode
from ..faults import Supervisor, fault_point
from ..obs import REGISTRY, instant, new_trace_id, span, trace_context
from .metrics import Counters, Rolling

#: EWMA smoothing for the per-rung batch-cost model (higher = faster
#: adaptation to load shifts, lower = steadier admission decisions).
COST_ALPHA = 0.3

# registry families (children labeled by the async engine's name; the
# Rolling windows mirror into the *_seconds histograms, so per-process
# snapshots keep their exact window percentiles while the registry view
# merges across engines/processes)
_M_QUEUE = REGISTRY.histogram(
    "async_queue_seconds", "submit -> dispatch queue wait",
    labelnames=("engine",))
_M_TOTAL = REGISTRY.histogram(
    "async_total_seconds", "submit -> resolve request latency",
    labelnames=("engine",))
_M_REQS = REGISTRY.counter(
    "async_requests", "submitted requests by outcome (completed / "
    "degraded / shed_queue_full / shed_deadline / shed_shutdown / "
    "shed_internal)",
    labelnames=("engine", "outcome"))
_M_DEPTH = REGISTRY.gauge(
    "async_queue_depth", "queued requests at last dispatch",
    labelnames=("engine",))

_async_ids = itertools.count()


@dataclass(frozen=True)
class Completed:
    """A served request: top-k neighbor ids/dists (-1 padded), the index
    epoch the serving replica answered at (the PR 5 "valid at some epoch"
    contract made visible), and queue/batch timing."""
    ids: np.ndarray
    dists: np.ndarray
    epoch: int | None
    queued_ms: float
    batch_ms: float

    @property
    def ok(self) -> bool:
        return True


@dataclass(frozen=True)
class Rejected:
    """A shed request. ``reason`` is one of ``"queue_full"`` (bounded
    queue was full at submit), ``"deadline"`` (queue time + predicted
    batch cost exceeded the request deadline at dispatch),
    ``"shutdown"`` (engine closed with the request still queued), or
    ``"internal"`` (the serving path itself failed — backend exception
    or dispatch-thread crash; ``detail`` names the error). A future from
    this engine ALWAYS resolves to a typed outcome: internal failures
    are rejections, never stranded futures."""
    reason: str
    queued_ms: float = 0.0
    predicted_ms: float = 0.0
    detail: str = ""

    @property
    def ok(self) -> bool:
        return False


@dataclass(frozen=True)
class Degraded:
    """A request served while NO healthy replica remained: sentinel
    ids/dists (no neighbors found), ``epoch=None``, the fleet's healthy
    ``coverage`` fraction at decision time, and the last error. Not
    ``ok`` — but not an exception either: closed-loop callers count
    degraded answers exactly like sheds, without try/except."""
    ids: np.ndarray
    dists: np.ndarray
    epoch: None
    coverage: float
    detail: str
    queued_ms: float = 0.0
    batch_ms: float = 0.0

    @property
    def ok(self) -> bool:
        return False

    @property
    def degraded(self) -> bool:
        return True


@dataclass
class _Request:
    row: np.ndarray
    length: int
    t_submit: float
    deadline: float | None          # absolute clock() seconds, or None
    trace: int = 0                  # trace ID minted at submit (obs.trace)
    future: Future = field(default_factory=Future)


def _resolve(fut: Future, value) -> None:
    """Resolve a future, tolerating caller-side cancellation."""
    try:
        fut.set_result(value)
    except InvalidStateError:
        pass


class AsyncEngine:
    """Background dispatch thread over a synchronous serving backend.

    ``backend`` is anything with a ``cfg`` (:class:`ServingConfig` — the
    ladder and max_batch come from there) and a ``query_batch(ids, lens)``
    returning ``(nid, nd)`` or ``(nid, nd, epoch)`` — a single
    :class:`~repro.index.service.QueryEngine` or a
    :class:`~repro.serve.fleet.ReplicaFleet`.

    * ``max_wait_ms`` — dispatch policy: a batch launches when it reaches
      ``cfg.max_batch`` requests or the oldest member has waited this
      long, whichever comes first (0 = greedy: take whatever is queued).
    * ``queue_depth`` — bound on queued requests; submits beyond it
      resolve immediately to ``Rejected("queue_full")``.
    * ``default_deadline_ms`` — applied to submits that pass no deadline
      (None = no deadline, never shed for time).
    * ``clock`` — injectable monotonic clock (tests use a fake one to
      make admission decisions deterministic).
    * ``start=False`` skips the thread; tests drive :meth:`_drain_once`.
    """

    def __init__(self, backend, *, max_wait_ms: float = 2.0,
                 queue_depth: int = 1024,
                 default_deadline_ms: float | None = None,
                 clock=time.monotonic, window: int = 4096,
                 name: str | None = None,
                 warmup=None, start: bool = True):
        self.backend = backend
        self.max_batch = int(backend.cfg.max_batch)
        self._ladder = tuple(backend.cfg.batch_ladder)
        self.max_wait = float(max_wait_ms) / 1e3
        self.default_deadline = (None if default_deadline_ms is None
                                 else float(default_deadline_ms) / 1e3)
        self._clock = clock
        self._q: queue.Queue = queue.Queue(maxsize=int(queue_depth))
        self._cost_ms: dict[int, float] = {}    # ladder rung -> EWMA ms
        self.name = name or f"async{next(_async_ids)}"
        self.counters = Counters("submitted", "completed", "degraded",
                                 "shed_queue_full", "shed_deadline",
                                 "shed_shutdown", "shed_internal",
                                 "batches")
        # exact window percentiles locally; merged histograms globally
        self.queue_lat = Rolling(window, _M_QUEUE.labels(engine=self.name))
        self.total_lat = Rolling(window, _M_TOTAL.labels(engine=self.name))
        self._m_reqs = _M_REQS
        self._m_depth = _M_DEPTH.labels(engine=self.name)
        self._closed = threading.Event()
        self._sup: Supervisor | None = None
        self._wedged = False
        if warmup is not None:      # compile every serving shape pre-traffic
            if isinstance(warmup, tuple):
                self.warmup(*warmup)
            else:
                self.warmup()
        if start:
            # supervised dispatch: a backend/dispatch crash resolves the
            # in-flight batch typed (inside _drain_once), then the
            # supervisor restarts the loop with backoff; exhausting the
            # restart budget fails the whole queue and latches degraded
            self._sup = Supervisor(
                f"dispatch-{self.name}",
                lambda: self._drain_once(timeout=0.02),
                on_giveup=self._fail_queue).start()

    # ------------------------------------------------------------ submit
    def submit(self, seq, *, deadline_ms: float | None = None) -> Future:
        """Enqueue one query (amino-acid string or encoded int8 row);
        returns a future resolving to :class:`Completed` or
        :class:`Rejected`. Never blocks: a full queue is an immediate
        typed rejection (back-pressure belongs to the caller, not a
        hidden ``put()`` stall)."""
        if isinstance(seq, str):
            row = np.asarray(encode(seq), np.int8)
        else:
            row = np.asarray(seq, np.int8).reshape(-1)
        now = self._clock()
        if deadline_ms is not None:
            deadline = now + float(deadline_ms) / 1e3
        elif self.default_deadline is not None:
            deadline = now + self.default_deadline
        else:
            deadline = None
        tid = new_trace_id()
        req = _Request(row, len(row), now, deadline, trace=tid)
        self.counters.bump("submitted")
        instant("submit", trace=[tid], engine=self.name, len=req.length)
        if self._closed.is_set():
            self._shed(req, "shutdown")
            return req.future
        if self._sup is not None and self._sup.degraded:
            # the dispatch loop gave up: nobody will ever drain the
            # queue — reject at the door instead of stranding the future
            self._shed(req, "internal",
                       detail=f"dispatch degraded: {self._sup.last_error}")
            return req.future
        try:
            self._q.put_nowait(req)
        except queue.Full:
            self._shed(req, "queue_full")
        return req.future

    def _shed(self, req: _Request, reason: str, **kw) -> None:
        self.counters.bump(f"shed_{reason}")
        self._m_reqs.inc(engine=self.name, outcome=f"shed_{reason}")
        instant("shed", trace=[req.trace], reason=reason)
        _resolve(req.future, Rejected(reason, **kw))

    def _fail_queue(self, exc: Exception | None = None) -> None:
        """Resolve every queued future with Rejected("internal") — runs
        when the supervised dispatch loop exhausts its restart budget
        (nothing may strand) and from close() for leftovers."""
        detail = f"{type(exc).__name__}: {exc}" if exc is not None else ""
        while True:
            try:
                r = self._q.get_nowait()
            except queue.Empty:
                return
            self._shed(r, "internal", detail=detail)

    def pending(self) -> int:
        return self._q.qsize()

    # ------------------------------------------------------------ dispatch
    def _rung(self, b: int) -> int:
        """Padding-ladder rung a batch of ``b`` requests lands on (the
        cost-model key — mirrors ``QueryEngine._pad_shapes``)."""
        ladder = [x for x in self._ladder if x >= b]
        return min(ladder) if ladder else self.max_batch

    def predicted_ms(self, b: int) -> float:
        """Predicted wall-clock of serving a batch of ``b`` requests:
        the EWMA for its ladder rung; optimistic 0 until that rung has
        been measured (first batches admit everything, then the model
        takes over)."""
        return self._cost_ms.get(self._rung(b), 0.0)

    def _update_cost(self, b: int, seconds: float) -> None:
        r = self._rung(b)
        ms = seconds * 1e3
        old = self._cost_ms.get(r)
        self._cost_ms[r] = ms if old is None else \
            COST_ALPHA * ms + (1.0 - COST_ALPHA) * old

    def _collect(self, timeout: float) -> list:
        """Gather one batch under the max-wait/max-batch policy."""
        try:
            batch = [self._q.get(timeout=timeout)]
        except queue.Empty:
            return []
        t_first = self._clock()
        while len(batch) < self.max_batch:
            wait = self.max_wait - (self._clock() - t_first)
            if wait <= 0:
                try:                        # greedy: only what's queued
                    batch.append(self._q.get_nowait())
                except queue.Empty:
                    break
            else:
                try:
                    batch.append(self._q.get(timeout=wait))
                except queue.Empty:
                    break
        return batch

    def _drain_once(self, timeout: float = 0.05) -> int:
        """One dispatch iteration: collect, admit/shed, serve, resolve.
        Returns the number of requests taken off the queue."""
        batch = self._collect(timeout)
        if not batch:
            return 0
        self._m_depth.set(self._q.qsize())
        now = self._clock()
        predicted = self.predicted_ms(len(batch))
        admitted = []
        for r in batch:
            # queue time is already inside `now`; shedding asks whether
            # the batch this request would join can finish by its deadline
            if r.deadline is not None and now + predicted / 1e3 > r.deadline:
                self._shed(r, "deadline",
                           queued_ms=(now - r.t_submit) * 1e3,
                           predicted_ms=predicted)
            else:
                admitted.append(r)
        if not admitted:
            return len(batch)
        n = len(admitted)
        L = max(r.length for r in admitted)
        ids = np.full((n, max(L, 1)), PAD, np.int8)
        lens = np.zeros(n, np.int32)
        for j, r in enumerate(admitted):
            ids[j, :r.length] = r.row
            lens[j] = r.length
        tids = tuple(r.trace for r in admitted)
        t0 = self._clock()
        # every span beneath (route, query_batch, probe, ring, rerank) is
        # tagged with this batch's query trace IDs via the contextvar
        try:
            with trace_context(tids):
                with span("dispatch", n=n, engine=self.name,
                          predicted_ms=round(predicted, 3)):
                    fault_point("engine.dispatch", n=n)
                    out = self.backend.query_batch(ids, lens)
        except Exception as e:          # noqa: BLE001 — the batch must
            # resolve typed BEFORE the crash propagates: the supervisor
            # restarts the loop, but these futures' fate is sealed here
            detail = f"{type(e).__name__}: {e}"
            for r in admitted:
                self._shed(r, "internal", detail=detail,
                           queued_ms=(t0 - r.t_submit) * 1e3)
            raise
        dt = self._clock() - t0
        done = self._clock()
        if getattr(out, "degraded", False):
            # the fleet had no healthy replica: typed partial answers
            # with the coverage fraction, not Completed (and not a cost
            # sample — nothing was actually served)
            for j, r in enumerate(admitted):
                self.counters.bump("degraded")
                self._m_reqs.inc(engine=self.name, outcome="degraded")
                self.total_lat.add(done - r.t_submit)
                instant("resolve_degraded", trace=[r.trace],
                        engine=self.name, coverage=out.coverage)
                _resolve(r.future, Degraded(
                    out.ids[j], out.dists[j], None, out.coverage,
                    out.detail, queued_ms=(t0 - r.t_submit) * 1e3,
                    batch_ms=dt * 1e3))
            return len(batch)
        if len(out) == 3:
            nid, nd, epoch = out
        else:
            nid, nd = out
            idx = getattr(self.backend, "index", None)
            epoch = idx.epoch if idx is not None else None
        self._update_cost(n, dt)
        self.counters.bump("batches")
        for j, r in enumerate(admitted):
            self.counters.bump("completed")
            self._m_reqs.inc(engine=self.name, outcome="completed")
            self.queue_lat.add(t0 - r.t_submit)
            self.total_lat.add(done - r.t_submit)
            instant("resolve", trace=[r.trace], engine=self.name)
            _resolve(r.future, Completed(
                nid[j], nd[j], epoch,
                queued_ms=(t0 - r.t_submit) * 1e3, batch_ms=dt * 1e3))
        return len(batch)

    # ------------------------------------------------------------ warmup
    def warmup(self, q_ids=None, q_lens=None, *,
               max_len: int | None = None) -> int:
        """Compile every (batch-rung, length-quantum) serving shape on the
        backend before traffic arrives (delegates to the backend's own
        ``warmup`` — :meth:`QueryEngine.warmup` /
        :meth:`ReplicaFleet.warmup`); pass ``warmup=True`` or
        ``warmup=(q_ids, q_lens)`` at construction to do this
        automatically. Returns the number of shapes warmed."""
        wu = getattr(self.backend, "warmup", None)
        if wu is None:
            return 0
        return wu(q_ids, q_lens, max_len=max_len)

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: float = 30.0) -> bool:
        """Stop dispatch; queued-but-unserved requests resolve to
        ``Rejected("shutdown")`` (a future from this engine always
        resolves). Returns False — and latches ``wedged`` in stats —
        when the dispatch thread failed to join within ``timeout``: a
        wedged thread is reported, never silently abandoned."""
        if self._closed.is_set():
            return not self._wedged
        self._closed.set()
        clean = True
        if self._sup is not None:
            clean = self._sup.stop(timeout=timeout)
            if not clean:
                self._wedged = True
                instant("close_wedged", cat="fault", engine=self.name,
                        timeout_s=timeout)
        while True:
            try:
                r = self._q.get_nowait()
            except queue.Empty:
                break
            self._shed(r, "shutdown")
        return clean

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Engine-level counters + rolling queue/total latency percentiles
        + the cost model, with the backend's own stats() nested under
        ``backend`` (per-stage timers, truncations, replica epochs)."""
        out = dict(
            pending=self.pending(),
            counters=self.counters.snapshot(),
            queue=self.queue_lat.snapshot(),
            latency=self.total_lat.snapshot(),
            cost_model_ms={str(k): round(v, 3)
                           for k, v in sorted(self._cost_ms.items())},
            wedged=self._wedged,
            backend=self.backend.stats(),
        )
        if self._sup is not None:
            out["dispatch"] = self._sup.stats()
        return out
