"""Serving-tier observability primitives: rolling percentiles + counters.

The synchronous :class:`~repro.index.service.QueryEngine` keeps *every*
batch latency forever — fine for a benchmark pass, wrong for an always-on
tier where stats() is polled while millions of requests stream through.
:class:`Rolling` keeps a bounded window (recent behaviour, O(1) memory);
:class:`Counters` is a plain named-counter bag shared by the async engine
and the fleet so shed/truncation accounting lives in one shape.
"""
from __future__ import annotations

import threading
from collections import deque

import numpy as np


class Rolling:
    """Rolling latency window: ``add(seconds)``, read p50/p95/p99 over the
    most recent ``window`` samples. Thread-safe — the dispatch thread adds
    while callers snapshot."""

    def __init__(self, window: int = 4096):
        self._buf: deque = deque(maxlen=int(window))
        self._n = 0                     # total ever added (not windowed)
        self._lock = threading.Lock()

    def add(self, seconds: float) -> None:
        with self._lock:
            self._buf.append(float(seconds))
            self._n += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    @property
    def total(self) -> int:
        """Samples ever added (the window only bounds what percentiles
        are computed over)."""
        with self._lock:
            return self._n

    def snapshot(self) -> dict:
        """{count, total, p50_ms, p95_ms, p99_ms, mean_ms} over the
        current window (zeros when empty)."""
        with self._lock:
            arr = np.asarray(self._buf, dtype=np.float64)
            n = self._n
        if arr.size == 0:
            return dict(count=0, total=n, p50_ms=0.0, p95_ms=0.0,
                        p99_ms=0.0, mean_ms=0.0)
        return dict(
            count=int(arr.size),
            total=n,
            p50_ms=float(np.percentile(arr, 50) * 1e3),
            p95_ms=float(np.percentile(arr, 95) * 1e3),
            p99_ms=float(np.percentile(arr, 99) * 1e3),
            mean_ms=float(arr.mean() * 1e3),
        )


class Counters:
    """Thread-safe named counters (shed reasons, ingests, compactions)."""

    def __init__(self, *names: str):
        self._lock = threading.Lock()
        self._c = {n: 0 for n in names}

    def bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._c[name] = self._c.get(name, 0) + by

    def __getitem__(self, name: str) -> int:
        with self._lock:
            return self._c.get(name, 0)

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._c)
