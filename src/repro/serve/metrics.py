"""Serving-tier observability adapters: rolling percentiles + counters.

These are now thin adapters over :mod:`repro.obs`. :class:`Rolling`
keeps its exact sample-window percentiles (tests pin the exact values,
and a window is the right view for "recent behaviour") but can *mirror*
every sample into a registry :class:`~repro.obs.registry.Histogram`
child, whose fixed-log-bucket counts merge exactly across replicas and
processes — the window alone never could. :class:`Counters` is a plain
named-counter bag whose names are **declared at construction**; bumping
an undeclared name warns (a typo'd counter name used to vanish silently
into a fresh key) but still counts, so existing callers keep working
while the typo surfaces.
"""
from __future__ import annotations

import threading
import warnings
from collections import deque

import numpy as np


class Rolling:
    """Rolling latency window: ``add(seconds)``, read p50/p95/p99 over the
    most recent ``window`` samples. Thread-safe — the dispatch thread adds
    while callers snapshot. ``hist`` (optional) is a
    :class:`repro.obs.registry.Histogram` that receives every sample too:
    the window answers "what is latency *now*", the histogram merges
    across replicas and never forgets."""

    def __init__(self, window: int = 4096, hist=None):
        self._buf: deque = deque(maxlen=int(window))
        self._n = 0                     # total ever added (not windowed)
        self._lock = threading.Lock()
        self._hist = hist

    def add(self, seconds: float) -> None:
        with self._lock:
            self._buf.append(float(seconds))
            self._n += 1
        if self._hist is not None:
            self._hist.observe(seconds)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    @property
    def total(self) -> int:
        """Samples ever added (the window only bounds what percentiles
        are computed over)."""
        with self._lock:
            return self._n

    def snapshot(self) -> dict:
        """{count, total, p50_ms, p95_ms, p99_ms, mean_ms} over the
        current window (zeros when empty)."""
        with self._lock:
            arr = np.asarray(self._buf, dtype=np.float64)
            n = self._n
        if arr.size == 0:
            return dict(count=0, total=n, p50_ms=0.0, p95_ms=0.0,
                        p99_ms=0.0, mean_ms=0.0)
        return dict(
            count=int(arr.size),
            total=n,
            p50_ms=float(np.percentile(arr, 50) * 1e3),
            p95_ms=float(np.percentile(arr, 95) * 1e3),
            p99_ms=float(np.percentile(arr, 99) * 1e3),
            mean_ms=float(arr.mean() * 1e3),
        )


class Counters:
    """Thread-safe named counters (shed reasons, ingests, compactions).

    Names are declared at construction. An undeclared ``bump`` warns —
    the registry's declared-at-registration discipline, adapted: the old
    behaviour silently created a fresh key, so a typo'd name split the
    count in two and both halves looked plausible. The bump still counts
    (back-compat), but the typo is now loud."""

    def __init__(self, *names: str):
        self._lock = threading.Lock()
        self._c = {n: 0 for n in names}
        self._declared = frozenset(names)

    def bump(self, name: str, by: int = 1) -> None:
        if name not in self._declared:
            warnings.warn(
                f"Counters.bump({name!r}): undeclared counter name "
                f"(declared: {sorted(self._declared)}) — counting anyway, "
                f"but check for a typo", stacklevel=2)
        with self._lock:
            self._c[name] = self._c.get(name, 0) + by

    def __getitem__(self, name: str) -> int:
        with self._lock:
            return self._c.get(name, 0)

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._c)
