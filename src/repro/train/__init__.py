"""Training runtime: hand-rolled AdamW (+fp32 master weights), schedules,
microbatched train step, gradient compression, sharded train state."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from .train_lib import TrainConfig, TrainState, make_train_step, init_train_state

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "warmup_cosine",
           "TrainConfig", "TrainState", "make_train_step",
           "init_train_state"]
