"""Sharded, microbatched training step.

Structure (DESIGN.md §5):
  * grad accumulation: `lax.scan` over microbatches so saved activations per
    step are bounded by one microbatch (granite-34b train_4k needs this);
  * params FSDP-sharded over "data" + TP over "model" via sharding rules;
    GSPMD inserts the per-layer all-gathers inside the layer scan (ZeRO-3)
    and the gradient reduce-scatters;
  * optional int8 gradient compression with error feedback on the pure-DP
    (pod) axis (train/compression.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import loss_fn
from ..models.sharding import make_rules, param_spec_tree, logical
from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    compression: bool = False     # int8 grad all-reduce on the pod axis


@jax.tree_util.register_pytree_node_class
@dataclass
class TrainState:
    params: dict
    opt_state: dict
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(key, cfg, mesh: Mesh | None = None) -> TrainState:
    from ..models import init_params
    params = init_params(key, cfg)
    opt_state = adamw_init(params)
    if mesh is not None:
        from ..models.sharding import shard_params
        params = shard_params(params, cfg, mesh)
        rules = make_rules(cfg, mesh)
        pspecs = param_spec_tree(params, cfg, rules)
        opt_specs = {"master": pspecs, "mu": pspecs, "nu": pspecs,
                     "step": P()}
        opt_state = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            opt_state, opt_specs,
            is_leaf=lambda x: isinstance(x, jnp.ndarray))
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model_cfg, train_cfg: TrainConfig, mesh: Mesh | None,
                    rules: dict | None = None):
    """Returns a jittable train_step(state, batch) -> (state, metrics).

    batch: dict(inputs (B, S[, d]), targets (B, S)) — global batch; it is
    split into train_cfg.n_microbatches along axis 0. `rules` overrides the
    default sharding rules (e.g. ZeRO-1 variants).
    """
    if rules is None:
        rules = make_rules(model_cfg, mesh) if mesh is not None else {}
    nm = train_cfg.n_microbatches

    def grad_accum(params, batch):
        def micro(carry, mb):
            gacc, lacc, aacc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb, model_cfg, rules)
            gacc = jax.tree.map(jnp.add, gacc, grads)
            return (gacc, lacc + loss, aacc + metrics["aux"]), None

        mb0 = jax.tree.map(
            lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum, asum), _ = jax.lax.scan(
            micro, (zeros, jnp.float32(0), jnp.float32(0)), mb0)
        grads = jax.tree.map(lambda g: g / nm, gsum)
        return grads, lsum / nm, asum / nm

    def train_step(state: TrainState, batch):
        if nm > 1:
            grads, loss, aux = grad_accum(state.params, batch)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch, model_cfg, rules)
            aux = metrics["aux"]
        # NB: gradient compression is an explicit-DP feature (see
        # train/compression.py header) — under GSPMD the reduction is
        # internal and already done here; the compressed path is
        # make_compressed_dp_step, exercised by the elastic-DP example.
        new_params, new_opt, stats = adamw_update(
            grads, state.opt_state, state.params, train_cfg.opt)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1)
        return new_state, {"loss": loss, "aux": aux, **stats}

    return train_step


def batch_sharding(mesh: Mesh, model_cfg):
    """NamedShardings for the global batch (batch axis over pod+data)."""
    rules = make_rules(model_cfg, mesh)
    tok_spec = logical(("batch", None), rules)
    emb_spec = logical(("batch", None, None), rules)
    inp = emb_spec if model_cfg.embedding_inputs else tok_spec
    return {"inputs": NamedSharding(mesh, inp),
            "targets": NamedSharding(mesh, tok_spec)}
