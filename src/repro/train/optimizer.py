"""Hand-rolled AdamW with fp32 master weights (no optax in this container —
and the brief wants the substrate built, not imported).

Params may live in bf16 (compute copies); the optimizer state carries fp32
master weights + moments. Update math runs in fp32; the new compute params
are the masters cast back to their original dtypes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def warmup_cosine(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio·lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    """State: (master fp32, mu fp32, nu fp32, step)."""
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    mu = jax.tree.map(jnp.zeros_like, master)
    nu = jax.tree.map(jnp.zeros_like, master)
    return {"master": master, "mu": mu, "nu": nu,
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = warmup_cosine(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        # decay only matrices (norm scales / biases exempt, standard practice)
        wd = cfg.weight_decay if w.ndim >= 2 else 0.0
        w_new = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + wd * w)
        return m, v, w_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    mu = treedef.unflatten([o[0] for o in out])
    nu = treedef.unflatten([o[1] for o in out])
    master = treedef.unflatten([o[2] for o in out])
    # compute params = master cast back to the original compute dtypes
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    new_state = {"master": master, "mu": mu, "nu": nu, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
