"""Gradient compression: int8 quantized all-reduce with error feedback.

Semantics note (why this is NOT wired inside the pjit train step): under
GSPMD, by the time gradients are visible as values they are already globally
reduced — there is no seam to compress. Compressed reduction therefore
belongs to *explicit* data-parallel execution: a `shard_map` step where each
DP shard computes local grads and the cross-shard mean is an explicit
collective we control. That is exactly the deployment where compression
matters (the cross-pod DCI hop, the scarcest bandwidth in the production
mesh); intra-pod reductions stay fp32 under GSPMD.

Provides:
  * quantize_int8 / dequantize_int8 — blockwise symmetric int8 (scale =
    max|g|/127 per 2048-block): 4x traffic cut, one fp32 scale per block.
  * compressed_dp_mean — int8 psum-mean inside shard_map, with the
    quantization residual returned for error feedback (Karimireddy et al.
    2019: feeding the residual into the next step keeps SGD convergence).
  * make_compressed_dp_step — a complete explicit-DP train step (used by the
    elastic/compression example and tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..util import shard_map_compat

BLOCK = 2048


def quantize_int8(g, block: int = BLOCK):
    """g (flat fp32) -> (q (nb, block) int8, scales (nb, 1) fp32, true_len)."""
    n = g.shape[0]
    nb = -(-n // block)
    gp = jnp.pad(g, (0, nb * block - n)).reshape(nb, block)
    scale = jnp.max(jnp.abs(gp), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(gp / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compressed_dp_mean(g_flat, axis_name: str):
    """int8-compressed mean over `axis_name` (call inside shard_map).

    Returns (mean fp32, residual fp32) — residual = what quantization lost
    locally; callers add it to the next step's gradient (error feedback).
    The wire format is (int8 payload, fp32 scales): the psum itself runs on
    the dequantized payload, modelling the 4x-smaller transfer.
    """
    q, scale, n = quantize_int8(g_flat)
    deq = dequantize_int8(q, scale, n)
    residual = g_flat - deq
    total = jax.lax.psum(deq, axis_name)
    return total / jax.lax.psum(1.0, axis_name), residual


def tree_to_vec(tree):
    flat, treedef = jax.tree.flatten(tree)
    sizes = [x.size for x in flat]
    shapes = [x.shape for x in flat]
    dtypes = [x.dtype for x in flat]
    vec = jnp.concatenate([x.reshape(-1).astype(jnp.float32) for x in flat])
    return vec, (treedef, sizes, shapes, dtypes)


def vec_to_tree(vec, meta):
    treedef, sizes, shapes, dtypes = meta
    out, off = [], 0
    for sz, shp, dt in zip(sizes, shapes, dtypes):
        out.append(vec[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return treedef.unflatten(out)


def make_compressed_dp_step(loss_fn, mesh, axis_name: str = "data",
                            lr: float = 1e-2, error_feedback: bool = True):
    """Explicit-DP SGD step with int8-compressed gradient mean.

    loss_fn(params, batch) -> scalar; params replicated, batch sharded on
    axis 0 across `axis_name`. State: (params, residual_vec).
    Returns step(state, batch) -> (state, loss_mean).
    """
    def local_step(params, residual, batch):
        # residual arrives (1, nvec) — this shard's slice of the stacked
        # per-shard residual state.
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        gvec, meta = tree_to_vec(grads)
        if error_feedback:
            gvec = gvec + residual[0]
        gmean, new_residual = compressed_dp_mean(gvec, axis_name)
        pvec, pmeta = tree_to_vec(params)
        new_params = vec_to_tree(pvec - lr * gmean, pmeta)
        return (new_params, new_residual[None],
                jax.lax.pmean(loss, axis_name))

    def step(state, batch):
        params, residual = state      # residual: (n_shards, nvec)
        fn = shard_map_compat(
            local_step, mesh,
            in_specs=(P(), P(axis_name), P(axis_name)),
            out_specs=(P(), P(axis_name), P()))
        new_params, new_res, loss = fn(params, residual, batch)
        return (new_params, new_res), loss

    def init_residual(params):
        nvec = sum(x.size for x in jax.tree.leaves(params))
        return jnp.zeros((mesh.shape[axis_name], nvec), jnp.float32)

    step.init_residual = init_residual
    return step
