"""repro.index — persistent sharded LSH index + batched query serving.

The paper's economic insight (§5.3) is that reference-database signature
generation is paid once and amortized across query sets. This subsystem makes
that a first-class artifact:

* ``store``     — :class:`SignatureIndex`: packed signatures + per-band
  sorted bucket keys with CSR offsets, persistence keyed by a config
  fingerprint (segment directory or legacy monolithic npz), append-only
  ``add()`` and an explicit ``compact()``.
* ``segments``  — :class:`Segment`: the unit of incremental growth
  (sealed per-ingest CSR over global ids), stable linear merge into the
  full bucket table, manifest + per-segment persistence (O(delta) saves).
* ``partition`` — :class:`BucketPartition`: shard-owned stacked CSR slabs,
  buckets routed by ``mix32(band_key) % n_shards`` (the MapReduce shuffle
  as a data layout) — the one distribution primitive under the
  single-device probe, the sharded serving ring, and the all-pairs
  self-join.
* ``shard``     — :class:`ShardedIndex`: bucket-sharded probe serving over
  a mesh; query blocks rotate around the ring (``ppermute``) probing each
  shard's local slab, bit-exact with the single-device probe.
* ``service``   — :class:`QueryEngine`: micro-batched serving with
  fixed-shape padding (jit-cache stability), bucket probing, exact Hamming
  filtering, fixed-capacity top-k, overflow grow-and-retry, optional
  Smith-Waterman re-rank, and latency/throughput stats.
* ``stats``     — bucket-occupancy/entropy diagnostics (per-band
  histograms, hash-scheme comparison).
"""
from .store import IndexConfigMismatch, SignatureIndex, config_fingerprint
from .segments import Segment, merge_band_csrs
from .partition import BucketPartition, bucket_owners
from .shard import ShardedIndex
from .service import QueryEngine, ServingConfig, topk_dense, topk_probe
from .stats import BandStats, band_stats, compare_schemes, occupancy_report

__all__ = [
    "SignatureIndex", "IndexConfigMismatch", "config_fingerprint",
    "Segment", "merge_band_csrs",
    "BucketPartition", "bucket_owners",
    "ShardedIndex",
    "QueryEngine", "ServingConfig", "topk_dense", "topk_probe",
    "BandStats", "band_stats", "compare_schemes", "occupancy_report",
]
