"""Bucket-occupancy diagnostics for a :class:`SignatureIndex`.

The quality of every probe and self-join depends on how evenly the LSH keys
spread references over buckets: a degenerate band (one giant bucket) turns
the probe into a dense sweep and the self-join quadratic. These helpers make
that observable — per-band bucket-size histograms, occupancy entropy, and a
scheme comparison used to answer the ROADMAP question of whether
``scheme="splitmix"`` recovers the key diversity the Java-hash signature
bits lose to position skew.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .store import SignatureIndex


@dataclass(frozen=True)
class BandStats:
    band: int
    n_buckets: int               # unique keys
    n_entries: int               # references placed (valid only)
    max_bucket: int
    mean_bucket: float
    entropy_bits: float          # Shannon entropy of the occupancy dist.
    entropy_frac: float          # entropy / log2(n_entries) in [0, 1]
    expected_probe: float        # E[bucket size of a random member] =
                                 # sum m^2 / n — the probe/self-join cost
    hist: dict[int, int]         # bucket size -> count (log2-binned above 8)


def _hist(sizes: np.ndarray) -> dict[int, int]:
    out: dict[int, int] = {}
    for s in sizes:
        s = int(s)
        key = s if s <= 8 else 1 << int(np.ceil(np.log2(s)))
        out[key] = out.get(key, 0) + 1
    return dict(sorted(out.items()))


def band_stats(index: SignatureIndex) -> list[BandStats]:
    """Per-band occupancy statistics of a built index."""
    index._ensure_built()
    out = []
    for b, (keys, offsets, ids) in enumerate(index._csr_np):
        sizes = np.diff(np.asarray(offsets)).astype(np.int64)
        n = int(sizes.sum())
        if n == 0:
            out.append(BandStats(b, 0, 0, 0, 0.0, 0.0, 0.0, 0.0, {}))
            continue
        p = sizes / n
        ent = float(-(p * np.log2(p, where=p > 0)).sum())
        out.append(BandStats(
            band=b, n_buckets=len(sizes), n_entries=n,
            max_bucket=int(sizes.max()), mean_bucket=float(sizes.mean()),
            entropy_bits=ent,
            entropy_frac=ent / max(np.log2(n), 1e-9),
            expected_probe=float((sizes.astype(float) ** 2).sum() / n),
            hist=_hist(sizes)))
    return out


def occupancy_report(index: SignatureIndex) -> str:
    """Human-readable per-band occupancy summary."""
    lines = [f"index: {index.size} refs, layout={index.layout}, "
             f"bands={index.n_bands}, scheme={index.cfg.scheme}"]
    for s in band_stats(index):
        lines.append(
            f"  band {s.band}: {s.n_buckets} buckets / {s.n_entries} refs, "
            f"max={s.max_bucket}, E[probe]={s.expected_probe:.1f}, "
            f"entropy={s.entropy_bits:.2f}b ({s.entropy_frac:.0%} of ideal)")
    return "\n".join(lines)


def compare_schemes(cfg, ids, lens, *, schemes=("java", "splitmix"),
                    bands: int | None = None) -> dict[str, list[BandStats]]:
    """Build an index per hash scheme over the same corpus and report
    occupancy side by side (the ROADMAP key-entropy experiment)."""
    import dataclasses as dc
    out = {}
    for scheme in schemes:
        c = dc.replace(cfg, scheme=scheme)
        idx = SignatureIndex.build(c, ids, lens, bands=bands)
        out[scheme] = band_stats(idx)
    return out
