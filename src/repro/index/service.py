"""Batched query serving over a :class:`~repro.index.store.SignatureIndex`.

The serving path (paper §5.3's "database prepared once" made operational):

  submit -> micro-batch queue -> pad to a fixed shape ladder (jit-cache
  stability) -> signature generation -> bucket probe (CSR searchsorted) ->
  exact Hamming filter -> fixed-capacity top-k -> optional Smith-Waterman
  re-rank of the top-k.

Two exact-filter paths:

* ``dense`` — the Pallas ``hamming_dist_kernel`` sweeps the query batch
  against the whole index (:func:`repro.kernels.ops.all_pairs_hamming`);
  right when the index fits the arithmetic-intensity window.
* ``probe`` — CSR bucket probing generates candidates; only candidate
  signatures are gathered and popcount-filtered. Right at scale.

Capacity discipline (DESIGN.md §5 "no silent caps"): the probe reports
overflow when a bucket exceeds the candidate cap and the engine grows the
cap and retries; the pair-dump path (:meth:`QueryEngine.search_pairs`) uses
the ``overflowed`` flag of :class:`~repro.core.pipeline.SearchResult` the
same way.
"""
from __future__ import annotations

import functools
import itertools
import time
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alphabet import PAD, encode
from ..core.hamming import hamming_distance
from ..core.pipeline import ScalLoPS
from ..kernels import ops
from ..obs import REGISTRY, Histogram, span, trace_sentinel
from ..obs.trace import record as record_span
from .spgemm import row_product_positions
from .store import SignatureIndex

BIG = 1 << 30  # sentinel distance for masked slots (int32-safe)


# ---------------------------------------------------------------- primitives
# The searchsorted core of every bucket probe is the row slice of the
# SpGEMM candidate product (repro.index.spgemm): a query's product row IS
# the matched bucket's member window. Shared by the id-returning probe
# below and the sharded ring's sig-gathering probe (repro.index.shard),
# so probe and join semantics can never diverge.
_probe_csr_positions = row_product_positions


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("probe_csr")
def _probe_csr(qkeys, csr_keys, csr_offsets, csr_ids, *, cap: int):
    """One band's bucket probe: searchsorted into the CSR unique keys.

    qkeys (B,) uint32 -> (cand (B, cap) int32 with -1 padding,
    bucket_size (B,) int32 — the *true* matched-bucket size, which may
    exceed cap; the caller detects truncation from it).
    """
    B = qkeys.shape[0]
    U = csr_keys.shape[0]
    E = csr_ids.shape[0]
    if U == 0 or E == 0:
        return (jnp.full((B, cap), -1, jnp.int32), jnp.zeros(B, jnp.int32))
    idx, ok, size = _probe_csr_positions(qkeys, csr_keys, csr_offsets,
                                         cap=cap, E=E)
    cand = jnp.where(ok, csr_ids[idx], -1)
    return cand, size


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("probe_fused")
def _probe_csr_fused(qkeys, csr_keys, csr_offsets, csr_ids, *, cap: int):
    """All bands' bucket probes + cross-band dedup in ONE jitted program.

    The per-band CSR arrays are stacked and padded to common sizes by the
    bucket partition layer (keys padded by repeating the last key, offsets
    by repeating the end offset — padded entries are empty buckets, so they
    match nothing; see repro.index.partition). Fusing removes the per-band
    Python dispatch loop from the probe hot path — one device program per
    query batch instead of n_bands (ROADMAP "probe path on-device").

    qkeys (nb, B) uint32, csr_keys (nb, U), csr_offsets (nb, U+1),
    csr_ids (nb, E) -> (cand (B, nb*cap) int32 with -1 padding, duplicates
    across bands allowed — _topk_from_candidates dedups downstream,
    bucket_size (nb, B) int32 — true matched-bucket sizes).
    """
    def one_band(qk, keys, offsets, ids):
        return _probe_csr(qk, keys, offsets, ids, cap=cap)

    cand, size = jax.vmap(one_band)(qkeys, csr_keys, csr_offsets, csr_ids)
    B = qkeys.shape[1]
    return jnp.transpose(cand, (1, 0, 2)).reshape(B, -1), size


def _dedup_candidates(cand, dist, ok):
    """Row-wise candidate dedup: sort slots by candidate id (invalid ids
    last), mask repeated ids (duplicates carry the same exact distance, so
    keeping the first is lossless). Returns (ids_sorted (B, C),
    dvals (B, C) with BIG in masked slots). Sorting by id makes the
    downstream ``top_k`` break distance ties toward the smaller id — the
    ONE tie-break rule shared by the single-device probe and the sharded
    ring merge (repro.index.shard), which is what makes them bit-exact."""
    B = cand.shape[0]
    sort_key = jnp.where(ok, cand, jnp.int32(2**31 - 1))
    order = jnp.argsort(sort_key, axis=1)
    cs = jnp.take_along_axis(cand, order, axis=1)
    ds = jnp.take_along_axis(dist, order, axis=1)
    oks = jnp.take_along_axis(ok, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((B, 1), bool), cs[:, 1:] == cs[:, :-1]], axis=1)
    oks = oks & ~dup
    return cs, jnp.where(oks, ds, BIG)


@functools.partial(jax.jit, static_argnames=("k",))
@trace_sentinel("topk_candidates")
def _topk_from_candidates(q_sigs, cand, ref_sigs, ref_valid, *, k: int):
    """Exact-filter candidates and keep the k nearest per query.

    cand (B, C) int32 with -1 padding (duplicates across bands allowed —
    deduplicated here). Returns (ids (B, k) int32 with -1 padding,
    dists (B, k) int32 with -1 padding).
    """
    safe = jnp.maximum(cand, 0)
    dist = hamming_distance(q_sigs[:, None, :], ref_sigs[safe])   # (B, C)
    ok = (cand >= 0) & ref_valid[safe]
    cs, dvals = _dedup_candidates(cand, dist, ok)
    return _finalize_topk(dvals, cs, k)


def _finalize_topk(dvals, id_source, k: int):
    """Shared top-k tail: (B, C) distances (BIG = masked) + per-slot ids ->
    ((B, k) ids, (B, k) dists), -1-padded past the valid entries.
    ``id_source=None`` means slot index == reference id (dense path)."""
    C = dvals.shape[1]
    kk = min(k, C)
    neg, idx = jax.lax.top_k(-dvals, kk)
    nd = -neg
    nid = (idx.astype(jnp.int32) if id_source is None
           else jnp.take_along_axis(id_source, idx, axis=1))
    nid = jnp.where(nd < BIG, nid, -1)
    nd = jnp.where(nd < BIG, nd, -1)
    if kk < k:
        pad = ((0, 0), (0, k - kk))
        nid = jnp.pad(nid, pad, constant_values=-1)
        nd = jnp.pad(nd, pad, constant_values=-1)
    return nid, nd


@functools.partial(jax.jit, static_argnames=("k",))
@trace_sentinel("topk_dense")
def _topk_from_dists(dist, ref_valid, *, k: int):
    """(B, N) distances -> top-k (ids, dists) with invalid refs masked."""
    dvals = jnp.where(ref_valid[None, :], dist, BIG)
    return _finalize_topk(dvals, None, k)


def topk_dense(index: SignatureIndex, q_sigs, *, k: int):
    """Exact top-k via the Pallas all-pairs Hamming kernel (whole index)."""
    dist = ops.all_pairs_hamming(jnp.asarray(q_sigs), index.device_sigs)
    return _topk_from_dists(dist, index.device_valid, k=k)


def topk_probe(index: SignatureIndex, q_sigs, *, k: int, cap: int,
               max_cap: int = 1 << 14):
    """Top-k via bucket probing, growing the candidate cap on overflow.

    Returns (ids, dists, final_cap, truncated). Exact within the layout's
    guarantee — every reference within Hamming d of the query shares a
    bucket, so the top-k among candidates contains all true neighbors
    within d — *unless* ``truncated`` is True: a bucket exceeded ``max_cap``
    and candidates were dropped (no silent caps: the flag makes it
    observable; raise ``max_cap`` to restore exactness).
    """
    q_sigs = jnp.asarray(q_sigs)
    while True:
        cand, overflowed = index.probe(q_sigs, cap=cap)
        if not bool(overflowed) or cap >= max_cap:
            break
        cap = min(cap * 2, max_cap)     # grow-and-retry
    ids, dists = _topk_from_candidates(
        q_sigs, cand, index.device_sigs, index.device_valid, k=k)
    return ids, dists, cap, bool(overflowed)


# ---------------------------------------------------------------- serving
@dataclass
class ServingConfig:
    k: int = 10
    max_batch: int = 64
    batch_ladder: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)
    len_quantum: int = 64           # pad query length to multiples of this
    probe_cap: int = 32             # initial candidates per band per query
    max_probe_cap: int = 1 << 14
    dense_threshold: int = 1024     # "auto": dense kernel below this size
    mode: str = "auto"              # "probe" | "dense" | "auto"
    rerank: bool = False            # Smith-Waterman re-rank of the top-k
    dp_kernel: str = "wavefront"    # re-rank DP sweep: "wavefront" (anti-
                                    # diagonal, no prefix scan) | "rowwave"
    gap_mode: str = "linear"        # "linear" | "affine" (Gotoh; needs
                                    # dp_kernel="wavefront")
    gap_open: int | None = None     # affine defaults: BLOSUM62 -11 / -1
    gap_extend: int | None = None


_STAGES = ("ladder", "sig", "probe", "rerank")

# registry families every engine registers into (children labeled by the
# engine's name, so per-replica streams stay attributable AND merge — the
# fleet-wide latency histogram is the exact fold of the children)
_M_BATCH = REGISTRY.histogram(
    "serve_batch_seconds", "query_batch wall-clock", labelnames=("engine",))
_M_STAGE = REGISTRY.histogram(
    "serve_stage_seconds", "per-batch serving-stage wall-clock "
    "(ladder/sig/probe/rerank)", labelnames=("engine", "stage"))
_M_QUERIES = REGISTRY.counter(
    "serve_queries", "queries served", labelnames=("engine",))
_M_TRUNC = REGISTRY.counter(
    "serve_truncations", "batches whose probe overflowed even at "
    "max_probe_cap (the no-silent-caps counter)", labelnames=("engine",))

_engine_ids = itertools.count()


class _Stats:
    """Bounded per-engine serving stats. The first cut kept EVERY batch
    latency in a growing list — flagged wrong for always-on serving in
    serve/metrics.py's own docstring — and its percentiles couldn't merge
    across replicas. Fixed-log-bucket histograms fix both: O(buckets)
    memory forever, and the registry children (labeled by engine name)
    fold exactly across replicas (repro.obs.registry). Each batch is
    observed twice — into the resettable ``stats()`` view here and into
    the monotonic registry children (reset() must not rewind a scrape)."""

    def __init__(self, name: str):
        self._m_lat = _M_BATCH.labels(engine=name)
        self._m_stage = {s: _M_STAGE.labels(engine=name, stage=s)
                         for s in _STAGES}
        self._m_queries = _M_QUERIES.labels(engine=name)
        self._m_trunc = _M_TRUNC.labels(engine=name)
        self.reset()

    def reset(self) -> None:
        self.lat = Histogram(self._m_lat.bounds)
        # accumulated per-stage seconds over every batch served (coarse
        # wall-clock attribution — jax dispatch is async, so work issued in
        # one stage can complete inside the next sync point; the probe
        # stage carries that slack, documented in stats())
        self.stage = dict.fromkeys(_STAGES, 0.0)
        self.n_queries = 0
        self.truncations = 0        # batches whose probe hit max_probe_cap

    def observe_batch(self, n_queries: int, seconds: float,
                      stage_seconds: dict) -> None:
        self.lat.observe(seconds)
        self._m_lat.observe(seconds)
        self.n_queries += n_queries
        self._m_queries.inc(n_queries)
        for s, v in stage_seconds.items():
            self.stage[s] += v
            self._m_stage[s].observe(v)

    def observe_truncation(self) -> None:
        self.truncations += 1
        self._m_trunc.inc()


class QueryEngine:
    """Micro-batched query serving over a built or loaded index.

    ``submit()`` enqueues raw sequences (strings or encoded int8 rows);
    ``flush()`` drains the queue in fixed-shape micro-batches and returns
    per-query results; ``query_batch()`` is the synchronous batch entry.
    ``ref_seqs=(ids, lens)`` enables Smith-Waterman re-ranking.
    """

    def __init__(self, index: SignatureIndex, cfg: ServingConfig | None = None,
                 *, ref_seqs=None, sharded=None, name: str | None = None):
        self.index = index
        self.cfg = cfg or ServingConfig()
        self.sl = ScalLoPS(index.cfg)
        self.ref_seqs = ref_seqs
        self.sharded = sharded          # optional ShardedIndex fan-out path
        self.name = name or f"engine{next(_engine_ids)}"
        self._probe_cap = self.cfg.probe_cap
        self._queue: list[tuple[np.ndarray, int]] = []
        self._stats = _Stats(self.name)
        self._ref_dev = None            # device-resident (ids, lens) for the
                                        # SW re-rank gather (uploaded once)
        if self.cfg.rerank and ref_seqs is None:
            raise ValueError("rerank=True needs ref_seqs=(ref_ids, ref_lens)")
        self._ref_dev_src = None        # the ref_seqs the snapshot mirrors
        if self.cfg.rerank:       # upload once; skipped when never re-ranking
            self._upload_refs()

    def _upload_refs(self) -> None:
        """Mirror ``self.ref_seqs`` on device for the re-rank gather. Rebind
        ``engine.ref_seqs`` to refresh (e.g. after ``index.add``); in-place
        mutation of the arrays is not tracked (same contract as the index's
        own signatures, which are computed at build time)."""
        self._ref_dev = (jnp.asarray(np.asarray(self.ref_seqs[0], np.int8)),
                         jnp.asarray(np.asarray(self.ref_seqs[1], np.int32)))
        self._ref_dev_src = self.ref_seqs

    # ------------------------------------------------------------ queue
    def submit(self, seq) -> None:
        """Enqueue one query (amino-acid string or encoded int8 array)."""
        if isinstance(seq, str):
            row = np.asarray(encode(seq), np.int8)
        else:
            row = np.asarray(seq, np.int8).reshape(-1)
        self._queue.append((row, len(row)))

    def pending(self) -> int:
        return len(self._queue)

    def flush(self):
        """Serve every queued query; returns [(ids (k,), dists (k,)), ...]
        in submission order."""
        out = []
        queue, self._queue = self._queue, []
        for i in range(0, len(queue), self.cfg.max_batch):
            chunk = queue[i:i + self.cfg.max_batch]
            L = max(l for _, l in chunk)
            ids = np.full((len(chunk), max(L, 1)), PAD, np.int8)
            lens = np.zeros(len(chunk), np.int32)
            for j, (row, l) in enumerate(chunk):
                ids[j, :l] = row
                lens[j] = l
            nid, nd = self.query_batch(ids, lens)
            out.extend((nid[j], nd[j]) for j in range(len(chunk)))
        return out

    # ------------------------------------------------------------ shaping
    def _pad_shapes(self, ids, lens):
        """Pad batch and length to the fixed-shape ladder (jit stability)."""
        B0, L0 = ids.shape
        ladder = [b for b in self.cfg.batch_ladder if b >= B0]
        B = min(ladder) if ladder else self.cfg.max_batch
        q = self.cfg.len_quantum
        L = max(q, -(-L0 // q) * q)
        out = np.full((B, L), PAD, np.int8)
        out[:B0, :L0] = ids
        olens = np.zeros(B, np.int32)
        olens[:B0] = lens
        return out, olens

    # ------------------------------------------------------------ serving
    def query_batch(self, ids, lens):
        """Serve one batch: (B0, L) int8 + (B0,) lengths ->
        (neighbor_ids (B0, k), neighbor_dists (B0, k)) int32 numpy, -1 padded.
        Queries with zero neighbour features (paper §5.2) get all -1."""
        ids = np.asarray(ids, np.int8)
        lens = np.asarray(lens, np.int32)
        B0 = ids.shape[0]
        if B0 > self.cfg.max_batch:
            parts = [self.query_batch(ids[i:i + self.cfg.max_batch],
                                      lens[i:i + self.cfg.max_batch])
                     for i in range(0, B0, self.cfg.max_batch)]
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]))

        t0 = time.perf_counter()
        pids, plens = self._pad_shapes(ids, lens)
        t_ladder = time.perf_counter()
        q_sigs = self.sl.signatures(pids, plens)
        q_valid = np.asarray(self.sl.feature_counts(pids, plens)) > 0
        t_sig = time.perf_counter()

        k = self.cfg.k
        truncated = False
        if self.sharded is not None:
            nid, nd, self._probe_cap, truncated = self.sharded.topk(
                q_sigs, k=k, cap=self._probe_cap,
                max_cap=self.cfg.max_probe_cap)
        elif self._mode() == "dense":
            nid, nd = topk_dense(self.index, q_sigs, k=k)
        else:
            nid, nd, self._probe_cap, truncated = topk_probe(
                self.index, q_sigs, k=k, cap=self._probe_cap,
                max_cap=self.cfg.max_probe_cap)
        if truncated:
            self._stats.observe_truncation()
            warnings.warn(
                f"probe candidates truncated at max_probe_cap="
                f"{self.cfg.max_probe_cap}; top-k may miss neighbors — "
                f"raise ServingConfig.max_probe_cap", RuntimeWarning,
                stacklevel=2)
        nid = np.array(nid)     # writable host copies
        nd = np.array(nd)
        t_probe = time.perf_counter()
        nid[~q_valid] = -1
        nd[~q_valid] = -1
        nid, nd = nid[:B0], nd[:B0]
        if self.cfg.rerank:
            nid, nd = self._rerank(ids, lens, nid, nd)

        t_end = time.perf_counter()
        # spans from the timestamps already taken (no extra clock reads);
        # the enclosing dispatch/route context tags them with the batch's
        # query trace IDs (repro.obs.trace)
        record_span("query_batch", t0, t_end, engine=self.name, B=B0)
        record_span("ladder", t0, t_ladder)
        record_span("sig", t_ladder, t_sig)
        record_span("probe", t_sig, t_probe, cap=self._probe_cap,
                    sharded=self.sharded is not None)
        if self.cfg.rerank:
            record_span("rerank", t_probe, t_end)
        self._stats.observe_batch(B0, t_end - t0, {
            "ladder": t_ladder - t0, "sig": t_sig - t_ladder,
            "probe": t_probe - t_sig, "rerank": t_end - t_probe})
        return nid, nd

    def _mode(self) -> str:
        if self.cfg.mode != "auto":
            return self.cfg.mode
        return "dense" if self.index.size <= self.cfg.dense_threshold \
            else "probe"

    # ------------------------------------------------------------ pair dump
    def search_pairs(self, q_ids, q_lens, *, max_pairs: int | None = None,
                     max_grow: int = 1 << 22):
        """Classic unordered pair dump (`ScalLoPS.search` semantics) against
        the indexed references, honouring the result's ``overflowed`` flag:
        capacity grows and the join retries until nothing is truncated."""
        q_sigs = self.sl.signatures(np.asarray(q_ids, np.int8),
                                    np.asarray(q_lens, np.int32))
        q_valid = np.asarray(self.sl.feature_counts(q_ids, q_lens)) > 0
        mp = max_pairs or self.index.cfg.max_pairs
        while True:
            res = self.sl.search(q_sigs, self.index.device_sigs,
                                 max_pairs=mp, q_valid=q_valid,
                                 r_valid=self.index.device_valid)
            if not bool(res.overflowed) or mp >= max_grow:
                return res
            mp = min(mp * 2, max_grow)  # grow-and-retry

    # ------------------------------------------------------------ rerank
    def _rerank(self, ids, lens, nid, nd):
        """Reorder each query's top-k by Smith-Waterman score (descending).

        Device-resident: the reference corpus was uploaded once at engine
        construction; both pair sides are gathered *on device* inside one
        jitted gather+DP program (`align.smith_waterman.sw_gather_scores`) —
        the only H2D traffic per call is the query batch and the (M,) index
        vectors, never a per-pair host copy loop. The (query, slot) pair
        list is padded to a fixed M (all-PAD rows score 0) and the query
        length is quantized to the serving padding ladder
        (``len_quantum``), so the gather+DP program compiles once per
        ladder rung instead of once per raw batch width.
        """
        from ..align.smith_waterman import sw_gather_scores
        if self.ref_seqs is not self._ref_dev_src:
            self._upload_refs()     # caller rebound ref_seqs (index.add etc.)
        ref_ids_dev, ref_lens_dev = self._ref_dev
        B, K = nid.shape
        qi, ki = np.nonzero(nid >= 0)
        if len(qi) == 0:
            return nid, nd
        rid = nid[qi, ki]
        if rid.max(initial=-1) >= ref_ids_dev.shape[0]:
            # the on-device gather clamps instead of raising — fail loudly
            # rather than silently re-rank against the wrong reference
            raise IndexError(
                f"re-rank hit reference id {int(rid.max())} outside "
                f"ref_seqs ({int(ref_ids_dev.shape[0])} rows); pass the "
                f"grown corpus as ref_seqs after index.add()")
        M = -(-len(qi) // 64) * 64          # fixed-shape ladder for the wave
        qv = np.full(M, -1, np.int32)
        rv = np.full(M, -1, np.int32)
        qv[:len(qi)] = qi
        rv[:len(qi)] = rid
        # quantize Lq to the serving ladder (raw batch widths would retrace
        # the gather+DP program on every new width)
        q = self.cfg.len_quantum
        Lq = max(q, -(-ids.shape[1] // q) * q)
        ids_q = np.full((ids.shape[0], Lq), PAD, np.int8)
        ids_q[:, :ids.shape[1]] = ids
        scores = np.asarray(sw_gather_scores(
            jnp.asarray(ids_q),
            jnp.asarray(np.asarray(lens, np.int32)),
            ref_ids_dev, ref_lens_dev, qv, rv,
            Lq=Lq, Lr=int(ref_ids_dev.shape[1]),
            dp_kernel=self.cfg.dp_kernel, gap_mode=self.cfg.gap_mode,
            gap_open=self.cfg.gap_open,
            gap_extend=self.cfg.gap_extend))[:len(qi)]
        smat = np.full((B, K), -np.inf)
        smat[qi, ki] = scores
        order = np.argsort(-smat, axis=1, kind="stable")
        return (np.take_along_axis(nid, order, axis=1),
                np.take_along_axis(nd, order, axis=1))

    # ------------------------------------------------------------ warmup
    def warmup(self, q_ids=None, q_lens=None, *,
               max_len: int | None = None) -> int:
        """Compile every (batch-rung, length-quantum) serving shape before
        traffic arrives, so the open-loop points of an SLO sweep measure
        serving instead of XLA compiles (the jit cache keys on the padded
        ARRAY width — warming only full-width rows silently leaves real
        quanta cold, which the SLO benchmark learned the hard way).

        With sample queries ``(q_ids, q_lens)``, first runs EVERY sample
        through the engine to settle the grow-and-retry probe cap — the
        worst *bucket* in the sample set decides the cap, not the longest
        row, and a cap grown after warmup would retrace every rung
        mid-traffic — then warms exactly the length quanta the samples
        occupy at the settled cap. Without samples, synthesizes rows for
        every quantum up to ``max_len`` (default: one quantum). Emits one
        ``warmup`` span per (rung, quantum); returns shapes warmed. Runs
        through ``query_batch``, so call :meth:`reset_stats` afterwards
        if warmup batches must not pollute serving stats."""
        quanta: dict[int, np.ndarray] = {}
        qm = self.cfg.len_quantum
        if q_ids is not None:
            lens = np.asarray(q_lens)
            for j, L in enumerate(lens):
                q = int(-(-int(L) // qm) * qm)
                if q not in quanta or int(L) > len(quanta[q]):
                    quanta[q] = np.asarray(q_ids[j][:int(L)], np.int8)
        else:
            top = max(int(max_len or qm), qm)
            for q in range(qm, (-(-top // qm) * qm) + 1, qm):
                quanta[q] = np.zeros(q, np.int8)    # shapes are what compile
        rungs = [b for b in self.cfg.batch_ladder if b <= self.cfg.max_batch]
        if q_ids is not None:
            # cap-settling pass: one chunked sweep over the full sample
            # set so the rung loop below compiles at the FINAL cap
            b = max(rungs)
            lens32 = np.asarray(q_lens, np.int32)
            with span("warmup", rung=b, engine=self.name, settle=True,
                      samples=len(lens32)):
                for i in range(0, len(lens32), b):
                    self.query_batch(q_ids[i:i + b], lens32[i:i + b])
        for b in rungs:
            for q, row in sorted(quanta.items()):
                with span("warmup", rung=b, quantum=q, engine=self.name):
                    self.query_batch(np.repeat(row[None, :], b, axis=0),
                                     np.full(b, len(row), np.int32))
        return len(rungs) * len(quanta)

    def reset_stats(self) -> None:
        """Zero the ``stats()`` view (e.g. after warmup). The registry
        children stay monotonic — a Prometheus scrape never rewinds."""
        self._stats.reset()

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Latency/throughput summary over every batch served so far —
        bounded memory (fixed-log-bucket histograms, repro.obs.registry),
        percentiles are bucket-interpolated estimates (<= one bucket's
        relative width off the sample percentile). ``index_epoch`` is the
        backing index's segment counter — it moves when the engine serves
        across a live refresh (``index.add`` landed between batches)
        without the engine being rebuilt. ``stage_ms`` splits the
        accumulated wall-clock by serving stage (ladder/sig/probe/rerank;
        jax dispatch is async, so the probe stage — the device sync point
        — absorbs work issued earlier); ``truncations`` counts batches
        whose probe overflowed even at ``max_probe_cap`` (the
        no-silent-caps counter)."""
        st = self._stats
        lat = st.lat
        stage_ms = {s: v * 1e3 for s, v in st.stage.items()}
        if lat.count == 0:
            return dict(n_queries=0, n_batches=0, qps=0.0,
                        p50_ms=0.0, p95_ms=0.0, p99_ms=0.0, mean_ms=0.0,
                        stage_ms=stage_ms, truncations=0,
                        index_epoch=self.index.epoch)
        return dict(
            n_queries=st.n_queries,
            n_batches=lat.count,
            qps=st.n_queries / lat.sum,
            p50_ms=lat.quantile(0.50) * 1e3,
            p95_ms=lat.quantile(0.95) * 1e3,
            p99_ms=lat.quantile(0.99) * 1e3,
            mean_ms=lat.mean * 1e3,
            stage_ms=stage_ms,
            truncations=st.truncations,
            index_epoch=self.index.epoch,
        )
