"""Append-only index segments — the unit of incremental growth.

The paper's premise is that the corpus grows faster than compute, yet a
batch-built index answers ``add()`` by re-sorting the world. This module
makes growth first-class: every ingest seals a **segment** — its own
packed signature rows plus its own per-band CSR buckets over *global*
ids — and every other layer consumes segments:

* the merged bucket table of the whole index is a **stable linear merge**
  of the segment CSRs (:func:`merge_band_csrs`), bit-exact with a
  from-scratch build (both orders group equal keys by ascending id);
* the serving partition ingests a *delta* partition of just the new
  segments (``repro.index.shard.ShardedIndex.refresh``) — owners never
  change (``mix32(key) % n_shards`` is id-free), so a new segment only
  grows the owning shards' slabs;
* the all-pairs self-join emits only new-vs-resident pairs from the
  touched buckets (``repro.allpairs.selfjoin.lsh_delta_join``).

Persistence is a **manifest + per-segment files** (the map-side
incremental shuffle made durable): ``save_segmented`` appends only the
segment files that are not on disk yet, so persisting an ingest is
O(delta); an explicit compaction (``SignatureIndex.compact``) merges the
segments back into one (the reduce step). The monolithic ``.npz`` of
PR 1–4 keeps loading through the same entry point as a single sealed
segment.

**Crash safety** (PR 8): every file this module writes — segment npz
and manifest alike — goes through :func:`repro.faults.atomic_write`
(tmp + fsync + ``os.replace``), so a kill at any instant leaves the
directory loadable: segments land before the manifest that references
them, and the manifest swap is atomic. Damage that arrives anyway
(bitrot, a partial copy, a legacy non-atomic writer) raises a typed
:class:`CorruptSegment` naming the offending file; ``load_segmented``
with ``recover=True`` instead moves the damaged segment *and everything
after it* into ``quarantine/`` (the prefix property — later segments'
global ids assume every earlier row exists), rewrites the manifest to
the longest valid prefix, and serves that.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import zipfile

import jax.numpy as jnp
import numpy as np

from ..faults import atomic_write
from ..obs import REGISTRY

_M_QUARANTINED = REGISTRY.counter(
    "segments_quarantined", "damaged segment files moved to quarantine/ "
    "during recovery loads")


class CorruptSegment(ValueError):
    """A persisted segment file (or the manifest entry describing it) is
    damaged: truncated, checksum-mismatched, missing, or inconsistent
    with its neighbours. ``file`` names the offending file."""

    def __init__(self, file: str, message: str):
        super().__init__(message)
        self.file = file


@dataclasses.dataclass
class Segment:
    """One sealed, immutable slice of the index.

    ``base`` is the global id of row 0; ``csr`` holds one
    ``(keys, offsets, ids)`` sorted bucket table per band with **global**
    ids, so segments concatenate without any id arithmetic downstream.
    """
    base: int
    sigs: np.ndarray                    # (n, f//32) uint32
    valid: np.ndarray                   # (n,) bool
    csr: list                           # per band: (keys, offsets, ids)

    @property
    def n_rows(self) -> int:
        return int(self.sigs.shape[0])

    @property
    def n_entries(self) -> int:
        return sum(len(ids) for _, _, ids in self.csr)


def sort_bucket(keys: np.ndarray, ids: np.ndarray):
    """Group (key, id) entries into CSR: (unique keys, offsets, sorted ids).

    The stable sort is the bit-exactness anchor of the whole lifecycle:
    ids enter in ascending order, so every bucket's members come out in
    ascending id order — which is also what a stable merge of per-segment
    buckets (ascending, disjoint id ranges) produces.
    """
    order = np.argsort(keys, kind="stable")
    ks, sids = keys[order], ids[order]
    uk, first = np.unique(ks, return_index=True)
    offsets = np.concatenate([first, [len(ks)]]).astype(np.int32)
    return uk.astype(np.uint32), offsets, sids.astype(np.int32)


def _empty_csr():
    return sort_bucket(np.zeros(0, np.uint32), np.zeros(0, np.int32))


def build_segment(sigs, valid, base: int, *, layout: str, f: int, d: int,
                  bands: int, interleave: bool, key_hash: str) -> Segment:
    """Seal a segment: bucket its rows under the index's banding config.

    Only the NEW rows pay signature->key work — resident segments are
    never touched (the append-only contract).
    """
    from ..core.join import band_keys, flip_masks

    sigs = np.ascontiguousarray(np.asarray(sigs, np.uint32))
    valid = np.asarray(valid, bool).reshape(-1)
    local_ids = np.nonzero(valid)[0].astype(np.int64)
    gids = (local_ids + base).astype(np.int32)
    if layout == "flip":
        if len(gids) == 0:
            return Segment(base, sigs, valid, [_empty_csr()])
        masks = flip_masks(f, d)[:, 0]                      # (M,) uint32
        keys = (sigs[local_ids, 0][:, None] ^ masks[None, :]).ravel()
        ids = np.repeat(gids, masks.shape[0])
        return Segment(base, sigs, valid, [sort_bucket(keys, ids)])
    if len(gids) == 0:
        return Segment(base, sigs, valid,
                       [_empty_csr() for _ in range(bands)])
    kb = np.asarray(band_keys(jnp.asarray(sigs[local_ids]), f, bands,
                              interleave=interleave,
                              key_hash=key_hash))           # (V, bands)
    return Segment(base, sigs, valid,
                   [sort_bucket(kb[:, b], gids) for b in range(bands)])


def merge_band_csrs(csr_lists: list[list]) -> list:
    """Merge per-segment per-band CSRs into one bucket table per band.

    Segments arrive in base order with disjoint ascending id ranges, and
    each segment's buckets hold ascending ids, so the stable sort groups
    equal keys with ids ascending — exactly the table a from-scratch
    build over the concatenated corpus produces (bit-exact, including
    bucket member order). Linear in total entries up to the sort; no
    signature or band-key recompute ever happens here.
    """
    if len(csr_lists) == 1:
        return csr_lists[0]
    n_bands = len(csr_lists[0])
    out = []
    for b in range(n_bands):
        keys = np.concatenate(
            [np.repeat(c[b][0], np.diff(c[b][1])) for c in csr_lists])
        ids = np.concatenate([c[b][2] for c in csr_lists])
        out.append(sort_bucket(keys, ids))
    return out


# ---------------------------------------------------------------- manifest IO
MANIFEST_VERSION = 1
MANIFEST_NAME = "manifest.json"


def _segment_filename(gen: int, i: int) -> str:
    return f"seg-g{gen:03d}-{i:05d}.npz"


def manifest_path(path) -> str:
    p = str(path)
    return p if p.endswith(MANIFEST_NAME) else os.path.join(p, MANIFEST_NAME)


def is_segmented(path) -> bool:
    """True when ``path`` names a segment directory / manifest (the
    monolithic legacy ``.npz`` loads through the other branch)."""
    p = str(path)
    return (p.endswith(MANIFEST_NAME) or os.path.isdir(p)
            or not p.endswith(".npz"))


def segment_checksum(seg: Segment) -> str:
    """Content hash of a segment (signatures + validity + every band's
    CSR). Shape metadata alone cannot distinguish two same-config indexes
    over different same-sized corpora — the checksum is what lets the
    append-only save prove the on-disk prefix really IS this index's
    prefix, and the loader prove the files were not swapped/corrupted."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(seg.sigs).tobytes())
    h.update(np.ascontiguousarray(seg.valid).tobytes())
    for keys, offsets, ids in seg.csr:
        h.update(np.ascontiguousarray(keys).tobytes())
        h.update(np.ascontiguousarray(offsets).tobytes())
        h.update(np.ascontiguousarray(ids).tobytes())
    return h.hexdigest()[:16]


def _segment_entry(gen: int, i: int, seg: Segment) -> dict:
    return {"file": _segment_filename(gen, i), "base": int(seg.base),
            "n_rows": seg.n_rows, "n_entries": seg.n_entries,
            "sha": segment_checksum(seg)}


def save_segmented(path, meta: dict, segments: list[Segment],
                   n_bands: int) -> int:
    """Write manifest + per-segment npz files; returns how many segment
    files were (re)written.

    Append-only: when the directory already holds a manifest with the
    same fingerprint whose segment list is a prefix of ours, only the NEW
    segments hit disk — persisting an ingest costs O(delta), never
    O(corpus). Any mismatch (different fingerprint, diverged prefix, or
    a compaction that shrank the list) rewrites everything under a NEW
    write generation (filenames are generation-prefixed, so rewrites
    never touch the files the current manifest points at — a crash
    mid-rewrite leaves the old manifest + old files fully loadable) and
    drops the stale generation's files only after the new manifest has
    landed atomically.
    """
    mpath = manifest_path(path)
    root = os.path.dirname(mpath)
    os.makedirs(root, exist_ok=True)
    start = 0
    gen = 0
    old_files = []
    old = None
    if os.path.exists(mpath):
        try:
            with open(mpath) as fh:
                old = json.load(fh)
        except (OSError, json.JSONDecodeError):
            old = None
    if old is not None:
        old_entries = old.get("segments", [])
        old_files = [e["file"] for e in old_entries]
        gen = int(old.get("write_gen", 0))
        entries = [_segment_entry(gen, i, s)
                   for i, s in enumerate(segments)]
        same_cfg = old.get("fingerprint") == meta["fingerprint"]
        prefix = (len(old_entries) <= len(entries)
                  and all(o == n for o, n in zip(old_entries, entries)))
        if same_cfg and prefix:
            start = len(old_entries)    # append within the old generation
        else:
            gen += 1                    # full rewrite: fresh filenames
    entries = [_segment_entry(gen, i, s) for i, s in enumerate(segments)]
    written = 0
    for i in range(start, len(entries)):
        seg = segments[i]
        payload = {"sigs": seg.sigs, "valid": seg.valid,
                   "base": np.int64(seg.base)}
        for b in range(n_bands):
            keys, offsets, ids = seg.csr[b]
            payload[f"band{b}_keys"] = keys
            payload[f"band{b}_offsets"] = offsets
            payload[f"band{b}_ids"] = ids
        # atomic: a crash mid-save leaves the old manifest pointing only
        # at complete files (segments land before the manifest below)
        atomic_write(os.path.join(root, entries[i]["file"]),
                     lambda fh, p=payload: np.savez_compressed(fh, **p))
        written += 1
    manifest = dict(meta)
    manifest["manifest_version"] = MANIFEST_VERSION
    manifest["write_gen"] = gen
    manifest["segments"] = entries
    blob = json.dumps(manifest, sort_keys=True, indent=1).encode()
    atomic_write(mpath, lambda fh: fh.write(blob))  # lands atomically, last
    keep = {e["file"] for e in entries}
    for f in old_files:                 # a rewrite dropped the old gen
        if f not in keep and os.path.exists(os.path.join(root, f)):
            os.unlink(os.path.join(root, f))
    return written


def _load_segment_file(root: str, e: dict, n_bands: int,
                       expect_base: int) -> Segment:
    """Load + verify ONE manifest entry's segment file; every failure
    mode is a :class:`CorruptSegment` naming the file."""
    f = e["file"]
    fpath = os.path.join(root, f)
    try:
        with np.load(fpath) as z:
            csr = [(z[f"band{b}_keys"], z[f"band{b}_offsets"],
                    z[f"band{b}_ids"]) for b in range(n_bands)]
            seg = Segment(int(z["base"]), z["sigs"],
                          np.asarray(z["valid"], bool), csr)
    except FileNotFoundError:
        raise CorruptSegment(f, f"segment {f} is missing from disk") \
            from None
    except (OSError, EOFError, KeyError, zipfile.BadZipFile,
            ValueError) as err:
        # a torn write truncates the npz zip container — np.load raises
        # BadZipFile/EOFError/OSError depending on where the tear landed
        raise CorruptSegment(
            f, f"segment {f} is unreadable (truncated or torn write): "
               f"{type(err).__name__}: {err}") from err
    if seg.n_rows != e["n_rows"]:
        raise CorruptSegment(f, f"segment {f} holds {seg.n_rows} rows, "
                                f"manifest says {e['n_rows']}")
    if "sha" in e and segment_checksum(seg) != e["sha"]:
        raise CorruptSegment(
            f, f"segment {f} content hash does not match the "
               f"manifest — swapped or corrupt segment file")
    if seg.base != expect_base or int(e["base"]) != expect_base:
        # segments concatenate in manifest order and their CSR ids
        # embed the stored base — any disagreement (reordered entries,
        # corrupt base) would silently map global ids to the WRONG
        # signature rows, so fail loudly instead
        raise CorruptSegment(
            f, f"segment {f} claims base {seg.base} "
               f"(manifest {e['base']}) but {expect_base} rows precede "
               f"it — manifest reordered or corrupt")
    return seg


def _quarantine(root: str, entries: list[dict]) -> list[str]:
    """Move the given manifest entries' files into ``quarantine/``
    (keeping the evidence — nothing is deleted) and count them."""
    qdir = os.path.join(root, "quarantine")
    os.makedirs(qdir, exist_ok=True)
    moved = []
    for e in entries:
        src = os.path.join(root, e["file"])
        if os.path.exists(src):
            shutil.move(src, os.path.join(qdir, e["file"]))
            moved.append(e["file"])
            _M_QUARANTINED.inc()
    return moved


def load_segmented(path, *, recover: bool = False
                   ) -> tuple[dict, list[Segment], dict | None]:
    """Read manifest + every segment file; returns
    ``(meta, segments, recovery)``.

    Default: any damaged segment raises :class:`CorruptSegment` naming
    the file — a load either serves exactly what was saved or refuses.
    With ``recover=True`` the longest valid segment *prefix* is served
    instead: the first damaged segment and every segment after it (their
    global ids assume the damaged rows exist) move to ``quarantine/``,
    the manifest is rewritten (atomically) to the surviving prefix, and
    ``recovery`` reports what was dropped — degraded-but-correct beats
    refusing the whole index.
    """
    mpath = manifest_path(path)
    root = os.path.dirname(mpath)
    with open(mpath) as fh:
        manifest = json.load(fh)
    if manifest.get("manifest_version") != MANIFEST_VERSION:
        raise ValueError(
            f"manifest version {manifest.get('manifest_version')} != "
            f"{MANIFEST_VERSION}")
    n_bands = 1 if manifest["layout"] == "flip" else int(manifest["bands"])
    segments = []
    recovery = None
    total = 0
    entries = manifest["segments"]
    for i, e in enumerate(entries):
        try:
            seg = _load_segment_file(root, e, n_bands, total)
        except CorruptSegment as err:
            if not recover:
                raise
            quarantined = _quarantine(root, entries[i:])
            manifest["segments"] = entries[:i]
            blob = json.dumps(manifest, sort_keys=True, indent=1).encode()
            atomic_write(mpath, lambda fh: fh.write(blob))
            recovery = dict(
                file=err.file, reason=str(err), quarantined=quarantined,
                n_segments_dropped=len(entries) - i,
                n_rows_dropped=sum(int(x["n_rows"]) for x in entries[i:]),
                n_rows_served=total)
            break
        total += seg.n_rows
        segments.append(seg)
    return manifest, segments, recovery
