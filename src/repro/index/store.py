"""Signature index over a reference database — built once, grown forever.

Structure (DESIGN.md §2 "HDFS -> on-device buffers + manifests"):

* packed signatures ``sigs`` (N, f//32) uint32 — job 1's output, persisted;
* ``valid`` (N,) bool — the paper's non-zero-signature rule (§5.2): sequences
  with zero neighbour features collapse to the all-ones fingerprint and are
  excluded from every bucket;
* per-band sorted buckets in CSR form: for each band, the sorted unique
  bucket ``keys`` (U,), ``offsets`` (U+1,) into ``ids`` (E,) — the reference
  ids grouped by bucket. Two layouts:

  - ``layout="band"`` (default): keys from :func:`repro.core.join.band_keys`
    with ``bands >= d+1`` — the pigeonhole guarantee of ``band_join`` (any
    pair within Hamming d agrees exactly on >= 1 band), so a probe of all
    bands has no false negatives within d.
  - ``layout="flip"``: the paper-faithful expansion — every reference emits
    all C(f, <=d) bit-flips (:func:`repro.core.join.flip_masks`) as keys and
    queries probe with their raw signature; one sorted array, exact, no
    duplicate candidates. f <= 32.

Growth is **append-only** (:mod:`repro.index.segments`): every ``add()``
seals a new segment (its own CSR buckets over global ids) and resident
segments are never re-bucketed. The merged bucket table consumers probe
against is a stable linear merge of the segment tables — bit-exact with a
from-scratch build — materialized lazily and only for consumers that need
the whole table (the single-device probe, a full partition, a legacy
save); the serving ring ingests segment *deltas* instead
(:meth:`repro.index.shard.ShardedIndex.refresh`). ``compact()`` is the
explicit reduce step: it folds every segment into one.

The stacked-padded slabs every probe/join consumer runs against are built
by the bucket partition layer (:mod:`repro.index.partition`) via
:meth:`SignatureIndex.partition` — the single-device probe is just shard 0
of the 1-way partition.

Persistence is fingerprint-versioned (the LSH parameters that determine
signature semantics; ``n_shards`` joins it when != 1, and pre-sharding
fingerprints stay valid) in two containers: a **segment directory**
(manifest + per-segment npz, appends cost O(delta)) or the legacy
monolithic ``.npz`` (paths ending in ``.npz``; what PR 1–4 wrote, still
read and written for compatibility). Loading an index against a different
:class:`~repro.core.pipeline.LSHConfig` raises :class:`IndexConfigMismatch`
— a stale index never silently serves wrong candidates.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import zipfile

import jax.numpy as jnp
import numpy as np

from ..core.pipeline import LSHConfig, ScalLoPS
from ..core.join import band_keys
from ..faults import atomic_write
from ..obs import span
from . import segments as seglib
from .segments import CorruptSegment, Segment

FORMAT_VERSION = 1

# Fields of LSHConfig that determine signature/bucket semantics. Serving-time
# knobs (max_pairs, join_method) are deliberately excluded: changing them must
# not invalidate a persisted index.
_FINGERPRINT_FIELDS = ("k", "T", "f", "d", "scheme", "siggen_method")


class IndexConfigMismatch(RuntimeError):
    """A persisted index was loaded against an incompatible LSHConfig."""


def config_fingerprint(cfg: LSHConfig, *, layout: str, bands: int,
                       interleave: bool = True,
                       key_hash: str = "none",
                       n_shards: int = 1) -> str:
    """Stable 16-hex-digit fingerprint of the index-relevant config."""
    payload = {
        "cfg": {f: getattr(cfg, f) for f in _FINGERPRINT_FIELDS},
        "layout": layout, "bands": bands, "interleave": interleave,
        "format": FORMAT_VERSION,
    }
    # key_hash="none" is omitted so pre-key-hash fingerprints stay valid
    if key_hash != "none":
        payload["key_hash"] = key_hash
    # n_shards=1 is omitted so pre-sharding fingerprints stay valid
    if n_shards != 1:
        payload["n_shards"] = n_shards
    blob = json.dumps(payload, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class SignatureIndex:
    """Segmented reference index over packed LSH signatures.

    Use :meth:`build` (from sequences) or :meth:`load` (from disk); query
    via :meth:`probe` / the serving layer (:mod:`repro.index.service`);
    grow via :meth:`add` (seals an append-only segment).
    """

    def __init__(self, cfg: LSHConfig, sigs: np.ndarray, valid: np.ndarray,
                 *, layout: str = "band", bands: int | None = None,
                 interleave: bool = True, key_hash: str = "splitmix",
                 n_shards: int = 1):
        if layout not in ("band", "flip"):
            raise ValueError(f"unknown index layout {layout!r}")
        if layout == "flip" and cfg.f > 32:
            raise ValueError("flip layout needs f <= 32 (paper used f=32)")
        if key_hash not in ("splitmix", "none"):
            raise ValueError(f"unknown key_hash {key_hash!r}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.cfg = cfg
        self.layout = layout
        # Intended bucket-shard count (the MapReduce reducer count). Purely
        # a placement property — bucket contents are identical for every
        # n_shards — but persisted (and fingerprinted when != 1) so a
        # serving replica reloads the same partition it was built for.
        self.n_shards = int(n_shards)
        # Interleaved banding (bit i -> band i % bands) spreads the
        # position-skewed signature-bit entropy evenly; see band_bit_groups.
        self.interleave = bool(interleave)
        # Serving default: splitmix-mix band keys before bucketing (a
        # bijection — bucket membership is untouched; key *arithmetic*
        # becomes skew-free, the ROADMAP "hash band keys" follow-on).
        # key_hash="none" keeps the raw band bits for paper-fidelity runs.
        self.key_hash = key_hash if layout == "band" else "none"
        self.bands = int(bands if bands is not None else max(cfg.d + 1, 1))
        if layout == "band" and self.bands < cfg.d + 1:
            raise ValueError("bands must be >= d+1 for an exact probe")
        self.sigs = np.ascontiguousarray(np.asarray(sigs, np.uint32))
        self.valid = np.asarray(valid, bool).reshape(-1).copy()
        assert self.sigs.shape == (self.valid.shape[0], cfg.f // 32)
        # -------- append-only lifecycle state
        self.segments: list[Segment] = []   # sealed (CSR built)
        self._pending: list[tuple] = []     # (sigs, valid, base) to seal
        if self.size:
            self._pending.append((self.sigs, self.valid, 0))
        self.generation = 0         # bumps on compact() (forest of segments
                                    # collapsed — delta consumers re-place)
        self._merged_stale = True   # merged CSR needs a (re)merge
        self._csr_np = None         # merged per-band CSR (lazy)
        self.recovery = None        # set by load(recover=True) when a
                                    # damaged tail was quarantined
        self._partitions = {}       # n_shards -> BucketPartition (slabs)
        self._dev_sigs = None
        self._dev_valid = None
        self._dev_band_keys = None
        self._pipeline = None

    # ------------------------------------------------------------ properties
    @property
    def size(self) -> int:
        return self.sigs.shape[0]

    @property
    def n_bands(self) -> int:
        return 1 if self.layout == "flip" else self.bands

    @property
    def epoch(self) -> int:
        """Segment count (sealed + pending) — the serving layers' staleness
        counter: a replica that last saw epoch e ingests segments[e:]."""
        return len(self.segments) + len(self._pending)

    @property
    def lifecycle(self) -> tuple[int, int]:
        """(generation, epoch) — changes iff a delta refresh or a full
        re-place is due."""
        return (self.generation, self.epoch)

    @property
    def fingerprint(self) -> str:
        return config_fingerprint(self.cfg, layout=self.layout,
                                   bands=self.bands,
                                   interleave=self.interleave,
                                   key_hash=self.key_hash,
                                   n_shards=self.n_shards)

    @property
    def device_sigs(self) -> jnp.ndarray:
        if self._dev_sigs is None or self._dev_sigs.shape[0] != self.size:
            self._dev_sigs = jnp.asarray(self.sigs)
            self._dev_valid = jnp.asarray(self.valid)
        return self._dev_sigs

    @property
    def device_valid(self) -> jnp.ndarray:
        self.device_sigs
        return self._dev_valid

    @property
    def device_band_keys(self) -> jnp.ndarray:
        """(N, n_bands) uint32 — every sequence's bucket key in every band
        (band layout only; a sequence occupies exactly ONE bucket per band).
        This is the duplicate-structure oracle of the fused self-join: a
        candidate pair is a cross-band duplicate iff the two rows agree in
        an earlier band (``repro.index.spgemm.spgemm_join_self_keys``)."""
        if self.layout != "band":
            raise ValueError("band keys are only defined for layout='band'")
        if (self._dev_band_keys is None
                or self._dev_band_keys.shape[0] != self.size):
            self._dev_band_keys = band_keys(
                self.device_sigs, self.cfg.f, self.bands,
                interleave=self.interleave, key_hash=self.key_hash)
        return self._dev_band_keys

    # ------------------------------------------------------------ build
    @classmethod
    def build(cls, cfg: LSHConfig, ref_ids, ref_lens, *,
              layout: str = "band", bands: int | None = None,
              interleave: bool = True,
              key_hash: str = "splitmix",
              n_shards: int = 1) -> "SignatureIndex":
        """Run job 1 (signature generation + validity) over the reference set."""
        sl = ScalLoPS(cfg)
        sigs = np.asarray(sl.signatures(ref_ids, ref_lens))
        valid = np.asarray(sl.feature_counts(ref_ids, ref_lens)) > 0
        idx = cls(cfg, sigs, valid, layout=layout, bands=bands,
                  interleave=interleave, key_hash=key_hash,
                  n_shards=n_shards)
        idx._pipeline = sl
        return idx

    def add(self, ref_ids, ref_lens) -> None:
        """Incremental growth: signatures for the NEW rows only, appended as
        a pending segment and sealed (bucketed) lazily on the next
        probe/refresh/save. Resident segments are never re-bucketed; the
        merged table re-merges lazily for consumers that need it."""
        if self._pipeline is None:
            self._pipeline = ScalLoPS(self.cfg)
        sl = self._pipeline
        new_sigs = np.asarray(sl.signatures(ref_ids, ref_lens))
        new_valid = np.asarray(sl.feature_counts(ref_ids, ref_lens)) > 0
        if new_sigs.shape[0] == 0:
            return
        base = self.size
        self.sigs = np.concatenate([self.sigs, new_sigs], axis=0)
        self.valid = np.concatenate([self.valid, new_valid], axis=0)
        self._pending.append((new_sigs, new_valid, base))
        self._merged_stale = True
        self._partitions = {}       # full partitions derive from the merge

    def seal(self) -> None:
        """Seal pending rows into segments (bucket the new rows). Cheap
        relative to a rebuild: O(new rows), resident segments untouched."""
        if not self._pending:
            return
        with span("seal", cat="lifecycle", pending=len(self._pending),
                  epoch=len(self.segments)):
            while self._pending:
                sigs, valid, base = self._pending.pop(0)
                self.segments.append(seglib.build_segment(
                    sigs, valid, base, layout=self.layout, f=self.cfg.f,
                    d=self.cfg.d, bands=self.bands,
                    interleave=self.interleave, key_hash=self.key_hash))

    def _ensure_built(self) -> None:
        """Seal pending segments and materialize the merged bucket table."""
        self.seal()
        if not self._merged_stale and self._csr_np is not None:
            return
        if self.segments:
            self._csr_np = seglib.merge_band_csrs(
                [s.csr for s in self.segments])
        else:
            self._csr_np = [seglib._empty_csr() for _ in range(self.n_bands)]
        self._partitions = {}       # slabs derive from the fresh merge
        self._merged_stale = False

    def compact(self) -> None:
        """Fold every segment into one (the explicit reduce step).

        Probe results are identical before and after — compaction changes
        the storage shape, never the bucket table. Bumps ``generation`` so
        delta consumers (:class:`ShardedIndex`) re-place instead of
        stacking deltas on a base that no longer exists. Already-compact
        indexes (one sealed segment, nothing pending) are a no-op — no
        generation bump, so serving replicas skip the full re-place."""
        self.seal()
        if len(self.segments) == 1:
            return
        with span("compact_index", cat="lifecycle",
                  segments=len(self.segments), size=self.size):
            self._ensure_built()
            self.segments = [Segment(0, self.sigs, self.valid, self._csr_np)]
            self._pending = []
            self.generation += 1

    def partition(self, n_shards: int | None = None) -> "BucketPartition":
        """Shard-owned stacked CSR slabs (:mod:`repro.index.partition`) —
        the single stacking code path shared by the fused single-device
        probe (``n_shards=1``), the sharded serving ring, and the sharded
        self-join. Cached per shard count; invalidated on add/compact."""
        from .partition import BucketPartition
        self._ensure_built()
        n = int(n_shards if n_shards is not None else self.n_shards)
        part = self._partitions.get(n)
        if part is None:
            part = BucketPartition(self._csr_np, n, sigs=self.sigs)
            self._partitions[n] = part
        return part

    def delta_partition(self, n_shards: int, from_epoch: int):
        """Partition of just the segments sealed at/after ``from_epoch`` —
        what a serving replica ingests on refresh. Never touches the
        merged table; cost is O(delta entries)."""
        from .partition import BucketPartition
        self.seal()
        segs = self.segments[from_epoch:]
        if segs:
            csr = seglib.merge_band_csrs([s.csr for s in segs])
        else:
            csr = [seglib._empty_csr() for _ in range(self.n_bands)]
        return BucketPartition(csr, n_shards, sigs=self.sigs)

    # ------------------------------------------------------------ probing
    def query_keys(self, q_sigs) -> jnp.ndarray:
        """Per-band probe keys for a query batch: (n_bands, B) uint32."""
        q_sigs = jnp.asarray(q_sigs)
        if self.layout == "flip":
            return q_sigs[:, 0][None, :]
        return band_keys(q_sigs, self.cfg.f, self.bands,
                         interleave=self.interleave,
                         key_hash=self.key_hash).T

    def probe(self, q_sigs, *, cap: int):
        """Candidate generation: for each query, up to ``cap`` reference ids
        per band whose bucket key matches.

        Returns (cand (B, n_bands*cap) int32 with -1 padding — duplicates
        across bands allowed, consumers dedup, overflowed 0-d bool — True
        iff some matched bucket held more than ``cap`` entries, i.e.
        candidates were truncated and the caller should grow ``cap`` and
        retry).

        All bands probe in ONE jitted program over the stacked per-band
        CSR arrays (no per-band Python dispatch loop).
        """
        from .service import _probe_csr_fused  # jitted probe primitive
        self._ensure_built()
        qk = self.query_keys(q_sigs)
        keys_s, offs_s, ids_s = self.partition(1).probe_arrays(0)
        if keys_s.shape[1] == 0:           # no buckets at all (empty index)
            B = qk.shape[1]
            return (jnp.full((B, self.n_bands * cap), -1, jnp.int32),
                    jnp.zeros((), bool))
        cand, sizes = _probe_csr_fused(qk, keys_s, offs_s, ids_s, cap=cap)
        return cand, jnp.max(sizes) > cap

    # ------------------------------------------------------------ persistence
    def _meta(self) -> dict:
        return {
            "format": FORMAT_VERSION,
            "fingerprint": self.fingerprint,
            "cfg": dataclasses.asdict(self.cfg),
            "layout": self.layout,
            "bands": self.bands,
            "interleave": self.interleave,
            "key_hash": self.key_hash,
            "n_shards": self.n_shards,
            "n_refs": self.size,
        }

    def save(self, path: str | os.PathLike) -> int:
        """Persist the index; returns the number of segment files written.

        Paths ending in ``.npz`` write the legacy monolithic container
        (merged table, one file — what PR 1–4 produced). Any other path is
        a segment directory: manifest + per-segment files, and repeated
        saves append only the segments not on disk yet (O(delta) — the
        point of the append-only lifecycle).
        """
        if not seglib.is_segmented(path):
            self._ensure_built()
            payload = {
                "meta_json": np.frombuffer(
                    json.dumps(self._meta(), sort_keys=True).encode(),
                    dtype=np.uint8),
                "sigs": self.sigs,
                "valid": self.valid,
            }
            for b, (keys, offsets, ids) in enumerate(self._csr_np):
                payload[f"band{b}_keys"] = keys
                payload[f"band{b}_offsets"] = offsets
                payload[f"band{b}_ids"] = ids
            atomic_write(os.fspath(path),
                         lambda fh: np.savez_compressed(fh, **payload))
            return 1
        self.seal()                 # segments only — no merge needed
        return seglib.save_segmented(path, self._meta(), self.segments,
                                     self.n_bands)

    @classmethod
    def _check_meta(cls, meta: dict, expected_cfg: LSHConfig | None):
        """Shared fingerprint verification for both containers; returns the
        constructor kwargs."""
        cfg = LSHConfig(**meta["cfg"])
        layout, bands = meta["layout"], int(meta["bands"])
        interleave = bool(meta.get("interleave", True))
        # pre-key-hash indexes (PR 1/2) bucketed on raw band keys
        key_hash = meta.get("key_hash", "none")
        # pre-sharding indexes (PR 1-3) are 1-way partitions
        n_shards = int(meta.get("n_shards", 1))
        stored = meta["fingerprint"]
        recomputed = config_fingerprint(cfg, layout=layout, bands=bands,
                                        interleave=interleave,
                                        key_hash=key_hash,
                                        n_shards=n_shards)
        if stored != recomputed:
            raise IndexConfigMismatch(
                f"fingerprint {stored} does not match stored config "
                f"(expected {recomputed}) — corrupt or stale index")
        if expected_cfg is not None:
            want = config_fingerprint(expected_cfg, layout=layout,
                                      bands=bands, interleave=interleave,
                                      key_hash=key_hash,
                                      n_shards=n_shards)
            if want != stored:
                raise IndexConfigMismatch(
                    f"index fingerprint {stored} != {want} for the "
                    f"requested config; rebuild the index")
        return cfg, dict(layout=layout, bands=bands, interleave=interleave,
                         key_hash=key_hash, n_shards=n_shards)

    @classmethod
    def load(cls, path: str | os.PathLike,
             expected_cfg: LSHConfig | None = None, *,
             recover: bool = False) -> "SignatureIndex":
        """Load a persisted index; fails loudly on config mismatch.

        One entry point for both containers: segment directories load
        their manifest + segment files; ``.npz`` paths load the PR 1–4
        monolithic format as a single sealed segment (back-compat — the
        pre-key-hash and pre-sharding metadata defaults apply).

        If ``expected_cfg`` is given, its fingerprint must match the stored
        one — a stale index built under different LSH parameters raises
        :class:`IndexConfigMismatch` instead of silently serving wrong
        buckets.

        Damaged segment files raise a typed
        :class:`~repro.index.segments.CorruptSegment` naming the file;
        with ``recover=True`` the damaged tail is quarantined instead and
        the longest valid segment prefix is served, with the drop report
        on ``idx.recovery`` (see :func:`repro.index.segments.
        load_segmented`).
        """
        if seglib.is_segmented(path) and os.path.exists(
                seglib.manifest_path(path)):
            meta, segments, recovery = seglib.load_segmented(
                path, recover=recover)
            if meta.get("format") != FORMAT_VERSION:
                raise IndexConfigMismatch(
                    f"index format {meta.get('format')} != {FORMAT_VERSION}")
            cfg, kw = cls._check_meta(meta, expected_cfg)
            if segments:
                sigs = np.concatenate([s.sigs for s in segments], axis=0)
                valid = np.concatenate([s.valid for s in segments], axis=0)
            else:
                sigs = np.zeros((0, cfg.f // 32), np.uint32)
                valid = np.zeros((0,), bool)
            idx = cls(cfg, sigs, valid, **kw)
            idx._pending = []
            idx.segments = segments
            idx.recovery = recovery
            return idx
        try:
            z = np.load(path)
        except (OSError, EOFError, ValueError,
                zipfile.BadZipFile) as err:
            # the monolithic container has no prefix to fall back to —
            # a torn legacy npz is typed, named, and unrecoverable
            raise CorruptSegment(
                os.fspath(path),
                f"legacy index {path} is unreadable (truncated or torn "
                f"write): {type(err).__name__}: {err}") from err
        with z:
            meta = json.loads(bytes(z["meta_json"].tobytes()).decode())
            if meta.get("format") != FORMAT_VERSION:
                raise IndexConfigMismatch(
                    f"index format {meta.get('format')} != {FORMAT_VERSION}")
            cfg, kw = cls._check_meta(meta, expected_cfg)
            idx = cls(cfg, z["sigs"], z["valid"], **kw)
            csr = []
            for b in range(idx.n_bands):
                csr.append((z[f"band{b}_keys"], z[f"band{b}_offsets"],
                            z[f"band{b}_ids"]))
        # the monolithic table IS one sealed segment (ids are global,
        # base 0) — no re-bucketing, and the merged view is it
        idx._pending = []
        idx.segments = [Segment(0, idx.sigs, idx.valid, csr)]
        idx._csr_np = csr
        idx._merged_stale = False
        return idx
