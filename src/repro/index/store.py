"""Immutable signature index over a reference database (build once, query many).

Structure (DESIGN.md §2 "HDFS -> on-device buffers + manifests"):

* packed signatures ``sigs`` (N, f//32) uint32 — job 1's output, persisted;
* ``valid`` (N,) bool — the paper's non-zero-signature rule (§5.2): sequences
  with zero neighbour features collapse to the all-ones fingerprint and are
  excluded from every bucket;
* per-band sorted buckets in CSR form: for each band, the sorted unique
  bucket ``keys`` (U,), ``offsets`` (U+1,) into ``ids`` (E,) — the reference
  ids grouped by bucket. Two layouts:

  - ``layout="band"`` (default): keys from :func:`repro.core.join.band_keys`
    with ``bands >= d+1`` — the pigeonhole guarantee of ``band_join`` (any
    pair within Hamming d agrees exactly on >= 1 band), so a probe of all
    bands has no false negatives within d.
  - ``layout="flip"``: the paper-faithful expansion — every reference emits
    all C(f, <=d) bit-flips (:func:`repro.core.join.flip_masks`) as keys and
    queries probe with their raw signature; one sorted array, exact, no
    duplicate candidates. f <= 32.

The stacked-padded slabs every probe/join consumer runs against are built
by the bucket partition layer (:mod:`repro.index.partition`) via
:meth:`SignatureIndex.partition` — the single-device probe is just shard 0
of the 1-way partition.

Persistence is a single ``.npz`` keyed by a *config fingerprint* (the LSH
parameters that determine signature semantics; ``n_shards`` joins it when
!= 1, and pre-sharding fingerprints stay valid). Loading an index against
a different :class:`~repro.core.pipeline.LSHConfig` raises
:class:`IndexConfigMismatch` — a stale index never silently serves wrong
candidates.

``add()`` appends new references cheaply (signatures only) and defers the
bucket re-sort until the next probe/save (amortized growth).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import jax.numpy as jnp
import numpy as np

from ..core.join import band_keys, flip_masks
from ..core.pipeline import LSHConfig, ScalLoPS

FORMAT_VERSION = 1

# Fields of LSHConfig that determine signature/bucket semantics. Serving-time
# knobs (max_pairs, join_method) are deliberately excluded: changing them must
# not invalidate a persisted index.
_FINGERPRINT_FIELDS = ("k", "T", "f", "d", "scheme", "siggen_method")


class IndexConfigMismatch(RuntimeError):
    """A persisted index was loaded against an incompatible LSHConfig."""


def config_fingerprint(cfg: LSHConfig, *, layout: str, bands: int,
                       interleave: bool = True,
                       key_hash: str = "none",
                       n_shards: int = 1) -> str:
    """Stable 16-hex-digit fingerprint of the index-relevant config."""
    payload = {
        "cfg": {f: getattr(cfg, f) for f in _FINGERPRINT_FIELDS},
        "layout": layout, "bands": bands, "interleave": interleave,
        "format": FORMAT_VERSION,
    }
    # key_hash="none" is omitted so pre-key-hash fingerprints stay valid
    if key_hash != "none":
        payload["key_hash"] = key_hash
    # n_shards=1 is omitted so pre-sharding fingerprints stay valid
    if n_shards != 1:
        payload["n_shards"] = n_shards
    blob = json.dumps(payload, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _sort_bucket(keys: np.ndarray, ids: np.ndarray):
    """Group (key, id) entries into CSR: (unique keys, offsets, sorted ids)."""
    order = np.argsort(keys, kind="stable")
    ks, sids = keys[order], ids[order]
    uk, first = np.unique(ks, return_index=True)
    offsets = np.concatenate([first, [len(ks)]]).astype(np.int32)
    return uk.astype(np.uint32), offsets, sids.astype(np.int32)


class SignatureIndex:
    """Build-once reference index over packed LSH signatures.

    Use :meth:`build` (from sequences) or :meth:`load` (from disk); query via
    :meth:`probe` / the serving layer (:mod:`repro.index.service`).
    """

    def __init__(self, cfg: LSHConfig, sigs: np.ndarray, valid: np.ndarray,
                 *, layout: str = "band", bands: int | None = None,
                 interleave: bool = True, key_hash: str = "splitmix",
                 n_shards: int = 1):
        if layout not in ("band", "flip"):
            raise ValueError(f"unknown index layout {layout!r}")
        if layout == "flip" and cfg.f > 32:
            raise ValueError("flip layout needs f <= 32 (paper used f=32)")
        if key_hash not in ("splitmix", "none"):
            raise ValueError(f"unknown key_hash {key_hash!r}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.cfg = cfg
        self.layout = layout
        # Intended bucket-shard count (the MapReduce reducer count). Purely
        # a placement property — bucket contents are identical for every
        # n_shards — but persisted (and fingerprinted when != 1) so a
        # serving replica reloads the same partition it was built for.
        self.n_shards = int(n_shards)
        # Interleaved banding (bit i -> band i % bands) spreads the
        # position-skewed signature-bit entropy evenly; see band_bit_groups.
        self.interleave = bool(interleave)
        # Serving default: splitmix-mix band keys before bucketing (a
        # bijection — bucket membership is untouched; key *arithmetic*
        # becomes skew-free, the ROADMAP "hash band keys" follow-on).
        # key_hash="none" keeps the raw band bits for paper-fidelity runs.
        self.key_hash = key_hash if layout == "band" else "none"
        self.bands = int(bands if bands is not None else max(cfg.d + 1, 1))
        if layout == "band" and self.bands < cfg.d + 1:
            raise ValueError("bands must be >= d+1 for an exact probe")
        self.sigs = np.ascontiguousarray(np.asarray(sigs, np.uint32))
        self.valid = np.asarray(valid, bool).reshape(-1).copy()
        assert self.sigs.shape == (self.valid.shape[0], cfg.f // 32)
        self._dirty = True          # buckets need (re)building
        self._csr_np = None         # list[(keys, offsets, ids)] numpy
        self._partitions = {}       # n_shards -> BucketPartition (slabs)
        self._dev_sigs = None
        self._dev_valid = None
        self._pipeline = None

    # ------------------------------------------------------------ properties
    @property
    def size(self) -> int:
        return self.sigs.shape[0]

    @property
    def n_bands(self) -> int:
        return 1 if self.layout == "flip" else self.bands

    @property
    def fingerprint(self) -> str:
        return config_fingerprint(self.cfg, layout=self.layout,
                                   bands=self.bands,
                                   interleave=self.interleave,
                                   key_hash=self.key_hash,
                                   n_shards=self.n_shards)

    @property
    def device_sigs(self) -> jnp.ndarray:
        self._ensure_built()
        return self._dev_sigs

    @property
    def device_valid(self) -> jnp.ndarray:
        self._ensure_built()
        return self._dev_valid

    # ------------------------------------------------------------ build
    @classmethod
    def build(cls, cfg: LSHConfig, ref_ids, ref_lens, *,
              layout: str = "band", bands: int | None = None,
              interleave: bool = True,
              key_hash: str = "splitmix",
              n_shards: int = 1) -> "SignatureIndex":
        """Run job 1 (signature generation + validity) over the reference set."""
        sl = ScalLoPS(cfg)
        sigs = np.asarray(sl.signatures(ref_ids, ref_lens))
        valid = np.asarray(sl.feature_counts(ref_ids, ref_lens)) > 0
        idx = cls(cfg, sigs, valid, layout=layout, bands=bands,
                  interleave=interleave, key_hash=key_hash,
                  n_shards=n_shards)
        idx._pipeline = sl
        return idx

    def add(self, ref_ids, ref_lens) -> None:
        """Incremental growth: append signatures now, re-sort buckets lazily
        on the next probe/save (deferred, amortized)."""
        if self._pipeline is None:
            self._pipeline = ScalLoPS(self.cfg)
        sl = self._pipeline
        new_sigs = np.asarray(sl.signatures(ref_ids, ref_lens))
        new_valid = np.asarray(sl.feature_counts(ref_ids, ref_lens)) > 0
        self.sigs = np.concatenate([self.sigs, new_sigs], axis=0)
        self.valid = np.concatenate([self.valid, new_valid], axis=0)
        self._dirty = True

    def _build_csr(self) -> list:
        valid_ids = np.nonzero(self.valid)[0].astype(np.int32)
        if self.layout == "flip":
            masks = flip_masks(self.cfg.f, self.cfg.d)[:, 0]      # (M,) uint32
            if len(valid_ids) == 0:
                return [_sort_bucket(np.zeros(0, np.uint32),
                                     np.zeros(0, np.int32))]
            keys = (self.sigs[valid_ids, 0][:, None]
                    ^ masks[None, :]).ravel()
            ids = np.repeat(valid_ids, masks.shape[0])
            return [_sort_bucket(keys, ids)]
        if len(valid_ids) == 0:
            return [_sort_bucket(np.zeros(0, np.uint32), np.zeros(0, np.int32))
                    for _ in range(self.bands)]
        kb = np.asarray(band_keys(jnp.asarray(self.sigs[valid_ids]),
                                  self.cfg.f, self.bands,
                                  interleave=self.interleave,
                                  key_hash=self.key_hash))        # (V, bands)
        return [_sort_bucket(kb[:, b], valid_ids) for b in range(self.bands)]

    def _ensure_built(self) -> None:
        if not self._dirty and self._csr_np is not None:
            return
        self._csr_np = self._build_csr()
        self._partitions = {}       # slabs derive from the fresh CSR
        self._dev_sigs = jnp.asarray(self.sigs)
        self._dev_valid = jnp.asarray(self.valid)
        self._dirty = False

    def partition(self, n_shards: int | None = None) -> "BucketPartition":
        """Shard-owned stacked CSR slabs (:mod:`repro.index.partition`) —
        the single stacking code path shared by the fused single-device
        probe (``n_shards=1``), the sharded serving ring, and the sharded
        self-join. Cached per shard count; invalidated on rebuild."""
        from .partition import BucketPartition
        self._ensure_built()
        n = int(n_shards if n_shards is not None else self.n_shards)
        part = self._partitions.get(n)
        if part is None:
            part = BucketPartition(self._csr_np, n, sigs=self.sigs)
            self._partitions[n] = part
        return part

    # ------------------------------------------------------------ probing
    def query_keys(self, q_sigs) -> jnp.ndarray:
        """Per-band probe keys for a query batch: (n_bands, B) uint32."""
        q_sigs = jnp.asarray(q_sigs)
        if self.layout == "flip":
            return q_sigs[:, 0][None, :]
        return band_keys(q_sigs, self.cfg.f, self.bands,
                         interleave=self.interleave,
                         key_hash=self.key_hash).T

    def probe(self, q_sigs, *, cap: int):
        """Candidate generation: for each query, up to ``cap`` reference ids
        per band whose bucket key matches.

        Returns (cand (B, n_bands*cap) int32 with -1 padding — duplicates
        across bands allowed, consumers dedup, overflowed 0-d bool — True
        iff some matched bucket held more than ``cap`` entries, i.e.
        candidates were truncated and the caller should grow ``cap`` and
        retry).

        All bands probe in ONE jitted program over the stacked per-band
        CSR arrays (no per-band Python dispatch loop).
        """
        from .service import _probe_csr_fused  # jitted probe primitive
        self._ensure_built()
        qk = self.query_keys(q_sigs)
        keys_s, offs_s, ids_s = self.partition(1).probe_arrays(0)
        if keys_s.shape[1] == 0:           # no buckets at all (empty index)
            B = qk.shape[1]
            return (jnp.full((B, self.n_bands * cap), -1, jnp.int32),
                    jnp.zeros((), bool))
        cand, sizes = _probe_csr_fused(qk, keys_s, offs_s, ids_s, cap=cap)
        return cand, jnp.max(sizes) > cap

    # ------------------------------------------------------------ persistence
    def save(self, path: str | os.PathLike) -> None:
        """Persist signatures + CSR buckets + config to one npz file."""
        self._ensure_built()
        meta = {
            "format": FORMAT_VERSION,
            "fingerprint": self.fingerprint,
            "cfg": dataclasses.asdict(self.cfg),
            "layout": self.layout,
            "bands": self.bands,
            "interleave": self.interleave,
            "key_hash": self.key_hash,
            "n_shards": self.n_shards,
            "n_refs": self.size,
        }
        payload = {
            "meta_json": np.frombuffer(
                json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8),
            "sigs": self.sigs,
            "valid": self.valid,
        }
        for b, (keys, offsets, ids) in enumerate(self._csr_np):
            payload[f"band{b}_keys"] = keys
            payload[f"band{b}_offsets"] = offsets
            payload[f"band{b}_ids"] = ids
        np.savez_compressed(path, **payload)

    @classmethod
    def load(cls, path: str | os.PathLike,
             expected_cfg: LSHConfig | None = None) -> "SignatureIndex":
        """Load a persisted index; fails loudly on config mismatch.

        If ``expected_cfg`` is given, its fingerprint must match the stored
        one — a stale index built under different LSH parameters raises
        :class:`IndexConfigMismatch` instead of silently serving wrong
        buckets.
        """
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta_json"].tobytes()).decode())
            if meta.get("format") != FORMAT_VERSION:
                raise IndexConfigMismatch(
                    f"index format {meta.get('format')} != {FORMAT_VERSION}")
            cfg = LSHConfig(**meta["cfg"])
            layout, bands = meta["layout"], int(meta["bands"])
            interleave = bool(meta.get("interleave", True))
            # pre-key-hash indexes bucketed on raw band keys
            key_hash = meta.get("key_hash", "none")
            # pre-sharding indexes are 1-way partitions (back-compat)
            n_shards = int(meta.get("n_shards", 1))
            stored = meta["fingerprint"]
            recomputed = config_fingerprint(cfg, layout=layout, bands=bands,
                                            interleave=interleave,
                                            key_hash=key_hash,
                                            n_shards=n_shards)
            if stored != recomputed:
                raise IndexConfigMismatch(
                    f"fingerprint {stored} does not match stored config "
                    f"(expected {recomputed}) — corrupt or stale index")
            if expected_cfg is not None:
                want = config_fingerprint(expected_cfg, layout=layout,
                                          bands=bands, interleave=interleave,
                                          key_hash=key_hash,
                                          n_shards=n_shards)
                if want != stored:
                    raise IndexConfigMismatch(
                        f"index fingerprint {stored} != {want} for the "
                        f"requested config; rebuild the index")
            idx = cls(cfg, z["sigs"], z["valid"], layout=layout,
                      bands=bands, interleave=interleave, key_hash=key_hash,
                      n_shards=n_shards)
            csr = []
            for b in range(idx.n_bands):
                csr.append((z[f"band{b}_keys"], z[f"band{b}_offsets"],
                            z[f"band{b}_ids"]))
        idx._csr_np = csr
        idx._partitions = {}
        idx._dev_sigs = jnp.asarray(idx.sigs)
        idx._dev_valid = jnp.asarray(idx.valid)
        idx._dirty = False
        return idx
