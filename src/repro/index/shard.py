"""Bucket-sharded probe serving: shards own buckets, query blocks rotate.

The MapReduce analogue made literal: each shard of the mesh owns the
buckets that :func:`repro.index.partition.bucket_owners` routes to it
(``mix32(band_key) % n_shards`` — the shuffle), holding them as a
self-contained stacked-padded CSR slab *including its bucket entries'
signature rows* — no shard ever holds the full (N, nw) signature matrix,
so index memory scales down with the mesh. Serving probes run
shard-local: the query batch is split into per-shard blocks that rotate
around the mesh with ``ppermute`` (the ``ring_sweep`` discipline from
:mod:`repro.core.mapreduce`), each hop probing the resident slab
(searchsorted core shared with the single-device probe,
``_probe_csr_positions``) and folding the matches into the block's
carried top-k. After ``n_shards`` hops every block has visited every
bucket owner and carries its global top-k home — no dense sweep, no
global-id arithmetic (buckets store global ids directly), and per-hop
communication is just the rotating query block + its k-row accumulator.

Exactness: buckets are never split across shards, so the union of
per-shard probes is exactly the single-device candidate set; the carried
top-k merges under the total order (distance, id) via the shared
``_dedup_candidates`` tie-break, so results are bit-exact with
:func:`repro.index.service.topk_probe` for every ``n_shards`` — including
tie-breaks — and overflow detection (true matched-bucket size vs cap) is
the max over all (shard, hop) probes, the same grow-and-retry contract.

The placement tracks the backing :class:`SignatureIndex`: references
appended with ``add()`` are re-partitioned automatically on the next
``topk`` (same deferred-rebuild discipline as the CSR buckets).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.hamming import hamming_distance
from ..util import shard_map_compat
from .service import BIG, _dedup_candidates, _probe_csr_positions
from .store import SignatureIndex


def _merge_topk(best_id, best_d, cand, dist, k: int):
    """Fold new candidates into a carried top-k under the total order
    (distance, id): concat, dedup by id (``_dedup_candidates`` — a
    candidate re-surfacing on a later hop has the same exact distance),
    keep the best k. The shared sort-by-id dedup breaks distance ties
    toward the smaller id, exactly like ``_topk_from_candidates``.

    best_id/best_d (B, K) carried accumulator (-1 / BIG in empty slots);
    cand/dist (B, C) this hop's candidates (dist == BIG where masked).
    """
    ids_all = jnp.concatenate([best_id, cand], axis=1)
    d_all = jnp.concatenate([best_d, dist], axis=1)
    ii, dvals = _dedup_candidates(ids_all, d_all, d_all < BIG)
    neg, idx = jax.lax.top_k(-dvals, k)
    nd = -neg
    nid = jnp.take_along_axis(ii, idx, axis=1)
    nid = jnp.where(nd < BIG, nid, -1)
    nd = jnp.where(nd < BIG, nd, BIG)
    return nid, nd


class ShardedIndex:
    """A :class:`SignatureIndex` whose *buckets* are laid out over a mesh."""

    def __init__(self, index: SignatureIndex, mesh=None,
                 *, axis_name: str = "data"):
        self.index = index
        self.axis_name = axis_name
        if mesh is None:
            n = jax.device_count()
            mesh = jax.make_mesh((n,), (axis_name,))
        if axis_name not in mesh.axis_names:
            raise ValueError(f"mesh has axes {mesh.axis_names}, expected "
                             f"{axis_name!r}")
        self.mesh = mesh
        self.n_shards = mesh.shape[axis_name]
        self._snapshot_size = -1        # forces first placement
        self._fn_cache = {}             # (Bl, cap, k) -> jitted ring program
        self._place()

    def _place(self) -> None:
        """(Re)partition the index's buckets across the mesh shards.

        Slabs go straight from host to their owning devices with a
        ``NamedSharding`` split on the shard axis — no single device ever
        materializes the full stack, and the jitted ring (whose in_specs
        expect exactly this layout) never reshards on the serving path."""
        index = self.index
        part = index.partition(self.n_shards)
        sharding = NamedSharding(self.mesh, P(self.axis_name))
        self._slabs = tuple(jax.device_put(a, sharding)
                            for a in part.host_slabs())
        self._esigs = jax.device_put(part.host_entry_sigs(), sharding)
        self._part = part
        self._snapshot_size = index.size
        self._fn_cache.clear()          # slab shapes may have changed

    def _refresh_if_stale(self) -> None:
        if self.index._dirty or self.index.size != self._snapshot_size:
            self._place()

    @property
    def size(self) -> int:
        return self.index.size

    def _ring_fn(self, Bl: int, cap: int, k: int):
        """Jitted shard_map ring program for a (Bl per-shard) query block
        shape (cached — serving hot path, no per-call re-trace)."""
        key = (Bl, cap, k)
        fn = self._fn_cache.get(key)
        if fn is not None:
            return fn
        n, ax = self.n_shards, self.axis_name
        perm = [(i, (i + 1) % n) for i in range(n)]

        def shard_fn(qk, qs, keys_s, offs_s, ids_s, esig_s):
            # qk (Bl, nb), qs (Bl, nw) — this shard's starting query block;
            # slabs arrive (1, nb, ...) after the P(ax) split
            keys_l, offs_l = keys_s[0], offs_s[0]
            ids_l, esig_l = ids_s[0], esig_s[0]
            E = ids_l.shape[1]

            def probe_band(qk_b, keys_b, offs_b, ids_b, esig_b, qs_c):
                """One band's probe + local-sig Hamming filter."""
                idx, ok, size = _probe_csr_positions(qk_b, keys_b, offs_b,
                                                     cap=cap, E=E)
                cand = jnp.where(ok, ids_b[idx], -1)
                dist = hamming_distance(qs_c[:, None, :], esig_b[idx])
                return cand, jnp.where(ok, dist, BIG), size

            def hop(carry, _):
                qk_c, qs_c, bid, bd, msz = carry
                cand, dist, size = jax.vmap(
                    probe_band, in_axes=(1, 0, 0, 0, 0, None))(
                        qk_c, keys_l, offs_l, ids_l, esig_l, qs_c)
                # (nb, Bl, cap) -> (Bl, nb*cap), the fused-probe layout
                cand = jnp.transpose(cand, (1, 0, 2)).reshape(Bl, -1)
                dist = jnp.transpose(dist, (1, 0, 2)).reshape(Bl, -1)
                bid, bd = _merge_topk(bid, bd, cand, dist, k)
                msz = jnp.maximum(msz, jnp.max(size))
                # rotate the block and its accumulator one hop (ring_sweep
                # discipline); after n hops it is home with its global top-k
                qk_c = jax.lax.ppermute(qk_c, ax, perm)
                qs_c = jax.lax.ppermute(qs_c, ax, perm)
                bid = jax.lax.ppermute(bid, ax, perm)
                bd = jax.lax.ppermute(bd, ax, perm)
                return (qk_c, qs_c, bid, bd, msz), None

            init = (qk, qs,
                    jnp.full((Bl, k), -1, jnp.int32),
                    jnp.full((Bl, k), BIG, jnp.int32),
                    jnp.zeros((), jnp.int32))
            (_, _, bid, bd, msz), _ = jax.lax.scan(hop, init, None, length=n)
            return bid, bd, msz[None]

        fn = jax.jit(shard_map_compat(
            shard_fn, self.mesh,
            in_specs=(P(ax), P(ax), P(ax), P(ax), P(ax), P(ax)),
            out_specs=(P(ax), P(ax), P(ax)),
        ))
        self._fn_cache[key] = fn
        return fn

    def topk(self, q_sigs, *, k: int, cap: int = 32, max_cap: int = 1 << 14):
        """Global top-k via shard-local bucket probes.

        (B, nw) query signatures -> (ids (B, k), dists (B, k), final_cap,
        truncated), both -1-padded — bit-exact with
        :func:`~repro.index.service.topk_probe` (same candidates, same
        tie-breaks, same grow-and-retry overflow contract).
        """
        self._refresh_if_stale()
        q = np.asarray(q_sigs, np.uint32)
        B = q.shape[0]
        n = self.n_shards
        keys_s, _, _ = self._slabs
        if B == 0 or keys_s.shape[2] == 0:  # no queries / no buckets at all
            return (np.full((B, k), -1, np.int32),
                    np.full((B, k), -1, np.int32), cap, False)
        qk = np.asarray(self.index.query_keys(q)).T     # (B, nb)
        Bl = max(-(-B // n), 1)
        # padding rows replicate query 0: real keys, so they can only
        # re-match buckets query 0 already probed — the overflow max and
        # the (cap, truncated) contract stay bit-exact with topk_probe
        # (all-zero padding keys could match a real key-0 bucket that no
        # actual query probes)
        qk_p = np.tile(qk[:1], (Bl * n, 1))
        qk_p[:B] = qk
        qs_p = np.tile(q[:1], (Bl * n, 1))
        qs_p[:B] = q
        while True:
            fn = self._ring_fn(Bl, cap, k)
            bid, bd, msz = fn(qk_p, qs_p, *self._slabs, self._esigs)
            truncated = int(np.max(np.asarray(msz))) > cap
            if not truncated or cap >= max_cap:
                break
            cap = min(cap * 2, max_cap)     # grow-and-retry
        nid = np.array(bid[:B])
        nd = np.array(bd[:B])
        nd[nd >= BIG] = -1
        return nid, nd, cap, truncated
