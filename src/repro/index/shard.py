"""Device-sharded index placement: queries fan out, results gather globally.

Placement is round-robin by reference id (global id g lives on shard
``g % n_shards`` at local slot ``g // n_shards``), matching the
``key % n_shards`` ownership convention of :mod:`repro.core.mapreduce`.
Round-robin keeps every shard's load balanced regardless of insertion order.

Queries are replicated to every shard with ``shard_map``; each shard sweeps
its resident signatures (XOR + popcount on the VPU, the same hot loop the
Pallas kernel compiles on TPU) and returns its local top-k *with global
ids*; the host merges the per-shard top-k lists into the final top-k — a
classic scatter-gather serving tree. The placement tracks the backing
:class:`SignatureIndex`: references appended with ``add()`` are re-placed
automatically on the next ``topk`` (same deferred-rebuild discipline as the
CSR buckets).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.hamming import hamming_distance
from ..util import shard_map_compat
from .service import BIG, _finalize_topk
from .store import SignatureIndex


class ShardedIndex:
    """A :class:`SignatureIndex` laid out over a device mesh."""

    def __init__(self, index: SignatureIndex, mesh=None,
                 *, axis_name: str = "data"):
        self.index = index
        self.axis_name = axis_name
        if mesh is None:
            n = jax.device_count()
            mesh = jax.make_mesh((n,), (axis_name,))
        self.mesh = mesh
        self.n_shards = mesh.shape[axis_name]
        self._snapshot_size = -1        # forces first placement
        self._fn_cache = {}             # (B, kk) -> jitted fan-out program
        self._place()

    def _place(self) -> None:
        """(Re)distribute the index rows round-robin across shards."""
        index = self.index
        index._ensure_built()
        n = self.n_shards
        N, nw = index.sigs.shape
        Nl = max(-(-N // n), 1)         # local rows per shard (>=1 for SPMD)
        sig_p = np.full((Nl * n, nw), 0xFFFFFFFF, np.uint32)
        val_p = np.zeros(Nl * n, bool)
        sig_p[:N] = index.sigs
        val_p[:N] = index.valid
        # Round-robin: padded row j*n + s -> shard s, local slot j. Reshape
        # (Nl, n) -> transpose puts shard s's rows [s, s+n, s+2n, ...]
        # contiguous; shard_map's P(axis) split then hands shard s exactly
        # that block.
        self._local_sigs = jnp.asarray(
            sig_p.reshape(Nl, n, nw).transpose(1, 0, 2).reshape(n * Nl, nw))
        self._local_valid = jnp.asarray(
            val_p.reshape(Nl, n).T.reshape(n * Nl))
        self.local_rows = Nl
        self._snapshot_size = N
        self._fn_cache.clear()          # shapes may have changed

    def _refresh_if_stale(self) -> None:
        if self.index._dirty or self.index.size != self._snapshot_size:
            self._place()

    @property
    def size(self) -> int:
        return self.index.size

    def _fan_out_fn(self, B: int, kk: int):
        """Jitted shard_map program for a (B, kk) query shape (cached —
        this is the serving hot path, so no per-call re-trace)."""
        key = (B, kk)
        fn = self._fn_cache.get(key)
        if fn is not None:
            return fn
        n, ax = self.n_shards, self.axis_name

        def shard_fn(qs, rs, rv):
            s = jax.lax.axis_index(ax)
            dist = hamming_distance(qs[:, None, :], rs[None, :, :])  # (B, Nl)
            dist = jnp.where(rv[None, :], dist, BIG)
            neg, idx = jax.lax.top_k(-dist, kk)
            d = -neg
            gid = idx.astype(jnp.int32) * n + s          # local -> global id
            gid = jnp.where(d < BIG, gid, -1)
            d = jnp.where(d < BIG, d, BIG)
            return gid, d

        fn = jax.jit(shard_map_compat(
            shard_fn, self.mesh,
            in_specs=(P(), P(ax), P(ax)),
            out_specs=(P(ax), P(ax)),
        ))
        self._fn_cache[key] = fn
        return fn

    def topk(self, q_sigs, *, k: int):
        """Global top-k: (B, nw) query signatures -> ((B, k) global ids,
        (B, k) dists), both -1-padded, merged across shards."""
        self._refresh_if_stale()
        q_sigs = jnp.asarray(q_sigs)
        B = q_sigs.shape[0]
        n = self.n_shards
        kk = min(k, self.local_rows)
        fn = self._fan_out_fn(B, kk)
        gids, dists = fn(q_sigs, self._local_sigs, self._local_valid)
        # out axis 0 concatenates shards: (n*B, kk) -> (B, n*kk)
        gids = jnp.transpose(gids.reshape(n, B, kk), (1, 0, 2)).reshape(B, -1)
        dists = jnp.transpose(dists.reshape(n, B, kk), (1, 0, 2)).reshape(B, -1)
        # merge: global top-k over the per-shard winners (shared tail with
        # the single-device service paths)
        return _finalize_topk(dists, gids, k)
