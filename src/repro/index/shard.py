"""Bucket-sharded probe serving: shards own buckets, query blocks rotate.

The MapReduce analogue made literal: each shard of the mesh owns the
buckets that :func:`repro.index.partition.bucket_owners` routes to it
(``mix32(band_key) % n_shards`` — the shuffle), holding them as a
self-contained stacked-padded CSR slab *including its bucket entries'
signature rows* — no shard ever holds the full (N, nw) signature matrix,
so index memory scales down with the mesh. Serving probes run
shard-local: the query batch is split into per-shard blocks that rotate
around the mesh with ``ppermute`` (the ``ring_sweep`` discipline from
:mod:`repro.core.mapreduce`) in a **two-phase** sweep:

* **phase 1 — collect**: each hop only *searchsorts* the resident slab
  (core shared with the single-device probe, ``_probe_csr_positions``)
  and writes the candidate ids + their signature rows into the block's
  carried candidate buffers. A (band, key) bucket is owned by exactly
  one shard, so each buffer slot is written on exactly one hop — the
  non-owning hops touch nothing.
* **phase 2 — score at home**: after ``n_shards`` hops the buffers are
  back at the block's home shard, which runs ONE Hamming-distance pass
  over the collected ``nb*cap`` candidates, then the shared dedup +
  top-k tail. The old ring scored all ``nb*cap`` visiting slots on
  *every* hop even though non-owners match nothing — ``n_shards``-fold
  more distance work (and a per-hop top-k merge) for the same result.

No dense sweep, no global-id arithmetic (buckets store global ids
directly); per-hop communication is the rotating query keys + the
candidate id/signature buffers.

Growth is a **delta refresh**, not a re-place: references appended with
``index.add()`` arrive as sealed segments, and because
``mix32(key) % n_shards`` never changes a bucket's owner, :meth:`refresh`
partitions just the new segments and uploads them as a second, small
*delta slab* per shard. Each ring hop probes base + delta and sums the
matched-bucket sizes, so the grow-and-retry overflow contract sees the
same true bucket sizes as a merged table — results are **bit-exact with a
compacted rebuild** (asserted in tests/test_lifecycle.py). When the delta
outgrows the base (or after ``index.compact()``), :meth:`compact`
re-places everything into one base slab; probe results are identical
before and after.

Exactness: buckets are never split across shards, so the union of
per-shard collections is exactly the single-device candidate set, the
collected signature rows are exactly ``ref_sigs[cand]``, and the home
pass is literally ``topk_probe``'s filter — one Hamming sweep, the shared
``_dedup_candidates`` (distance, id) tie-break, one top-k — so results
are bit-exact with :func:`repro.index.service.topk_probe` for every
``n_shards`` — including tie-breaks — and overflow detection (true
matched-bucket size vs cap) is the max over all (shard, hop) probes, the
same grow-and-retry contract.
Both layouts partition identically — the flip layout's single expanded
table is just ``n_bands == 1`` (tested under sharding in
tests/test_sharding.py).
"""
from __future__ import annotations

import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.hamming import hamming_distance
from ..obs import span, trace_sentinel
from ..obs.trace import record as record_span
from ..util import shard_map_compat
from .partition import pad_slabs_pow2
from .service import BIG, _dedup_candidates, _probe_csr_positions
from .store import SignatureIndex


def _merge_topk(best_id, best_d, cand, dist, k: int):
    """Fold new candidates into a carried top-k under the total order
    (distance, id): concat, dedup by id (``_dedup_candidates`` — a
    candidate re-surfacing on a later hop has the same exact distance),
    keep the best k. The shared sort-by-id dedup breaks distance ties
    toward the smaller id, exactly like ``_topk_from_candidates``.

    best_id/best_d (B, K) carried accumulator (-1 / BIG in empty slots);
    cand/dist (B, C) this hop's candidates (dist == BIG where masked).
    """
    ids_all = jnp.concatenate([best_id, cand], axis=1)
    d_all = jnp.concatenate([best_d, dist], axis=1)
    ii, dvals = _dedup_candidates(ids_all, d_all, d_all < BIG)
    neg, idx = jax.lax.top_k(-dvals, k)
    nd = -neg
    nid = jnp.take_along_axis(ii, idx, axis=1)
    nid = jnp.where(nd < BIG, nid, -1)
    nd = jnp.where(nd < BIG, nd, BIG)
    return nid, nd


@functools.lru_cache(maxsize=128)
def _ring_program(devices: tuple, axis_name: str, Bl: int, cap: int, k: int,
                  has_delta: bool):
    """The jitted two-phase shard_map ring program, cached at MODULE level
    by the device tuple (never a Mesh object or a replica instance) — the
    same keying lesson as the self-join's emission cache: equal meshes and
    every replica over them share one compiled program, so constructing a
    new ShardedIndex (or refreshing one) never silently recompiles a ring
    it has already paid for. The ``has_delta`` variant collects from the
    base and delta slabs each hop and sums their matched-bucket sizes (the
    merged-table overflow contract)."""
    ax = axis_name
    mesh = Mesh(np.array(devices), (ax,))
    n = len(devices)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def collect_slab(qk_c, keys_l, offs_l, ids_l, esig_l):
        """Phase-1 collection on one slab: candidate ids + their signature
        rows for the slots this shard owns -> cand (nb, Bl, cap) int32
        (-1 where unmatched), sig (nb, Bl, cap, nw), size (nb, Bl). No
        distance work — that happens once, at home."""
        E = ids_l.shape[1]

        def collect_band(qk_b, keys_b, offs_b, ids_b, esig_b):
            idx, ok, size = _probe_csr_positions(qk_b, keys_b, offs_b,
                                                 cap=cap, E=E)
            cand = jnp.where(ok, ids_b[idx], -1)
            sig = jnp.where(ok[..., None], esig_b[idx], 0)
            return cand, sig, size

        return jax.vmap(collect_band, in_axes=(1, 0, 0, 0, 0))(
            qk_c, keys_l, offs_l, ids_l, esig_l)

    @trace_sentinel("ring", static_key=(devices, Bl, cap, k, has_delta))
    def shard_fn(qk, qs, *slabs):
        # qk (Bl, nb), qs (Bl, nw) — this shard's starting query block;
        # slabs arrive (1, nb, ...) after the P(ax) split: base
        # (keys, offs, ids, esig) then, when present, the delta four.
        # qs never rotates: the one distance pass runs at home (phase 2).
        base = tuple(a[0] for a in slabs[:4])
        delta = tuple(a[0] for a in slabs[4:8]) if has_delta else None
        nw = base[3].shape[-1]
        C = qk.shape[1] * cap * (2 if has_delta else 1)

        def hop(carry, _):
            qk_c, idb, sgb, msz = carry
            cand, sig, size = collect_slab(qk_c, *base)
            if delta is not None:
                c2, s2, z2 = collect_slab(qk_c, *delta)
                # a bucket split across base+delta is ONE bucket of the
                # merged table: candidates union, true size is the sum
                cand = jnp.concatenate([cand, c2], axis=2)
                sig = jnp.concatenate([sig, s2], axis=2)
                size = size + z2
            # (nb, Bl, cap) -> (Bl, nb*cap), the fused-probe layout
            cand = jnp.transpose(cand, (1, 0, 2)).reshape(Bl, -1)
            sig = jnp.transpose(sig, (1, 0, 2, 3)).reshape(Bl, -1, nw)
            ok = cand >= 0
            # each (query, band) bucket is owned by exactly one shard, so
            # each slot is written on exactly one hop — where() is a union
            idb = jnp.where(ok, cand, idb)
            sgb = jnp.where(ok[..., None], sig, sgb)
            msz = jnp.maximum(msz, jnp.max(size))
            # rotate the block's keys and candidate buffers one hop
            # (ring_sweep discipline); after n hops they are home
            qk_c = jax.lax.ppermute(qk_c, ax, perm)
            idb = jax.lax.ppermute(idb, ax, perm)
            sgb = jax.lax.ppermute(sgb, ax, perm)
            return (qk_c, idb, sgb, msz), None

        init = (qk,
                jnp.full((Bl, C), -1, jnp.int32),
                jnp.zeros((Bl, C, nw), jnp.uint32),
                jnp.zeros((), jnp.int32))
        (_, idb, sgb, msz), _ = jax.lax.scan(hop, init, None, length=n)
        # phase 2: ONE Hamming pass over the collected candidates at home,
        # then the shared dedup + top-k tail — exactly topk_probe's filter
        dist = hamming_distance(qs[:, None, :], sgb)
        dist = jnp.where(idb >= 0, dist, BIG)
        bid, bd = _merge_topk(jnp.full((Bl, k), -1, jnp.int32),
                              jnp.full((Bl, k), BIG, jnp.int32),
                              idb, dist, k)
        return bid, bd, msz[None]

    n_args = 10 if has_delta else 6
    return jax.jit(shard_map_compat(
        shard_fn, mesh,
        in_specs=tuple(P(ax) for _ in range(n_args)),
        out_specs=(P(ax), P(ax), P(ax)),
    ))


class ShardedIndex:
    """A :class:`SignatureIndex` whose *buckets* are laid out over a mesh."""

    def __init__(self, index: SignatureIndex, mesh=None,
                 *, axis_name: str = "data"):
        self.index = index
        self.axis_name = axis_name
        if mesh is None:
            n = jax.device_count()
            mesh = jax.make_mesh((n,), (axis_name,))
        if axis_name not in mesh.axis_names:
            raise ValueError(f"mesh has axes {mesh.axis_names}, expected "
                             f"{axis_name!r}")
        self.mesh = mesh
        self.n_shards = mesh.shape[axis_name]
        # Serializes this replica's slab swaps AND the backing index's
        # lazy lifecycle mutations (seal/merge/partition) that refresh
        # triggers. Reentrant because refresh() takes it and is also
        # called under it from _refresh_if_stale. A replica fleet
        # (repro.serve.fleet) swaps in ONE lock shared by every replica
        # and the ingest thread, so a concurrent ``index.add()`` can
        # never interleave with a replica sealing/partitioning the same
        # segments (torn reads). Single-threaded use pays one uncontended
        # RLock acquire per staleness check.
        self.refresh_lock = threading.RLock()
        self._place()

    # ------------------------------------------------------------ placement
    def _put(self, part, quantize: bool = True):
        """Slabs go straight from host to their owning devices with a
        ``NamedSharding`` split on the shard axis — no single device ever
        materializes the full stack, and the jitted ring (whose in_specs
        expect exactly this layout) never reshards on the serving path.

        ``quantize`` pads the bucket (U) and entry (E) axes to powers of
        two (:func:`repro.index.partition.pad_slabs_pow2` — the shared
        inert-padding discipline) so repeated placements repeat slab
        shapes and the ring program stays jit-cache-hot. Originally only
        the DELTA slabs were quantized; the recompile sentinel
        (repro.obs.jit) showed the BASE slabs retracing the ring on every
        compaction (+32 refs = new exact E = new program), so the base is
        now quantized too — a major compaction only recompiles when a
        slab genuinely crosses a power-of-two bin."""
        keys, offs, ids = part.host_slabs()
        esig = part.host_entry_sigs()
        if quantize and ids.shape[-1] > 0:
            keys, offs, ids, esig = pad_slabs_pow2(keys, offs, ids, esig)
        sharding = NamedSharding(self.mesh, P(self.axis_name))
        slabs = tuple(jax.device_put(a, sharding)
                      for a in (keys, offs, ids))
        esigs = jax.device_put(esig, sharding)
        return slabs, esigs

    def _place(self) -> None:
        """Full (re)placement: every segment merged into the base slabs.
        Paid at construction, after ``index.compact()``, and when the
        delta outgrows the base — never on a routine refresh."""
        index = self.index
        index.seal()
        with span("place", cat="lifecycle", shards=self.n_shards,
                  epoch=index.epoch):
            part = index.partition(self.n_shards)
            self._slabs, self._esigs = self._put(part)
        self._part = part
        self._delta = None          # (slabs, esigs) of segments past base
        self._delta_part = None
        self._gen = index.generation
        self._base_epoch = index.epoch
        self._delta_epoch = index.epoch

    def refresh(self) -> None:
        """Ingest segment deltas without a full reload.

        Bucket owners never change (``mix32(key) % n_shards`` is id-free),
        so segments sealed since the base placement partition on their own
        and ride along as per-shard delta slabs; upload cost is O(delta).
        Falls back to a full re-place when the index was compacted
        (generation bump), the base is empty, or the delta has outgrown
        the base (at which point merging is cheaper than carrying both).
        """
        with self.refresh_lock:
            index = self.index
            index.seal()
            if index.generation != self._gen:
                self._place()       # compaction collapsed our base segments
                return
            if index.epoch == self._delta_epoch:
                return              # nothing new
            base_keys = self._slabs[0]
            if base_keys.shape[2] == 0:     # empty base: just re-place
                self._place()
                return
            dpart = self.index.delta_partition(self.n_shards,
                                               self._base_epoch)
            if int(dpart.n_entries.sum()) >= int(self._part.n_entries.sum()):
                self._place()       # delta outgrew base: compact placement
                return
            if int(dpart.n_buckets.sum()) == 0:  # only invalid rows arrived
                self._delta_epoch = index.epoch
                return
            with span("refresh", cat="lifecycle",
                      from_epoch=self._delta_epoch, to_epoch=index.epoch,
                      entries=int(dpart.n_entries.sum())):
                self._delta = None  # drop the old delta before realloc
                delta_slabs, delta_esigs = self._put(dpart)
                self._delta = (delta_slabs, delta_esigs)
            self._delta_part = dpart
            self._delta_epoch = index.epoch

    def compact(self) -> None:
        """Fold the delta slabs back into one base placement (serving-side
        compaction; probe results are identical before and after)."""
        with self.refresh_lock:
            with span("compact_serving", cat="lifecycle",
                      epoch=self.index.epoch):
                self._place()

    def _refresh_if_stale(self) -> None:
        with self.refresh_lock:
            if (self.index.generation, self.index.epoch) != \
                    (self._gen, self._delta_epoch):
                self.refresh()

    @property
    def size(self) -> int:
        return self.index.size

    @property
    def epoch(self) -> tuple[int, int]:
        """(base_epoch, delta_epoch) segment counters this replica serves."""
        return (self._base_epoch, self._delta_epoch)

    # ------------------------------------------------------------ ring
    def _ring_fn(self, Bl: int, cap: int, k: int, has_delta: bool):
        """Resolve this replica's mesh to the module-cached ring program
        (serving hot path, no per-call or per-replica re-trace)."""
        return _ring_program(tuple(self.mesh.devices.flat), self.axis_name,
                             Bl, cap, k, has_delta)

    def topk(self, q_sigs, *, k: int, cap: int = 32, max_cap: int = 1 << 14):
        """Global top-k via shard-local bucket probes.

        (B, nw) query signatures -> (ids (B, k), dists (B, k), final_cap,
        truncated), both -1-padded — bit-exact with
        :func:`~repro.index.service.topk_probe` (same candidates, same
        tie-breaks, same grow-and-retry overflow contract), whether the
        placement is one base slab or base + delta (live refresh).
        """
        self._refresh_if_stale()
        q = np.asarray(q_sigs, np.uint32)
        B = q.shape[0]
        n = self.n_shards
        n_buckets = self._slabs[0].shape[2]
        if self._delta is not None:
            n_buckets += self._delta[0][0].shape[2]
        if B == 0 or n_buckets == 0:    # no queries / no buckets at all
            return (np.full((B, k), -1, np.int32),
                    np.full((B, k), -1, np.int32), cap, False)
        qk = np.asarray(self.index.query_keys(q)).T     # (B, nb)
        Bl = max(-(-B // n), 1)
        # padding rows replicate query 0: real keys, so they can only
        # re-match buckets query 0 already probed — the overflow max and
        # the (cap, truncated) contract stay bit-exact with topk_probe
        # (all-zero padding keys could match a real key-0 bucket that no
        # actual query probes)
        qk_p = np.tile(qk[:1], (Bl * n, 1))
        qk_p[:B] = qk
        qs_p = np.tile(q[:1], (Bl * n, 1))
        qs_p[:B] = q
        t_ring = time.perf_counter()
        while True:
            fn = self._ring_fn(Bl, cap, k, self._delta is not None)
            args = (qk_p, qs_p, *self._slabs, self._esigs)
            if self._delta is not None:
                args = args + (*self._delta[0], self._delta[1])
            bid, bd, msz = fn(*args)
            truncated = int(np.max(np.asarray(msz))) > cap
            if not truncated or cap >= max_cap:
                break
            cap = min(cap * 2, max_cap)     # grow-and-retry
        record_span("ring_probe", t_ring, time.perf_counter(), B=B,
                    shards=n, cap=cap, truncated=truncated,
                    delta=self._delta is not None)
        nid = np.array(bid[:B])
        nd = np.array(bd[:B])
        nd[nd >= BIG] = -1
        return nid, nd, cap, truncated
