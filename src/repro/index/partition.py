"""Shard-owned CSR bucket partition — the MapReduce shuffle as a data layout.

The paper scales because *buckets* are the unit of distribution: the Hadoop
shuffle routes each band key to the reducer that owns it, and all work on a
bucket (pair emission, probing) happens where the bucket lives. This module
is that shuffle as a layer: every (band, key) bucket of a
:class:`~repro.index.store.SignatureIndex` is assigned to shard

    owner = mix32(band_key) % n_shards

(:func:`repro.core.join.mix32` is a uint32 bijection, so ownership is
uniform even for skewed raw keys), and each shard gets a **self-contained
stacked-padded CSR slab** — exactly the layout ``_probe_csr_fused`` runs
against, so a shard can probe (serving) or emit within-bucket pairs
(self-join) entirely locally. Buckets are never split: the union of all
shards' buckets is the original bucket table, which is what makes every
consumer's exactness proof carry over unchanged.

``n_shards=1`` produces the identical stacked arrays the single-device
probe always used — the partition layer is the *only* stacking code path,
so sharded and unsharded serving can never diverge structurally.

Consumers:

* :class:`repro.index.shard.ShardedIndex` — probe serving, query block
  rotated around the mesh (``ppermute`` ring) over shard-local slabs;
* :func:`repro.allpairs.selfjoin.lsh_self_join` — per-shard within-bucket
  pair emission with host-side merge + cross-shard dedup;
* :meth:`repro.index.store.SignatureIndex.probe` — the single-device fused
  probe, which is just shard 0 of the 1-way partition.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.join import mix32
from ..util import next_pow2


def pad_slabs_pow2(keys, offs, ids, esig=None):
    """Pad stacked CSR slabs' bucket (U) and entry (E) axes to powers of
    two — the ONE copy of the quantization discipline shared by the
    serving delta slabs (:meth:`ShardedIndex._put`) and the delta-join
    emission (:func:`repro.allpairs.selfjoin.lsh_delta_join`), so
    successive ingests repeat program shapes and stay jit-cache-hot.

    Operates on the trailing axes (works for (nb, U) and (S, nb, U)
    stacks alike) and follows the probe's inertness rules: keys repeat the
    last key (sorted; can only match empty buckets), offsets repeat the
    end (padded slots own zero entries/pairs), ids — and entry-signature
    rows when given — pad zeros (masked before anything survives).
    """
    U, E = keys.shape[-1], ids.shape[-1]
    Uq, Eq = next_pow2(max(U, 1)), next_pow2(max(E, 1))
    if Uq > U:
        keys = np.concatenate(
            [keys, np.repeat(keys[..., -1:], Uq - U, axis=-1)], axis=-1)
        offs = np.concatenate(
            [offs, np.repeat(offs[..., -1:], Uq - U, axis=-1)], axis=-1)
    if Eq > E:
        ids = np.concatenate(
            [ids, np.zeros(ids.shape[:-1] + (Eq - E,), ids.dtype)],
            axis=-1)
        if esig is not None:
            pad = np.zeros(esig.shape[:-2] + (Eq - E, esig.shape[-1]),
                           esig.dtype)
            esig = np.concatenate([esig, pad], axis=-2)
    return (keys, offs, ids) if esig is None else (keys, offs, ids, esig)


def bucket_owners(keys, n_shards: int) -> np.ndarray:
    """Owning shard of each bucket key: ``mix32(key) % n_shards`` (int32).

    The mix is applied to the *stored* key, so ownership is uniform whether
    the index bucketed raw band bits (``key_hash="none"``) or already-mixed
    ones (``"splitmix"`` — mixing twice is still a bijection).
    """
    mixed = np.asarray(mix32(np.asarray(keys, np.uint32)))
    return (mixed % np.uint32(max(n_shards, 1))).astype(np.int32)


def _take_buckets(keys, offsets, ids, sel):
    """Sub-CSR of the buckets at (ascending) positions ``sel``.

    Keys stay sorted (sel is ascending over sorted keys); offsets restart at
    0; ids are the concatenated member slices, order preserved.
    """
    keys = np.asarray(keys)
    offsets = np.asarray(offsets).astype(np.int64)
    ids = np.asarray(ids)
    sizes = (offsets[1:] - offsets[:-1])[sel]
    sub_offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    total = int(sizes.sum())
    if total == 0:
        return (keys[sel].astype(np.uint32), sub_offsets,
                np.zeros(0, np.int32))
    start = np.repeat(offsets[sel], sizes)
    base = np.repeat(sub_offsets[:-1].astype(np.int64), sizes)
    idx = start + (np.arange(total, dtype=np.int64) - base)
    return (keys[sel].astype(np.uint32), sub_offsets,
            ids[idx].astype(np.int32))


class BucketPartition:
    """``n_shards`` shard-owned slabs over per-band CSR bucket tables.

    Built from the index's per-band ``(keys, offsets, ids)`` CSR arrays;
    exposes both per-shard host CSRs (``shards[s][b]``) and the stacked
    padded device slabs shard_map programs consume. Padding follows the
    probe's inertness discipline: keys pad by repeating the last key
    (sortedness preserved, probes still find the *first* occurrence),
    offsets pad by repeating the end offset (padded key slots are empty
    buckets), so padded slots can never contribute candidates or pairs.
    """

    def __init__(self, csr_per_band, n_shards: int, sigs=None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.n_bands = len(csr_per_band)
        # packed signatures of the indexed corpus; when given, each shard's
        # slab also carries its bucket entries' signature rows, so probes
        # never need the (N, nw) matrix replicated to every shard (the
        # memory-scaling point of sharding in the first place)
        self._sigs = None if sigs is None else np.asarray(sigs, np.uint32)
        self.shards: list[list] = []
        # exact within-bucket pair totals per (shard, band), in int64 — the
        # emission capacity sizing must never wrap (selfjoin discipline)
        self.pair_totals = np.zeros((self.n_shards, self.n_bands), np.int64)
        owners = [bucket_owners(keys, self.n_shards)
                  for keys, _, _ in csr_per_band]
        for s in range(self.n_shards):
            per_band = []
            for b, (keys, offsets, ids) in enumerate(csr_per_band):
                sel = np.flatnonzero(owners[b] == s)
                sub = _take_buckets(keys, offsets, ids, sel)
                sizes = np.diff(sub[1]).astype(np.int64)
                self.pair_totals[s, b] = int((sizes * (sizes - 1) // 2).sum())
                per_band.append(sub)
            self.shards.append(per_band)
        self._stack()
        self._dev = None

    # ------------------------------------------------------------ stacking
    def _stack(self) -> None:
        """Stack every (shard, band) sub-CSR padded to common sizes:
        keys (S, nb, U) uint32, offsets (S, nb, U+1) int32,
        ids (S, nb, max(E, 1)) int32."""
        S, nb = self.n_shards, self.n_bands
        U = max((len(k) for per in self.shards for k, _, _ in per), default=0)
        E = max((len(i) for per in self.shards for _, _, i in per), default=0)
        keys_s = np.zeros((S, nb, U), np.uint32)
        offs_s = np.zeros((S, nb, U + 1), np.int32)
        ids_s = np.zeros((S, nb, max(E, 1)), np.int32)
        for s, per_band in enumerate(self.shards):
            for b, (keys, offsets, ids) in enumerate(per_band):
                u, e = len(keys), len(ids)
                keys_s[s, b, :u] = keys
                if u:
                    keys_s[s, b, u:] = keys[-1]
                offs_s[s, b, :u + 1] = offsets
                offs_s[s, b, u + 1:] = offsets[u] if u else 0
                ids_s[s, b, :e] = ids
        self._stacked = (keys_s, offs_s, ids_s)
        self._esig_np = None
        self._esig_dev = None

    # ------------------------------------------------------------ accessors
    @property
    def n_buckets(self) -> np.ndarray:
        """(S,) bucket count owned by each shard (load-balance diagnostic)."""
        return np.array([sum(len(k) for k, _, _ in per)
                         for per in self.shards], np.int64)

    @property
    def n_entries(self) -> np.ndarray:
        """(S,) bucket-entry count owned by each shard."""
        return np.array([sum(len(i) for _, _, i in per)
                         for per in self.shards], np.int64)

    def host_slabs(self):
        """The stacked numpy slabs (keys (S, nb, U), offsets (S, nb, U+1),
        ids (S, nb, E)) — callers wanting a distributed layout
        ``jax.device_put`` these with a ``NamedSharding`` directly, so no
        single device ever materializes the full stack."""
        return self._stacked

    def host_entry_sigs(self) -> np.ndarray:
        """Per-entry signature rows aligned with the ids slab:
        (S, nb, E, nw) uint32 numpy — see :meth:`device_entry_sigs`.
        Built lazily: only the serving ring pays for it."""
        if self._sigs is None:
            raise ValueError("partition built without sigs; entry "
                             "signatures unavailable")
        if self._esig_np is None:
            _, _, ids_s = self._stacked
            nw = self._sigs.shape[1]
            if self._sigs.shape[0] == 0:    # empty index: all-pad slots
                self._esig_np = np.zeros(ids_s.shape + (nw,), np.uint32)
            else:
                # padded/empty slots hold id 0; their rows are garbage that
                # the probe's ok-mask discards before any distance survives
                self._esig_np = self._sigs[ids_s]
        return self._esig_np

    def device_slabs(self):
        """Stacked slabs as device arrays (uploaded once, cached):
        (keys (S, nb, U), offsets (S, nb, U+1), ids (S, nb, E))."""
        if self._dev is None:
            self._dev = tuple(jnp.asarray(a) for a in self._stacked)
        return self._dev

    def device_entry_sigs(self):
        """Per-entry signature rows aligned with the ids slab:
        (S, nb, E, nw) uint32 device array — each shard's probe ring
        Hamming-filters against THESE, never a replicated (N, nw) matrix.
        Needs the partition built with ``sigs=`` (SignatureIndex does).
        Built lazily: only the serving ring pays for it — the self-join
        and the single-device probe never touch entry signatures."""
        if self._sigs is None:
            raise ValueError("partition built without sigs; entry "
                             "signatures unavailable")
        if self._esig_dev is None:
            self._esig_dev = jnp.asarray(self.host_entry_sigs())
        return self._esig_dev

    def probe_arrays(self, shard: int):
        """Shard ``shard``'s slab as the (nb, ...) arrays
        ``_probe_csr_fused`` consumes."""
        keys_s, offs_s, ids_s = self.device_slabs()
        return keys_s[shard], offs_s[shard], ids_s[shard]
