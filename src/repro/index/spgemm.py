"""Candidate generation as ONE masked sparse-matrix product (SpGEMM).

Every band's bucket CSR ``(keys, offsets, ids)`` is the bucket-major CSR of
a sequence×bucket incidence matrix ``A`` (PASTIS: *Distributed Many-to-Many
Protein Sequence Alignment using Sparse Matrices*): bucket ``u``'s member
list is the nonzero pattern of column ``u``. Candidate discovery — which
sequences share a bucket — is the Boolean-semiring product ``AᵀA``, and the
three hand-rolled emission paths this module replaces are structural masks
over that one product:

* **self-join** — the strict upper triangle of ``AᵀA`` over one slab
  (``mask="upper"``): entry ``p`` of a bucket pairs with every *later*
  member of its own bucket, so each unordered pair is emitted exactly once;
* **delta-join** — ``Aᵀ_delta · A_resident`` (``mask="cross"``): the
  resident×resident block is masked off by never forming it, and the
  delta×delta block is the upper mask over the delta slab;
* **probe** — a row slice of ``Aᵀ_query · A_index``: each query contributes
  one incidence column per band, so its product row is exactly the matched
  bucket's member window (:func:`row_product_positions`).

The structural join on bucket key (:func:`match_buckets`) and the
cumsum-based flattening of per-entry partner windows into a fixed pair
buffer (:func:`_window_pairs`) are each written ONCE here; the legacy
``repro.allpairs.selfjoin`` emission loops and the serving probe both
resolve to them, so the semantics cannot diverge.

Buffer discipline is unchanged from ``core/join.py``: outputs are
fixed-capacity ``(cap, 2)`` int32 buffers with -1 past the true count,
capacities are sized host-side in int64 (the on-device int32 cumsum would
wrap for a degenerate ~66k-member bucket) and quantized to powers of two
(jit-cache stability), and nothing here can truncate when the caller sizes
``cap >= true demand``.

:func:`spgemm_join_self` is the fused fast path (the PR 10 throughput
play): per-band products, cross-band dedup, the optional exact Hamming
filter, and survivor compaction run in ONE jitted program — the pair
buffer stays device-resident end to end, so the fused ungapped prefilter
(PR 9, ``JoinPrefilter``) consumes SpGEMM output with zero host round-trip
and the whole join costs a single host sync (the survivor count).
:func:`spgemm_join_self_keys` sharpens it further for the band layout:
duplicates and Hamming failures are masked at emission (a pair can only
repeat ACROSS bands, and ``index.device_band_keys`` makes that checkable
per slot), so the pack collapses to one ``lax.sort`` of packed int32 keys
plus a clipped gather — no dedup sort, no scatter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.hamming import hamming_distance
from ..core.join import pack_unique_pairs
from ..obs import trace_sentinel


# ------------------------------------------------------------ structural join
def match_buckets(keys, csr_keys, csr_offsets):
    """The structural key join under every mask: for each query key, the
    member window ``[start, end)`` of the right CSR bucket with that key
    (empty when no bucket matches).

    ``keys`` may be per-query probe keys (B,) or per-entry keys of a left
    slab (E,) — the math is identical, which is what makes the probe a row
    slice of the same product as the cross join.
    """
    U = csr_keys.shape[0]
    pos = jnp.searchsorted(csr_keys, keys).astype(jnp.int32)
    pos_c = jnp.clip(pos, 0, max(U - 1, 0))
    match = (pos < U) & (csr_keys[pos_c] == keys)
    start = csr_offsets[pos_c]
    end = jnp.where(match, csr_offsets[jnp.clip(pos_c + 1, 0, U)], start)
    return start, end


def entry_buckets(offsets, n_entries: int):
    """Owning bucket of each CSR entry position: (E,) int32 (entries past
    ``offsets[-1]`` — slab padding — resolve past the last bucket and own
    empty windows under every mask)."""
    pos = jnp.arange(n_entries, dtype=jnp.int32)
    return jnp.searchsorted(offsets, pos, side="right").astype(jnp.int32) - 1


def _window_pairs(left_ids, win_start, cnt, right_ids, *, cap: int):
    """Flatten per-entry partner windows into a fixed (cap, 2) pair buffer.

    Entry ``p`` owns ``cnt[p]`` pairs against ``right_ids[win_start[p] +
    j]`` for ``j < cnt[p]``; a cumsum over ``cnt`` maps fixed buffer slots
    back to (entry, partner). Rows past the true total are -1. The caller
    guarantees ``cap >= sum(cnt)`` (host-side int64 sizing), so nothing
    truncates. Pairs come out as (lo, hi) = (min, max) of the two ids —
    the upper-triangular orientation every consumer dedups on.
    """
    E = left_ids.shape[0]
    Er = right_ids.shape[0]
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(cnt)])
    total = cum[-1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    p = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32) - 1
    p = jnp.clip(p, 0, max(E - 1, 0))
    partner = right_ids[jnp.clip(win_start[p] + (slots - cum[p]), 0,
                                 max(Er - 1, 0))]
    a = left_ids[p]
    valid = slots < total
    lo = jnp.minimum(a, partner)
    hi = jnp.maximum(a, partner)
    return jnp.stack([jnp.where(valid, lo, -1),
                      jnp.where(valid, hi, -1)], axis=-1)


def masked_pair_product(loffs, lids, *, cap: int, mask: str = "upper",
                        lkeys=None, rkeys=None, roffs=None, rids=None):
    """One band's masked semiring product as a flat pair buffer.

    ``mask="upper"``: strict upper triangle of AᵀA over the (loffs, lids)
    slab — entry ``p`` pairs with the later members of its own bucket
    (``cnt[p] = bucket_end(p) - 1 - p``), so each unordered within-bucket
    pair is emitted exactly once (the batch self-join).

    ``mask="cross"``: ``Aᵀ_left · A_right`` — each left entry pairs with
    every member of the right bucket sharing its key (the delta-join's
    new-vs-resident block; never forming the resident×resident block IS
    the mask). Requires ``lkeys/rkeys/roffs/rids``.

    Slab padding is inert under both masks: padded entry slots sit past
    ``loffs[-1]`` and own empty windows; padded right keys repeat the last
    key with empty offsets and match nothing.
    """
    E = lids.shape[0]
    if mask == "upper":
        U = loffs.shape[0] - 1
        pos = jnp.arange(E, dtype=jnp.int32)
        b = entry_buckets(loffs, E)
        end = loffs[jnp.clip(b + 1, 0, U)].astype(jnp.int32)
        cnt = jnp.maximum(end - 1 - pos, 0)
        return _window_pairs(lids, pos + 1, cnt, lids, cap=cap)
    if mask != "cross":
        raise ValueError(f"unknown SpGEMM mask {mask!r}")
    Ul = lkeys.shape[0]
    pos = jnp.arange(E, dtype=jnp.int32)
    u = jnp.clip(entry_buckets(loffs, E), 0, max(Ul - 1, 0))
    start, end = match_buckets(lkeys[u], rkeys, roffs)
    real = pos < loffs[-1]          # past-the-end left slots own nothing
    cnt = jnp.where(real, end - start, 0)
    return _window_pairs(lids, start, cnt, rids, cap=cap)


# ------------------------------------------------------- band-stacked slabs
@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("spgemm_self")
def spgemm_self_slab(offs_s, ids_s, *, cap: int):
    """Upper-mask products of one shard's band-stacked slab: offsets
    (nb, U+1), ids (nb, E) -> (nb, cap, 2) int32, -1 past each band's true
    count. Dispatches through `kernels.ops.emit_upper_pairs`: the Pallas
    kernel lowers natively on TPU, the vmapped jnp product is the fast
    path elsewhere — bit-exact either way."""
    from ..kernels.ops import emit_upper_pairs
    return emit_upper_pairs(offs_s, ids_s, cap=cap)


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("spgemm_cross")
def spgemm_cross_slab(dkeys_s, doffs_s, dids_s, rkeys_s, roffs_s, rids_s,
                      *, cap: int):
    """Cross-mask products of band-stacked delta × resident slabs ->
    (nb, cap, 2) int32."""
    return jax.vmap(lambda dk, do, di, rk, ro, ri: masked_pair_product(
        do, di, cap=cap, mask="cross", lkeys=dk, rkeys=rk, roffs=ro,
        rids=ri))(dkeys_s, doffs_s, dids_s, rkeys_s, roffs_s, rids_s)


# ------------------------------------------------- dup-free keyed self-join
def upper_keys_dupfree(loffs, lids, band, band_keys_nb, sigs, d,
                       *, cap: int, stride: int):
    """One band's upper-mask product emitted as PACKED SORT KEYS
    ``lo*stride + hi`` (-1 on empty slots) with cross-band duplicates and
    Hamming failures masked AT THE SOURCE.

    Under the band layout a sequence occupies exactly one bucket per band,
    so a pair can collide at most once *within* a band — duplicates only
    arise across bands. ``band_keys_nb`` (N, nb) makes that structure
    checkable per emitted slot: the pair is a duplicate iff its two rows
    agree in any band *earlier* than this one (two gathers + a compare),
    so each surviving key is globally unique by construction and the pack
    needs no dedup sort at all. The optional exact Hamming filter rides
    the same mask (the sigs rows are already gathered conceptually — one
    more gather), which is the fully fused form of the semiring: multiply,
    mask, and filter in one emission pass.
    """
    E = lids.shape[0]
    U = loffs.shape[0] - 1
    pos = jnp.arange(E, dtype=jnp.int32)
    b = entry_buckets(loffs, E)
    end = loffs[jnp.clip(b + 1, 0, U)].astype(jnp.int32)
    cnt = jnp.maximum(end - 1 - pos, 0)
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(cnt)])
    total = cum[-1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    p = jnp.clip(jnp.searchsorted(cum, slots, side="right").astype(jnp.int32)
                 - 1, 0, max(E - 1, 0))
    a = lids[p]
    q = lids[jnp.clip(pos[p] + 1 + (slots - cum[p]), 0, max(E - 1, 0))]
    valid = slots < total
    ac = jnp.maximum(a, 0)
    qc = jnp.maximum(q, 0)
    eq = band_keys_nb[ac] == band_keys_nb[qc]                  # (cap, nb)
    earlier = jnp.arange(band_keys_nb.shape[1],
                         dtype=jnp.int32)[None, :] < band
    keep = valid & ~jnp.any(eq & earlier, axis=-1)
    if d is not None:
        keep = keep & (hamming_distance(sigs[ac], sigs[qc]) <= d)
    lo = jnp.minimum(a, q)
    hi = jnp.maximum(a, q)
    return jnp.where(keep, lo * jnp.int32(stride) + hi, -1)


@functools.partial(jax.jit, static_argnames=("cap", "out_cap", "d"))
@trace_sentinel("spgemm_join_keys")
def spgemm_join_self_keys(offs_f, ids_f, band_f, band_keys_nb, sigs,
                          *, cap: int, out_cap: int, d: int | None):
    """The dup-free fused batch self-join (band layout, ids packable into
    one int32 key — ``sigs.shape[0] <= PACKED_KEY_MAX_ID``).

    Because :func:`upper_keys_dupfree` masks cross-band duplicates and
    Hamming failures at emission, the whole pack tail collapses to ONE
    ``lax.sort`` of the key stream: the -1 empty/masked slots sort to the
    front, survivors follow in canonical order, and compaction is a single
    clipped gather (no dedup sort, no cumsum scatter). ``band_f`` (G,) is
    each flattened slab row's band number (``tile(arange(nb), S)``).
    Returns (pairs (out_cap, 2) int32, count) under the same buffer
    contract as :func:`spgemm_join_self` — bit-identical output.
    """
    stride = sigs.shape[0] + 1          # static at trace
    ks = jax.lax.sort(jax.vmap(
        lambda o, i, bb: upper_keys_dupfree(
            o, i, bb, band_keys_nb, sigs, d, cap=cap, stride=stride)
    )(offs_f, ids_f, band_f).reshape(-1))
    M = ks.shape[0]
    n_inv = jnp.searchsorted(ks, 0, side="left").astype(jnp.int32)
    count = M - n_inv
    j = jnp.arange(out_cap, dtype=jnp.int32)
    o = ks[jnp.clip(j + n_inv, 0, M - 1)]
    ok = j < count
    o0 = o // jnp.int32(stride)
    pairs = jnp.stack([jnp.where(ok, o0, -1),
                       jnp.where(ok, o - o0 * jnp.int32(stride), -1)],
                      axis=-1)
    return pairs, count


# ------------------------------------------------------------ probe row slice
def row_product_positions(qkeys, csr_keys, csr_offsets, *, cap: int, E: int):
    """Row slice of the query×index product: qkeys (B,) uint32 -> (entry
    positions (B, cap) int32 clipped into [0, E), ok (B, cap) — position
    is a real member of the matched bucket, size (B,) int32 — the *true*
    matched-bucket size, which may exceed cap). Shared by the id-returning
    serving probe and the sharded ring's sig-gathering probe
    (repro.index.shard), so the probe semantics can never diverge from the
    join's structural key match."""
    start, end = match_buckets(qkeys, csr_keys, csr_offsets)
    size = (end - start).astype(jnp.int32)
    idx = start[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    ok = idx < end[:, None]
    return jnp.clip(idx, 0, max(E - 1, 0)), ok, size


# --------------------------------------------------------------- fused join
def _pack_body(cand, sigs, out_cap: int, d: int | None):
    """Shared pack tail: cross-band/-shard dedup + optional exact Hamming
    filter, compacted to ``out_cap`` rows. Returns (pairs, count); count
    is the TRUE survivor count (may exceed out_cap — caller detects).
    Every id < the corpus size (static at trace), so small corpora run the
    packed single-key sort path of :func:`pack_unique_pairs`."""
    return pack_unique_pairs(cand, out_cap=out_cap, id_bound=sigs.shape[0],
                             sigs=sigs, d=d)


@functools.partial(jax.jit, static_argnames=("out_cap", "d"))
@trace_sentinel("spgemm_pack")
def spgemm_pack(cand, sigs, *, out_cap: int, d: int | None):
    """Dedup + filter + compact an already-emitted device candidate buffer
    (the ragged/SPMD merge tail — emission buffers differ in shape, so the
    product ran in separate programs)."""
    return _pack_body(cand, sigs, out_cap=out_cap, d=d)


@functools.partial(jax.jit, static_argnames=("cap", "out_cap", "d"))
@trace_sentinel("spgemm_join")
def spgemm_join_self(offs_f, ids_f, sigs, *, cap: int, out_cap: int,
                     d: int | None):
    """The fused batch self-join: upper-mask AᵀA over every (shard, band)
    slab + cross-band dedup + optional exact Hamming filter + survivor
    compaction in ONE jitted program.

    offs_f (G, U+1), ids_f (G, E) — the (S, nb) slab axes flattened to
    G = S*nb. Returns (pairs (out_cap, 2) int32, count). The pair buffer
    never leaves the device: the fused prefilter chunks it in place and
    the only host sync the join pays is ``int(count)``. With
    ``out_cap >= total emitted`` (host-side int64 sizing) the dedup can
    never overflow, so the grow-and-retry loop of the legacy
    orchestration disappears entirely.
    """
    cand = spgemm_self_slab(offs_f, ids_f, cap=cap).reshape(-1, 2)
    return _pack_body(cand, sigs, out_cap=out_cap, d=d)
