"""Many-against-many driver: corpus -> similarity graph -> protein families.

  PYTHONPATH=src python -m repro.launch.allpairs \
      --n-families 64 --family-size 4 --n-singletons 256 --d 1 \
      --min-pid 50 [--out /tmp/families.npz] [--pallas] [--stats] \
      [--incremental 128]

Builds (or loads, --index) the corpus SignatureIndex, runs the LSH
self-join, scores the candidate pairs with device-resident tiled
Smith-Waterman waves (fused gather + ungapped X-drop prefilter + async
drain ring), and clusters the thresholded similarity graph into families.

``--incremental N`` holds the last N sequences out of the batch run and
ingests them afterwards through the append-only lifecycle: the index
grows by a sealed segment, the DELTA self-join emits only new-vs-resident
pairs from the touched buckets, only those pairs are scored, and the
surviving edges union into the persistent disjoint-set forest — families
equal a from-scratch recluster at delta cost. With a directory --index
the forest persists beside the manifest as ``families.npz``.

Band keys are splitmix-mixed before bucketing (the serving default,
exactness-preserving); the signature scheme itself stays ``java`` here
because the self-join's Hamming threshold is calibrated to the java
hash's compressed distance scale (``--scheme splitmix`` needs a larger
``--d``).
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="all-pairs corpus similarity search (repro.allpairs)")
    ap.add_argument("--n-families", type=int, default=64)
    ap.add_argument("--family-size", type=int, default=4)
    ap.add_argument("--n-singletons", type=int, default=256)
    ap.add_argument("--len-mean", type=int, default=200)
    ap.add_argument("--sub-rate", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d", type=int, default=1,
                    help="Hamming threshold for the candidate filter")
    ap.add_argument("--scheme", default="java",
                    choices=["java", "splitmix"],
                    help="signature hash bits. Stays java here (unlike the "
                         "serving CLIs): the self-join's d threshold is "
                         "calibrated to the java hash's compressed distance "
                         "scale — splitmix's honest bits need a larger --d")
    ap.add_argument("--no-hamming-filter", action="store_true",
                    help="score every band collision (no distance filter)")
    ap.add_argument("--prefilter", action="store_true",
                    help="skip full SW for pairs whose best ungapped "
                         "diagonal run scores < --prefilter-min. Opt-in "
                         "here: the ungapped score is a LOWER bound of the "
                         "gapped score, and for indel-rich homologs (runs "
                         "chopped by gaps) it can fall below any useful "
                         "threshold — calibrate on your corpus (the "
                         "benchmark corpus keeps 100% recall at 40)")
    ap.add_argument("--prefilter-min", type=int, default=40,
                    help="ungapped score below which full SW is skipped")
    ap.add_argument("--xdrop", type=int, default=None,
                    help="finite X-drop margin (default: best ungapped run)")
    ap.add_argument("--fuse-prefilter", action="store_true",
                    help="run the ungapped prefilter INSIDE the self-join "
                         "(rejected pairs never reach the host); same "
                         "thresholds as --prefilter, identical survivors")
    ap.add_argument("--dp-kernel", default="wavefront",
                    choices=["wavefront", "rowwave"],
                    help="DP sweep for score-only waves: anti-diagonal "
                         "wavefront (default; no within-row prefix scan) "
                         "or the legacy row wave")
    ap.add_argument("--gap-mode", default="linear",
                    choices=["linear", "affine"],
                    help="gap model: linear (-4/residue) or affine Gotoh "
                         "(open/extend; needs --dp-kernel wavefront and "
                         "--pallas/--min-score scoring, PID waves stay "
                         "linear)")
    ap.add_argument("--gap-open", type=int, default=None,
                    help="affine gap-open score (default -11)")
    ap.add_argument("--gap-extend", type=int, default=None,
                    help="affine gap-extend score (default -1)")
    ap.add_argument("--host-gather", action="store_true",
                    help="assemble waves with the host copy loop "
                         "(PR 2 behaviour, for comparison)")
    ap.add_argument("--min-pid", type=float, default=50.0,
                    help="percent-identity threshold for family edges")
    ap.add_argument("--shards", type=int, default=1,
                    help="bucket shards: the self-join emits each shard's "
                         "buckets' pairs on its own device (mix32(key) %% "
                         "n_shards ownership). Score-only waves (--pallas "
                         "off + --prefilter's ungapped phase, or score "
                         "thresholding) additionally split over that many "
                         "devices as one SPMD program; the PID traceback "
                         "wave (the default scoring mode here) is "
                         "host-bound and stays single-device")
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--wave-batch", type=int, default=64)
    ap.add_argument("--pallas", action="store_true",
                    help="score waves with the Pallas SW tile kernel "
                         "(turns off PID: families then threshold on "
                         "SW score >= --min-score)")
    ap.add_argument("--min-score", type=int, default=60,
                    help="SW score threshold used with --pallas")
    ap.add_argument("--index", default=None,
                    help="reuse/persist the corpus index here (.npz = "
                         "legacy monolithic; otherwise a segment directory "
                         "with O(delta) appends)")
    ap.add_argument("--incremental", type=int, default=0, metavar="N",
                    help="hold the last N sequences out of the batch run "
                         "and ingest them afterwards via the delta "
                         "self-join + persistent family forest (families "
                         "equal the from-scratch recluster, at delta cost)")
    ap.add_argument("--join-impl", default="spgemm",
                    choices=["spgemm", "legacy"],
                    help="candidate-generation orchestration: the fused "
                         "device-resident masked-SpGEMM path (default) or "
                         "the pre-SpGEMM host-merge path (identical pair "
                         "arrays; kept one PR for comparison)")
    ap.add_argument("--out", default=None,
                    help="write edges + labels npz here")
    ap.add_argument("--stats", action="store_true",
                    help="print per-band bucket occupancy before joining")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write this process's metrics registry here: "
                         ".json = mergeable registry_state snapshot (what "
                         "--metrics-merge consumes), anything else = "
                         "Prometheus text exposition")
    ap.add_argument("--metrics-merge", nargs="*", default=None,
                    metavar="JSON",
                    help="fold worker registry_state JSON snapshots "
                         "(written by their --metrics-out *.json) into "
                         "this process's registry before rendering "
                         "--metrics-out — histogram buckets add exactly, "
                         "so N workers aggregate into the true fleet "
                         "histogram")
    args = ap.parse_args(argv)

    import os

    if args.shards > 1 and "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"

    import numpy as np

    from ..allpairs import (AllPairsConfig, WaveConfig, all_pairs_ingest,
                            all_pairs_search, forest_from_result)
    from ..core import LSHConfig
    from ..data import FamilyCorpusConfig, make_family_corpus
    from ..index import SignatureIndex, occupancy_report

    import jax
    if args.shards > 1 and jax.device_count() < args.shards:
        # no silent fallback: the self-join would run its one-device vmap
        # path and waves would clamp to one device
        raise SystemExit(
            f"--shards {args.shards} needs that many devices, have "
            f"{jax.device_count()} (XLA_FLAGS was already set in the "
            f"environment? add --xla_force_host_platform_device_count="
            f"{args.shards} to it)")

    corpus = make_family_corpus(FamilyCorpusConfig(
        n_families=args.n_families, family_size=args.family_size,
        n_singletons=args.n_singletons, len_mean=args.len_mean,
        sub_rate=args.sub_rate, seed=args.seed))
    ids, lens, labels = corpus["ids"], corpus["lens"], corpus["labels"]
    n = len(lens)
    lsh = LSHConfig(k=3, T=13, f=32, d=args.d, scheme=args.scheme)

    index = None
    if args.index and os.path.exists(args.index):
        t0 = time.time()
        index = SignatureIndex.load(args.index, expected_cfg=lsh)
        print(f"[index] loaded {index.size} sigs in {time.time()-t0:.2f}s "
              f"(fp={index.fingerprint})")
    cfg = AllPairsConfig(
        lsh=lsh, hamming_filter=not args.no_hamming_filter,
        min_pid=args.min_pid, min_score=args.min_score,
        n_shards=args.shards,
        wave=WaveConfig(tile=args.tile, wave_batch=args.wave_batch,
                        use_pallas=args.pallas or None,
                        with_pid=not args.pallas,
                        device_gather=not args.host_gather,
                        n_devices=args.shards,
                        prefilter=args.prefilter,
                        prefilter_min=args.prefilter_min,
                        xdrop=args.xdrop,
                        dp_kernel=args.dp_kernel,
                        gap_mode=args.gap_mode,
                        gap_open=args.gap_open,
                        gap_extend=args.gap_extend),
        fuse_prefilter=args.fuse_prefilter,
        join_impl=args.join_impl)

    def _emit_metrics():
        from ..obs import REGISTRY, merge_registry_state, registry_state
        if args.metrics_merge:
            import json
            for path in args.metrics_merge:
                with open(path) as fh:
                    merge_registry_state(json.load(fh))
            print(f"[metrics] merged {len(args.metrics_merge)} worker "
                  f"snapshot(s)")
        if args.metrics_out:
            if str(args.metrics_out).endswith(".json"):
                import json
                with open(args.metrics_out, "w") as fh:
                    json.dump(registry_state(REGISTRY), fh)
            else:
                with open(args.metrics_out, "w") as fh:
                    fh.write(REGISTRY.prometheus())
            print(f"[metrics] wrote {args.metrics_out}")

    # ---- incremental mode: batch the resident corpus, ingest the rest
    if args.incremental:
        base = n - args.incremental
        if base <= 0:
            raise SystemExit(f"--incremental {args.incremental} leaves no "
                             f"resident corpus (total {n} seqs)")
        if index is not None and index.size != base:
            print(f"[index] loaded index covers {index.size} != resident "
                  f"{base} seqs; rebuilding")
            index = None
        t0 = time.time()
        res = all_pairs_search(ids[:base], lens[:base], cfg, index=index)
        t_batch = time.time() - t0
        forest = forest_from_result(res)
        t0 = time.time()
        ing = all_pairs_ingest(ids, lens, base, cfg, index=res.index,
                               forest=forest)
        t_ingest = time.time() - t0
        print(f"[batch]  {base} seqs -> {res.join.n_candidates} pairs, "
              f"{res.families.n_families} families ({t_batch:.2f}s)")
        print(f"[ingest] +{args.incremental} seqs -> "
              f"{ing.join.n_candidates} DELTA pairs "
              f"(epoch {res.index.epoch}), "
              f"{int(ing.edge_mask.sum())} edges survived "
              f"({t_ingest:.2f}s vs {t_batch:.2f}s batch — the "
              f"resident corpus was never re-joined or re-scored)")
        fams = ing.families
        pure = sum(1 for fam in fams if len(set(labels[fam])) == 1)
        largest = max((len(f) for f in fams), default=0)
        print(f"[truth]  {pure}/{len(fams)} families over the grown corpus "
              f"are pure; largest={largest}")
        if args.index:
            n_seg = res.index.save(args.index)
            msg = f"[index]  persisted to {args.index} ({n_seg} file(s))"
            if not str(args.index).endswith(".npz"):
                fpath = os.path.join(args.index, "families.npz")
                # the forest lives beside the manifest, stamped with the
                # generation it was clustered against
                forest.save(fpath, generation=res.index.generation)
                msg += f" + forest {fpath}"
            print(msg)
        if args.out:
            pairs = np.concatenate([res.pairs, ing.join.pairs], axis=0)
            scores = np.concatenate([res.scored.scores, ing.scored.scores])
            payload = dict(pairs=pairs, scores=scores,
                           labels=ing.labels, truth=labels)
            if res.scored.pid is not None and ing.scored.pid is not None:
                payload["pid"] = np.concatenate([res.scored.pid,
                                                 ing.scored.pid])
            np.savez_compressed(args.out, **payload)
            print(f"[out]    wrote {args.out}")
        _emit_metrics()
        return

    t0 = time.time()
    res = all_pairs_search(ids, lens, cfg, index=index)
    wall = time.time() - t0
    if args.stats:
        print(occupancy_report(res.index))
    if args.index and index is None:
        res.index.save(args.index)
        print(f"[index] persisted to {args.index}")

    sc = res.scored
    print(f"[join]  {n} seqs -> {res.join.n_candidates} candidate pairs "
          f"({res.join.n_candidates / max(n*(n-1)//2, 1):.2%} of all pairs)")
    print(f"[score] {sc.n_waves} SW waves over {sc.n_shapes} fixed shapes"
          f"{' (pallas)' if args.pallas else ''}"
          + (f"; prefilter rejected {sc.n_prefiltered}/{len(res.pairs)} "
             f"({sc.n_prefiltered / max(len(res.pairs), 1):.0%})"
             if sc.kept is not None else ""))
    thresh = (f"SW score >= {args.min_score}" if args.pallas
              else f"{args.min_pid:.0f}% PID")
    print(f"[graph] {int(res.families.edge_mask.sum())} edges at {thresh} "
          f"-> {res.families.n_families} families (total {wall:.2f}s)")

    # ground-truth purity (synthetic corpora only)
    pure = sum(1 for fam in res.families.families
               if len(set(labels[fam])) == 1)
    largest = max((len(f) for f in res.families.families), default=0)
    print(f"[truth] {pure}/{res.families.n_families} discovered families "
          f"are pure; largest={largest}")

    if args.out:
        payload = dict(pairs=res.pairs, scores=sc.scores,
                       labels=res.labels, truth=labels)
        if sc.pid is not None:
            payload["pid"] = sc.pid
        np.savez_compressed(args.out, **payload)
        print(f"[out]   wrote {args.out}")
    _emit_metrics()


if __name__ == "__main__":
    main()
