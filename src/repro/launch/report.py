"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs import ARCHS, SHAPES, shape_applicable

FIX_NOTES = {
    "compute": "raise arithmetic intensity: bigger per-device tiles / fewer"
               " remat recomputes",
    "memory": "fuse/bridge HBM round-trips: larger attention chunks, fused"
              " CE, fewer scan-boundary materializations",
    "collective": "cut gather volume: ZeRO-1 instead of per-microbatch FSDP"
                  " regather; overlap collectives with compute",
}


def load(dirpath: Path):
    cells = {}
    for p in sorted(dirpath.glob("*.json")):
        with open(p) as f:
            r = json.load(f)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_table(cells, mesh="single"):
    hdr = ("| arch | shape | compute(ms) | memory(ms) | collective(ms) | "
           "bottleneck | useful | peak GiB/dev |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for a in ARCHS:
        for s in SHAPES:
            ok, why = shape_applicable(a, s)
            if not ok:
                lines.append(f"| {a} | {s} | — | — | — | SKIP: {why} | — | — |")
                continue
            r = cells.get((a, s, mesh))
            if r is None:
                lines.append(f"| {a} | {s} | (missing) | | | | | |")
                continue
            lines.append(
                f"| {a} | {s} | {r['compute_s']*1e3:.1f} | "
                f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
                f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
                f"{r['peak_memory_bytes']/2**30:.1f} |")
    return "\n".join(lines)


def fmt_details(cells, mesh="single"):
    out = []
    for a in ARCHS:
        for s in SHAPES:
            r = cells.get((a, s, mesh))
            if r is None:
                continue
            colls = {k: v for k, v in r["collectives"].items() if v}
            out.append(
                f"- **{a} × {s}**: bottleneck={r['bottleneck']}; "
                f"flops/dev={r['hlo_flops']:.2e}, bytes/dev="
                f"{r['hlo_bytes']:.2e}, coll/dev={r['collective_bytes']:.2e} "
                f"({colls}); MODEL_FLOPS/HLO={r['useful_ratio']:.2f}; "
                f"fix: {FIX_NOTES[r['bottleneck']]}.")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--details", action="store_true")
    args = ap.parse_args(argv)
    cells = load(Path(args.dir))
    print(fmt_table(cells, args.mesh))
    if args.details:
        print()
        print(fmt_details(cells, args.mesh))


if __name__ == "__main__":
    main()
