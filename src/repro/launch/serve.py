"""Serving driver: batched prefill + decode with a KV/recurrent cache.

CPU-scale demo of the serve path the decode_* dry-run cells lower.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --smoke --batch 2 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config, shape_applicable
from ..models import decode_step, init_cache, init_params, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ok, why = shape_applicable(args.arch, "decode_32k")
    if not ok:
        raise SystemExit(f"{args.arch} has no decode step: {why}")
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, max_len=P + G)

    t0 = time.time()
    logits, cache = prefill(params, prompt, cache, cfg)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print(f"[prefill] {B}x{P} in {time.time()-t0:.2f}s")

    dstep = jax.jit(lambda c, t, p: decode_step(params, c, t, p, cfg))
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = dstep(cache, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    dt = time.time() - t0
    print(f"[decode] {G-1} steps in {dt:.2f}s "
          f"({B*(G-1)/max(dt,1e-9):.1f} tok/s)")
    print("generated token ids:\n", gen)


if __name__ == "__main__":
    main()
