"""Trip-count-aware cost extraction from optimized HLO text.

Why: XLA's `compiled.cost_analysis()` counts a while/scan BODY ONCE — it
does not multiply by trip count (verified: a 16-step scanned matmul reports
1/16 of the unrolled FLOPs). Every model here scans over layer groups,
microbatches, attention chunks and CE chunks, so aggregate cost_analysis
under-reports by 1-3 orders of magnitude. This walker parses the optimized
HLO module, recurses through called computations, multiplies while-body
costs by the loop trip count, and accumulates:

  * flops            — dot ops: 2 * numel(result) * contracted_size
                       (matmuls dominate every assigned arch; elementwise
                       flops are ignored, consistent with roofline practice)
  * hbm_bytes        — per top-level op: operand bytes + result bytes, with
                       fusions counted as single ops (their internals are
                       VMEM-resident) — the standard HBM-traffic proxy
  * collective_bytes — result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       trip-multiplied

Trip counts: XLA canonicalizes counted loops; the loop bound appears as an
integer constant in the while *condition* computation, compared against the
induction variable. We take the constant operand of the compare. Unknown
bounds fall back to 1 and are reported in `unknown_loops`.

The module produced under SPMD partitioning is per-device, so totals are
per-device — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|condition|body|calls)=\{?%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(text: str):
    """All dtype[dims] shapes in a string -> [(dtype, [dims...]), ...]."""
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(text)]


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Computation:
    name: str
    lines: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # op name -> result shape str


def parse_computations(hlo: str) -> tuple[dict, str]:
    """Split module text into computations; returns ({name: comp}, entry)."""
    comps: dict[str, _Computation] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s or s.startswith("//"):
            continue
        # computation header: "[ENTRY ]%name (args...) -> shape {"
        # (args may contain nested parens; op lines always contain " = ")
        head = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(", s)
        if head and " = " not in s.split("(", 1)[0] + "(" \
                and "->" in s and s.endswith("{"):
            cur = _Computation(name=head.group(2))
            comps[cur.name] = cur
            if head.group(1):
                entry = cur.name
            continue
        if s.startswith("}"):
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(s)
        if d:
            cur.lines.append(s)
            cur.shapes[d.group(1)] = d.group(2)
    return comps, entry


def _trip_count(cond: _Computation) -> int | None:
    """Loop bound from the condition computation's compare-with-constant."""
    consts = {}
    for line in cond.lines:
        d = _DEF_RE.match(line)
        if not d:
            continue
        c = _CONST_RE.search(line)
        if c:
            consts[d.group(1)] = int(c.group(1))
    for line in cond.lines:
        if "compare(" in line:
            args = line.split("compare(", 1)[1].split(")")[0]
            for tok in re.findall(r"%?([\w.\-]+)", args):
                if tok in consts:
                    return consts[tok]
    # fallback: any integer constant in the condition
    if consts:
        return max(consts.values())
    return None


def _op_token_pos(rhs: str):
    m = re.search(r"\b([a-z][\w\-]*)\(", rhs)
    return (m.group(1), m.start()) if m else ("", len(rhs))


def _result_shapes(rhs: str):
    _, pos = _op_token_pos(rhs)
    return _shape_list(rhs[:pos])


def _args_segment(rhs: str) -> str:
    _, pos = _op_token_pos(rhs)
    start = rhs.find("(", pos)
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                return rhs[start + 1:i]
    return rhs[start + 1:]


_SLICING_OPS = ("dynamic-slice", "gather", "dynamic-update-slice", "scatter")


def _io_bytes(rhs: str, shapes_by_name: dict,
              sliced_params: set | None = None) -> int:
    """Result bytes + operand bytes (operands resolved via the defs map).

    Slicing ops (dynamic-slice/gather/DUS/scatter) touch only the sliced
    window, not the whole operand — counting the full operand would charge
    a scan body the entire stacked parameter tensor EVERY iteration. Those
    operands are charged at result size instead. `sliced_params`: operand
    positions of a fusion op whose corresponding parameter is only consumed
    by slicing ops inside the fusion body.
    """
    result_b = _bytes_of(_result_shapes(rhs))
    total = result_b
    op, _ = _op_token_pos(rhs)
    args = _args_segment(rhs)
    names = re.findall(r"%([\w.\-]+)", args)
    if op in _SLICING_OPS:
        # read + write proportional to the moved window (= result for slice/
        # gather; ~update operand for DUS/scatter, bounded by result)
        return 2 * result_b if op in ("dynamic-slice", "gather") \
            else 2 * result_b if not names else min(
                2 * result_b,
                2 * max(result_b,
                        _bytes_of(_result_shapes(
                            shapes_by_name.get(names[-1], "")))))
    for i, nm in enumerate(names):
        ref = shapes_by_name.get(nm)
        if ref is None:
            continue
        b = _bytes_of(_result_shapes(ref))
        if sliced_params is not None and i in sliced_params:
            b = min(b, result_b)
        total += b
    return total


def _fusion_sliced_params(fc: "_Computation") -> set:
    """Parameter indices consumed ONLY by slicing ops inside a fusion body."""
    param_idx = {}      # op name -> parameter index
    consumers = {}      # param name -> set of consuming op kinds
    for line in fc.lines:
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        m = re.search(r"parameter\((\d+)\)", rhs)
        if m:
            param_idx[name] = int(m.group(1))
            continue
        op, _ = _op_token_pos(rhs)
        for nm in re.findall(r"%([\w.\-]+)", _args_segment(rhs)):
            consumers.setdefault(nm, set()).add(op)
    out = set()
    for pname, idx in param_idx.items():
        kinds = consumers.get(pname, set())
        if kinds and kinds <= set(_SLICING_OPS):
            out.add(idx)
    return out


@dataclass
class WalkResult:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = field(default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    unknown_loops: int = 0


def _dot_flops(rhs: str, shapes_by_name: dict) -> float:
    """rhs like 'bf16[a,b] dot(bf16[..] %x, bf16[..] %y), lhs_contracting_dims={1}, ...'"""
    result = _shape_list(rhs.split("dot(")[0])
    numel = 1
    for dt, dims in result[:1]:
        for d in dims:
            numel *= d
    # contracting size: from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    args = rhs.split("dot(", 1)[1]
    # operand shapes usually inline; fall back to defs map
    arg_shapes = _shape_list(args.split("), ")[0] + ")")
    if not arg_shapes:
        # look up operand names
        names = re.findall(r"%([\w.\-]+)", args)
        if names and names[0] in shapes_by_name:
            arg_shapes = _shape_list(shapes_by_name[names[0]])
    contract = 1
    if m and arg_shapes:
        lhs_dims = arg_shapes[0][1]
        for i in (int(x) for x in m.group(1).split(",") if x):
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * numel * contract


def walk(hlo: str) -> WalkResult:
    comps, entry = parse_computations(hlo)
    res = WalkResult()
    # fusion computations are costed as single ops at their call site;
    # but dots INSIDE fusions still contribute flops.
    fusion_comps = set()
    for c in comps.values():
        for line in c.lines:
            if "fusion(" in line:
                m = _CALLED_RE.search(line)
                if m:
                    fusion_comps.add(m.group(1))

    def comp_cost(name: str, mult: float, seen: tuple):
        if name not in comps or name in seen:
            return
        comp = comps[name]
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            rhs = d.group(2)
            opm = re.search(r"\b([a-z][\w\-]*)\(", rhs)
            op = opm.group(1) if opm else ""
            if op == "while":
                body = re.search(r"body=\{?%?([\w.\-]+)", rhs)
                cond = re.search(r"condition=\{?%?([\w.\-]+)", rhs)
                tc = None
                if cond and cond.group(1) in comps:
                    tc = _trip_count(comps[cond.group(1)])
                if tc is None:
                    tc = 1
                    res.unknown_loops += 1
                if body:
                    comp_cost(body.group(1), mult * tc, seen + (name,))
                continue
            if op in ("call", "conditional"):
                for sub in _CALLED_RE.finditer(rhs):
                    comp_cost(sub.group(1), mult, seen + (name,))
                # fallthrough: also count op IO below
            if op == "fusion":
                m = _CALLED_RE.search(rhs)
                sliced = None
                if m:
                    fc = comps.get(m.group(1))
                    if fc:
                        # flops of dots inside the fusion body
                        for fl in fc.lines:
                            if " dot(" in fl or "= dot(" in fl:
                                fd = _DEF_RE.match(fl)
                                if fd:
                                    res.flops += mult * _dot_flops(
                                        fd.group(2), fc.shapes)
                        sliced = _fusion_sliced_params(fc)
                # IO bytes of the fusion op itself (slice-consumed operands
                # charged at window size, not full-tensor size)
                res.hbm_bytes += mult * _io_bytes(rhs, comp.shapes, sliced)
                continue
            if op == "dot":
                res.flops += mult * _dot_flops(rhs, comp.shapes)
                res.hbm_bytes += mult * _io_bytes(rhs, comp.shapes)
                continue
            hit = None
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    hit = c
                    break
            if hit:
                payload = _bytes_of(_shape_list(rhs.split(hit)[0]))
                res.collective_bytes += mult * payload
                res.collectives[hit] += mult * payload
                res.hbm_bytes += mult * payload
                continue
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "partition-id", ""):
                continue
            # generic op: IO proxy
            res.hbm_bytes += mult * _io_bytes(rhs, comp.shapes)

    if entry:
        comp_cost(entry, 1.0, ())
    return res
