"""Training driver: synthetic-data LM training with checkpoint/restart.

CPU-scale entry point (the e2e example trains a ~100M model for a few
hundred steps); the same code path is what the dry-run lowers against the
production mesh. Fault tolerance: periodic atomic checkpoints + --resume;
the data pipeline is stateless in (seed, step, shard) so a restarted run
reproduces the exact batch sequence (tested).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data.lm_data import LMDataConfig, lm_batches, dedup_corpus, synth_corpus
from ..train import AdamWConfig, TrainConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dedup", action="store_true",
                    help="run the ScalLoPS LSH dedup stage on a probe corpus "
                         "before training (the paper's technique in the "
                         "data plane)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    dc = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)

    if args.dedup:
        docs, lens = synth_corpus(dc, n_docs=256, dup_fraction=0.1)
        keep, n_dups = dedup_corpus(docs, lens)
        print(f"[dedup] ScalLoPS SimHash stage: {n_dups} near-duplicates "
              f"dropped of {len(keep)} docs")

    tc = TrainConfig(
        n_microbatches=args.microbatches,
        opt=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        total_steps=args.steps))
    step_fn = jax.jit(make_train_step(cfg, tc, mesh=None))
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and mgr is not None and mgr.latest_step() is not None:
        state, start = mgr.restore(state)
        print(f"[resume] restored step {start}")

    t0 = time.time()
    for s in range(start, args.steps):
        x, y = lm_batches(dc, s)
        if cfg.embedding_inputs:
            key = jax.random.fold_in(jax.random.PRNGKey(args.seed ^ 7), s)
            inputs = jax.random.normal(
                key, (x.shape[0], x.shape[1], cfg.d_model), jnp.float32)
        else:
            inputs = x
        state, metrics = step_fn(state, {"inputs": inputs, "targets": y})
        if s % 10 == 0 or s == args.steps - 1:
            dt = time.time() - t0
            tok_s = (s - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {s:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"tok/s={tok_s:.0f}")
        if mgr is not None and (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, state)
    if mgr is not None:
        mgr.save(args.steps, state)
    print("done.")


if __name__ == "__main__":
    main()
