"""Roofline-term extraction from compiled dry-run artifacts (brief §Roofline).

TPU v5e targets (the runtime here is CPU — terms are derived, not timed):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

  compute term    = HLO_FLOPs / (chips * peak)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes
is parsed from the optimized HLO text: the summed operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
cost_analysis is per-device under SPMD partitioning, so `chips` divides out
of the compute/memory terms; the collective parse is per-device module too.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' HLO shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Uses the op's RESULT shape (the transferred payload for gather/permute;
    for all-reduce the payload equals the result). Tuple shapes are summed.
    Fusion-internal lines can't contain collectives, so a line scan is exact.
    """
    out = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "name = shape op-name(...)" — find the op after '='
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        for c in _COLLECTIVES:
            # match 'all-reduce(' / 'all-reduce-start(' etc.
            if re.search(rf"\b{c}(-start)?\(", rhs):
                # result shape = text before the op token
                shape_part = rhs.split(c)[0].strip()
                out[c] += sum(_shape_bytes(f"{m.group(1)}[{m.group(2)}]")
                              for m in _SHAPE_RE.finditer(shape_part))
                break
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-device
    hlo_bytes: float            # per-device HBM traffic
    collective_bytes: float     # per-device
    collectives: dict
    model_flops: float          # 6·N·D (global, analytic)
    peak_memory_bytes: float    # per-device, from memory_analysis
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0   # MODEL_FLOPS / (HLO_FLOPs * chips)

    def finalize(self):
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.hlo_flops * self.chips
        self.useful_ratio = (self.model_flops / total_hlo
                             if total_hlo else 0.0)
        return self


def analyze(compiled, *, arch, shape, mesh_name, chips, model_flops) -> Roofline:
    """Roofline terms from a compiled module.

    FLOPs/bytes/collectives come from the trip-count-aware HLO walker
    (hlo_walk.py): XLA's aggregate cost_analysis counts while bodies ONCE
    and so under-reports scanned models by orders of magnitude (verified in
    tests/test_roofline.py).
    """
    from .hlo_walk import walk

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = dict(
            argument=getattr(ma, "argument_size_in_bytes", 0),
            output=getattr(ma, "output_size_in_bytes", 0),
            temp=getattr(ma, "temp_size_in_bytes", 0),
        )
    except Exception:
        pass
    peak = (mem.get("argument", 0) + mem.get("output", 0)
            + mem.get("temp", 0))
    w = walk(compiled.as_text())
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(w.flops),
        hlo_bytes=float(w.hbm_bytes),
        collective_bytes=float(w.collective_bytes),
        collectives={k: int(v) for k, v in w.collectives.items()},
        model_flops=float(model_flops),
        peak_memory_bytes=float(peak),
    )
    if w.unknown_loops:
        r.collectives["unknown_loops"] = w.unknown_loops
    return r.finalize()


def model_flops_for(cfg, shape_name: str, n_params_active: int,
                    seq_len: int, global_batch: int, kind: str) -> float:
    """6·N·D for train, 2·N·D for inference forward; decode D = batch tokens
    (one step). Attention FLOPs beyond 6·N·D are excluded by convention —
    the useful-ratio column then shows attention+remat overhead explicitly."""
    if kind == "train":
        return 6.0 * n_params_active * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * n_params_active * seq_len * global_batch
    return 2.0 * n_params_active * global_batch  # decode: 1 token/seq


def save_json(path, roof: Roofline):
    with open(path, "w") as f:
        json.dump(asdict(roof), f, indent=1)
