"""Production mesh + ShapeDtypeStruct input specs for every dry-run cell.

make_production_mesh is a FUNCTION (importing this module never touches jax
device state — the brief's requirement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES
from ..models.sharding import make_rules, logical


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def batch_specs(cfg, mesh, batch: int):
    """Logical batch sharding: DP axes when they divide the batch."""
    rules = make_rules(cfg, mesh)
    b = rules["batch"] if batch % dp_size(mesh) == 0 else None
    return rules, b


def input_specs(cfg, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins (+ NamedShardings) for one cell's inputs.

    train  -> batch dict(inputs, targets)
    prefill-> tokens/embeds (B, S)
    decode -> (tokens (B, 1), pos ()) — the cache is built separately.
    """
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    rules, b = batch_specs(cfg, mesh, B)
    kind = sh["kind"]
    if kind in ("train", "prefill"):
        if cfg.embedding_inputs:
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16,
                                          sharding=NamedSharding(
                                              mesh, P(b, None, None)))
        else:
            inputs = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                          sharding=NamedSharding(
                                              mesh, P(b, None)))
        if kind == "prefill":
            return {"inputs": inputs}
        targets = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=NamedSharding(
                                           mesh, P(b, None)))
        return {"inputs": inputs, "targets": targets}
    # decode: one new token against a seq_len-deep cache
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, P(b, None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"tokens": tokens, "pos": pos}
