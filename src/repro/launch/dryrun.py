import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init). 512 host-platform placeholder devices back the production meshes:
# single-pod (16, 16) and multi-pod (2, 16, 16). Dry-run ONLY — tests and
# benches see the real 1-CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell (31 of the 40 — see configs.shape_applicable):
  * train_4k    -> jit(train_step).lower(state, batch).compile()
  * prefill_32k -> jit(prefill_forward).lower(batch).compile()
  * decode_*    -> jit(serve_step).lower(cache, tokens, pos).compile()
then records memory_analysis / cost_analysis / collective-bytes and the
roofline terms to experiments/dryrun/<arch>__<shape>__<mesh>.json.

All model state is jax.eval_shape'd — nothing is allocated; compile proves
the sharding is coherent and the memory analysis proves it fits 16 GB/chip.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from ..models import ModelConfig
from ..models.sharding import (cache_spec_tree, make_rules, param_spec_tree,
                               logical)
from ..train import AdamWConfig, TrainConfig, make_train_step
from .mesh import make_production_mesh, dp_size, input_specs
from .roofline import analyze, model_flops_for, save_json

# Per-arch microbatch counts for train_4k (sized so saved residuals fit
# 16 GB/chip at batch 256/16-way DP — see DESIGN.md §5 napkin math).
TRAIN_MICROBATCHES = {
    "olmoe-1b-7b": 4, "qwen3-moe-30b-a3b": 8, "hubert-xlarge": 4,
    "recurrentgemma-2b": 8, "qwen2-vl-7b": 16, "nemotron-4-15b": 8,
    "granite-3-8b": 8, "granite-34b": 16, "yi-9b": 8, "xlstm-1.3b": 8,
}


def _state_specs(cfg: ModelConfig, mesh, rules, opt_rules=None):
    """(ShapeDtypeStructs, NamedShardings) for TrainState via eval_shape.

    opt_rules: sharding rules for the OPTIMIZER state — under ZeRO-1 the
    compute params drop FSDP (rules) while master/mu/nu keep it (opt_rules).
    """
    from ..train.train_lib import TrainState, init_train_state

    def init_fn():
        return init_train_state(jax.random.PRNGKey(0), cfg, mesh=None)

    state_sds = jax.eval_shape(init_fn)
    pspecs = param_spec_tree(state_sds.params, cfg, rules)
    ospecs = param_spec_tree(state_sds.params, cfg, opt_rules or rules)
    opt_specs = {"master": ospecs, "mu": ospecs, "nu": ospecs, "step": P()}
    specs = TrainState(params=pspecs, opt_state=opt_specs, step=P())
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    sds = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        state_sds, shardings)
    return sds, shardings


def _cache_specs(cfg: ModelConfig, mesh, rules, batch: int, max_len: int):
    from ..models import init_cache
    cache_sds = jax.eval_shape(partial(init_cache, cfg, batch, max_len))
    cspecs = cache_spec_tree(cache_sds, cfg, rules)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                             is_leaf=lambda x: isinstance(x, P))
    sds = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        cache_sds, shardings)
    return sds, shardings


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               *, zero1: bool = False, causal_skip: bool = False):
    """Lower + compile one cell; returns (compiled, roofline).

    zero1=True: hillclimb-A variant — compute params replicated over "data"
    (no per-microbatch FSDP regather), optimizer state stays FSDP-sharded.
    causal_skip=True: hillclimb-B variant — triangular attention schedule.
    """
    from ..models import decode_step, loss_fn
    from ..models.config import active_param_count
    from ..models.model import forward, _lm_head_matrix

    cfg = get_config(arch)
    if causal_skip:
        cfg = cfg.scaled(causal_skip=True)
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    B, S = sh["global_batch"], sh["seq_len"]
    rules = make_rules(cfg, mesh, fsdp=not zero1)
    opt_rules = make_rules(cfg, mesh, fsdp=True)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]

    with mesh:
        if kind == "train":
            # each microbatch must divide the DP axes (else GSPMD pads a
            # fractional per-device batch — measured +50% temp memory)
            nm = TRAIN_MICROBATCHES[arch]
            while B // nm % dp_size(mesh) != 0 and nm > 1:
                nm //= 2
            tc = TrainConfig(n_microbatches=nm, opt=AdamWConfig())
            step = make_train_step(cfg, tc, mesh, rules=rules)
            state_sds, state_sh = _state_specs(cfg, mesh, rules, opt_rules)
            batch_sds = input_specs(cfg, shape_name, mesh)
            lowered = jax.jit(step).lower(state_sds, batch_sds)
        elif kind == "prefill":
            def prefill_fn(params, batch):
                hidden, _, _ = forward(params, batch["inputs"], cfg, rules)
                W = _lm_head_matrix(params, cfg)
                return hidden[:, -1].astype(jnp.float32) @ W.astype(
                    jnp.float32)
            from ..models import init_params
            p_sds = jax.eval_shape(
                partial(init_params, jax.random.PRNGKey(0), cfg))
            pspecs = param_spec_tree(p_sds, cfg, rules)
            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
            p_sds = jax.tree.map(
                lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                  sharding=s), p_sds, p_sh)
            batch_sds = input_specs(cfg, shape_name, mesh)
            lowered = jax.jit(prefill_fn).lower(p_sds, batch_sds)
        else:  # decode
            def serve_step(params, cache, tokens, pos):
                return decode_step(params, cache, tokens, pos, cfg, rules)
            from ..models import init_params
            p_sds = jax.eval_shape(
                partial(init_params, jax.random.PRNGKey(0), cfg))
            pspecs = param_spec_tree(p_sds, cfg, rules)
            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
            p_sds = jax.tree.map(
                lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                  sharding=s), p_sds, p_sh)
            cache_sds, cache_sh = _cache_specs(cfg, mesh, rules, B, S)
            io = input_specs(cfg, shape_name, mesh)
            lowered = jax.jit(serve_step).lower(
                p_sds, cache_sds, io["tokens"], io["pos"])
        compiled = lowered.compile()

    mf = model_flops_for(cfg, shape_name, active_param_count(cfg), S, B, kind)
    roof = analyze(compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
                   chips=chips, model_flops=mf)
    return compiled, roof


def run_cell(arch, shape_name, mesh_name, outdir: Path, verbose=True,
             zero1=False, causal_skip=False):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    tag = ("+zero1" if zero1 else "") + ("+cskip" if causal_skip else "")
    compiled, roof = lower_cell(arch, shape_name, mesh, mesh_name + tag,
                                zero1=zero1, causal_skip=causal_skip)
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    suffix = tag.replace("+", "__")
    out = outdir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    save_json(out, roof)
    if verbose:
        print(f"[OK] {arch} x {shape_name} x {mesh_name} "
              f"({dt:.0f}s compile)")
        print(f"     mem/device: arg={mem.argument_size_in_bytes/2**30:.2f}G "
              f"out={mem.output_size_in_bytes/2**30:.2f}G "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}G")
        print(f"     flops/dev={roof.hlo_flops:.3e} bytes/dev="
              f"{roof.hlo_bytes:.3e} coll={roof.collective_bytes:.3e}")
        print(f"     terms: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"-> {roof.bottleneck}-bound, useful={roof.useful_ratio:.2f}")
    return roof


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zero1", action="store_true",
                    help="hillclimb-A variant: ZeRO-1 instead of FSDP")
    ap.add_argument("--causal-skip", action="store_true",
                    help="hillclimb-B variant: triangular attention")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            ok, why = shape_applicable(a, s)
            if ok:
                cells.append((a, s))
            else:
                print(f"[SKIP] {a} x {s}: {why}")

    failures = []
    for a, s in cells:
        for m in meshes:
            sfx = ("__zero1" if args.zero1 else "") + \
                ("__cskip" if args.causal_skip else "")
            marker = outdir / f"{a}__{s}__{m}{sfx}.json"
            if marker.exists():
                print(f"[CACHED] {a} x {s} x {m}")
                continue
            try:
                run_cell(a, s, m, outdir, zero1=args.zero1,
                         causal_skip=args.causal_skip)
            except Exception as e:
                failures.append((a, s, m, repr(e)))
                print(f"[FAIL] {a} x {s} x {m}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        sys.exit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
