"""Serving driver for indexed protein search: build -> persist -> load -> serve.

The index analogue of ``repro.launch.serve``'s LM path: pays the reference
database cost once (paper §5.3), persists the artifact, then serves query
micro-batches with latency/throughput stats.

  PYTHONPATH=src python -m repro.launch.search_serve \
      --n-refs 2048 --n-queries 256 --batch 32 --k 5 --d 1 \
      --index /tmp/scallops.npz [--shards 4] [--rerank] [--layout flip]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-refs", type=int, default=2048)
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--d", type=int, default=1)
    ap.add_argument("--scheme", default="splitmix",
                    choices=["splitmix", "java"],
                    help="signature hash bits; the serving default is "
                         "splitmix (>= 99%% of ideal bucket entropy vs "
                         "54-60%% for the Java hash — index.stats); pass "
                         "java for paper-fidelity runs")
    ap.add_argument("--index", default=None,
                    help="npz path for the persisted index (default: tmp)")
    ap.add_argument("--layout", default="band", choices=["band", "flip"])
    ap.add_argument("--shards", type=int, default=1,
                    help="bucket shards: each device owns the buckets "
                         "mix32(band_key) %% n_shards routes to it (the "
                         "MapReduce shuffle) and probes only those; query "
                         "blocks rotate around the mesh via ppermute")
    ap.add_argument("--rerank", action="store_true",
                    help="Smith-Waterman re-rank of the top-k")
    args = ap.parse_args(argv)

    if args.shards > 1 and "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"

    import numpy as np
    import jax

    from ..core import LSHConfig
    from ..data import SyntheticProteinConfig, make_protein_sets
    from ..index import QueryEngine, ServingConfig, ShardedIndex, SignatureIndex

    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=args.n_refs, n_homolog_queries=args.n_queries // 4,
        n_decoy_queries=args.n_queries - args.n_queries // 4,
        ref_len_mean=150, ref_len_std=30, sub_rates=(0.05, 0.15), seed=13))
    cfg = LSHConfig(k=3, T=13, f=32, d=args.d, scheme=args.scheme,
                    max_pairs=1 << 15)

    # ---- build + persist (paid once per reference database)
    t0 = time.time()
    index = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"],
                                 layout=args.layout, n_shards=args.shards)
    index._ensure_built()
    t_build = time.time() - t0
    path = args.index or os.path.join(tempfile.gettempdir(), "scallops.npz")
    t0 = time.time()
    index.save(path)
    t_save = time.time() - t0
    print(f"[build] {index.size} refs -> {index.n_bands}-band {args.layout} "
          f"index in {t_build:.2f}s (save {t_save:.2f}s, "
          f"{os.path.getsize(path)/1e6:.1f} MB, fp={index.fingerprint})")

    # ---- load (fingerprint-verified) + serve
    t0 = time.time()
    loaded = SignatureIndex.load(path, expected_cfg=cfg)
    print(f"[load]  verified fingerprint in {time.time()-t0:.2f}s")

    sharded = None
    if args.shards > 1:
        from jax.sharding import Mesh
        if jax.device_count() < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs that many devices, have "
                f"{jax.device_count()} (XLA_FLAGS was already set?)")
        # mesh sized by --shards (== the index's persisted n_shards), not
        # by whatever the process happens to expose
        mesh = Mesh(np.array(jax.devices()[:args.shards]), ("data",))
        sharded = ShardedIndex(loaded, mesh)
        part = sharded._part
        print(f"[shard] {int(part.n_buckets.sum())} buckets over "
              f"{sharded.n_shards} devices (per-shard buckets "
              f"{part.n_buckets.tolist()}, entries {part.n_entries.tolist()})")

    scfg = ServingConfig(k=args.k, max_batch=args.batch, rerank=args.rerank)
    engine = QueryEngine(loaded, scfg, sharded=sharded,
                         ref_seqs=(data["ref_ids"], data["ref_lens"]))
    mode = "sharded-probe" if sharded is not None else engine._mode()
    print(f"[mode]  {mode} serving (probe candidates are exact within "
          f"Hamming d={args.d}; the dense path ranks ALL refs — raise --d "
          f"for deeper top-k recall under probe/sharded serving)")
    # warm-up batch compiles the fixed-shape serving path
    engine.query_batch(data["query_ids"][:args.batch],
                       data["query_lens"][:args.batch])
    engine._stats.batch_sizes.clear()
    engine._stats.latencies.clear()

    qids, qlens = data["query_ids"], data["query_lens"]
    hits = 0
    t0 = time.time()
    for i in range(0, len(qlens), args.batch):
        nid, nd = engine.query_batch(qids[i:i + args.batch],
                                     qlens[i:i + args.batch])
        for j, (parent, _rate) in enumerate(data["truth"][i:i + args.batch]):
            if parent >= 0 and parent in set(nid[j][nid[j] >= 0]):
                hits += 1
    wall = time.time() - t0
    s = engine.stats()
    n_hom = sum(1 for p, _ in data["truth"] if p >= 0)
    print(f"[serve] {s['n_queries']} queries in {wall:.2f}s — "
          f"{s['qps']:.0f} q/s, p50={s['p50_ms']:.1f}ms "
          f"p95={s['p95_ms']:.1f}ms (batch={args.batch}, k={args.k}"
          f"{', rerank' if args.rerank else ''})")
    print(f"[quality] planted homologs in top-{args.k}: "
          f"{hits}/{n_hom} ({hits/max(n_hom,1):.0%})")
    if args.index is None:
        os.unlink(path)


if __name__ == "__main__":
    main()
