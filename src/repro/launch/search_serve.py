"""Serving driver for indexed protein search: build -> persist -> load ->
serve -> grow -> compact.

The index analogue of ``repro.launch.serve``'s LM path: pays the reference
database cost once (paper §5.3), persists the artifact, then serves query
micro-batches with latency/throughput stats. Growth is append-only: an
``--index`` path WITHOUT ``.npz`` is a segment directory (manifest +
per-segment files) where ``--add-fasta`` appends O(delta) segment files
and a live serving replica ingests the delta without a full reload;
``--compact`` folds the segments back into one.

  PYTHONPATH=src python -m repro.launch.search_serve \
      --n-refs 2048 --n-queries 256 --batch 32 --k 5 --d 1 \
      --index /tmp/scallops_idx [--shards 4] [--rerank] [--layout flip] \
      [--add-fasta new_refs.fasta] [--compact]

With ``--replicas N`` the queries go through the asynchronous serving
tier instead (:mod:`repro.serve`): N sharded replicas behind a
least-outstanding router, futures-based ``submit()`` with
``--deadline-ms`` admission control and a ``--max-wait-ms`` dispatch
policy; ``--add-fasta`` then ingests through the fleet's background
loop while serving stays live.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time


def _dump_obs(args) -> None:
    """Write the observability artifacts the flags asked for: Prometheus
    text exposition (--metrics-out) and/or the Chrome/Perfetto trace
    (--trace-out; open at https://ui.perfetto.dev)."""
    from ..obs import REGISTRY, TRACER
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(REGISTRY.prometheus())
        print(f"[obs]   metrics -> {args.metrics_out}")
    if args.trace_out:
        n = TRACER.export(args.trace_out)
        print(f"[obs]   trace -> {args.trace_out} ({n} events; open in "
              f"chrome://tracing or ui.perfetto.dev)")


def _serve_async(args, data, loaded, mesh, ref_seqs, scfg, path):
    """Serve through the async tier: ReplicaFleet + AsyncEngine, one
    future per query, with ``--add-fasta`` ingested live mid-stream."""
    import numpy as np

    from ..serve import AsyncEngine, ReplicaFleet

    fleet = ReplicaFleet(loaded, scfg, n_replicas=args.replicas,
                         mesh=mesh, ref_seqs=ref_seqs)
    eng = AsyncEngine(fleet, max_wait_ms=args.max_wait_ms,
                      default_deadline_ms=args.deadline_ms)
    plan = None
    if args.chaos:
        # a small scripted demo of the PR 8 fault machinery: two replica
        # crashes (each retried on the other replica, bit-exact) and one
        # slow call — deterministic because the dispatch thread serializes
        # fleet calls, so per-site call numbers are reproducible
        from ..faults import FaultPlan
        plan = (FaultPlan()
                .add("replica.query", "raise", on=2)
                .add("replica.query", "raise", on=5)
                .add("replica.query", "latency", on=6, delay_s=0.03)
                .install())
        print("[chaos] fault plan installed: replica.query raise@{2,5} "
              "latency@6 (expect 2 router retries, 0 degraded)")
    print(f"[async] {args.replicas} replica(s) x "
          f"{fleet._replicas[0].sharded.n_shards} shard(s), "
          f"max_wait={args.max_wait_ms}ms, "
          f"deadline={args.deadline_ms or 'none'}"
          f"{'' if args.deadline_ms is None else 'ms'}")
    # warm-up: every (rung, length-quantum) serving shape on every replica
    # (replicas share the compiled ring programs — one compile total)
    fleet.warmup(data["query_ids"], data["query_lens"])

    qids, qlens = data["query_ids"], data["query_lens"]
    ingest_ev = None
    new_count = 0
    futures = []
    t0 = time.time()
    for i in range(len(qlens)):
        if args.add_fasta and i == len(qlens) // 2:
            # ingest the delta while requests are still streaming in:
            # serving never pauses, replicas refresh off-rotation
            from ..data.fasta import load_fasta_encoded
            _names, new_ids, new_lens = load_fasta_encoded(args.add_fasta)
            new_count = len(new_lens)
            ingest_ev = fleet.ingest(new_ids, new_lens)
        futures.append(eng.submit(qids[i][:qlens[i]]))
    results = [f.result(timeout=120) for f in futures]
    wall = time.time() - t0

    hits = served = shed = degraded = 0
    epochs = {}
    for r, (parent, _rate) in zip(results, data["truth"]):
        if getattr(r, "degraded", False):
            degraded += 1
            continue
        if not r.ok:
            shed += 1
            continue
        served += 1
        epochs[r.epoch] = epochs.get(r.epoch, 0) + 1
        if parent >= 0 and parent in set(r.ids[r.ids >= 0]):
            hits += 1
    if ingest_ev is not None:
        ingest_ev.wait(timeout=120)
        loaded.save(path)               # appends ONLY the new segment
        print(f"[add]   +{new_count} refs ingested LIVE mid-stream -> "
              f"epoch {loaded.epoch}; served epochs "
              f"{dict(sorted(epochs.items()))} (every result tagged with "
              f"the index state it was answered at)")

    s = eng.stats()
    lat, qlat = s["latency"], s["queue"]
    n_hom = sum(1 for p, _ in data["truth"] if p >= 0)
    print(f"[serve] {served}/{len(results)} queries in {wall:.2f}s — "
          f"{served / max(wall, 1e-9):.0f} q/s, "
          f"p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
          f"p99={lat['p99_ms']:.1f}ms (queue p95={qlat['p95_ms']:.1f}ms, "
          f"{s['counters']['batches']} batches, "
          f"shed={shed}, degraded={degraded}, k={args.k})")
    print(f"[quality] planted homologs in top-{args.k}: "
          f"{hits}/{n_hom} ({hits / max(n_hom, 1):.0%})")

    fs = fleet.stats()
    health = " ".join(
        f"{r['name']}:{'QUAR' if r['health']['quarantined'] else 'up'}"
        f"(fails={r['health']['fails']})" for r in fs["replicas"])
    print(f"[health] coverage={fs['coverage']:.0%} {health} — "
          f"retries={fs['counters'].get('retries', 0)} "
          f"retry_ok={fs['counters'].get('retry_success', 0)} "
          f"quarantines={fs['counters'].get('replica_quarantines', 0)} "
          f"degraded_batches={fs['counters'].get('degraded_batches', 0)}; "
          f"dispatch crashes="
          f"{s.get('dispatch', {}).get('crashes', 0)}, "
          f"wedged={s['wedged']}")
    if plan is not None:
        plan.uninstall()
        missed = plan.unfired()
        n_scripted = sum(plan.summary()["scripted"].values())
        print(f"[chaos] fired {plan.fired()} of {n_scripted} "
              f"scripted faults"
              + ("" if not missed else
                 f" — UNFIRED (traffic too short?): {missed}"))

    if args.compact:
        before = fleet.query_batch(qids[:args.batch], qlens[:args.batch])
        t1 = time.time()
        fleet.compact_index()
        loaded.save(path)
        after = fleet.query_batch(qids[:args.batch], qlens[:args.batch])
        same = (np.array_equal(before[0], after[0])
                and np.array_equal(before[1], after[1]))
        print(f"[compact] {time.time() - t1:.2f}s -> epoch {loaded.epoch} "
              f"gen {loaded.generation} (rolling, serving stayed live); "
              f"probe results "
              f"{'identical' if same else 'DIVERGED (BUG)'}")
        if not same:
            raise SystemExit(1)
    eng.close()
    fleet.close()
    _dump_obs(args)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-refs", type=int, default=2048)
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--d", type=int, default=1)
    ap.add_argument("--f", type=int, default=32,
                    help="signature width in bits (multiple of 32; 64/128 "
                         "need --scheme splitmix, and band keys wider than "
                         "32 bits fold through the mix32 chain)")
    ap.add_argument("--scheme", default="splitmix",
                    choices=["splitmix", "java"],
                    help="signature hash bits; the serving default is "
                         "splitmix (>= 99%% of ideal bucket entropy vs "
                         "54-60%% for the Java hash — index.stats); pass "
                         "java for paper-fidelity runs")
    ap.add_argument("--index", default=None,
                    help="persisted index path (default: tmp). Paths ending "
                         "in .npz write the monolithic legacy container; "
                         "anything else is a SEGMENT DIRECTORY — manifest + "
                         "per-segment files, where repeated saves append "
                         "only the new segments (O(delta) persistence)")
    ap.add_argument("--layout", default="band", choices=["band", "flip"])
    ap.add_argument("--shards", type=int, default=1,
                    help="bucket shards: each device owns the buckets "
                         "mix32(band_key) %% n_shards routes to it (the "
                         "MapReduce shuffle) and probes only those; query "
                         "blocks rotate around the mesh via ppermute. "
                         "Works for both layouts (flip = one expanded band)")
    ap.add_argument("--add-fasta", default=None, metavar="FASTA",
                    help="after the first serving pass, append these "
                         "sequences as a sealed index segment and keep "
                         "serving: the sharded replica ingests the delta "
                         "slab via refresh() (no full reload) and a "
                         "directory --index persists just the new segment")
    ap.add_argument("--compact", action="store_true",
                    help="fold all segments into one after serving "
                         "(results identical before/after; a directory "
                         "--index is rewritten as a single segment)")
    ap.add_argument("--rerank", action="store_true",
                    help="Smith-Waterman re-rank of the top-k")
    ap.add_argument("--dp-kernel", default="wavefront",
                    choices=["wavefront", "rowwave"],
                    help="re-rank DP sweep (anti-diagonal wavefront is "
                         "the default; rowwave is the legacy prefix-scan "
                         "path)")
    ap.add_argument("--gap-mode", default="linear",
                    choices=["linear", "affine"],
                    help="re-rank gap model; affine (Gotoh -11/-1) needs "
                         "--dp-kernel wavefront")
    ap.add_argument("--gap-open", type=int, default=None,
                    help="affine gap-open score (default -11)")
    ap.add_argument("--gap-extend", type=int, default=None,
                    help="affine gap-extend score (default -1)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through the ASYNC tier: this many "
                         "ShardedIndex replicas behind a least-outstanding "
                         "router with futures-based submit() and a "
                         "background ingest loop (0 = the synchronous "
                         "QueryEngine path). Replicas share compiled ring "
                         "programs, so N replicas cost one compile")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for the async tier: "
                         "requests whose queue time + predicted batch "
                         "cost exceed it are shed with a typed Rejected "
                         "outcome instead of served late (default: no "
                         "deadline, nothing is shed)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="async dispatch policy: a micro-batch launches "
                         "at --batch requests or when its oldest request "
                         "has waited this long (0 = greedy)")
    ap.add_argument("--chaos", action="store_true",
                    help="install a small scripted FaultPlan during the "
                         "async serving pass (needs --replicas >= 2): two "
                         "replica crashes and one slow call, each retried "
                         "or absorbed by the router; prints retry / "
                         "quarantine / coverage accounting at the end. "
                         "Deterministic — benchmarks/chaos_soak.py is the "
                         "full closed-loop version")
    ap.add_argument("--recover", action="store_true",
                    help="load the index with crash recovery enabled: a "
                         "torn or checksum-failed trailing segment is "
                         "QUARANTINED (moved to quarantine/, manifest "
                         "rewritten) and serving continues on the longest "
                         "valid prefix instead of refusing to start")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the process-wide metrics registry as "
                         "Prometheus text exposition on exit (merged "
                         "histograms, counters, recompile-sentinel counts)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable structured tracing and write a "
                         "Chrome/Perfetto trace_event JSON on exit (every "
                         "span carries its queries' trace IDs; open in "
                         "chrome://tracing or ui.perfetto.dev)")
    args = ap.parse_args(argv)

    if args.chaos and args.replicas < 2:
        ap.error("--chaos needs --replicas >= 2 (the router retries a "
                 "crashed call on a DIFFERENT replica)")

    if args.trace_out:
        from ..obs import enable as _trace_enable
        _trace_enable()     # before any serving work: spans from the first
                            # warm-up batch onward land in the buffer

    if args.shards > 1 and "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (host platform device count)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.shards}"

    import numpy as np
    import jax

    from ..core import LSHConfig
    from ..core.alphabet import PAD
    from ..data import SyntheticProteinConfig, make_protein_sets
    from ..index import QueryEngine, ServingConfig, ShardedIndex, SignatureIndex

    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=args.n_refs, n_homolog_queries=args.n_queries // 4,
        n_decoy_queries=args.n_queries - args.n_queries // 4,
        ref_len_mean=150, ref_len_std=30, sub_rates=(0.05, 0.15), seed=13))
    cfg = LSHConfig(k=3, T=13, f=args.f, d=args.d, scheme=args.scheme,
                    max_pairs=1 << 15)

    # ---- build + persist (paid once per reference database)
    t0 = time.time()
    index = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"],
                                 layout=args.layout, n_shards=args.shards)
    index._ensure_built()
    t_build = time.time() - t0
    tmp_dir = None
    if args.index:
        path = args.index
    else:
        tmp_dir = tempfile.mkdtemp(prefix="scallops_idx_")
        path = os.path.join(tmp_dir, "idx")
    t0 = time.time()
    n_written = index.save(path)
    t_save = time.time() - t0
    container = "monolithic npz" if str(path).endswith(".npz") \
        else f"segment dir ({n_written} segment file(s))"
    print(f"[build] {index.size} refs -> {index.n_bands}-band {args.layout} "
          f"index in {t_build:.2f}s (save {t_save:.2f}s, {container}, "
          f"fp={index.fingerprint})")

    # ---- load (fingerprint-verified) + serve
    t0 = time.time()
    loaded = SignatureIndex.load(path, expected_cfg=cfg,
                                 recover=args.recover)
    print(f"[load]  verified fingerprint in {time.time()-t0:.2f}s "
          f"(epoch={loaded.epoch})")
    if getattr(loaded, "recovery", None):
        rec = loaded.recovery
        print(f"[recover] quarantined {rec['n_segments_dropped']} damaged "
              f"segment(s) from {rec['file']} onward "
              f"({rec['n_rows_dropped']} rows dropped, "
              f"{rec['n_rows_served']} served): {rec['reason']}")

    mesh = None
    if args.shards > 1:
        from jax.sharding import Mesh
        if jax.device_count() < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs that many devices, have "
                f"{jax.device_count()} (XLA_FLAGS was already set?)")
        # mesh sized by --shards (== the index's persisted n_shards), not
        # by whatever the process happens to expose
        mesh = Mesh(np.array(jax.devices()[:args.shards]), ("data",))

    ref_seqs = (data["ref_ids"], data["ref_lens"])
    scfg = ServingConfig(k=args.k, max_batch=args.batch, rerank=args.rerank,
                         dp_kernel=args.dp_kernel, gap_mode=args.gap_mode,
                         gap_open=args.gap_open, gap_extend=args.gap_extend)

    if args.replicas >= 1:
        _serve_async(args, data, loaded, mesh, ref_seqs, scfg, path)
        if args.index is None:
            import shutil
            shutil.rmtree(tmp_dir, ignore_errors=True)
        return

    sharded = None
    if mesh is not None:
        sharded = ShardedIndex(loaded, mesh)
        part = sharded._part
        print(f"[shard] {int(part.n_buckets.sum())} buckets over "
              f"{sharded.n_shards} devices (per-shard buckets "
              f"{part.n_buckets.tolist()}, entries {part.n_entries.tolist()})")
    engine = QueryEngine(loaded, scfg, sharded=sharded, ref_seqs=ref_seqs)
    mode = "sharded-probe" if sharded is not None else engine._mode()
    print(f"[mode]  {mode} serving (probe candidates are exact within "
          f"Hamming d={args.d}; the dense path ranks ALL refs — raise --d "
          f"for deeper top-k recall under probe/sharded serving)")
    # warm-up: every (rung, length-quantum) serving shape, pre-traffic
    engine.warmup(data["query_ids"], data["query_lens"])

    # ---- grow the live index (append-only segment + delta refresh)
    if args.add_fasta:
        from ..data.fasta import load_fasta_encoded
        names, new_ids, new_lens = load_fasta_encoded(args.add_fasta)
        t0 = time.time()
        loaded.add(new_ids, new_lens)
        n_written = loaded.save(path)       # appends ONLY the new segment
        t_add = time.time() - t0
        if args.rerank:                     # re-rank gather needs the rows
            L = max(ref_seqs[0].shape[1], new_ids.shape[1])
            grown = np.full((loaded.size, L), PAD, np.int8)
            grown[:len(ref_seqs[1]), :ref_seqs[0].shape[1]] = ref_seqs[0]
            grown[len(ref_seqs[1]):, :new_ids.shape[1]] = new_ids
            engine.ref_seqs = (grown, np.concatenate(
                [np.asarray(ref_seqs[1], np.int32),
                 np.asarray(new_lens, np.int32)]))
        print(f"[add]   +{len(new_lens)} refs from {args.add_fasta} -> "
              f"epoch {loaded.epoch} ({n_written} segment file(s) appended, "
              f"{t_add:.2f}s); serving replica will ingest the delta on "
              f"its next batch (no reload)")
        t0 = time.time()
        engine.query_batch(data["query_ids"][:args.batch],
                           data["query_lens"][:args.batch])
        if sharded is not None:
            print(f"[add]   delta refresh + first batch {time.time()-t0:.2f}s "
                  f"(replica epochs base={sharded.epoch[0]} "
                  f"delta={sharded.epoch[1]})")
    engine.reset_stats()        # warm-up/ingest batches aren't traffic

    qids, qlens = data["query_ids"], data["query_lens"]
    hits = 0
    t0 = time.time()
    for i in range(0, len(qlens), args.batch):
        nid, nd = engine.query_batch(qids[i:i + args.batch],
                                     qlens[i:i + args.batch])
        for j, (parent, _rate) in enumerate(data["truth"][i:i + args.batch]):
            if parent >= 0 and parent in set(nid[j][nid[j] >= 0]):
                hits += 1
    wall = time.time() - t0
    s = engine.stats()
    n_hom = sum(1 for p, _ in data["truth"] if p >= 0)
    print(f"[serve] {s['n_queries']} queries in {wall:.2f}s — "
          f"{s['qps']:.0f} q/s, p50={s['p50_ms']:.1f}ms "
          f"p95={s['p95_ms']:.1f}ms (batch={args.batch}, k={args.k}"
          f"{', rerank' if args.rerank else ''}, "
          f"epoch={s['index_epoch']})")
    print(f"[quality] planted homologs in top-{args.k}: "
          f"{hits}/{n_hom} ({hits/max(n_hom,1):.0%})")

    # ---- explicit compaction (the reduce step; results must not move)
    if args.compact:
        before = engine.query_batch(qids[:args.batch], qlens[:args.batch])
        t0 = time.time()
        loaded.compact()
        n_written = loaded.save(path)
        if sharded is not None:
            sharded.compact()
        after = engine.query_batch(qids[:args.batch], qlens[:args.batch])
        same = (np.array_equal(before[0], after[0])
                and np.array_equal(before[1], after[1]))
        print(f"[compact] {time.time()-t0:.2f}s -> epoch {loaded.epoch} "
              f"({n_written} file(s) rewritten); probe results "
              f"{'identical' if same else 'DIVERGED (BUG)'} across "
              f"compaction")
        if not same:
            raise SystemExit(1)

    _dump_obs(args)
    if args.index is None:
        import shutil
        shutil.rmtree(tmp_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
