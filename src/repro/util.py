"""Small compat utilities."""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (check_rep -> check_vma rename)."""
    try:
        from jax import shard_map as sm
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
