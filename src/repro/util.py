"""Small compat utilities."""
from __future__ import annotations

import jax


def next_pow2(x: int) -> int:
    """Smallest power of two >= x; 0 stays 0 (callers wanting a nonzero
    floor clamp first — buffer/capacity quantization shared by the
    self-join emission caps and the serving delta slabs)."""
    return 1 << (int(x) - 1).bit_length() if x > 0 else 0


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (check_rep -> check_vma rename)."""
    try:
        from jax import shard_map as sm
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
