"""Tiled pair scheduler: candidate pairs -> fixed-shape batched SW waves.

At corpus scale the candidate set of the self-join is far too ragged to
score naively: pair lengths vary, and per-pair DP calls retrace the jit
cache for every new (Lq, Lr) and leave the device idle between dispatches.
The scheduler imposes structure in three steps:

1. **(tile_i, tile_j) blocks** — pairs are grouped by the corpus tile of
   each endpoint (tile size ~ device-memory budget for gathered sequences),
   and blocks are walked in order, so the working set of gathered rows is
   bounded by two tiles regardless of corpus size.
2. **length buckets** — within a block, pairs are bucketed by their padded
   (Lq, Lr) on a quantized ladder (same idea as ``QueryEngine``'s padding
   ladder: a small, closed set of shapes keeps the jit cache stable).
3. **waves** — each bucket is chunked into fixed-size (B, Lq, Lr) pair
   blocks, padded with all-PAD rows (which score 0 and are discarded), and
   dispatched as one jitted Smith-Waterman row-wave program — optionally the
   Pallas tile kernel (``use_pallas=True``).

Scores (and optionally PID via the batched wave + host traceback) come back
aligned with the input pair order.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..align.smith_waterman import sw_align_batch, sw_wave_pid
from ..core.alphabet import PAD


@dataclass(frozen=True)
class WaveConfig:
    tile: int = 1024             # corpus rows per (tile_i, tile_j) block
    wave_batch: int = 64         # pairs per SW wave (upper bound)
    len_quantum: int = 64        # pad pair lengths to multiples of this
    max_wave_cells: int = 1 << 23  # B*Lq*Lr budget; shrinks B for long pairs
    use_pallas: bool = False     # score-only waves via the Pallas tile
                                 # kernel (ignored when with_pid is set —
                                 # the PID traceback needs the DP matrices,
                                 # which only the jnp wave materializes)
    with_pid: bool = False       # also run the batched PID traceback


@dataclass(frozen=True)
class PairScores:
    scores: np.ndarray           # (P,) int32 SW best score per input pair
    pid: np.ndarray | None       # (P,) float64 percent identity (with_pid)
    aln_len: np.ndarray | None   # (P,) int64 alignment length (with_pid)
    n_waves: int                 # jitted dispatches issued
    n_shapes: int                # distinct (B, Lq, Lr) wave shapes compiled


def _quantize(lens: np.ndarray, quantum: int) -> np.ndarray:
    return np.maximum(quantum, -(-lens // quantum) * quantum)


def wave_plan(pairs: np.ndarray, lens: np.ndarray, cfg: WaveConfig):
    """Group pair indices into dispatch order: (tile_i, tile_j) block, then
    padded-length bucket. Yields (pair_idx (m,), Lq_pad, Lr_pad) with
    pair_idx referring to rows of ``pairs``."""
    if len(pairs) == 0:
        return
    ti = pairs[:, 0] // cfg.tile
    tj = pairs[:, 1] // cfg.tile
    lq = _quantize(lens[pairs[:, 0]], cfg.len_quantum)
    lr = _quantize(lens[pairs[:, 1]], cfg.len_quantum)
    # dispatch key: block-major, then shape; lexsort is stable so pairs stay
    # in input order within a wave
    order = np.lexsort((lr, lq, tj, ti))
    keys = np.stack([ti[order], tj[order], lq[order], lr[order]], axis=1)
    starts = np.flatnonzero(
        np.concatenate([[True], (np.diff(keys, axis=0) != 0).any(axis=1)]))
    bounds = np.concatenate([starts, [len(order)]])
    for s, e in zip(bounds[:-1], bounds[1:]):
        yield order[s:e], int(keys[s, 2]), int(keys[s, 3])


def score_pairs(ids: np.ndarray, lens: np.ndarray, pairs: np.ndarray,
                cfg: WaveConfig | None = None) -> PairScores:
    """Score every (i, j) candidate pair with batched Smith-Waterman waves.

    ids (N, L) int8 PAD-padded corpus, lens (N,), pairs (P, 2) int32.
    Returns scores (and PID when ``cfg.with_pid``) aligned with ``pairs``.
    """
    cfg = cfg or WaveConfig()
    pairs = np.asarray(pairs, np.int32)
    lens = np.asarray(lens, np.int32)
    P = len(pairs)
    scores = np.zeros(P, np.int32)
    pid = np.zeros(P) if cfg.with_pid else None
    aln = np.zeros(P, np.int64) if cfg.with_pid else None
    n_waves = 0
    shapes: set[tuple[int, int, int]] = set()
    for idx, Lq, Lr in wave_plan(pairs, lens, cfg):
        # shrink the wave batch so B*Lq*Lr respects the cell budget
        B = max(1, min(cfg.wave_batch, cfg.max_wave_cells // (Lq * Lr)))
        for s in range(0, len(idx), B):
            chunk = idx[s:s + B]
            qm = np.full((B, Lq), PAD, np.int8)
            rm = np.full((B, Lr), PAD, np.int8)
            for n, p in enumerate(chunk):
                i, j = pairs[p]
                qm[n, :lens[i]] = ids[i, :lens[i]]
                rm[n, :lens[j]] = ids[j, :lens[j]]
            if cfg.with_pid:
                pw, lw, sw = sw_wave_pid(qm, rm, chunk=B)
                pid[chunk] = pw[:len(chunk)]
                aln[chunk] = lw[:len(chunk)]
                scores[chunk] = sw[:len(chunk)]
            elif cfg.use_pallas:
                from ..kernels import ops
                sw = np.asarray(ops.sw_wave_scores(qm, rm))
                scores[chunk] = sw[:len(chunk)]
            else:
                sw = sw_align_batch(qm, rm)
                scores[chunk] = sw[:len(chunk)]
            n_waves += 1
            shapes.add((B, Lq, Lr))
    return PairScores(scores=scores, pid=pid, aln_len=aln,
                      n_waves=n_waves, n_shapes=len(shapes))
