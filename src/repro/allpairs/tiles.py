"""Tiled pair scheduler: candidate pairs -> device-resident batched SW waves.

At corpus scale the candidate set of the self-join is far too ragged to
score naively: pair lengths vary, per-pair DP calls retrace the jit cache
for every new (Lq, Lr), and any host work between dispatches leaves the
device idle. The scheduler imposes structure — and keeps the whole hot path
on device:

1. **(tile_i, tile_j) blocks** — pairs are grouped by the corpus tile of
   each endpoint (tile size ~ device-memory budget for gathered sequences),
   and blocks are walked in order, so the working set of gathered rows is
   bounded by two tiles regardless of corpus size.
2. **length buckets** — within a block, pairs are bucketed by their padded
   (Lq, Lr) on a quantized ladder (same idea as ``QueryEngine``'s padding
   ladder: a small, closed set of shapes keeps the jit cache stable).
3. **fused device gather** — the padded corpus ``(N, Lmax)`` is uploaded
   ONCE; each wave is one jitted take-and-mask program over pair index
   arrays (``ids[pair_idx, :Lq]``), so the only per-wave H2D traffic is the
   (B,) index vectors — no per-pair host copy loop
   (``device_gather=False`` restores the PR 2 host path, bit-exact).
4. **ungapped X-drop prefilter** (``prefilter=True``) — every wave first
   runs a cheap ungapped diagonal scan (BLAST-style X-drop extension, an
   elementwise DP with no within-row prefix scan); only pairs whose
   ungapped score reaches ``prefilter_min`` proceed to the full gapped
   wave. The ungapped score is a *lower bound* of the SW score, so the
   filter never adds pairs; rejected pairs report their ungapped score
   (``kept`` marks the survivors, whose scores are full SW, bit-exact).
5. **async double-buffered dispatch** — wave n+1's gather+DP is issued
   while wave n's scores are still in flight; a small FIFO ring
   (``inflight``) drains ``device_get`` results, so wall-clock tracks
   device DP time instead of Python dispatch.
6. **multi-device waves** (``n_devices > 1``) — each wave batch is split
   over the first ``n_devices`` of ``jax.devices()`` as ONE SPMD program
   (``shard_map``: pair index vectors partitioned, corpus replicated), so
   ``n_devices`` pair blocks gather+score concurrently per dispatch — the
   reduce-side join of the sharded self-join run on the reducers
   themselves. SPMD (not per-device round-robin dispatch) is load-bearing:
   the CPU PJRT client serializes independent per-device executions, and
   only partitions *inside* one program run on parallel threads; on
   accelerator meshes the same program overlaps the usual way. Pairs are
   embarrassingly parallel, so the split is bit-exact by construction.

    pairs ──wave_plan──▶ [gather ▶ prefilter ▶ full SW] ──▶ drain ring
                           (one jitted program per wave shape,
                            split P("wave") over n_devices)

Scores (and optionally PID via the batched wave + host traceback) come back
aligned with the input pair order.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..align.smith_waterman import (gather_rows, sw_gather_scores,
                                    sw_scores_device, sw_wave_pid,
                                    ungapped_xdrop_scores)
from ..core.alphabet import PAD
from ..kernels.sw import on_tpu
from ..obs import span, trace_sentinel
from ..obs.trace import record as record_span


@dataclass(frozen=True)
class WaveConfig:
    tile: int = 1024             # corpus rows per (tile_i, tile_j) block
    wave_batch: int = 64         # pairs per full-SW wave (upper bound)
    len_quantum: int = 64        # pad pair lengths to multiples of this
    max_wave_cells: int = 1 << 23  # B*Lq*Lr budget; shrinks B for long pairs
    device_gather: bool = True   # fused on-device wave gather (False: PR 2
                                 # host copy loop, bit-exact, for comparison)
    inflight: int = 2            # async ring depth: waves in flight before
                                 # the oldest result is drained to host
    n_devices: int = 1           # split each wave over this many devices
                                 # as one SPMD shard_map program (clamped
                                 # to jax.device_count(); needs
                                 # device_gather; wave_batch becomes the
                                 # PER-DEVICE batch). Ignored by the
                                 # Pallas and PID paths (kernel resp. host
                                 # traceback stay single-device).
    dp_kernel: str = "wavefront"  # score-only DP sweep: "wavefront" (the
                                 # anti-diagonal Gotoh sweep of
                                 # `align.gotoh`, ~2.8x on CPU) or
                                 # "rowwave" (the int32 prefix-scan row
                                 # wave, linear-gap fallback). The PID
                                 # path always uses the row wave (its
                                 # traceback needs the DP matrix).
    gap_mode: str = "linear"     # gap model: "linear" (GAP = -4, both
                                 # kernels, scores bit-exact across them)
                                 # or "affine" (Gotoh open/extend,
                                 # wavefront only)
    gap_open: int | None = None  # None -> GAP (linear) / -11 (affine)
    gap_extend: int | None = None  # None -> -1; affine only
    prefilter: bool = False      # ungapped X-drop prefilter before full SW
    prefilter_min: int = 40      # skip full SW below this ungapped score
    xdrop: int | None = None     # X-drop termination margin; None is the
                                 # x->inf limit (plain best ungapped
                                 # segment): max recall AND fastest (the
                                 # run-best carry drops out of the scan)
    prefilter_batch: int = 256   # pairs per prefilter wave (the ungapped
                                 # scan is elementwise, so it batches wider)
    use_pallas: bool | None = None  # route score-only waves through the
                                 # Pallas tile kernel; None = auto (TPU
                                 # only — interpret mode is slower than the
                                 # jnp wave off-TPU). Ignored with with_pid
                                 # (the PID traceback needs the DP matrices,
                                 # which only the jnp wave materializes).
    pallas_interpret: bool | None = None  # kernel interpret override
                                 # (None = autodetect by backend)
    with_pid: bool = False       # also run the batched PID traceback
    profile: bool = False        # block after each phase for an accurate
                                 # gather/DP/drain time split (slower)


@dataclass(frozen=True)
class PairScores:
    scores: np.ndarray           # (P,) int32 SW best score per input pair
                                 # (prefilter-rejected pairs: ungapped score,
                                 # a lower bound — see ``kept``)
    pid: np.ndarray | None       # (P,) float64 percent identity (with_pid)
    aln_len: np.ndarray | None   # (P,) int64 alignment length (with_pid)
    n_waves: int                 # jitted dispatches issued (incl. prefilter)
    n_shapes: int                # distinct wave shapes compiled
    ungapped: np.ndarray | None = None  # (P,) int32 prefilter scores
    kept: np.ndarray | None = None      # (P,) bool — pair ran full SW
    timings: dict | None = None  # coarse phase seconds: host_gather,
                                 # dispatch (gather/DP issue), drain,
                                 # prefilter, pid_wave (device DP + H
                                 # transfer + host traceback combined)

    @property
    def n_prefiltered(self) -> int:
        return 0 if self.kept is None else int((~self.kept).sum())


def _quantize(lens: np.ndarray, quantum: int) -> np.ndarray:
    return np.maximum(quantum, -(-lens // quantum) * quantum)


def wave_plan(pairs: np.ndarray, lens: np.ndarray, cfg: WaveConfig):
    """Group pair indices into dispatch order: (tile_i, tile_j) block, then
    padded-length bucket. Yields (pair_idx (m,), Lq_pad, Lr_pad) with
    pair_idx referring to rows of ``pairs``."""
    if len(pairs) == 0:
        return
    ti = pairs[:, 0] // cfg.tile
    tj = pairs[:, 1] // cfg.tile
    lq = _quantize(lens[pairs[:, 0]], cfg.len_quantum)
    lr = _quantize(lens[pairs[:, 1]], cfg.len_quantum)
    # dispatch key: block-major, then shape; lexsort is stable so pairs stay
    # in input order within a wave
    order = np.lexsort((lr, lq, tj, ti))
    keys = np.stack([ti[order], tj[order], lq[order], lr[order]], axis=1)
    starts = np.flatnonzero(
        np.concatenate([[True], (np.diff(keys, axis=0) != 0).any(axis=1)]))
    bounds = np.concatenate([starts, [len(order)]])
    for s, e in zip(bounds[:-1], bounds[1:]):
        yield order[s:e], int(keys[s, 2]), int(keys[s, 3])


# ---------------------------------------------------------------- device side
@functools.partial(jax.jit, static_argnames=("Lq", "Lr"))
@trace_sentinel("wave_gather")
def _gather_wave(ids_dev, lens_dev, pi, pj, *, Lq: int, Lr: int):
    return (gather_rows(ids_dev, lens_dev, pi, Lq),
            gather_rows(ids_dev, lens_dev, pj, Lr))


@functools.partial(jax.jit, static_argnames=("x", "Lq", "Lr"))
@trace_sentinel("wave_ungapped")
def _wave_ungapped_device(ids_dev, lens_dev, pi, pj, *, x: int | None,
                          Lq: int, Lr: int):
    """Fused gather + ungapped X-drop prefilter scan."""
    qm, rm = _gather_wave(ids_dev, lens_dev, pi, pj, Lq=Lq, Lr=Lr)
    return ungapped_xdrop_scores(qm, rm, x=x)


@functools.lru_cache(maxsize=8)
def _sharded_wave_fns(devices: tuple):
    """SPMD wave programs over ``devices``: the (B,) pair index vectors
    split ``P("wave")`` (B a multiple of len(devices)), the corpus
    replicates, and every device gathers+scores its share of pairs inside
    ONE jitted program — the only dispatch form the CPU PJRT client
    actually runs concurrently. Per-pair results are independent, so the
    split is bit-exact with the single-device wave.

    Cached by the DEVICE TUPLE — the same keying discipline as the
    self-join emission and the serving ring (PR 5): device objects are
    per-process singletons, so every caller resolving the same devices —
    across fresh ``WaveConfig`` instances, fresh meshes, repeated
    ``score_pairs`` calls — shares one compiled program pair (cache
    stability pinned in tests/test_sharding.py). The previous key was the
    bare device *count*, which happened to coincide but broke the
    discipline (and would silently recompile nothing while masking a
    wrong-devices bug if callers ever passed a different prefix)."""
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from ..util import shard_map_compat
    mesh = Mesh(np.array(devices), ("wave",))
    ax = "wave"

    @functools.partial(jax.jit, static_argnames=(
        "Lq", "Lr", "dp_kernel", "gap_mode", "gap_open", "gap_extend"))
    @trace_sentinel("wave_sw_spmd", static_key=(devices,))
    def sw_fn(ids_dev, lens_dev, pi, pj, *, Lq: int, Lr: int,
              dp_kernel: str = "wavefront", gap_mode: str = "linear",
              gap_open: int | None = None, gap_extend: int | None = None):
        f = shard_map_compat(
            lambda i, l, a, b: sw_gather_scores(
                i, l, i, l, a, b, Lq=Lq, Lr=Lr, dp_kernel=dp_kernel,
                gap_mode=gap_mode, gap_open=gap_open,
                gap_extend=gap_extend),
            mesh, in_specs=(P(), P(), P(ax), P(ax)), out_specs=P(ax))
        return f(ids_dev, lens_dev, pi, pj)

    @functools.partial(jax.jit, static_argnames=("x", "Lq", "Lr"))
    @trace_sentinel("wave_ungapped_spmd", static_key=(devices,))
    def ungapped_fn(ids_dev, lens_dev, pi, pj, *, x: int | None,
                    Lq: int, Lr: int):
        f = shard_map_compat(
            lambda i, l, a, b: ungapped_xdrop_scores(
                gather_rows(i, l, a, Lq), gather_rows(i, l, b, Lr), x=x),
            mesh, in_specs=(P(), P(), P(ax), P(ax)), out_specs=P(ax))
        return f(ids_dev, lens_dev, pi, pj)

    return sw_fn, ungapped_fn


class _DrainRing:
    """FIFO of in-flight device results. JAX dispatch is async: pushing wave
    n+1 before fetching wave n overlaps its gather+DP with wave n's D2H
    transfer; only when the ring exceeds ``depth`` does the oldest result
    block on ``np.asarray`` (device_get)."""

    def __init__(self, depth: int, sink):
        self.depth = max(0, depth)
        self.sink = sink                # sink(slots, host_values)
        self._q: deque = deque()

    def push(self, slots, dev) -> None:
        self._q.append((slots, dev))
        while len(self._q) > self.depth:
            self._pop()

    def _pop(self) -> None:
        slots, dev = self._q.popleft()
        self.sink(slots, np.asarray(dev))

    def drain(self) -> None:
        while self._q:
            self._pop()


# ---------------------------------------------------------------- scheduler
class _WaveStats:
    def __init__(self):
        self.n_waves = 0
        self.shapes: set = set()
        self.t = {"host_gather": 0.0, "dispatch": 0.0, "drain": 0.0,
                  "prefilter": 0.0, "pid_wave": 0.0}


def _host_gather(ids, lens, pairs, chunk, B, Lq, Lr):
    """PR 2 path: assemble the wave with a per-pair host copy loop."""
    qm = np.full((B, Lq), PAD, np.int8)
    rm = np.full((B, Lr), PAD, np.int8)
    for n, p in enumerate(chunk):
        i, j = pairs[p]
        qm[n, :lens[i]] = ids[i, :lens[i]]
        rm[n, :lens[j]] = ids[j, :lens[j]]
    return qm, rm


def _pad_chunk(pairs, chunk, B):
    """Pair index vectors for one wave, -1-padded to the fixed batch B."""
    pi = np.full(B, -1, np.int32)
    pj = np.full(B, -1, np.int32)
    pi[:len(chunk)] = pairs[chunk, 0]
    pj[:len(chunk)] = pairs[chunk, 1]
    return pi, pj


def _score_block(qm, rm, kind: str, x: int | None, use_pallas: bool,
                 cfg: WaveConfig):
    """Score one assembled (B, Lq) x (B, Lr) block on device, routed by
    ``cfg.dp_kernel`` / ``cfg.gap_mode`` (see WaveConfig)."""
    if use_pallas:
        from ..kernels import ops
        if kind == "ungapped":
            return ops.ungapped_wave_scores(
                qm, rm, x=2**30 if x is None else x,
                interpret=cfg.pallas_interpret)
        if cfg.dp_kernel == "wavefront":
            return ops.wavefront_scores(
                qm, rm, gap_mode=cfg.gap_mode, gap_open=cfg.gap_open,
                gap_extend=cfg.gap_extend, interpret=cfg.pallas_interpret)
        return ops.sw_wave_scores(qm, rm, interpret=cfg.pallas_interpret)
    if kind == "ungapped":
        return ungapped_xdrop_scores(qm, rm, x=x)
    if cfg.dp_kernel == "wavefront":
        from ..align.gotoh import sw_wave_affine, sw_wave_linear
        if cfg.gap_mode == "affine":
            kw = {} if cfg.gap_open is None else {"gap_open": cfg.gap_open}
            if cfg.gap_extend is not None:
                kw["gap_extend"] = cfg.gap_extend
            return sw_wave_affine(qm, rm, **kw)
        if cfg.gap_open is None:
            return sw_wave_linear(qm, rm)
        return sw_wave_linear(qm, rm, gap=cfg.gap_open)
    return sw_scores_device(jnp.asarray(qm), jnp.asarray(rm))


def _iter_wave_chunks(sub, lens, cfg: WaveConfig, wave_batch: int,
                      ndev: int = 1):
    """Shared wave-chunking skeleton: walk the dispatch plan, shrink the
    batch to the cell budget, and yield fixed-shape (chunk, B, Lq, Lr)
    work units (the last chunk of a bucket may be shorter than B — the
    dispatchers pad it). Single source of truth for the score and PID
    paths, so wave shapes can never diverge between them. ``wave_batch``
    and the cell budget are per-device: an SPMD wave (``ndev > 1``)
    carries ndev times the pairs per dispatch."""
    for idx, Lq, Lr in wave_plan(sub, lens, cfg):
        B = max(1, min(wave_batch, cfg.max_wave_cells // (Lq * Lr))) * ndev
        for s in range(0, len(idx), B):
            yield idx[s:s + B], B, Lq, Lr


def _run_score_waves(ids, lens, pairs, subset, cfg: WaveConfig, dev, out,
                     stats: _WaveStats, *, kind: str, wave_batch: int,
                     use_pallas: bool, ndev: int = 1) -> None:
    """Dispatch score-only waves (``kind``: "sw" | "ungapped") over
    ``pairs[subset]``, writing results into ``out[subset[...]]`` through
    the async drain ring. With ``ndev > 1`` each wave is one SPMD program
    splitting its batch over the mesh (``_sharded_wave_fns``)."""
    sub = pairs[subset]

    def sink(slots, host):
        out[slots] = host[:len(slots)]

    sharded = (_sharded_wave_fns(tuple(jax.devices()[:ndev]))
               if ndev > 1 else None)
    ring = _DrainRing(0 if cfg.profile else cfg.inflight, sink)
    for chunk, B, Lq, Lr in _iter_wave_chunks(sub, lens, cfg, wave_batch,
                                              ndev):
        t0 = time.perf_counter()
        if dev is None:                     # host-gather (PR 2) path
            qm, rm = _host_gather(ids, lens, sub, chunk, B, Lq, Lr)
            t1 = time.perf_counter()
            stats.t["host_gather"] += t1 - t0
            record_span("host_gather", t0, t1, cat="allpairs",
                        B=B, n=len(chunk))
            t0 = time.perf_counter()
            res = _score_block(qm, rm, kind, cfg.xdrop, use_pallas, cfg)
        elif use_pallas:                    # device gather -> Pallas tile
            pi, pj = _pad_chunk(sub, chunk, B)
            qm, rm = _gather_wave(dev[0], dev[1], jnp.asarray(pi),
                                  jnp.asarray(pj), Lq=Lq, Lr=Lr)
            res = _score_block(qm, rm, kind, cfg.xdrop, True, cfg)
        elif sharded is not None:           # SPMD split over the mesh
            pi, pj = _pad_chunk(sub, chunk, B)
            sw_fn, ungapped_fn = sharded
            if kind == "ungapped":
                res = ungapped_fn(dev[0], dev[1], pi, pj, x=cfg.xdrop,
                                  Lq=Lq, Lr=Lr)
            else:
                res = sw_fn(dev[0], dev[1], pi, pj, Lq=Lq, Lr=Lr,
                            dp_kernel=cfg.dp_kernel, gap_mode=cfg.gap_mode,
                            gap_open=cfg.gap_open,
                            gap_extend=cfg.gap_extend)
        elif kind == "ungapped":            # fused gather + scan
            pi, pj = _pad_chunk(sub, chunk, B)
            res = _wave_ungapped_device(dev[0], dev[1], pi, pj,
                                        x=cfg.xdrop, Lq=Lq, Lr=Lr)
        else:
            pi, pj = _pad_chunk(sub, chunk, B)
            res = sw_gather_scores(dev[0], dev[1], dev[0], dev[1],
                                   pi, pj, Lq=Lq, Lr=Lr,
                                   dp_kernel=cfg.dp_kernel,
                                   gap_mode=cfg.gap_mode,
                                   gap_open=cfg.gap_open,
                                   gap_extend=cfg.gap_extend)
        if cfg.profile:
            jax.block_until_ready(res)
        key = "prefilter" if kind == "ungapped" else "dispatch"
        t1 = time.perf_counter()
        stats.t[key] += t1 - t0
        # dispatch-side duration: device time hides in the drain unless
        # cfg.profile blocks per wave
        record_span("wave", t0, t1, cat="allpairs", kind=kind, B=B,
                    Lq=Lq, Lr=Lr, n=len(chunk), spmd=ndev > 1)
        t0 = time.perf_counter()
        ring.push(subset[chunk], res)
        stats.t["drain"] += time.perf_counter() - t0
        stats.n_waves += 1
        stats.shapes.add((kind, B, Lq, Lr))
    t0 = time.perf_counter()
    ring.drain()
    stats.t["drain"] += time.perf_counter() - t0


def _run_pid_waves(ids, lens, pairs, subset, cfg: WaveConfig, dev,
                   scores, pid, aln, stats: _WaveStats) -> None:
    """PID waves: batched DP (+ matrices) then the host traceback. The
    traceback is host-bound either way, so this path drains synchronously;
    the device gather still removes the per-pair copy loop."""
    sub = pairs[subset]
    for chunk, B, Lq, Lr in _iter_wave_chunks(sub, lens, cfg,
                                              cfg.wave_batch):
        t0 = time.perf_counter()
        if dev is None:
            qm, rm = _host_gather(ids, lens, sub, chunk, B, Lq, Lr)
            stats.t["host_gather"] += time.perf_counter() - t0
        else:
            pi, pj = _pad_chunk(sub, chunk, B)
            qmd, rmd = _gather_wave(dev[0], dev[1], jnp.asarray(pi),
                                    jnp.asarray(pj), Lq=Lq, Lr=Lr)
            qm, rm = np.asarray(qmd), np.asarray(rmd)
            stats.t["dispatch"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        pw, lw, sw = sw_wave_pid(qm, rm, chunk=B)
        # one bucket for the whole PID wave: device DP + H-matrix D2H +
        # host traceback (sw_wave_pid interleaves them internally)
        t1 = time.perf_counter()
        stats.t["pid_wave"] += t1 - t0
        record_span("wave", t0, t1, cat="allpairs", kind="pid", B=B,
                    Lq=Lq, Lr=Lr, n=len(chunk))
        slots = subset[chunk]
        pid[slots] = pw[:len(chunk)]
        aln[slots] = lw[:len(chunk)]
        scores[slots] = sw[:len(chunk)]
        stats.n_waves += 1
        stats.shapes.add(("pid", B, Lq, Lr))


def score_pairs(ids: np.ndarray, lens: np.ndarray, pairs: np.ndarray,
                cfg: WaveConfig | None = None) -> PairScores:
    """Score every (i, j) candidate pair with batched Smith-Waterman waves.

    ids (N, L) int8 PAD-padded corpus, lens (N,), pairs (P, 2) int32.
    Returns scores (and PID when ``cfg.with_pid``) aligned with ``pairs``.
    With ``cfg.prefilter`` the ungapped X-drop scan runs first and only
    survivors (``result.kept``) pay the full DP — their scores are bit-exact
    with the unfiltered path; rejected pairs report the ungapped lower
    bound (and PID 0).
    """
    cfg = cfg or WaveConfig()
    if cfg.dp_kernel not in ("wavefront", "rowwave"):
        raise ValueError(f"unknown dp_kernel {cfg.dp_kernel!r}")
    if cfg.gap_mode not in ("linear", "affine"):
        raise ValueError(f"unknown gap_mode {cfg.gap_mode!r}")
    if cfg.gap_mode == "affine":
        if cfg.dp_kernel == "rowwave":
            raise ValueError("affine gaps need dp_kernel='wavefront'")
        if cfg.with_pid:
            raise ValueError("with_pid needs gap_mode='linear' (the PID "
                             "traceback reads the linear-gap DP matrix)")
    pairs = np.asarray(pairs, np.int32)
    lens = np.asarray(lens, np.int32)
    P = len(pairs)
    t_all = time.perf_counter()
    scores = np.zeros(P, np.int32)
    pid = np.zeros(P) if cfg.with_pid else None
    aln = np.zeros(P, np.int64) if cfg.with_pid else None
    stats = _WaveStats()
    use_pallas = (cfg.use_pallas if cfg.use_pallas is not None
                  else (on_tpu() and not cfg.with_pid))
    dev = ((jnp.asarray(ids), jnp.asarray(lens))
           if cfg.device_gather and P else None)
    # SPMD wave split: only the jnp score/prefilter waves shard (the Pallas
    # kernel and the PID traceback stay single-device)
    ndev = 1
    if dev is not None and not use_pallas:
        ndev = max(1, min(cfg.n_devices, jax.device_count()))

    everything = np.arange(P)
    ungapped = None
    kept = None
    subset = everything
    if cfg.prefilter and P:
        ungapped = np.zeros(P, np.int32)
        _run_score_waves(ids, lens, pairs, everything, cfg, dev, ungapped,
                         stats, kind="ungapped",
                         wave_batch=cfg.prefilter_batch,
                         use_pallas=use_pallas, ndev=ndev)
        kept = ungapped >= cfg.prefilter_min
        scores[:] = ungapped        # lower bound for the rejected pairs
        subset = np.flatnonzero(kept)
    if len(subset):
        if cfg.with_pid:
            _run_pid_waves(ids, lens, pairs, subset, cfg, dev,
                           scores, pid, aln, stats)
        else:
            _run_score_waves(ids, lens, pairs, subset, cfg, dev, scores,
                             stats, kind="sw", wave_batch=cfg.wave_batch,
                             use_pallas=use_pallas, ndev=ndev)
    record_span("score_pairs", t_all, time.perf_counter(), cat="allpairs",
                pairs=P, waves=stats.n_waves, shapes=len(stats.shapes),
                prefiltered=0 if kept is None else int((~kept).sum()))
    return PairScores(scores=scores, pid=pid, aln_len=aln,
                      n_waves=stats.n_waves, n_shapes=len(stats.shapes),
                      ungapped=ungapped, kept=kept,
                      timings=dict(stats.t))
