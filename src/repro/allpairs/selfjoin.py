"""LSH self-join: the corpus joined against itself via the index's buckets.

The many-against-many candidate generator (PASTIS-style similarity graphs):
instead of probing queries against reference buckets, every bucket of the
:class:`~repro.index.store.SignatureIndex` emits its own within-bucket pairs.
A bucket of m members contributes m*(m-1)/2 unordered pairs; pairs colliding
in several bands are deduplicated; the result is the *exact* set of LSH band
collisions — upper-triangular (i < j), only valid (non-zero-signature)
sequences, identical to brute-force enumeration of per-band key equality.
The pigeonhole guarantee carries over: any pair within Hamming distance d of
each other shares >= 1 band, so filtering candidates by packed Hamming
distance (``d=``) yields the exact d-neighborhood graph.

Candidate emission is the masked SpGEMM primitive of
:mod:`repro.index.spgemm` — each bucket slab is the CSR of a
sequence×bucket incidence matrix ``A``, the self-join is the strict upper
triangle of ``AᵀA``, and the delta join is the ``Aᵀ_delta · A_resident``
cross mask (resident×resident never forms). Two orchestrations share those
products behind ``join_impl=``:

* ``"spgemm"`` (default) — the fused path: per-band products, cross-band
  dedup, the optional exact Hamming filter, and survivor compaction run
  device-resident (one program when per-shard demand is uniform), the
  output capacity is sized at the exact emission total so the dedup can
  never overflow (no grow-and-retry), and the fused prefilter consumes
  the pair buffer in place — the join pays ONE host sync (the count).
* ``"legacy"`` — the pre-SpGEMM orchestration (emission programs → host
  merge → separate dedup under grow-and-retry), kept for one PR as the
  bit-exactness reference; both paths produce IDENTICAL result arrays
  (the dedup output is the sorted unique pair set either way).

Emission runs over the shard-owned bucket slabs of
:class:`~repro.index.partition.BucketPartition` (``mix32(key) % n_shards``
— the MapReduce shuffle): with ``n_shards > 1`` each mesh device emits its
own buckets' pairs in parallel (``shard_map``; a vmap over the shard axis
when the process has fewer devices), and the per-shard buffers are merged
with the cross-shard/cross-band dedup. Buckets are never split across
shards, so the union of per-shard emissions is EXACTLY the single-device
pair set — the result arrays are bit-identical for every ``n_shards``.

Capacity is **skew-bounded**: each shard's emission buffer is sized at its
OWN per-(shard, band) within-bucket pair total (quantized to a power of
two to bound recompiles), so one degenerate bucket inflates one shard's
buffer, not every shard's. Uniform demand keeps the single SPMD
``shard_map`` program (one dispatch, the PR 4 lesson); skewed demand falls
back to per-shard emission with a ragged merge — the downstream dedup
lexsorts, so the pair arrays are identical either way.

Incremental growth joins incrementally too: :func:`lsh_delta_join` emits
only the pairs that touch rows appended after ``base_size`` — each new
segment's within-bucket pairs plus its cross pairs against every resident
segment's matching buckets — and, like the batch join, runs per shard
under the bucket partition (matching keys land on the same shard on both
sides of the cross mask, so the per-shard union is exact). The union of
the old pair set and the delta is EXACTLY the from-scratch self-join over
the grown corpus (any collision either has both rows resident, or its
later row lives in a new segment).

Emission reuses the fixed-capacity buffer discipline of ``core/join.py``
(rows past the count are -1; ``overflowed`` means rows were truncated), and
the legacy orchestration wraps dedup in the same grow-and-retry loop as the
serving layer — no silent caps.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..align.smith_waterman import gather_rows, ungapped_xdrop_scores
from ..core.join import PACKED_KEY_MAX_ID, compact_pairs
from ..index.partition import BucketPartition, pad_slabs_pow2
from ..index.spgemm import (spgemm_cross_slab, spgemm_join_self,
                            spgemm_join_self_keys, spgemm_pack,
                            spgemm_self_slab)
from ..index.store import SignatureIndex
from ..obs import span, trace_sentinel
from ..util import next_pow2, shard_map_compat

JOIN_IMPLS = ("spgemm", "legacy")


def _check_impl(join_impl: str) -> str:
    if join_impl not in JOIN_IMPLS:
        raise ValueError(f"unknown join_impl {join_impl!r} "
                         f"(expected one of {JOIN_IMPLS})")
    return join_impl


@functools.lru_cache(maxsize=16)
def _default_mesh(n: int, axis_name: str):
    """One mesh per shard count (a fresh Mesh per call would defeat the
    jit cache of every program built on it)."""
    return Mesh(np.array(jax.devices()[:n]), (axis_name,))


@functools.lru_cache(maxsize=64)
def _emit_sharded_cached(devices: tuple, axis_name: str, cap: int):
    """The jitted shard_map emission program, cached by the DEVICE TUPLE —
    never by a Mesh object. Device objects are per-process singletons, so
    two freshly constructed (equal) meshes resolve to the same program;
    keying by Mesh relied on Mesh equality semantics and a fresh Mesh per
    call could silently recompile (the PR 5 regression test pins this)."""
    ax = axis_name
    mesh = Mesh(np.array(devices), (ax,))

    @trace_sentinel("emission_spmd", static_key=(devices, cap))
    def shard_fn(offs, ids):
        return spgemm_self_slab(offs[0], ids[0], cap=cap)

    return jax.jit(shard_map_compat(
        shard_fn, mesh, in_specs=(P(ax), P(ax)), out_specs=P(ax)))


def _emit_sharded_fn(mesh, axis_name: str, cap: int):
    """Resolve a mesh to the cached SPMD emission program (identity-stable
    across equal meshes — see :func:`_emit_sharded_cached`)."""
    return _emit_sharded_cached(tuple(mesh.devices.flat), axis_name, cap)


@functools.lru_cache(maxsize=64)
def _emit_cross_sharded_cached(devices: tuple, axis_name: str, cap: int):
    """shard_map program for the per-shard delta×resident cross mask —
    cached by device tuple like :func:`_emit_sharded_cached`."""
    ax = axis_name
    mesh = Mesh(np.array(devices), (ax,))

    @trace_sentinel("delta_cross_spmd", static_key=(devices, cap))
    def shard_fn(dk, do, di, rk, ro, ri):
        return spgemm_cross_slab(dk[0], do[0], di[0], rk[0], ro[0], ri[0],
                                 cap=cap)

    return jax.jit(shard_map_compat(
        shard_fn, mesh, in_specs=(P(ax),) * 6, out_specs=P(ax)))


def _shard_caps(part: BucketPartition) -> np.ndarray:
    """(S,) int64 emission capacity per shard: its own max per-(shard,
    band) within-bucket pair total, quantized to the next power of two
    (bounds both recompiles and worst-case over-allocation at 2x true
    demand). Skew-bounding: a degenerate bucket inflates only its owning
    shard's cap."""
    if part.pair_totals.size == 0:
        return np.zeros(part.n_shards, np.int64)
    per_shard = part.pair_totals.max(axis=1)
    return np.array([next_pow2(int(c)) for c in per_shard], np.int64)


def _emit_partition(part: BucketPartition, caps: np.ndarray, mesh,
                    axis_name: str, *, to_host: bool = True):
    """Emit every shard's within-bucket pairs over the partition slabs;
    returns the merged (M, 2) candidate rows (-1 rows allowed — the
    downstream dedup drops them) — numpy when ``to_host`` (the legacy
    orchestration), a device array otherwise (the spgemm pack consumes it
    without a host round-trip).

    Uniform demand (all nonzero shard caps equal): ONE program — the
    ``shard_map`` SPMD emission on a mesh of ``part.n_shards`` devices, or
    a vmap over the shard axis on one device. Skewed demand: per-shard
    emission at each shard's own cap (placed on its owning mesh device
    when a mesh is given) with a ragged merge, so buffer memory follows
    per-shard demand instead of the global max.
    """
    live = caps[caps > 0]
    uniform = live.size == 0 or int(live.min()) == int(live.max())
    if uniform:
        cap = int(caps.max())
        if mesh is not None:
            # host -> owning devices directly (NamedSharding split on the
            # shard axis): device 0 never concentrates the stack, and the
            # emission program's in_specs see their layout w/o resharding
            sharding = NamedSharding(mesh, P(axis_name))
            _, offs_np, ids_np = part.host_slabs()
            offs_s = jax.device_put(offs_np, sharding)
            ids_s = jax.device_put(ids_np, sharding)
            out = _emit_sharded_fn(mesh, axis_name, cap)(offs_s, ids_s)
        else:
            _, offs_s, ids_s = part.device_slabs()
            out = spgemm_self_slab(offs_s.reshape(-1, offs_s.shape[-1]),
                                   ids_s.reshape(-1, ids_s.shape[-1]),
                                   cap=cap)
        return np.asarray(out).reshape(-1, 2) if to_host \
            else out.reshape(-1, 2)
    _, offs_np, ids_np = part.host_slabs()
    devices = list(mesh.devices.flat) if mesh is not None else None
    bufs = []
    for s in range(part.n_shards):
        if caps[s] == 0:
            continue                    # this shard's buckets are singletons
        offs, ids = offs_np[s], ids_np[s]
        if devices is not None:         # emit on the shard's own device
            offs = jax.device_put(offs, devices[s])
            ids = jax.device_put(ids, devices[s])
        bufs.append(spgemm_self_slab(offs, ids, cap=int(caps[s])))
    # ragged merge: per-shard buffers differ in cap, so the merge is a
    # concat (the cross-shard dedup downstream lexsorts anyway)
    if to_host:
        return np.concatenate(
            [np.asarray(b).reshape(-1, 2) for b in bufs], axis=0)
    if devices is not None:
        # the pack runs as ONE program: gather the per-shard buffers onto
        # the lead device (device-to-device, still no host round-trip)
        bufs = [jax.device_put(b, devices[0]) for b in bufs]
    return jnp.concatenate([b.reshape(-1, 2) for b in bufs], axis=0)


@dataclass(frozen=True)
class JoinPrefilter:
    """Fused in-join ungapped X-drop prefilter (see :func:`lsh_self_join`).

    With this attached, the deduplicated candidate buffer is scored by the
    ungapped diagonal scan ON DEVICE, straight off the device pair buffer,
    and only survivors (ungapped >= ``min_score``) are compacted and copied
    to host — rejected pairs never materialize as host pair arrays. The
    ungapped score is padding-invariant and a lower bound of the SW score,
    so the surviving pair set is bit-exact with filtering
    ``score_pairs(..., prefilter=True)`` output post hoc (same
    ``min_score``/``x``).
    """
    ids: np.ndarray         # (N, L) int8 PAD-padded corpus
    lens: np.ndarray        # (N,) int32
    min_score: int = 40     # survivors: ungapped score >= this (must be >= 1
                            # so the -1 padding slots, which gather all-PAD
                            # rows and score 0, can never survive)
    x: int | None = None    # X-drop margin (None = inf, plain best segment)
    batch: int = 256        # pairs per prefilter chunk (one program shape)
    len_quantum: int = 64   # gathered-length quantization (jit-cache ladder)


@functools.partial(jax.jit, static_argnames=("x", "L", "B"))
@trace_sentinel("join_prefilter")
def _join_prefilter_chunk(ids_dev, lens_dev, pairs_dev, start, *,
                          x: int | None, L: int, B: int):
    """Score one fixed-size chunk of the device pair buffer: fused
    dynamic-slice + gather + ungapped diagonal scan, no host round-trip.
    ``start`` is a traced scalar, so every chunk offset reuses ONE
    compiled program per (x, L, B)."""
    chunk = jax.lax.dynamic_slice(pairs_dev, (start, 0), (B, 2))
    qm = gather_rows(ids_dev, lens_dev, chunk[:, 0], L)
    rm = gather_rows(ids_dev, lens_dev, chunk[:, 1], L)
    return ungapped_xdrop_scores(qm, rm, x=x)


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("join_prefilter_pack")
def _prefilter_pack(pairs_dev, scores, min_score, *, cap: int):
    """Compact prefilter survivors (and their ungapped scores) to the
    front of the fixed buffer; (pairs+score (cap, 3) int32, count)."""
    keep = (pairs_dev[:, 0] >= 0) & (scores >= min_score)
    return compact_pairs((pairs_dev[:, 0], pairs_dev[:, 1], scores),
                         keep, cap)


def _prefilter_join(pairs_dev, n_cand: int, pf: JoinPrefilter):
    """Run the fused prefilter over a deduplicated device pair buffer.

    Returns (kept_pairs (K, 2), kept_ungapped (K,) int32) host arrays —
    the only D2H copy of pair data, already survivor-compacted."""
    if pf.min_score < 1:
        raise ValueError("JoinPrefilter.min_score must be >= 1 (padding "
                         "slots score 0 and must never survive)")
    lens_np = np.asarray(pf.lens, np.int32)
    ids_dev = jnp.asarray(pf.ids)
    lens_dev = jnp.asarray(lens_np)
    q = pf.len_quantum
    L = int(max(q, -(-int(lens_np.max(initial=1)) // q) * q))
    cap, B = pairs_dev.shape[0], pf.batch
    # only chunks that can contain real rows are scored; rows past the
    # count are -1 (all-PAD gathers scoring 0) and can never survive
    n_eff = min(cap, -(-max(n_cand, 1) // B) * B)
    pp = (jnp.pad(pairs_dev, ((0, (-cap) % B), (0, 0)), constant_values=-1)
          if cap % B else pairs_dev)
    chunks = [_join_prefilter_chunk(ids_dev, lens_dev, pp,
                                    jnp.asarray(s, jnp.int32),
                                    x=pf.x, L=L, B=B)
              for s in range(0, n_eff, B)]
    scores = jnp.concatenate(chunks)[:cap] if chunks else \
        jnp.zeros(cap, jnp.int32)
    if scores.shape[0] < cap:
        scores = jnp.pad(scores, (0, cap - scores.shape[0]))
    out, cnt = _prefilter_pack(pairs_dev, scores,
                               jnp.asarray(pf.min_score, jnp.int32), cap=cap)
    k = int(cnt)
    host = np.asarray(out[:k])
    return np.ascontiguousarray(host[:, :2]), np.ascontiguousarray(host[:, 2])


@dataclass(frozen=True)
class SelfJoinResult:
    """Deduplicated upper-triangular candidate set as a CSR adjacency."""
    pairs: np.ndarray      # (P, 2) int32, i < j, lexicographically sorted
    indptr: np.ndarray     # (N+1,) int64 — CSR row offsets over corpus ids
    indices: np.ndarray    # (P,) int32 — CSR column ids (the j of each pair)
    n_candidates: int      # == P
    ungapped: np.ndarray | None = None  # (P,) int32 prefilter scores of the
                                        # SURVIVING pairs (fused prefilter)
    n_prefiltered: int = 0  # candidates dropped in-join by the prefilter

    @property
    def n_rows(self) -> int:
        return len(self.indptr) - 1

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]


def _pairs_to_csr(pairs: np.ndarray, n: int, *, ungapped=None,
                  n_prefiltered: int = 0) -> SelfJoinResult:
    rows = pairs[:, 0]
    indptr = np.searchsorted(rows, np.arange(n + 1)).astype(np.int64)
    return SelfJoinResult(pairs=pairs, indptr=indptr,
                          indices=np.ascontiguousarray(pairs[:, 1]),
                          n_candidates=len(pairs), ungapped=ungapped,
                          n_prefiltered=n_prefiltered)


def _grow_overflow(scope: str, max_grow: int):
    raise RuntimeError(
        f"{scope} exceeded max_grow={max_grow} pairs; the corpus "
        f"has a degenerate bucket (see repro.index.stats) — raise "
        f"max_grow or increase bands/d selectivity")


def _finish_pairs(pairs_dev, n_cand: int, index: SignatureIndex,
                  prefilter: JoinPrefilter | None) -> SelfJoinResult:
    """Shared join tail off a deduplicated DEVICE pair buffer: either the
    fused prefilter (survivors are the only D2H copy) or the plain host
    copy of the first ``n_cand`` rows."""
    if prefilter is None:
        return _pairs_to_csr(np.asarray(pairs_dev[:n_cand]), index.size)
    with span("join_prefilter", cat="allpairs", candidates=n_cand):
        kept, ung = _prefilter_join(pairs_dev, n_cand, prefilter)
    return _pairs_to_csr(kept, index.size, ungapped=ung,
                         n_prefiltered=n_cand - len(kept))


def _dedup_and_pack(cand, index: SignatureIndex,
                    d: int | None, cap: int, max_grow: int, scope: str,
                    prefilter: JoinPrefilter | None = None
                    ) -> SelfJoinResult:
    """Legacy-orchestration tail: cross-band/-shard dedup + optional exact
    Hamming filter under the grow-and-retry capacity discipline (the
    spgemm path sizes the output at the exact emission total instead and
    never retries)."""
    while True:
        pairs, count = spgemm_pack(cand, index.device_sigs,
                                   out_cap=cap, d=d)
        if int(count) <= cap:
            return _finish_pairs(pairs, int(count), index, prefilter)
        if cap >= max_grow:         # dedup union overran the buffer
            _grow_overflow(scope, max_grow)
        cap = min(cap * 2, max_grow)    # grow-and-retry


def _pack_exact(cand_dev, index: SignatureIndex, d: int | None,
                total: int, max_grow: int, scope: str,
                prefilter: JoinPrefilter | None,
                limit: int | None = None) -> SelfJoinResult:
    """SpGEMM-orchestration tail: the pack output is sized at the exact
    emission total (survivors <= emitted always), so it can never
    overflow — no grow-and-retry, one host sync (the count).

    ``limit`` is the legacy-equivalent capacity ceiling
    (``max(starting cap, max_grow)``): legacy only raises when the dedup
    union must GROW past ``max_grow``, so a count the starting buffer
    already covers must succeed here too — never raise where legacy
    would not."""
    limit = max_grow if limit is None else limit
    out_cap = next_pow2(max(1, min(total, limit)))
    pairs, count = spgemm_pack(cand_dev, index.device_sigs,
                               out_cap=out_cap, d=d)
    n_cand = int(count)
    if n_cand > limit:
        _grow_overflow(scope, max_grow)
    return _finish_pairs(pairs, n_cand, index, prefilter)


def _resolve_mesh(n: int, mesh, axis_name: str):
    if n > 1 and mesh is None and jax.device_count() >= n:
        mesh = _default_mesh(n, axis_name)
    if mesh is not None and (axis_name not in mesh.axis_names
                             or mesh.shape[axis_name] != n):
        # shard_fn emits block[0] only — a smaller mesh would silently
        # drop the other shards' pairs
        raise ValueError(
            f"mesh axes {dict(mesh.shape)} do not provide {n} devices on "
            f"axis {axis_name!r} (one per partition shard)")
    if n == 1:
        mesh = None     # a 1-ring shard_map would only add dispatch cost
    return mesh


def lsh_self_join(index: SignatureIndex, *, d: int | None = None,
                  max_pairs: int = 1 << 16,
                  max_grow: int = 1 << 24,
                  n_shards: int | None = None,
                  mesh=None, axis_name: str = "data",
                  prefilter: JoinPrefilter | None = None,
                  join_impl: str = "spgemm") -> SelfJoinResult:
    """All-pairs candidate generation over the indexed corpus.

    Emits every within-bucket pair of every band, deduplicates across bands
    (and shards), and (optionally, ``d=``) exact-filters by packed Hamming
    distance. ``n_shards`` (default: the index's own ``n_shards``) routes
    emission through the bucket partition: with a mesh — ``mesh=`` or, when
    the process has that many devices, the first ``n_shards`` of
    ``jax.devices()`` — each shard emits its buckets' pairs on its own
    device in parallel; the pair set (and the result arrays) are
    bit-identical for every ``n_shards``.

    ``join_impl="spgemm"`` (default) fuses emission + dedup + filter +
    compaction device-resident and sizes the output at the exact emission
    total (no grow-and-retry, one host sync); ``"legacy"`` is the
    pre-SpGEMM orchestration (host merge + grow-and-retry), kept one PR as
    the bit-exactness reference — both produce identical arrays.

    Capacity discipline: per-shard emission capacity is sized from host-side
    int64 bucket totals (the device-side int32 count would wrap for a
    degenerate ~66k-member bucket and truncate silently), each shard at its
    OWN demand (:func:`_shard_caps` — skew-bounded); demand beyond
    ``max_grow`` raises — never a silent cap.

    ``prefilter=`` fuses the ungapped X-drop prefilter into the join
    (:class:`JoinPrefilter`): candidates are scored off the deduplicated
    DEVICE pair buffer and rejected pairs never reach the host — the
    returned pairs are exactly the survivors (``result.ungapped`` holds
    their prefilter scores, ``result.n_prefiltered`` the rejected count).
    """
    _check_impl(join_impl)
    n = int(n_shards) if n_shards is not None else index.n_shards
    part = index.partition(n)
    # the overflow check judges TRUE demand (the quantized caps below only
    # size buffers — quantization must never turn a legal corpus into an
    # error for non-pow2 max_grow values)
    need = int(part.pair_totals.max()) if part.pair_totals.size else 0
    if need > max_grow:
        _grow_overflow("self-join", max_grow)
    if need == 0:       # every bucket is a singleton: no collisions at all
        return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
    caps = _shard_caps(part)
    mesh = _resolve_mesh(n, mesh, axis_name)
    # Emission runs ONCE at per-shard exact-or-2x capacity (it can never
    # truncate); only the deduplicated cross-shard union can grow (legacy)
    # — the spgemm pack is sized at the exact emission total instead.
    with span("emission", cat="allpairs", shards=n, impl=join_impl,
              spmd=mesh is not None, need=need):
        if join_impl == "legacy":
            cand = _emit_partition(part, caps, mesh, axis_name)
            cap = max(max_pairs, int(caps.max()))
            return _dedup_and_pack(cand, index, d, cap, max_grow,
                                   "self-join", prefilter=prefilter)
        total = int(part.pair_totals.sum())
        # legacy-equivalent ceiling: legacy starts at max(max_pairs, caps)
        # and only raises when the union must GROW past max_grow
        limit = max(max_pairs, int(caps.max()), max_grow)
        live = caps[caps > 0]
        uniform = live.size == 0 or int(live.min()) == int(live.max())
        if mesh is None and uniform:
            # the fully fused program: products + dedup + filter + compact
            _, offs_s, ids_s = part.device_slabs()
            offs_f = offs_s.reshape(-1, offs_s.shape[-1])
            ids_f = ids_s.reshape(-1, ids_s.shape[-1])
            out_cap = next_pow2(max(1, min(total, limit)))
            if index.layout == "band" and index.size <= PACKED_KEY_MAX_ID:
                # band layout: duplicates only arise ACROSS bands and the
                # band-key matrix detects them at emission, so the pack is
                # one sort of packed keys — no dedup pass at all
                band_f = jnp.tile(
                    jnp.arange(offs_s.shape[1], dtype=jnp.int32),
                    offs_s.shape[0])
                pairs, count = spgemm_join_self_keys(
                    offs_f, ids_f, band_f, index.device_band_keys,
                    index.device_sigs, cap=int(caps.max()),
                    out_cap=out_cap, d=d)
            else:
                pairs, count = spgemm_join_self(
                    offs_f, ids_f, index.device_sigs,
                    cap=int(caps.max()), out_cap=out_cap, d=d)
            n_cand = int(count)
            if n_cand > limit:
                _grow_overflow("self-join", max_grow)
            return _finish_pairs(pairs, n_cand, index, prefilter)
        # SPMD or skewed demand: per-shard products, device-side merge,
        # fused pack — still no host round-trip of candidate rows
        cand = _emit_partition(part, caps, mesh, axis_name, to_host=False)
        return _pack_exact(cand, index, d, total, max_grow, "self-join",
                           prefilter, limit=limit)


def _segment_stack(seg, n_shards: int = 1):
    """One sealed segment's delta-join arrays for one shard count, CACHED
    ON THE SEGMENT (sealed = immutable, so they are built once per segment
    lifetime, not once per ingest — resident segments stay cheap across
    ``--incremental`` rounds): the :class:`BucketPartition` (band-stacked
    per-shard slabs + exact per-(shard, band) pair totals, the single
    stacking code path) and its pow2-quantized host slabs
    (:func:`~repro.index.partition.pad_slabs_pow2` — shapes repeat across
    ingests, keeping the jitted emission programs cache-hot)."""
    cache = getattr(seg, "_join_stacks", None)
    if cache is None:
        cache = {}
        seg._join_stacks = cache
    cached = cache.get(n_shards)
    if cached is None:
        part = BucketPartition(seg.csr, n_shards)
        keys_s, offs_s, ids_s = (np.asarray(a) for a in part.host_slabs())
        slabs = pad_slabs_pow2(keys_s, offs_s, ids_s)   # (S, nb, ...) stacks
        cached = (part, slabs)
        cache[n_shards] = cached
    return cached


def _cross_totals(dpart: BucketPartition, rpart: BucketPartition
                  ) -> np.ndarray:
    """Exact int64 cross-pair totals per (shard, band) between a delta
    partition's buckets and a resident partition's matching buckets
    (host-side — the capacity sizing must never wrap). Bucket ownership is
    keyed on the bucket key, so matching buckets always land on the SAME
    shard of both partitions — the per-shard cross products cover exactly
    the unsharded cross product."""
    out = np.zeros((dpart.n_shards, dpart.n_bands), np.int64)
    for s in range(dpart.n_shards):
        for b in range(dpart.n_bands):
            dk, do, _ = dpart.shards[s][b]
            rk, ro, _ = rpart.shards[s][b]
            if len(dk) == 0 or len(rk) == 0:
                continue
            dn = np.diff(do).astype(np.int64)
            pos = np.searchsorted(rk, dk)
            pos_c = np.clip(pos, 0, len(rk) - 1)
            match = (pos < len(rk)) & (rk[pos_c] == dk)
            rn = np.where(match,
                          (np.asarray(ro)[pos_c + 1] - np.asarray(ro)[pos_c]
                           ).astype(np.int64), 0)
            out[s, b] = int((dn * rn).sum())
    return out


def _flat(a):
    """(S, nb, X) slab -> (S*nb, X) for the band-stacked product programs."""
    return a.reshape(-1, a.shape[-1])


def lsh_delta_join(index: SignatureIndex, *, base_size: int,
                   d: int | None = None,
                   max_pairs: int = 1 << 16,
                   max_grow: int = 1 << 24,
                   n_shards: int | None = None,
                   mesh=None, axis_name: str = "data",
                   prefilter: JoinPrefilter | None = None,
                   join_impl: str = "spgemm") -> SelfJoinResult:
    """Incremental self-join: only the pairs touching rows >= ``base_size``.

    ``base_size`` must be a segment boundary (the corpus size before the
    ``add()`` calls being ingested). For each new segment the join emits
    its within-bucket pairs (upper mask over the delta slab) plus its
    cross pairs against the matching buckets of every earlier segment
    (the ``Aᵀ_delta · A_resident`` cross mask) — resident-vs-resident
    pairs are never re-enumerated, so ingest cost scales with the delta's
    bucket footprint, not the corpus. With ``n_shards > 1`` (default: the
    index's own) both masks run per shard under the segment bucket
    partitions — matching keys own the same shard on both sides, so the
    per-shard union is exactly the unsharded pair set; with a mesh each
    shard emits on its own device (``shard_map``). The result unions with
    the pre-ingest pair set to EXACTLY the from-scratch
    :func:`lsh_self_join` over the grown corpus (same dedup, same optional
    Hamming filter, same sort order); tests/test_lifecycle.py asserts the
    equality. ``join_impl="legacy"`` keeps the pre-SpGEMM single-device
    orchestration for one PR (identical arrays).
    """
    _check_impl(join_impl)
    index.seal()
    segs = index.segments
    boundaries = [s.base for s in segs] + [index.size]
    if base_size not in boundaries:
        raise ValueError(
            f"base_size {base_size} is not a segment boundary "
            f"{boundaries}; delta joins ingest whole segments")
    if base_size == index.size:     # nothing new
        return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
    k = boundaries.index(base_size)
    n = 1 if join_impl == "legacy" else (
        int(n_shards) if n_shards is not None else index.n_shards)
    mesh = _resolve_mesh(n, mesh, axis_name)

    def part(i) -> BucketPartition:
        return _segment_stack(segs[i], n)[0]

    def slabs(i):
        # pow2-quantized shapes + pow2 caps keep the jitted emission
        # programs cache-hot across successive ingests (exact shapes/caps
        # would retrace per segment — the recompile trap this PR fixes
        # everywhere else)
        return _segment_stack(segs[i], n)[1]

    def emit_within(i, cap: int):
        keys_s, offs_s, ids_s = slabs(i)
        if mesh is not None:
            sharding = NamedSharding(mesh, P(axis_name))
            return _emit_sharded_fn(mesh, axis_name, cap)(
                jax.device_put(offs_s, sharding),
                jax.device_put(ids_s, sharding))
        return spgemm_self_slab(_flat(offs_s), _flat(ids_s), cap=cap)

    def emit_cross(s, r, cap: int):
        dk, do, di = slabs(s)
        rk, ro, ri = slabs(r)
        if mesh is not None:
            sh = NamedSharding(mesh, P(axis_name))
            args = [jax.device_put(a, sh) for a in (dk, do, di, rk, ro, ri)]
            return _emit_cross_sharded_cached(
                tuple(mesh.devices.flat), axis_name, cap)(*args)
        return spgemm_cross_slab(_flat(dk), _flat(do), _flat(di),
                                 _flat(rk), _flat(ro), _flat(ri), cap=cap)

    bufs = []
    total = 0
    with span("delta_emission", cat="allpairs", shards=n, impl=join_impl,
              new_segments=len(segs) - k, resident_segments=k):
        for s in range(k, len(segs)):
            within = part(s).pair_totals
            need_w = int(within.max(initial=0))
            if need_w > max_grow:
                _grow_overflow("delta join", max_grow)
            if need_w > 0:
                total += int(within.sum())
                bufs.append(emit_within(s, next_pow2(need_w)))
            for r in range(s):      # every earlier segment is resident
                totals = _cross_totals(part(s), part(r))
                need_c = int(totals.max(initial=0))
                if need_c > max_grow:
                    _grow_overflow("delta join", max_grow)
                if need_c == 0:
                    continue
                total += int(totals.sum())
                bufs.append(emit_cross(s, r, next_pow2(need_c)))
        if not bufs:
            return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
        if join_impl == "legacy":
            # ragged host merge (buffers differ in cap); dedup lexsorts
            cand = np.concatenate(
                [np.asarray(b).reshape(-1, 2) for b in bufs], axis=0)
            return _dedup_and_pack(cand, index, d, max_pairs, max_grow,
                                   "delta join", prefilter=prefilter)
        # spgemm: device-side ragged merge + exact-sized fused pack
        cand = jnp.concatenate([b.reshape(-1, 2) for b in bufs], axis=0)
    return _pack_exact(cand, index, d, total, max_grow, "delta join",
                       prefilter, limit=max(max_pairs, max_grow))


def brute_force_collisions(index: SignatureIndex) -> set[tuple[int, int]]:
    """Oracle: enumerate all within-bucket pairs with host loops (exactness
    reference for tests/benchmarks — O(sum m^2), small corpora only)."""
    index._ensure_built()
    out: set[tuple[int, int]] = set()
    for (keys, offsets, ids) in index._csr_np:
        ids = np.asarray(ids)
        offsets = np.asarray(offsets)
        for u in range(len(keys)):
            members = ids[offsets[u]:offsets[u + 1]]
            for a in range(len(members)):
                for b in range(a + 1, len(members)):
                    i, j = int(members[a]), int(members[b])
                    out.add((min(i, j), max(i, j)))
    return out
