"""LSH self-join: the corpus joined against itself via the index's buckets.

The many-against-many candidate generator (PASTIS-style similarity graphs):
instead of probing queries against reference buckets, every bucket of the
:class:`~repro.index.store.SignatureIndex` emits its own within-bucket pairs.
A bucket of m members contributes m*(m-1)/2 unordered pairs; pairs colliding
in several bands are deduplicated; the result is the *exact* set of LSH band
collisions — upper-triangular (i < j), only valid (non-zero-signature)
sequences, identical to brute-force enumeration of per-band key equality.
The pigeonhole guarantee carries over: any pair within Hamming distance d of
each other shares >= 1 band, so filtering candidates by packed Hamming
distance (``d=``) yields the exact d-neighborhood graph.

Emission runs over the shard-owned bucket slabs of
:class:`~repro.index.partition.BucketPartition` (``mix32(key) % n_shards``
— the MapReduce shuffle): with ``n_shards > 1`` each mesh device emits its
own buckets' pairs in parallel (``shard_map``; a vmap over the shard axis
when the process has fewer devices), and the per-shard buffers are merged
host-side with the cross-shard/cross-band dedup. Buckets are never split
across shards, so the union of per-shard emissions is EXACTLY the
single-device pair set — the result arrays are bit-identical for every
``n_shards``.

Capacity is **skew-bounded**: each shard's emission buffer is sized at its
OWN per-(shard, band) within-bucket pair total (quantized to a power of
two to bound recompiles), so one degenerate bucket inflates one shard's
buffer, not every shard's. Uniform demand keeps the single SPMD
``shard_map`` program (one dispatch, the PR 4 lesson); skewed demand falls
back to per-shard emission with a ragged host merge — the downstream
dedup lexsorts, so the pair arrays are identical either way.

Incremental growth joins incrementally too: :func:`lsh_delta_join` emits
only the pairs that touch rows appended after ``base_size`` — each new
segment's within-bucket pairs plus its cross pairs against every resident
segment's matching buckets — so ingesting a segment never re-enumerates
the resident corpus. The union of the old pair set and the delta is
EXACTLY the from-scratch self-join over the grown corpus (any collision
either has both rows resident, or its later row lives in a new segment).

Emission reuses the fixed-capacity buffer discipline of ``core/join.py``
(rows past the count are -1; ``overflowed`` means rows were truncated), and
:func:`lsh_self_join` wraps it in the same grow-and-retry loop as the
serving layer — no silent caps.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..align.smith_waterman import gather_rows, ungapped_xdrop_scores
from ..core.hamming import hamming_distance
from ..core.join import compact_pairs, dedup_pairs
from ..index.partition import BucketPartition, pad_slabs_pow2
from ..index.store import SignatureIndex
from ..obs import span, trace_sentinel
from ..util import next_pow2, shard_map_compat


@functools.partial(jax.jit, static_argnames=("cap",))
def _emit_bucket_pairs(offsets, ids, *, cap: int):
    """Within-bucket upper-triangular pairs of one band's CSR buckets.

    offsets (U+1,) int32, ids (E,) int32 (ids grouped by bucket). Element at
    position p pairs with every later position of its bucket, so it owns
    c[p] = bucket_end(p) - 1 - p pairs; a cumsum over c maps fixed buffer
    slots back to (p, partner). Returns pairs (cap, 2) int32, -1 past the
    band's true pair count. The caller guarantees cap >= that count (sized
    host-side in int64 — the on-device int32 cumsum would wrap for a
    degenerate bucket of ~66k members), so nothing here can truncate.
    """
    E = ids.shape[0]
    pos = jnp.arange(E, dtype=jnp.int32)
    b = jnp.searchsorted(offsets, pos, side="right").astype(jnp.int32) - 1
    end = offsets[jnp.clip(b + 1, 0, offsets.shape[0] - 1)].astype(jnp.int32)
    cnt = jnp.maximum(end - 1 - pos, 0)
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(cnt)])
    total = cum[-1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    p = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32) - 1
    p = jnp.clip(p, 0, E - 1)
    partner = p + 1 + (slots - cum[p])
    valid = slots < total
    a = ids[p]
    c2 = ids[jnp.clip(partner, 0, E - 1)]
    lo = jnp.minimum(a, c2)
    hi = jnp.maximum(a, c2)
    return jnp.stack([jnp.where(valid, lo, -1),
                      jnp.where(valid, hi, -1)], axis=-1)


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("emit_slab")
def _emit_slab_pairs(offs_s, ids_s, *, cap: int):
    """Within-bucket pairs of one shard's stacked slab: offsets (nb, U+1),
    ids (nb, E) -> (nb, cap, 2) int32, -1 past each band's true count.
    Padded bucket slots (offsets repeating the end) own zero pairs by
    construction, so slab padding can never emit."""
    return jax.vmap(
        lambda o, i: _emit_bucket_pairs(o, i, cap=cap))(offs_s, ids_s)


@functools.partial(jax.jit, static_argnames=("cap",))
def _emit_cross_pairs(dkeys, doffs, dids, rkeys, roffs, rids, *, cap: int):
    """Cross pairs between one band's *delta* buckets and the matching
    *resident* buckets (the delta-join primitive).

    Each delta bucket entry pairs with every member of the resident bucket
    sharing its key, so entry p owns c[p] = |resident bucket| pairs; the
    same cumsum slot mapping as ``_emit_bucket_pairs`` turns that into a
    fixed (cap, 2) buffer, -1 past the true count. Stacked-slab padding is
    inert on both sides: padded delta entry slots sit past ``doffs[-1]``
    (own zero pairs), padded resident keys repeat the last key with empty
    offsets (match nothing). The caller sizes cap >= the true demand,
    computed host-side in int64 — emission can never truncate.
    """
    Ud, Ed = dkeys.shape[0], dids.shape[0]
    Ur, Er = rkeys.shape[0], rids.shape[0]
    pos = jnp.arange(Ed, dtype=jnp.int32)
    u = jnp.searchsorted(doffs, pos, side="right").astype(jnp.int32) - 1
    u = jnp.clip(u, 0, max(Ud - 1, 0))
    key = dkeys[u]
    rpos = jnp.searchsorted(rkeys, key).astype(jnp.int32)
    rpos_c = jnp.clip(rpos, 0, max(Ur - 1, 0))
    match = (rpos < Ur) & (rkeys[rpos_c] == key)
    rstart = roffs[rpos_c]
    rend = jnp.where(match, roffs[jnp.clip(rpos_c + 1, 0, Ur)], rstart)
    real = pos < doffs[-1]              # past-the-end delta slots own nothing
    cnt = jnp.where(real & match, rend - rstart, 0)
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(cnt)])
    total = cum[-1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    p = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32) - 1
    p = jnp.clip(p, 0, max(Ed - 1, 0))
    partner = rids[jnp.clip(rstart[p] + (slots - cum[p]), 0,
                            max(Er - 1, 0))]
    a = dids[p]
    valid = slots < total
    lo = jnp.minimum(a, partner)
    hi = jnp.maximum(a, partner)
    return jnp.stack([jnp.where(valid, lo, -1),
                      jnp.where(valid, hi, -1)], axis=-1)


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("emit_cross")
def _emit_cross_slab(dkeys_s, doffs_s, dids_s, rkeys_s, roffs_s, rids_s,
                     *, cap: int):
    """Band-stacked cross emission: (nb, ...) delta + resident slabs ->
    (nb, cap, 2) int32."""
    return jax.vmap(lambda a, b, c, d, e, f: _emit_cross_pairs(
        a, b, c, d, e, f, cap=cap))(dkeys_s, doffs_s, dids_s,
                                    rkeys_s, roffs_s, rids_s)


@functools.lru_cache(maxsize=16)
def _default_mesh(n: int, axis_name: str):
    """One mesh per shard count (a fresh Mesh per call would defeat the
    jit cache of every program built on it)."""
    return Mesh(np.array(jax.devices()[:n]), (axis_name,))


@functools.lru_cache(maxsize=64)
def _emit_sharded_cached(devices: tuple, axis_name: str, cap: int):
    """The jitted shard_map emission program, cached by the DEVICE TUPLE —
    never by a Mesh object. Device objects are per-process singletons, so
    two freshly constructed (equal) meshes resolve to the same program;
    keying by Mesh relied on Mesh equality semantics and a fresh Mesh per
    call could silently recompile (the PR 5 regression test pins this)."""
    ax = axis_name
    mesh = Mesh(np.array(devices), (ax,))

    @trace_sentinel("emission_spmd", static_key=(devices, cap))
    def shard_fn(offs, ids):
        return _emit_slab_pairs(offs[0], ids[0], cap=cap)

    return jax.jit(shard_map_compat(
        shard_fn, mesh, in_specs=(P(ax), P(ax)), out_specs=P(ax)))


def _emit_sharded_fn(mesh, axis_name: str, cap: int):
    """Resolve a mesh to the cached SPMD emission program (identity-stable
    across equal meshes — see :func:`_emit_sharded_cached`)."""
    return _emit_sharded_cached(tuple(mesh.devices.flat), axis_name, cap)


def _shard_caps(part: BucketPartition) -> np.ndarray:
    """(S,) int64 emission capacity per shard: its own max per-(shard,
    band) within-bucket pair total, quantized to the next power of two
    (bounds both recompiles and worst-case over-allocation at 2x true
    demand). Skew-bounding: a degenerate bucket inflates only its owning
    shard's cap."""
    if part.pair_totals.size == 0:
        return np.zeros(part.n_shards, np.int64)
    per_shard = part.pair_totals.max(axis=1)
    return np.array([next_pow2(int(c)) for c in per_shard], np.int64)


def _emit_partition(part: BucketPartition, caps: np.ndarray, mesh,
                    axis_name: str) -> np.ndarray:
    """Emit every shard's within-bucket pairs over the partition slabs;
    returns the merged (M, 2) candidate rows (-1 rows allowed — the
    downstream dedup drops them).

    Uniform demand (all nonzero shard caps equal): ONE program — the
    ``shard_map`` SPMD emission on a mesh of ``part.n_shards`` devices, or
    a vmap over the shard axis on one device. Skewed demand: per-shard
    emission at each shard's own cap (placed on its owning mesh device
    when a mesh is given) with a ragged host merge, so buffer memory
    follows per-shard demand instead of the global max.
    """
    live = caps[caps > 0]
    uniform = live.size == 0 or int(live.min()) == int(live.max())
    if uniform:
        cap = int(caps.max())
        if mesh is not None:
            # host -> owning devices directly (NamedSharding split on the
            # shard axis): device 0 never concentrates the stack, and the
            # emission program's in_specs see their layout w/o resharding
            sharding = NamedSharding(mesh, P(axis_name))
            _, offs_np, ids_np = part.host_slabs()
            offs_s = jax.device_put(offs_np, sharding)
            ids_s = jax.device_put(ids_np, sharding)
            out = _emit_sharded_fn(mesh, axis_name, cap)(offs_s, ids_s)
            return np.asarray(out).reshape(-1, 2)
        _, offs_s, ids_s = part.device_slabs()
        out = jax.vmap(
            lambda o, i: _emit_slab_pairs(o, i, cap=cap))(offs_s, ids_s)
        return np.asarray(out).reshape(-1, 2)
    _, offs_np, ids_np = part.host_slabs()
    devices = list(mesh.devices.flat) if mesh is not None else None
    bufs = []
    for s in range(part.n_shards):
        if caps[s] == 0:
            continue                    # this shard's buckets are singletons
        offs, ids = offs_np[s], ids_np[s]
        if devices is not None:         # emit on the shard's own device
            offs = jax.device_put(offs, devices[s])
            ids = jax.device_put(ids, devices[s])
        bufs.append(_emit_slab_pairs(offs, ids, cap=int(caps[s])))
    # ragged host merge: per-shard buffers differ in cap, so the merge is
    # a host concat (the cross-shard dedup downstream lexsorts anyway)
    return np.concatenate([np.asarray(b).reshape(-1, 2) for b in bufs],
                          axis=0)


@functools.partial(jax.jit, static_argnames=("max_pairs", "d"))
def _dedup_filter(cand, sigs, *, max_pairs: int, d: int | None):
    """Cross-band dedup (core.join machinery) + optional exact Hamming
    filter, compacted to ``max_pairs`` rows. Returns (pairs, count)."""
    cs, keep = dedup_pairs(cand)
    if d is not None:
        dist = hamming_distance(sigs[jnp.maximum(cs[:, 0], 0)],
                                sigs[jnp.maximum(cs[:, 1], 0)])
        keep = keep & (dist <= d)
    return compact_pairs((cs[:, 0], cs[:, 1]), keep, max_pairs)


@dataclass(frozen=True)
class JoinPrefilter:
    """Fused in-join ungapped X-drop prefilter (see :func:`lsh_self_join`).

    With this attached, the deduplicated candidate buffer is scored by the
    ungapped diagonal scan ON DEVICE, straight off the device pair buffer,
    and only survivors (ungapped >= ``min_score``) are compacted and copied
    to host — rejected pairs never materialize as host pair arrays. The
    ungapped score is padding-invariant and a lower bound of the SW score,
    so the surviving pair set is bit-exact with filtering
    ``score_pairs(..., prefilter=True)`` output post hoc (same
    ``min_score``/``x``).
    """
    ids: np.ndarray         # (N, L) int8 PAD-padded corpus
    lens: np.ndarray        # (N,) int32
    min_score: int = 40     # survivors: ungapped score >= this (must be >= 1
                            # so the -1 padding slots, which gather all-PAD
                            # rows and score 0, can never survive)
    x: int | None = None    # X-drop margin (None = inf, plain best segment)
    batch: int = 256        # pairs per prefilter chunk (one program shape)
    len_quantum: int = 64   # gathered-length quantization (jit-cache ladder)


@functools.partial(jax.jit, static_argnames=("x", "L", "B"))
@trace_sentinel("join_prefilter")
def _join_prefilter_chunk(ids_dev, lens_dev, pairs_dev, start, *,
                          x: int | None, L: int, B: int):
    """Score one fixed-size chunk of the device pair buffer: fused
    dynamic-slice + gather + ungapped diagonal scan, no host round-trip.
    ``start`` is a traced scalar, so every chunk offset reuses ONE
    compiled program per (x, L, B)."""
    chunk = jax.lax.dynamic_slice(pairs_dev, (start, 0), (B, 2))
    qm = gather_rows(ids_dev, lens_dev, chunk[:, 0], L)
    rm = gather_rows(ids_dev, lens_dev, chunk[:, 1], L)
    return ungapped_xdrop_scores(qm, rm, x=x)


@functools.partial(jax.jit, static_argnames=("cap",))
@trace_sentinel("join_prefilter_pack")
def _prefilter_pack(pairs_dev, scores, min_score, *, cap: int):
    """Compact prefilter survivors (and their ungapped scores) to the
    front of the fixed buffer; (pairs+score (cap, 3) int32, count)."""
    keep = (pairs_dev[:, 0] >= 0) & (scores >= min_score)
    return compact_pairs((pairs_dev[:, 0], pairs_dev[:, 1], scores),
                         keep, cap)


def _prefilter_join(pairs_dev, n_cand: int, pf: JoinPrefilter):
    """Run the fused prefilter over a deduplicated device pair buffer.

    Returns (kept_pairs (K, 2), kept_ungapped (K,) int32) host arrays —
    the only D2H copy of pair data, already survivor-compacted."""
    if pf.min_score < 1:
        raise ValueError("JoinPrefilter.min_score must be >= 1 (padding "
                         "slots score 0 and must never survive)")
    lens_np = np.asarray(pf.lens, np.int32)
    ids_dev = jnp.asarray(pf.ids)
    lens_dev = jnp.asarray(lens_np)
    q = pf.len_quantum
    L = int(max(q, -(-int(lens_np.max(initial=1)) // q) * q))
    cap, B = pairs_dev.shape[0], pf.batch
    # only chunks that can contain real rows are scored; rows past the
    # count are -1 (all-PAD gathers scoring 0) and can never survive
    n_eff = min(cap, -(-max(n_cand, 1) // B) * B)
    pp = (jnp.pad(pairs_dev, ((0, (-cap) % B), (0, 0)), constant_values=-1)
          if cap % B else pairs_dev)
    chunks = [_join_prefilter_chunk(ids_dev, lens_dev, pp,
                                    jnp.asarray(s, jnp.int32),
                                    x=pf.x, L=L, B=B)
              for s in range(0, n_eff, B)]
    scores = jnp.concatenate(chunks)[:cap] if chunks else \
        jnp.zeros(cap, jnp.int32)
    if scores.shape[0] < cap:
        scores = jnp.pad(scores, (0, cap - scores.shape[0]))
    out, cnt = _prefilter_pack(pairs_dev, scores,
                               jnp.asarray(pf.min_score, jnp.int32), cap=cap)
    k = int(cnt)
    host = np.asarray(out[:k])
    return np.ascontiguousarray(host[:, :2]), np.ascontiguousarray(host[:, 2])


@dataclass(frozen=True)
class SelfJoinResult:
    """Deduplicated upper-triangular candidate set as a CSR adjacency."""
    pairs: np.ndarray      # (P, 2) int32, i < j, lexicographically sorted
    indptr: np.ndarray     # (N+1,) int64 — CSR row offsets over corpus ids
    indices: np.ndarray    # (P,) int32 — CSR column ids (the j of each pair)
    n_candidates: int      # == P
    ungapped: np.ndarray | None = None  # (P,) int32 prefilter scores of the
                                        # SURVIVING pairs (fused prefilter)
    n_prefiltered: int = 0  # candidates dropped in-join by the prefilter

    @property
    def n_rows(self) -> int:
        return len(self.indptr) - 1

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]


def _pairs_to_csr(pairs: np.ndarray, n: int, *, ungapped=None,
                  n_prefiltered: int = 0) -> SelfJoinResult:
    rows = pairs[:, 0]
    indptr = np.searchsorted(rows, np.arange(n + 1)).astype(np.int64)
    return SelfJoinResult(pairs=pairs, indptr=indptr,
                          indices=np.ascontiguousarray(pairs[:, 1]),
                          n_candidates=len(pairs), ungapped=ungapped,
                          n_prefiltered=n_prefiltered)


def _grow_overflow(scope: str, max_grow: int):
    raise RuntimeError(
        f"{scope} exceeded max_grow={max_grow} pairs; the corpus "
        f"has a degenerate bucket (see repro.index.stats) — raise "
        f"max_grow or increase bands/d selectivity")


def _dedup_and_pack(cand: np.ndarray, index: SignatureIndex,
                    d: int | None, cap: int, max_grow: int, scope: str,
                    prefilter: JoinPrefilter | None = None
                    ) -> SelfJoinResult:
    """Shared tail of both joins: cross-band/-shard dedup + optional exact
    Hamming filter under the grow-and-retry capacity discipline. With a
    :class:`JoinPrefilter`, the deduplicated device buffer is additionally
    X-drop-prefiltered before the host copy — only survivors come back."""
    while True:
        pairs, count = _dedup_filter(cand, index.device_sigs,
                                     max_pairs=cap, d=d)
        if int(count) <= cap:
            n_cand = int(count)
            if prefilter is None:
                p = np.asarray(pairs[:n_cand])
                return _pairs_to_csr(p, index.size)
            with span("join_prefilter", cat="allpairs", candidates=n_cand):
                kept, ung = _prefilter_join(pairs, n_cand, prefilter)
            return _pairs_to_csr(kept, index.size, ungapped=ung,
                                 n_prefiltered=n_cand - len(kept))
        if cap >= max_grow:         # dedup union overran the buffer
            _grow_overflow(scope, max_grow)
        cap = min(cap * 2, max_grow)    # grow-and-retry


def lsh_self_join(index: SignatureIndex, *, d: int | None = None,
                  max_pairs: int = 1 << 16,
                  max_grow: int = 1 << 24,
                  n_shards: int | None = None,
                  mesh=None, axis_name: str = "data",
                  prefilter: JoinPrefilter | None = None) -> SelfJoinResult:
    """All-pairs candidate generation over the indexed corpus.

    Emits every within-bucket pair of every band, deduplicates across bands
    (and shards), and (optionally, ``d=``) exact-filters by packed Hamming
    distance. ``n_shards`` (default: the index's own ``n_shards``) routes
    emission through the bucket partition: with a mesh — ``mesh=`` or, when
    the process has that many devices, the first ``n_shards`` of
    ``jax.devices()`` — each shard emits its buckets' pairs on its own
    device in parallel; the pair set (and the result arrays) are
    bit-identical for every ``n_shards``.

    Capacity discipline: per-shard emission capacity is sized from host-side
    int64 bucket totals (the device-side int32 count would wrap for a
    degenerate ~66k-member bucket and truncate silently), each shard at its
    OWN demand (:func:`_shard_caps` — skew-bounded); the deduplicated
    cross-band union still grow-and-retries. Either demand beyond
    ``max_grow`` raises — never a silent cap.

    ``prefilter=`` fuses the ungapped X-drop prefilter into the join
    (:class:`JoinPrefilter`): candidates are scored off the deduplicated
    DEVICE pair buffer and rejected pairs never reach the host — the
    returned pairs are exactly the survivors (``result.ungapped`` holds
    their prefilter scores, ``result.n_prefiltered`` the rejected count).
    """
    n = int(n_shards) if n_shards is not None else index.n_shards
    part = index.partition(n)
    # the overflow check judges TRUE demand (the quantized caps below only
    # size buffers — quantization must never turn a legal corpus into an
    # error for non-pow2 max_grow values)
    need = int(part.pair_totals.max()) if part.pair_totals.size else 0
    if need > max_grow:
        _grow_overflow("self-join", max_grow)
    if need == 0:       # every bucket is a singleton: no collisions at all
        return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
    caps = _shard_caps(part)
    if n > 1 and mesh is None and jax.device_count() >= n:
        mesh = _default_mesh(n, axis_name)
    if mesh is not None and (axis_name not in mesh.axis_names
                             or mesh.shape[axis_name] != n):
        # shard_fn emits block[0] only — a smaller mesh would silently
        # drop the other shards' pairs
        raise ValueError(
            f"mesh axes {dict(mesh.shape)} do not provide {n} devices on "
            f"axis {axis_name!r} (one per partition shard)")
    if n == 1:
        mesh = None     # a 1-ring shard_map would only add dispatch cost
    # Emission runs ONCE at per-shard exact-or-2x capacity (it can never
    # truncate); only the deduplicated cross-shard union below grows, so a
    # retry re-runs just the dedup/compact step, never the emission.
    with span("emission", cat="allpairs", shards=n,
              spmd=mesh is not None, need=need):
        cand = _emit_partition(part, caps, mesh, axis_name)
    cap = max(max_pairs, int(caps.max()))
    return _dedup_and_pack(cand, index, d, cap, max_grow, "self-join",
                           prefilter=prefilter)


def _segment_stack(seg):
    """One sealed segment's delta-join arrays, CACHED ON THE SEGMENT
    (sealed = immutable, so they are built once per segment lifetime, not
    once per ingest — resident segments stay cheap across ``--incremental``
    rounds): the 1-way :class:`BucketPartition` (band-stacked slabs + exact
    per-band pair totals, the single stacking code path) and its
    pow2-quantized host slabs (:func:`~repro.index.partition.pad_slabs_pow2`
    — shapes repeat across ingests, keeping the jitted emission programs
    cache-hot)."""
    cached = getattr(seg, "_join_stack", None)
    if cached is None:
        part = BucketPartition(seg.csr, 1)
        keys_s, offs_s, ids_s = (np.asarray(a) for a in part.host_slabs())
        slabs = pad_slabs_pow2(keys_s[0], offs_s[0], ids_s[0])
        cached = (part, slabs)
        seg._join_stack = cached
    return cached


def _cross_totals(dseg, rseg) -> np.ndarray:
    """Exact int64 cross-pair totals per band between a delta segment's
    buckets and a resident segment's matching buckets (host-side — the
    capacity sizing must never wrap)."""
    out = np.zeros(len(dseg.csr), np.int64)
    for b, ((dk, do, _), (rk, ro, _)) in enumerate(zip(dseg.csr, rseg.csr)):
        if len(dk) == 0 or len(rk) == 0:
            continue
        dn = np.diff(do).astype(np.int64)
        pos = np.searchsorted(rk, dk)
        pos_c = np.clip(pos, 0, len(rk) - 1)
        match = (pos < len(rk)) & (rk[pos_c] == dk)
        rn = np.where(match,
                      (np.asarray(ro)[pos_c + 1] - np.asarray(ro)[pos_c]
                       ).astype(np.int64), 0)
        out[b] = int((dn * rn).sum())
    return out


def lsh_delta_join(index: SignatureIndex, *, base_size: int,
                   d: int | None = None,
                   max_pairs: int = 1 << 16,
                   max_grow: int = 1 << 24,
                   prefilter: JoinPrefilter | None = None
                   ) -> SelfJoinResult:
    """Incremental self-join: only the pairs touching rows >= ``base_size``.

    ``base_size`` must be a segment boundary (the corpus size before the
    ``add()`` calls being ingested). For each new segment the join emits
    its within-bucket pairs plus its cross pairs against the matching
    buckets of every earlier segment — resident-vs-resident pairs are
    never re-enumerated, so ingest cost scales with the delta's bucket
    footprint, not the corpus. The result unions with the pre-ingest pair
    set to EXACTLY the from-scratch :func:`lsh_self_join` over the grown
    corpus (same dedup, same optional Hamming filter, same sort order);
    tests/test_lifecycle.py asserts the equality.
    """
    index.seal()
    segs = index.segments
    boundaries = [s.base for s in segs] + [index.size]
    if base_size not in boundaries:
        raise ValueError(
            f"base_size {base_size} is not a segment boundary "
            f"{boundaries}; delta joins ingest whole segments")
    if base_size == index.size:     # nothing new
        return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
    k = boundaries.index(base_size)

    def part(i) -> BucketPartition:
        return _segment_stack(segs[i])[0]

    def slabs(i):
        # pow2-quantized shapes + pow2 caps keep the jitted emission
        # programs cache-hot across successive ingests (exact shapes/caps
        # would retrace per segment — the recompile trap this PR fixes
        # everywhere else)
        return _segment_stack(segs[i])[1]

    bufs = []
    with span("delta_emission", cat="allpairs",
              new_segments=len(segs) - k, resident_segments=k):
        for s in range(k, len(segs)):
            need_w = int(part(s).pair_totals[0].max(initial=0))
            if need_w > max_grow:
                _grow_overflow("delta join", max_grow)
            if need_w > 0:
                _, doffs, dids = slabs(s)
                bufs.append(_emit_slab_pairs(doffs, dids,
                                             cap=next_pow2(need_w)))
            for r in range(s):      # every earlier segment is resident
                totals = _cross_totals(segs[s], segs[r])
                need_c = int(totals.max(initial=0))
                if need_c > max_grow:
                    _grow_overflow("delta join", max_grow)
                if need_c == 0:
                    continue
                dk, do, di = slabs(s)
                rk, ro, ri = slabs(r)
                bufs.append(_emit_cross_slab(dk, do, di, rk, ro, ri,
                                             cap=next_pow2(need_c)))
    if not bufs:
        return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
    # ragged host merge (buffers differ in cap); dedup lexsorts downstream
    cand = np.concatenate([np.asarray(b).reshape(-1, 2) for b in bufs],
                          axis=0)
    return _dedup_and_pack(cand, index, d, max_pairs, max_grow, "delta join",
                           prefilter=prefilter)


def brute_force_collisions(index: SignatureIndex) -> set[tuple[int, int]]:
    """Oracle: enumerate all within-bucket pairs with host loops (exactness
    reference for tests/benchmarks — O(sum m^2), small corpora only)."""
    index._ensure_built()
    out: set[tuple[int, int]] = set()
    for (keys, offsets, ids) in index._csr_np:
        ids = np.asarray(ids)
        offsets = np.asarray(offsets)
        for u in range(len(keys)):
            members = ids[offsets[u]:offsets[u + 1]]
            for a in range(len(members)):
                for b in range(a + 1, len(members)):
                    i, j = int(members[a]), int(members[b])
                    out.add((min(i, j), max(i, j)))
    return out
