"""LSH self-join: the corpus joined against itself via the index's buckets.

The many-against-many candidate generator (PASTIS-style similarity graphs):
instead of probing queries against reference buckets, every bucket of the
:class:`~repro.index.store.SignatureIndex` emits its own within-bucket pairs.
A bucket of m members contributes m*(m-1)/2 unordered pairs; pairs colliding
in several bands are deduplicated; the result is the *exact* set of LSH band
collisions — upper-triangular (i < j), only valid (non-zero-signature)
sequences, identical to brute-force enumeration of per-band key equality.
The pigeonhole guarantee carries over: any pair within Hamming distance d of
each other shares >= 1 band, so filtering candidates by packed Hamming
distance (``d=``) yields the exact d-neighborhood graph.

Emission runs over the shard-owned bucket slabs of
:class:`~repro.index.partition.BucketPartition` (``mix32(key) % n_shards``
— the MapReduce shuffle): with ``n_shards > 1`` each mesh device emits its
own buckets' pairs in parallel (``shard_map``; a vmap over the shard axis
when the process has fewer devices), and the per-shard buffers are merged
host-side with the cross-shard/cross-band dedup. Buckets are never split
across shards, so the union of per-shard emissions is EXACTLY the
single-device pair set — the result arrays are bit-identical for every
``n_shards``.

Emission reuses the fixed-capacity buffer discipline of ``core/join.py``
(rows past the count are -1; ``overflowed`` means rows were truncated), and
:func:`lsh_self_join` wraps it in the same grow-and-retry loop as the
serving layer — no silent caps.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.hamming import hamming_distance
from ..core.join import compact_pairs, dedup_pairs
from ..index.store import SignatureIndex
from ..util import shard_map_compat


@functools.partial(jax.jit, static_argnames=("cap",))
def _emit_bucket_pairs(offsets, ids, *, cap: int):
    """Within-bucket upper-triangular pairs of one band's CSR buckets.

    offsets (U+1,) int32, ids (E,) int32 (ids grouped by bucket). Element at
    position p pairs with every later position of its bucket, so it owns
    c[p] = bucket_end(p) - 1 - p pairs; a cumsum over c maps fixed buffer
    slots back to (p, partner). Returns pairs (cap, 2) int32, -1 past the
    band's true pair count. The caller guarantees cap >= that count (sized
    host-side in int64 — the on-device int32 cumsum would wrap for a
    degenerate bucket of ~66k members), so nothing here can truncate.
    """
    E = ids.shape[0]
    pos = jnp.arange(E, dtype=jnp.int32)
    b = jnp.searchsorted(offsets, pos, side="right").astype(jnp.int32) - 1
    end = offsets[jnp.clip(b + 1, 0, offsets.shape[0] - 1)].astype(jnp.int32)
    cnt = jnp.maximum(end - 1 - pos, 0)
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(cnt)])
    total = cum[-1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    p = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32) - 1
    p = jnp.clip(p, 0, E - 1)
    partner = p + 1 + (slots - cum[p])
    valid = slots < total
    a = ids[p]
    c2 = ids[jnp.clip(partner, 0, E - 1)]
    lo = jnp.minimum(a, c2)
    hi = jnp.maximum(a, c2)
    return jnp.stack([jnp.where(valid, lo, -1),
                      jnp.where(valid, hi, -1)], axis=-1)


@functools.partial(jax.jit, static_argnames=("cap",))
def _emit_slab_pairs(offs_s, ids_s, *, cap: int):
    """Within-bucket pairs of one shard's stacked slab: offsets (nb, U+1),
    ids (nb, E) -> (nb, cap, 2) int32, -1 past each band's true count.
    Padded bucket slots (offsets repeating the end) own zero pairs by
    construction, so slab padding can never emit."""
    return jax.vmap(
        lambda o, i: _emit_bucket_pairs(o, i, cap=cap))(offs_s, ids_s)


@functools.lru_cache(maxsize=16)
def _default_mesh(n: int, axis_name: str):
    """One mesh per shard count (a fresh Mesh per call would defeat the
    jit cache of every program built on it)."""
    return Mesh(np.array(jax.devices()[:n]), (axis_name,))


@functools.lru_cache(maxsize=64)
def _emit_sharded_fn(mesh, axis_name: str, cap: int):
    """Cached jitted shard_map emission program (keyed by mesh + capacity —
    Mesh hashes by device set, so repeated self-joins reuse the program)."""
    ax = axis_name

    def shard_fn(offs, ids):
        return _emit_slab_pairs(offs[0], ids[0], cap=cap)

    return jax.jit(shard_map_compat(
        shard_fn, mesh, in_specs=(P(ax), P(ax)), out_specs=P(ax)))


def _emit_partition(part, cap: int, mesh, axis_name: str):
    """Emit every shard's within-bucket pairs over the partition slabs.

    Returns (S*nb, cap, 2) candidate buffers. With a mesh of
    ``part.n_shards`` devices each shard emits on its own device
    (``shard_map``); otherwise the same program runs as a vmap over the
    shard axis — identical math, one device.
    """
    if mesh is not None:
        # host -> owning devices directly (NamedSharding split on the shard
        # axis): device 0 never concentrates the stack, and the emission
        # program's in_specs see their expected layout without resharding
        sharding = NamedSharding(mesh, P(axis_name))
        _, offs_np, ids_np = part.host_slabs()
        offs_s = jax.device_put(offs_np, sharding)
        ids_s = jax.device_put(ids_np, sharding)
        return _emit_sharded_fn(mesh, axis_name, cap)(offs_s, ids_s)
    _, offs_s, ids_s = part.device_slabs()
    out = jax.vmap(
        lambda o, i: _emit_slab_pairs(o, i, cap=cap))(offs_s, ids_s)
    return out.reshape(-1, cap, 2)


@functools.partial(jax.jit, static_argnames=("max_pairs", "d"))
def _dedup_filter(cand, sigs, *, max_pairs: int, d: int | None):
    """Cross-band dedup (core.join machinery) + optional exact Hamming
    filter, compacted to ``max_pairs`` rows. Returns (pairs, count)."""
    cs, keep = dedup_pairs(cand)
    if d is not None:
        dist = hamming_distance(sigs[jnp.maximum(cs[:, 0], 0)],
                                sigs[jnp.maximum(cs[:, 1], 0)])
        keep = keep & (dist <= d)
    return compact_pairs((cs[:, 0], cs[:, 1]), keep, max_pairs)


@dataclass(frozen=True)
class SelfJoinResult:
    """Deduplicated upper-triangular candidate set as a CSR adjacency."""
    pairs: np.ndarray      # (P, 2) int32, i < j, lexicographically sorted
    indptr: np.ndarray     # (N+1,) int64 — CSR row offsets over corpus ids
    indices: np.ndarray    # (P,) int32 — CSR column ids (the j of each pair)
    n_candidates: int      # == P

    @property
    def n_rows(self) -> int:
        return len(self.indptr) - 1

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]


def _pairs_to_csr(pairs: np.ndarray, n: int) -> SelfJoinResult:
    rows = pairs[:, 0]
    indptr = np.searchsorted(rows, np.arange(n + 1)).astype(np.int64)
    return SelfJoinResult(pairs=pairs, indptr=indptr,
                          indices=np.ascontiguousarray(pairs[:, 1]),
                          n_candidates=len(pairs))


def lsh_self_join(index: SignatureIndex, *, d: int | None = None,
                  max_pairs: int = 1 << 16,
                  max_grow: int = 1 << 24,
                  n_shards: int | None = None,
                  mesh=None, axis_name: str = "data") -> SelfJoinResult:
    """All-pairs candidate generation over the indexed corpus.

    Emits every within-bucket pair of every band, deduplicates across bands
    (and shards), and (optionally, ``d=``) exact-filters by packed Hamming
    distance. ``n_shards`` (default: the index's own ``n_shards``) routes
    emission through the bucket partition: with a mesh — ``mesh=`` or, when
    the process has that many devices, the first ``n_shards`` of
    ``jax.devices()`` — each shard emits its buckets' pairs on its own
    device in parallel; the pair set (and the result arrays) are
    bit-identical for every ``n_shards``.

    Capacity discipline: per-(shard, band) emission capacity is sized
    EXACTLY from host-side int64 bucket totals (the device-side int32 count
    would wrap for a degenerate ~66k-member bucket and truncate silently);
    the deduplicated cross-band union still grow-and-retries. Either demand
    beyond ``max_grow`` raises — never a silent cap.
    """
    n = int(n_shards) if n_shards is not None else index.n_shards
    part = index.partition(n)
    # exact per-(shard, band) pair totals in int64
    need = int(part.pair_totals.max()) if part.pair_totals.size else 0

    def _raise():
        raise RuntimeError(
            f"self-join exceeded max_grow={max_grow} pairs; the corpus "
            f"has a degenerate bucket (see repro.index.stats) — raise "
            f"max_grow or increase bands/d selectivity")

    if need > max_grow:
        _raise()
    if need == 0:       # every bucket is a singleton: no collisions at all
        return _pairs_to_csr(np.zeros((0, 2), np.int32), index.size)
    if n > 1 and mesh is None and jax.device_count() >= n:
        mesh = _default_mesh(n, axis_name)
    if mesh is not None and (axis_name not in mesh.axis_names
                             or mesh.shape[axis_name] != n):
        # shard_fn emits block[0] only — a smaller mesh would silently
        # drop the other shards' pairs
        raise ValueError(
            f"mesh axes {dict(mesh.shape)} do not provide {n} devices on "
            f"axis {axis_name!r} (one per partition shard)")
    if n == 1:
        mesh = None     # a 1-ring shard_map would only add dispatch cost
    # Emission runs ONCE at the exact per-(shard, band) capacity (it can
    # never truncate); only the deduplicated cross-shard union below grows,
    # so a retry re-runs just the dedup/compact step, never the emission.
    cand = _emit_partition(part, need, mesh, axis_name).reshape(-1, 2)
    cap = max(max_pairs, need)
    while True:
        pairs, count = _dedup_filter(cand, index.device_sigs,
                                     max_pairs=cap, d=d)
        if int(count) <= cap:
            p = np.asarray(pairs[:int(count)])
            return _pairs_to_csr(p, index.size)
        if cap >= max_grow:         # dedup union overran the buffer
            _raise()
        cap = min(cap * 2, max_grow)    # grow-and-retry


def brute_force_collisions(index: SignatureIndex) -> set[tuple[int, int]]:
    """Oracle: enumerate all within-bucket pairs with host loops (exactness
    reference for tests/benchmarks — O(sum m^2), small corpora only)."""
    index._ensure_built()
    out: set[tuple[int, int]] = set()
    for (keys, offsets, ids) in index._csr_np:
        ids = np.asarray(ids)
        offsets = np.asarray(offsets)
        for u in range(len(keys)):
            members = ids[offsets[u]:offsets[u + 1]]
            for a in range(len(members)):
                for b in range(a + 1, len(members)):
                    i, j = int(members[a]), int(members[b])
                    out.add((min(i, j), max(i, j)))
    return out
