"""Similarity graph -> protein families (union-find connected components).

The scored edges of the all-pairs pipeline form a sparse similarity graph;
families are its connected components after thresholding (the classic
single-linkage clustering used by PASTIS-style many-to-many pipelines: an
edge survives if its alignment is strong enough, and transitive closure
groups distant relatives through intermediates).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def union_find(n: int, edges: np.ndarray) -> np.ndarray:
    """Connected-component labels of n nodes under (m, 2) edges.

    Path-halving + union by size, vectorized-ish host loop (edges are few
    after thresholding). Labels are the component's smallest member id, so
    they are stable under edge order.
    """
    parent = np.arange(n, dtype=np.int64)
    size = np.ones(n, dtype=np.int64)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]   # path halving
            x = parent[x]
        return x

    for a, b in np.asarray(edges, np.int64):
        ra, rb = find(int(a)), find(int(b))
        if ra == rb:
            continue
        if size[ra] < size[rb]:
            ra, rb = rb, ra
        parent[rb] = ra
        size[ra] += size[rb]
    # canonical label: smallest member id of each component
    roots = np.fromiter((find(i) for i in range(n)), np.int64, count=n)
    smallest = np.full(n, n, dtype=np.int64)
    np.minimum.at(smallest, roots, np.arange(n, dtype=np.int64))
    return smallest[roots].astype(np.int32)


@dataclass(frozen=True)
class FamilyResult:
    labels: np.ndarray            # (N,) int32 component label per sequence
    families: list[np.ndarray]    # members of each multi-member family
    edge_mask: np.ndarray         # (P,) bool — which input edges survived

    @property
    def n_families(self) -> int:
        return len(self.families)


def cluster_families(n: int, pairs: np.ndarray, pid: np.ndarray | None = None,
                     *, min_pid: float = 50.0,
                     scores: np.ndarray | None = None,
                     min_score: int | None = None) -> FamilyResult:
    """Threshold edges (PID and/or SW score) and extract families.

    ``pairs`` (P, 2); ``pid`` (P,) percent identities (NaN never passes);
    ``scores``/``min_score`` adds an SW-score floor. Families are the
    connected components with >= 2 members, largest first.
    """
    pairs = np.asarray(pairs)
    mask = np.ones(len(pairs), bool)
    if pid is not None:
        with np.errstate(invalid="ignore"):
            mask &= np.nan_to_num(np.asarray(pid), nan=-1.0) >= min_pid
    if min_score is not None:
        if scores is None:
            raise ValueError("min_score needs scores")
        mask &= np.asarray(scores) >= min_score
    labels = union_find(n, pairs[mask])
    uniq, counts = np.unique(labels, return_counts=True)
    fams = [np.flatnonzero(labels == u) for u in uniq[counts >= 2]]
    fams.sort(key=len, reverse=True)
    return FamilyResult(labels=labels, families=fams, edge_mask=mask)
