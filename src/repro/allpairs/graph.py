"""Similarity graph -> protein families (union-find connected components).

The scored edges of the all-pairs pipeline form a sparse similarity graph;
families are its connected components after thresholding (the classic
single-linkage clustering used by PASTIS-style many-to-many pipelines: an
edge survives if its alignment is strong enough, and transitive closure
groups distant relatives through intermediates).

The disjoint-set forest is **persistent** (:class:`FamilyForest`): it
lives beside the index manifest, grows with the corpus
(:meth:`FamilyForest.grow`), and unions each ingest's surviving delta
edges into the standing components — labels are canonicalized to the
component's smallest member id, so the incremental forest is EXACTLY the
from-scratch :func:`union_find` over the concatenated edge set (union
order never changes components, and the canonical label is order-free).

With the fused in-join prefilter (``AllPairsConfig.fuse_prefilter``) the
candidate edges entering this module are already X-drop survivors — the
fused and the wave prefilter share one threshold, so the surviving pair
set (and therefore every component) is identical under both routes. The
``min_score`` floor applies to whichever gap mode scored the edges:
BLOSUM62 thresholds calibrated under linear gaps carry over to affine
(-11/-1) wherever family alignments are gapless, since the two modes
score gapless alignments identically (Gotoh with no gap opened is the
plain match recurrence).
"""
from __future__ import annotations

import os
import zipfile
from dataclasses import dataclass

import numpy as np

from ..faults import atomic_write


class ForestMismatch(ValueError):
    """A persisted family forest that does not belong to the index it was
    loaded for (stale generation, wrong corpus size) or whose own arrays
    are internally inconsistent. Carries the offending ``file``."""

    def __init__(self, file: str, message: str):
        super().__init__(message)
        self.file = file


class FamilyForest:
    """Persistent disjoint-set over a growing corpus.

    Path-halving + union by size, vectorized-ish host loop (edges are few
    after thresholding). ``labels()`` canonicalizes each component to its
    smallest member id — stable under edge order AND under the
    incremental-vs-batch split, which is what makes the persisted forest
    interchangeable with a from-scratch recluster.
    """

    def __init__(self, n: int = 0):
        self.parent = np.arange(n, dtype=np.int64)
        self._size = np.ones(n, dtype=np.int64)

    @property
    def n(self) -> int:
        return len(self.parent)

    def grow(self, n: int) -> None:
        """Extend the forest to ``n`` nodes (new nodes start as singleton
        components — the ingest path calls this before unioning delta
        edges). Shrinking is refused: nodes never leave the corpus."""
        n0 = self.n
        if n < n0:
            raise ValueError(f"forest holds {n0} nodes; cannot shrink "
                             f"to {n}")
        if n == n0:
            return
        self.parent = np.concatenate(
            [self.parent, np.arange(n0, n, dtype=np.int64)])
        self._size = np.concatenate(
            [self._size, np.ones(n - n0, dtype=np.int64)])

    def find(self, x: int) -> int:
        parent = self.parent
        while parent[x] != x:
            parent[x] = parent[parent[x]]   # path halving
            x = parent[x]
        return int(x)

    def union_edges(self, edges: np.ndarray) -> None:
        """Union (m, 2) edges into the standing components."""
        for a, b in np.asarray(edges, np.int64).reshape(-1, 2):
            ra, rb = self.find(int(a)), self.find(int(b))
            if ra == rb:
                continue
            if self._size[ra] < self._size[rb]:
                ra, rb = rb, ra
            self.parent[rb] = ra
            self._size[ra] += self._size[rb]

    def labels(self) -> np.ndarray:
        """(n,) int32 component label per node — the component's smallest
        member id (order-free canonical form)."""
        n = self.n
        roots = np.fromiter((self.find(i) for i in range(n)), np.int64,
                            count=n)
        smallest = np.full(n, n, dtype=np.int64)
        np.minimum.at(smallest, roots, np.arange(n, dtype=np.int64))
        return smallest[roots].astype(np.int32)

    # ------------------------------------------------------------ persistence
    def save(self, path: str | os.PathLike,
             *, generation: int | None = None) -> None:
        """Persist the forest (conventionally ``families.npz`` beside the
        index manifest — the ingest CLI does exactly that). ``generation``
        stamps the index generation the forest was built against, so a
        later load can refuse a forest that went stale (the index was
        compacted or recovered without re-clustering). The write is
        atomic: a crash mid-save leaves the previous forest intact."""
        gen = -1 if generation is None else int(generation)
        meta = np.array([self.n, gen], np.int64)
        atomic_write(path, lambda fh: np.savez_compressed(
            fh, parent=self.parent, size=self._size, meta=meta))

    @classmethod
    def load(cls, path: str | os.PathLike, *,
             expect_n: int | None = None,
             expect_generation: int | None = None) -> "FamilyForest":
        """Load a persisted forest, optionally pinned to the index it must
        belong to. ``expect_n`` is the index's row count and
        ``expect_generation`` its generation; either mismatch raises
        :class:`ForestMismatch` naming the file (a stale forest silently
        mislabeling families is the failure this guards against).
        Pre-PR 8 files carry no metadata and skip the generation check."""
        spath = os.fspath(path)
        try:
            z = np.load(spath)
        except (OSError, EOFError, ValueError, KeyError,
                zipfile.BadZipFile) as err:
            raise ForestMismatch(
                spath, f"family forest {spath} is unreadable (truncated or "
                f"torn write): {type(err).__name__}: {err}") from err
        with z:
            forest = cls(0)
            forest.parent = np.asarray(z["parent"], np.int64).copy()
            forest._size = np.asarray(z["size"], np.int64).copy()
            stored_gen = None
            if "meta" in z.files:
                stored_n, stored_gen = (int(v) for v in z["meta"])
                if stored_n != forest.n:
                    raise ForestMismatch(
                        spath, f"family forest {spath} metadata says "
                        f"{stored_n} nodes but arrays hold {forest.n} — "
                        f"corrupt or hand-edited file")
                if stored_gen < 0:
                    stored_gen = None
        if expect_n is not None and forest.n != expect_n:
            raise ForestMismatch(
                spath, f"family forest {spath} covers {forest.n} nodes but "
                f"the index holds {expect_n} rows — stale forest (recluster "
                f"or re-run ingest)")
        if (expect_generation is not None and stored_gen is not None
                and stored_gen != expect_generation):
            raise ForestMismatch(
                spath, f"family forest {spath} was built at index "
                f"generation {stored_gen} but the index is at generation "
                f"{expect_generation} — stale forest (recluster)")
        return forest


def union_find(n: int, edges: np.ndarray) -> np.ndarray:
    """Connected-component labels of n nodes under (m, 2) edges.

    The from-scratch convenience wrapper over :class:`FamilyForest`;
    labels are the component's smallest member id, so they are stable
    under edge order (and equal to an incrementally grown forest fed the
    same edges in any split).
    """
    forest = FamilyForest(n)
    forest.union_edges(edges)
    return forest.labels()


@dataclass(frozen=True)
class FamilyResult:
    labels: np.ndarray            # (N,) int32 component label per sequence
    families: list[np.ndarray]    # members of each multi-member family
    edge_mask: np.ndarray         # (P,) bool — which input edges survived

    @property
    def n_families(self) -> int:
        return len(self.families)


def threshold_edges(pairs: np.ndarray, pid: np.ndarray | None = None,
                    *, min_pid: float = 50.0,
                    scores: np.ndarray | None = None,
                    min_score: int | None = None) -> np.ndarray:
    """(P,) bool mask of edges passing the PID and/or SW-score floors
    (NaN PID never passes) — shared by the batch clusterer and the
    incremental ingest, so an edge survives identically in both."""
    mask = np.ones(len(pairs), bool)
    if pid is not None:
        with np.errstate(invalid="ignore"):
            mask &= np.nan_to_num(np.asarray(pid), nan=-1.0) >= min_pid
    if min_score is not None:
        if scores is None:
            raise ValueError("min_score needs scores")
        mask &= np.asarray(scores) >= min_score
    return mask


def families_from_labels(labels: np.ndarray) -> list[np.ndarray]:
    """Multi-member components of a label vector, largest first."""
    uniq, counts = np.unique(labels, return_counts=True)
    fams = [np.flatnonzero(labels == u) for u in uniq[counts >= 2]]
    fams.sort(key=len, reverse=True)
    return fams


def cluster_families(n: int, pairs: np.ndarray, pid: np.ndarray | None = None,
                     *, min_pid: float = 50.0,
                     scores: np.ndarray | None = None,
                     min_score: int | None = None) -> FamilyResult:
    """Threshold edges (PID and/or SW score) and extract families.

    ``pairs`` (P, 2); ``pid`` (P,) percent identities (NaN never passes);
    ``scores``/``min_score`` adds an SW-score floor. Families are the
    connected components with >= 2 members, largest first.
    """
    pairs = np.asarray(pairs)
    mask = threshold_edges(pairs, pid, min_pid=min_pid, scores=scores,
                           min_score=min_score)
    labels = union_find(n, pairs[mask])
    return FamilyResult(labels=labels, families=families_from_labels(labels),
                        edge_mask=mask)
