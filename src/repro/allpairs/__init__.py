"""repro.allpairs — many-against-many all-pairs similarity search.

The paper's pipeline is one-directional (queries vs. a reference DB); the
dominant metagenomic workload is all-vs-all over a whole corpus (PASTIS,
arXiv:2009.14467; extreme-scale many-against-many, arXiv:2303.01845). This
subsystem computes the corpus similarity graph on top of the persistent LSH
index:

  corpus -> SignatureIndex.build -> LSH self-join (within-bucket pairs,
  deduped, upper-triangular CSR) -> tiled pair scheduler (length-bucketed
  fixed-shape waves) -> batched Smith-Waterman row-wave scoring (+ PID)
  -> similarity graph -> union-find connected components = protein families

* ``selfjoin`` — :func:`lsh_self_join`: exact band-collision enumeration
  with the grow-and-retry capacity discipline; CSR adjacency output.
* ``tiles``   — :func:`score_pairs`: (tile_i, tile_j) blocks, padded-length
  ladder, *device-resident* batched SW waves — fused on-device gathers
  (corpus uploaded once, per-wave H2D is just pair indices), an optional
  ungapped X-drop prefilter that skips full DP for hopeless pairs, and
  async double-buffered dispatch drained through a small in-flight ring
  (jnp row-wave or the Pallas tile kernel).
* ``graph``   — :func:`cluster_families`: PID/score-thresholded edges,
  union-find components, families largest-first.

Growth is incremental end to end: :func:`all_pairs_ingest` appends new
sequences to the index (append-only segments), delta-joins only the pairs
touching the new rows (:func:`lsh_delta_join` — resident-vs-resident pairs
are never re-enumerated), scores them through the same wave pipeline, and
unions the surviving edges into a persistent disjoint-set
(:class:`~repro.allpairs.graph.FamilyForest`) — families equal a
from-scratch recluster of the grown corpus.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from ..core.pipeline import LSHConfig
from ..index.store import SignatureIndex
from .graph import (FamilyForest, FamilyResult, ForestMismatch,
                    cluster_families, families_from_labels, threshold_edges,
                    union_find)
from .selfjoin import (JoinPrefilter, SelfJoinResult,
                       brute_force_collisions, lsh_delta_join, lsh_self_join)
from .tiles import PairScores, WaveConfig, score_pairs, wave_plan


@dataclass(frozen=True)
class AllPairsConfig:
    lsh: LSHConfig = field(default_factory=lambda: LSHConfig(k=3, T=13, f=32,
                                                             d=1))
    bands: int | None = None     # index bands (default: d+1)
    n_shards: int = 1            # bucket shards for the self-join (each
                                 # device emits its own buckets' pairs);
                                 # pair with wave=WaveConfig(n_devices=...)
                                 # for multi-device SW waves
    hamming_filter: bool = True  # exact-filter candidates at Hamming <= d
    wave: WaveConfig = field(default_factory=lambda: WaveConfig(with_pid=True))
    min_pid: float = 50.0        # family edge threshold (percent identity)
    min_score: int = 60          # edge threshold when waves skip PID
    max_pairs: int = 1 << 16     # initial self-join capacity (grows)
    fuse_prefilter: bool = False  # run the ungapped X-drop prefilter INSIDE
                                  # join emission (rejected pairs never reach
                                  # the host; wave.prefilter_min/xdrop supply
                                  # the threshold) — the surviving pair set
                                  # is bit-exact with the unfused wave
                                  # prefilter, which is then skipped
    join_impl: str = "spgemm"    # candidate-generation orchestration:
                                 # "spgemm" (fused device-resident masked
                                 # A^T A) or "legacy" (pre-SpGEMM host-merge
                                 # path, kept one PR) — identical pair arrays


@dataclass(frozen=True)
class AllPairsResult:
    join: SelfJoinResult         # candidate pair set (CSR adjacency)
    scored: PairScores           # SW scores (+ PID) aligned with join.pairs
    families: FamilyResult       # thresholded components
    index: SignatureIndex        # the corpus index (reusable/persistable)

    @property
    def pairs(self) -> np.ndarray:
        return self.join.pairs

    @property
    def labels(self) -> np.ndarray:
        return self.families.labels


def _join_prefilter(cfg: AllPairsConfig, ids, lens):
    """The fused in-join prefilter (and the prefilter-free wave to pair it
    with): thresholds come from the SAME WaveConfig knobs as the unfused
    wave prefilter, so fusing never changes which pairs survive."""
    if not cfg.fuse_prefilter:
        return None, cfg.wave
    pf = JoinPrefilter(ids=ids, lens=lens, min_score=cfg.wave.prefilter_min,
                       x=cfg.wave.xdrop, batch=cfg.wave.prefilter_batch,
                       len_quantum=cfg.wave.len_quantum)
    return pf, replace(cfg.wave, prefilter=False)


def all_pairs_search(ids, lens, cfg: AllPairsConfig | None = None,
                     *, index: SignatureIndex | None = None) -> AllPairsResult:
    """Corpus in, protein families out (the subsystem's one-call driver).

    ``index=`` reuses a prebuilt/loaded :class:`SignatureIndex` over the
    same corpus (the paper's pay-once economics applied to the self-join).
    """
    cfg = cfg or AllPairsConfig()
    ids = np.asarray(ids, np.int8)
    lens = np.asarray(lens, np.int32)
    if index is None:
        index = SignatureIndex.build(cfg.lsh, ids, lens, bands=cfg.bands,
                                     n_shards=cfg.n_shards)
    elif index.size != len(lens):
        raise ValueError(f"index covers {index.size} sequences, corpus has "
                         f"{len(lens)}")
    pf, wave = _join_prefilter(cfg, ids, lens)
    join = lsh_self_join(index, d=cfg.lsh.d if cfg.hamming_filter else None,
                         max_pairs=cfg.max_pairs, n_shards=cfg.n_shards,
                         prefilter=pf, join_impl=cfg.join_impl)
    scored = score_pairs(ids, lens, join.pairs, wave)
    if cfg.wave.with_pid:
        families = cluster_families(index.size, join.pairs, scored.pid,
                                    min_pid=cfg.min_pid)
    else:       # score-only waves (e.g. the Pallas kernel path)
        families = cluster_families(index.size, join.pairs, None,
                                    scores=scored.scores,
                                    min_score=cfg.min_score)
    return AllPairsResult(join=join, scored=scored, families=families,
                          index=index)


def _edge_mask(scored: PairScores, cfg: AllPairsConfig, pairs) -> np.ndarray:
    """The one edge-survival rule, shared by batch search and ingest."""
    if cfg.wave.with_pid:
        return threshold_edges(pairs, scored.pid, min_pid=cfg.min_pid)
    return threshold_edges(pairs, None, scores=scored.scores,
                           min_score=cfg.min_score)


def forest_from_result(res: AllPairsResult) -> FamilyForest:
    """Seed a persistent forest from a batch run's surviving edges — the
    handoff point from :func:`all_pairs_search` to incremental ingest."""
    forest = FamilyForest(res.index.size)
    forest.union_edges(res.pairs[res.families.edge_mask])
    return forest


@dataclass(frozen=True)
class IngestResult:
    """One incremental ingest: the delta candidate pairs, their scores, and
    the grown corpus's family labels from the persistent forest."""
    join: SelfJoinResult         # DELTA pairs only (>= 1 row is new)
    scored: PairScores           # aligned with join.pairs
    edge_mask: np.ndarray        # which delta pairs survived the threshold
    labels: np.ndarray           # (N,) labels over the GROWN corpus
    forest: FamilyForest         # the updated persistent disjoint-set

    @property
    def families(self) -> list[np.ndarray]:
        return families_from_labels(self.labels)


def all_pairs_ingest(ids, lens, base_size: int,
                     cfg: AllPairsConfig | None = None, *,
                     index: SignatureIndex,
                     forest: FamilyForest) -> IngestResult:
    """Grow the corpus incrementally: rows ``[base_size:]`` of ``ids/lens``
    are new; everything before is the resident corpus ``index`` and
    ``forest`` already cover.

    Appends the new rows to the index (append-only segment) unless the
    caller already did, delta-joins only the pairs touching new rows,
    scores them through the standard wave pipeline, and unions the
    surviving edges into ``forest``. The resulting labels are EXACTLY what
    a from-scratch :func:`all_pairs_search` over the grown corpus produces
    (asserted in tests/test_lifecycle.py) — at delta cost, the paper's
    "data grows faster than compute" economics applied to clustering.
    """
    cfg = cfg or AllPairsConfig()
    ids = np.asarray(ids, np.int8)
    lens = np.asarray(lens, np.int32)
    # validate BEFORE mutating: a stale forest must not leave the index
    # grown (and out of sync with the caller's labels) on the error path
    if forest.n not in (base_size, len(lens)):
        raise ValueError(f"forest covers {forest.n} nodes; expected "
                         f"{base_size} or {len(lens)}")
    if index.size == base_size:
        index.add(ids[base_size:], lens[base_size:])
    elif index.size != len(lens):
        raise ValueError(
            f"index covers {index.size} sequences; expected the resident "
            f"{base_size} (add() pending) or the grown {len(lens)}")
    pf, wave = _join_prefilter(cfg, ids, lens)
    join = lsh_delta_join(index, base_size=base_size,
                          d=cfg.lsh.d if cfg.hamming_filter else None,
                          max_pairs=cfg.max_pairs, n_shards=cfg.n_shards,
                          prefilter=pf, join_impl=cfg.join_impl)
    scored = score_pairs(ids, lens, join.pairs, wave)
    mask = _edge_mask(scored, cfg, join.pairs)
    forest.grow(index.size)
    forest.union_edges(join.pairs[mask])
    return IngestResult(join=join, scored=scored, edge_mask=mask,
                        labels=forest.labels(), forest=forest)


__all__ = [
    "AllPairsConfig", "AllPairsResult", "all_pairs_search",
    "IngestResult", "all_pairs_ingest", "forest_from_result",
    "SelfJoinResult", "JoinPrefilter", "lsh_self_join", "lsh_delta_join",
    "brute_force_collisions",
    "WaveConfig", "PairScores", "score_pairs", "wave_plan",
    "FamilyResult", "FamilyForest", "cluster_families", "threshold_edges",
    "families_from_labels", "union_find",
]
