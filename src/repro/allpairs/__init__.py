"""repro.allpairs — many-against-many all-pairs similarity search.

The paper's pipeline is one-directional (queries vs. a reference DB); the
dominant metagenomic workload is all-vs-all over a whole corpus (PASTIS,
arXiv:2009.14467; extreme-scale many-against-many, arXiv:2303.01845). This
subsystem computes the corpus similarity graph on top of the persistent LSH
index:

  corpus -> SignatureIndex.build -> LSH self-join (within-bucket pairs,
  deduped, upper-triangular CSR) -> tiled pair scheduler (length-bucketed
  fixed-shape waves) -> batched Smith-Waterman row-wave scoring (+ PID)
  -> similarity graph -> union-find connected components = protein families

* ``selfjoin`` — :func:`lsh_self_join`: exact band-collision enumeration
  with the grow-and-retry capacity discipline; CSR adjacency output.
* ``tiles``   — :func:`score_pairs`: (tile_i, tile_j) blocks, padded-length
  ladder, *device-resident* batched SW waves — fused on-device gathers
  (corpus uploaded once, per-wave H2D is just pair indices), an optional
  ungapped X-drop prefilter that skips full DP for hopeless pairs, and
  async double-buffered dispatch drained through a small in-flight ring
  (jnp row-wave or the Pallas tile kernel).
* ``graph``   — :func:`cluster_families`: PID/score-thresholded edges,
  union-find components, families largest-first.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.pipeline import LSHConfig
from ..index.store import SignatureIndex
from .graph import FamilyResult, cluster_families, union_find
from .selfjoin import SelfJoinResult, brute_force_collisions, lsh_self_join
from .tiles import PairScores, WaveConfig, score_pairs, wave_plan


@dataclass(frozen=True)
class AllPairsConfig:
    lsh: LSHConfig = field(default_factory=lambda: LSHConfig(k=3, T=13, f=32,
                                                             d=1))
    bands: int | None = None     # index bands (default: d+1)
    n_shards: int = 1            # bucket shards for the self-join (each
                                 # device emits its own buckets' pairs);
                                 # pair with wave=WaveConfig(n_devices=...)
                                 # for multi-device SW waves
    hamming_filter: bool = True  # exact-filter candidates at Hamming <= d
    wave: WaveConfig = field(default_factory=lambda: WaveConfig(with_pid=True))
    min_pid: float = 50.0        # family edge threshold (percent identity)
    min_score: int = 60          # edge threshold when waves skip PID
    max_pairs: int = 1 << 16     # initial self-join capacity (grows)


@dataclass(frozen=True)
class AllPairsResult:
    join: SelfJoinResult         # candidate pair set (CSR adjacency)
    scored: PairScores           # SW scores (+ PID) aligned with join.pairs
    families: FamilyResult       # thresholded components
    index: SignatureIndex        # the corpus index (reusable/persistable)

    @property
    def pairs(self) -> np.ndarray:
        return self.join.pairs

    @property
    def labels(self) -> np.ndarray:
        return self.families.labels


def all_pairs_search(ids, lens, cfg: AllPairsConfig | None = None,
                     *, index: SignatureIndex | None = None) -> AllPairsResult:
    """Corpus in, protein families out (the subsystem's one-call driver).

    ``index=`` reuses a prebuilt/loaded :class:`SignatureIndex` over the
    same corpus (the paper's pay-once economics applied to the self-join).
    """
    cfg = cfg or AllPairsConfig()
    ids = np.asarray(ids, np.int8)
    lens = np.asarray(lens, np.int32)
    if index is None:
        index = SignatureIndex.build(cfg.lsh, ids, lens, bands=cfg.bands,
                                     n_shards=cfg.n_shards)
    elif index.size != len(lens):
        raise ValueError(f"index covers {index.size} sequences, corpus has "
                         f"{len(lens)}")
    join = lsh_self_join(index, d=cfg.lsh.d if cfg.hamming_filter else None,
                         max_pairs=cfg.max_pairs, n_shards=cfg.n_shards)
    scored = score_pairs(ids, lens, join.pairs, cfg.wave)
    if cfg.wave.with_pid:
        families = cluster_families(index.size, join.pairs, scored.pid,
                                    min_pid=cfg.min_pid)
    else:       # score-only waves (e.g. the Pallas kernel path)
        families = cluster_families(index.size, join.pairs, None,
                                    scores=scored.scores,
                                    min_score=cfg.min_score)
    return AllPairsResult(join=join, scored=scored, families=families,
                          index=index)


__all__ = [
    "AllPairsConfig", "AllPairsResult", "all_pairs_search",
    "SelfJoinResult", "lsh_self_join", "brute_force_collisions",
    "WaveConfig", "PairScores", "score_pairs", "wave_plan",
    "FamilyResult", "cluster_families", "union_find",
]
