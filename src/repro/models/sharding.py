"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Physical mesh axes: ("pod", "data", "model") multi-pod, ("data", "model")
single-pod. Logical axes used by the model code:

  batch   -> ("pod", "data")   pure DP (pods are extra DP)
  embed   -> "data"            FSDP / ZeRO-3: params sharded on d_model over
                               the data axis; XLA all-gathers per layer inside
                               the scan (gather size = one layer's params)
  heads   -> "model"           Megatron TP for attention (iff divisible)
  kv      -> "model" iff n_kv_heads % model == 0 else replicated
  mlp     -> "model"           TP for the FFN hidden dim
  experts -> "model"           expert parallelism
  vocab   -> "model"           sharded logits/embedding rows
  seq     -> None              (sequence kept whole; KV cache of long-decode
                               shards seq on "model")

Archs whose n_heads is not divisible by the model axis (qwen2-vl 28H,
recurrentgemma 10H) replicate attention over "model" and carry TP in the MLP
(DESIGN.md §5).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def make_rules(cfg, mesh: Mesh, *, fsdp: bool = True) -> dict[str, object]:
    """Resolve logical axes -> physical axes for this (config, mesh).

    fsdp=False selects ZeRO-1: compute params replicate over "data" (no
    per-layer/per-microbatch regather); optimizer state keeps the full FSDP
    sharding regardless (see launch/dryrun.py ZERO1_ARCHS + EXPERIMENTS.md
    §Perf hillclimb A).
    """
    model = _axis_size(mesh, "model")
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    heads_ok = cfg.n_heads % model == 0
    kv_ok = cfg.n_kv_heads % model == 0
    # Array-level NamedShardings require even tiling (GSPMD pads only
    # *internal* values): granite-3-8b's vocab 49155 therefore keeps its
    # embedding replicated over "model" and FSDP-sharded on the embed dim.
    vocab_ok = cfg.vocab_size % model == 0
    rules = {
        "batch": dp,
        "embed": "data" if fsdp else None,
        "heads": "model" if heads_ok else None,
        "kv": "model" if (heads_ok and kv_ok) else None,
        "mlp": "model",
        "experts": "model",
        "vocab": "model" if vocab_ok else None,
        "seq": None,
        "kv_seq": "model",   # long-context decode: shard the KV cache on seq
        "_mesh": mesh,       # carried for shard_map sub-regions (seq-parallel
                             # decode attention); not a logical axis
    }
    return rules


def logical(spec: tuple[str | None, ...], rules) -> P:
    """Translate a logical spec tuple to a PartitionSpec."""
    return P(*[rules.get(a) if a is not None else None for a in spec])


def constrain(x, rules, *spec):
    """with_sharding_constraint under a mesh context (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, logical(spec, rules))
    except (ValueError, RuntimeError):
        return x  # no mesh context (e.g. plain CPU tests)


# ------------------------------------------------------------ param specs
def param_spec_tree(params, cfg, rules):
    """PartitionSpec pytree matching init_params' structure.

    Conventions (see model.init_params):
      embedding      (vocab, embed)            -> (vocab, embed)
      lm_head        (embed, vocab)            -> (embed, vocab)
      attn wq/wo     (embed, heads*hd)         -> (embed, heads)
      attn wk/wv     (embed, kv*hd)            -> (embed, kv)
      mlp wi/wg      (embed, mlp)              -> (embed, mlp)
      mlp wo         (mlp, embed)              -> (mlp, embed)
      moe w* (E, ...)                          -> (experts, embed/mlp)
      rglru/lstm matrices (embed, X)           -> (embed, None)
      scanned leaves have a leading layer-group axis -> None prefix
    """
    mesh = rules.get("_mesh")

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        # leading scan axis for stacked block params
        prefix = ("blocks",) if path.startswith("blocks/") else ()
        lead = (None,) * len(prefix)

        def L(*axes):
            return logical(lead + axes, rules)

        name = path.split("/")[-1]
        if name == "embedding":
            return logical(("vocab", "embed"), rules)
        if name == "lm_head":
            return logical(("embed", "vocab"), rules)
        if name in ("wq", "wo_attn"):
            return L("embed", "heads") if name == "wq" else L("heads", "embed")
        if name in ("wk", "wv"):
            return L("embed", "kv")
        if name in ("wi", "wg"):
            return L("embed", "mlp")
        if name == "wo":
            return L("mlp", "embed")
        if name == "router":
            return L("embed", "experts")
        # MoE experts: EP on "model" via the experts axis; the per-expert ff
        # dim stays unsharded (it already lives on the same axis via E).
        if name in ("ewi", "ewg"):      # (E, d, ff)
            return L("experts", "embed", None)
        if name == "ewo":               # (E, ff, d)
            return L("experts", None, "embed")
        if name in ("w_in", "w_gate"):  # rglru up-projections (d, dr)
            return L("embed", "mlp")
        if name == "conv_w":            # (conv_width, dr)
            return L(None, "mlp")
        # recurrent / misc matrices: FSDP on dim0 when it divides the axis
        if nd - len(prefix) == 2:
            data_n = mesh.shape["data"] if mesh is not None else 1
            dim0 = leaf.shape[len(prefix)]
            return L("embed" if dim0 % max(data_n, 1) == 0 else None, None)
        return L(*((None,) * (nd - len(prefix))))

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    for kp, leaf in flat:
        path = "/".join(getattr(k, "key", str(k)) for k in kp)
        specs[path] = spec_for(path, leaf)
    # rebuild as tree
    treedef = jax.tree_util.tree_structure(params)
    leaves = [specs["/".join(getattr(k, "key", str(k)) for k in kp)]
              for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def shard_params(params, cfg, mesh: Mesh):
    rules = make_rules(cfg, mesh)
    specs = param_spec_tree(params, cfg, rules)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)


def cache_spec_tree(cache, cfg, rules):
    """PartitionSpec pytree for a decode cache (models.init_cache structure).

    Global-attention KV caches shard their SEQUENCE axis on "model"
    (sequence-parallel decode); ring (windowed) caches and recurrent states
    are small and stay batch-sharded only. Batch stays on the DP axes when it
    divides them, else replicated (long_500k has global_batch=1).
    """
    mesh = rules["_mesh"]
    n_model = mesh.shape["model"]
    batch_axes = rules["batch"] if isinstance(rules["batch"], tuple) \
        else (rules["batch"],)
    dp_total = 1
    for a in batch_axes:
        dp_total *= mesh.shape[a] if a else 1

    def kind_of(path: str) -> str | None:
        parts = path.split("/")
        for p in parts:
            if p.startswith("b") and p[1:].isdigit():
                return cfg.block_pattern[int(p[1:])]
            if p.startswith("r") and p[1:].isdigit():
                return cfg.block_pattern[int(p[1:])]
        return None

    def spec_for(path: str, leaf) -> P:
        lead = (None,) if path.startswith("blocks/") else ()
        kind = kind_of(path)
        name = path.split("/")[-1]
        nd = leaf.ndim - len(lead)
        if kind in ("attn", "local_attn"):
            is_ring = (kind == "local_attn" and cfg.window)
            if name in ("k", "v"):
                B, Smax = leaf.shape[len(lead)], leaf.shape[len(lead) + 1]
                b = rules["batch"] if B % dp_total == 0 else None
                s = "model" if (not is_ring and Smax % n_model == 0) else None
                return P(*lead, b, s, None, None)
            if name == "pos":
                Smax = leaf.shape[len(lead)]
                s = "model" if (not is_ring and Smax % n_model == 0) else None
                return P(*lead, s)
        # recurrent states & ring misc: batch on dp when divisible
        B = leaf.shape[len(lead)] if nd >= 1 else 1
        b = rules["batch"] if (nd >= 1 and B % dp_total == 0) else None
        return P(*lead, b, *([None] * (nd - 1)))

    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    leaves = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        leaves.append(spec_for(path, leaf))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, leaves)
