"""Recurrent blocks: Griffin RG-LRU (recurrentgemma) and xLSTM (mLSTM/sLSTM).

These are the sub-quadratic archs that run the long_500k shape. Training/
prefill uses parallel forms (associative scan for RG-LRU; chunkwise-parallel
for mLSTM); decode uses O(1)-state recurrent steps — the whole point of
running 500k-token decode on them.

States are fp32 regardless of activation dtype (carried across long
horizons; bf16 recurrences drift).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import norm, _act
from .sharding import constrain

RGLRU_C = 8.0


# ------------------------------------------------------------ causal conv1d
def causal_conv1d(x, w, state=None):
    """Depthwise causal conv: x (B,S,D), w (W,D). state: (B,W-1,D) | None.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return y, new_state


# ------------------------------------------------------------ RG-LRU
def rglru(x, p, state=None):
    """Real-Gated Linear Recurrent Unit (Griffin eq. 1-4).

    x: (B,S,D). p: dict(wa (D,D_in? -> here gates from x itself: (D,) params)
    — gates are elementwise from projections: r = σ(x@Wa+ba), i = σ(x@Wx+bx),
    a = exp(-c·softplus(Λ)·r); h_t = a·h_{t-1} + sqrt(1-a²)·(i·x).
    state: (B,D) fp32 h_{-1}. Returns (h (B,S,D), h_last).
    Parallel mode uses associative_scan over time.
    """
    B, S, D = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"] + p["bx"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r            # (B,S,D) < 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xf)

    if state is not None:
        # fold the carried state into the first step's additive term
        b = b.at[:, 0].add(a[:, 0] * state)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = bv                                                       # (B,S,D)
    return h.astype(x.dtype), h[:, -1]


def rglru_block(x, p, cfg, rules, *, state=None):
    """Griffin recurrent block: [linear -> conv1d -> RG-LRU] ⊙ gelu(linear).

    state: None | dict(conv (B,W-1,D), h (B,D)). Returns (out, new_state).
    """
    h = norm(x, p["norm"], cfg.norm_type)
    u = h @ p["w_in"]                                            # (B,S,Dr)
    u = constrain(u, rules, "batch", None, "mlp")
    g = jax.nn.gelu(h @ p["w_gate"])
    g = constrain(g, rules, "batch", None, "mlp")
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv1d(u, p["conv_w"], conv_state)
    h_state = state["h"] if state is not None else None
    y, h_last = rglru(u, p, state=h_state)
    out = (y * g) @ p["w_out"]
    out = constrain(out, rules, "batch", None, None)
    new_state = ({"conv": new_conv, "h": h_last}
                 if state is not None else None)
    return out, new_state


# ------------------------------------------------------------ mLSTM
def mlstm_chunked(q, k, v, i_raw, f_raw, *, chunk: int, state=None):
    """Chunkwise-parallel mLSTM (xLSTM §2.3), stabilized.

    q,k,v: (B,S,H,Dh); i_raw,f_raw: (B,S,H) pre-activation gates.
    state: None | (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)) fp32.
    Returns (h (B,S,H,Dh), new_state).
    """
    B, S, H, Dh = q.shape
    c = min(chunk, S)
    nc = -(-S // c)
    pad = nc * c - S

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    qf = pad_t(q).astype(jnp.float32).reshape(B, nc, c, H, Dh) / math.sqrt(Dh)
    kf = pad_t(k).astype(jnp.float32).reshape(B, nc, c, H, Dh)
    vf = pad_t(v).astype(jnp.float32).reshape(B, nc, c, H, Dh)
    lf = jax.nn.log_sigmoid(pad_t(f_raw).astype(jnp.float32)
                            ).reshape(B, nc, c, H)
    li = pad_t(i_raw).astype(jnp.float32).reshape(B, nc, c, H)

    if state is None:
        C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
        n0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, blk):
        C, n, m = carry
        qb, kb, vb, lfb, lib = blk                # (B,c,H,*) time-major slice
        F = jnp.cumsum(lfb, axis=1)               # (B,c,H) Σ log f (1..t)
        # stabilizer: running max of (F_t + m_prev) and intra (F_t - F_j + li_j)
        a_intra = F[:, :, None, :] - F[:, None, :, :] + lib[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        a_intra = jnp.where(tri[None, :, :, None], a_intra, -1e30)
        m_inter = F + m[:, None, :]               # (B,c,H)
        m_new_t = jnp.maximum(jnp.max(a_intra, axis=2), m_inter)  # (B,c,H)
        # intra-chunk quadratic term
        w = jnp.exp(a_intra - m_new_t[:, :, None, :])             # (B,c,c,H)
        s = jnp.einsum("bthd,bjhd->btjh", qb, kb)
        h_intra = jnp.einsum("btjh,btjh,bjhd->bthd", s, w, vb)
        qn_intra = jnp.einsum("btjh,btjh->bth", s, w)   # q·(Σ w_j k_j)
        # inter-chunk term from carried state
        scale_inter = jnp.exp(m_inter - m_new_t)                  # (B,c,H)
        h_inter = jnp.einsum("bthd,bhde->bthe", qb, C) * scale_inter[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qb, n) * scale_inter
        qn = qn_intra + n_inter
        h = (h_intra + h_inter) / jnp.maximum(
            jnp.abs(qn), jnp.exp(-m_new_t))[..., None]
        # chunk-end state update
        F_end = F[:, -1][:, None, :]                              # (B,1,H)
        m_end = jnp.maximum(F_end[:, 0] + m,
                            jnp.max(F_end - F + lib, axis=1))     # (B,H)
        wk = jnp.exp(F_end - F + lib - m_end[:, None, :])         # (B,c,H)
        C_new = C * jnp.exp(F_end[:, 0] + m - m_end)[..., None, None] \
            + jnp.einsum("bthd,bth,bthe->bhde", kb, wk, vb)
        n_new = n * jnp.exp(F_end[:, 0] + m - m_end)[..., None] \
            + jnp.einsum("bthd,bth->bhd", kb, wk)
        return (C_new, n_new, m_end), h

    blks = tuple(jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, lf, li))
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), blks)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, nc * c, H, Dh)[:, :S]
    return h.astype(q.dtype), (Cf, nf, mf)


def mlstm_block(x, p, cfg, rules, *, state=None):
    """mLSTM block: qkv + exponential gating + matrix memory + gated output."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    h = norm(x, p["norm"], cfg.norm_type)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, H, hd)
    v = (h @ p["wv"]).reshape(B, S, H, hd)
    i_raw = (h @ p["wi_gate"]).reshape(B, S, H)
    f_raw = (h @ p["wf_gate"]).reshape(B, S, H) + 1.0   # forget bias init
    y, new_state = mlstm_chunked(q, k, v, i_raw, f_raw,
                                 chunk=cfg.attn_chunk,
                                 state=state)
    o = jax.nn.sigmoid(h @ p["wo_gate"]).reshape(B, S, H, hd)
    out = (y * o).reshape(B, S, H * hd) @ p["w_out"]
    return constrain(out, rules, "batch", None, None), new_state


# ------------------------------------------------------------ sLSTM
def slstm_block(x, p, cfg, rules, *, state=None):
    """sLSTM: scalar memory, exponential gating, recurrent head mixing.

    Sequential by construction (h_{t-1} feeds the gates through R matrices);
    lax.scan over time. state: (c, n, h, m) each (B, H, hd) fp32.
    """
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xn = norm(x, p["norm"], cfg.norm_type).astype(jnp.float32)
    zx = (xn @ p["wz"]).reshape(B, S, H, hd)
    ix = (xn @ p["wi"]).reshape(B, S, H, hd)
    fx = (xn @ p["wf"]).reshape(B, S, H, hd)
    ox = (xn @ p["wo_g"]).reshape(B, S, H, hd)

    if state is None:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        state = (zeros, zeros, zeros, zeros - 1e30)  # c, n, h, m

    Rz, Ri, Rf, Ro = p["rz"], p["ri"], p["rf"], p["ro"]  # (H, hd, hd)

    def step(carry, inp):
        c, n, hprev, m = carry
        zt, it, ft, ot = inp                              # (B,H,hd)
        zr = jnp.einsum("bhd,hde->bhe", hprev, Rz)
        ir = jnp.einsum("bhd,hde->bhe", hprev, Ri)
        fr = jnp.einsum("bhd,hde->bhe", hprev, Rf)
        orr = jnp.einsum("bhd,hde->bhe", hprev, Ro)
        z = jnp.tanh(zt + zr)
        li = it + ir                                      # log-space input gate
        lf = jax.nn.log_sigmoid(ft + fr)
        m_new = jnp.maximum(lf + m, li)
        i_g = jnp.exp(li - m_new)
        f_g = jnp.exp(lf + m - m_new)
        c_new = f_g * c + i_g * z
        n_new = f_g * n + i_g
        o_g = jax.nn.sigmoid(ot + orr)
        h_new = o_g * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    inps = tuple(jnp.moveaxis(t, 1, 0) for t in (zx, ix, fx, ox))
    new_state, hs = jax.lax.scan(step, state, inps)
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, H * hd).astype(x.dtype)
    out = y @ p["w_out"]
    return constrain(out, rules, "batch", None, None), new_state
