"""Architecture config schema covering all 10 assigned architectures.

One dataclass, one block vocabulary:
  'attn'       global GQA attention + MLP        (dense/moe/vlm archs)
  'local_attn' sliding-window GQA + MLP          (recurrentgemma)
  'rglru'      Griffin RG-LRU recurrent block    (recurrentgemma)
  'mlstm'      xLSTM matrix-memory block         (xlstm)
  'slstm'      xLSTM scalar-memory block         (xlstm)
`block_pattern` cycles over layers; scan-over-layers groups whole pattern
repeats (HLO stays O(1) in depth), the remainder is applied unrolled.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encoder | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None    # default d_model // n_heads

    # block structure
    block_pattern: tuple[str, ...] = ("attn",)
    is_encoder: bool = False       # bidirectional attention, no decode step
    window: int | None = None      # sliding window for 'local_attn'

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # MLP
    mlp_act: str = "silu"          # silu | gelu | relu2 (squared ReLU)
    mlp_gated: bool = True         # SwiGLU-style gate

    # misc
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embedding_inputs: bool = False  # vlm/audio: frontend supplies (B,S,d) embeds
    rnn_width: int | None = None    # RG-LRU recurrence width (default d_model)
    conv_width: int = 4             # temporal conv in recurrent blocks

    # numerics / training structure
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024          # flash chunk (queries and kv)
    ce_chunk: int = 512             # chunked cross-entropy sequence chunk
    causal_skip: bool = False       # triangular attention schedule (§Perf B)

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0
        assert self.n_layers >= len(self.block_pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        """Number of whole block-pattern repeats (the scan length)."""
        return self.n_layers // len(self.block_pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers - self.n_groups * len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config variant (smoke tests)."""
        return replace(self, **kw)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embeddings + blocks), for 6·N·D roofline."""
    d, hd = cfg.d_model, cfg.hd
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    counts = {"attn": 0, "local_attn": 0, "rglru": 0, "mlstm": 0, "slstm": 0}
    attn = d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2
    if cfg.is_moe:
        mlp = cfg.n_experts * (d * cfg.d_ff * (3 if cfg.mlp_gated else 2))
        mlp += d * cfg.n_experts  # router
    else:
        mlp = d * cfg.d_ff * (3 if cfg.mlp_gated else 2)
    counts["attn"] = attn + mlp + 2 * d
    counts["local_attn"] = counts["attn"]
    rw = cfg.rnn_width or d
    counts["rglru"] = (d * rw * 2 + rw * cfg.conv_width + rw * 2 + d * rw
                       + mlp + 2 * d)
    counts["mlstm"] = (d * (cfg.n_heads * hd) * 3 + cfg.n_heads * hd * 2
                       + d * cfg.n_heads * hd + 2 * cfg.n_heads * hd * d // d
                       + mlp + 2 * d)
    counts["slstm"] = (d * (cfg.n_heads * hd) * 4 + cfg.n_heads * hd * hd * 4
                       + mlp + 2 * d)
    for i in range(cfg.n_layers):
        total += counts[cfg.block_pattern[i % len(cfg.block_pattern)]]
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) params for MoE 6·N_active·D."""
    if not cfg.is_moe:
        return param_count(cfg)
    d = cfg.d_model
    full = param_count(cfg)
    moe_total = cfg.n_layers * cfg.n_experts * (
        d * cfg.d_ff * (3 if cfg.mlp_gated else 2))
    moe_active = cfg.n_layers * cfg.experts_per_token * (
        d * cfg.d_ff * (3 if cfg.mlp_gated else 2))
    return full - moe_total + moe_active
