"""Model assembly: scan-over-layers decoder/encoder covering all 10 archs.

Layers are grouped by whole repeats of `cfg.block_pattern`; the repeated
group is a single `lax.scan` body (HLO size O(1) in depth — essential for
the 62-cell dry-run compile budget and for fast compiles at scale), with the
pattern remainder applied unrolled. Params for scanned groups have a leading
(G, ...) axis, built by vmap'ing the per-group initializer.

Cross-entropy is chunked over the sequence axis with vocab sharded on
"model": the (B, S, V) logits tensor never exists (vocab 256000 x 4k tokens
per device would be ~34 GB otherwise).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import attention_block, mlp_block, moe_block, norm
from .recurrent import mlstm_block, rglru_block, slstm_block
from .sharding import constrain

MOE_AUX_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


# ------------------------------------------------------------ init
def _dense(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _init_block(key, kind: str, cfg: ModelConfig):
    d, H, Kh, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                        cfg.d_ff)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 24)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers * max(ff, d))
    p = {}
    if kind in ("attn", "local_attn"):
        p["attn"] = {
            "norm": jnp.ones((d,), dt),
            "wq": _dense(ks[0], (d, H * hd), dtype=dt),
            "wk": _dense(ks[1], (d, Kh * hd), dtype=dt),
            "wv": _dense(ks[2], (d, Kh * hd), dtype=dt),
            "wo_attn": _dense(ks[3], (H * hd, d), out_scale, dt),
        }
    elif kind == "rglru":
        dr = cfg.rnn_width or d
        p["rglru"] = {
            "norm": jnp.ones((d,), dt),
            "w_in": _dense(ks[0], (d, dr), dtype=dt),
            "w_gate": _dense(ks[1], (d, dr), dtype=dt),
            "conv_w": _dense(ks[2], (cfg.conv_width, dr), 0.1, dt),
            "wa": _dense(ks[3], (dr, dr)), "ba": jnp.zeros((dr,)),
            "wx": _dense(ks[4], (dr, dr)), "bx": jnp.zeros((dr,)),
            "lam": jnp.full((dr,), 0.5, jnp.float32),
            "w_out": _dense(ks[5], (dr, d), out_scale, dt),
        }
    elif kind == "mlstm":
        p["mlstm"] = {
            "norm": jnp.ones((d,), dt),
            "wq": _dense(ks[0], (d, H * hd), dtype=dt),
            "wk": _dense(ks[1], (d, H * hd), dtype=dt),
            "wv": _dense(ks[2], (d, H * hd), dtype=dt),
            "wi_gate": _dense(ks[3], (d, H), dtype=dt),
            "wf_gate": _dense(ks[4], (d, H), dtype=dt),
            "wo_gate": _dense(ks[5], (d, H * hd), dtype=dt),
            "w_out": _dense(ks[6], (H * hd, d), out_scale, dt),
        }
    elif kind == "slstm":
        p["slstm"] = {
            "norm": jnp.ones((d,), dt),
            "wz": _dense(ks[0], (d, H * hd), dtype=dt),
            "wi": _dense(ks[1], (d, H * hd), dtype=dt),
            "wf": _dense(ks[2], (d, H * hd), dtype=dt),
            "wo_g": _dense(ks[3], (d, H * hd), dtype=dt),
            "rz": _dense(ks[4], (H, hd, hd), 1.0 / math.sqrt(hd)),
            "ri": _dense(ks[5], (H, hd, hd), 1.0 / math.sqrt(hd)),
            "rf": _dense(ks[6], (H, hd, hd), 1.0 / math.sqrt(hd)),
            "ro": _dense(ks[7], (H, hd, hd), 1.0 / math.sqrt(hd)),
            "w_out": _dense(ks[8], (H * hd, d), out_scale, dt),
        }
    else:
        raise ValueError(kind)

    if ff > 0:
        if cfg.is_moe and kind in ("attn", "local_attn"):
            E = cfg.n_experts
            p["moe"] = {
                "norm": jnp.ones((d,), dt),
                "router": _dense(ks[10], (d, E), dtype=jnp.float32),
                "ewi": _dense(ks[11], (E, d, ff), 1.0 / math.sqrt(d), dt),
                "ewo": _dense(ks[13], (E, ff, d), out_scale, dt),
            }
            if cfg.mlp_gated:
                p["moe"]["ewg"] = _dense(ks[12], (E, d, ff),
                                         1.0 / math.sqrt(d), dt)
        else:
            p["mlp"] = {
                "norm": jnp.ones((d,), dt),
                "wi": _dense(ks[10], (d, ff), dtype=dt),
                "wo": _dense(ks[12], (ff, d), out_scale, dt),
            }
            if cfg.mlp_gated:
                p["mlp"]["wg"] = _dense(ks[11], (d, ff), dtype=dt)
    return p


def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    kemb, khead, kblocks, krem = jax.random.split(key, 4)
    params = {
        "embedding": _dense(kemb, (cfg.vocab_size, cfg.d_model), 0.02,
                            jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(khead, (cfg.d_model, cfg.vocab_size),
                                   dtype=jnp.float32)

    def init_group(k):
        kk = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}": _init_block(kk[i], kind, cfg)
                for i, kind in enumerate(cfg.block_pattern)}

    G = cfg.n_groups
    params["blocks"] = jax.vmap(init_group)(jax.random.split(kblocks, G))
    if cfg.n_remainder:
        kr = jax.random.split(krem, cfg.n_remainder)
        params["rem"] = {
            f"r{i}": _init_block(kr[i], cfg.block_pattern[i], cfg)
            for i in range(cfg.n_remainder)}
    return params


# ------------------------------------------------------------ cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree, mirroring the params structure."""
    Kh, hd = cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)

    def block_cache(kind: str):
        if kind in ("attn", "local_attn"):
            Smax = cfg.window if (kind == "local_attn" and cfg.window) \
                else max_len
            Smax = min(Smax, max_len)
            return {
                "k": jnp.zeros((batch, Smax, Kh, hd), dt),
                "v": jnp.zeros((batch, Smax, Kh, hd), dt),
                "pos": jnp.full((Smax,), -1, jnp.int32),
            }
        if kind == "rglru":
            dr = cfg.rnn_width or cfg.d_model
            return {"conv": jnp.zeros((batch, cfg.conv_width - 1, dr),
                                      jnp.float32),
                    "h": jnp.zeros((batch, dr), jnp.float32)}
        if kind == "mlstm":
            H = cfg.n_heads
            return (jnp.zeros((batch, H, hd, hd), jnp.float32),
                    jnp.zeros((batch, H, hd), jnp.float32),
                    jnp.full((batch, H), -1e30, jnp.float32))
        if kind == "slstm":
            H = cfg.n_heads
            z = jnp.zeros((batch, H, hd), jnp.float32)
            return (z, z, z, z - 1e30)
        raise ValueError(kind)

    def group_cache():
        return {f"b{i}": block_cache(kind)
                for i, kind in enumerate(cfg.block_pattern)}

    cache = {"blocks": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape).copy()
        if cfg.n_groups > 1 else x[None].copy(), group_cache())}
    if cfg.n_remainder:
        cache["rem"] = {f"r{i}": block_cache(cfg.block_pattern[i])
                        for i in range(cfg.n_remainder)}
    return cache


# ------------------------------------------------------------ forward
def _apply_block(x, p, kind, cfg, rules, *, positions, cache=None):
    """One block: mixer sublayer + (optional) MLP/MoE sublayer."""
    aux = jnp.float32(0.0)
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else None
        mix, new_c = attention_block(
            x, p["attn"], cfg, rules, positions=positions,
            causal=not cfg.is_encoder, window=window, cache=cache)
    elif kind == "rglru":
        mix, new_c = rglru_block(x, p["rglru"], cfg, rules, state=cache)
    elif kind == "mlstm":
        mix, new_c = mlstm_block(x, p["mlstm"], cfg, rules, state=cache)
    elif kind == "slstm":
        mix, new_c = slstm_block(x, p["slstm"], cfg, rules, state=cache)
    else:
        raise ValueError(kind)
    x = x + mix
    if "moe" in p:
        y, aux = moe_block(x, p["moe"], cfg, rules)
        x = x + y
    elif "mlp" in p:
        x = x + mlp_block(x, p["mlp"], cfg, rules)
    return x, new_c, aux


def forward(params, inputs, cfg: ModelConfig, rules=None, *,
            positions=None, cache=None):
    """Returns (hidden (B,S,d), new_cache, aux_loss).

    inputs: int tokens (B, S) or float embeddings (B, S, d) (stub frontends).
    cache: decode cache from init_cache (positions required), or None.
    """
    rules = rules or {}
    dt = jnp.dtype(cfg.dtype)
    if inputs.ndim == 2:
        x = params["embedding"].astype(dt)[inputs]
    else:
        x = inputs.astype(dt)
    x = constrain(x, rules, "batch", None, None)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)

    def group_fn(x, gp, gcache):
        new_cache = {}
        aux = jnp.float32(0.0)
        for i, kind in enumerate(cfg.block_pattern):
            c = None if gcache is None else gcache[f"b{i}"]
            x, nc, a = _apply_block(x, gp[f"b{i}"], kind, cfg, rules,
                                    positions=positions, cache=c)
            new_cache[f"b{i}"] = nc
            aux = aux + a
        return x, new_cache, aux

    if cache is None:
        def scan_body(x, gp):
            fn = jax.checkpoint(lambda x_, gp_: group_fn(x_, gp_, None)[::2]) \
                if cfg.remat else (lambda x_, gp_: group_fn(x_, gp_, None)[::2])
            x, aux = fn(x, gp)
            return x, aux
        x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
        new_cache = None
        aux_total = jnp.sum(auxs)
    else:
        def scan_body(x, gp_gc):
            gp, gc = gp_gc
            x, nc, aux = group_fn(x, gp, gc)
            return x, (nc, aux)
        x, (ncs, auxs) = jax.lax.scan(scan_body, x,
                                      (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": ncs}
        aux_total = jnp.sum(auxs)

    if cfg.n_remainder:
        for i in range(cfg.n_remainder):
            kind = cfg.block_pattern[i]
            c = None if cache is None else cache["rem"][f"r{i}"]
            x, nc, a = _apply_block(x, params["rem"][f"r{i}"], kind, cfg,
                                    rules, positions=positions, cache=c)
            aux_total = aux_total + a
            if cache is not None:
                new_cache["rem"] = new_cache.get("rem", {})
                new_cache["rem"][f"r{i}"] = nc

    x = norm(x, params["final_norm"], cfg.norm_type)
    return x, new_cache, aux_total


# ------------------------------------------------------------ loss
def _lm_head_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embedding"].T
    return params["lm_head"]


def chunked_ce(hidden, W, targets, cfg, rules):
    """Chunked cross-entropy: scan over sequence chunks; vocab on "model".

    hidden (B,S,d) dtype cfg.dtype; W (d,V) fp32; targets (B,S) int32
    (-1 = ignore). Returns (mean_loss fp32, token_count).
    """
    B, S, d = hidden.shape
    ck = min(cfg.ce_chunk, S)
    nc = -(-S // ck)
    pad = nc * ck - S
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    t = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    h = jnp.moveaxis(h.reshape(B, nc, ck, d), 1, 0)
    t = jnp.moveaxis(t.reshape(B, nc, ck), 1, 0)

    def chunk_loss(carry, blk):
        hc, tc = blk
        logits = hc.astype(jnp.float32) @ W.astype(jnp.float32)  # (B,ck,V)
        logits = constrain(logits, rules, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = tc >= 0
        ce = jnp.where(valid, lse - lab, 0.0)
        zl = jnp.where(valid, jnp.square(lse), 0.0)
        loss, zloss, count = carry
        return (loss + ce.sum(), zloss + zl.sum(),
                count + valid.sum()), None

    (loss, zloss, count), _ = jax.lax.scan(
        chunk_loss, (jnp.float32(0), jnp.float32(0), jnp.int32(0)), (h, t))
    n = jnp.maximum(count, 1)
    return loss / n + Z_LOSS_WEIGHT * zloss / n, count


def loss_fn(params, batch, cfg: ModelConfig, rules=None):
    """batch: dict(inputs (B,S) int or (B,S,d) float, targets (B,S) int).
    Returns (loss, metrics dict)."""
    rules = rules or {}
    hidden, _, aux = forward(params, batch["inputs"], cfg, rules)
    W = _lm_head_matrix(params, cfg)
    ce, count = chunked_ce(hidden, W, batch["targets"], cfg, rules)
    loss = ce + MOE_AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": count}


# ------------------------------------------------------------ decode
def decode_step(params, cache, tokens, pos, cfg: ModelConfig, rules=None):
    """One decode step: tokens (B, 1) int32, pos () int32 absolute position.
    Returns (logits (B, V) fp32, new_cache)."""
    rules = rules or {}
    positions = jnp.arange(1, dtype=jnp.int32) + pos
    hidden, new_cache, _ = forward(params, tokens, cfg, rules,
                                   positions=positions, cache=cache)
    W = _lm_head_matrix(params, cfg)
    logits = hidden[:, -1].astype(jnp.float32) @ W.astype(jnp.float32)
    return constrain(logits, rules, "batch", "vocab"), new_cache


def prefill(params, tokens, cache, cfg: ModelConfig, rules=None):
    """Prefill the cache with a prompt (B, S); returns (last_logits, cache)."""
    rules = rules or {}
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    hidden, new_cache, _ = forward(params, tokens, cfg, rules,
                                   positions=positions, cache=cache)
    W = _lm_head_matrix(params, cfg)
    logits = hidden[:, -1].astype(jnp.float32) @ W.astype(jnp.float32)
    return logits, new_cache


def train_step_fn(params, batch, cfg, rules=None):
    """Plain grad step (no optimizer) — smoke tests; real training lives in
    repro.train."""
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg, rules)
    return loss, metrics, grads
