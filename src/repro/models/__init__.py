"""LM model stack: the 10 assigned architectures as one composable decoder/
encoder family (GQA/MoE/RG-LRU/xLSTM/encoder blocks, scan-over-layers)."""
from .config import ModelConfig
from .model import (init_params, forward, loss_fn, train_step_fn,
                    decode_step, prefill, init_cache)

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn",
           "train_step_fn", "decode_step", "prefill", "init_cache"]
