"""Shared transformer layers: norms, RoPE, chunked GQA attention, MLP, MoE.

Attention is blockwise ("flash"-style online softmax over KV chunks, scanned
over query chunks) so prefill_32k never materializes an (S, S) score matrix.
The baseline computes all (q-chunk, kv-chunk) tiles with masking — exact but
~2x the causal-optimal attention FLOPs; EXPERIMENTS.md §Perf tracks the
triangular-skip optimization against this honestly-reported baseline.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .sharding import constrain

NEG_INF = -1e30


# ------------------------------------------------------------ norms
def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * scale


def norm(x, scale, kind: str):
    return rmsnorm(x, scale) if kind == "rmsnorm" else layernorm(x, scale)


# ------------------------------------------------------------ RoPE
def rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (S,) int32. Standard rotary embedding.
    (qwen2-vl's M-RoPE degenerates to this for the text/stub-frontend path —
    the three M-RoPE channels share identical position ids; DESIGN.md §4.)
    Negative positions (empty cache slots) are clamped — those slots are
    masked out of attention anyway."""
    Dh = x.shape[-1]
    half = Dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.maximum(positions, 0).astype(jnp.float32)
    ang = pos[:, None] * freqs                                # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention
def _attend_chunk(q, k, v, mask, scale):
    """q (B,qc,Kh,G,Dh) k/v (B,kc,Kh,Dh) mask (B,qc,kc) -> (acc, m, l)."""
    s = jnp.einsum("bqkgd,bckd->bqkgc", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,qc,Kh,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), v)
    return acc, m, l


def flash_attention(q, k, v, *, q_pos, k_pos, causal: bool,
                    window: int | None, chunk: int,
                    causal_skip: bool = False):
    """Blockwise online-softmax attention with explicit position vectors.

    q: (B, Sq, H, Dh); k, v: (B, Skv, Kh, Dh). GQA via grouped einsum
    (no materialized KV repetition). q_pos (Sq,), k_pos (Skv,) int32 are
    absolute positions; k slots with k_pos < 0 are invalid (empty cache
    slots in ring buffers). Returns (B, Sq, H, Dh).

    causal_skip: triangular scheduling — each query block scans only its
    static KV prefix (blocks j <= i), halving causal-attention FLOPs vs the
    masked-full baseline. Requires aligned q/kv (self-attention, no cache)
    and no window. EXPERIMENTS.md §Perf hillclimb B measures the delta.
    """
    B, Sq, H, Dh = q.shape
    _, Skv, Kh, _ = k.shape
    G = H // Kh
    scale = 1.0 / math.sqrt(Dh)
    qc = min(chunk, Sq)
    kc = min(chunk, Skv)
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    qp = qp.reshape(B, nq, qc, Kh, G, Dh)
    kp = kp.reshape(B, nk, kc, Kh, Dh)
    vp = vp.reshape(B, nk, kc, Kh, Dh)
    qpos = jnp.pad(q_pos, (0, nq * qc - Sq),
                   constant_values=-(10**9)).reshape(nq, qc)
    kpos = jnp.pad(k_pos, (0, nk * kc - Skv),
                   constant_values=-1).reshape(nk, kc)

    def q_block(args):
        qb, qpo = args                                        # (B,qc,Kh,G,Dh)

        def kv_step(carry, blk):
            acc, m, l = carry
            kb, vb, kpo = blk
            mask = (kpo >= 0)[None, None, :]
            if causal:
                mask = mask & (qpo[None, :, None] >= kpo[None, None, :])
            if window is not None:
                mask = mask & ((qpo[None, :, None] - kpo[None, None, :])
                               < window)
            mask = jnp.broadcast_to(mask, (B, qc, kc))
            a, m2, l2 = _attend_chunk(qb, kb, vb, mask, scale)
            m_new = jnp.maximum(m, m2)
            c1 = jnp.exp(m - m_new)
            c2 = jnp.exp(m2 - m_new)
            acc = acc * c1[..., None].astype(acc.dtype) + \
                a * c2[..., None].astype(a.dtype)
            l = l * c1 + l2 * c2
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, qc, Kh, G, Dh), qb.dtype)
        m0 = jnp.full((B, qc, Kh, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Kh, G), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0), kpos))
        return acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)

    if causal_skip and causal and window is None and nq > 1:
        # Triangular schedule: query block i scans only kv blocks 0..i
        # (STATIC prefix per block — python loop, nq separate scans). Total
        # work = S^2/2 + O(S*chunk) instead of the masked-full S^2.
        outs = []
        kp_t = jnp.moveaxis(kp, 1, 0)       # (nk, B, kc, Kh, Dh)
        vp_t = jnp.moveaxis(vp, 1, 0)
        for i in range(nq):
            qb, qpo = qp[:, i], qpos[i]

            def kv_step(carry, blk):
                acc, m, l = carry
                kb, vb, kpo = blk
                mask = (kpo >= 0)[None, None, :] & \
                    (qpo[None, :, None] >= kpo[None, None, :])
                mask = jnp.broadcast_to(mask, (B, qc, kc))
                a, m2, l2 = _attend_chunk(qb, kb, vb, mask, scale)
                m_new = jnp.maximum(m, m2)
                c1, c2 = jnp.exp(m - m_new), jnp.exp(m2 - m_new)
                acc = acc * c1[..., None].astype(acc.dtype) + \
                    a * c2[..., None].astype(a.dtype)
                return (acc, m_new, l * c1 + l2 * c2), None

            acc0 = jnp.zeros((B, qc, Kh, G, Dh), q.dtype)
            m0 = jnp.full((B, qc, Kh, G), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, qc, Kh, G), jnp.float32)
            (acc, m, l), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0),
                (kp_t[: i + 1], vp_t[: i + 1], kpos[: i + 1]))
            outs.append(acc / jnp.maximum(l, 1e-30)[..., None]
                        .astype(acc.dtype))
        out = jnp.stack(outs, axis=1)
    elif nq == 1:
        out = q_block((qp[:, 0], qpos[0]))[:, None]
    else:
        out = jax.lax.map(q_block, (jnp.moveaxis(qp, 1, 0), qpos))
        out = jnp.moveaxis(out, 0, 1)
    out = out.reshape(B, nq * qc, H, Dh)
    return out[:, :Sq]


def _flash_unnormalized(q, k, v, mask, scale, chunk: int):
    """Single-q-block flash returning raw (acc, m, l) — the combinable form
    used by sequence-parallel decode (partial softmax per KV shard, merged
    with pmax/psum across the "model" axis)."""
    B, Sq, Kh, G, Dh = q.shape
    Skv = k.shape[1]
    kc = min(chunk, Skv)
    nk = -(-Skv // kc)
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    mp = jnp.pad(mask, ((0, 0), (0, 0), (0, nk * kc - Skv)))
    kp = jnp.moveaxis(kp.reshape(B, nk, kc, Kh, Dh), 1, 0)
    vp = jnp.moveaxis(vp.reshape(B, nk, kc, Kh, Dh), 1, 0)
    mp = jnp.moveaxis(mp.reshape(B, Sq, nk, kc), 2, 0)

    def kv_step(carry, blk):
        acc, m, l = carry
        kb, vb, mb = blk
        a, m2, l2 = _attend_chunk(q, kb, vb, mb, scale)
        m_new = jnp.maximum(m, m2)
        c1, c2 = jnp.exp(m - m_new), jnp.exp(m2 - m_new)
        acc = acc * c1[..., None].astype(acc.dtype) + \
            a * c2[..., None].astype(a.dtype)
        return (acc, m_new, l * c1 + l2 * c2), None

    acc0 = jnp.zeros((B, Sq, Kh, G, Dh), q.dtype)
    m0 = jnp.full((B, Sq, Kh, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Kh, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kp, vp, mp))
    return acc, m, l


def seq_sharded_decode_attention(q, cache, k_new, v_new, positions, cfg,
                                 mesh, *, causal=True):
    """Single-token decode against a KV cache whose SEQUENCE axis is sharded
    over the "model" mesh axis (sequence-parallel serving, DESIGN.md §5).

    Every decode_32k cell needs this: the global-attention KV cache is
    12-43 GB per device batch otherwise. Each model shard holds S/|model|
    cache slots, computes a partial flash (acc, m, l) over its slice, and the
    partials merge with pmax/psum — the online-softmax combine is associative
    so the merge is exact.

    q: (B, 1, H, Dh); cache k/v: (B, Smax, Kh, Dh) sharded (dp, model, ..);
    positions: (1,) absolute. Returns (out (B,1,H,Dh), new_cache).
    """
    from jax.sharding import PartitionSpec as P
    from ..util import shard_map_compat

    B, S, H, Dh = q.shape
    Kh = k_new.shape[2]
    G = H // Kh
    scale = 1.0 / math.sqrt(Dh)
    Smax = cache["k"].shape[1]
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    bspec = dp if B % dp_total == 0 else None

    def local_fn(qL, kC, vC, pC, kN, vN, pos):
        # NB: shapes here are PER-SHARD (batch may be dp-sharded, cache seq
        # is model-sharded) — never use the closed-over global B.
        Bl = qL.shape[0]
        idx = jax.lax.axis_index("model")
        Sloc = kC.shape[1]
        slot_g = jnp.mod(pos[0], Smax)
        slot_l = slot_g - idx * Sloc
        inside = (slot_l >= 0) & (slot_l < Sloc)
        sl = jnp.clip(slot_l, 0, Sloc - 1)
        upd = lambda C, N: jnp.where(
            inside, jax.lax.dynamic_update_slice_in_dim(C, N, sl, axis=1), C)
        kC = upd(kC, kN)
        vC = upd(vC, vN)
        pC = jnp.where(inside, jax.lax.dynamic_update_slice_in_dim(
            pC, pos, sl, axis=0), pC)
        kR = rope(kC, pC, cfg.rope_theta)
        qR = qL.reshape(Bl, S, Kh, G, Dh)
        mask = (pC >= 0)[None, None, :]
        if causal:
            mask = mask & (pos[0] >= pC)[None, None, :]
        mask = jnp.broadcast_to(mask, (Bl, S, Sloc))
        acc, m, l = _flash_unnormalized(qR, kR, vC, mask, scale,
                                        cfg.attn_chunk)
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(
            (acc * corr[..., None].astype(acc.dtype)).astype(jnp.float32),
            "model")
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(qL.dtype)
        return out.reshape(Bl, S, H, Dh), kC, vC, pC

    fn = shard_map_compat(
        local_fn, mesh,
        in_specs=(P(bspec), P(bspec, "model"), P(bspec, "model"),
                  P("model"), P(bspec), P(bspec), P()),
        out_specs=(P(bspec), P(bspec, "model"), P(bspec, "model"),
                   P("model")))
    out, ck, cv, cpos = fn(q, cache["k"], cache["v"], cache["pos"],
                           k_new, v_new, positions)
    return out, {"k": ck, "v": cv, "pos": cpos}


def attention_block(x, p, cfg, rules, *, positions, causal: bool,
                    window: int | None, cache=None):
    """Pre-norm GQA attention with optional KV cache (decode).

    p: dict(wq (d, H*hd), wk/wv (d, Kh*hd), wo_attn (H*hd, d), norm (d,)).
    cache: None | dict(k (B, Smax, Kh, hd) UNROPED, v likewise,
    pos (Smax,) absolute positions, -1 = empty, ptr () next write slot).
    Windowed layers use a ring buffer (Smax == window); global layers a
    linear buffer. K is roped at use time from stored positions, so ring
    overwrites stay correct. Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = norm(x, p["norm"], cfg.norm_type)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, Kh, hd)
    v = (h @ p["wv"]).reshape(B, S, Kh, hd)
    q = constrain(q, rules, "batch", None, "heads", None)
    k = constrain(k, rules, "batch", None, "kv", None)
    q = rope(q, positions, cfg.rope_theta)

    if cache is None:
        k = rope(k, positions, cfg.rope_theta)
        out = flash_attention(q, k, v, q_pos=positions, k_pos=positions,
                              causal=causal, window=window,
                              chunk=cfg.attn_chunk,
                              causal_skip=cfg.causal_skip)
        new_cache = None
    else:
        Smax = cache["k"].shape[1]
        mesh = rules.get("_mesh")
        seq_shardable = (S == 1 and window is None and mesh is not None
                         and rules.get("kv_seq") == "model"
                         and Smax % mesh.shape["model"] == 0)
        if seq_shardable:
            # Sequence-parallel decode: cache seq axis sharded on "model",
            # partial flash per shard merged with pmax/psum.
            out, new_cache = seq_sharded_decode_attention(
                q, cache, k, v, positions, cfg, mesh, causal=causal)
        elif S == 1:
            # Single-token decode: write-then-attend is exact (the slot
            # written IS the current position; a ring overwrite only evicts
            # pos - Smax, which the window predicate masks anyway) and
            # avoids concatenating a copy of the whole cache every step.
            slots = jnp.mod(positions, Smax)
            ck = cache["k"].at[:, slots].set(k)
            cv = cache["v"].at[:, slots].set(v)
            cpos = cache["pos"].at[slots].set(positions)
            k_roped = rope(ck, cpos, cfg.rope_theta)
            out = flash_attention(q, k_roped, cv, q_pos=positions,
                                  k_pos=cpos, causal=causal, window=window,
                                  chunk=cfg.attn_chunk)
            new_cache = {"k": ck, "v": cv, "pos": cpos}
        else:
            # Chunked prefill: attend BEFORE writing — ring-buffer writes of
            # a multi-token chunk would clobber keys that early queries in
            # the chunk still need. Attention runs over concat(cache, fresh);
            # stale ring entries are masked by the window predicate, empty
            # slots (pos == -1) by the validity predicate.
            ck_in, cv_in, cpos_in = cache["k"], cache["v"], cache["pos"]
            if mesh is not None and rules.get("kv_seq"):
                # XLA SPMD (jax 0.4.37) mis-partitions concatenate along a
                # "model"-sharded axis when the other operand is replicated:
                # the output is the elementwise SUM of the shards, not their
                # concatenation (cache values come out doubled, positions
                # 0..7 become 0,2,..,14 — every slot looks invalid or
                # mis-placed and attention reads garbage). Gathering the
                # cache's seq axis before the concat sidesteps the bug;
                # prefill runs once per sequence, so the all-gather is paid
                # off the decode hot path (which takes the seq-sharded
                # shard_map route above, no concat involved).
                ck_in = constrain(ck_in, rules, "batch", None, "kv", None)
                cv_in = constrain(cv_in, rules, "batch", None, "kv", None)
                cpos_in = constrain(cpos_in, rules, None)
            k_all = jnp.concatenate([ck_in, k], axis=1)
            v_all = jnp.concatenate([cv_in, v], axis=1)
            pos_all = jnp.concatenate([cpos_in, positions])
            k_roped = rope(k_all, pos_all, cfg.rope_theta)
            out = flash_attention(q, k_roped, v_all, q_pos=positions,
                                  k_pos=pos_all, causal=causal,
                                  window=window, chunk=cfg.attn_chunk)
            slots = jnp.mod(positions, Smax)
            ck = cache["k"].at[:, slots].set(k)
            cv = cache["v"].at[:, slots].set(v)
            cpos = cache["pos"].at[slots].set(positions)
            new_cache = {"k": ck, "v": cv, "pos": cpos}
    out = out.reshape(B, S, H * hd) @ p["wo_attn"]
    out = constrain(out, rules, "batch", None, None)
    return out, new_cache


# ------------------------------------------------------------ MLP
def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp_block(x, p, cfg, rules):
    """Pre-norm MLP: gated (SwiGLU-style) or plain, activation per config."""
    h = norm(x, p["norm"], cfg.norm_type)
    u = h @ p["wi"]
    u = constrain(u, rules, "batch", None, "mlp")
    if cfg.mlp_gated:
        g = _act(h @ p["wg"], cfg.mlp_act)
        u = u * g
    else:
        u = _act(u, cfg.mlp_act)
    out = u @ p["wo"]
    return constrain(out, rules, "batch", None, None)


# ------------------------------------------------------------ MoE
def moe_block(x, p, cfg, rules):
    """Dropped-token top-k MoE with SORT-BASED dispatch.

    The classic one-hot dispatch tensor is O(T·E·C) — at train_4k's 1M global
    tokens that is ~1e16 elements. Here dispatch is a gather/scatter over a
    fixed (E·C + 1, d) expert buffer (the +1 row swallows capacity-dropped
    writes), memory O(T·k·cf·d):

      1. route: top-k gates per token (router fp32);
      2. rank each (token, k) within its expert's queue via a stable sort
         over expert ids (the Hadoop-shuffle idiom again — sort-by-key is
         this framework's join primitive, cf. core/mapreduce.py);
      3. scatter kept tokens into slot = e·C + rank;
      4. expert FFN on (E, C, d), E sharded on "model" (EP);
      5. gather + weighted scatter-add back to (T, d).

    Returns (out, aux_loss).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token

    # ROUTING GROUPS: routing/capacity are enforced per batch row (or per
    # the whole batch when S == 1, i.e. decode). Grouping keeps every
    # intermediate carrying the batch axis, so the dp sharding survives the
    # sort/scatter (a single global routing pool would materialize replicated
    # multi-GB gather/scatter buffers — measured 122 GB/device on olmoe
    # train_4k before this change).
    if S == 1:
        groups, Tg = 1, B
    else:
        groups, Tg = B, S
    C = max(int(math.ceil(Tg / E * K * cfg.capacity_factor)), 4)

    h = norm(x, p["norm"], cfg.norm_type).reshape(groups, Tg, d)

    def route_group(hg):
        """hg: (Tg, d) -> (out (Tg, d), me (E,), ce (E,))."""
        logits = hg.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                # (Tg, E)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        e_flat = gate_idx.reshape(Tg * K)
        t_flat = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
        w_flat = gate_vals.reshape(Tg * K)
        # rank within expert queue (stable sort by expert id)
        order = jnp.argsort(e_flat, stable=True)
        e_s = e_flat[order]
        seg = jnp.concatenate([jnp.ones(1, bool), e_s[1:] != e_s[:-1]])
        idx = jnp.arange(Tg * K, dtype=jnp.int32)
        rank_s = idx - jax.lax.cummax(jnp.where(seg, idx, 0), axis=0)
        rank = jnp.zeros_like(rank_s).at[order].set(rank_s)
        keep = rank < C
        slot = jnp.where(keep, e_flat * C + rank, E * C)       # drop row
        xb = jnp.zeros((E * C + 1, d), hg.dtype).at[slot].set(hg[t_flat])
        xe = xb[: E * C].reshape(E, C, d)
        me = probs.mean(0)
        ce = jnp.zeros((E,), jnp.float32).at[e_flat].add(1.0) / (Tg * K) * E
        return xe, (slot, t_flat, w_flat), me, ce

    xe, routing, me, ce = jax.vmap(route_group)(h)             # (G,E,C,d)
    xe = constrain(xe, rules, "batch", "experts", None, None)
    u = jnp.einsum("gecd,edf->gecf", xe, p["ewi"])
    if cfg.mlp_gated:
        g = _act(jnp.einsum("gecd,edf->gecf", xe, p["ewg"]), cfg.mlp_act)
        u = u * g
    else:
        u = _act(u, cfg.mlp_act)
    ye = jnp.einsum("gecf,efd->gecd", u, p["ewo"])             # (G,E,C,d)
    ye = constrain(ye, rules, "batch", "experts", None, None)

    def combine_group(ye_g, routing_g):
        slot, t_flat, w_flat = routing_g
        yb = jnp.concatenate([ye_g.reshape(E * C, d),
                              jnp.zeros((1, d), ye_g.dtype)])  # drop row = 0
        y_rec = yb[slot] * w_flat[:, None].astype(ye_g.dtype)
        return jnp.zeros((Tg, d), ye_g.dtype).at[t_flat].add(y_rec)

    out = jax.vmap(combine_group)(ye, routing).reshape(B, S, d)
    out = constrain(out, rules, "batch", None, None)
    aux = (me.mean(0) * ce.mean(0)).sum()
    return out, aux
