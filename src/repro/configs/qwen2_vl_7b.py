"""Qwen2-VL-7B [arXiv:2409.12191]: 28L d=3584 28H (GQA kv=4) d_ff=18944,
vocab 152064. M-RoPE + dynamic-resolution ViT frontend.

Frontend is a STUB (per brief): training consumes precomputed patch/text
embeddings (B, S, d). M-RoPE's three position channels coincide for the
stub/text path, so it reduces to standard RoPE (DESIGN.md §4). n_heads=28
not divisible by the model axis -> attention replicated over "model", TP in
the MLP."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064,
        embedding_inputs=True,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke", family="vlm",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=128, vocab_size=256,
        embedding_inputs=True,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        rope_theta=1e6, attn_chunk=16, ce_chunk=16,
    )
