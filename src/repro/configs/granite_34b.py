"""Granite-34B-Code [arXiv:2405.04324]: 88L d=6144 48H (MQA kv=1) d_ff=24576,
vocab 49152. Deepest assigned arch — the layer-scan + FSDP + grad-accum
stress case.

Non-gated GELU MLP (GPT-BigCode lineage): with a gated MLP the analytic
count lands at 47B, with 2-matrix GELU it lands at 34B — matching the
published size pins the MLP variant."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152,
        mlp_act="gelu", mlp_gated=False, norm_type="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=256,
        mlp_act="gelu", mlp_gated=False, norm_type="layernorm",
        attn_chunk=16, ce_chunk=16,
    )
