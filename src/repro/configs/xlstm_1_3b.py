"""xLSTM-1.3B [arXiv:2405.04517]: 48L d=2048 4H d_ff=0 (no MLP sublayer),
vocab 50304. mLSTM:sLSTM at 7:1 — pattern of 8 blocks, 6 scan groups.
Pure recurrent (runs long_500k with O(1) decode state)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=("mlstm",) * 7 + ("slstm",),
        mlp_act="gelu", mlp_gated=False, norm_type="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab_size=256,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        mlp_act="gelu", mlp_gated=False, norm_type="layernorm",
        attn_chunk=16, ce_chunk=16,
    )
