"""Yi-9B [arXiv:2403.04652]: 48L d=4096 32H (GQA kv=4) d_ff=11008,
vocab 64000. Llama-arch."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        rope_theta=5e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=256,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        rope_theta=5e6, attn_chunk=16, ce_chunk=16,
    )
