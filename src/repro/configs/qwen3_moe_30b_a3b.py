"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (GQA kv=4,
head_dim 128) d_ff=768/expert, vocab 151936, MoE 128 experts top-8."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151936,
        n_experts=128, experts_per_token=8,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        n_experts=8, experts_per_token=2,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        rope_theta=1e6, attn_chunk=16, ce_chunk=16,
    )
