"""OLMoE-1B-7B [arXiv:2409.02060]: 16L d=2048 16H (GQA kv=16) d_ff=1024,
vocab 50304, MoE 64 experts top-8."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, experts_per_token=8,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab_size=256,
        n_experts=8, experts_per_token=2,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        attn_chunk=16, ce_chunk=16,
    )
