"""Architecture registry: the 10 assigned archs + the paper's own pipeline.

Each module exposes config() (exact published shape) and smoke_config()
(reduced same-family variant for CPU tests). Select with --arch <id>.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "olmoe-1b-7b",
    "qwen3-moe-30b-a3b",
    "hubert-xlarge",
    "recurrentgemma-2b",
    "qwen2-vl-7b",
    "nemotron-4-15b",
    "granite-3-8b",
    "granite-34b",
    "yi-9b",
    "xlstm-1.3b",
]


def _module(name: str):
    return importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()


# ---------------------------------------------------------------- shapes
# Input-shape set shared by all LM archs (the brief's 4 shapes).
SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4_096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32_768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524_288, global_batch=1),
}

# Sub-quadratic / decode-capable skips (DESIGN.md §4).
SUBQUADRATIC = {"recurrentgemma-2b", "xlstm-1.3b"}
ENCODER_ONLY = {"hubert-xlarge"}


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if arch in ENCODER_ONLY and shape in ("decode_32k", "long_500k"):
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "long_500k needs sub-quadratic attention (brief rule)"
    return True, ""


def cells():
    """All 40 (arch, shape) cells with applicability."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            ok, why = shape_applicable(a, s)
            out.append((a, s, ok, why))
    return out
