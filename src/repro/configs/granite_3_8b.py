"""Granite-3.0-8B [hf:ibm-granite]: 40L d=4096 32H (GQA kv=8) d_ff=12800,
vocab 49155 (uneven over a 16-way model axis — GSPMD pads; exercised
deliberately)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab_size=49155,
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=255,   # odd vocab on purpose (uneven shards)
        mlp_act="silu", mlp_gated=True, norm_type="rmsnorm",
        attn_chunk=16, ce_chunk=16,
    )
