"""Nemotron-4-15B [arXiv:2402.16819]: 32L d=6144 48H (GQA kv=8) d_ff=24576,
vocab 256000. Squared-ReLU MLP (no gate), LayerNorm, untied embeddings."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab_size=256000,
        mlp_act="relu2", mlp_gated=False, norm_type="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=256,
        mlp_act="relu2", mlp_gated=False, norm_type="layernorm",
        attn_chunk=16, ce_chunk=16,
    )
