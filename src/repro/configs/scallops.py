"""The paper's own configuration: the ScalLoPS LSH pipeline parameters
(§5.2's best-quality point and §5.3's performance point), plus the dataset
shape grid mirroring Tables 5.1/5.2."""
from repro.core import LSHConfig


def quality_config() -> LSHConfig:
    """k=4, T=22, d=0 — the paper's best-quality operating point (§5.2)."""
    return LSHConfig(k=4, T=22, f=32, d=0, scheme="java",
                     join_method="flip")


def perf_config() -> LSHConfig:
    """k=3, T=13, d=0 — the paper's performance-comparison point (§5.3)."""
    return LSHConfig(k=3, T=13, f=32, d=0, scheme="java",
                     join_method="flip")


def optimized_config() -> LSHConfig:
    """Beyond-paper: 64-bit splitmix signatures + banding join + table
    siggen (EXPERIMENTS.md §Perf)."""
    return LSHConfig(k=3, T=13, f=64, d=3, scheme="splitmix",
                     siggen_method="table", join_method="band")


# Dataset-scale grid from the paper (Tables 5.1/5.2), used to size benches.
DATASETS = {
    "NC_000913": dict(n=4_146, avg_len=316),
    "227_01_prot": dict(n=547_169, avg_len=81),
    "allgos": dict(n=120_723_333, avg_len=24),
    "myva": dict(n=192_987, avg_len=305),
    "swissprot": dict(n=454_401, avg_len=373),
    "nr": dict(n=23_074_873, avg_len=343),
}
