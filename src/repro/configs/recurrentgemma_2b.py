"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: 26L d=2560 10H (MQA kv=1,
head_dim 256) d_ff=7680, vocab 256000. RG-LRU + local attention, 1:2 —
pattern (rglru, rglru, local_attn), window 2048, tied embeddings.

26 = 8 whole pattern repeats + 2 remainder rglru blocks (scan + unrolled
tail). n_heads=10 is not divisible by the model axis -> attention is
replicated over "model"; TP lives in the MLP (DESIGN.md §5)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab_size=256000,
        block_pattern=("rglru", "rglru", "local_attn"), window=2048,
        rnn_width=2560, conv_width=4,
        mlp_act="gelu", mlp_gated=True, norm_type="rmsnorm",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=256,
        block_pattern=("rglru", "rglru", "local_attn"), window=16,
        rnn_width=64, conv_width=4,
        mlp_act="gelu", mlp_gated=True, norm_type="rmsnorm",
        tie_embeddings=True, attn_chunk=16, ce_chunk=16,
    )
