"""HuBERT-XLarge [arXiv:2106.07447]: 48L d=1280 16H d_ff=5120, encoder-only
(wav2vec2-style), masked-unit prediction over 504 cluster targets.

The CNN audio frontend is a STUB (per brief): input_specs()/loss take
precomputed frame embeddings (B, S, d)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504,
        is_encoder=True, embedding_inputs=True,
        mlp_act="gelu", mlp_gated=False, norm_type="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=64,
        is_encoder=True, embedding_inputs=True,
        mlp_act="gelu", mlp_gated=False, norm_type="layernorm",
        attn_chunk=16, ce_chunk=16,
    )
