"""Deterministic, seedable fault-injection registry.

A :class:`FaultPlan` is a script: *at the Nth call to site S, do X*.
Sites are string names compiled into the serving/persistence layers
(:func:`fault_point` calls); call numbers are per-site, 1-based, counted
only while the plan is installed. Because the serving tier funnels every
query batch through one dispatch thread, call numbering at a site is a
deterministic function of the driver's submission order — which is what
lets the chaos benchmark assert its retry/quarantine/shed counters
against the script exactly, not approximately.

Fault kinds:

``raise``    raise :class:`InjectedFault` (an ordinary ``Exception`` —
             the handling under test must treat it like any backend
             error).
``kill``     raise :class:`ThreadKilled` — semantically "this worker
             thread died"; the supervisor restarts the loop, and any
             per-call handling that resolved outstanding work first has
             done its job.
``latency``  sleep ``delay_s`` then continue (a slow replica / GC pause
             / straggler — admission control and deadline shedding see
             it, nothing fails).
``torn``     returned to the call site instead of raised — only
             :func:`repro.faults.atomic.atomic_write` consumes it, by
             writing ``frac`` of the payload straight to the destination
             and then crashing (the non-atomic writer this repo no
             longer is, manufactured on demand for recovery tests).

Install with ``with plan: ...`` (or ``plan.install()`` /
``plan.uninstall()``). The active plan is a module-level global, not a
contextvar, deliberately: faults must fire on *background threads*
(dispatch, ingest) that were started long before the plan existed.
"""
from __future__ import annotations

import threading
import time

from ..obs import REGISTRY

#: every injected fault, labeled by site and kind — the registry-side
#: mirror of the plan's ledger (merges across processes like any counter)
_M_INJECTED = REGISTRY.counter(
    "faults_injected", "deterministically injected faults",
    labelnames=("site", "kind"))

_KINDS = ("raise", "kill", "latency", "torn")


class InjectedFault(RuntimeError):
    """A deterministically injected failure (never raised in production:
    only a :class:`FaultPlan` constructs it)."""

    def __init__(self, site: str, call: int, kind: str = "raise"):
        super().__init__(f"injected {kind} fault at {site!r} (call #{call})")
        self.site = site
        self.call = call
        self.kind = kind


class ThreadKilled(InjectedFault):
    """An injected worker-thread death (``kind="kill"``)."""

    def __init__(self, site: str, call: int):
        super().__init__(site, call, kind="kill")


class FaultSpec:
    """One scripted fault: fire ``kind`` at site ``site`` on the call
    numbers in ``on`` (1-based, counted per site while the plan is
    installed)."""

    __slots__ = ("site", "kind", "on", "delay_s", "frac")

    def __init__(self, site: str, kind: str = "raise", *,
                 on=1, delay_s: float = 0.05, frac: float = 0.5):
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (one of {_KINDS})")
        self.site = site
        self.kind = kind
        self.on = frozenset(int(n) for n in
                            ((on,) if isinstance(on, int) else on))
        if any(n < 1 for n in self.on):
            raise ValueError("fault call numbers are 1-based")
        self.delay_s = float(delay_s)
        self.frac = float(frac)

    def __repr__(self):
        return (f"FaultSpec({self.site!r}, {self.kind!r}, "
                f"on={sorted(self.on)})")


class FaultPlan:
    """A deterministic fault script plus its execution ledger.

    Thread-safe: per-site call counters and the ledger are updated under
    one lock, so concurrent serving threads observe a single global call
    order per site (which thread draws the faulted call number may vary;
    *how many* faults fire, and their handling counts, never does).
    """

    def __init__(self, *specs: FaultSpec, sleep=time.sleep):
        self._specs: list[FaultSpec] = list(specs)
        self._calls: dict[str, int] = {}
        self._ledger: list[tuple[str, int, str]] = []   # (site, call, kind)
        self._lock = threading.Lock()
        self._sleep = sleep

    # ------------------------------------------------------------ scripting
    def add(self, site: str, kind: str = "raise", *, on=1,
            delay_s: float = 0.05, frac: float = 0.5) -> "FaultPlan":
        """Append one scripted fault; chainable."""
        self._specs.append(FaultSpec(site, kind, on=on, delay_s=delay_s,
                                     frac=frac))
        return self

    # ------------------------------------------------------------ firing
    def fire(self, site: str, **ctx) -> FaultSpec | None:
        """Count one call to ``site`` and apply any fault scripted for
        this call number. ``raise``/``kill`` raise, ``latency`` sleeps,
        ``torn`` is *returned* for the call site to enact. Returns None
        when nothing fires."""
        with self._lock:
            call = self._calls.get(site, 0) + 1
            self._calls[site] = call
            hit = next((s for s in self._specs
                        if s.site == site and call in s.on), None)
            if hit is not None:
                self._ledger.append((site, call, hit.kind))
        if hit is None:
            return None
        _M_INJECTED.inc(site=site, kind=hit.kind)
        if hit.kind == "latency":
            self._sleep(hit.delay_s)
            return None
        if hit.kind == "kill":
            raise ThreadKilled(site, call)
        if hit.kind == "raise":
            raise InjectedFault(site, call)
        return hit                                      # torn: caller enacts

    # ------------------------------------------------------------ ledger
    def calls(self, site: str) -> int:
        """Calls counted at ``site`` so far (while installed)."""
        with self._lock:
            return self._calls.get(site, 0)

    def fired(self, site: str | None = None, kind: str | None = None) -> int:
        """How many scripted faults actually fired (optionally filtered)."""
        with self._lock:
            return sum(1 for s, _c, k in self._ledger
                       if (site is None or s == site)
                       and (kind is None or k == kind))

    def ledger(self) -> list[tuple[str, int, str]]:
        with self._lock:
            return list(self._ledger)

    def unfired(self) -> list[FaultSpec]:
        """Scripted faults whose call numbers were never reached — a
        chaos run asserting determinism wants this EMPTY."""
        with self._lock:
            fired = {(s, c) for s, c, _k in self._ledger}
            return [spec for spec in self._specs
                    if any((spec.site, n) not in fired
                           and n > self._calls.get(spec.site, 0)
                           for n in spec.on)]

    def summary(self) -> dict:
        """JSON-able script-vs-execution accounting for bench artifacts."""
        with self._lock:
            scripted: dict[str, int] = {}
            for s in self._specs:
                key = f"{s.site}:{s.kind}"
                scripted[key] = scripted.get(key, 0) + len(s.on)
            fired: dict[str, int] = {}
            for site, _c, kind in self._ledger:
                key = f"{site}:{kind}"
                fired[key] = fired.get(key, 0) + 1
            return dict(scripted=scripted, fired=fired,
                        calls=dict(self._calls))

    # ------------------------------------------------------------ install
    def install(self) -> "FaultPlan":
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is not None and _ACTIVE is not self:
                raise RuntimeError("another FaultPlan is already installed")
            _ACTIVE = self
        return self

    def uninstall(self) -> None:
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is self:
                _ACTIVE = None

    def __enter__(self) -> "FaultPlan":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()


_ACTIVE: FaultPlan | None = None
_INSTALL_LOCK = threading.Lock()


def active_plan() -> FaultPlan | None:
    return _ACTIVE


def fault_point(site: str, **ctx) -> FaultSpec | None:
    """The hook compiled into serving/persistence code. No plan installed
    (production): one global load + branch. Plan installed: count the
    call and apply whatever the script says. Only ``torn``-aware call
    sites (``atomic_write``) use the return value."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.fire(site, **ctx)
