"""repro.faults — deterministic fault injection and the handling that
makes the faults survivable.

ScalLoPS gets its fault tolerance for free from Hadoop re-execution; our
always-on serving tier (PR 6) and segmented persistence (PR 5) had **no
failure model at all** — a replica exception, a dead ingest thread, or a
kill mid-``save()`` was silent data loss or a wedged process. This
package supplies both halves of the fix:

* ``plan``       — :class:`FaultPlan`: a seedable, deterministic
  fault-injection registry. Faults fire at named **sites**
  (``replica.query``, ``ingest.apply``, ``engine.dispatch``,
  ``store.write``) on scripted call numbers: raise-on-Nth-call, latency
  spikes, thread kills, torn writes. Call sites cost one attribute load
  + ``is None`` branch when no plan is installed; with a plan installed
  every firing lands in a ledger, so a chaos run can assert its
  shed/retry counts against the script *exactly*.
* ``supervisor`` — :class:`Supervisor`: the worker-thread harness the
  serving tier runs its dispatch and ingest loops under. Crashes are
  caught, reported through ``on_crash`` (the owner resolves every
  outstanding future/event with a typed error), counted in the obs
  registry, and the loop restarts under exponential backoff with
  deterministic seeded jitter; a bounded run of consecutive failures
  gives up into a visible ``degraded`` state instead of spinning.
* ``atomic``     — :func:`atomic_write`: tmp file + fsync +
  ``os.replace`` (+ directory fsync), the single write path every
  manifest/segment/legacy-npz write goes through — a crash anywhere
  inside leaves the destination either old or new, never torn. The
  torn-write fault *kind* deliberately bypasses it (partial bytes
  straight to the destination, then a crash) to manufacture exactly the
  damage the recovery path (:func:`repro.index.segments.load_segmented`
  with ``recover=True``) must survive.

The chaos soak benchmark (``benchmarks/chaos_soak.py``) scripts all of
this end to end; ``tests/test_faults.py`` pins each piece.
"""
from .atomic import atomic_write
from .plan import (FaultPlan, FaultSpec, InjectedFault, ThreadKilled,
                   active_plan, fault_point)
from .supervisor import Supervisor

__all__ = [
    "FaultPlan", "FaultSpec", "InjectedFault", "ThreadKilled",
    "active_plan", "fault_point",
    "Supervisor",
    "atomic_write",
]
