"""Supervised worker threads: catch, report, back off, restart, give up
visibly.

The serving tier's background loops (AsyncEngine dispatch, ReplicaFleet
ingest) used to be bare ``threading.Thread`` targets: any exception
unwound the loop and the thread died **silently** — queued futures
stranded forever, ingest waiters hung until timeout. A
:class:`Supervisor` owns the loop instead:

* ``run_once`` is ONE iteration of the worker (drain one batch / apply
  one ingest item), returning the number of items it processed;
* an exception is a **crash**: ``on_crash(exc)`` runs first (the owner
  resolves every outstanding future/event with a typed error — nothing
  may strand), the crash is counted in the obs registry, and the loop
  restarts after an exponential backoff with deterministic seeded
  jitter (decorrelated restarts without wall-clock randomness — a chaos
  run replays bit-identically);
* a successful iteration that did work resets the consecutive-failure
  count; ``max_consecutive_failures`` crashes in a row means the fault
  is not transient — the supervisor **gives up**: ``on_giveup(exc)``
  fires, ``degraded`` latches True, and the owner surfaces it in
  ``stats()`` instead of spinning forever against a broken backend.
"""
from __future__ import annotations

import random
import threading
import time

from ..obs import REGISTRY, instant

_M_RESTARTS = REGISTRY.counter(
    "worker_restarts", "supervised worker crashes that led to a restart",
    labelnames=("worker",))
_M_BACKOFF = REGISTRY.histogram(
    "worker_restart_backoff_seconds", "restart backoff delays",
    labelnames=("worker",))
_M_DEGRADED = REGISTRY.counter(
    "worker_degraded", "supervised workers that exhausted their restart "
    "budget and gave up", labelnames=("worker",))


class Supervisor:
    """Run ``run_once`` in a loop on a daemon thread, surviving crashes.

    ``sleep`` is injectable (tests pass a no-op); backoff jitter comes
    from ``random.Random(seed)`` so a replayed fault script produces the
    same delays. ``stats()`` is the owner's window into crash counts,
    the last error, and the degraded latch.
    """

    def __init__(self, name: str, run_once, *, on_crash=None, on_giveup=None,
                 max_consecutive_failures: int = 5,
                 backoff_base_s: float = 0.01, backoff_cap_s: float = 1.0,
                 seed: int = 0, sleep=None, idle_sleep_s: float = 0.0):
        self.name = name
        self._run_once = run_once
        self._on_crash = on_crash
        self._on_giveup = on_giveup
        self.max_consecutive_failures = int(max_consecutive_failures)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._rng = random.Random(seed)
        self._sleep = sleep
        self.idle_sleep_s = float(idle_sleep_s)
        self._closed = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self.crashes = 0
        self.consecutive = 0
        self.degraded = False
        self.last_error: str | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "Supervisor":
        self._thread = threading.Thread(target=self._loop,
                                        name=f"supervised-{self.name}",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> bool:
        """Signal the loop to exit and join; returns False when the
        thread failed to join in time (wedged — the caller must report
        it, not swallow it)."""
        self._closed.set()
        if self._thread is None:
            return True
        self._thread.join(timeout=timeout)
        return not self._thread.is_alive()

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # ------------------------------------------------------------ the loop
    def backoff_s(self, consecutive: int) -> float:
        """Backoff before restart number ``consecutive`` (1-based):
        ``min(cap, base * 2**(n-1))`` scaled by jitter in [0.5, 1.5)."""
        raw = min(self.backoff_cap_s,
                  self.backoff_base_s * (2.0 ** (consecutive - 1)))
        return raw * (0.5 + self._rng.random())

    def _wait(self, seconds: float) -> None:
        if self._sleep is not None:
            self._sleep(seconds)
        else:
            self._closed.wait(seconds)      # interruptible: stop() wakes it

    def _loop(self) -> None:
        while not self._closed.is_set():
            try:
                did = self._run_once()
            except Exception as e:          # noqa: BLE001 — the whole point
                with self._lock:
                    self.crashes += 1
                    self.consecutive += 1
                    self.last_error = f"{type(e).__name__}: {e}"
                    consec = self.consecutive
                instant("worker_crash", cat="fault", worker=self.name,
                        error=type(e).__name__, consecutive=consec)
                if self._on_crash is not None:
                    try:
                        self._on_crash(e)
                    except Exception:       # noqa: BLE001 — crash handler
                        pass                # must never kill the supervisor
                if consec >= self.max_consecutive_failures:
                    with self._lock:
                        self.degraded = True
                    _M_DEGRADED.inc(worker=self.name)
                    if self._on_giveup is not None:
                        try:
                            self._on_giveup(e)
                        except Exception:   # noqa: BLE001
                            pass
                    return                  # visible death, not a spin
                _M_RESTARTS.inc(worker=self.name)
                delay = self.backoff_s(consec)
                _M_BACKOFF.observe(delay, worker=self.name)
                self._wait(delay)
                continue
            if did:
                with self._lock:
                    self.consecutive = 0
            elif self.idle_sleep_s:
                self._wait(self.idle_sleep_s)

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        with self._lock:
            return dict(worker=self.name, alive=self.alive,
                        crashes=self.crashes,
                        consecutive_failures=self.consecutive,
                        degraded=self.degraded,
                        last_error=self.last_error)
