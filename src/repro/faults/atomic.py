"""One crash-safe write path for every persisted artifact.

``atomic_write`` is tmp file + flush + fsync + ``os.replace`` +
directory fsync: a crash at ANY instant leaves the destination either
the complete old content or the complete new content, never a torn
file. Every manifest, segment npz, legacy monolithic npz, and family
forest write goes through here — there is exactly one place where the
durability discipline lives (and exactly one fault site,
``store.write``, where the chaos plan can attack it).

The ``torn`` fault kind is the attack this helper exists to make
impossible: when the installed :class:`~repro.faults.plan.FaultPlan`
scripts a torn write for this call, the helper deliberately regresses to
the pre-PR 8 behaviour — partial bytes straight onto the destination
path, then a crash (:class:`InjectedFault`) — manufacturing exactly the
on-disk damage that ``load(..., recover=True)`` must quarantine. Torn
injection is the only way this module ever writes non-atomically.
"""
from __future__ import annotations

import io
import os

from .plan import InjectedFault, active_plan, fault_point


def atomic_write(path: str | os.PathLike, writer, *,
                 site: str = "store.write") -> None:
    """Write a file atomically: ``writer(fh)`` produces the full content
    into a binary file object; the destination is replaced only after
    the bytes are on disk (fsync), and the containing directory entry is
    fsynced so the rename itself survives a crash."""
    path = os.fspath(path)
    spec = fault_point(site, path=path)
    if spec is not None and spec.kind == "torn":
        # scripted torn write: the non-atomic writer of old, resurrected
        # for recovery testing — frac of the payload lands directly on
        # the destination, then the "process dies"
        buf = io.BytesIO()
        writer(buf)
        data = buf.getvalue()
        with open(path, "wb") as fh:
            fh.write(data[:max(1, int(len(data) * spec.frac))])
        plan = active_plan()
        raise InjectedFault(site, plan.calls(site) if plan else 0,
                            kind="torn")
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as fh:
            writer(fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.unlink(tmp)
            except OSError:
                pass
    _fsync_dir(os.path.dirname(path) or ".")


def _fsync_dir(dirpath: str) -> None:
    """fsync a directory so a completed rename is durable; best-effort on
    platforms/filesystems that refuse directory fds."""
    try:
        fd = os.open(dirpath, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)
