"""Signature joins — finding (query, reference) pairs within Hamming d.

Three implementations (DESIGN.md §2):

* ``flip_join`` — paper-faithful (Algorithms 3+4): every reference signature
  emits all C(f, <=d) bit-flips of itself as join keys; queries emit their own
  signature; equal keys collide. The Hadoop shuffle becomes an on-device
  sort + searchsorted key-collision join. Exact, no duplicates (a pair at
  distance h <= d collides on exactly one mask, m = q xor r). f <= 32.

* ``band_join`` — beyond-paper: pigeonhole banding. Split f bits into
  b >= d+1 bands; any pair within distance d agrees exactly on >= 1 band.
  Candidates from per-band equality joins are exact-filtered by popcount and
  deduplicated. Key count is O(b*N) instead of O(C(f,<=d)*N) — at f=32,d=2
  that is 3 keys/ref instead of 529.

* ``all_pairs`` thresholding (kernels/hamming.py) — the dense sweep used when
  the reference shard is small enough that the XOR+popcount matrix beats the
  join on arithmetic intensity.

All functions return fixed-capacity pair buffers (SPMD-friendly): rows past
the true count are (-1,-1,-1), and the true count is returned so callers can
detect overflow and grow capacity.
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from .hamming import hamming_distance
from .simhash import unpack_bits


# ---------------------------------------------------------------- flip masks
@functools.lru_cache(maxsize=8)
def flip_masks(f: int, d: int) -> np.ndarray:
    """All XOR masks with popcount <= d, packed: (M, f//32) uint32."""
    nw = f // 32
    masks = []
    for dd in range(d + 1):
        for comb in itertools.combinations(range(f), dd):
            m = np.zeros(nw, dtype=np.uint64)
            for b in comb:
                m[b // 32] |= np.uint64(1) << np.uint64(b % 32)
            masks.append(m.astype(np.uint32))
    return np.stack(masks, axis=0)


def _emit_from_ranges(left, counts, sorted_ids, max_pairs):
    """Turn per-query ranges [left, left+counts) over sorted_ids into a fixed
    (max_pairs, 2) (qid, rid) buffer. Returns (pairs, total_count)."""
    total = jnp.sum(counts)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])
    slots = jnp.arange(max_pairs, dtype=jnp.int32)
    qid = jnp.searchsorted(offsets, slots, side="right").astype(jnp.int32) - 1
    qid = jnp.clip(qid, 0, counts.shape[0] - 1)
    j = slots - offsets[qid].astype(jnp.int32)
    valid = slots < total
    rid = sorted_ids[jnp.clip(left[qid].astype(jnp.int32) + j, 0, sorted_ids.shape[0] - 1)]
    pairs = jnp.stack(
        [jnp.where(valid, qid, -1), jnp.where(valid, rid, -1)], axis=-1
    ).astype(jnp.int32)
    return pairs, total


def flip_join(q_sigs, r_sigs, *, f: int, d: int, max_pairs: int):
    """Paper-faithful flip join (f <= 32: keys are single uint32 words).

    Returns (pairs (max_pairs, 3) int32 [qid, rid, dist], count).
    """
    assert f <= 32, "flip_join keys are single uint32 words (paper used f=32)"
    masks = jnp.asarray(flip_masks(f, d))[:, 0]          # (M,)
    rk = (r_sigs[:, 0][:, None] ^ masks[None, :]).ravel()  # (R*M,)
    rid = jnp.repeat(
        jnp.arange(r_sigs.shape[0], dtype=jnp.int32), masks.shape[0]
    )
    order = jnp.argsort(rk)
    rk_sorted, rid_sorted = rk[order], rid[order]
    qk = q_sigs[:, 0]
    left = jnp.searchsorted(rk_sorted, qk, side="left")
    right = jnp.searchsorted(rk_sorted, qk, side="right")
    pairs2, count = _emit_from_ranges(left, (right - left).astype(jnp.int32),
                                      rid_sorted, max_pairs)
    qv, rv = pairs2[:, 0], pairs2[:, 1]
    dist = hamming_distance(q_sigs[jnp.maximum(qv, 0)], r_sigs[jnp.maximum(rv, 0)])
    dist = jnp.where(qv >= 0, dist, -1).astype(jnp.int32)
    return jnp.concatenate([pairs2, dist[:, None]], axis=-1), count


# ---------------------------------------------------------------- band join
def band_bit_groups(f: int, bands: int, *, interleave: bool = False):
    """Disjoint partition of bit positions into ``bands`` groups.

    Contiguous (default, the classic banding) or interleaved (bit i -> band
    i % bands). The pigeonhole guarantee needs only *disjointness*, so both
    are exact; interleaving matters in practice because signature bit
    entropy is position-skewed (the Java hashCode's high bits are nearly
    constant for short words — see simhash.py), and a contiguous high-bit
    band degenerates into one giant bucket.
    """
    if interleave:
        return [np.arange(b, f, bands) for b in range(bands)]
    edges = np.linspace(0, f, bands + 1).astype(int)
    return [np.arange(edges[b], edges[b + 1]) for b in range(bands)]


def mix32(keys) -> jnp.ndarray:
    """Splitmix-style 32-bit finalizer (murmur3 fmix32) over uint32 keys.

    A *bijection* on uint32, so equality classes — and therefore bucket
    membership and the pigeonhole guarantee — are exactly preserved; what
    changes is that the mixed keys are uniform over the word, so anything
    that partitions by key arithmetic (``key % n_shards`` bucket sharding,
    hash tables) sees splitmix-grade diversity even when the raw band bits
    are position-skewed (the Java hashCode problem measured in
    ``index.stats``).
    """
    h = jnp.asarray(keys, jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 16)


def band_keys(sigs, f: int, bands: int, *, interleave: bool = False,
              key_hash: str = "none") -> jnp.ndarray:
    """Per-band integer keys: (N, bands) uint32.

    Bands up to 32 bits wide pack exactly into the uint32 key. Wider bands
    (f=64/128 signatures at low band counts) FOLD: the band's 32-bit words
    are chained through the :func:`mix32` bijection
    (``acc = mix32(acc) ^ word``) — equal band bits always produce equal
    keys, so bucket co-membership and the pigeonhole guarantee are intact;
    a ~2^-32 accidental key collision between unequal bands can only ADD a
    candidate, which the exact Hamming filter downstream removes.

    ``key_hash="splitmix"`` mixes each band key through :func:`mix32`
    before bucketing (exactness-preserving — the mix is bijective).
    """
    bits = unpack_bits(sigs, f)                      # (N, f) in {0,1}
    keys = []
    for grp in band_bit_groups(f, bands, interleave=interleave):
        seg = bits[:, grp].astype(jnp.uint32)
        w = seg.shape[-1]
        acc = None
        for s0 in range(0, w, 32):
            wordbits = seg[:, s0:s0 + 32]
            ww = wordbits.shape[-1]
            word = jnp.sum(wordbits << jnp.arange(ww, dtype=jnp.uint32),
                           axis=-1)
            acc = word if acc is None else mix32(acc) ^ word
        keys.append(acc)
    out = jnp.stack(keys, axis=-1)
    if key_hash == "splitmix":
        return mix32(out)
    if key_hash != "none":
        raise ValueError(f"unknown key_hash {key_hash!r}")
    return out


# largest id for which the packed int32 sort key c0*(B+1)+c1 stays exact:
# (B-1)*(B+1) + (B-1) = B^2 + B - 2 must fit int32
PACKED_KEY_MAX_ID = 46340


def dedup_pairs(cand):
    """Sort a (M, 2) candidate buffer lexicographically and mark first
    occurrences.

    Returns (cand_sorted, keep): ``keep`` is True on the first copy of each
    valid (qid >= 0) pair. One multi-key ``lax.sort`` pass; shared by the
    query join (band_join) and — as the wide-id fallback of
    :func:`pack_unique_pairs` — the corpus self-join.
    """
    c0, c1 = jax.lax.sort((cand[:, 0], cand[:, 1]), num_keys=2)
    cs = jnp.stack([c0, c1], axis=-1)
    same = (cs[1:, 0] == cs[:-1, 0]) & (cs[1:, 1] == cs[:-1, 1])
    keep = jnp.concatenate([jnp.ones(1, bool), ~same]) & (cs[:, 0] >= 0)
    return cs, keep


def pack_unique_pairs(cand, *, out_cap: int, id_bound: int, sigs=None,
                      d: int | None = None):
    """Dedup + optional exact Hamming filter + front-compaction of a (M, 2)
    candidate buffer — the shared pack tail of every join.

    Returns (pairs (out_cap, 2) int32 with -1 past the survivors, count —
    the TRUE survivor count, which exceeds ``out_cap`` when the buffer
    truncated; truncation keeps the canonically-first survivors).

    With ``id_bound <= PACKED_KEY_MAX_ID`` (every id < bound — e.g. the
    corpus size, static at trace) the whole tail runs as two SINGLE-key
    sorts of the packed int32 key ``c0*(bound+1) + c1`` (exact and
    order-preserving; -1 invalid rows go negative and sort first): sort
    once to make duplicates adjacent, mark survivors, remap dropped keys to
    int32-max and sort again — the second sort IS the compaction, and both
    ids reconstruct from the key by one divide. On the pack's critical
    path this beats the generic multi-key sort + scatter several-fold:
    payload columns triple a CPU/TPU sort's data movement, and the cumsum
    scatter a compaction otherwise needs is the single most expensive op
    in the tail. Ids at or past ``id_bound`` would alias keys, so wide
    corpora fall back to :func:`dedup_pairs` + :func:`compact_pairs` —
    bit-identical output, same buffer contract.
    """
    if id_bound > PACKED_KEY_MAX_ID:
        cs, keep = dedup_pairs(cand)
        if d is not None:
            dist = hamming_distance(sigs[jnp.maximum(cs[:, 0], 0)],
                                    sigs[jnp.maximum(cs[:, 1], 0)])
            keep = keep & (dist <= d)
        return compact_pairs((cs[:, 0], cs[:, 1]), keep, out_cap)
    stride = jnp.int32(id_bound + 1)
    ks = jax.lax.sort(cand[:, 0] * stride + cand[:, 1])
    same = ks[1:] == ks[:-1]
    keep = jnp.concatenate([jnp.ones(1, bool), ~same]) & (ks >= 0)
    if d is not None:
        c0 = ks // stride
        c1 = ks - c0 * stride
        dist = hamming_distance(sigs[jnp.maximum(c0, 0)],
                                sigs[jnp.maximum(c1, 0)])
        keep = keep & (dist <= d)
    count = jnp.sum(keep.astype(jnp.int32))
    # max valid key is bound^2 + bound - 2 < int32-max for bound <= 46340,
    # so int32-max is a safe past-the-end sentinel
    sentinel = jnp.iinfo(jnp.int32).max
    ks2 = jax.lax.sort(jnp.where(keep, ks, sentinel))
    M = ks2.shape[0]
    if out_cap <= M:
        ks2 = ks2[:out_cap]
    else:
        ks2 = jnp.concatenate(
            [ks2, jnp.full(out_cap - M, sentinel, jnp.int32)])
    o0 = ks2 // stride
    pairs = jnp.stack(
        [jnp.where(ks2 == sentinel, -1, o0),
         jnp.where(ks2 == sentinel, -1, ks2 - o0 * stride)], axis=-1)
    return pairs, count


def compact_pairs(cols, keep, max_pairs: int):
    """Stable-compact kept rows to the front of a fixed (max_pairs, k) buffer.

    cols: per-column (M,) arrays; rows where ``keep`` is False become -1.
    Returns (out (max_pairs, len(cols)) int32, count — the TRUE kept count,
    which exceeds max_pairs when the buffer truncated).

    Compaction is a cumsum scatter, not a sort: kept row i lands at
    ``sum(keep[:i])`` (order-preserving by construction — exactly what the
    stable ``argsort(~keep)`` computed, at O(M) instead of a second
    O(M log M) sort on the pack's critical path); dropped and overflowing
    rows scatter into a discard slot past the buffer.
    """
    count = jnp.sum(keep.astype(jnp.int32))
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    dst = jnp.where(keep & (pos < max_pairs), pos, max_pairs)
    rows = jnp.stack([c.astype(jnp.int32) for c in cols], axis=-1)
    out = jnp.full((max_pairs + 1, rows.shape[-1]), -1, jnp.int32)
    out = out.at[dst].set(rows, mode="drop")
    return out[:max_pairs], count


def band_join(q_sigs, r_sigs, *, f: int, d: int, max_pairs: int,
              bands: int | None = None):
    """Pigeonhole banding join: exact for bands >= d+1, no false negatives.

    Candidates colliding in multiple bands are deduplicated; all candidates
    are exact-filtered by packed Hamming distance.

    Returns (pairs, count, truncated): ``truncated`` is True when a band's
    candidate emission overran the per-band capacity — the emitted pair set
    (and ``count`` itself) may then be incomplete, so callers must treat it
    as overflow and grow ``max_pairs``, even though count <= max_pairs.
    """
    b = bands if bands is not None else d + 1
    assert b >= d + 1, "bands must be >= d+1 for an exact join"
    qk = band_keys(q_sigs, f, b)                     # (Q, b)
    rk = band_keys(r_sigs, f, b)                     # (R, b)
    R = r_sigs.shape[0]
    cap = max_pairs  # per-band candidate capacity

    all_pairs = []
    truncated = jnp.zeros((), bool)
    for band in range(b):
        order = jnp.argsort(rk[:, band])
        rks = rk[:, band][order]
        rids = order.astype(jnp.int32)
        left = jnp.searchsorted(rks, qk[:, band], side="left")
        right = jnp.searchsorted(rks, qk[:, band], side="right")
        p2, emitted = _emit_from_ranges(
            left, (right - left).astype(jnp.int32), rids, cap)
        truncated = truncated | (emitted > cap)
        all_pairs.append(p2)
    cand = jnp.concatenate(all_pairs, axis=0)        # (b*cap, 2)

    cand_s, keep = dedup_pairs(cand)
    qv = jnp.where(keep, cand_s[:, 0], -1)
    rv = jnp.where(keep, cand_s[:, 1], -1)
    dist = hamming_distance(q_sigs[jnp.maximum(qv, 0)], r_sigs[jnp.maximum(rv, 0)])
    hit = keep & (dist <= d)
    out, count = compact_pairs((qv, rv, dist), hit, max_pairs)
    return out, count, truncated


def pairs_to_set(pairs) -> set[tuple[int, int]]:
    """Host-side helper: valid (q, r) rows of a pair buffer as a set."""
    arr = np.asarray(pairs)
    return {(int(a), int(b)) for a, b, *_ in arr if a >= 0}
