"""Amino-acid alphabet encoding and the BLOSUM62 substitution matrix.

Sequences are int8 tensors end-to-end (DESIGN.md §2: "no JVM strings
anywhere"); FASTA/strings exist only at the I/O edge.
"""
from __future__ import annotations

import numpy as np

# Canonical 20-letter amino-acid alphabet, in the standard BLOSUM row order.
AMINO_ACIDS = "ARNDCQEGHILKMFPSTWYV"
ALPHABET_SIZE = len(AMINO_ACIDS)  # 20
PAD = ALPHABET_SIZE               # padding token id (scores 0 everywhere)

_CHAR_TO_ID = {c: i for i, c in enumerate(AMINO_ACIDS)}

# BLOSUM62 (Henikoff & Henikoff 1992), 20x20, row/col order = AMINO_ACIDS.
# fmt: off
BLOSUM62 = np.array([
    #  A   R   N   D   C   Q   E   G   H   I   L   K   M   F   P   S   T   W   Y   V
    [  4, -1, -2, -2,  0, -1, -1,  0, -2, -1, -1, -1, -1, -2, -1,  1,  0, -3, -2,  0],  # A
    [ -1,  5,  0, -2, -3,  1,  0, -2,  0, -3, -2,  2, -1, -3, -2, -1, -1, -3, -2, -3],  # R
    [ -2,  0,  6,  1, -3,  0,  0,  0,  1, -3, -3,  0, -2, -3, -2,  1,  0, -4, -2, -3],  # N
    [ -2, -2,  1,  6, -3,  0,  2, -1, -1, -3, -4, -1, -3, -3, -1,  0, -1, -4, -3, -3],  # D
    [  0, -3, -3, -3,  9, -3, -4, -3, -3, -1, -1, -3, -1, -2, -3, -1, -1, -2, -2, -1],  # C
    [ -1,  1,  0,  0, -3,  5,  2, -2,  0, -3, -2,  1,  0, -3, -1,  0, -1, -2, -1, -2],  # Q
    [ -1,  0,  0,  2, -4,  2,  5, -2,  0, -3, -3,  1, -2, -3, -1,  0, -1, -3, -2, -2],  # E
    [  0, -2,  0, -1, -3, -2, -2,  6, -2, -4, -4, -2, -3, -3, -2,  0, -2, -2, -3, -3],  # G
    [ -2,  0,  1, -1, -3,  0,  0, -2,  8, -3, -3, -1, -2, -1, -2, -1, -2, -2,  2, -3],  # H
    [ -1, -3, -3, -3, -1, -3, -3, -4, -3,  4,  2, -3,  1,  0, -3, -2, -1, -3, -1,  3],  # I
    [ -1, -2, -3, -4, -1, -2, -3, -4, -3,  2,  4, -2,  2,  0, -3, -2, -1, -2, -1,  1],  # L
    [ -1,  2,  0, -1, -3,  1,  1, -2, -1, -3, -2,  5, -1, -3, -1,  0, -1, -3, -2, -2],  # K
    [ -1, -1, -2, -3, -1,  0, -2, -3, -2,  1,  2, -1,  5,  0, -2, -1, -1, -1, -1,  1],  # M
    [ -2, -3, -3, -3, -2, -3, -3, -3, -1,  0,  0, -3,  0,  6, -4, -2, -2,  1,  3, -1],  # F
    [ -1, -2, -2, -1, -3, -1, -1, -2, -2, -3, -3, -1, -2, -4,  7, -1, -1, -4, -3, -2],  # P
    [  1, -1,  1,  0, -1,  0,  0,  0, -1, -2, -2,  0, -1, -2, -1,  4,  1, -3, -2, -2],  # S
    [  0, -1,  0, -1, -1, -1, -1, -2, -2, -1, -1, -1, -1, -2, -1,  1,  5, -2, -2,  0],  # T
    [ -3, -3, -4, -4, -2, -2, -3, -2, -2, -3, -2, -3, -1,  1, -4, -3, -2, 11,  2, -3],  # W
    [ -2, -2, -2, -3, -2, -1, -2, -3,  2, -1, -1, -2, -1,  3, -3, -2, -2,  2,  7, -2],  # Y
    [  0, -3, -3, -3, -1, -2, -2, -3, -3,  3,  1, -2,  1, -1, -2, -2,  0, -3, -2,  4],  # V
], dtype=np.int32)
# fmt: on

# Padded variant: row/col PAD scores 0 so padded positions never contribute.
BLOSUM62_PADDED = np.zeros((ALPHABET_SIZE + 1, ALPHABET_SIZE + 1), dtype=np.int32)
BLOSUM62_PADDED[:ALPHABET_SIZE, :ALPHABET_SIZE] = BLOSUM62


def encode(seq: str) -> np.ndarray:
    """Encode an amino-acid string to an int8 id array (unknowns -> PAD)."""
    return np.array([_CHAR_TO_ID.get(c, PAD) for c in seq.upper()], dtype=np.int8)


def decode(ids) -> str:
    """Decode an id array back to a string (PAD -> 'X')."""
    out = []
    for i in np.asarray(ids).ravel():
        out.append(AMINO_ACIDS[int(i)] if 0 <= int(i) < ALPHABET_SIZE else "X")
    return "".join(out)


def encode_batch(seqs: list[str], max_len: int | None = None):
    """Encode a ragged batch -> (ids (N, L) int8 padded with PAD, lengths (N,))."""
    lens = np.array([len(s) for s in seqs], dtype=np.int32)
    L = int(max_len if max_len is not None else (lens.max() if len(seqs) else 0))
    ids = np.full((len(seqs), L), PAD, dtype=np.int8)
    for i, s in enumerate(seqs):
        e = encode(s)[:L]
        ids[i, : len(e)] = e
    return ids, np.minimum(lens, L)
