"""k-shingle extraction as strided gathers over int8 residue tensors.

The paper tokenizes each sequence into overlapping k-letter words (BLAST's
tokenization step). Here a batch of padded sequences (N, L) becomes a dense
shingle tensor (N, S, k) with a validity mask — no string ops.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .alphabet import PAD


def num_shingles(seq_len: int, k: int) -> int:
    return max(seq_len - k + 1, 0)


def extract_shingles(ids, lengths, k: int):
    """Extract overlapping k-shingles from a padded batch.

    Args:
      ids: (N, L) int8 residue ids, padded with PAD.
      lengths: (N,) int32 true sequence lengths.
      k: shingle length.

    Returns:
      shingles: (N, S, k) int8 where S = L - k + 1; invalid positions are PAD.
      mask: (N, S) bool — True where the shingle is fully inside the sequence.
    """
    ids = jnp.asarray(ids)
    lengths = jnp.asarray(lengths)
    N, L = ids.shape
    S = num_shingles(L, k)
    # (S, k) gather indices: row s takes positions s..s+k-1.
    idx = jnp.arange(S)[:, None] + jnp.arange(k)[None, :]
    sh = ids[:, idx]  # (N, S, k)
    mask = (jnp.arange(S)[None, :] + k) <= lengths[:, None]
    sh = jnp.where(mask[..., None], sh, jnp.int8(PAD))
    return sh, mask


def shingle_ids(shingles, alphabet_size: int = 20):
    """Flatten (…, k) shingles to integer word ids in [0, alphabet_size**k).

    Invalid shingles (containing PAD) map to -1.
    """
    k = shingles.shape[-1]
    valid = jnp.all(shingles < alphabet_size, axis=-1)
    powers = alphabet_size ** np.arange(k - 1, -1, -1)
    wid = jnp.sum(shingles.astype(jnp.int32) * jnp.asarray(powers, jnp.int32), axis=-1)
    return jnp.where(valid, wid, -1)
