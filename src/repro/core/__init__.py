"""ScalLoPS core: LSH protein-similarity search (the paper's contribution).

Public API: LSHConfig, ScalLoPS (pipeline.py); signature generation
(simhash.py); joins (join.py); distributed MapReduce engine (mapreduce.py).
"""
from .alphabet import AMINO_ACIDS, ALPHABET_SIZE, PAD, BLOSUM62, encode, decode, encode_batch
from .pipeline import LSHConfig, ScalLoPS, SearchResult

__all__ = [
    "AMINO_ACIDS", "ALPHABET_SIZE", "PAD", "BLOSUM62",
    "encode", "decode", "encode_batch", "LSHConfig", "ScalLoPS",
    "SearchResult",
]
