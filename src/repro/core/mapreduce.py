"""The paper's MapReduce layer, restated on `shard_map` + JAX collectives.

Hadoop concept -> TPU-native construct (DESIGN.md §2):

  map task        -> per-device shard compute inside `shard_map`
  shuffle         -> `jax.lax.all_to_all` routing records to owner shard
                     (owner = key mod n_shards)
  reduce-by-key   -> on-owner `sort` by key + segment-boundary cross-product
  speculative     -> hot-bucket *salting*: keys whose bucket exceeds a cap are
  re-execution       split across shards by a salt so no single reducer
                     receives a skewed bucket (the straggler fix native to
                     this domain)
  HDFS            -> fixed-capacity on-device buffers + checkpoint manifests

Everything is fixed-shape (SPMD): shuffles move exactly `capacity` records
per (src, dst) shard pair; overflow is *counted and reported*, never silent
(DESIGN.md §5 "no silent caps").

Also provides `ring_sweep`: the streaming alternative to the shuffle — the
reference set stays sharded and blocks rotate around the ring via
`lax.ppermute`, overlapping each block's Hamming sweep with the transfer of
the next (comm/compute overlap without a global barrier).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..util import shard_map_compat

from .hamming import hamming_distance


# ----------------------------------------------------------------- shuffle
def shuffle_records(keys, payload, *, axis_name: str, n_shards: int,
                    capacity: int):
    """Route (key, payload) records to owner shard = key % n_shards.

    Per-shard inputs (inside shard_map):
      keys: (n,) uint32 — join keys; key==0xFFFFFFFF marks an empty slot.
      payload: (n, p) int32.
    Returns (keys', payload', dropped) where keys'/payload' hold up to
    `n_shards*capacity` received records and `dropped` counts overflow
    records (per destination) that could not be packed.
    """
    n = keys.shape[0]
    EMPTY = jnp.uint32(0xFFFFFFFF)
    dst = (keys % jnp.uint32(n_shards)).astype(jnp.int32)
    dst = jnp.where(keys == EMPTY, -1, dst)

    # Pack records destined for shard s into row s of a (n_shards, capacity)
    # send buffer. rank_within_dst = stable per-destination arrival order.
    order = jnp.argsort(jnp.where(dst < 0, n_shards, dst), stable=True)
    dst_s = dst[order]
    seg_start = jnp.concatenate([jnp.ones(1, bool), dst_s[1:] != dst_s[:-1]])
    pos = jnp.arange(n) - jax.lax.cummax(
        jnp.where(seg_start, jnp.arange(n), 0), axis=0
    )
    ok = (dst_s >= 0) & (pos < capacity)
    send_k = jnp.full((n_shards, capacity), EMPTY, jnp.uint32)
    send_p = jnp.full((n_shards, capacity) + payload.shape[1:], -1, payload.dtype)
    flat = jnp.where(ok, dst_s * capacity + pos.astype(jnp.int32), 0)
    send_k = send_k.ravel().at[flat].set(
        jnp.where(ok, keys[order], send_k.ravel()[flat])).reshape(n_shards, capacity)
    pf = payload[order]
    send_p = send_p.reshape(n_shards * capacity, -1).at[flat].set(
        jnp.where(ok[:, None], pf.reshape(n, -1),
                  send_p.reshape(n_shards * capacity, -1)[flat])
    ).reshape((n_shards, capacity) + payload.shape[1:])
    dropped = jnp.sum((dst_s >= 0) & ~ok)

    # The shuffle itself: one all_to_all per tensor.
    recv_k = jax.lax.all_to_all(send_k, axis_name, 0, 0, tiled=False)
    recv_p = jax.lax.all_to_all(send_p, axis_name, 0, 0, tiled=False)
    return (recv_k.reshape(n_shards * capacity),
            recv_p.reshape((n_shards * capacity,) + payload.shape[1:]),
            dropped)


# ----------------------------------------------------------------- salting
def salt_hot_keys(keys, *, hot_threshold: int, n_salt: int, is_query,
                  replicate_queries: bool):
    """Split oversized buckets: refs in a hot bucket get key ^= salt<<24 with
    salt = slot % n_salt; queries in hot buckets are replicated across all
    salts (done by the caller via `query_salt_copies`). Here we just detect
    hot keys and re-key references.

    Returns (new_keys, hot_mask). Detection is per-shard (approximate global
    histogram — exact detection would need a count shuffle; per-shard counts
    upper-bound skew well for hash-distributed keys, and correctness never
    depends on detection: salting only *re-buckets*, the exact filter runs
    after the join).
    """
    order = jnp.argsort(keys)
    ks = keys[order]
    seg = jnp.concatenate([jnp.ones(1, bool), ks[1:] != ks[:-1]])
    seg_id = jnp.cumsum(seg) - 1
    counts = jnp.zeros(keys.shape[0], jnp.int32).at[seg_id].add(1)
    hot_sorted = counts[seg_id] > hot_threshold
    hot = jnp.zeros(keys.shape[0], bool).at[order].set(hot_sorted)
    salt = (jnp.arange(keys.shape[0], dtype=jnp.uint32) % jnp.uint32(n_salt)) + 1
    new_keys = jnp.where(hot & ~is_query, keys ^ (salt << jnp.uint32(24)), keys)
    return new_keys, hot


# ----------------------------------------------------------------- reduce
def reduce_join(keys, payload, *, max_pairs: int):
    """Per-owner reduce: group by key, emit query x reference cross products.

    payload rows are (seq_id, is_query). Mirrors Algorithm 4 of the paper,
    vectorized: sort by (key, is_query) so queries precede references within
    a bucket, then for every reference row emit pairs against the bucket's
    query prefix.
    """
    EMPTY = jnp.uint32(0xFFFFFFFF)
    n = keys.shape[0]
    is_q = payload[:, 1] == 1
    # Sort by key, queries first inside each bucket (lexsort: last key primary).
    order = jnp.lexsort((jnp.where(is_q, 0, 1).astype(jnp.int32), keys))
    ks, ids, qflag = keys[order], payload[order, 0], is_q[order]
    valid = ks != EMPTY

    seg = jnp.concatenate([jnp.ones(1, bool), ks[1:] != ks[:-1]])
    seg_id = jnp.cumsum(seg) - 1                       # (n,)
    # Number of queries in each bucket, and this row's bucket query offset.
    qcount_per_seg = jnp.zeros(n, jnp.int32).at[seg_id].add(
        (qflag & valid).astype(jnp.int32))
    nq = qcount_per_seg[seg_id]
    # Index of the first row of this row's bucket.
    seg_start_idx = jax.lax.cummax(jnp.where(seg, jnp.arange(n), 0), axis=0)
    # Each *reference* row emits nq pairs (its bucket's queries).
    emit_counts = jnp.where(valid & ~qflag, nq, 0)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(emit_counts)])
    total = offsets[-1]
    slots = jnp.arange(max_pairs, dtype=jnp.int32)
    row = jnp.searchsorted(offsets, slots, side="right").astype(jnp.int32) - 1
    row = jnp.clip(row, 0, n - 1)
    j = slots - offsets[row]
    ok = slots < total
    q_idx = seg_start_idx[row] + j                     # queries sit at bucket head
    qid = jnp.where(ok, ids[jnp.clip(q_idx, 0, n - 1)], -1)
    rid = jnp.where(ok, ids[row], -1)
    pairs = jnp.stack([qid, rid], axis=-1).astype(jnp.int32)
    return pairs, total


# ----------------------------------------------------------------- engine
@dataclass(frozen=True)
class MapReduceConfig:
    n_shards: int
    shuffle_capacity: int = 4096     # records per (src,dst) shard pair
    max_pairs_per_shard: int = 8192
    hot_threshold: int = 64
    n_salt: int = 4
    salting: bool = True
    axis_name: str = "data"


def distributed_flip_join(q_sigs, r_sigs, q_ids, r_ids, *, f: int, d: int,
                          mesh, cfg: MapReduceConfig):
    """The paper's Signature Processor as a shard_map program.

    q_sigs/r_sigs: (Nq, 1)/(Nr, 1) uint32 (f <= 32), sharded on axis 0.
    q_ids/r_ids: global sequence ids (int32).
    Returns (pairs (n_shards, max_pairs, 2), counts, dropped) — host code
    concatenates valid rows; every emitted pair is exact-filtered by the
    caller (pairs carry ids, signatures are re-looked-up host-side).
    """
    from .join import flip_masks
    masks = jnp.asarray(flip_masks(f, d))[:, 0]        # (M,)
    M = int(masks.shape[0])
    ax = cfg.axis_name

    def shard_fn(qs, rs, qi, ri):
        # --- map phase: queries emit own key; refs emit M flipped keys.
        qk = qs[:, 0]
        rk = (rs[:, 0][:, None] ^ masks[None, :]).ravel()
        rid = jnp.repeat(ri, M)
        keys = jnp.concatenate([qk, rk])
        ids = jnp.concatenate([qi, rid])
        isq = jnp.concatenate(
            [jnp.ones_like(qi), jnp.zeros_like(rid)]).astype(jnp.int32)
        # Empty-slot convention: ids < 0 mark padding rows.
        EMPTY = jnp.uint32(0xFFFFFFFF)
        keys = jnp.where(ids >= 0, keys, EMPTY)
        if cfg.salting:
            keys, _ = salt_hot_keys(
                keys, hot_threshold=cfg.hot_threshold, n_salt=cfg.n_salt,
                is_query=isq == 1, replicate_queries=False)
            # Replicate each query record across all salts of its bucket.
            qkeys = keys[: qk.shape[0]]
            salts = (jnp.arange(cfg.n_salt, dtype=jnp.uint32) + 1) << jnp.uint32(24)
            qk_rep = (qkeys[:, None] ^ jnp.concatenate(
                [jnp.zeros(1, jnp.uint32), salts])[None, :]).ravel()
            qi_rep = jnp.repeat(qi, cfg.n_salt + 1)
            keys = jnp.concatenate([qk_rep, keys[qk.shape[0]:]])
            ids = jnp.concatenate([qi_rep, ids[qk.shape[0]:]])
            isq = jnp.concatenate(
                [jnp.ones_like(qi_rep), isq[qk.shape[0]:]]).astype(jnp.int32)
            keys = jnp.where(ids >= 0, keys, EMPTY)
        payload = jnp.stack([ids, isq], axis=-1)
        # --- shuffle phase.
        k2, p2, dropped = shuffle_records(
            keys, payload, axis_name=ax, n_shards=cfg.n_shards,
            capacity=cfg.shuffle_capacity)
        # --- reduce phase.
        pairs, total = reduce_join(k2, p2, max_pairs=cfg.max_pairs_per_shard)
        return pairs, total[None], dropped[None]

    fn = shard_map_compat(
        shard_fn, mesh,
        in_specs=(P(ax), P(ax), P(ax), P(ax)),
        out_specs=(P(ax), P(ax), P(ax)),
    )
    return fn(q_sigs, r_sigs, q_ids, r_ids)


def ring_sweep(q_sigs, r_sigs, *, d: int, mesh, axis_name: str = "data",
               max_pairs_per_shard: int = 8192, q_ids=None, r_ids=None):
    """Streaming all-pairs sweep: reference blocks rotate around the ring via
    `ppermute` while each resident block is swept with the XOR+popcount
    distance — the comm/compute-overlap alternative to the shuffle join
    (DESIGN.md §5). Exact (no candidate generation).
    """
    n = mesh.shape[axis_name]

    def shard_fn(qs, rs, qi, ri):
        def step(carry, _):
            rblk, rids, pairs, cnt, hop = carry
            dist = jnp.sum(
                jax.lax.population_count(qs[:, None, :] ^ rblk[None, :, :]),
                axis=-1).astype(jnp.int32)
            hit = (dist <= d) & (qi[:, None] >= 0) & (rids[None, :] >= 0)
            # Compact hits into the fixed buffer at offset cnt.
            flat = hit.ravel()
            order = jnp.argsort(~flat, stable=True)[:max_pairs_per_shard]
            ok = flat[order]
            qq = qi[(order // rblk.shape[0]).astype(jnp.int32)]
            rr = rids[(order % rblk.shape[0]).astype(jnp.int32)]
            new = jnp.stack([jnp.where(ok, qq, -1), jnp.where(ok, rr, -1)], -1)
            nh = jnp.sum(ok.astype(jnp.int32))
            idx = jnp.arange(max_pairs_per_shard)
            write = (idx >= cnt) & (idx < cnt + nh)
            src = jnp.clip(idx - cnt, 0, max_pairs_per_shard - 1)
            pairs = jnp.where(write[:, None], new[src], pairs)
            cnt = cnt + nh
            # Rotate the reference block one hop around the ring (overlaps
            # with the next iteration's sweep under async dispatch).
            perm = [(i, (i + 1) % n) for i in range(n)]
            rblk = jax.lax.ppermute(rblk, axis_name, perm)
            rids = jax.lax.ppermute(rids, axis_name, perm)
            return (rblk, rids, pairs, cnt, hop + 1), None

        pairs0 = jnp.full((max_pairs_per_shard, 2), -1, jnp.int32)
        carry0 = (rs, ri, pairs0, jnp.int32(0), jnp.int32(0))
        (rs_f, ri_f, pairs, cnt, _), _ = jax.lax.scan(step, carry0, None, length=n)
        return pairs, cnt[None]

    if q_ids is None:
        q_ids = jnp.arange(q_sigs.shape[0], dtype=jnp.int32)
    if r_ids is None:
        r_ids = jnp.arange(r_sigs.shape[0], dtype=jnp.int32)
    fn = shard_map_compat(
        shard_fn, mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)),
    )
    return fn(q_sigs, r_sigs, q_ids, r_ids)
