"""BLAST-style neighbouring-word generation, restated as dense linear algebra.

The paper walks a per-shingle trie to enumerate all k-letter words whose
BLOSUM62 score against the shingle is >= T.  (The paper's prose says "below a
certain threshold" but its own experiments — fewer words as T grows, zero
words at very high T — match BLAST's `score >= T` semantics; the prose is a
typo and we follow the experiments.)

TPU-native restatement (DESIGN.md §2): the score of shingle s against every
word w of the 20^k codebook is

    score[s, w] = sum_i B62[s_i, w_i]
                = rows(s) @ onehot(codebook)^T

i.e. ONE matmul of (S, k*21) x (k*21, W) — an MXU operand, not a dictionary.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .alphabet import ALPHABET_SIZE, BLOSUM62_PADDED


@functools.lru_cache(maxsize=8)
def codebook(k: int) -> np.ndarray:
    """All 20^k words as (W, k) int8, word id = base-20 big-endian digits."""
    W = ALPHABET_SIZE**k
    ids = np.arange(W, dtype=np.int64)
    cols = []
    for i in range(k - 1, -1, -1):
        cols.append((ids // (ALPHABET_SIZE**i)) % ALPHABET_SIZE)
    return np.stack(cols, axis=-1).astype(np.int8)


@functools.lru_cache(maxsize=8)
def codebook_onehot(k: int) -> np.ndarray:
    """Codebook as (W, k*(ALPHABET_SIZE+1)) one-hot int8 matmul operand."""
    cb = codebook(k)
    W = cb.shape[0]
    A = ALPHABET_SIZE + 1
    oh = np.zeros((W, k, A), dtype=np.int8)
    np.put_along_axis(oh, cb[..., None].astype(np.int64), 1, axis=-1)
    return oh.reshape(W, k * A)


def shingle_rows(shingles) -> jnp.ndarray:
    """Per-shingle BLOSUM rows: (..., k) ids -> (..., k*(A+1)) int32.

    rows[..., i*(A+1) + a] = B62P[shingle_i, a]; PAD rows are all-zero so
    padded shingles score 0 against every word.
    """
    B = jnp.asarray(BLOSUM62_PADDED)  # (21, 21) int32
    r = B[shingles.astype(jnp.int32)]  # (..., k, 21)
    return r.reshape(*shingles.shape[:-1], -1)


def neighbor_scores(shingles, k: int) -> jnp.ndarray:
    """Dense neighbour scores (..., W) int32 via the codebook matmul."""
    rows = shingle_rows(shingles)  # (..., k*(A+1))
    C = jnp.asarray(codebook_onehot(k))  # (W, k*(A+1))
    return rows @ C.T.astype(jnp.int32)  # (..., W)


def neighbor_weights(shingles, k: int, T: int) -> jnp.ndarray:
    """Thresholded feature weights: score if score >= T else 0 (paper §3.1)."""
    s = neighbor_scores(shingles, k)
    return jnp.where(s >= T, s, 0)
