"""SimHash signature generation (paper §3 / Algorithm 2), TPU-native.

Two mathematically identical execution paths:

* ``method="matmul"`` — the paper's structure on the MXU: per block of
  codebook words, score shingles against the word block (matmul), threshold
  at T, multiply by the ±1 hyperplane block H[w, :f] and accumulate V.
  This is what ``kernels/siggen.py`` fuses into one Pallas kernel.

* ``method="table"`` — beyond-paper: because the neighbour set and scores of
  a shingle depend only on its word id, the *total* contribution of a shingle
  to V is a pure function of that id. We precompute
      C[p] = sum_w [score(p,w) >= T] * score(p,w) * H[w]      (W, f) int32
  once per (k, T, f); signature generation then collapses to a gather +
  segment-sum over shingle ids — O(S) per sequence instead of O(S*W).
  (BLAST itself precomputes its neighbourhood lookup; this is the same trick
  lifted to the hyperplane domain.)

Hash-bit sources for the hyperplanes:
* ``scheme="java"`` — faithful: Java ``String.hashCode`` of the word's
  letters (polynomial-31, int32 wraparound), f <= 32 (paper used f=32).
* ``scheme="splitmix"`` — beyond-paper: splitmix64 chain over the word id,
  arbitrary f; better bit entropy (the Java hash's high bits are skewed for
  short words — measured in benchmarks/quality.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .alphabet import ALPHABET_SIZE, AMINO_ACIDS
from .neighbors import codebook, codebook_onehot
from .shingle import extract_shingles, shingle_ids

GOLDEN = np.uint64(0x9E3779B97F4A7C15)


# ---------------------------------------------------------------- hash bits
def java_hash(k: int) -> np.ndarray:
    """Java String.hashCode of every codebook word: (W,) int32 (wraparound)."""
    cb = codebook(k)  # (W, k) int8 ids
    chars = np.array([ord(c) for c in AMINO_ACIDS], dtype=np.uint32)
    h = np.zeros(cb.shape[0], dtype=np.uint32)
    for i in range(k):
        h = h * np.uint32(31) + chars[cb[:, i].astype(np.int64)]
    return h.view(np.int32)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + GOLDEN).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@functools.lru_cache(maxsize=16)
def hyperplanes(k: int, f: int, scheme: str = "java") -> np.ndarray:
    """±1 hyperplane matrix H (W, f) int8 — bit j of hash(word) picks the sign."""
    W = ALPHABET_SIZE**k
    if scheme == "java":
        if f > 32:
            raise ValueError("java hashCode provides 32 bits; use scheme='splitmix'")
        h = java_hash(k).view(np.uint32)
        bits = ((h[:, None] >> np.arange(f, dtype=np.uint32)) & 1).astype(np.int8)
    elif scheme == "splitmix":
        n64 = (f + 63) // 64
        ids = np.arange(W, dtype=np.uint64)
        words = np.stack(
            [_splitmix64(ids * np.uint64(n64) + np.uint64(r)) for r in range(n64)],
            axis=-1,
        )  # (W, n64) uint64
        all_bits = (
            (words[:, :, None] >> np.arange(64, dtype=np.uint64)) & np.uint64(1)
        ).astype(np.int8)
        bits = all_bits.reshape(W, n64 * 64)[:, :f]
    else:
        raise ValueError(f"unknown hash scheme {scheme!r}")
    return (bits * 2 - 1).astype(np.int8)  # {0,1} -> {-1,+1}


# ---------------------------------------------------------------- packing
def pack_bits(bits) -> jnp.ndarray:
    """(..., f) bool/int -> (..., f//32) uint32 little-endian bit packing."""
    f = bits.shape[-1]
    assert f % 32 == 0, "f must be a multiple of 32"
    b = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], f // 32, 32)
    return jnp.sum(b << jnp.arange(32, dtype=jnp.uint32), axis=-1).astype(jnp.uint32)


def unpack_bits(packed, f: int) -> jnp.ndarray:
    """(..., f//32) uint32 -> (..., f) int32 in {0,1}."""
    w = packed[..., :, None] >> jnp.arange(32, dtype=jnp.uint32)
    return (w & 1).astype(jnp.int32).reshape(*packed.shape[:-1], f)


# ---------------------------------------------------------------- contribution table
@functools.lru_cache(maxsize=8)
def contribution_table(k: int, T: int, f: int, scheme: str = "java") -> np.ndarray:
    """C[p] = Σ_w [score(p,w) >= T]·score(p,w)·H[w]  — (W, f) int32.

    Computed blockwise with numpy (one-off, cacheable); identical semantics to
    the matmul path (verified in tests/test_simhash.py).
    """
    cb_oh = codebook_onehot(k).astype(np.int32)  # (W, k*(A+1))
    from .alphabet import BLOSUM62_PADDED

    B = BLOSUM62_PADDED  # (21, 21)
    cb = codebook(k).astype(np.int64)  # (W, k)
    # rows[p] = concat_i B[p_i, :] -> (W, k*(A+1))
    rows = B[cb].reshape(cb.shape[0], -1).astype(np.int32)
    H = hyperplanes(k, f, scheme).astype(np.int32)  # (W, f)
    W_total = cb.shape[0]
    out = np.zeros((W_total, f), dtype=np.int32)
    blk = 4096
    # float32 BLAS is exact here: |score| <= 44, |V| < 2^24 — and ~100x
    # faster than numpy's unaccelerated integer matmul (k=4 is a one-off
    # 160k x 160k sweep).
    rows_f = rows.astype(np.float32)
    cb_f = cb_oh.T.astype(np.float32)
    H_f = H.astype(np.float32)
    for i in range(0, W_total, blk):
        scores = rows_f[i : i + blk] @ cb_f          # (blk, W)
        wts = np.where(scores >= T, scores, 0.0)
        out[i : i + blk] = (wts @ H_f).astype(np.int32)
    return out


# ---------------------------------------------------------------- signature gen
def signatures_matmul(ids, lengths, *, k: int, T: int, f: int,
                      scheme: str = "java", word_block: int = 4096):
    """Paper-structure path: V = Σ_shingles thresholded-scores @ H, blocked
    over the codebook so the (S, W) score matrix never hits HBM whole.

    Args:
      ids: (N, L) int8 padded residues;  lengths: (N,).
    Returns:
      packed signatures (N, f//32) uint32.
    """
    from .neighbors import shingle_rows

    sh, mask = extract_shingles(ids, lengths, k)        # (N, S, k), (N, S)
    rows = shingle_rows(sh)                              # (N, S, k*(A+1)) int32
    rows = rows * mask[..., None].astype(jnp.int32)
    N, S, D = rows.shape
    C = jnp.asarray(codebook_onehot(k), jnp.int32)       # (W, D)
    H = jnp.asarray(hyperplanes(k, f, scheme), jnp.int32)  # (W, f)
    Wt = C.shape[0]
    nblk = -(-Wt // word_block)
    pad = nblk * word_block - Wt
    Cp = jnp.pad(C, ((0, pad), (0, 0))).reshape(nblk, word_block, D)
    Hp = jnp.pad(H, ((0, pad), (0, 0))).reshape(nblk, word_block, f)

    def body(V, blk):
        Cb, Hb = blk
        scores = jnp.einsum("nsd,wd->nsw", rows, Cb)     # (N, S, wb)
        wts = jnp.where(scores >= T, scores, 0)
        V = V + jnp.einsum("nsw,wf->nf", wts, Hb)        # accumulate
        return V, None

    V0 = jnp.zeros((N, f), jnp.int32)
    V, _ = jax.lax.scan(body, V0, (Cp, Hp))
    return pack_bits(V >= 0)


def signatures_table(ids, lengths, *, k: int, T: int, f: int,
                     scheme: str = "java", table=None):
    """Beyond-paper path: signature = pack(Σ_s C[shingle_id(s)] >= 0)."""
    if table is None:
        table = contribution_table(k, T, f, scheme)
    Ct = jnp.asarray(table)                              # (W, f) int32
    sh, mask = extract_shingles(ids, lengths, k)
    wid = shingle_ids(sh)                                # (N, S), -1 invalid
    contrib = jnp.where(wid[..., None] >= 0, Ct[jnp.maximum(wid, 0)], 0)
    V = jnp.sum(contrib, axis=1)                         # (N, f)
    return pack_bits(V >= 0)


def signatures(ids, lengths, *, k: int = 3, T: int = 13, f: int = 32,
               scheme: str = "java", method: str = "table", **kw):
    fn = {"table": signatures_table, "matmul": signatures_matmul}[method]
    return fn(ids, lengths, k=k, T=T, f=f, scheme=scheme, **kw)


@functools.lru_cache(maxsize=8)
def feature_count_table(k: int, T: int) -> np.ndarray:
    """count[p] = #{w : score(p, w) >= T} — neighbours per parent word."""
    from .alphabet import BLOSUM62_PADDED
    cb_oh = codebook_onehot(k).astype(np.float32)
    cb = codebook(k).astype(np.int64)
    rows = BLOSUM62_PADDED[cb].reshape(cb.shape[0], -1).astype(np.float32)
    W = cb.shape[0]
    out = np.zeros((W,), np.int32)
    blk = 4096
    for i in range(0, W, blk):
        scores = rows[i:i + blk] @ cb_oh.T
        out[i:i + blk] = (scores >= T).sum(axis=1)
    return out


def feature_counts(ids, lengths, *, k: int, T: int) -> jnp.ndarray:
    """Per-sequence total neighbour-feature count. The paper's Signature
    Processor "is designed to process only the sequences with non-zero
    signatures" (§5.2): sequences with zero features collapse to the
    all-ones fingerprint (V=0 -> every bit set) and must be filtered."""
    table = jnp.asarray(feature_count_table(k, T))
    sh, mask = extract_shingles(ids, lengths, k)
    wid = shingle_ids(sh)
    cnt = jnp.where(wid >= 0, table[jnp.maximum(wid, 0)], 0)
    return jnp.sum(cnt, axis=1)
