"""End-to-end ScalLoPS pipeline: the paper's two MapReduce jobs as one API.

    cfg = LSHConfig(k=4, T=22, f=32, d=0)
    sl = ScalLoPS(cfg)
    ref_sigs = sl.signatures(ref_ids_padded, ref_lengths)      # job 1 (refs)
    qry_sigs = sl.signatures(qry_ids_padded, qry_lengths)      # job 1 (queries)
    pairs, count, overflowed = sl.search(qry_sigs, ref_sigs)   # job 2

Reference signatures are reusable across query sets (paper §5.3: the
database-preparation analogue is paid once); `repro.index` builds that reuse
into a persistent, servable artifact.

`search` returns a SearchResult: the fixed-capacity pair buffer, the *true*
match count, and an `overflowed` flag — True when count exceeded the buffer
and rows were truncated, so callers can grow capacity and retry instead of
silently losing pairs (DESIGN.md §5 "no silent caps").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import simhash
from .join import band_join, flip_join
from .hamming import threshold_pairs


@dataclass(frozen=True)
class LSHConfig:
    """Paper parameters (§5): shingle length k, neighbour threshold T,
    signature bits f, Hamming threshold d. Paper defaults k=3/T=13 for the
    perf runs and best quality at k=4/T=22/d=0; f was 32 (JVM int)."""
    k: int = 3
    T: int = 13
    f: int = 32
    d: int = 0
    scheme: str = "java"          # "java" (faithful) | "splitmix" (beyond-paper)
    siggen_method: str = "table"  # "table" (beyond-paper) | "matmul" (paper structure)
    join_method: str = "flip"     # "flip" (paper) | "band" | "dense"
    max_pairs: int = 1 << 16

    def __post_init__(self):
        assert self.f % 32 == 0 and self.f >= 32
        if self.scheme == "java":
            assert self.f <= 32, "java hashCode yields 32 bits (paper); use splitmix"


class SearchResult(NamedTuple):
    """Fixed-capacity join result. ``count`` is the true number of matches;
    ``overflowed`` is True iff the buffer truncated rows (grow + retry)."""
    pairs: jax.Array        # (max_pairs, >=2) int32, -1 past the stored rows
    count: jax.Array        # () int32 — true match count
    overflowed: jax.Array   # () bool — buffer truncated


class ScalLoPS:
    def __init__(self, cfg: LSHConfig):
        self.cfg = cfg
        self._sig_fn = jax.jit(
            lambda ids, lens: simhash.signatures(
                ids, lens, k=cfg.k, T=cfg.T, f=cfg.f,
                scheme=cfg.scheme, method=cfg.siggen_method)
        )

    # ---- job 1: Signature Generator (map-only) ----
    def signatures(self, ids, lengths):
        return self._sig_fn(jnp.asarray(ids), jnp.asarray(lengths))

    def feature_counts(self, ids, lengths):
        """Per-sequence neighbour-feature counts (0 => degenerate
        all-ones signature; the paper filters those, §5.2)."""
        return simhash.feature_counts(jnp.asarray(ids),
                                      jnp.asarray(lengths),
                                      k=self.cfg.k, T=self.cfg.T)

    # ---- job 2: Signature Processor ----
    def search(self, q_sigs, r_sigs, *, max_pairs: int | None = None,
               q_valid=None, r_valid=None) -> SearchResult:
        """Join the signature sets. q_valid/r_valid: optional bool masks —
        pairs touching invalid (zero-feature) sequences are dropped, per the
        paper's non-zero-signature rule. Returns a :class:`SearchResult`;
        check ``overflowed`` before trusting the pair buffer to be complete.
        """
        cfg = self.cfg
        mp = max_pairs or cfg.max_pairs
        truncated = jnp.zeros((), bool)
        if cfg.join_method == "flip":
            pairs, count = flip_join(q_sigs, r_sigs, f=cfg.f, d=cfg.d,
                                     max_pairs=mp)
        elif cfg.join_method == "band":
            # band_join's count is computed from a capacity-bounded candidate
            # buffer, so it can undercount once a band overran capacity; the
            # truncated flag covers that case.
            pairs, count, truncated = band_join(q_sigs, r_sigs, f=cfg.f,
                                                d=cfg.d, max_pairs=mp)
        elif cfg.join_method == "dense":
            pairs, count = threshold_pairs(q_sigs, r_sigs, cfg.d, mp)
        else:
            raise ValueError(cfg.join_method)
        # Overflow is judged on the raw join count: once the buffer
        # truncates, any downstream count (including the masked one below)
        # undercounts.
        overflowed = (count > mp) | truncated
        if q_valid is not None or r_valid is not None:
            qv = (jnp.asarray(q_valid) if q_valid is not None
                  else jnp.ones(q_sigs.shape[0], bool))
            rv = (jnp.asarray(r_valid) if r_valid is not None
                  else jnp.ones(r_sigs.shape[0], bool))
            ok = (pairs[:, 0] >= 0) \
                & qv[jnp.maximum(pairs[:, 0], 0)] \
                & rv[jnp.maximum(pairs[:, 1], 0)]
            pairs = jnp.where(ok[:, None], pairs, -1)
            count = jnp.sum(ok.astype(jnp.int32))
        return SearchResult(pairs, count, overflowed)
