"""Hamming-distance operations on packed signatures.

Signatures are (..., nwords) uint32 (f = nwords*32 bits). The Signature
Processor's similarity measure is the Hamming distance between signatures
(paper §3) — on TPU this is XOR + ``lax.population_count`` on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_distance(a, b) -> jnp.ndarray:
    """Elementwise Hamming distance of packed signatures (broadcasting)."""
    x = jnp.bitwise_xor(a, b)
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def all_pairs_hamming(q, r, block: int = 1024) -> jnp.ndarray:
    """(Q, nw) x (R, nw) -> (Q, R) int32 distance matrix, blocked over R.

    Pure-jnp reference; the production path is kernels/hamming.py.
    """
    Q, nw = q.shape
    R = r.shape[0]
    nblk = -(-R // block)
    pad = nblk * block - R
    rp = jnp.pad(r, ((0, pad), (0, 0))).reshape(nblk, block, nw)

    def body(_, rb):
        d = hamming_distance(q[:, None, :], rb[None, :, :])  # (Q, block)
        return None, d

    _, out = jax.lax.scan(body, None, rp)           # (nblk, Q, block)
    out = jnp.moveaxis(out, 0, 1).reshape(Q, nblk * block)
    return out[:, :R]


def threshold_pairs(q, r, d: int, max_pairs: int):
    """Emit (qid, rid, dist) for all pairs with Hamming distance <= d.

    Fixed-capacity output (SPMD-friendly): returns
      pairs (max_pairs, 3) int32 — rows past ``count`` are (-1, -1, -1);
      count () int32 — true number of matches (may exceed max_pairs; then
      the emitted set is truncated and the caller should grow capacity).
    """
    dist = all_pairs_hamming(q, r)
    hit = dist <= d
    count = jnp.sum(hit.astype(jnp.int32))
    flat = hit.ravel()
    # Stable compaction: indices of hits, padded with -1.
    order = jnp.argsort(~flat, stable=True)[:max_pairs]
    ok = flat[order]
    qid = (order // r.shape[0]).astype(jnp.int32)
    rid = (order % r.shape[0]).astype(jnp.int32)
    dd = dist.ravel()[order].astype(jnp.int32)
    pairs = jnp.stack(
        [jnp.where(ok, qid, -1), jnp.where(ok, rid, -1), jnp.where(ok, dd, -1)],
        axis=-1,
    )
    return pairs, count
