"""Minimal FASTA I/O (strings live only at this edge; everything inside the
framework is int8 tensors)."""
from __future__ import annotations

from pathlib import Path

import numpy as np

from ..core.alphabet import encode_batch, decode


def read_fasta(path) -> tuple[list[str], list[str]]:
    """Returns (names, sequences)."""
    names, seqs, cur = [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith(">"):
                if cur:
                    seqs.append("".join(cur))
                    cur = []
                names.append(line[1:].split()[0])
            else:
                cur.append(line)
    if cur:
        seqs.append("".join(cur))
    return names, seqs


def write_fasta(path, names, ids, lens) -> None:
    with open(path, "w") as f:
        for i, name in enumerate(names):
            f.write(f">{name}\n{decode(np.asarray(ids[i])[:int(lens[i])])}\n")


def load_fasta_encoded(path, max_len: int | None = None):
    names, seqs = read_fasta(path)
    ids, lens = encode_batch(seqs, max_len)
    return names, ids, lens
