"""Synthetic protein datasets with *planted, known* homology.

The paper evaluates on E. coli / Ace Lake / GOS query sets against
myva/swissprot/nr (none redistributable here, and the container is offline).
We generate structurally matched stand-ins: a reference set of random
sequences (residues drawn from the empirical SwissProt amino-acid frequency)
plus query sets derived by a point-mutation/indel/truncation channel with a
*controlled* target identity — so every quality experiment has exact ground
truth (which reference each query descends from, and at what mutation rate),
strictly stronger than the paper's BLAST-intersection proxy. Benchmarks
also reproduce the paper's set-size ratios (queries >> references for the
metagenomic regime, §5.4).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.alphabet import ALPHABET_SIZE, AMINO_ACIDS

# Empirical amino-acid frequencies (SwissProt composition), AMINO_ACIDS order.
AA_FREQ = np.array([
    0.0826, 0.0553, 0.0406, 0.0546, 0.0137, 0.0393, 0.0674, 0.0708,
    0.0227, 0.0593, 0.0966, 0.0582, 0.0241, 0.0386, 0.0474, 0.0660,
    0.0535, 0.0110, 0.0292, 0.0687,
])
AA_FREQ = AA_FREQ / AA_FREQ.sum()


def random_protein(rng: np.random.Generator, length: int) -> np.ndarray:
    return rng.choice(ALPHABET_SIZE, size=length, p=AA_FREQ).astype(np.int8)


def mutate(rng: np.random.Generator, seq: np.ndarray, *,
           sub_rate: float, indel_rate: float = 0.0,
           truncate_to: int | None = None) -> np.ndarray:
    """Point-substitution + indel channel; expected identity ≈ 1 - sub_rate."""
    s = seq.copy()
    subs = rng.random(len(s)) < sub_rate
    s[subs] = rng.choice(ALPHABET_SIZE, size=int(subs.sum()), p=AA_FREQ)
    if indel_rate > 0:
        keep = rng.random(len(s)) >= indel_rate
        ins_mask = rng.random(len(s)) < indel_rate
        out = []
        for i, ch in enumerate(s):
            if keep[i]:
                out.append(ch)
            if ins_mask[i]:
                out.append(rng.choice(ALPHABET_SIZE, p=AA_FREQ))
        s = np.asarray(out, np.int8)
    if truncate_to is not None:
        s = s[:truncate_to]
    return s


@dataclass(frozen=True)
class SyntheticProteinConfig:
    n_refs: int = 256
    n_homolog_queries: int = 64     # queries descended from references
    n_decoy_queries: int = 64       # unrelated random queries
    ref_len_mean: int = 300         # paper: myva/swissprot avg ≈ 300-370
    ref_len_std: int = 80
    query_len_mean: int | None = None  # None -> same as parent (Fig 5.4 uses short)
    sub_rates: tuple[float, ...] = (0.05, 0.15, 0.30)  # planted identity tiers
    seed: int = 0


def make_protein_sets(cfg: SyntheticProteinConfig):
    """Returns dict with padded id arrays, lengths, and ground-truth labels.

    ground_truth[i] = (parent_ref_index, sub_rate) for homolog queries,
    (-1, nan) for decoys.
    """
    rng = np.random.default_rng(cfg.seed)
    refs = []
    for _ in range(cfg.n_refs):
        L = max(30, int(rng.normal(cfg.ref_len_mean, cfg.ref_len_std)))
        refs.append(random_protein(rng, L))
    queries, truth = [], []
    for i in range(cfg.n_homolog_queries):
        parent = int(rng.integers(cfg.n_refs))
        rate = cfg.sub_rates[i % len(cfg.sub_rates)]
        q = mutate(rng, refs[parent], sub_rate=rate,
                   truncate_to=cfg.query_len_mean)
        queries.append(q)
        truth.append((parent, rate))
    for _ in range(cfg.n_decoy_queries):
        L = cfg.query_len_mean or max(
            30, int(rng.normal(cfg.ref_len_mean, cfg.ref_len_std)))
        queries.append(random_protein(rng, L))
        truth.append((-1, float("nan")))

    def pad(seqs):
        if not seqs:
            return (np.zeros((0, 1), np.int8), np.zeros((0,), np.int32))
        L = max(len(s) for s in seqs)
        out = np.full((len(seqs), L), ALPHABET_SIZE, np.int8)  # PAD
        lens = np.zeros(len(seqs), np.int32)
        for i, s in enumerate(seqs):
            out[i, : len(s)] = s
            lens[i] = len(s)
        return out, lens

    r_ids, r_lens = pad(refs)
    q_ids, q_lens = pad(queries)
    return dict(ref_ids=r_ids, ref_lens=r_lens, query_ids=q_ids,
                query_lens=q_lens, truth=truth)


@dataclass(frozen=True)
class FamilyCorpusConfig:
    """A flat corpus with planted protein families (for all-vs-all search)."""
    n_families: int = 32
    family_size: int = 4            # members per family (>= 2)
    n_singletons: int = 64          # unrelated sequences (their own family)
    len_mean: int = 200
    len_std: int = 40
    sub_rate: float = 0.1           # within-family mutation channel
    indel_rate: float = 0.0
    seed: int = 0


def make_family_corpus(cfg: FamilyCorpusConfig):
    """Corpus with known family structure for many-against-many search.

    Each family is one random founder plus ``family_size - 1`` mutated
    copies; singletons are unrelated random sequences. Members are shuffled
    so family structure never aligns with corpus order.

    Returns dict(ids (N, L) int8 PAD-padded, lens (N,) int32,
    labels (N,) int32 — ground-truth family id, singletons get unique ids).
    """
    rng = np.random.default_rng(cfg.seed)
    seqs, labels = [], []
    for fam in range(cfg.n_families):
        L = max(30, int(rng.normal(cfg.len_mean, cfg.len_std)))
        founder = random_protein(rng, L)
        seqs.append(founder)
        labels.append(fam)
        for _ in range(cfg.family_size - 1):
            seqs.append(mutate(rng, founder, sub_rate=cfg.sub_rate,
                               indel_rate=cfg.indel_rate))
            labels.append(fam)
    for s in range(cfg.n_singletons):
        L = max(30, int(rng.normal(cfg.len_mean, cfg.len_std)))
        seqs.append(random_protein(rng, L))
        labels.append(cfg.n_families + s)
    perm = rng.permutation(len(seqs))
    seqs = [seqs[i] for i in perm]
    labels = np.asarray(labels, np.int32)[perm]

    L = max(len(s) for s in seqs)
    ids = np.full((len(seqs), L), ALPHABET_SIZE, np.int8)  # PAD
    lens = np.zeros(len(seqs), np.int32)
    for i, s in enumerate(seqs):
        ids[i, : len(s)] = s
        lens[i] = len(s)
    return dict(ids=ids, lens=lens, labels=labels)


def to_strings(ids, lens) -> list[str]:
    from ..core.alphabet import decode
    return [decode(ids[i][: int(lens[i])]) for i in range(len(lens))]
