"""LM token pipeline with the paper's LSH as a first-class dedup stage.

ScalLoPS' role inside the LM framework (DESIGN.md §3): Manku-style SimHash
near-duplicate detection over token streams. Token documents are sketched
with the same signature machinery as protein sequences — k-shingles of
tokens, splitmix hyperplanes, Hamming join — and near-duplicate documents
(distance <= d) are dropped before batching. The batch iterator is a
*stateless* function of (step, shard): a restarted worker re-joins at a step
boundary with identical data order (fault-tolerance requirement, §5).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.simhash import pack_bits, GOLDEN
from ..core.join import band_join


@dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dedup: bool = True
    # Calibration (see tests): a mutation rate m changes ~m*L*k of ~L shingle
    # features; expected signature distance ≈ f·acos(1-k·m)/π. With k=4,
    # f=128: 2%-mutated twins land at E[dist]≈16 (σ≈3.8) while unrelated docs
    # sit at f/2=64 (σ≈5.7) — d=28 splits them by >6σ either side.
    dedup_k: int = 4        # token-shingle length
    dedup_f: int = 128      # signature bits
    dedup_d: int = 28       # Hamming threshold


def _splitmix_jnp(x):
    x = (x + jnp.uint32(0x9E3779B9)).astype(jnp.uint32)
    z = x
    z = (z ^ (z >> 16)) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> 13)) * jnp.uint32(0xC2B2AE35)
    return z ^ (z >> 16)


def token_signatures(tokens, lengths, *, k: int = 8, f: int = 64):
    """SimHash over token k-shingles, unit weights, hash-derived hyperplanes.

    tokens: (N, L) int32; PAD = -1. Returns (N, f//32) uint32.
    Unlike proteins there is no substitution neighbourhood — the feature set
    is the shingle multiset itself (Manku et al.'s document regime).
    """
    tokens = jnp.asarray(tokens)
    N, L = tokens.shape
    S = L - k + 1
    idx = jnp.arange(S)[:, None] + jnp.arange(k)[None, :]
    sh = tokens[:, idx]                                   # (N, S, k)
    valid = (jnp.arange(S)[None, :] + k) <= jnp.asarray(lengths)[:, None]
    # rolling polynomial hash of each shingle -> uint32
    h = jnp.zeros((N, S), jnp.uint32)
    for i in range(k):
        h = h * jnp.uint32(1000003) + sh[:, :, i].astype(jnp.uint32)
    # f sign bits per shingle from per-bit-word splitmix streams
    Vs = []
    for w in range(f // 32):
        hw = _splitmix_jnp(h ^ jnp.uint32((w * 0x9E3779B9) & 0xFFFFFFFF))
        bits = ((hw[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1)
        pm = bits.astype(jnp.int32) * 2 - 1               # (N, S, 32) ±1
        pm = pm * valid[..., None].astype(jnp.int32)
        Vs.append(pm.sum(axis=1))                         # (N, 32)
    V = jnp.concatenate(Vs, axis=-1)                      # (N, f)
    return pack_bits(V >= 0)


def dedup_corpus(tokens, lengths, *, k: int = 4, f: int = 128, d: int = 28,
                 max_pairs: int = 1 << 16):
    """Drop near-duplicate documents: returns (keep_mask (N,) bool, n_dups).

    Self-join of the corpus signatures; for every duplicate pair the higher
    index is dropped (first occurrence wins — deterministic).
    """
    sigs = token_signatures(tokens, lengths, k=k, f=f)
    # Grow-and-retry on overflow: a truncated self-join would silently keep
    # real duplicates in the corpus (no silent caps).
    while True:
        pairs, count, truncated = band_join(sigs, sigs, f=f, d=d,
                                            max_pairs=max_pairs)
        if not (bool(truncated) or int(count) > max_pairs):
            break
        max_pairs *= 2
    p = np.asarray(pairs)
    N = tokens.shape[0]
    keep = np.ones(N, bool)
    for qi, ri, _dd in p:
        if qi >= 0 and ri > qi:       # drop the later twin
            keep[ri] = False
    return keep, int((~keep).sum())


def synth_corpus(cfg: LMDataConfig, n_docs: int, dup_fraction: float = 0.1):
    """Synthetic token corpus with planted near-duplicates (mutation rate 2%)."""
    rng = np.random.default_rng(cfg.seed)
    docs = rng.integers(0, cfg.vocab_size, (n_docs, cfg.seq_len), np.int32)
    n_dup = int(n_docs * dup_fraction)
    for i in range(n_dup):
        src = int(rng.integers(n_docs - n_dup))
        twin = docs[src].copy()
        flips = rng.random(cfg.seq_len) < 0.02
        twin[flips] = rng.integers(0, cfg.vocab_size, int(flips.sum()))
        docs[n_docs - n_dup + i] = twin
    lens = np.full(n_docs, cfg.seq_len, np.int32)
    return docs, lens


def lm_batches(cfg: LMDataConfig, step: int, *, shard: int = 0,
               n_shards: int = 1):
    """Stateless batch for `step`: tokens/targets (per-shard slice).

    Deterministic in (cfg.seed, step, shard) — a restarted worker regenerates
    exactly the batch it would have seen (checkpoint/restart invariant,
    tested in tests/test_checkpoint.py).
    """
    per_shard = cfg.global_batch // n_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
    toks = jax.random.randint(key, (per_shard, cfg.seq_len + 1), 0,
                              cfg.vocab_size, jnp.int32)
    return toks[:, :-1], toks[:, 1:]
