"""Data substrate: synthetic protein sets with planted homology, FASTA I/O,
LM token pipeline with the paper's LSH as a dedup stage."""
from .synthetic import (FamilyCorpusConfig, SyntheticProteinConfig,
                        make_family_corpus, make_protein_sets, mutate)
from .fasta import read_fasta, write_fasta
from .lm_data import LMDataConfig, lm_batches, dedup_corpus

__all__ = ["SyntheticProteinConfig", "make_protein_sets", "mutate",
           "FamilyCorpusConfig", "make_family_corpus",
           "read_fasta", "write_fasta", "LMDataConfig", "lm_batches",
           "dedup_corpus"]
