"""Pallas TPU kernels for the paper's compute hot-spots (validated with
interpret=True on CPU):

  hamming.py — the Signature Processor's blocked XOR+popcount sweep
  siggen.py  — the Signature Generator's fused score->threshold->hyperplane
               accumulation (two chained MXU matmuls per VMEM tile)
  sw.py      — batched Smith-Waterman row-wave DP over a pair block (the
               all-pairs tiler's inner loop; lane-parallel prefix max)

ops.py: jit'd public wrappers (padding + platform dispatch).
ref.py: pure-jnp oracles — the correctness contract for every kernel.
"""
