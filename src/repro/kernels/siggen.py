"""Pallas TPU kernel: fused SimHash signature accumulation.

The Signature Generator's hot loop (paper §3.1 / Algorithm 2), restated as
two chained matmuls per tile (DESIGN.md §2) and fused so the (S, W)
neighbour-score matrix never leaves VMEM:

    grid (S/bs, W/bw):
        scores = rows_tile (bs, D) @ codebook_tile^T (D, bw)     # MXU
        wts    = where(scores >= T, scores, 0)                   # VPU
        V_tile += wts (bs, bw) @ H_tile (bw, f)                  # MXU

* rows: per-shingle BLOSUM row concatenations, D = k*(A+1) (A=20).
* codebook: one-hot words — static operand, streamed block-by-block.
* H: ±1 hyperplane matrix — static operand, streamed with the codebook.
* V: (S, f) int32 accumulator; the word-grid axis revisits the output block.

The sign/packing epilogue stays outside the kernel (cheap, O(S*f) bits).
VMEM per step ≈ bs*D + bw*D + bs*bw + bw*f + bs*f ints; with bs=bw=256,
D=105 (k=4), f=128: ~0.6 MB — far under the ~16 MB v5e VMEM budget, leaving
room for double-buffered streaming of the (W-major) codebook/H operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BS = 256   # shingle-block (sublane-aligned)
DEFAULT_BW = 512   # word-block (lane-aligned)


def _siggen_kernel(rows_ref, cb_ref, h_ref, v_ref, *, T: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    rows = rows_ref[...].astype(jnp.int32)          # (bs, D)
    cb = cb_ref[...].astype(jnp.int32)              # (bw, D)
    scores = jax.lax.dot_general(
        rows, cb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)            # (bs, bw)
    wts = jnp.where(scores >= T, scores, 0)
    h = h_ref[...].astype(jnp.int32)                # (bw, f)
    v_ref[...] += jax.lax.dot_general(
        wts, h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)            # (bs, f)


@functools.partial(jax.jit,
                   static_argnames=("T", "bs", "bw", "interpret"))
def siggen_accumulate_kernel(rows, cb, H, *, T: int, bs: int = DEFAULT_BS,
                             bw: int = DEFAULT_BW, interpret: bool = True):
    """Accumulate SimHash vectors V = Σ_w [score>=T]·score·H over the codebook.

    Args:
      rows: (S, D) int32 — shingle BLOSUM rows (padded shingles = all-zero
        rows, which score 0 < T against every word and contribute nothing).
      cb:   (W, D) int8  — one-hot codebook.
      H:    (W, f) int8  — ±1 hyperplanes.
    Returns:
      V: (S, f) int32 (callers apply sign + pack_bits).
    """
    S, D = rows.shape
    W, f = H.shape
    assert S % bs == 0 and W % bw == 0, "pad in ops.signatures_fused"
    grid = (S // bs, W // bw)
    return pl.pallas_call(
        functools.partial(_siggen_kernel, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bw, D), lambda i, j: (j, 0)),
            pl.BlockSpec((bw, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bs, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, f), jnp.int32),
        interpret=interpret,
    )(rows, cb, H)
