"""Pallas TPU kernel: batched masked-SpGEMM candidate emission.

The device inner loop of ``repro.index.spgemm``: each band's bucket CSR is
the sequence×bucket incidence matrix ``A``, and the strict upper triangle
of the Boolean-semiring ``AᵀA`` — every unordered within-bucket pair,
emitted once — is flattened into a fixed-capacity pair buffer. The grid is
2-D over (band slab, slot block); each program holds one band's offsets
``(1, U+1)`` and entry ids ``(1, E)`` in VMEM and materializes one block
of output slots.

Everything is expressed in the Pallas-friendly subset the SW kernels
established (`kernels/sw.py`): ``broadcasted_iota`` instead of captured
``arange`` constants, searchsorted as a comparison-sum reduction, gathers
as one-hot compare-and-reduce, and the per-band prefix sum (slot -> owning
entry) as a log-doubling shifted add (Hillis-Steele) — ``lax.cumsum`` does
not lower inside Pallas TPU kernels. The per-program working set is the
(U+1, E) bucket-membership comparison and an (E, SB) one-hot block, so
slabs up to a few thousand entries per band fit VMEM comfortably (the
pow2-padded slabs of `index/partition.py` are exactly that size at the
benchmark corpora).

``interpret`` defaults to autodetect (native lowering on TPU, interpret
elsewhere — this CPU container). Output is bit-exact with the jnp
reference ``repro.index.spgemm.masked_pair_product(mask="upper")`` and the
host oracle `kernels.ref.spgemm_upper_ref`: same pairs in the same slot
order ((lo, hi)-oriented, -1 past each band's true count).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sw import resolve_interpret

DEFAULT_SLOT_BLOCK = 512


def _upper_kernel(offs_ref, ids_ref, lo_ref, hi_ref, *, SB: int):
    offs = offs_ref[...].astype(jnp.int32)        # (1, U1)
    ids = ids_ref[...].astype(jnp.int32)          # (1, E)
    U1 = offs.shape[1]
    E = ids.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, E), 1)
    # owning bucket of each entry: searchsorted(offs, pos, 'right') - 1,
    # as a comparison-sum (slab padding repeats the last offset, so padded
    # entry positions resolve past the last real bucket and own nothing)
    le = (offs[0, :, None] <= pos[0, None, :]).astype(jnp.int32)  # (U1, E)
    b = jnp.sum(le, axis=0, keepdims=True) - 1                    # (1, E)
    # bucket end of each entry: offs[b + 1] via one-hot reduce (no gathers)
    row = jax.lax.broadcasted_iota(jnp.int32, (U1, E), 0)
    bp1 = jnp.clip(b + 1, 0, U1 - 1)
    end = jnp.sum(jnp.where(row == bp1, offs[0, :, None], 0), axis=0,
                  keepdims=True)                                  # (1, E)
    # upper mask: entry p pairs with the LATER members of its own bucket
    cnt = jnp.maximum(end - 1 - pos, 0)                           # (1, E)
    # inclusive prefix sum over entries: log-doubling shifted add
    inc = cnt
    s = 1
    while s < E:
        shifted = jnp.concatenate(
            [jnp.zeros((1, s), jnp.int32), inc[:, :-s]], axis=1)
        inc = inc + shifted
        s *= 2
    total = jnp.max(inc)          # == inc[0, -1]: cumsum is non-decreasing
    exc = inc - cnt               # exclusive prefix = first slot of entry p
    # this block's global slot indices
    sl = (jax.lax.broadcasted_iota(jnp.int32, (1, SB), 1)
          + pl.program_id(1) * SB)                                # (1, SB)
    # owning entry of each slot: searchsorted(inc, slot, 'right')
    p = jnp.sum((inc[0, :, None] <= sl[0, None, :]).astype(jnp.int32),
                axis=0, keepdims=True)                            # (1, SB)
    p = jnp.clip(p, 0, E - 1)
    sel = (jax.lax.broadcasted_iota(jnp.int32, (E, SB), 0) == p)  # one-hot
    a = jnp.sum(jnp.where(sel, ids[0, :, None], 0), axis=0,
                keepdims=True)                                    # left id
    exc_p = jnp.sum(jnp.where(sel, exc[0, :, None], 0), axis=0,
                    keepdims=True)
    # upper-mask window starts at the NEXT entry: win_start[p] = p + 1
    j = jnp.clip(p + 1 + (sl - exc_p), 0, E - 1)
    selj = (jax.lax.broadcasted_iota(jnp.int32, (E, SB), 0) == j)
    partner = jnp.sum(jnp.where(selj, ids[0, :, None], 0), axis=0,
                      keepdims=True)
    valid = sl < total
    lo_ref[...] = jnp.where(valid, jnp.minimum(a, partner), -1)
    hi_ref[...] = jnp.where(valid, jnp.maximum(a, partner), -1)


@functools.partial(jax.jit, static_argnames=("cap", "slot_block",
                                             "interpret"))
def upper_pairs_kernel(offs_s, ids_s, *, cap: int,
                       slot_block: int = DEFAULT_SLOT_BLOCK,
                       interpret: bool | None = None):
    """Band-stacked upper-mask SpGEMM emission: offsets (G, U+1) int32,
    ids (G, E) int32 -> (G, cap, 2) int32 pair buffers, -1 past each
    band's true count. ``cap`` must be a power of two (the emission caps
    of `allpairs/selfjoin.py` always are), so the slot grid divides
    evenly. Bit-exact with the jnp reference (same slot order)."""
    G, E = ids_s.shape
    U1 = offs_s.shape[1]
    SB = min(cap, slot_block)
    assert cap % SB == 0, "cap must be a pow2 multiple of the slot block"
    lo, hi = pl.pallas_call(
        functools.partial(_upper_kernel, SB=SB),
        grid=(G, cap // SB),
        in_specs=[
            pl.BlockSpec((1, U1), lambda g, s: (g, 0)),
            pl.BlockSpec((1, E), lambda g, s: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, SB), lambda g, s: (g, s)),
            pl.BlockSpec((1, SB), lambda g, s: (g, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, cap), jnp.int32),
            jax.ShapeDtypeStruct((G, cap), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(offs_s.astype(jnp.int32), ids_s.astype(jnp.int32))
    return jnp.stack([lo, hi], axis=-1)
