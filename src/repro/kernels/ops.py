"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples and platform dispatch: on TPU the
compiled kernels run natively; elsewhere (this CPU container) they execute
under ``interpret=True`` — same kernel body, Python evaluation — or fall
back to the jnp reference for speed when ``prefer_ref=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alphabet import PAD
from . import ref as kref
from .hamming import hamming_count_kernel, hamming_dist_kernel
from .siggen import siggen_accumulate_kernel
from .sw import (on_tpu, resolve_interpret, sw_scores_kernel,
                 ungapped_scores_kernel, wave_scores_kernel)

_on_tpu = on_tpu  # back-compat alias


def _pad_rows(x, mult, value=0):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x, n
    return jnp.pad(x, ((0, p),) + ((0, 0),) * (x.ndim - 1),
                   constant_values=value), n


def all_pairs_hamming(q, r, *, bq: int = 256, br: int = 256,
                      prefer_ref: bool = False) -> jnp.ndarray:
    """All-pairs Hamming distances via the Pallas kernel (padded + cropped)."""
    if prefer_ref:
        return kref.hamming_dist_ref(q, r)
    qp, Q = _pad_rows(q, bq)
    rp, R = _pad_rows(r, br)
    out = hamming_dist_kernel(qp, rp, bq=bq, br=br, interpret=not _on_tpu())
    return out[:Q, :R]


def hamming_counts(q, r, d: int, *, bq: int = 256, br: int = 256,
                   prefer_ref: bool = False) -> jnp.ndarray:
    """Per-query counts of references within Hamming distance d: (Q,) int32.

    Padded reference rows are all-ones signatures; queries are real data, so
    a padded ref can only collide if a real query is within d of the all-ones
    word — excluded by padding refs with the complement of 0 (distance from
    any real signature >= f - d in practice). To be exact we subtract the
    padded-row hits computed against the padding pattern.
    """
    if prefer_ref:
        return kref.hamming_count_ref(q, r, d)[:, 0]
    qp, Q = _pad_rows(q, bq)
    PADV = jnp.uint32(0xFFFFFFFF)
    rp, R = _pad_rows(r, br, value=PADV)
    out = hamming_count_kernel(qp, rp, d=d, bq=bq, br=br,
                               interpret=not _on_tpu())[:, 0]
    if rp.shape[0] != R:
        # exact correction: count hits of each query against the pad pattern
        pad_sig = jnp.full((1, r.shape[1]), PADV, jnp.uint32)
        per_pad = kref.hamming_count_ref(qp, pad_sig, d)[:, 0]
        out = out - per_pad * (rp.shape[0] - R)
    return out[:Q]


def sw_wave_scores(qs, rs, *, bb: int = 8, prefer_ref: bool = False,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Batched Smith-Waterman best scores for a (B, Lq) x (B, Lr) pair block
    via the Pallas row-wave kernel (padded + cropped); bit-exact with the
    jnp wave (`align.smith_waterman.sw_align_batch`), which is also the
    ``prefer_ref`` fallback. ``interpret=None`` autodetects by backend."""
    if prefer_ref:
        from ..align.smith_waterman import _sw_scores_batch
        return _sw_scores_batch(jnp.asarray(qs), jnp.asarray(rs))
    qp, B = _pad_rows(jnp.asarray(qs), bb, value=PAD)
    rp, _ = _pad_rows(jnp.asarray(rs), bb, value=PAD)
    out = sw_scores_kernel(qp, rp, bb=bb, interpret=resolve_interpret(interpret))
    return out[:B, 0]


def wavefront_scores(qs, rs, *, gap_mode: str = "linear",
                     gap_open: int | None = None,
                     gap_extend: int | None = None, bb: int = 8,
                     prefer_ref: bool = False,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Batched SW best scores for a (B, Lq) x (B, Lr) pair block via the
    anti-diagonal wavefront kernel (padded + cropped), linear or affine
    (Gotoh) gaps; score-exact with the row wave under ``"linear"`` and
    with `kernels.ref.sw_affine_ref` under ``"affine"``. The jnp sweep
    (`align.gotoh`) is the ``prefer_ref`` fallback (also the fast path
    off-TPU). ``interpret=None`` autodetects by backend."""
    if prefer_ref:
        from ..align import gotoh
        if gap_mode == "affine":
            return gotoh.sw_wave_affine(
                qs, rs,
                gap_open=gotoh.GAP_OPEN if gap_open is None else gap_open,
                gap_extend=(gotoh.GAP_EXTEND if gap_extend is None
                            else gap_extend))
        from ..align.smith_waterman import GAP
        return gotoh.sw_wave_linear(
            qs, rs, gap=GAP if gap_open is None else gap_open)
    qp, B = _pad_rows(jnp.asarray(qs), bb, value=PAD)
    rp, _ = _pad_rows(jnp.asarray(rs), bb, value=PAD)
    out = wave_scores_kernel(qp, rp, gap_mode=gap_mode, gap_open=gap_open,
                             gap_extend=gap_extend, bb=bb,
                             interpret=resolve_interpret(interpret))
    return out[:B, 0]


def ungapped_wave_scores(qs, rs, *, x: int = 20, bb: int = 8,
                         prefer_ref: bool = False,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Batched ungapped X-drop prefilter scores for a (B, Lq) x (B, Lr) pair
    block via the Pallas diagonal-scan kernel (padded + cropped); bit-exact
    with `align.smith_waterman.ungapped_xdrop_scores` (the ``prefer_ref``
    fallback, which is also faster off-TPU)."""
    if prefer_ref:
        from ..align.smith_waterman import ungapped_xdrop_scores
        return ungapped_xdrop_scores(qs, rs, x=x)
    qp, B = _pad_rows(jnp.asarray(qs), bb, value=PAD)
    rp, _ = _pad_rows(jnp.asarray(rs), bb, value=PAD)
    out = ungapped_scores_kernel(qp, rp, x=x, bb=bb,
                                 interpret=resolve_interpret(interpret))
    return out[:B, 0]


def emit_upper_pairs(offs_s, ids_s, *, cap: int,
                     prefer_ref: bool | None = None,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Band-stacked upper-mask SpGEMM candidate emission: offsets (G, U+1),
    ids (G, E) -> (G, cap, 2) int32 pair buffers (-1 past each band's true
    count) — the strict upper triangle of each band's AᵀA incidence
    product. On TPU the Pallas kernel (`kernels/spgemm.py`) lowers
    natively; elsewhere the jnp product of `repro.index.spgemm` is the
    fast path (``prefer_ref`` default autodetects). Bit-exact across all
    three paths (same pairs, same slot order)."""
    if prefer_ref is None:
        prefer_ref = not _on_tpu()
    if prefer_ref:
        from ..index.spgemm import masked_pair_product
        return jax.vmap(
            lambda o, i: masked_pair_product(o, i, cap=cap))(offs_s, ids_s)
    from .spgemm import upper_pairs_kernel
    return upper_pairs_kernel(offs_s, ids_s, cap=cap,
                              interpret=resolve_interpret(interpret))


def signatures_fused(rows, cb, H, *, T: int, bs: int = 256, bw: int = 512,
                     prefer_ref: bool = False) -> jnp.ndarray:
    """Fused SimHash accumulation V (S, f); pad shingle rows with zeros
    (score 0 < T contributes nothing) and codebook words with zeros (one-hot
    all-zero scores 0 < T, also inert) — exactness preserved for T >= 1."""
    assert T >= 1, "padding exactness requires T >= 1 (paper uses T >= 11)"
    if prefer_ref:
        return kref.siggen_accumulate_ref(rows, cb, H, T)
    rp, S = _pad_rows(rows, bs)
    cbp, W = _pad_rows(cb, bw)
    Hp, _ = _pad_rows(H, bw)
    out = siggen_accumulate_kernel(rp, cbp, Hp, T=T, bs=bs, bw=bw,
                                   interpret=not _on_tpu())
    return out[:S]
