"""Pallas TPU kernel: blocked all-pairs Hamming distance on packed signatures.

The Signature Processor's hot loop (paper §4.2). Signatures are packed
(N, nwords) uint32; the distance of a (query, reference) pair is
popcount(xor) summed over words. A (Q, R) sweep is a 2-D grid of VMEM tiles:

    grid (Q/bq, R/br):
        dist[bq, br] = sum_w popcount(q_tile[:, None, w] ^ r_tile[None, :, w])

XOR + ``lax.population_count`` run on the VPU; tiles are MXU/VPU-aligned
(bq, br multiples of 8x128). A second kernel fuses the ``<= d`` threshold and
reduces to per-query match counts, accumulated across the reference grid axis
(revisited output block) — the roofline-friendly form when only counts or a
candidate mask are needed, as in the join's verification pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 256
DEFAULT_BR = 256


def _dist_kernel(q_ref, r_ref, out_ref):
    q = q_ref[...]                      # (bq, nw) uint32
    r = r_ref[...]                      # (br, nw) uint32
    x = q[:, None, :] ^ r[None, :, :]   # (bq, br, nw)
    out_ref[...] = jnp.sum(
        jax.lax.population_count(x).astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("bq", "br", "interpret"))
def hamming_dist_kernel(q, r, *, bq: int = DEFAULT_BQ, br: int = DEFAULT_BR,
                        interpret: bool = True):
    """(Q, nw) x (R, nw) uint32 -> (Q, R) int32 distances. Q % bq == R % br == 0
    is handled by padding inside ops.all_pairs_hamming."""
    Q, nw = q.shape
    R = r.shape[0]
    assert Q % bq == 0 and R % br == 0, "pad inputs to block multiples"
    grid = (Q // bq, R // br)
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, nw), lambda i, j: (i, 0)),
            pl.BlockSpec((br, nw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, R), jnp.int32),
        interpret=interpret,
    )(q, r)


def _count_kernel(q_ref, r_ref, out_ref, *, d: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...]
    r = r_ref[...]
    x = q[:, None, :] ^ r[None, :, :]
    dist = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    hits = (dist <= d).astype(jnp.int32)                # (bq, br)
    out_ref[...] += jnp.sum(hits, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("d", "bq", "br", "interpret"))
def hamming_count_kernel(q, r, *, d: int, bq: int = DEFAULT_BQ,
                         br: int = DEFAULT_BR, interpret: bool = True):
    """Fused threshold+reduce: per-query count of references within distance d.

    (Q, nw) x (R, nw) -> (Q, 1) int32. The reference grid axis revisits the
    output block and accumulates (classic Pallas reduction pattern). d is a
    compile-time constant (the paper sweeps d in {0,1,2}).
    """
    Q, nw = q.shape
    R = r.shape[0]
    assert Q % bq == 0 and R % br == 0
    grid = (Q // bq, R // br)
    return pl.pallas_call(
        functools.partial(_count_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, nw), lambda i, j: (i, 0)),
            pl.BlockSpec((br, nw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.int32),
        interpret=interpret,
    )(q, r)
