"""Pallas TPU kernels: batched Smith-Waterman row-wave DP and the ungapped
X-drop prefilter over a pair block.

The all-pairs tiler's inner loop (`repro.allpairs.tiles`): score a block of
(query, reference) pairs in one program. The grid is 1-D over pair blocks;
each program holds a (bb, Lq) query block and a (bb, Lr) reference block in
VMEM and scans query rows with `fori_loop`, keeping only the previous DP row
(bb, Lr+1) and the running best — O(bb*Lr) state, never the full matrix.

``interpret`` defaults to *autodetect*: kernels lower natively wherever the
backend supports Pallas TPU lowering and fall back to interpret mode only
where it is unavailable (this CPU container). Pass ``interpret=True/False``
to override (exposed as ``WaveConfig.pallas_interpret``).

Per row the within-row gap dependency is resolved by the same max-plus
prefix scan as :mod:`repro.align.smith_waterman` (H = cummax(A + c*t) - c*t),
implemented lane-parallel with a log-doubling shifted-max (Hillis-Steele),
since `lax.cummax` does not lower inside Pallas TPU kernels. Substitution
scores are looked up without gathers: the per-row BLOSUM slice B[q_i] is
prefetched as a (bb, Lq, A+1) tensor and reduced against one-hot reference
comparisons — 21 vectorized selects per row, MXU/VPU-friendly.

Cell values are integer and identical to the classic recurrence: scores are
bit-exact with `align.smith_waterman.sw_align_batch` (the jnp wave) and with
the per-pair path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..align.smith_waterman import GAP, NEG
from ..core.alphabet import ALPHABET_SIZE, BLOSUM62_PADDED, PAD

DEFAULT_BB = 8


def on_tpu() -> bool:
    """True iff the default backend lowers Pallas TPU kernels natively."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Autodetect interpret mode: explicit override wins, otherwise
    interpret only where native Pallas lowering is unavailable."""
    return (not on_tpu()) if interpret is None else bool(interpret)


def _sw_kernel(q_ref, qsub_ref, r_ref, out_ref, *, Lq: int):
    q = q_ref[...].astype(jnp.int32)          # (bb, Lq)
    qsub = qsub_ref[...]                      # (bb, Lq, A+1) int32
    r = r_ref[...].astype(jnp.int32)          # (bb, Lr)
    bb, Lr = r.shape
    c = jnp.int32(-GAP)
    # iota, not arange: pallas kernels may not capture constant arrays
    t = jax.lax.broadcasted_iota(jnp.int32, (1, Lr), 1) + 1  # (1, Lr)
    r_pad = r == PAD

    def row_step(i, carry):
        prev, best = carry                    # (bb, Lr+1), (bb, 1)
        qi = jax.lax.dynamic_index_in_dim(q, i, axis=1, keepdims=False)
        si = jax.lax.dynamic_index_in_dim(qsub, i, axis=1, keepdims=False)
        # sub_row[b, j] = B[q[b, i], r[b, j]] via 21 selects (no gathers)
        sub_row = jnp.zeros((bb, Lr), jnp.int32)
        for a in range(ALPHABET_SIZE + 1):
            sub_row = jnp.where(r == a, si[:, a][:, None], sub_row)
        masked = r_pad | (qi == PAD)[:, None]
        sub_row = jnp.where(masked, NEG, sub_row)
        a_row = jnp.maximum(0, jnp.maximum(prev[:, :-1] + sub_row,
                                           prev[:, 1:] + GAP))
        # lane-parallel prefix max of (a_row + c*t): log-doubling shifts
        x = a_row + c * t
        s = 1
        while s < Lr:
            shifted = jnp.concatenate(
                [jnp.full((bb, s), jnp.int32(-2**31 + 1)), x[:, :-s]], axis=1)
            x = jnp.maximum(x, shifted)
            s *= 2
        row_tail = x - c * t
        row = jnp.concatenate([jnp.zeros((bb, 1), jnp.int32), row_tail],
                              axis=1)
        best = jnp.maximum(best, jnp.max(row, axis=1, keepdims=True))
        return row, best

    prev0 = jnp.zeros((bb, Lr + 1), jnp.int32)
    best0 = jnp.zeros((bb, 1), jnp.int32)
    _, best = jax.lax.fori_loop(0, Lq, row_step, (prev0, best0))
    out_ref[...] = best


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def sw_scores_kernel(qs, rs, *, bb: int = DEFAULT_BB,
                     interpret: bool | None = None):
    """(B, Lq) x (B, Lr) int8 pair block -> (B, 1) int32 best local scores.

    B % bb == 0 is handled by padding in ops.sw_wave_scores.
    ``interpret=None`` autodetects (native lowering on TPU).
    """
    B, Lq = qs.shape
    Lr = rs.shape[1]
    assert B % bb == 0, "pad the pair block to a bb multiple"
    qsub = jnp.asarray(BLOSUM62_PADDED)[qs.astype(jnp.int32)]  # (B, Lq, A+1)
    grid = (B // bb,)
    return pl.pallas_call(
        functools.partial(_sw_kernel, Lq=Lq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, Lq), lambda i: (i, 0)),
            pl.BlockSpec((bb, Lq, ALPHABET_SIZE + 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, Lr), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(qs, qsub, rs)


def _wave_sw_kernel(sk_ref, out_ref, *, gap_open: int, gap_extend: int,
                    affine: bool):
    """Anti-diagonal (wavefront) SW sweep over a (bb,) pair block. The
    skewed substitution block sk[c, b, i] = s_b[i, c-i] arrives
    precomputed and sentinel-padded (`align.gotoh`), so each diagonal
    step is pure elementwise arithmetic over (bb, Lq) lanes — no prefix
    scan, no gathers, no masking pass. ``affine`` threads the Gotoh E/F
    gap lanes; with it off the step is the linear 3-way max."""
    sk = sk_ref[...].astype(jnp.int32)        # (nd, bb, Lq)
    nd, bb, Lq = sk.shape
    z = jnp.zeros((bb, Lq), jnp.int32)

    def shift(x):
        return jnp.concatenate(
            [jnp.zeros((bb, 1), jnp.int32), x[:, :-1]], axis=1)

    if affine:
        def step(c, carry):
            h1, h2s, e1, f1, best = carry
            s = jax.lax.dynamic_index_in_dim(sk, c, axis=0, keepdims=False)
            h1s = shift(h1)
            e = jnp.maximum(e1 + gap_extend, h1 + gap_open)
            f = jnp.maximum(shift(f1) + gap_extend, h1s + gap_open)
            h = jnp.maximum(jnp.maximum(h2s + s, 0), jnp.maximum(e, f))
            return h, h1s, e, f, jnp.maximum(best, h)

        init = (z, z, z, z, z)
    else:
        def step(c, carry):
            h1, h2s, best = carry
            s = jax.lax.dynamic_index_in_dim(sk, c, axis=0, keepdims=False)
            h1s = shift(h1)
            h = jnp.maximum(jnp.maximum(h2s + s, 0),
                            jnp.maximum(h1, h1s) + gap_open)
            return h, h1s, jnp.maximum(best, h)

        init = (z, z, z)

    out = jax.lax.fori_loop(0, nd, step, init)
    out_ref[...] = jnp.max(out[-1], axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=(
    "gap_mode", "gap_open", "gap_extend", "bb", "interpret"))
def wave_scores_kernel(qs, rs, *, gap_mode: str = "linear",
                       gap_open: int | None = None,
                       gap_extend: int | None = None,
                       bb: int = DEFAULT_BB,
                       interpret: bool | None = None):
    """(B, Lq) x (B, Lr) int8 pair block -> (B, 1) int32 best local scores
    via the wavefront kernel. ``gap_mode="linear"`` (default gap = GAP) is
    bit-exact with `sw_scores_kernel`; ``"affine"`` scores Gotoh gaps
    (defaults -11/-1), bit-exact with `kernels.ref.sw_affine_ref`.

    B % bb == 0 is handled by padding in ops.wavefront_scores.
    """
    from ..align.gotoh import GAP_EXTEND, GAP_OPEN, _skew_flat, _sub_block
    B, Lq = qs.shape
    assert B % bb == 0, "pad the pair block to a bb multiple"
    if gap_mode == "affine":
        go = GAP_OPEN if gap_open is None else int(gap_open)
        ge = GAP_EXTEND if gap_extend is None else int(gap_extend)
    else:
        go = GAP if gap_open is None else int(gap_open)
        ge = go
    sk = jnp.transpose(_skew_flat(_sub_block(qs, rs)), (0, 2, 1))
    nd = sk.shape[0]                          # (nd, B, Lq) int8
    return pl.pallas_call(
        functools.partial(_wave_sw_kernel, gap_open=go, gap_extend=ge,
                          affine=(gap_mode == "affine")),
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((nd, bb, Lq), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(sk)


def _ungapped_kernel(q_ref, qsub_ref, r_ref, out_ref, *, Lq: int, x: int):
    """Ungapped X-drop diagonal scan over a (bb,) pair block — the prefilter
    twin of `_sw_kernel`. Carries are indexed by reference column, so the
    diagonal predecessor is a right-shift: every row is elementwise (no
    prefix scan), O(bb*Lr) state."""
    q = q_ref[...].astype(jnp.int32)          # (bb, Lq)
    qsub = qsub_ref[...]                      # (bb, Lq, A+1) int32
    r = r_ref[...].astype(jnp.int32)          # (bb, Lr)
    bb, Lr = r.shape
    r_pad = r == PAD

    def row_step(i, carry):
        cur, rbest, gbest = carry             # (bb, Lr) x2, (bb, 1)
        qi = jax.lax.dynamic_index_in_dim(q, i, axis=1, keepdims=False)
        si = jax.lax.dynamic_index_in_dim(qsub, i, axis=1, keepdims=False)
        sub_row = jnp.zeros((bb, Lr), jnp.int32)
        for a in range(ALPHABET_SIZE + 1):
            sub_row = jnp.where(r == a, si[:, a][:, None], sub_row)
        masked = r_pad | (qi == PAD)[:, None]
        sub_row = jnp.where(masked, NEG, sub_row)
        cur_s = jnp.concatenate(
            [jnp.zeros((bb, 1), jnp.int32), cur[:, :-1]], axis=1)
        rb_s = jnp.concatenate(
            [jnp.zeros((bb, 1), jnp.int32), rbest[:, :-1]], axis=1)
        c = cur_s + sub_row
        drop = (c <= 0) | (rb_s - c > x)
        c = jnp.where(drop, 0, c)
        rb = jnp.where(drop, 0, jnp.maximum(rb_s, c))
        gbest = jnp.maximum(gbest, jnp.max(c, axis=1, keepdims=True))
        return c, rb, gbest

    z = jnp.zeros((bb, Lr), jnp.int32)
    _, _, best = jax.lax.fori_loop(
        0, Lq, row_step, (z, z, jnp.zeros((bb, 1), jnp.int32)))
    out_ref[...] = best


@functools.partial(jax.jit, static_argnames=("x", "bb", "interpret"))
def ungapped_scores_kernel(qs, rs, *, x: int, bb: int = DEFAULT_BB,
                           interpret: bool | None = None):
    """(B, Lq) x (B, Lr) int8 pair block -> (B, 1) int32 best ungapped
    X-drop run scores; bit-exact with
    `align.smith_waterman.ungapped_xdrop_scores`."""
    B, Lq = qs.shape
    Lr = rs.shape[1]
    assert B % bb == 0, "pad the pair block to a bb multiple"
    qsub = jnp.asarray(BLOSUM62_PADDED)[qs.astype(jnp.int32)]  # (B, Lq, A+1)
    return pl.pallas_call(
        functools.partial(_ungapped_kernel, Lq=Lq, x=x),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, Lq), lambda i: (i, 0)),
            pl.BlockSpec((bb, Lq, ALPHABET_SIZE + 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, Lr), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(qs, qsub, rs)
