"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_dist_ref(q, r) -> jnp.ndarray:
    """(Q, nw) x (R, nw) uint32 -> (Q, R) int32."""
    x = q[:, None, :] ^ r[None, :, :]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_count_ref(q, r, d: int) -> jnp.ndarray:
    """(Q, nw) x (R, nw) -> (Q, 1) int32 counts of refs within distance d."""
    dist = hamming_dist_ref(q, r)
    return jnp.sum((dist <= d).astype(jnp.int32), axis=-1, keepdims=True)


def siggen_accumulate_ref(rows, cb, H, T: int) -> jnp.ndarray:
    """(S, D) x (W, D) x (W, f) -> (S, f) int32 SimHash accumulators."""
    scores = rows.astype(jnp.int32) @ cb.astype(jnp.int32).T   # (S, W)
    wts = jnp.where(scores >= T, scores, 0)
    return wts @ H.astype(jnp.int32)                           # (S, f)
