"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_dist_ref(q, r) -> jnp.ndarray:
    """(Q, nw) x (R, nw) uint32 -> (Q, R) int32."""
    x = q[:, None, :] ^ r[None, :, :]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_count_ref(q, r, d: int) -> jnp.ndarray:
    """(Q, nw) x (R, nw) -> (Q, 1) int32 counts of refs within distance d."""
    dist = hamming_dist_ref(q, r)
    return jnp.sum((dist <= d).astype(jnp.int32), axis=-1, keepdims=True)


def siggen_accumulate_ref(rows, cb, H, T: int) -> jnp.ndarray:
    """(S, D) x (W, D) x (W, f) -> (S, f) int32 SimHash accumulators."""
    scores = rows.astype(jnp.int32) @ cb.astype(jnp.int32).T   # (S, W)
    wts = jnp.where(scores >= T, scores, 0)
    return wts @ H.astype(jnp.int32)                           # (S, f)


def sw_affine_ref(q, r, gap_open: int = -11, gap_extend: int = -1):
    """Host Gotoh oracle: best local alignment score of one encoded pair
    (unpadded int8 arrays) under affine gaps, walking every cell of the
    three-lane DP. Convention: ``gap_open`` is the cost of the FIRST gap
    residue and ``gap_extend`` of each further one, so
    ``gap_open == gap_extend`` degenerates exactly to the linear-gap SW
    recurrence of ``align.smith_waterman`` (cell-exact on H).

    Returns (best_score, H) with H the (Lq+1, Lr+1) int64 DP matrix.
    """
    import numpy as np

    from ..core.alphabet import BLOSUM62_PADDED

    q = np.asarray(q, np.int64)
    r = np.asarray(r, np.int64)
    sub = BLOSUM62_PADDED[q][:, r].astype(np.int64)
    Lq, Lr = len(q), len(r)
    NEGI = -(1 << 40)           # true -inf boundary for the gap lanes
    H = np.zeros((Lq + 1, Lr + 1), np.int64)
    E = np.full((Lq + 1, Lr + 1), NEGI, np.int64)
    F = np.full((Lq + 1, Lr + 1), NEGI, np.int64)
    best = 0
    for i in range(1, Lq + 1):
        for j in range(1, Lr + 1):
            E[i, j] = max(E[i, j - 1] + gap_extend, H[i, j - 1] + gap_open)
            F[i, j] = max(F[i - 1, j] + gap_extend, H[i - 1, j] + gap_open)
            H[i, j] = max(0, H[i - 1, j - 1] + sub[i - 1, j - 1],
                          E[i, j], F[i, j])
            if H[i, j] > best:
                best = int(H[i, j])
    return best, H


def spgemm_upper_ref(offsets, ids, cap: int):
    """Host oracle for the upper-mask SpGEMM emission of ONE band: walk the
    bucket CSR and enumerate each unordered within-bucket pair once, in
    entry-major slot order — (cap, 2) int32, -1 past the true count.
    Independent of the jnp/Pallas implementations (plain loops)."""
    import numpy as np

    offsets = np.asarray(offsets)
    ids = np.asarray(ids)
    out = np.full((cap, 2), -1, np.int32)
    n = 0
    for u in range(len(offsets) - 1):
        members = ids[offsets[u]:offsets[u + 1]]
        for a in range(len(members)):
            for b in range(a + 1, len(members)):
                i, j = int(members[a]), int(members[b])
                out[n] = (min(i, j), max(i, j))
                n += 1
    return out


def ungapped_xdrop_ref(q, r, x: int) -> int:
    """Host oracle for the ungapped X-drop diagonal scan: one encoded pair
    (unpadded int8 arrays), walking every diagonal cell-by-cell with the
    exact restart rule of ``align.smith_waterman._ungapped_pair``."""
    import numpy as np

    from ..core.alphabet import BLOSUM62_PADDED

    q = np.asarray(q, np.int64)
    r = np.asarray(r, np.int64)
    sub = BLOSUM62_PADDED[q][:, r].astype(np.int64)
    best = 0
    for k in range(-(len(q) - 1), len(r)):
        i0, j0 = (max(0, -k), max(0, k))
        cur, rbest = 0, 0
        while i0 < len(q) and j0 < len(r):
            c = cur + int(sub[i0, j0])
            if c <= 0 or rbest - c > x:
                c, rbest = 0, 0
            else:
                rbest = max(rbest, c)
            best = max(best, c)
            cur = c
            i0, j0 = i0 + 1, j0 + 1
    return best
