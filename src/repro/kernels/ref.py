"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_dist_ref(q, r) -> jnp.ndarray:
    """(Q, nw) x (R, nw) uint32 -> (Q, R) int32."""
    x = q[:, None, :] ^ r[None, :, :]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_count_ref(q, r, d: int) -> jnp.ndarray:
    """(Q, nw) x (R, nw) -> (Q, 1) int32 counts of refs within distance d."""
    dist = hamming_dist_ref(q, r)
    return jnp.sum((dist <= d).astype(jnp.int32), axis=-1, keepdims=True)


def siggen_accumulate_ref(rows, cb, H, T: int) -> jnp.ndarray:
    """(S, D) x (W, D) x (W, f) -> (S, f) int32 SimHash accumulators."""
    scores = rows.astype(jnp.int32) @ cb.astype(jnp.int32).T   # (S, W)
    wts = jnp.where(scores >= T, scores, 0)
    return wts @ H.astype(jnp.int32)                           # (S, f)


def ungapped_xdrop_ref(q, r, x: int) -> int:
    """Host oracle for the ungapped X-drop diagonal scan: one encoded pair
    (unpadded int8 arrays), walking every diagonal cell-by-cell with the
    exact restart rule of ``align.smith_waterman._ungapped_pair``."""
    import numpy as np

    from ..core.alphabet import BLOSUM62_PADDED

    q = np.asarray(q, np.int64)
    r = np.asarray(r, np.int64)
    sub = BLOSUM62_PADDED[q][:, r].astype(np.int64)
    best = 0
    for k in range(-(len(q) - 1), len(r)):
        i0, j0 = (max(0, -k), max(0, k))
        cur, rbest = 0, 0
        while i0 < len(q) and j0 < len(r):
            c = cur + int(sub[i0, j0])
            if c <= 0 or rbest - c > x:
                c, rbest = 0, 0
            else:
                rbest = max(rbest, c)
            best = max(best, c)
            cur = c
            i0, j0 = i0 + 1, j0 + 1
    return best
