"""Checkpoint manager: the fault-tolerance substrate (DESIGN.md §5).

Design (no tensorstore/orbax in this container — built from primitives):
  * one .npy per pytree leaf + a JSON manifest (tree structure, shapes,
    dtypes, step, mesh shape) — the HDFS-replication analogue of the paper's
    Hadoop layer is the atomic-manifest protocol below;
  * ATOMIC: writes go to `step_N.tmp/`, fsync'd, then os.rename -> `step_N/`.
    A crash mid-write never corrupts the latest checkpoint; restore picks the
    newest *complete* step directory;
  * ASYNC: save() can hand the host copy to a writer thread — training
    continues while bytes hit disk (device->host copy is synchronous, disk
    I/O is not);
  * ELASTIC: restore(sharding_tree=...) device_puts each leaf under a NEW
    mesh/sharding — a job restarted at a different scale resumes from the
    same manifest (tested in tests/test_checkpoint.py).

In a real multi-host pod each process writes only its addressable shards and
the manifest is written by process 0; on this single-process container every
shard is addressable, which degenerates to full-array writes — the protocol
(manifest + atomic rename + per-leaf files) is unchanged.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

# numpy's .npy format doesn't know bfloat16 etc. — store the raw bits with a
# same-width integer dtype and record the logical dtype in the manifest.
_BITCAST = {"bfloat16": "uint16", "float8_e4m3fn": "uint8",
            "float8_e5m2": "uint8"}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _from_savable(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _BITCAST:
        return arr.view(getattr(ml_dtypes, logical))
    return arr


class CheckpointManager:
    def __init__(self, directory, keep_last: int = 3, async_writes: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._q: queue.Queue | None = None
        self._thread = None
        if async_writes:
            self._q = queue.Queue(maxsize=2)
            self._thread = threading.Thread(target=self._writer_loop,
                                            daemon=True)
            self._thread.start()

    # ------------------------------------------------------------ save
    def save(self, step: int, state, *, block: bool = True):
        """Snapshot `state` (any pytree of arrays) at `step`."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        # device->host copy happens NOW (state may be donated/mutated next step)
        host = [(self._path_str(kp), np.asarray(leaf)) for kp, leaf in flat]
        payload = (step, host, jax.tree.unflatten(treedef, [None] * len(flat)))
        if self._q is not None and not block:
            self._q.put(payload)
        else:
            self._write(payload)

    def _writer_loop(self):
        while True:
            self._write(self._q.get())
            self._q.task_done()

    def wait(self):
        if self._q is not None:
            self._q.join()

    @staticmethod
    def _path_str(kp) -> str:
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)

    def _write(self, payload):
        step, host, skeleton = payload
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(host):
            fn = f"leaf_{i:05d}.npy"
            savable, logical = _to_savable(arr)
            np.save(tmp / fn, savable)
            manifest["leaves"].append(
                {"path": path, "file": fn, "shape": list(arr.shape),
                 "dtype": logical})
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------ restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.name.startswith("step_") and not p.name.endswith(".tmp") \
                    and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, sharding_tree=None):
        """Restore into the structure of `like` (a pytree template).

        sharding_tree: optional pytree of shardings (same structure) for
        elastic restore onto a different mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (jax.tree.leaves(sharding_tree)
                      if sharding_tree is not None else [None] * len(flat))
        out = []
        for (kp, leaf), sh in zip(flat, shard_flat):
            ent = by_path[self._path_str(kp)]
            arr = _from_savable(np.load(d / ent["file"]), ent["dtype"])
            assert list(arr.shape) == list(leaf.shape), \
                f"shape mismatch at {ent['path']}"
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree.unflatten(treedef, out), step
