"""Fault-tolerant checkpointing: atomic sharded save/restore, async writer,
elastic re-sharding on restore."""
from .manager import CheckpointManager

__all__ = ["CheckpointManager"]
