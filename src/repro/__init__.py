"""repro: ScalLoPS (LSH protein similarity search, UNSW-CSE-TR-201325) as a
TPU-native JAX/Pallas framework. See DESIGN.md / README.md."""
__version__ = "1.0.0"
