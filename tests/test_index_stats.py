"""index.stats: occupancy histograms and the splitmix key-diversity claim."""
import numpy as np

from repro.core import LSHConfig
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import (SignatureIndex, band_stats, compare_schemes,
                         occupancy_report)

CFG = LSHConfig(k=3, T=13, f=32, d=1)


def _refs(n=512, seed=9):
    d = make_protein_sets(SyntheticProteinConfig(
        n_refs=n, n_homolog_queries=0, n_decoy_queries=0,
        ref_len_mean=120, ref_len_std=20, seed=seed))
    return d["ref_ids"], d["ref_lens"]


def test_band_stats_consistency():
    ids, lens = _refs()
    idx = SignatureIndex.build(CFG, ids, lens)
    stats = band_stats(idx)
    assert len(stats) == idx.n_bands
    n_valid = int(idx.valid.sum())
    for s in stats:
        assert s.n_entries == n_valid
        assert 1 <= s.max_bucket <= n_valid
        assert 0.0 <= s.entropy_frac <= 1.0
        assert s.expected_probe >= 1.0
        assert sum(s.hist.values()) == s.n_buckets
    assert "entropy" in occupancy_report(idx)


def test_empty_index_stats():
    ids = np.zeros((0, 1), np.int8)
    lens = np.zeros((0,), np.int32)
    stats = band_stats(SignatureIndex.build(CFG, ids, lens))
    assert all(s.n_entries == 0 for s in stats)


def test_splitmix_recovers_key_diversity():
    """The ROADMAP key-entropy question, answered: splitmix hyperplane bits
    must spread buckets far more evenly than the position-skewed Java hash
    (higher occupancy entropy, cheaper expected probe)."""
    ids, lens = _refs()
    res = compare_schemes(CFG, ids, lens)
    for b in range(len(res["java"])):
        java, splitmix = res["java"][b], res["splitmix"][b]
        assert splitmix.entropy_frac > java.entropy_frac
        assert splitmix.expected_probe < java.expected_probe
        assert splitmix.max_bucket <= java.max_bucket
    # and the gap is large, not marginal: near-ideal entropy for splitmix
    assert min(s.entropy_frac for s in res["splitmix"]) > 0.9
