"""MoE sort-based dispatch vs a per-token python oracle.

With ample capacity (no drops), the sorted scatter/gather dispatch must
equal the naive per-token loop: out[t] = Σ_k w_k · FFN_{e_k}(h_t).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from repro.models.layers import moe_block, norm, _act


def _oracle(x, p, cfg):
    """Naive per-token MoE (same router math, no capacity)."""
    B, S, d = x.shape
    h = norm(x, p["norm"], cfg.norm_type).reshape(B * S, d)
    logits = h.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.experts_per_token)
    gv = gv / gv.sum(-1, keepdims=True)
    out = np.zeros((B * S, d), np.float32)
    hn = np.asarray(h, np.float32)
    for t in range(B * S):
        for k in range(cfg.experts_per_token):
            e = int(gi[t, k])
            u = hn[t] @ np.asarray(p["ewi"][e], np.float32)
            if cfg.mlp_gated:
                g = np.asarray(
                    _act(jnp.asarray(hn[t] @ np.asarray(
                        p["ewg"][e], np.float32)), cfg.mlp_act))
                u = u * g
            else:
                u = np.asarray(_act(jnp.asarray(u), cfg.mlp_act))
            y = u @ np.asarray(p["ewo"][e], np.float32)
            out[t] += float(gv[t, k]) * y
    return out.reshape(B, S, d)


@pytest.mark.parametrize("seq,batch", [(8, 2), (1, 6)])  # train & decode paths
def test_moe_dispatch_matches_per_token_oracle(seq, batch):
    cfg = ModelConfig(
        name="moe-test", family="moe", n_layers=2, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=24, vocab_size=64, n_experts=4,
        experts_per_token=2, capacity_factor=8.0,  # ample: no drops
        dtype="float32", attn_chunk=4, ce_chunk=4)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "norm": jnp.ones((d,)),
        "router": jax.random.normal(ks[0], (d, E)) * 0.5,
        "ewi": jax.random.normal(ks[1], (E, d, ff)) / np.sqrt(d),
        "ewg": jax.random.normal(ks[2], (E, d, ff)) / np.sqrt(d),
        "ewo": jax.random.normal(ks[3], (E, ff, d)) / np.sqrt(ff),
    }
    x = jax.random.normal(ks[4], (batch, seq, d))
    got, aux = moe_block(x, p, cfg, {})
    want = _oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_moe_capacity_drops_are_bounded_not_silent():
    """With capacity_factor < 1, some tokens drop — output stays finite and
    the kept fraction of tokens still routes correctly (no corruption)."""
    cfg = ModelConfig(
        name="moe-tight", family="moe", n_layers=2, d_model=8, n_heads=2,
        n_kv_heads=2, d_ff=8, vocab_size=64, n_experts=4,
        experts_per_token=1, capacity_factor=0.5, dtype="float32",
        attn_chunk=4, ce_chunk=4)
    key = jax.random.PRNGKey(1)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "norm": jnp.ones((d,)),
        "router": jax.random.normal(ks[0], (d, E)),
        "ewi": jax.random.normal(ks[1], (E, d, ff)),
        "ewg": jax.random.normal(ks[2], (E, d, ff)),
        "ewo": jax.random.normal(ks[3], (E, ff, d)),
    }
    x = jax.random.normal(ks[4], (2, 16, d))
    out, aux = moe_block(x, p, cfg, {})
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens contribute zero (identity via the residual add upstream)
    assert (np.abs(np.asarray(out)).sum(axis=-1) == 0).any()
