"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values + finite grads; decode step for decoder
archs (brief deliverable f)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config, shape_applicable
from repro.models import (init_params, loss_fn, train_step_fn, init_cache,
                          decode_step, prefill)
from repro.models.config import param_count


def _batch(cfg, B=2, S=24, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.embedding_inputs:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    targets = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "targets": targets}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    loss, metrics, grads = train_step_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # every grad leaf finite and at least one nonzero
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), arch
    # loss near ln(V) at init (sanity of the CE path)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 2.0, arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if shape_applicable(a, "decode_32k")[0]])
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.embedding_inputs:
        # vlm decodes text tokens through the embedding table
        pass
    params = init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, B, max_len=S + 4)
    logits, cache = prefill(params, toks, cache, cfg)
    assert logits.shape == (B, cfg.vocab_size)
    lg, cache = decode_step(params, cache, toks[:, :1], jnp.int32(S), cfg)
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_shapes(arch):
    """Full configs instantiate (metadata only — no allocation)."""
    cfg = get_config(arch)
    assert cfg.n_layers >= 16 and cfg.vocab_size >= 504
    n = param_count(cfg)
    assert n > 1e8, f"{arch}: {n}"
    # group decomposition covers all layers
    assert (cfg.n_groups * len(cfg.block_pattern) + cfg.n_remainder
            == cfg.n_layers)


def test_expected_param_counts():
    """Analytic param counts land near the published sizes."""
    expect = {
        "olmoe-1b-7b": (6.5e9, 7.5e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "granite-34b": (32e9, 36e9),
        "yi-9b": (8.0e9, 9.5e9),
        "nemotron-4-15b": (14e9, 17e9),
        "xlstm-1.3b": (1.0e9, 1.7e9),
        "recurrentgemma-2b": (2.2e9, 3.3e9),
        "qwen2-vl-7b": (6.5e9, 8.5e9),
        "hubert-xlarge": (0.9e9, 1.2e9),
        "granite-3-8b": (7.0e9, 9.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
