"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles.

Property-based (hypothesis) variants live in test_properties.py behind
``pytest.importorskip`` so this module always collects.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core.alphabet import encode_batch
from repro.core.neighbors import codebook_onehot
from repro.core.shingle import extract_shingles
from repro.core.simhash import hyperplanes, pack_bits, signatures_matmul
from repro.core.neighbors import shingle_rows


# ------------------------------------------------------------ hamming dist
@pytest.mark.parametrize("Q,R,nw,bq,br", [
    (8, 8, 1, 8, 8),        # exact block fit, f=32
    (37, 61, 2, 16, 32),    # ragged -> padding, f=64
    (256, 128, 4, 128, 128),  # f=128, production-ish tiles
    (5, 300, 1, 8, 256),    # tiny Q, wide R
])
def test_hamming_dist_sweep(Q, R, nw, bq, br):
    rng = np.random.default_rng(Q * 1000 + R)
    q = jnp.asarray(rng.integers(0, 2**32, (Q, nw), dtype=np.uint32))
    r = jnp.asarray(rng.integers(0, 2**32, (R, nw), dtype=np.uint32))
    got = ops.all_pairs_hamming(q, r, bq=bq, br=br)
    want = ref.hamming_dist_ref(q, r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hamming_identity_diagonal():
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.integers(0, 2**32, (16, 2), dtype=np.uint32))
    d = np.asarray(ops.all_pairs_hamming(s, s, bq=8, br=8))
    assert (np.diag(d) == 0).all()
    assert (d == d.T).all()


# ------------------------------------------------------------ siggen
@pytest.mark.parametrize("S,k,f,T,bs,bw", [
    (16, 2, 32, 8, 8, 128),
    (50, 2, 64, 10, 16, 200),   # ragged blocks
    (128, 3, 32, 13, 64, 512),  # paper's k=3/T=13
    (8, 3, 128, 22, 8, 1024),   # wide signatures, high T
])
def test_siggen_fused_sweep(S, k, f, T, bs, bw):
    rng = np.random.default_rng(S + k * 7)
    D = k * 21
    # synthetic but structurally faithful inputs: genuine shingle rows
    seqs = ["".join(rng.choice(list("ARNDCQEGHILKMFPSTWYV"), k + 4))
            for _ in range(S)]
    ids, lens = encode_batch(seqs)
    sh, mask = extract_shingles(ids, lens, k)
    rows = (shingle_rows(sh) * mask[..., None].astype(jnp.int32))
    rows = rows.reshape(-1, D)[:S]
    scheme = "java" if f <= 32 else "splitmix"
    cb = jnp.asarray(codebook_onehot(k))
    H = jnp.asarray(hyperplanes(k, f, scheme))
    got = ops.signatures_fused(rows, cb, H, T=T, bs=bs, bw=bw)
    want = ref.siggen_accumulate_ref(rows, cb, H, T)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------ sw / ungapped
def test_interpret_autodetect_off_tpu():
    from repro.kernels.sw import on_tpu, resolve_interpret
    assert resolve_interpret(None) == (not on_tpu())
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


@pytest.mark.parametrize("B,Lq,Lr,x", [
    (4, 24, 24, 20),       # square block, finite X
    (5, 17, 33, 20),       # ragged -> bb padding
    (8, 16, 16, 2**30),    # x -> inf (plain best ungapped segment)
])
def test_ungapped_kernel_matches_jnp(B, Lq, Lr, x):
    from repro.align.smith_waterman import ungapped_xdrop_scores
    from repro.core.alphabet import PAD

    rng = np.random.default_rng(B * 100 + Lq)
    qs = rng.integers(0, 20, (B, Lq)).astype(np.int8)
    rs = rng.integers(0, 20, (B, Lr)).astype(np.int8)
    for n in range(B):          # ragged PAD tails
        qs[n, rng.integers(Lq // 2, Lq):] = PAD
        rs[n, rng.integers(Lr // 2, Lr):] = PAD
    got = np.asarray(ops.ungapped_wave_scores(qs, rs, x=x, bb=4))
    want = np.asarray(ungapped_xdrop_scores(
        qs, rs, x=None if x >= 2**30 else x))
    np.testing.assert_array_equal(got, want)


def test_ungapped_jnp_matches_host_oracle():
    from repro.align.smith_waterman import ungapped_xdrop_scores
    from repro.core.alphabet import PAD

    rng = np.random.default_rng(9)
    for x in (20, None):
        for _ in range(4):
            lq, lr = rng.integers(4, 48, 2)
            q = rng.integers(0, 20, lq).astype(np.int8)
            r = rng.integers(0, 20, lr).astype(np.int8)
            qm = np.full((1, 64), PAD, np.int8)
            rm = np.full((1, 48), PAD, np.int8)
            qm[0, :lq] = q
            rm[0, :lr] = r
            got = int(np.asarray(ungapped_xdrop_scores(qm, rm, x=x))[0])
            assert got == ref.ungapped_xdrop_ref(q, r, 10**9 if x is None
                                                 else x)


def test_kernel_path_matches_core_signatures():
    """End-to-end: kernel-accumulated V signs == core signatures_matmul."""
    rng = np.random.default_rng(3)
    seqs = ["".join(rng.choice(list("ARNDCQEGHILKMFPSTWYV"), 20))
            for _ in range(6)]
    ids, lens = encode_batch(seqs)
    k, T, f = 3, 13, 32
    want = np.asarray(signatures_matmul(ids, lens, k=k, T=T, f=f))
    sh, mask = extract_shingles(ids, lens, k)
    rows = (shingle_rows(sh) * mask[..., None].astype(jnp.int32))
    N, S, D = rows.shape
    cb = jnp.asarray(codebook_onehot(k))
    H = jnp.asarray(hyperplanes(k, f, "java"))
    V = ops.signatures_fused(rows.reshape(N * S, D), cb, H, T=T, bs=8, bw=1000)
    got = np.asarray(pack_bits(V.reshape(N, S, f).sum(axis=1) >= 0))
    np.testing.assert_array_equal(got, want)
