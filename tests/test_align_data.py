"""Alignment substrate + synthetic data + LSH quality end-to-end."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.align import SeedExtendBaseline, percent_identity, sw_align_batch
from repro.align.smith_waterman import sw_score
from repro.core.alphabet import encode, encode_batch
from repro.core import LSHConfig, ScalLoPS
from repro.core.join import pairs_to_set
from repro.data import (SyntheticProteinConfig, make_protein_sets, mutate,
                        dedup_corpus)
from repro.data.lm_data import LMDataConfig, synth_corpus, lm_batches


# ------------------------------------------------------------ smith-waterman
def test_sw_identical_sequences_score_and_pid():
    q = encode("MDESFGLLLESMQ")
    pid, length, score = percent_identity(q, q)
    assert pid == 100.0 and length == len(q)
    # score == sum of diagonal BLOSUM62 self-scores
    from repro.core.alphabet import BLOSUM62
    want = sum(int(BLOSUM62[a, a]) for a in q)
    assert score == want


def test_sw_known_alignment():
    # classic check: local alignment ignores flanking junk
    q = encode("AAAWDERKQYTAAA")
    r = encode("PPPWDERKQYTPPP")
    pid, length, score = percent_identity(q, r)
    assert pid == 100.0 and length == 8  # WDERKQYT


def test_sw_mutation_lowers_pid():
    rng = np.random.default_rng(0)
    from repro.data.synthetic import random_protein
    base = random_protein(rng, 120)
    m = mutate(rng, base, sub_rate=0.2)
    pid, _, _ = percent_identity(base, m)
    assert 60.0 < pid < 95.0


def test_sw_batch_matches_single():
    rng = np.random.default_rng(1)
    from repro.data.synthetic import random_protein
    qs = np.stack([random_protein(rng, 40) for _ in range(4)])
    rs = np.stack([random_protein(rng, 40) for _ in range(4)])
    batch = sw_align_batch(qs, rs)
    singles = [sw_score(qs[i], rs[i]) for i in range(4)]
    np.testing.assert_array_equal(batch, singles)


# ------------------------------------------------------------ seed-extend
def test_seed_extend_finds_planted_homologs():
    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=24, n_homolog_queries=8, n_decoy_queries=8,
        ref_len_mean=80, ref_len_std=10, sub_rates=(0.05,), seed=2))
    base = SeedExtendBaseline(k=3, T=11, s_min=40).build_index(
        data["ref_ids"], data["ref_lens"])
    hits = base.search(data["query_ids"], data["query_lens"])
    found = {(q, r) for q, r, s in hits}
    # every homolog query must hit its parent; decoys shouldn't dominate
    for qi, (parent, rate) in enumerate(data["truth"]):
        if parent >= 0:
            assert (qi, parent) in found, f"missed homolog {qi}->{parent}"
    n_decoy_hits = sum(1 for q, r in found
                       if data["truth"][q][0] == -1)
    assert n_decoy_hits <= 4  # random 80-mers rarely share strong HSPs


# ------------------------------------------------------------ LSH quality e2e
def test_scallops_recovers_homologs_end_to_end():
    data = make_protein_sets(SyntheticProteinConfig(
        n_refs=48, n_homolog_queries=12, n_decoy_queries=12,
        ref_len_mean=120, ref_len_std=20, sub_rates=(0.03,), seed=3))
    sl = ScalLoPS(LSHConfig(k=3, T=13, f=32, d=2, join_method="flip",
                            max_pairs=1 << 14))
    rs = sl.signatures(data["ref_ids"], data["ref_lens"])
    qs = sl.signatures(data["query_ids"], data["query_lens"])
    pairs, count, _overflowed = sl.search(qs, rs)
    got = pairs_to_set(pairs)
    recovered = sum(1 for qi, (p, _) in enumerate(data["truth"])
                    if p >= 0 and (qi, p) in got)
    assert recovered >= 9  # ≥75% of 97%-identity homologs at d=2


# ------------------------------------------------------------ LM data + dedup
def test_dedup_drops_planted_twins():
    cfg = LMDataConfig(vocab_size=1000, seq_len=128, global_batch=8, seed=4)
    docs, lens = synth_corpus(cfg, n_docs=64, dup_fraction=0.25)
    keep, n_dups = dedup_corpus(docs, lens, k=4, f=128, d=28)
    # 16 planted twins; demand most are caught with no clean-doc collateral
    assert n_dups >= 14
    assert keep[:48].all()  # originals all kept (twins occupy the tail)


def test_lm_batches_deterministic_and_sharded():
    cfg = LMDataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=5)
    a1, t1 = lm_batches(cfg, step=7, shard=0, n_shards=2)
    a2, _ = lm_batches(cfg, step=7, shard=0, n_shards=2)
    b, _ = lm_batches(cfg, step=7, shard=1, n_shards=2)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    assert not np.array_equal(np.asarray(a1), np.asarray(b))
    assert a1.shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(a1[:, 1:]),
                                  np.asarray(t1[:, :-1]))
