"""Wavefront (anti-diagonal) Smith-Waterman: linear-gap bit-exactness vs
the row wave, affine (Gotoh) bit-exactness vs the numpy oracle, the int16
lane guard boundary, Pallas-kernel parity under interpret mode, routing
validation, recompile-sentinel steadiness across rung x quantum, and the
prefilter-fused self-join (survivors bit-exact with post-hoc filtering)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.align import gotoh
from repro.align.smith_waterman import (GAP, dp_scores_block,
                                        sw_align_batch, sw_gather_scores,
                                        ungapped_xdrop_scores)
from repro.allpairs import (AllPairsConfig, JoinPrefilter, WaveConfig,
                            all_pairs_search, lsh_self_join, score_pairs)
from repro.core import LSHConfig
from repro.core.alphabet import PAD
from repro.data import FamilyCorpusConfig, make_family_corpus
from repro.index import SignatureIndex
from repro.kernels import ops
from repro.kernels.ref import sw_affine_ref
from repro.obs import SENTINEL

CFG = LSHConfig(k=3, T=13, f=32, d=1)


def _ragged_block(rng, B, Lq, Lr, *, all_pad_rows=(), len1_rows=()):
    """(B, Lq) x (B, Lr) int8 PAD-padded block with ragged true lengths,
    plus forced all-PAD and length-1 rows."""
    qs = np.full((B, Lq), PAD, np.int8)
    rs = np.full((B, Lr), PAD, np.int8)
    for b in range(B):
        if b in all_pad_rows:
            continue
        lq = 1 if b in len1_rows else int(rng.integers(1, Lq + 1))
        lr = 1 if b in len1_rows else int(rng.integers(1, Lr + 1))
        qs[b, :lq] = rng.integers(0, 20, lq, dtype=np.int8)
        rs[b, :lr] = rng.integers(0, 20, lr, dtype=np.int8)
    return qs, rs


@pytest.fixture(scope="module")
def block():
    rng = np.random.default_rng(7)
    return _ragged_block(rng, 24, 96, 80, all_pad_rows=(0, 17),
                         len1_rows=(1, 9))


# --------------------------------------------------------------- linear
def test_wave_linear_matches_rowwave(block):
    """Diagonal sweep == row wave, bit-exact, on ragged blocks including
    all-PAD and length-1 rows."""
    qs, rs = block
    want = sw_align_batch(qs, rs)
    got = np.asarray(gotoh.sw_wave_linear(qs, rs))
    np.testing.assert_array_equal(got, want)


def test_wave_linear_empty_and_singleton():
    qs = np.full((2, 4), PAD, np.int8)
    rs = np.full((2, 4), PAD, np.int8)
    qs[1, 0] = 5
    rs[1, 0] = 5
    got = np.asarray(gotoh.sw_wave_linear(qs, rs))
    np.testing.assert_array_equal(got, sw_align_batch(qs, rs))
    assert got[0] == 0          # all-PAD pair scores exactly 0


def test_wave_linear_odd_diagonal_count():
    """Lq + Lr - 1 not divisible by _DIAG_CHUNK: the padded tail diagonal
    group must be inert."""
    rng = np.random.default_rng(11)
    qs, rs = _ragged_block(rng, 8, 7, 6)
    np.testing.assert_array_equal(np.asarray(gotoh.sw_wave_linear(qs, rs)),
                                  sw_align_batch(qs, rs))


# --------------------------------------------------------------- affine
def test_wave_affine_matches_gotoh_oracle(block):
    qs, rs = block
    got = np.asarray(gotoh.sw_wave_affine(qs, rs))
    for b in range(qs.shape[0]):
        q = qs[b][qs[b] != PAD]
        r = rs[b][rs[b] != PAD]
        want, _ = sw_affine_ref(q, r)
        assert got[b] == want, f"row {b}"


def test_wave_affine_open_eq_extend_degenerates_to_linear(block):
    """open == extend == GAP is bit-exactly the linear recurrence."""
    qs, rs = block
    got = np.asarray(gotoh.sw_wave_affine(qs, rs, gap_open=GAP,
                                          gap_extend=GAP))
    np.testing.assert_array_equal(got, sw_align_batch(qs, rs))


def test_affine_never_exceeds_linear_at_same_open(block):
    """With open=-11 < extend=-1, affine >= the linear-gap score at
    gap=-11 (extensions are cheaper) and <= at gap=-1 (opens are dearer)."""
    qs, rs = block
    aff = np.asarray(gotoh.sw_wave_affine(qs, rs))
    lin_open = np.asarray(gotoh.sw_wave_linear(qs, rs, gap=-11))
    lin_ext = np.asarray(gotoh.sw_wave_linear(qs, rs, gap=-1))
    assert (aff >= lin_open).all()
    assert (aff <= lin_ext).all()


# ---------------------------------------------------------- int16 guard
def test_lane_dtype_boundary():
    """11*L < 2^14 -> int16 lanes; the first length over the bound flips
    to int32 (1489*11 = 16379 < 16384 <= 1490*11)."""
    assert gotoh.lane_dtype(1489, 64) == jnp.int16
    assert gotoh.lane_dtype(1490, 64) == jnp.int32
    assert gotoh.lane_dtype(64, 1490) == jnp.int32
    assert gotoh.lane_dtype(8, 8) == jnp.int16


def test_wave_scores_exact_across_lane_dtype():
    """A perfect long repeat scores linearly in L: pushed past the int16
    guard the int32 lanes must carry the exact score."""
    L = 1490                                   # first int32-lane length
    q = np.tile(np.arange(20, dtype=np.int8), -(-L // 20))[:L]
    qs = q[None, :]
    got = int(np.asarray(gotoh.sw_wave_linear(qs, qs))[0])
    want = int(gotoh._BSENT[q, q].astype(np.int64).sum())
    assert got == want                         # self-alignment, no gaps


# ------------------------------------------------------------- routing
def test_dp_scores_block_routes_and_validates(block):
    qs, rs = block
    lin_row = np.asarray(dp_scores_block(qs, rs, dp_kernel="rowwave"))
    lin_wave = np.asarray(dp_scores_block(qs, rs, dp_kernel="wavefront"))
    np.testing.assert_array_equal(lin_row, lin_wave)
    aff = np.asarray(dp_scores_block(qs, rs, gap_mode="affine"))
    np.testing.assert_array_equal(aff, np.asarray(
        gotoh.sw_wave_affine(qs, rs)))
    with pytest.raises(ValueError, match="wavefront"):
        dp_scores_block(qs, rs, dp_kernel="rowwave", gap_mode="affine")
    with pytest.raises(ValueError, match="dp_kernel"):
        dp_scores_block(qs, rs, dp_kernel="zigzag")
    with pytest.raises(ValueError, match="gap_mode"):
        dp_scores_block(qs, rs, gap_mode="convex")


def test_score_pairs_validates_knobs(block):
    ids = np.asarray(block[0])
    lens = (ids != PAD).sum(axis=1).astype(np.int32)
    pairs = np.array([[0, 1]], np.int32)
    with pytest.raises(ValueError, match="wavefront"):
        score_pairs(ids, lens, pairs, WaveConfig(dp_kernel="rowwave",
                                                 gap_mode="affine"))
    with pytest.raises(ValueError, match="with_pid"):
        score_pairs(ids, lens, pairs, WaveConfig(gap_mode="affine",
                                                 with_pid=True))
    with pytest.raises(ValueError, match="dp_kernel"):
        score_pairs(ids, lens, pairs, WaveConfig(dp_kernel="zigzag"))


# ------------------------------------------------------- Pallas kernel
@pytest.mark.parametrize("gap_mode", ["linear", "affine"])
def test_pallas_wavefront_kernel_parity(gap_mode):
    """The Pallas wavefront kernel (interpret mode off-TPU) is bit-exact
    with the jnp sweep, including a non-multiple-of-bb batch with an
    all-PAD row."""
    rng = np.random.default_rng(3)
    qs, rs = _ragged_block(rng, 11, 40, 36, all_pad_rows=(4,),
                           len1_rows=(6,))
    got = np.asarray(ops.wavefront_scores(qs, rs, gap_mode=gap_mode))
    want = np.asarray(ops.wavefront_scores(qs, rs, gap_mode=gap_mode,
                                           prefer_ref=True))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------- recompile sentinel
def test_warm_wavefront_never_retraces():
    """One gather+wavefront program per (rung, quantum): after warming the
    shape ladder, serving-sized calls never re-trace — across batch rungs,
    length quanta, and both gap modes."""
    rng = np.random.default_rng(5)
    corp = rng.integers(0, 20, (64, 128), dtype=np.int8)
    lens = np.full(64, 128, np.int32)
    ids_dev = jnp.asarray(corp)
    lens_dev = jnp.asarray(lens)

    def call(B, Lq, gap_mode):
        qi = jnp.asarray(rng.integers(0, 64, B, dtype=np.int32))
        ri = jnp.asarray(rng.integers(0, 64, B, dtype=np.int32))
        sw_gather_scores(ids_dev, lens_dev, ids_dev, lens_dev, qi, ri,
                         Lq=Lq, Lr=128, gap_mode=gap_mode
                         ).block_until_ready()

    shapes = [(8, 64), (8, 128), (16, 64), (16, 128)]
    for B, Lq in shapes:            # warm every rung x quantum, both modes
        call(B, Lq, "linear")
        call(B, Lq, "affine")
    with SENTINEL.expect_no_compiles("sw_gather", message="warmed ladder"):
        for B, Lq in shapes * 2:
            call(B, Lq, "linear")
            call(B, Lq, "affine")


# -------------------------------------------------- fused join prefilter
@pytest.fixture(scope="module")
def corpus():
    return make_family_corpus(FamilyCorpusConfig(
        n_families=8, family_size=3, n_singletons=24, len_mean=90,
        len_std=12, sub_rate=0.04, seed=13))


@pytest.fixture(scope="module")
def index(corpus):
    return SignatureIndex.build(CFG, corpus["ids"], corpus["lens"])


def test_fused_prefilter_join_is_postfilter_exact(corpus, index):
    """The in-join prefilter emits exactly the unfused wave prefilter's
    survivors, with identical ungapped scores, and counts the rejects."""
    ids, lens = corpus["ids"], corpus["lens"]
    join = lsh_self_join(index)
    res = score_pairs(ids, lens, join.pairs,
                      WaveConfig(prefilter=True, prefilter_min=40,
                                 with_pid=False))
    fused = lsh_self_join(index, prefilter=JoinPrefilter(
        ids=ids, lens=lens, min_score=40))
    np.testing.assert_array_equal(fused.pairs, join.pairs[res.kept])
    np.testing.assert_array_equal(fused.ungapped, res.ungapped[res.kept])
    assert fused.n_prefiltered == int((~res.kept).sum())
    assert fused.n_candidates == len(fused.pairs)
    # CSR stays valid over the survivor subset
    assert fused.indptr[-1] == len(fused.pairs)
    for i in (0, 5, index.size - 1):
        np.testing.assert_array_equal(
            fused.neighbors(i), fused.pairs[fused.pairs[:, 0] == i, 1])


def test_fused_prefilter_scores_match_direct_ungapped(corpus, index):
    """Survivor scores equal a direct ungapped scan of the kept pairs
    (padding-invariance of the prefilter score)."""
    ids, lens = corpus["ids"], corpus["lens"]
    fused = lsh_self_join(index, prefilter=JoinPrefilter(
        ids=ids, lens=lens, min_score=40))
    L = int(ids.shape[1])
    for (i, j), s in zip(fused.pairs, fused.ungapped):
        direct = int(np.asarray(ungapped_xdrop_scores(
            ids[None, i, :L], ids[None, j, :L]))[0])
        assert direct == s


def test_fused_prefilter_min_score_validation(corpus, index):
    with pytest.raises(ValueError, match="min_score"):
        lsh_self_join(index, prefilter=JoinPrefilter(
            ids=corpus["ids"], lens=corpus["lens"], min_score=0))


def test_all_pairs_search_fused_equals_unfused(corpus):
    """End to end: fuse_prefilter=True produces the same families and the
    same surviving edges as the unfused prefilter pipeline."""
    wave = WaveConfig(with_pid=False, prefilter=True, prefilter_min=40)
    base = AllPairsConfig(wave=wave)
    fused_cfg = AllPairsConfig(wave=wave, fuse_prefilter=True)
    a = all_pairs_search(corpus["ids"], corpus["lens"], base)
    b = all_pairs_search(corpus["ids"], corpus["lens"], fused_cfg)
    np.testing.assert_array_equal(b.pairs, a.pairs[a.scored.kept])
    np.testing.assert_array_equal(a.labels, b.labels)
    kept_scores = a.scored.scores[a.scored.kept]
    np.testing.assert_array_equal(b.scored.scores, kept_scores)


@pytest.mark.parametrize("gap_mode", ["linear", "affine"])
def test_family_labels_stable_across_gap_modes(corpus, gap_mode):
    """Calibrated thresholds give the same families under both gap modes
    (family alignments in the benchmark corpus are gapless, where Gotoh
    and linear scoring coincide)."""
    cfg = AllPairsConfig(wave=WaveConfig(with_pid=False, gap_mode=gap_mode),
                         min_score=150)
    res = all_pairs_search(corpus["ids"], corpus["lens"], cfg)
    want = all_pairs_search(
        corpus["ids"], corpus["lens"],
        AllPairsConfig(wave=WaveConfig(with_pid=False), min_score=150))
    np.testing.assert_array_equal(res.labels, want.labels)
