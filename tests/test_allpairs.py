"""repro.allpairs: self-join exactness, tiled SW waves (device-resident
gather, ungapped X-drop prefilter, async drain ring), clustering, and the
batched Smith-Waterman edge cases (empty sets, length-1, all-PAD, PID
parity between the wave and the per-pair path)."""
import numpy as np
import pytest

from repro.align.smith_waterman import (percent_identity, sw_align_batch,
                                        sw_score, sw_wave_pid,
                                        ungapped_xdrop_scores)
from repro.allpairs import (AllPairsConfig, WaveConfig, all_pairs_search,
                            brute_force_collisions, cluster_families,
                            lsh_self_join, score_pairs, union_find)
from repro.core import LSHConfig
from repro.core.alphabet import PAD
from repro.data import FamilyCorpusConfig, make_family_corpus
from repro.index import SignatureIndex

CFG = LSHConfig(k=3, T=13, f=32, d=1)


@pytest.fixture(scope="module")
def corpus():
    return make_family_corpus(FamilyCorpusConfig(
        n_families=10, family_size=3, n_singletons=30, len_mean=90,
        len_std=12, sub_rate=0.04, seed=5))


@pytest.fixture(scope="module")
def index(corpus):
    return SignatureIndex.build(CFG, corpus["ids"], corpus["lens"])


# ---------------------------------------------------------------- self-join
def test_selfjoin_matches_bruteforce_collisions(index):
    join = lsh_self_join(index)
    got = {tuple(p) for p in join.pairs}
    assert got == brute_force_collisions(index)
    # upper-triangular, deduplicated, lex-sorted
    assert (join.pairs[:, 0] < join.pairs[:, 1]).all()
    assert len(got) == join.n_candidates == len(join.pairs)
    order = np.lexsort((join.pairs[:, 1], join.pairs[:, 0]))
    np.testing.assert_array_equal(order, np.arange(len(order)))


def test_selfjoin_grow_and_retry_exact(index):
    """A tiny initial capacity must still converge to the exact pair set."""
    small = lsh_self_join(index, max_pairs=2)
    full = lsh_self_join(index, max_pairs=1 << 16)
    np.testing.assert_array_equal(small.pairs, full.pairs)


def test_selfjoin_max_grow_raises(index):
    with pytest.raises(RuntimeError, match="max_grow"):
        lsh_self_join(index, max_pairs=2, max_grow=2)


def test_selfjoin_hamming_filter_subset(index):
    raw = lsh_self_join(index)
    filt = lsh_self_join(index, d=CFG.d)
    got = {tuple(p) for p in filt.pairs}
    assert got <= {tuple(p) for p in raw.pairs}
    # filter keeps exactly the within-d collisions
    sigs = index.sigs
    for i, j in raw.pairs:
        dist = bin(int(sigs[i, 0] ^ sigs[j, 0])).count("1")
        assert ((i, j) in got) == (dist <= CFG.d)


def test_selfjoin_csr_adjacency(index):
    join = lsh_self_join(index)
    assert join.indptr.shape == (index.size + 1,)
    assert join.indptr[-1] == join.n_candidates
    want = {tuple(p) for p in join.pairs}
    got = {(i, int(j)) for i in range(index.size)
           for j in join.neighbors(i)}
    assert got == want


def test_selfjoin_empty_corpus():
    ids = np.zeros((0, 1), np.int8)
    lens = np.zeros((0,), np.int32)
    idx = SignatureIndex.build(CFG, ids, lens)
    join = lsh_self_join(idx)
    assert join.n_candidates == 0 and join.indptr.shape == (1,)


# ---------------------------------------------------------------- SW waves
def test_wave_scores_match_per_pair(corpus):
    """Batched wave == per-pair scores over a randomized pair set."""
    rng = np.random.default_rng(0)
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    pairs = np.stack([rng.integers(0, n, 24), rng.integers(0, n, 24)],
                     axis=1).astype(np.int32)
    scored = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8))
    for row, (i, j) in enumerate(pairs):
        assert scored.scores[row] == sw_score(ids[i][:lens[i]],
                                              ids[j][:lens[j]])


def test_wave_pid_matches_per_pair(corpus):
    """PID parity: the batched wave + traceback must be bit-exact with the
    per-pair percent_identity path on a randomized corpus."""
    rng = np.random.default_rng(1)
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    pairs = np.stack([rng.integers(0, n, 16), rng.integers(0, n, 16)],
                     axis=1).astype(np.int32)
    scored = score_pairs(ids, lens, pairs,
                         WaveConfig(wave_batch=8, with_pid=True))
    for row, (i, j) in enumerate(pairs):
        pid, length, score = percent_identity(ids[i][:lens[i]],
                                              ids[j][:lens[j]])
        assert scored.pid[row] == pid
        assert scored.aln_len[row] == length
        assert scored.scores[row] == score


def test_wave_empty_candidate_set(corpus):
    scored = score_pairs(corpus["ids"], corpus["lens"],
                         np.zeros((0, 2), np.int32), WaveConfig())
    assert scored.scores.shape == (0,) and scored.n_waves == 0


def test_wave_length_one_sequences():
    ids = np.array([[0], [0], [4]], np.int8)      # A, A, C
    lens = np.ones(3, np.int32)
    pairs = np.array([[0, 1], [0, 2]], np.int32)
    scored = score_pairs(ids, lens, pairs, WaveConfig(with_pid=True))
    assert scored.scores[0] == 4                  # BLOSUM62[A,A]
    assert scored.pid[0] == 100.0 and scored.aln_len[0] == 1
    assert scored.scores[1] == 0                  # A vs C scores 0 locally
    assert scored.pid[1] == 0.0


def test_sw_int16_guard_long_sequences():
    """The gapped wave's int16 carries are guarded at 11*L < 2^14: a pair
    above the guard falls back to int32 and stays bit-exact with the
    (always-int32) matrix path; one below it runs int16 and agrees too."""
    rng = np.random.default_rng(9)
    for L in (180, 1600):       # int16 regime / int32 fallback
        q = rng.integers(0, 20, L).astype(np.int8)
        r = rng.integers(0, 20, L + 16).astype(np.int8)
        _, _, want = percent_identity(q, r)    # int32 DP matrix path
        assert sw_score(q, r) == want
        np.testing.assert_array_equal(
            sw_align_batch(q[None, :], r[None, :]), [want])


def test_wave_all_pad_rows():
    """All-PAD rows (wave padding) score 0 / PID 0 and never poison real
    rows in the same wave."""
    qs = np.full((3, 12), PAD, np.int8)
    rs = np.full((3, 12), PAD, np.int8)
    seq = np.array([12, 3, 4, 16, 5, 0], np.int8)
    qs[1, :6] = seq
    rs[1, :6] = seq
    pid, length, score = sw_wave_pid(qs, rs)
    assert score[0] == score[2] == 0 and pid[0] == 0 and length[0] == 0
    want_pid, want_len, want_score = percent_identity(seq, seq)
    assert (pid[1], length[1], score[1]) == (want_pid, want_len, want_score)
    np.testing.assert_array_equal(
        sw_align_batch(qs, rs), [0, want_score, 0])


def _random_pairs(corpus, m, seed):
    rng = np.random.default_rng(seed)
    n = len(corpus["lens"])
    return np.stack([rng.integers(0, n, m), rng.integers(0, n, m)],
                    axis=1).astype(np.int32)


# ------------------------------------------------- device-resident pipeline
def test_device_vs_host_gather_bitexact_ragged(corpus):
    """Fused on-device gather == host copy loop on a ragged corpus, for
    score-only, PID, and prefilter waves alike."""
    ids, lens = corpus["ids"], corpus["lens"]
    assert len(set(lens.tolist())) > 1, "corpus must be ragged"
    pairs = _random_pairs(corpus, 32, 3)
    host = score_pairs(ids, lens, pairs,
                       WaveConfig(wave_batch=8, device_gather=False,
                                  with_pid=True))
    dev = score_pairs(ids, lens, pairs,
                      WaveConfig(wave_batch=8, device_gather=True,
                                 with_pid=True))
    np.testing.assert_array_equal(host.scores, dev.scores)
    np.testing.assert_array_equal(host.pid, dev.pid)
    np.testing.assert_array_equal(host.aln_len, dev.aln_len)
    hostp = score_pairs(ids, lens, pairs,
                        WaveConfig(wave_batch=8, device_gather=False,
                                   prefilter=True))
    devp = score_pairs(ids, lens, pairs,
                       WaveConfig(wave_batch=8, device_gather=True,
                                  prefilter=True))
    np.testing.assert_array_equal(hostp.ungapped, devp.ungapped)
    np.testing.assert_array_equal(hostp.scores, devp.scores)


def test_max_wave_cells_forces_single_pair_waves(corpus):
    """A cell budget below one padded pair must degrade to B=1 waves and
    still score exactly."""
    ids, lens = corpus["ids"], corpus["lens"]
    pairs = _random_pairs(corpus, 6, 4)
    tiny = WaveConfig(wave_batch=8, max_wave_cells=1)   # << Lq*Lr
    scored = score_pairs(ids, lens, pairs, tiny)
    assert scored.n_waves == len(pairs)                 # B=1 -> one per pair
    ref = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8))
    np.testing.assert_array_equal(scored.scores, ref.scores)


def test_wave_last_chunk_all_padding(corpus):
    """A bucket one pair larger than a wave leaves a last chunk that is
    mostly padding; padding rows must not perturb real scores."""
    ids, lens = corpus["ids"], corpus["lens"]
    # 9 pairs of identical shape with wave_batch 8 -> waves of 8 and 1(+7 pad)
    i = int(np.argmax(lens))
    pairs = np.array([[i, i]] * 9, np.int32)
    scored = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8))
    want = sw_score(ids[i][:lens[i]], ids[i][:lens[i]])
    np.testing.assert_array_equal(scored.scores, [want] * 9)
    assert scored.n_waves == 2


def test_prefilter_survivors_bitexact_rejected_lower_bound(corpus):
    ids, lens = corpus["ids"], corpus["lens"]
    pairs = _random_pairs(corpus, 48, 5)
    full = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8))
    pre = score_pairs(ids, lens, pairs,
                      WaveConfig(wave_batch=8, prefilter=True,
                                 prefilter_min=40))
    assert pre.kept is not None and pre.ungapped is not None
    # ungapped is a lower bound of SW everywhere
    assert (pre.ungapped <= full.scores).all()
    # survivors re-scored by full SW, bit-exact
    np.testing.assert_array_equal(pre.scores[pre.kept],
                                  full.scores[pre.kept])
    # rejected pairs report the (lower-bound) ungapped score
    np.testing.assert_array_equal(pre.scores[~pre.kept],
                                  pre.ungapped[~pre.kept])
    assert pre.n_prefiltered == int((~pre.kept).sum())


def test_xdrop_recall_on_planted_families(corpus):
    """Prefilter recall: every pair scoring >= the family threshold must
    survive the ungapped X-drop filter (the benchmark's 99% criterion is
    exactly 100% on this corpus), for both x=None and finite x."""
    ids, lens, labels = corpus["ids"], corpus["lens"], corpus["labels"]
    res = lsh_self_join(SignatureIndex.build(CFG, ids, lens))
    full = score_pairs(ids, lens, res.pairs, WaveConfig())
    S = 150                                     # family score threshold
    fam = labels[res.pairs[:, 0]] == labels[res.pairs[:, 1]]
    assert (full.scores[fam] >= S).all(), "planted pairs must score >= S"
    for x in (None, 20):
        pre = score_pairs(ids, lens, res.pairs,
                          WaveConfig(prefilter=True, prefilter_min=40,
                                     xdrop=x))
        high = full.scores >= S
        assert pre.kept[high].all(), f"x={x} lost a high-scoring pair"


def test_prefilter_indel_regime_needs_calibration():
    """Documented limitation: dense indels chop ungapped runs, so the
    gapped/ungapped gap widens and the default threshold loses true pairs —
    the reason the clustering CLI keeps the prefilter opt-in."""
    c = make_family_corpus(FamilyCorpusConfig(
        n_families=8, family_size=3, n_singletons=16, len_mean=150,
        sub_rate=0.02, indel_rate=0.4, seed=3))
    cfg = AllPairsConfig(lsh=LSHConfig(k=3, T=13, f=32, d=4), min_pid=50.0,
                         wave=WaveConfig(with_pid=True, prefilter=True,
                                         prefilter_min=40))
    res = all_pairs_search(c["ids"], c["lens"], cfg)
    full = score_pairs(c["ids"], c["lens"], res.pairs,
                       WaveConfig(with_pid=True))
    true_edge = np.asarray(full.pid) >= 50.0
    # gapped homologs exist whose ungapped lower bound is under-threshold
    assert (res.scored.ungapped[true_edge] < 40).any()
    # and with the prefilter off, none of them are lost
    assert (np.asarray(full.pid)[true_edge] >= 50.0).all()


def test_ungapped_xdrop_monotone_in_x(corpus):
    """Finite X-drop can only terminate runs earlier: score(x) <=
    score(None), and both lower-bound the gapped SW score."""
    ids, lens = corpus["ids"], corpus["lens"]
    pairs = _random_pairs(corpus, 16, 6)
    qm, rm = ids[pairs[:, 0]], ids[pairs[:, 1]]
    inf_sc = np.asarray(ungapped_xdrop_scores(qm, rm, x=None))
    x_sc = np.asarray(ungapped_xdrop_scores(qm, rm, x=10))
    sw = sw_align_batch(qm, rm)
    assert (x_sc <= inf_sc).all()
    assert (inf_sc <= sw).all()


def test_async_ring_depths_agree(corpus):
    """Results are independent of the in-flight ring depth."""
    ids, lens = corpus["ids"], corpus["lens"]
    pairs = _random_pairs(corpus, 24, 7)
    base = score_pairs(ids, lens, pairs, WaveConfig(inflight=0))
    for depth in (1, 2, 8):
        got = score_pairs(ids, lens, pairs, WaveConfig(inflight=depth))
        np.testing.assert_array_equal(got.scores, base.scores)


def test_wave_pallas_kernel_parity(corpus):
    """The Pallas tile kernel scores == the jnp wave on ragged real pairs."""
    rng = np.random.default_rng(2)
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    pairs = np.stack([rng.integers(0, n, 10), rng.integers(0, n, 10)],
                     axis=1).astype(np.int32)
    a = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=4))
    b = score_pairs(ids, lens, pairs,
                    WaveConfig(wave_batch=4, use_pallas=True))
    np.testing.assert_array_equal(a.scores, b.scores)


# ---------------------------------------------------------------- clustering
def test_union_find_components():
    edges = np.array([[0, 1], [1, 2], [4, 5]], np.int64)
    labels = union_find(6, edges)
    assert labels[0] == labels[1] == labels[2]
    assert labels[4] == labels[5]
    assert labels[3] not in (labels[0], labels[4])
    # canonical label = smallest member
    assert labels[0] == 0 and labels[4] == 4 and labels[3] == 3


def test_cluster_families_thresholds():
    pairs = np.array([[0, 1], [2, 3], [4, 5]], np.int32)
    pid = np.array([90.0, 30.0, np.nan])
    fams = cluster_families(6, pairs, pid, min_pid=50.0)
    assert fams.n_families == 1
    np.testing.assert_array_equal(fams.families[0], [0, 1])
    np.testing.assert_array_equal(fams.edge_mask, [True, False, False])


def test_all_pairs_search_end_to_end(corpus):
    res = all_pairs_search(corpus["ids"], corpus["lens"],
                           AllPairsConfig(lsh=CFG, min_pid=60.0))
    labels = corpus["labels"]
    # every discovered family must be pure under the planted ground truth
    for fam in res.families.families:
        assert len(set(labels[fam])) == 1, f"mixed family {fam}"
    assert res.families.n_families >= 5       # most planted families surface
    # scored arrays align with the candidate pairs
    assert len(res.scored.scores) == res.join.n_candidates
    assert res.scored.pid is not None


def test_all_pairs_search_reuses_index(corpus, index):
    res = all_pairs_search(corpus["ids"], corpus["lens"],
                           AllPairsConfig(lsh=CFG), index=index)
    assert res.index is index
    with pytest.raises(ValueError, match="corpus"):
        all_pairs_search(corpus["ids"][:4], corpus["lens"][:4],
                         AllPairsConfig(lsh=CFG), index=index)
