"""repro.allpairs: self-join exactness, tiled SW waves, clustering, and the
batched Smith-Waterman edge cases (empty sets, length-1, all-PAD, PID
parity between the wave and the per-pair path)."""
import numpy as np
import pytest

from repro.align.smith_waterman import (percent_identity, sw_align_batch,
                                        sw_score, sw_wave_pid)
from repro.allpairs import (AllPairsConfig, WaveConfig, all_pairs_search,
                            brute_force_collisions, cluster_families,
                            lsh_self_join, score_pairs, union_find)
from repro.core import LSHConfig
from repro.core.alphabet import PAD
from repro.data import FamilyCorpusConfig, make_family_corpus
from repro.index import SignatureIndex

CFG = LSHConfig(k=3, T=13, f=32, d=1)


@pytest.fixture(scope="module")
def corpus():
    return make_family_corpus(FamilyCorpusConfig(
        n_families=10, family_size=3, n_singletons=30, len_mean=90,
        len_std=12, sub_rate=0.04, seed=5))


@pytest.fixture(scope="module")
def index(corpus):
    return SignatureIndex.build(CFG, corpus["ids"], corpus["lens"])


# ---------------------------------------------------------------- self-join
def test_selfjoin_matches_bruteforce_collisions(index):
    join = lsh_self_join(index)
    got = {tuple(p) for p in join.pairs}
    assert got == brute_force_collisions(index)
    # upper-triangular, deduplicated, lex-sorted
    assert (join.pairs[:, 0] < join.pairs[:, 1]).all()
    assert len(got) == join.n_candidates == len(join.pairs)
    order = np.lexsort((join.pairs[:, 1], join.pairs[:, 0]))
    np.testing.assert_array_equal(order, np.arange(len(order)))


def test_selfjoin_grow_and_retry_exact(index):
    """A tiny initial capacity must still converge to the exact pair set."""
    small = lsh_self_join(index, max_pairs=2)
    full = lsh_self_join(index, max_pairs=1 << 16)
    np.testing.assert_array_equal(small.pairs, full.pairs)


def test_selfjoin_max_grow_raises(index):
    with pytest.raises(RuntimeError, match="max_grow"):
        lsh_self_join(index, max_pairs=2, max_grow=2)


def test_selfjoin_hamming_filter_subset(index):
    raw = lsh_self_join(index)
    filt = lsh_self_join(index, d=CFG.d)
    got = {tuple(p) for p in filt.pairs}
    assert got <= {tuple(p) for p in raw.pairs}
    # filter keeps exactly the within-d collisions
    sigs = index.sigs
    for i, j in raw.pairs:
        dist = bin(int(sigs[i, 0] ^ sigs[j, 0])).count("1")
        assert ((i, j) in got) == (dist <= CFG.d)


def test_selfjoin_csr_adjacency(index):
    join = lsh_self_join(index)
    assert join.indptr.shape == (index.size + 1,)
    assert join.indptr[-1] == join.n_candidates
    want = {tuple(p) for p in join.pairs}
    got = {(i, int(j)) for i in range(index.size)
           for j in join.neighbors(i)}
    assert got == want


def test_selfjoin_empty_corpus():
    ids = np.zeros((0, 1), np.int8)
    lens = np.zeros((0,), np.int32)
    idx = SignatureIndex.build(CFG, ids, lens)
    join = lsh_self_join(idx)
    assert join.n_candidates == 0 and join.indptr.shape == (1,)


# ---------------------------------------------------------------- SW waves
def test_wave_scores_match_per_pair(corpus):
    """Batched wave == per-pair scores over a randomized pair set."""
    rng = np.random.default_rng(0)
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    pairs = np.stack([rng.integers(0, n, 24), rng.integers(0, n, 24)],
                     axis=1).astype(np.int32)
    scored = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=8))
    for row, (i, j) in enumerate(pairs):
        assert scored.scores[row] == sw_score(ids[i][:lens[i]],
                                              ids[j][:lens[j]])


def test_wave_pid_matches_per_pair(corpus):
    """PID parity: the batched wave + traceback must be bit-exact with the
    per-pair percent_identity path on a randomized corpus."""
    rng = np.random.default_rng(1)
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    pairs = np.stack([rng.integers(0, n, 16), rng.integers(0, n, 16)],
                     axis=1).astype(np.int32)
    scored = score_pairs(ids, lens, pairs,
                         WaveConfig(wave_batch=8, with_pid=True))
    for row, (i, j) in enumerate(pairs):
        pid, length, score = percent_identity(ids[i][:lens[i]],
                                              ids[j][:lens[j]])
        assert scored.pid[row] == pid
        assert scored.aln_len[row] == length
        assert scored.scores[row] == score


def test_wave_empty_candidate_set(corpus):
    scored = score_pairs(corpus["ids"], corpus["lens"],
                         np.zeros((0, 2), np.int32), WaveConfig())
    assert scored.scores.shape == (0,) and scored.n_waves == 0


def test_wave_length_one_sequences():
    ids = np.array([[0], [0], [4]], np.int8)      # A, A, C
    lens = np.ones(3, np.int32)
    pairs = np.array([[0, 1], [0, 2]], np.int32)
    scored = score_pairs(ids, lens, pairs, WaveConfig(with_pid=True))
    assert scored.scores[0] == 4                  # BLOSUM62[A,A]
    assert scored.pid[0] == 100.0 and scored.aln_len[0] == 1
    assert scored.scores[1] == 0                  # A vs C scores 0 locally
    assert scored.pid[1] == 0.0


def test_wave_all_pad_rows():
    """All-PAD rows (wave padding) score 0 / PID 0 and never poison real
    rows in the same wave."""
    qs = np.full((3, 12), PAD, np.int8)
    rs = np.full((3, 12), PAD, np.int8)
    seq = np.array([12, 3, 4, 16, 5, 0], np.int8)
    qs[1, :6] = seq
    rs[1, :6] = seq
    pid, length, score = sw_wave_pid(qs, rs)
    assert score[0] == score[2] == 0 and pid[0] == 0 and length[0] == 0
    want_pid, want_len, want_score = percent_identity(seq, seq)
    assert (pid[1], length[1], score[1]) == (want_pid, want_len, want_score)
    np.testing.assert_array_equal(
        sw_align_batch(qs, rs), [0, want_score, 0])


def test_wave_pallas_kernel_parity(corpus):
    """The Pallas tile kernel scores == the jnp wave on ragged real pairs."""
    rng = np.random.default_rng(2)
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    pairs = np.stack([rng.integers(0, n, 10), rng.integers(0, n, 10)],
                     axis=1).astype(np.int32)
    a = score_pairs(ids, lens, pairs, WaveConfig(wave_batch=4))
    b = score_pairs(ids, lens, pairs,
                    WaveConfig(wave_batch=4, use_pallas=True))
    np.testing.assert_array_equal(a.scores, b.scores)


# ---------------------------------------------------------------- clustering
def test_union_find_components():
    edges = np.array([[0, 1], [1, 2], [4, 5]], np.int64)
    labels = union_find(6, edges)
    assert labels[0] == labels[1] == labels[2]
    assert labels[4] == labels[5]
    assert labels[3] not in (labels[0], labels[4])
    # canonical label = smallest member
    assert labels[0] == 0 and labels[4] == 4 and labels[3] == 3


def test_cluster_families_thresholds():
    pairs = np.array([[0, 1], [2, 3], [4, 5]], np.int32)
    pid = np.array([90.0, 30.0, np.nan])
    fams = cluster_families(6, pairs, pid, min_pid=50.0)
    assert fams.n_families == 1
    np.testing.assert_array_equal(fams.families[0], [0, 1])
    np.testing.assert_array_equal(fams.edge_mask, [True, False, False])


def test_all_pairs_search_end_to_end(corpus):
    res = all_pairs_search(corpus["ids"], corpus["lens"],
                           AllPairsConfig(lsh=CFG, min_pid=60.0))
    labels = corpus["labels"]
    # every discovered family must be pure under the planted ground truth
    for fam in res.families.families:
        assert len(set(labels[fam])) == 1, f"mixed family {fam}"
    assert res.families.n_families >= 5       # most planted families surface
    # scored arrays align with the candidate pairs
    assert len(res.scored.scores) == res.join.n_candidates
    assert res.scored.pid is not None


def test_all_pairs_search_reuses_index(corpus, index):
    res = all_pairs_search(corpus["ids"], corpus["lens"],
                           AllPairsConfig(lsh=CFG), index=index)
    assert res.index is index
    with pytest.raises(ValueError, match="corpus"):
        all_pairs_search(corpus["ids"][:4], corpus["lens"][:4],
                         AllPairsConfig(lsh=CFG), index=index)
