"""Segmented mutable-index lifecycle: append-only segments, delta refresh,
manifest persistence (incl. PR 1/3/4 legacy-format back-compat), delta
self-join, and the persistent family forest.

The one invariant everything here pins: a segmented index — however it was
grown, refreshed, persisted, or compacted — is BIT-EXACT with a
from-scratch rebuild over the concatenated corpus (probe results, pair
sets, family labels, overflow contracts)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.allpairs import (AllPairsConfig, FamilyForest, all_pairs_ingest,
                            all_pairs_search, forest_from_result,
                            lsh_delta_join, lsh_self_join, union_find)
from repro.core import LSHConfig, ScalLoPS
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import (QueryEngine, ServingConfig, ShardedIndex,
                         SignatureIndex)
from repro.index.service import topk_probe

CFG = LSHConfig(k=3, T=13, f=32, d=1)


@pytest.fixture(scope="module")
def data():
    return make_protein_sets(SyntheticProteinConfig(
        n_refs=120, n_homolog_queries=16, n_decoy_queries=16,
        ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=77))


@pytest.fixture(scope="module")
def q_sigs(data):
    return ScalLoPS(CFG).signatures(data["query_ids"], data["query_lens"])


def _segmented(data, n_segments: int, **kw) -> SignatureIndex:
    """The corpus ingested in ``n_segments`` add() rounds."""
    n = len(data["ref_lens"])
    cuts = np.linspace(0, n, n_segments + 1).astype(int)
    idx = SignatureIndex.build(CFG, data["ref_ids"][:cuts[1]],
                               data["ref_lens"][:cuts[1]], **kw)
    for a, b in zip(cuts[1:-1], cuts[2:]):
        idx.add(data["ref_ids"][a:b], data["ref_lens"][a:b])
    return idx


# ------------------------------------------------------------ merged table
@pytest.mark.parametrize("n_segments", [1, 2, 3])
def test_segmented_probe_matches_rebuild(data, q_sigs, n_segments):
    """topk_probe over a segmented index == a from-scratch rebuild of the
    concatenated corpus, before AND after compact() — the acceptance grid's
    single-device arm (the sharded arm runs under forced devices below)."""
    full = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    seg = _segmented(data, n_segments)
    assert seg.epoch == n_segments
    want = topk_probe(full, q_sigs, k=6, cap=32)
    got = topk_probe(seg, q_sigs, k=6, cap=32)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the merged bucket table itself is bit-exact (stable linear merge ==
    # from-scratch sort), which is what makes every consumer agree
    full._ensure_built()
    for (k1, o1, i1), (k2, o2, i2) in zip(full._csr_np, seg._csr_np):
        np.testing.assert_array_equal(k1, k2)
        np.testing.assert_array_equal(o1, o2)
        np.testing.assert_array_equal(i1, i2)
    seg.compact()
    assert seg.epoch == 1
    after = topk_probe(seg, q_sigs, k=6, cap=32)
    for a, b in zip(want, after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_add_does_not_rebucket_resident_segments(data):
    """The append-only contract: sealing a new segment leaves resident
    segment objects untouched (no invalidate-and-rebuild)."""
    idx = _segmented(data, 2)
    idx.seal()
    resident = idx.segments[0]
    rkeys = [k.copy() for k, _, _ in resident.csr]
    idx.add(data["ref_ids"][:10], data["ref_lens"][:10])
    idx.seal()
    assert idx.segments[0] is resident
    for (k, _, _), k0 in zip(resident.csr, rkeys):
        np.testing.assert_array_equal(k, k0)


# ------------------------------------------------------------ delta refresh
def test_sharded_delta_refresh_bitexact(data, q_sigs):
    """A serving replica ingests segment deltas via refresh() — no full
    reload — and stays bit-exact with the merged-table probe, including
    the grow-and-retry overflow contract and compaction."""
    idx = SignatureIndex.build(CFG, data["ref_ids"][:70],
                               data["ref_lens"][:70])
    sh = ShardedIndex(idx)
    sh.topk(q_sigs, k=6, cap=32)            # base placement served
    idx.add(data["ref_ids"][70:100], data["ref_lens"][70:100])
    idx.add(data["ref_ids"][100:], data["ref_lens"][100:])
    got = sh.topk(q_sigs, k=6, cap=32)
    assert sh._delta is not None, "expected a delta slab, not a re-place"
    assert sh.epoch == (1, 3)
    want = topk_probe(idx, q_sigs, k=6, cap=32)
    np.testing.assert_array_equal(got[0], np.asarray(want[0]))
    np.testing.assert_array_equal(got[1], np.asarray(want[1]))
    assert (got[2], got[3]) == (want[2], want[3])
    # tiny cap: the retry loop must see summed base+delta bucket sizes
    grown = sh.topk(q_sigs, k=6, cap=1)
    w2 = topk_probe(idx, q_sigs, k=6, cap=1)
    np.testing.assert_array_equal(grown[0], np.asarray(w2[0]))
    assert (grown[2], grown[3]) == (w2[2], w2[3])
    # serving-side compaction: identical results, delta folded away
    sh.compact()
    assert sh._delta is None
    after = sh.topk(q_sigs, k=6, cap=32)
    np.testing.assert_array_equal(after[0], got[0])
    np.testing.assert_array_equal(after[1], got[1])
    # index-side compaction bumps generation -> replica re-places
    idx.add(data["ref_ids"][:5], data["ref_lens"][:5])
    idx.compact()
    gen_before = sh._gen
    sh.topk(q_sigs, k=6, cap=32)
    assert sh._gen == gen_before + 1 and sh._delta is None


def test_sharded_refresh_auto_compacts_large_delta(data, q_sigs):
    """A delta that outgrows the base placement is folded in instead of
    carried (the carrying cost would exceed the re-place)."""
    idx = SignatureIndex.build(CFG, data["ref_ids"][:20],
                               data["ref_lens"][:20])
    sh = ShardedIndex(idx)
    sh.topk(q_sigs, k=4, cap=32)
    idx.add(data["ref_ids"][20:], data["ref_lens"][20:])    # 100 >> 20
    got = sh.topk(q_sigs, k=4, cap=32)
    assert sh._delta is None, "oversized delta should have re-placed"
    want = topk_probe(idx, q_sigs, k=4, cap=32)
    np.testing.assert_array_equal(got[0], np.asarray(want[0]))


def test_flip_layout_sharded_and_refreshed(data, q_sigs):
    """The flip layout partitions like any other table (n_bands == 1):
    sharded serving and the delta refresh hold bit-exact (the ROADMAP
    'shard_map probe for flip layout' item; n_shards > 1 runs in the
    forced-device subprocess of test_sharding.py)."""
    idx = SignatureIndex.build(CFG, data["ref_ids"][:80],
                               data["ref_lens"][:80], layout="flip")
    sh = ShardedIndex(idx)
    got = sh.topk(q_sigs, k=6, cap=64)
    want = topk_probe(idx, q_sigs, k=6, cap=64)
    np.testing.assert_array_equal(got[0], np.asarray(want[0]))
    np.testing.assert_array_equal(got[1], np.asarray(want[1]))
    idx.add(data["ref_ids"][80:], data["ref_lens"][80:])
    got = sh.topk(q_sigs, k=6, cap=64)
    assert sh._delta is not None
    want = topk_probe(idx, q_sigs, k=6, cap=64)
    np.testing.assert_array_equal(got[0], np.asarray(want[0]))
    np.testing.assert_array_equal(got[1], np.asarray(want[1]))


def test_engine_serves_across_live_refresh(data):
    """QueryEngine keeps serving while the index grows underneath it; the
    epoch counter surfaces in stats, and results are identical before and
    after compaction of the refreshed placement."""
    idx = SignatureIndex.build(CFG, data["ref_ids"][:70],
                               data["ref_lens"][:70])
    eng = QueryEngine(idx, ServingConfig(k=5), sharded=ShardedIndex(idx))
    eng.query_batch(data["query_ids"][:8], data["query_lens"][:8])
    assert eng.stats()["index_epoch"] == 1
    idx.add(data["ref_ids"][70:], data["ref_lens"][70:])
    a = eng.query_batch(data["query_ids"][:8], data["query_lens"][:8])
    assert eng.stats()["index_epoch"] == 2
    eng.sharded.compact()
    b = eng.query_batch(data["query_ids"][:8], data["query_lens"][:8])
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


# ------------------------------------------------------------ persistence
def test_segmented_save_appends_only_new_segments(tmp_path, data, q_sigs):
    """Repeated saves of a growing index write only the new segment files
    (O(delta) persistence); the loaded replica is bit-exact."""
    d = tmp_path / "idx"
    idx = SignatureIndex.build(CFG, data["ref_ids"][:60],
                               data["ref_lens"][:60])
    assert idx.save(d) == 1
    seg0 = d / "seg-g000-00000.npz"
    stamp = seg0.stat().st_mtime_ns
    idx.add(data["ref_ids"][60:], data["ref_lens"][60:])
    assert idx.save(d) == 1                 # ONLY the new segment
    assert seg0.stat().st_mtime_ns == stamp
    assert sorted(p.name for p in d.glob("seg-*.npz")) == \
        ["seg-g000-00000.npz", "seg-g000-00001.npz"]
    loaded = SignatureIndex.load(d, expected_cfg=CFG)
    assert loaded.epoch == 2
    want = topk_probe(idx, q_sigs, k=5, cap=256)
    got = topk_probe(loaded, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(got[1]))


def test_segmented_compact_roundtrip(tmp_path, data, q_sigs):
    """save -> compact -> save -> load: one segment file remains, stale
    files are dropped, and probe results never move."""
    d = tmp_path / "idx"
    idx = _segmented(data, 3)
    idx.save(d)
    assert len(list(d.glob("seg-*.npz"))) == 3
    want = topk_probe(idx, q_sigs, k=5, cap=256)
    idx.compact()
    assert idx.save(d) == 1
    # the rewrite lands under a NEW write generation (crash mid-rewrite
    # can never clobber the files the old manifest points at) and the
    # stale generation is GC'd after the manifest commits
    assert sorted(p.name for p in d.glob("seg-*.npz")) == \
        ["seg-g001-00000.npz"]
    loaded = SignatureIndex.load(d, expected_cfg=CFG)
    got = topk_probe(loaded, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(got[1]))
    np.testing.assert_array_equal(
        lsh_self_join(idx).pairs, lsh_self_join(loaded).pairs)


def test_manifest_rejects_stale_config(tmp_path, data):
    from repro.index import IndexConfigMismatch
    d = tmp_path / "idx"
    _segmented(data, 2).save(d)
    with pytest.raises(IndexConfigMismatch):
        SignatureIndex.load(d, expected_cfg=LSHConfig(k=4, T=22, f=32))


def test_save_detects_different_corpus_same_shape(tmp_path, data, q_sigs):
    """The append-only prefix check is CONTENT-aware: saving a different
    index (same config, same corpus shape) into an existing directory
    must rewrite it, never silently keep the stale files."""
    d = tmp_path / "idx"
    a = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    a.save(d)
    # same shapes, different content (rows reversed)
    b = SignatureIndex.build(CFG, data["ref_ids"][::-1],
                             np.ascontiguousarray(data["ref_lens"][::-1]))
    assert b.save(d) == 1                   # rewritten, not skipped
    loaded = SignatureIndex.load(d, expected_cfg=CFG)
    np.testing.assert_array_equal(loaded.sigs, b.sigs)
    got = topk_probe(loaded, q_sigs, k=5, cap=256)
    want = topk_probe(b, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_manifest_rejects_swapped_segment_file(tmp_path, data):
    """A segment file whose content disagrees with the manifest checksum
    fails loudly instead of serving wrong signature rows."""
    d = tmp_path / "idx"
    idx = SignatureIndex.build(CFG, data["ref_ids"][:60],
                               data["ref_lens"][:60])
    idx.add(data["ref_ids"][60:120], data["ref_lens"][60:120])
    idx.save(d)
    seg1 = d / "seg-g000-00001.npz"
    z = dict(np.load(seg1))
    z["sigs"] = z["sigs"][::-1].copy()      # same shape, different content
    np.savez_compressed(seg1, **z)
    with pytest.raises(ValueError, match="content hash"):
        SignatureIndex.load(d)


def test_manifest_rejects_reordered_segments(tmp_path, data):
    """Segments concatenate in manifest order while their CSR ids embed
    the stored base — a reordered/corrupt manifest must fail loudly, never
    serve wrong signature rows silently."""
    d = tmp_path / "idx"
    _segmented(data, 2).save(d)
    mpath = d / "manifest.json"
    m = json.loads(mpath.read_text())
    m["segments"] = m["segments"][::-1]
    mpath.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="reordered or corrupt"):
        SignatureIndex.load(d)


def test_truncated_segment_raises_typed_error_naming_file(tmp_path, data):
    """A torn/truncated segment file raises CorruptSegment carrying the
    offending filename — the operator knows WHICH file to restore."""
    from repro.index.segments import CorruptSegment
    d = tmp_path / "idx"
    _segmented(data, 3).save(d)
    victim = d / "seg-g000-00001.npz"
    blob = victim.read_bytes()
    victim.write_bytes(blob[:len(blob) // 3])        # torn write of old
    with pytest.raises(CorruptSegment) as ei:
        SignatureIndex.load(d)
    assert "seg-g000-00001.npz" in ei.value.file
    assert "seg-g000-00001.npz" in str(ei.value)


def test_checksum_mismatch_is_typed_with_file(tmp_path, data):
    """The PR 5 checksum rejection is now a typed CorruptSegment (still a
    ValueError — older handlers keep working) that names the file."""
    from repro.index.segments import CorruptSegment
    d = tmp_path / "idx"
    _segmented(data, 2).save(d)
    seg1 = d / "seg-g000-00001.npz"
    z = dict(np.load(seg1))
    z["sigs"] = z["sigs"][::-1].copy()
    np.savez_compressed(seg1, **z)
    with pytest.raises(CorruptSegment) as ei:
        SignatureIndex.load(d)
    assert isinstance(ei.value, ValueError)
    assert "seg-g000-00001.npz" in ei.value.file


def test_recovery_quarantines_tail_serves_valid_prefix(tmp_path, data,
                                                       q_sigs):
    """load(recover=True) on a damaged middle segment quarantines it AND
    everything after it (later global ids assume the damaged rows exist),
    rewrites the manifest to the valid prefix, and serves that prefix
    bit-exact with a from-scratch rebuild of the same rows."""
    d = tmp_path / "idx"
    _segmented(data, 3).save(d)              # 3 segments: 40 rows each
    victim = d / "seg-g000-00001.npz"
    blob = victim.read_bytes()
    victim.write_bytes(blob[: len(blob) // 3])
    idx = SignatureIndex.load(d, recover=True)
    rec = idx.recovery
    assert rec is not None and "seg-g000-00001.npz" in rec["file"]
    assert rec["n_segments_dropped"] == 2    # the damaged one AND its tail
    assert rec["n_rows_served"] == idx.size == 40
    assert sorted(rec["quarantined"]) == ["seg-g000-00001.npz",
                                          "seg-g000-00002.npz"]
    for f in rec["quarantined"]:             # evidence moved, not deleted
        assert (d / "quarantine" / f).exists()
        assert not (d / f).exists()
    # the served prefix is bit-exact with a rebuild of those rows
    prefix = SignatureIndex.build(CFG, data["ref_ids"][:40],
                                  data["ref_lens"][:40])
    want = topk_probe(prefix, q_sigs, k=5, cap=64)
    got = topk_probe(idx, q_sigs, k=5, cap=64)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the rewritten manifest loads CLEAN now — recovery is durable
    again = SignatureIndex.load(d)
    assert again.recovery is None and again.size == 40


def test_legacy_npz_torn_write_is_typed(tmp_path, data):
    """A truncated monolithic .npz (no prefix to fall back to) raises a
    typed CorruptSegment naming the path instead of a bare zipfile/OSError
    from deep inside numpy."""
    from repro.index.segments import CorruptSegment
    p = tmp_path / "idx.npz"
    SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"]).save(p)
    blob = p.read_bytes()
    p.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(CorruptSegment) as ei:
        SignatureIndex.load(p)
    assert "idx.npz" in ei.value.file


def test_forest_generation_and_size_mismatch_typed(tmp_path, data):
    """A persisted family forest that does not belong to the index it is
    loaded for (stale generation, wrong corpus size, torn file) raises
    ForestMismatch naming the file — a stale forest silently mislabeling
    families is the failure this guards against."""
    from repro.allpairs import ForestMismatch
    fpath = tmp_path / "families.npz"
    forest = FamilyForest(12)
    forest.union_edges(np.array([[0, 1], [2, 3]]))
    forest.save(fpath, generation=2)
    ok = FamilyForest.load(fpath, expect_n=12, expect_generation=2)
    np.testing.assert_array_equal(ok.labels(), forest.labels())
    with pytest.raises(ForestMismatch) as ei:
        FamilyForest.load(fpath, expect_generation=3)
    assert "families.npz" in ei.value.file and "generation" in str(ei.value)
    with pytest.raises(ForestMismatch, match="stale forest"):
        FamilyForest.load(fpath, expect_n=99)
    blob = fpath.read_bytes()                # torn forest file: typed too
    fpath.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(ForestMismatch, match="unreadable"):
        FamilyForest.load(fpath)
    # pre-PR 8 files carry no metadata: load fine, skip the gen check
    np.savez_compressed(fpath, parent=forest.parent, size=forest._size)
    FamilyForest.load(fpath, expect_generation=7)


def test_compact_noop_when_already_compact(data):
    """compact() on a single-sealed-segment index must not bump the
    generation (a replica would pay a full re-place for zero change)."""
    idx = _segmented(data, 2)
    sh = ShardedIndex(idx)
    idx.compact()
    gen = idx.generation
    sh.topk(np.asarray(idx.sigs[:4]), k=3, cap=32)      # re-placed once
    idx.compact()
    assert idx.generation == gen
    # loading a legacy monolithic npz is already compact too
    assert len(idx.segments) == 1


def _doctor_npz(path, drop_keys):
    """Rewrite a monolithic npz's embedded meta WITHOUT the given keys —
    reproducing what PR 1/PR 3-era files actually contain (their
    fingerprints omitted those fields, so they stay self-consistent)."""
    z = dict(np.load(path))
    meta = json.loads(bytes(z["meta_json"].tobytes()).decode())
    for k in drop_keys:
        meta.pop(k, None)
    z["meta_json"] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8)
    np.savez_compressed(path, **z)


@pytest.mark.parametrize("era,kw,drop", [
    # PR 1/2 files: raw band keys, no key_hash or n_shards metadata
    ("pr1", dict(key_hash="none"), ["key_hash", "n_shards"]),
    # PR 3 files: splitmix key mixing, still pre-sharding
    ("pr3", dict(key_hash="splitmix"), ["n_shards"]),
    # PR 4 files: n_shards joined the metadata/fingerprint
    ("pr4", dict(key_hash="splitmix", n_shards=4), []),
])
def test_legacy_npz_formats_load(tmp_path, data, q_sigs, era, kw, drop):
    """Monolithic fixtures from every prior era load through the one
    entry point (as a single sealed segment) and probe bit-exact."""
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"], **kw)
    path = tmp_path / f"{era}.npz"
    idx.save(path)
    _doctor_npz(path, drop)
    loaded = SignatureIndex.load(path, expected_cfg=CFG)
    assert loaded.key_hash == kw.get("key_hash", "splitmix")
    assert loaded.n_shards == kw.get("n_shards", 1)
    assert loaded.epoch == 1
    want = topk_probe(idx, q_sigs, k=5, cap=256)
    got = topk_probe(loaded, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(got[1]))
    # ...and a legacy index keeps growing through the segmented lifecycle
    loaded.add(data["query_ids"], data["query_lens"])
    assert loaded.epoch == 2
    d = tmp_path / f"{era}_grown"
    loaded.save(d)
    re = SignatureIndex.load(d, expected_cfg=CFG)
    a = topk_probe(loaded, q_sigs, k=5, cap=256)
    b = topk_probe(re, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


# ------------------------------------------------------------ delta join
@pytest.mark.parametrize("d_filter", [None, CFG.d])
@pytest.mark.parametrize("rounds", [1, 2])
def test_delta_join_union_equals_full(data, d_filter, rounds):
    """old pairs ∪ delta pairs == from-scratch self-join over the grown
    corpus (same dedup, filter, and sort order), with every delta pair
    touching at least one new row."""
    n = len(data["ref_lens"])
    base = n - 40
    idx = SignatureIndex.build(CFG, data["ref_ids"][:base],
                               data["ref_lens"][:base])
    old = lsh_self_join(idx, d=d_filter)
    cuts = np.linspace(base, n, rounds + 1).astype(int)
    for a, b in zip(cuts[:-1], cuts[1:]):
        idx.add(data["ref_ids"][a:b], data["ref_lens"][a:b])
    delta = lsh_delta_join(idx, base_size=base, d=d_filter)
    assert (delta.pairs[:, 1] >= base).all()
    full = lsh_self_join(
        SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"]),
        d=d_filter)
    union = np.concatenate([old.pairs, delta.pairs], axis=0)
    union = union[np.lexsort((union[:, 1], union[:, 0]))]
    np.testing.assert_array_equal(union, full.pairs)


def test_delta_join_boundary_and_empty(data):
    idx = SignatureIndex.build(CFG, data["ref_ids"][:60],
                               data["ref_lens"][:60])
    idx.add(data["ref_ids"][60:], data["ref_lens"][60:])
    with pytest.raises(ValueError):
        lsh_delta_join(idx, base_size=61)   # not a segment boundary
    empty = lsh_delta_join(idx, base_size=idx.size)
    assert empty.n_candidates == 0 and empty.n_rows == idx.size


# ------------------------------------------------------------ family forest
def test_forest_incremental_equals_scratch():
    rng = np.random.default_rng(3)
    n = 200
    edges = np.stack([rng.integers(0, n, 300),
                      rng.integers(0, n, 300)], axis=1)
    want = union_find(n, edges)
    forest = FamilyForest(120)
    forest.union_edges(edges[(edges < 120).all(axis=1)][:50])
    forest.grow(n)
    mask = np.ones(len(edges), bool)        # replay the rest in odd order
    mask[np.flatnonzero((edges < 120).all(axis=1))[:50]] = False
    forest.union_edges(edges[mask][::-1])
    np.testing.assert_array_equal(forest.labels(), want)


def test_forest_roundtrip_and_shrink(tmp_path):
    forest = FamilyForest(10)
    forest.union_edges(np.array([[0, 3], [3, 7], [1, 2]]))
    p = tmp_path / "families.npz"
    forest.save(p)
    loaded = FamilyForest.load(p)
    np.testing.assert_array_equal(loaded.labels(), forest.labels())
    loaded.grow(12)
    assert loaded.n == 12
    with pytest.raises(ValueError):
        loaded.grow(5)


def test_ingest_families_equal_scratch(data):
    """End-to-end incremental clustering: index.add + delta join + delta
    scoring + forest union == all_pairs_search over the grown corpus."""
    ids = np.asarray(data["ref_ids"], np.int8)
    lens = np.asarray(data["ref_lens"], np.int32)
    n = len(lens)
    base = n - 40
    cfg = AllPairsConfig(lsh=CFG)
    res = all_pairs_search(ids[:base], lens[:base], cfg)
    forest = forest_from_result(res)
    ing = all_pairs_ingest(ids, lens, base, cfg, index=res.index,
                           forest=forest)
    scratch = all_pairs_search(ids, lens, cfg)
    np.testing.assert_array_equal(ing.labels, scratch.families.labels)


# ------------------------------------------------- sharded grid (forced dev)
_SUBPROCESS = """
import numpy as np
import jax
assert jax.device_count() == 4, jax.devices()
from jax.sharding import Mesh

from repro.core import LSHConfig, ScalLoPS
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import ShardedIndex, SignatureIndex
from repro.index.service import topk_probe

data = make_protein_sets(SyntheticProteinConfig(
    n_refs=160, n_homolog_queries=16, n_decoy_queries=16,
    ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=51))
cfg = LSHConfig(k=3, T=13, f=32, d=1)
q = ScalLoPS(cfg).signatures(data["query_ids"], data["query_lens"])
n = len(data["ref_lens"])

# the acceptance grid: every (n_segments, n_shards), bit-exact with a
# from-scratch rebuild before and after compaction, through the real
# shard_map/ppermute delta ring
full = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
want = topk_probe(full, q, k=6, cap=32)
for n_segments in (2, 3):
    # majority-resident splits: the delta must stay smaller than the base
    # or refresh() (correctly) auto-compacts instead of carrying it
    cuts = np.concatenate(
        [[0], np.linspace(100, n, n_segments).astype(int)])
    for n_shards in (1, 2, 4):
        idx = SignatureIndex.build(cfg, data["ref_ids"][:cuts[1]],
                                   data["ref_lens"][:cuts[1]])
        sh = ShardedIndex(idx, Mesh(np.array(jax.devices()[:n_shards]),
                                    ("data",)))
        sh.topk(q, k=6, cap=32)             # base placement
        for a, b in zip(cuts[1:-1], cuts[2:]):
            idx.add(data["ref_ids"][a:b], data["ref_lens"][a:b])
        got = sh.topk(q, k=6, cap=32)       # delta refresh path
        assert sh._delta is not None, (n_segments, n_shards)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        sh.compact()
        assert sh._delta is None
        got = sh.topk(q, k=6, cap=32)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
print("GRID-EXACT")
"""


@pytest.mark.slow
def test_lifecycle_grid_forced_four_devices():
    """(n_segments, n_shards) acceptance grid under XLA-forced 4 host
    devices: the real ppermute ring probes base+delta slabs bit-exact
    with the from-scratch rebuild, before and after compaction."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "GRID-EXACT" in out.stdout, (out.stdout, out.stderr)
