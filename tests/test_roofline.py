"""Roofline machinery: HLO walker trip-count correctness + collective parse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_walk import walk, parse_computations
from repro.launch.roofline import Roofline


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


def test_walker_multiplies_scan_trip_count():
    def body(x, w):
        return x @ w, None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 256, 256), jnp.float32)
    c = _compile(scanned, x, ws)
    r = walk(c.as_text())
    assert r.flops == 16 * 2 * 128 * 256 * 256
    assert r.unknown_loops == 0
    # sanity: XLA's own aggregate misses the trip count
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] < r.flops / 10


def test_walker_nested_scans():
    def body(x, w):
        return x @ w, None

    def nested(x, ws):
        def outer(x, _):
            return jax.lax.scan(body, x, ws)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    r = walk(_compile(nested, x, ws).as_text())
    assert r.flops == 3 * 5 * 2 * 64 * 64 * 64


def test_walker_plain_dot_and_bytes():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    r = walk(_compile(lambda a, b: a @ b, a, b).as_text())
    assert r.flops == 2 * 32 * 48 * 16
    # bytes proxy at least covers operands + result once
    assert r.hbm_bytes >= (32 * 48 + 48 * 16 + 32 * 16) * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="x", shape="y", mesh="single", chips=256,
                 hlo_flops=197e12, hlo_bytes=819e9 * 2,
                 collective_bytes=50e9 * 0.5, collectives={},
                 model_flops=197e12 * 256 * 0.5,
                 peak_memory_bytes=0).finalize()
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert abs(r.collective_s - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.useful_ratio - 0.5) < 1e-9


def test_parse_computations_finds_entry():
    c = _compile(lambda x: x + 1.0, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps, entry = parse_computations(c.as_text())
    assert entry is not None and entry in comps
