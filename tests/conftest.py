"""Shared test configuration: a per-test wall-clock cap.

PR 8 exists because background threads can wedge; a wedged thread must
fail its test, not hang the whole suite. CI installs ``pytest-timeout``
(a dev extra) and the ``timeout`` ini in pyproject.toml does the rest.
This conftest covers the environment where the plugin is NOT installed
(the ini key would be unknown, and nothing would enforce the cap): it
registers the ini key itself and enforces the cap with SIGALRM — an
in-process approximation that catches the common case (a test blocked
on a join/wait on the main thread).
"""
from __future__ import annotations

import importlib.util
import signal
import threading

import pytest

_HAVE_PLUGIN = importlib.util.find_spec("pytest_timeout") is not None


def pytest_addoption(parser):
    if not _HAVE_PLUGIN:
        # pytest-timeout owns this ini key when installed; declaring it
        # twice would be a duplicate-ini error
        parser.addini("timeout", "per-test timeout in seconds "
                      "(SIGALRM fallback; pytest-timeout not installed)",
                      default="0")


if not _HAVE_PLUGIN:
    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        seconds = float(item.config.getini("timeout") or 0)
        use_alarm = (seconds > 0
                     and threading.current_thread()
                     is threading.main_thread()
                     and hasattr(signal, "SIGALRM"))
        if not use_alarm:
            yield
            return

        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded {seconds:.0f}s wall-clock cap "
                f"(SIGALRM fallback — a background thread is likely "
                f"wedged; see the supervisor stats in the failure)")

        old = signal.signal(signal.SIGALRM, _on_alarm)
        signal.alarm(int(seconds))
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)
