"""repro.obs: structured tracing, mergeable metrics, recompile sentinel.

The invariants pinned here:

* **one trace ID per query, end to end** — a query submitted to the
  async tier carries its ID from the ``submit`` instant through the
  dispatch batch, the router, the replica's serving spans, down to the
  ``resolve`` instant, with micro-batched queries sharing the batch's
  spans (honest attribution: the span names every query it served);
* **histograms merge exactly** — fixed-bucket merge is associative and
  equals the histogram of the concatenated samples, and quantiles stay
  within one bucket's relative width of the sample percentiles;
* **disabled tracing records nothing** — the serving path pays one
  branch, not a span;
* **the sentinel turns recompiles into assertions** — a warmed engine
  serves under ``expect_no_compiles``; a *fresh* ``Mesh`` over the same
  devices reuses every compiled ring (the PR 5 cache-key regression,
  now pinned at the sentinel layer); a changed static (probe cap) is a
  fresh program, never a silent recompile of the old one.
"""
import functools
import json
import warnings

import numpy as np
import pytest

from repro.core import LSHConfig
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import (QueryEngine, ServingConfig, ShardedIndex,
                         SignatureIndex)
from repro.obs import (REGISTRY, SENTINEL, TRACER, Histogram, Registry,
                       current_trace, default_bounds, span, trace_context,
                       trace_sentinel)
from repro.serve import AsyncEngine, ReplicaFleet
from repro.serve.metrics import Counters

CFG = LSHConfig(k=3, T=13, f=32, d=1)
SCFG = ServingConfig(k=5, max_batch=8, mode="probe")


@pytest.fixture(scope="module")
def data():
    return make_protein_sets(SyntheticProteinConfig(
        n_refs=120, n_homolog_queries=12, n_decoy_queries=12,
        ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=77))


@pytest.fixture(scope="module")
def index(data):
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    idx._ensure_built()
    return idx


@pytest.fixture
def traced():
    """Tracing on with a clean buffer; always off + cleared afterwards."""
    TRACER.clear()
    TRACER.enable()
    yield TRACER
    TRACER.disable()
    TRACER.clear()


# ---------------------------------------------------------------- histograms
def test_histogram_quantiles_track_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-4.0, sigma=1.0, size=20_000)
    h = Histogram()
    for v in samples:
        h.observe(float(v))
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum())
    for q in (0.50, 0.95, 0.99):
        want = float(np.percentile(samples, 100 * q))
        got = h.quantile(q)
        # one bucket's relative width (2**0.25 - 1 ~ 19%) is the bound
        assert abs(got - want) / want < 0.19, (q, got, want)


def test_histogram_merge_is_associative_and_exact():
    rng = np.random.default_rng(1)
    parts = [rng.lognormal(-4, 1, size=n) for n in (300, 1000, 50)]

    def hist(samples_list):
        h = Histogram()
        for s in samples_list:
            for v in s:
                h.observe(float(v))
        return h

    a, b, c = (hist([p]) for p in parts)
    left = hist([parts[0]]).merge(hist([parts[1]])).merge(hist([parts[2]]))
    right = hist([parts[0]]).merge(hist([parts[1]]).merge(hist([parts[2]])))
    whole = hist(parts)
    for other in (right, whole):
        np.testing.assert_array_equal(left.counts, other.counts)
        assert left.count == other.count
        assert left.sum == pytest.approx(other.sum)
    # unmerged inputs unchanged by being merge() arguments
    assert b.count == 1000 and c.count == 50
    with pytest.raises(ValueError):
        a.merge(Histogram(default_bounds(lo=1e-3)))


def test_histogram_state_roundtrip_merges():
    rng = np.random.default_rng(2)
    h = Histogram()
    for v in rng.lognormal(-4, 1, size=500):
        h.observe(float(v))
    # state() is what crosses a process boundary — must JSON-roundtrip
    rebuilt = Histogram.from_state(json.loads(json.dumps(h.state())))
    np.testing.assert_array_equal(rebuilt.counts, h.counts)
    assert rebuilt.quantile(0.95) == h.quantile(0.95)
    merged = Histogram().merge(h).merge(rebuilt)
    assert merged.count == 1000


# ---------------------------------------------------------------- registry
def test_registry_prometheus_exposition():
    reg = Registry()
    c = reg.counter("reqs_total", "requests", labelnames=("engine",))
    c.inc(engine="e0")
    c.inc(by=2, engine="e1")
    reg.gauge("depth").set(3)
    hf = reg.histogram("lat_seconds", "latency", labelnames=("engine",))
    hf.observe(0.010, engine="e0")
    hf.observe(0.020, engine="e0")
    text = reg.prometheus()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{engine="e0"} 1' in text
    assert 'reqs_total{engine="e1"} 2' in text
    assert "# TYPE depth gauge" in text and "depth 3" in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{engine="e0",le="+Inf"} 2' in text
    assert 'lat_seconds_count{engine="e0"} 2' in text
    # cumulative bucket counts are monotonically non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 2
    # redeclaration with different type or labels is a bug, not a metric
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")
    with pytest.raises(ValueError):
        reg.counter("reqs_total", labelnames=("replica",))
    assert reg.snapshot()["lat_seconds"]["engine=e0"]["count"] == 2


def test_family_merged_view():
    reg = Registry()
    hf = reg.histogram("h", labelnames=("replica",))
    for r, vals in (("r0", [0.01, 0.02]), ("r1", [0.03])):
        for v in vals:
            hf.observe(v, replica=r)
    assert hf.merged().count == 3


# ---------------------------------------------------------------- tracing
def test_disabled_tracing_records_nothing(index, data):
    assert not TRACER.enabled
    n0 = len(TRACER)
    with span("probe", B=4):
        pass
    eng = QueryEngine(index, SCFG, sharded=ShardedIndex(index))
    eng.query_batch(data["query_ids"][:2], data["query_lens"][:2])
    assert len(TRACER) == n0     # one branch, zero spans


def test_trace_context_tags_spans(traced):
    with trace_context((5, 6)):
        assert current_trace() == (5, 6)
        with span("probe", B=2):
            pass
    assert current_trace() == ()
    probes = [s for s in traced.spans() if s["name"] == "probe"]
    assert probes and probes[-1]["args"]["trace"] == [5, 6]
    assert probes[-1]["dur"] is not None


def test_trace_buffer_bounded(traced):
    traced.enable(capacity=64)
    for i in range(200):
        with span("x", i=i):
            pass
    assert len(traced) == 64
    assert traced.chrome_trace()["otherData"]["dropped_spans"] == 136


def test_trace_id_propagation_end_to_end(index, data, traced, tmp_path):
    """Every submitted query's ID spans submit -> dispatch -> the serving
    spans of its batch -> resolve, on one timeline."""
    fleet = ReplicaFleet(index, SCFG, n_replicas=1, start_ingest=False)
    eng = AsyncEngine(fleet, start=False)
    rows = [np.asarray(data["query_ids"][j][:data["query_lens"][j]], np.int8)
            for j in range(3)]
    futs = [eng.submit(r) for r in rows]
    eng._drain_once()
    assert all(f.result(timeout=60).ok for f in futs)
    spans = traced.spans()
    submits = {s["args"]["trace"][0] for s in spans if s["name"] == "submit"}
    assert len(submits) == 3     # one fresh trace ID per query
    by_trace = {}
    for s in spans:
        for tid in s["args"].get("trace", ()):
            by_trace.setdefault(tid, set()).add(s["name"])
    for tid in submits:
        path = by_trace[tid]
        assert {"submit", "dispatch", "route", "query_batch",
                "probe", "resolve"} <= path, (tid, sorted(path))
    # micro-batching attribution is honest: the one dispatch span names
    # all three queries it served
    dispatch = [s for s in spans if s["name"] == "dispatch"]
    assert len(dispatch) == 1 and set(dispatch[0]["args"]["trace"]) == submits
    out = tmp_path / "trace.json"
    n = traced.export(out)
    obj = json.loads(out.read_text())
    assert n == len(obj["traceEvents"]) and n > 0
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert {"X", "i", "M"} <= phases


def test_shed_resolves_with_reason(index, traced):
    fleet = ReplicaFleet(index, SCFG, n_replicas=1, start_ingest=False)
    eng = AsyncEngine(fleet, queue_depth=1, start=False)
    rows = [np.zeros(40, np.int8)] * 3
    futs = [eng.submit(r) for r in rows]
    outs = [f.result(timeout=5) for f in futs if f.done()]
    assert any(not o.ok and o.reason == "queue_full" for o in outs)
    sheds = [s for s in traced.spans() if s["name"] == "shed"]
    assert sheds and sheds[0]["args"]["reason"] == "queue_full"


# ---------------------------------------------------------------- metrics glue
def test_counters_undeclared_bump_warns_but_counts():
    c = Counters("a")
    with pytest.warns(UserWarning, match="undeclared"):
        c.bump("typo")
    assert c["typo"] == 1        # back-compat: still counted
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        c.bump("a", by=2)        # declared names never warn
    assert c.snapshot() == {"a": 2, "typo": 1}


def test_engine_stats_bounded_and_resettable(index, data):
    eng = QueryEngine(index, SCFG, sharded=ShardedIndex(index))
    for _ in range(3):
        eng.query_batch(data["query_ids"][:4], data["query_lens"][:4])
    st = eng.stats()
    assert st["n_batches"] == 3 and st["n_queries"] == 12
    assert st["p50_ms"] > 0 and st["p99_ms"] >= st["p50_ms"]
    assert set(st["stage_ms"]) >= {"ladder", "sig", "probe"}
    eng.reset_stats()
    assert eng.stats()["n_batches"] == 0
    # the registry view is monotonic: reset never rewinds the scrape
    merged = REGISTRY.histogram("serve_batch_seconds",
                                labelnames=("engine",)).merged()
    assert merged.count >= 3


# ---------------------------------------------------------------- sentinel
def test_sentinel_counts_traces_not_calls():
    import jax

    site = "obs_test_traces"

    @jax.jit
    @trace_sentinel(site)
    def f(x):
        return x + 1

    f(np.ones(4, np.float32))
    f(np.zeros(4, np.float32))       # same shape: cached, no re-trace
    assert SENTINEL.total(site) == 1
    f(np.ones(8, np.float32))        # new shape: one fresh compile
    assert SENTINEL.total(site) == 2
    assert SENTINEL.recompiled() == {}
    assert SENTINEL.by_site()[site] == 2
    with pytest.raises(AssertionError, match="zero-compile"):
        with SENTINEL.expect_no_compiles(site, message="steady state"):
            f(np.ones(16, np.float32))
    with SENTINEL.expect_no_compiles(site):
        f(np.ones(16, np.float32))   # now warm: passes


def test_warmup_then_serving_is_compile_free(index, data):
    eng = QueryEngine(index, SCFG, sharded=ShardedIndex(index))
    n = eng.warmup(data["query_ids"], data["query_lens"])
    assert n > 0
    with SENTINEL.expect_no_compiles("ring",
                                     message="warmed sync engine"):
        for j in range(0, 12, 4):
            eng.query_batch(data["query_ids"][j:j + 4],
                            data["query_lens"][j:j + 4])


def test_fresh_mesh_does_not_recompile_ring(index, data):
    """The PR 5 regression, pinned at the sentinel layer: programs are
    cached by DEVICE TUPLE, so a freshly constructed (equal) Mesh and a
    fresh ShardedIndex reuse every compiled ring."""
    import jax
    from jax.sharding import Mesh

    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    eng1 = QueryEngine(index, SCFG, sharded=ShardedIndex(index, mesh1))
    eng1.warmup(data["query_ids"], data["query_lens"])
    mesh2 = Mesh(np.array(jax.devices()[:1]), ("data",))   # fresh, equal
    eng2 = QueryEngine(index, SCFG, sharded=ShardedIndex(index, mesh2))
    with SENTINEL.expect_no_compiles("ring",
                                     message="fresh Mesh, same devices"):
        eng2.query_batch(data["query_ids"][:4], data["query_lens"][:4])
    # a changed static (probe cap) is a FRESH program — the sentinel must
    # see a new key, not a silent recompile of the old one
    before = SENTINEL.total("ring")
    cfg3 = ServingConfig(k=5, max_batch=8, mode="probe", probe_cap=64)
    eng3 = QueryEngine(index, cfg3, sharded=ShardedIndex(index, mesh1))
    eng3.query_batch(data["query_ids"][:4], data["query_lens"][:4])
    assert SENTINEL.total("ring") > before
    assert not {k: n for k, n in SENTINEL.recompiled().items()
                if k[0] == "ring"}, "cap growth misread as a recompile"


# ------------------------------------------- cross-process metric carrier
def test_registry_state_json_roundtrip_merges_exactly():
    """A worker snapshot survives json encode/decode and folds into a
    fresh parent registry exactly: counters add, gauges take the incoming
    value, histogram bucket counts add bucket-for-bucket."""
    from repro.obs import merge_registry_state, registry_state

    worker = Registry()
    worker.counter("pairs_total", "emitted pairs",
                   ("impl",)).labels(impl="spgemm").inc(7)
    worker.gauge("resident_rows", "rows").labels().set(128.0)
    h = worker.histogram("join_ms", "join latency", bounds=(1.0, 10.0))
    for v in (0.5, 3.0, 30.0):
        h.labels().observe(v)

    snap = json.loads(json.dumps(registry_state(worker)))
    parent = Registry()
    # the parent already saw some of the same traffic
    parent.counter("pairs_total", "emitted pairs",
                   ("impl",)).labels(impl="spgemm").inc(3)
    ph = parent.histogram("join_ms", "join latency", bounds=(1.0, 10.0))
    ph.labels().observe(5.0)
    merge_registry_state(snap, parent)
    merge_registry_state(snap, parent)       # associative: fold twice

    fams = parent.families()
    assert fams["pairs_total"].labels(impl="spgemm").value == 3 + 2 * 7
    assert fams["resident_rows"].labels().value == 128.0
    merged = ph.labels().state()
    # parent's one sample in (1,10] plus two copies of the worker's three
    assert merged["counts"] == [2, 3, 2]
    assert merged["count"] == 7


def test_merge_declares_missing_families():
    from repro.obs import merge_registry_state, registry_state

    worker = Registry()
    worker.histogram("only_in_worker_ms", "h", ("shard",),
                     bounds=(2.0,)).labels(shard="3").observe(1.0)
    parent = merge_registry_state(
        registry_state(worker), Registry())
    fam = parent.families()["only_in_worker_ms"]
    assert fam.bounds == (2.0,)
    assert fam.labels(shard="3").state()["count"] == 1


def test_merge_identity_drift_raises():
    """kind or labelname drift between worker and parent is a declaration
    bug and must raise, not silently fork the metric."""
    from repro.obs import merge_registry_state, registry_state

    worker = Registry()
    worker.counter("m", "as counter").labels().inc(1)
    parent = Registry()
    parent.gauge("m", "as gauge").labels().set(1.0)
    with pytest.raises(ValueError, match="redeclaration"):
        merge_registry_state(registry_state(worker), parent)

    worker2 = Registry()
    worker2.counter("n", "c", ("a",)).labels(a="x").inc(1)
    parent2 = Registry()
    parent2.counter("n", "c", ("b",)).labels(b="y").inc(1)
    with pytest.raises(ValueError, match="redeclaration"):
        merge_registry_state(registry_state(worker2), parent2)
