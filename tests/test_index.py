"""repro.index: store (build/persist/add), shard (fan-out), service (top-k,
overflow retry), plus the q_valid/r_valid masking branch of ScalLoPS.search."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import LSHConfig, ScalLoPS
from repro.core.join import pairs_to_set
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import (IndexConfigMismatch, QueryEngine, ServingConfig,
                         ShardedIndex, SignatureIndex)
from repro.index.service import topk_dense, topk_probe

CFG = LSHConfig(k=3, T=13, f=32, d=1, max_pairs=1 << 14)


@pytest.fixture(scope="module")
def data():
    return make_protein_sets(SyntheticProteinConfig(
        n_refs=96, n_homolog_queries=24, n_decoy_queries=24,
        ref_len_mean=100, ref_len_std=15, sub_rates=(0.03, 0.1), seed=17))


@pytest.fixture(scope="module")
def index(data):
    return SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])


@pytest.fixture(scope="module")
def q_sigs(data):
    return ScalLoPS(CFG).signatures(data["query_ids"], data["query_lens"])


def _brute_dists(q_sigs, index):
    q = np.asarray(q_sigs)
    r = index.sigs
    dist = np.zeros((len(q), len(r)), np.int32)
    for w in range(q.shape[1]):
        x = q[:, w][:, None] ^ r[:, w][None, :]
        dist += np.vectorize(lambda v: bin(int(v)).count("1"))(x)
    dist[:, ~index.valid] = 1 << 30
    return dist


# ---------------------------------------------------------------- store
def test_dense_topk_matches_bruteforce(index, q_sigs):
    nid, nd = topk_dense(index, q_sigs, k=5)
    dist = _brute_dists(q_sigs, index)
    want = np.sort(dist, axis=1)[:, :5]
    nd_np = np.asarray(nd).astype(np.int64)
    nd_np[nd_np < 0] = 1 << 30
    np.testing.assert_array_equal(nd_np, np.minimum(want, 1 << 30))


def test_probe_finds_all_neighbors_within_d(index, q_sigs):
    """Pigeonhole guarantee: every reference within Hamming d must surface
    in the probe top-k (k large enough to hold them all)."""
    dist = _brute_dists(q_sigs, index)
    k = int((dist <= CFG.d).sum(axis=1).max()) + 1
    nid, nd, *_ = topk_probe(index, q_sigs, k=k, cap=256)
    nid, nd = np.asarray(nid), np.asarray(nd)
    for i in range(dist.shape[0]):
        want = set(np.nonzero(dist[i] <= CFG.d)[0])
        got = set(nid[i][(nd[i] >= 0) & (nd[i] <= CFG.d)])
        assert got == want, f"query {i}: {got} != {want}"


def test_flip_layout_matches_flip_join(data, index, q_sigs):
    """flip-layout probe == the paper-faithful flip_join pair set within d."""
    from repro.core.join import flip_join
    idxf = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"],
                                layout="flip")
    dist = _brute_dists(q_sigs, idxf)
    k = int((dist <= CFG.d).sum(axis=1).max()) + 1
    nid, nd, *_ = topk_probe(idxf, q_sigs, k=k, cap=256)
    nid, nd = np.asarray(nid), np.asarray(nd)
    pairs, _ = flip_join(jnp.asarray(q_sigs), jnp.asarray(idxf.sigs),
                         f=CFG.f, d=CFG.d, max_pairs=1 << 14)
    want = pairs_to_set(pairs)
    want = {(q, r) for q, r in want if idxf.valid[r]}
    got = {(i, int(r)) for i in range(nid.shape[0])
           for r, dd in zip(nid[i], nd[i]) if r >= 0 and 0 <= dd <= CFG.d}
    assert got == want


def test_persistence_roundtrip_exact(tmp_path, index, q_sigs):
    path = tmp_path / "idx.npz"
    index.save(path)
    loaded = SignatureIndex.load(path, expected_cfg=CFG)
    a_ids, a_d, *_ = topk_probe(index, q_sigs, k=7, cap=128)
    b_ids, b_d, *_ = topk_probe(loaded, q_sigs, k=7, cap=128)
    np.testing.assert_array_equal(np.asarray(a_ids), np.asarray(b_ids))
    np.testing.assert_array_equal(np.asarray(a_d), np.asarray(b_d))


def test_load_rejects_stale_config(tmp_path, index):
    path = tmp_path / "idx.npz"
    index.save(path)
    with pytest.raises(IndexConfigMismatch):
        SignatureIndex.load(path, expected_cfg=LSHConfig(k=4, T=22, f=32))
    # serving-time knobs must NOT invalidate the index
    compatible = LSHConfig(k=CFG.k, T=CFG.T, f=CFG.f, d=CFG.d,
                           max_pairs=123, join_method="band")
    SignatureIndex.load(path, expected_cfg=compatible)


def test_incremental_add_matches_full_build(data, index, q_sigs):
    half = SignatureIndex.build(CFG, data["ref_ids"][:48],
                                data["ref_lens"][:48])
    half.add(data["ref_ids"][48:], data["ref_lens"][48:])
    assert half.size == index.size
    a_ids, a_d, *_ = topk_probe(index, q_sigs, k=5, cap=256)
    b_ids, b_d, *_ = topk_probe(half, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(a_d), np.asarray(b_d))


def test_add_then_save_roundtrips(tmp_path, data, q_sigs):
    half = SignatureIndex.build(CFG, data["ref_ids"][:48],
                                data["ref_lens"][:48])
    half.add(data["ref_ids"][48:], data["ref_lens"][48:])
    path = tmp_path / "grown.npz"
    half.save(path)  # forces the deferred re-sort
    loaded = SignatureIndex.load(path)
    a = topk_probe(half, q_sigs, k=5, cap=256)
    b = topk_probe(loaded, q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


# ---------------------------------------------------------------- search mask
def test_search_valid_masking_drops_pairs(q_sigs, index):
    """q_valid/r_valid branch: pairs touching invalid rows are dropped and
    the count reflects the mask."""
    sl = ScalLoPS(CFG)
    r_sigs = jnp.asarray(index.sigs)
    full = sl.search(q_sigs, r_sigs)
    assert not bool(full.overflowed)
    base = pairs_to_set(full.pairs)
    assert base, "need some pairs for a meaningful mask test"
    drop_q = {q for q, _ in base if q % 2 == 0}
    qv = np.ones(np.asarray(q_sigs).shape[0], bool)
    qv[list(drop_q)] = False
    rv = np.ones(index.size, bool)
    drop_r = {r for _, r in base if r % 3 == 0}
    rv[list(drop_r)] = False
    res = sl.search(q_sigs, r_sigs, q_valid=qv, r_valid=rv)
    got = pairs_to_set(res.pairs)
    want = {(q, r) for q, r in base if qv[q] and rv[r]}
    assert got == want
    assert int(res.count) == len(want)


def test_search_overflow_flag(q_sigs, index):
    sl = ScalLoPS(CFG)
    r_sigs = jnp.asarray(index.sigs)
    full = sl.search(q_sigs, r_sigs)
    n = int(full.count)
    assert n > 2
    small = sl.search(q_sigs, r_sigs, max_pairs=2)
    assert bool(small.overflowed) and int(small.count) == n
    grown = sl.search(q_sigs, r_sigs, max_pairs=2 * n)
    assert not bool(grown.overflowed)


@pytest.mark.parametrize("method", ["flip", "band", "dense"])
def test_search_overflow_flag_all_joins(method):
    """Regression: band_join's candidate buffer can truncate *before* the
    final count, so count alone can look <= max_pairs while pairs were
    lost — overflowed must still be True (8x8 identical sigs, 64 pairs)."""
    sl = ScalLoPS(LSHConfig(k=3, T=13, f=32, d=0, join_method=method))
    sigs = jnp.tile(jnp.uint32([[0x12345678]]), (8, 1))
    res = sl.search(sigs, sigs, max_pairs=16)
    assert bool(res.overflowed)
    big = sl.search(sigs, sigs, max_pairs=256)
    assert not bool(big.overflowed) and int(big.count) == 64


# ---------------------------------------------------------------- service
def test_engine_overflow_grow_and_retry(data, index):
    eng = QueryEngine(index, ServingConfig(k=5, mode="probe", probe_cap=1))
    nid, nd = eng.query_batch(data["query_ids"], data["query_lens"])
    assert eng._probe_cap > 1          # capacity grew on overflow
    dense = QueryEngine(index, ServingConfig(k=5, mode="dense"))
    nid2, nd2 = dense.query_batch(data["query_ids"], data["query_lens"])
    # within-d neighbors agree between probe (grown) and dense paths
    for i in range(nid.shape[0]):
        a = set(nid[i][(nd[i] >= 0) & (nd[i] <= CFG.d)])
        b = set(nid2[i][(nd2[i] >= 0) & (nd2[i] <= CFG.d)])
        assert a == b


def test_engine_queue_and_invalid_queries(data, index):
    eng = QueryEngine(index, ServingConfig(k=3, max_batch=8))
    eng.submit("AAA")                  # k=3 -> single low-complexity shingle
    eng.submit("MDESFGLLLESMQARIEELNDVLRLINKWLRSTDAAQ")
    out = eng.flush()
    assert len(out) == 2 and eng.pending() == 0
    s = eng.stats()
    assert s["n_queries"] == 2 and s["n_batches"] == 1 and s["qps"] > 0


def test_engine_search_pairs_grows_capacity(data, index):
    eng = QueryEngine(index, ServingConfig(k=3))
    res = eng.search_pairs(data["query_ids"], data["query_lens"],
                           max_pairs=2)
    assert not bool(res.overflowed)    # grew until nothing truncated
    assert int(res.count) == len(pairs_to_set(res.pairs))


def test_engine_rerank_lq_quantized_no_retrace(data, index):
    """Two batches whose raw widths share a ladder rung must reuse ONE
    compiled gather+DP program (Lq is quantized to len_quantum)."""
    from repro.align.smith_waterman import sw_gather_scores
    eng = QueryEngine(index, ServingConfig(k=3, rerank=True),
                      ref_seqs=(data["ref_ids"], data["ref_lens"]))
    qi, ql = data["query_ids"], data["query_lens"]
    eng.query_batch(qi[:4, :70], np.minimum(ql[:4], 70))
    n1 = sw_gather_scores._cache_size()
    eng.query_batch(qi[:4, :90], np.minimum(ql[:4], 90))   # same 128 rung
    assert sw_gather_scores._cache_size() == n1
    eng.query_batch(qi[:4, :150], np.minimum(ql[:4], 150))  # new 192 rung
    assert sw_gather_scores._cache_size() == n1 + 1


def test_engine_rerank_reorders_by_sw(data, index):
    eng = QueryEngine(index, ServingConfig(k=3, rerank=True),
                      ref_seqs=(data["ref_ids"], data["ref_lens"]))
    nid, nd = eng.query_batch(data["query_ids"][:4], data["query_lens"][:4])
    assert nid.shape == (4, 3)
    # valid slots stay ahead of -1 padding after the reorder
    for row in nid:
        seen_invalid = False
        for v in row:
            if v < 0:
                seen_invalid = True
            else:
                assert not seen_invalid


# ---------------------------------------------------------------- shard
def test_sharded_single_device_matches_probe(index, q_sigs):
    """The bucket-sharded ring at n_shards=1 is bit-exact with topk_probe
    (same candidates, same tie-breaks, same overflow contract)."""
    sh = ShardedIndex(index)           # 1 CPU device in the main process
    nid, nd, cap, tr = sh.topk(q_sigs, k=5, cap=256)
    want_id, want_d, want_cap, want_tr = topk_probe(index, q_sigs, k=5,
                                                    cap=256)
    np.testing.assert_array_equal(nid, np.asarray(want_id))
    np.testing.assert_array_equal(nd, np.asarray(want_d))
    assert (cap, tr) == (want_cap, want_tr)


def test_sharded_grow_and_retry(index, q_sigs):
    """A tiny cap must grow until no matched bucket truncates, landing on
    the same results as a comfortably large cap."""
    sh = ShardedIndex(index)
    nid, nd, cap, tr = sh.topk(q_sigs, k=5, cap=1)
    assert cap > 1 and not tr
    big_id, big_d, *_ = sh.topk(q_sigs, k=5, cap=256)
    np.testing.assert_array_equal(nid, big_id)
    np.testing.assert_array_equal(nd, big_d)


def test_sharded_engine_path_matches_probe_engine(data, index):
    """QueryEngine served through a ShardedIndex == the probe engine."""
    probe_eng = QueryEngine(index, ServingConfig(k=5, mode="probe"))
    a_id, a_d = probe_eng.query_batch(data["query_ids"], data["query_lens"])
    sh_eng = QueryEngine(index, ServingConfig(k=5), sharded=ShardedIndex(index))
    b_id, b_d = sh_eng.query_batch(data["query_ids"], data["query_lens"])
    np.testing.assert_array_equal(a_id, b_id)
    np.testing.assert_array_equal(a_d, b_d)


@pytest.mark.slow
def test_sharded_multi_device_matches_probe():
    """4 host devices in a subprocess (XLA flag must precede jax import)."""
    code = """
import numpy as np
from repro.core import LSHConfig, ScalLoPS
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import ShardedIndex, SignatureIndex
from repro.index.service import topk_probe

data = make_protein_sets(SyntheticProteinConfig(
    n_refs=50, n_homolog_queries=8, n_decoy_queries=8,
    ref_len_mean=80, ref_len_std=10, sub_rates=(0.05,), seed=23))
cfg = LSHConfig(k=3, T=13, f=32, d=1)
idx = SignatureIndex.build(cfg, data["ref_ids"], data["ref_lens"])
q = ScalLoPS(cfg).signatures(data["query_ids"], data["query_lens"])
sh = ShardedIndex(idx)
assert sh.n_shards == 4
nid, nd, cap, tr = sh.topk(q, k=5, cap=256)
want_id, want_d, *_ = topk_probe(idx, q, k=5, cap=256)
np.testing.assert_array_equal(nid, np.asarray(want_id))
np.testing.assert_array_equal(nd, np.asarray(want_d))
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=
                         os.path.dirname(os.path.dirname(__file__)),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
