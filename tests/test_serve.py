"""The async serving tier (repro.serve): futures engine, admission
control, replica fleet, and observability.

The invariants pinned here:

* **batching can't change answers** — a future resolved by the async
  dispatcher carries exactly what the synchronous ``flush()`` path would
  have returned for the same query, however the submits happened to
  batch (the padding ladder serves PAD rows that match nothing);
* **shedding is deterministic** — under an injectable clock and a preset
  cost model, which requests get ``Rejected("deadline")`` is a pure
  function of submit times and deadlines;
* **serving never tears** — a fleet result produced while refreshes and
  compactions race against queries is bit-exact with a from-scratch
  rebuild at the epoch it is tagged with (the PR 5 lifecycle contract
  extended across threads), and no request ever fails because a replica
  was mid-swap.
"""
import threading

import numpy as np
import pytest

from repro.core import LSHConfig
from repro.data import SyntheticProteinConfig, make_protein_sets
from repro.index import QueryEngine, ServingConfig, ShardedIndex, SignatureIndex
from repro.serve import AsyncEngine, Completed, Rejected, ReplicaFleet
from repro.serve.engine import COST_ALPHA
from repro.serve.metrics import Counters, Rolling

CFG = LSHConfig(k=3, T=13, f=32, d=1)
# probe mode on both sides of every parity assertion: the fleet always
# serves the sharded probe ring, while mode="auto" below dense_threshold
# would take the dense path (which ranks ALL refs — different semantics)
SCFG = ServingConfig(k=5, max_batch=8, mode="probe")


@pytest.fixture(scope="module")
def data():
    return make_protein_sets(SyntheticProteinConfig(
        n_refs=120, n_homolog_queries=16, n_decoy_queries=16,
        ref_len_mean=90, ref_len_std=12, sub_rates=(0.04, 0.1), seed=77))


@pytest.fixture(scope="module")
def index(data):
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    idx._ensure_built()
    return idx


def _rows(data):
    """Queries as length-trimmed rows (what a caller submits)."""
    return [np.asarray(data["query_ids"][j][:data["query_lens"][j]], np.int8)
            for j in range(len(data["query_lens"]))]


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------------------------ bit-exactness
def test_async_matches_flush_bitexact(data, index):
    """Every async result == the synchronous flush() result for the same
    query, despite completely different batch compositions (async batches
    form by arrival under max-wait; flush batches by submission chunks)."""
    rows = _rows(data)
    sync = QueryEngine(index, SCFG)
    for r in rows:
        sync.submit(r)
    want = sync.flush()

    async_backend = QueryEngine(index, SCFG)
    with AsyncEngine(async_backend, max_wait_ms=1.0) as eng:
        # interleave: stagger the submit order and let the dispatch
        # thread cut batches wherever the timing happens to fall
        order = list(range(len(rows)))
        order = order[1::2] + order[0::2]
        futs = {j: eng.submit(rows[j]) for j in order}
        got = {j: f.result(timeout=120) for j, f in futs.items()}
    for j, (wid, wd) in enumerate(want):
        r = got[j]
        assert isinstance(r, Completed) and r.ok
        np.testing.assert_array_equal(r.ids, wid)
        np.testing.assert_array_equal(r.dists, wd)
        assert r.epoch == index.epoch


def test_async_singleton_vs_batch_composition(data, index):
    """The same query submitted alone and buried in a big batch returns
    identical ids/dists — per-query results are independent of batch
    composition (the bit-exactness argument the tier rests on)."""
    rows = _rows(data)
    backend = QueryEngine(index, SCFG)
    with AsyncEngine(backend, max_wait_ms=0.0, start=False) as eng:
        solo = eng.submit(rows[0])
        eng._drain_once(timeout=0.01)           # batch of exactly 1
        futs = [eng.submit(r) for r in rows]    # batch of many
        while eng.pending():
            eng._drain_once(timeout=0.01)
        a = solo.result(timeout=5)
        b = futs[0].result(timeout=5)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.dists, b.dists)


# ------------------------------------------------------------ admission
def test_deadline_shedding_deterministic(data, index):
    """With a fake clock and a preset cost model, shedding is a pure
    function of (queue time + predicted batch cost) vs deadline."""
    rows = _rows(data)
    clock = FakeClock()
    backend = QueryEngine(index, SCFG)
    eng = AsyncEngine(backend, max_wait_ms=0.0, clock=clock, start=False)
    # batch of 3 lands on ladder rung 4; predict 50ms for it
    eng._cost_ms[eng._rung(3)] = 50.0

    f_tight = eng.submit(rows[0], deadline_ms=60.0)     # dies in queue
    f_loose = eng.submit(rows[1], deadline_ms=500.0)    # survives
    f_none = eng.submit(rows[2])                        # no deadline
    clock.advance(0.020)    # 20ms queued: 20 + 50 predicted > 60 tight
    eng._drain_once(timeout=0.0)

    r = f_tight.result(timeout=5)
    assert isinstance(r, Rejected) and r.reason == "deadline" and not r.ok
    assert r.predicted_ms == pytest.approx(50.0)
    assert r.queued_ms == pytest.approx(20.0)
    assert f_loose.result(timeout=5).ok
    assert f_none.result(timeout=5).ok
    assert eng.counters["shed_deadline"] == 1
    assert eng.counters["completed"] == 2
    # identical setup, identical outcome (no hidden wall-clock)
    clock2 = FakeClock()
    eng2 = AsyncEngine(QueryEngine(index, SCFG), max_wait_ms=0.0,
                       clock=clock2, start=False)
    eng2._cost_ms[eng2._rung(3)] = 50.0
    g1 = eng2.submit(rows[0], deadline_ms=60.0)
    g2 = eng2.submit(rows[1], deadline_ms=500.0)
    g3 = eng2.submit(rows[2])
    clock2.advance(0.020)
    eng2._drain_once(timeout=0.0)
    assert [f.result(5).ok for f in (g1, g2, g3)] == \
           [f.result(5).ok for f in (f_tight, f_loose, f_none)]
    eng.close()
    eng2.close()


def test_queue_full_and_shutdown_rejections(data, index):
    rows = _rows(data)
    backend = QueryEngine(index, SCFG)
    eng = AsyncEngine(backend, queue_depth=2, start=False)
    f1, f2 = eng.submit(rows[0]), eng.submit(rows[1])
    f3 = eng.submit(rows[2])
    r3 = f3.result(timeout=5)       # immediate: submit never blocks
    assert isinstance(r3, Rejected) and r3.reason == "queue_full"
    assert eng.counters["shed_queue_full"] == 1
    eng.close()                     # f1/f2 still queued -> shutdown
    assert f1.result(timeout=5).reason == "shutdown"
    assert f2.result(timeout=5).reason == "shutdown"
    assert eng.submit(rows[0]).result(timeout=5).reason == "shutdown"
    assert eng.counters["shed_shutdown"] == 3


def test_cost_model_rung_and_ewma(index):
    eng = AsyncEngine(QueryEngine(index, SCFG), start=False)
    # ladder (1, 2, 4, 8, ...) capped at max_batch=8
    assert [eng._rung(b) for b in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    assert eng.predicted_ms(3) == 0.0       # optimistic until measured
    eng._update_cost(3, 0.100)
    assert eng.predicted_ms(3) == pytest.approx(100.0)
    eng._update_cost(3, 0.200)              # EWMA, not overwrite
    assert eng.predicted_ms(3) == pytest.approx(
        COST_ALPHA * 200.0 + (1 - COST_ALPHA) * 100.0)
    assert eng.predicted_ms(8) == 0.0       # other rungs untouched
    eng.close()


# ------------------------------------------------------------ fleet races
def test_fleet_serving_during_refresh_and_compaction(data):
    """Queries racing a live ingest + compactions: every result is
    bit-exact with a from-scratch rebuild at the epoch it is tagged
    with, and nothing is ever rejected or torn."""
    n = len(data["ref_lens"])
    cut1, cut2 = n // 2, 3 * n // 4
    qids = data["query_ids"][:8]
    qlens = data["query_lens"][:8]

    # expected answers per epoch, from clean single-threaded rebuilds
    # (epoch == number of sealed segments: 1, then 2, then 3)
    expect = {}
    for epoch, upto in ((1, cut1), (2, cut2), (3, n)):
        idx = SignatureIndex.build(CFG, data["ref_ids"][:upto],
                                   data["ref_lens"][:upto])
        eng = QueryEngine(idx, SCFG, sharded=ShardedIndex(idx))
        expect[epoch] = eng.query_batch(qids, qlens)

    live = SignatureIndex.build(CFG, data["ref_ids"][:cut1],
                                data["ref_lens"][:cut1])
    fleet = ReplicaFleet(live, SCFG, n_replicas=2, minor_compact_every=2)
    try:
        results, errors = [], []
        stop = threading.Event()

        def pound():
            try:
                while not stop.is_set():
                    nid, nd, epoch = fleet.query_batch(qids, qlens)
                    results.append((np.asarray(nid), np.asarray(nd), epoch))
            except Exception as e:        # noqa: BLE001 - reraised below
                errors.append(e)

        threads = [threading.Thread(target=pound) for _ in range(2)]
        for t in threads:
            t.start()
        ev1 = fleet.ingest(data["ref_ids"][cut1:cut2],
                           data["ref_lens"][cut1:cut2])
        assert ev1.wait(timeout=120)
        ev2 = fleet.ingest(data["ref_ids"][cut2:], data["ref_lens"][cut2:])
        assert ev2.wait(timeout=120)      # 2nd ingest -> minor compaction
        # a few more results at the final epoch, then stop
        nid, nd, epoch = fleet.query_batch(qids, qlens)
        assert epoch == 3
        stop.set()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        results.append((np.asarray(nid), np.asarray(nd), epoch))

        seen = set()
        for nid, nd, epoch in results:
            assert epoch in expect, f"torn epoch tag {epoch}"
            seen.add(epoch)
            np.testing.assert_array_equal(nid, expect[epoch][0])
            np.testing.assert_array_equal(nd, expect[epoch][1])
        assert 3 in seen                 # the final state was served
        assert fleet.counters["ingests"] == 2
        assert fleet.counters["minor_compactions"] == 1

        # major compaction racing queries: content (and answers) frozen
        threads = [threading.Thread(target=pound) for _ in range(2)]
        stop.clear()
        n_before = len(results)
        for t in threads:
            t.start()
        fleet.compact_index()
        stop.set()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        for nid, nd, _epoch in results[n_before:]:
            np.testing.assert_array_equal(nid, expect[3][0])
            np.testing.assert_array_equal(nd, expect[3][1])
        assert live.generation == 1 and live.epoch == 1
    finally:
        fleet.close()


def test_fleet_through_async_engine_bitexact(data):
    """The full stack — AsyncEngine over a 2-replica fleet — returns
    flush()-identical answers with epoch tags, end to end."""
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    rows = _rows(data)
    sync = QueryEngine(idx, SCFG, sharded=ShardedIndex(idx))
    for r in rows:
        sync.submit(r)
    want = sync.flush()
    with ReplicaFleet(idx, SCFG, n_replicas=2) as fleet, \
            AsyncEngine(fleet, max_wait_ms=1.0) as eng:
        got = [eng.submit(r).result(timeout=120) for r in rows]
    for r, (wid, wd) in zip(got, want):
        assert r.ok and r.epoch == idx.epoch
        np.testing.assert_array_equal(r.ids, wid)
        np.testing.assert_array_equal(r.dists, wd)


def test_fleet_router_least_outstanding(data):
    idx = SignatureIndex.build(CFG, data["ref_ids"], data["ref_lens"])
    with ReplicaFleet(idx, SCFG, n_replicas=3, start_ingest=False) as fleet:
        # all idle: picks rotate by last_used, spreading load
        picked = []
        for _ in range(3):
            rep = fleet._pick()
            picked.append(rep.name)
            with fleet._pick_lock:
                rep.last_used = fleet._ticket
            rep.lock.release()
        assert len(set(picked)) == 3
        # a busy replica (lock held) is skipped, never waited on while a
        # free one exists
        busy = fleet._replicas[0]
        assert busy.lock.acquire(blocking=False)
        try:
            for _ in range(4):
                rep = fleet._pick()
                assert rep.name != busy.name
                rep.lock.release()
        finally:
            busy.lock.release()
        assert fleet.counters["waited_busy"] == 0


# ------------------------------------------------------------ observability
def test_rolling_window_and_counters():
    r = Rolling(window=4)
    for ms in (10, 20, 30, 40, 50, 60):   # first two fall out the window
        r.add(ms / 1e3)
    snap = r.snapshot()
    assert snap["count"] == 4 and snap["total"] == 6
    assert snap["p50_ms"] == pytest.approx(45.0)
    assert snap["mean_ms"] == pytest.approx(45.0)
    assert snap["p99_ms"] <= 60.0 + 1e-9
    assert Rolling().snapshot() == dict(count=0, total=0, p50_ms=0.0,
                                        p95_ms=0.0, p99_ms=0.0, mean_ms=0.0)
    c = Counters("a")
    c.bump("a")
    c.bump("b", by=2)
    assert c["a"] == 1 and c["b"] == 2 and c["missing"] == 0
    assert c.snapshot() == {"a": 1, "b": 2}


def test_stats_surfaces(data, index):
    """stats() exposes the new observability everywhere: per-stage timers
    + p99 + truncations on the sync engine, queue/latency/cost-model on
    the async engine, per-replica epochs on the fleet."""
    sync = QueryEngine(index, SCFG)
    sync.query_batch(data["query_ids"][:4], data["query_lens"][:4])
    s = sync.stats()
    assert set(s["stage_ms"]) == {"ladder", "sig", "probe", "rerank"}
    assert s["p99_ms"] >= s["p95_ms"] >= s["p50_ms"] >= 0
    assert s["truncations"] == 0
    assert sum(s["stage_ms"].values()) > 0

    rows = _rows(data)
    with AsyncEngine(QueryEngine(index, SCFG), max_wait_ms=0.5) as eng:
        [f.result(timeout=120) for f in (eng.submit(r) for r in rows[:4])]
        es = eng.stats()
    assert es["counters"]["completed"] == 4
    assert es["latency"]["count"] == 4
    assert es["queue"]["p95_ms"] <= es["latency"]["p95_ms"] + 1e9
    assert es["cost_model_ms"]            # at least one rung measured
    assert es["backend"]["n_queries"] >= 4

    with ReplicaFleet(index, SCFG, n_replicas=2,
                      start_ingest=False) as fleet:
        fleet.query_batch(data["query_ids"][:4], data["query_lens"][:4])
        fs = fleet.stats()
    assert fs["n_replicas"] == 2
    assert len(fs["replicas"]) == 2
    assert all(r["epoch"] == (index.epoch, index.epoch)
               for r in fs["replicas"])
    assert fs["counters"]["batches"] == 1
