"""SpGEMM candidate generation: ONE masked sparse-product primitive behind
self-join, delta-join, and probe (``repro.index.spgemm``).

The contract under test: bucket slabs are CSRs of a sequence×bucket
incidence matrix A, candidates are masks over the semiring AᵀA, and the
two orchestrations behind ``join_impl=`` — the fused device-resident
SpGEMM path and the legacy host-merge + grow-and-retry path — produce
BIT-IDENTICAL result arrays across shard counts, segment layouts, Hamming
filters, and the flip layout; the probe is a row slice of the same
product; warmed joins never retrace; and the wider-f (64/128) folded band
keys keep the join and probe exact.
"""
import json

import numpy as np
import pytest
import jax.numpy as jnp

from repro.allpairs import (JoinPrefilter, brute_force_collisions,
                            lsh_delta_join, lsh_self_join)
from repro.core import LSHConfig
from repro.core.join import (PACKED_KEY_MAX_ID, band_keys, compact_pairs,
                             dedup_pairs, pack_unique_pairs)
from repro.data import FamilyCorpusConfig, make_family_corpus
from repro.index import SignatureIndex
from repro.index import service as index_service
from repro.index.spgemm import (masked_pair_product, match_buckets,
                                row_product_positions, spgemm_join_self,
                                spgemm_join_self_keys)
from repro.kernels.ref import spgemm_upper_ref
from repro.kernels.spgemm import upper_pairs_kernel
from repro.obs import SENTINEL
from repro.util import next_pow2

CFG = LSHConfig(k=3, T=13, f=32, d=1)


@pytest.fixture(scope="module")
def corpus():
    return make_family_corpus(FamilyCorpusConfig(
        n_families=12, family_size=3, n_singletons=36, len_mean=90,
        len_std=12, sub_rate=0.04, seed=11))


@pytest.fixture(scope="module")
def index(corpus):
    return SignatureIndex.build(CFG, corpus["ids"], corpus["lens"])


# ----------------------------------------------- join_impl equivalence grid
@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("d_filter", [None, CFG.d])
def test_join_impl_equivalence_grid(index, n_shards, d_filter):
    """legacy and spgemm orchestrations return bit-identical arrays for
    every (n_shards, d) cell, and the unfiltered set is the brute-force
    collision oracle."""
    legacy = lsh_self_join(index, d=d_filter, n_shards=n_shards,
                           join_impl="legacy")
    fused = lsh_self_join(index, d=d_filter, n_shards=n_shards,
                          join_impl="spgemm")
    np.testing.assert_array_equal(legacy.pairs, fused.pairs)
    np.testing.assert_array_equal(legacy.indptr, fused.indptr)
    assert legacy.n_candidates == fused.n_candidates
    if d_filter is None:
        assert {tuple(p) for p in fused.pairs} == \
            brute_force_collisions(index)


def test_join_impl_flip_layout(corpus):
    """The flip layout (each signature in C(f,<=d) buckets of ONE band)
    exercises the dedup pack — a pair can collide many times within the
    single band, so the keyed dup-free path must gate itself off."""
    idx = SignatureIndex.build(CFG, corpus["ids"], corpus["lens"],
                               layout="flip")
    legacy = lsh_self_join(idx, join_impl="legacy")
    fused = lsh_self_join(idx, join_impl="spgemm")
    np.testing.assert_array_equal(legacy.pairs, fused.pairs)
    assert {tuple(p) for p in fused.pairs} == brute_force_collisions(idx)


def test_join_impl_grow_caps(index):
    """A tiny starting capacity converges identically under both impls
    (legacy grows-and-retries; spgemm sizes the output exactly), and a
    max_grow below true demand raises for both — never a silent cap."""
    full = lsh_self_join(index, max_pairs=1 << 16)
    for impl in ("legacy", "spgemm"):
        small = lsh_self_join(index, max_pairs=2, join_impl=impl)
        np.testing.assert_array_equal(small.pairs, full.pairs)
        with pytest.raises(RuntimeError, match="max_grow"):
            lsh_self_join(index, max_pairs=2, max_grow=2, join_impl=impl)
        # max_grow caps GROWTH, not the count: the unique pair count here
        # (119) exceeds the per-band emission max (69), yet with a roomy
        # max_pairs legacy never grows its buffer and so never raises —
        # spgemm must mirror that exactly
        need = int(index.partition(1).pair_totals.max())
        assert need < len(full.pairs)
        big = lsh_self_join(index, max_pairs=1 << 16, max_grow=need,
                            join_impl=impl)
        np.testing.assert_array_equal(big.pairs, full.pairs)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_delta_join_impl_equivalence(corpus, n_shards):
    """Multi-segment delta join: per-shard cross emission under the bucket
    partition is bit-exact vs the from-scratch join, for both impls."""
    ids, lens = corpus["ids"], corpus["lens"]
    n = len(lens)
    base = n - 24
    idx = SignatureIndex.build(CFG, ids[:base], lens[:base])
    old = lsh_self_join(idx)
    for a, b in ((base, n - 12), (n - 12, n)):      # two sealed segments
        idx.add(ids[a:b], lens[a:b])
    deltas = [lsh_delta_join(idx, base_size=base, n_shards=n_shards,
                             join_impl=impl)
              for impl in ("legacy", "spgemm")]
    np.testing.assert_array_equal(deltas[0].pairs, deltas[1].pairs)
    full = lsh_self_join(SignatureIndex.build(CFG, ids, lens))
    union = np.concatenate([old.pairs, deltas[1].pairs], axis=0)
    union = union[np.lexsort((union[:, 1], union[:, 0]))]
    np.testing.assert_array_equal(union, full.pairs)


def test_prefilter_fused_identical_across_impls(corpus, index):
    pf = JoinPrefilter(ids=corpus["ids"], lens=corpus["lens"],
                       min_score=20)
    legacy = lsh_self_join(index, prefilter=pf, join_impl="legacy")
    fused = lsh_self_join(index, prefilter=pf, join_impl="spgemm")
    np.testing.assert_array_equal(legacy.pairs, fused.pairs)
    np.testing.assert_array_equal(legacy.ungapped, fused.ungapped)
    assert legacy.n_prefiltered == fused.n_prefiltered


# ------------------------------------------------- probe = row slice of AᵀA
def test_probe_is_row_slice_of_product(index):
    """The serving probe resolves to the same structural key match as the
    join: each query row's product window is exactly the matched bucket's
    member list."""
    assert index_service._probe_csr_positions is row_product_positions
    index._ensure_built()
    part = index.partition(1)
    qk = np.asarray(index.query_keys(jnp.asarray(index.sigs)))   # (nb, N)
    for band, (keys_s, offs_s, ids_s) in enumerate(zip(*[
            np.asarray(a) for a in part.probe_arrays(0)])):
        pos, ok, size = row_product_positions(
            jnp.asarray(qk[band]), jnp.asarray(keys_s),
            jnp.asarray(offs_s), cap=8, E=ids_s.shape[0])
        pos, ok, size = map(np.asarray, (pos, ok, size))
        start, end = map(np.asarray, match_buckets(
            jnp.asarray(qk[band]), jnp.asarray(keys_s),
            jnp.asarray(offs_s)))
        for q in range(qk.shape[1]):
            want = set(ids_s[start[q]:end[q]].tolist())
            got = set(ids_s[pos[q][ok[q]]].tolist())
            assert size[q] == len(want)
            if size[q] <= 8:
                assert got == want
                if index.valid[q]:
                    assert q in want          # every row collides with itself


# --------------------------------------------------- fused program variants
def test_keyed_join_matches_dedup_join(index):
    """The dup-free keyed program and the sort-dedup program are
    interchangeable: identical pairs and count off the same slabs."""
    index._ensure_built()
    part = index.partition(1)
    _, offs_s, ids_s = part.device_slabs()
    offs_f = offs_s.reshape(-1, offs_s.shape[-1])
    ids_f = ids_s.reshape(-1, ids_s.shape[-1])
    cap = next_pow2(int(part.pair_totals.max()))
    out_cap = next_pow2(int(part.pair_totals.sum()))
    band_f = jnp.tile(jnp.arange(offs_s.shape[1], dtype=jnp.int32),
                      offs_s.shape[0])
    for d in (None, CFG.d):
        p1, c1 = spgemm_join_self(offs_f, ids_f, index.device_sigs,
                                  cap=cap, out_cap=out_cap, d=d)
        p2, c2 = spgemm_join_self_keys(
            offs_f, ids_f, band_f, index.device_band_keys,
            index.device_sigs, cap=cap, out_cap=out_cap, d=d)
        assert int(c1) == int(c2)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_pack_unique_pairs_wide_id_fallback():
    """Ids past PACKED_KEY_MAX_ID fall back to the multi-key sort +
    scatter pack — same buffer contract, same output."""
    rng = np.random.default_rng(3)
    cand = rng.integers(0, 50, size=(256, 2), dtype=np.int32)
    cand.sort(axis=1)
    cand[rng.random(256) < 0.3] = -1
    packed, n1 = pack_unique_pairs(jnp.asarray(cand), out_cap=128,
                                   id_bound=50)
    wide, n2 = pack_unique_pairs(jnp.asarray(cand), out_cap=128,
                                 id_bound=PACKED_KEY_MAX_ID + 1)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(wide))
    # and both match the primitive dedup+compact composition
    cs, keep = dedup_pairs(jnp.asarray(cand))
    ref, n3 = compact_pairs((cs[:, 0], cs[:, 1]), keep, 128)
    assert int(n1) == int(n3)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref))


# ------------------------------------------------------ Pallas kernel parity
def test_upper_kernel_matches_ref_and_product():
    """The Pallas upper-mask kernel (interpret mode on CPU), the vmapped
    jnp product, and the host-loop oracle agree on randomized multi-band
    slabs with pow2 padding."""
    rng = np.random.default_rng(7)
    for trial in range(4):
        nb, U, E = 3, 8, 32
        offs, ids = [], []
        for _ in range(nb):
            cuts = np.sort(rng.integers(0, E, U - 1))
            o = np.concatenate([[0], cuts, [E]]).astype(np.int32)
            offs.append(o)
            ids.append(rng.permutation(E).astype(np.int32))
        offs_s = jnp.asarray(np.stack(offs))
        ids_s = jnp.asarray(np.stack(ids))
        need = max(int(((np.diff(o) * (np.diff(o) - 1)) // 2).sum())
                   for o in offs)
        cap = next_pow2(max(need, 8))
        kern = np.asarray(upper_pairs_kernel(offs_s, ids_s, cap=cap,
                                             slot_block=8, interpret=True))
        prod = np.asarray(jnp.stack([
            masked_pair_product(offs_s[b], ids_s[b], cap=cap)
            for b in range(nb)]))
        np.testing.assert_array_equal(kern, prod)
        for b in range(nb):
            ref = spgemm_upper_ref(np.asarray(offs_s[b]),
                                   np.asarray(ids_s[b]), cap)
            np.testing.assert_array_equal(prod[b], ref)


# ------------------------------------------------------- recompile sentinel
def test_spgemm_steady_state_no_recompiles(index):
    """Warmed joins retrace nothing: the fused keyed program, the dedup
    pack, and the legacy orchestration all hit their jit caches on every
    subsequent call."""
    for _ in range(2):                                 # warm every program
        for impl in ("legacy", "spgemm"):
            for ns in (1, 2):
                lsh_self_join(index, n_shards=ns, join_impl=impl)
    for site in ("spgemm_join_keys", "spgemm_self", "spgemm_pack"):
        assert SENTINEL.total(site) >= 1, f"site {site} never traced"
    with SENTINEL.expect_no_compiles(message="warmed self-join retraced"):
        for impl in ("legacy", "spgemm"):
            for ns in (1, 2):
                lsh_self_join(index, n_shards=ns, join_impl=impl)


# ------------------------------------------------------------ wider-f (64+)
@pytest.mark.parametrize("f", [64, 128])
def test_wider_f_join_and_probe_exact(corpus, f):
    """f=64/128 signatures fold each band's words through the mix32 chain:
    bucket co-membership is preserved, so the join still equals the
    brute-force oracle and every valid row probes itself."""
    cfg = LSHConfig(k=3, T=13, f=f, d=3, scheme="splitmix")
    idx = SignatureIndex.build(cfg, corpus["ids"], corpus["lens"])
    join = lsh_self_join(idx)
    assert {tuple(p) for p in join.pairs} == brute_force_collisions(idx)
    # exact multiword Hamming filter stays a subset with exact membership
    filt = lsh_self_join(idx, d=cfg.d)
    got = {tuple(p) for p in filt.pairs}
    sigs = idx.sigs
    for i, j in join.pairs:
        dist = sum(bin(int(a ^ b)).count("1")
                   for a, b in zip(sigs[i], sigs[j]))
        assert ((int(i), int(j)) in got) == (dist <= cfg.d)
    # probe self-hit through the same folded keys
    cand, sizes = idx.probe(jnp.asarray(idx.sigs), cap=64)
    cand = np.asarray(cand)
    for q in range(idx.size):
        if idx.valid[q]:
            assert q in cand[q]


def test_wider_f_band_keys_fold_exact(corpus):
    """Folded keys collide exactly when the band bits are equal (the
    ~2^-32 accidental-collision tail can only ADD candidates)."""
    cfg = LSHConfig(k=3, T=13, f=64, d=3, scheme="splitmix")
    idx = SignatureIndex.build(cfg, corpus["ids"], corpus["lens"])
    from repro.core.simhash import unpack_bits
    from repro.core.join import band_bit_groups
    keys = np.asarray(band_keys(jnp.asarray(idx.sigs), 64, idx.bands,
                                interleave=idx.interleave,
                                key_hash=idx.key_hash))
    bits = np.asarray(unpack_bits(jnp.asarray(idx.sigs), 64))
    groups = band_bit_groups(64, idx.bands, interleave=idx.interleave)
    n = idx.size
    for b, grp in enumerate(groups):
        for i in range(0, n, 7):
            for j in range(i + 1, n, 13):
                if (bits[i, grp] == bits[j, grp]).all():
                    assert keys[i, b] == keys[j, b]


def test_wider_f_fingerprint_and_roundtrip(corpus, tmp_path):
    cfg64 = LSHConfig(k=3, T=13, f=64, d=3, scheme="splitmix")
    idx = SignatureIndex.build(cfg64, corpus["ids"], corpus["lens"])
    idx32 = SignatureIndex.build(
        LSHConfig(k=3, T=13, f=32, d=1, scheme="splitmix"),
        corpus["ids"], corpus["lens"])
    assert idx.fingerprint != idx32.fingerprint
    d = tmp_path / "f64"
    idx.save(d)
    re = SignatureIndex.load(d, expected_cfg=cfg64)
    a = lsh_self_join(idx)
    b = lsh_self_join(re)
    np.testing.assert_array_equal(a.pairs, b.pairs)


def test_java_scheme_rejects_wide_f():
    with pytest.raises(AssertionError, match="32 bits"):
        LSHConfig(k=3, T=13, f=64, d=1, scheme="java")


# ----------------------------------------------------- metrics CLI carrier
def test_allpairs_cli_metrics_out_and_merge(tmp_path):
    """--metrics-out writes a mergeable registry snapshot; --metrics-merge
    folds a worker snapshot in before rendering (the cross-process
    histogram aggregation satellite, end to end through the CLI)."""
    from repro.launch.allpairs import main as allpairs_main
    from repro.obs import Registry, registry_state

    worker = Registry()
    worker.counter("worker_pairs_total", "pairs from a worker shard")\
        .labels().inc(41)
    h = worker.histogram("worker_join_ms", "worker join latency",
                         bounds=(1.0, 10.0, 100.0))
    h.labels().observe(3.0)
    h.labels().observe(30.0)
    wpath = tmp_path / "worker_metrics.json"
    wpath.write_text(json.dumps(registry_state(worker)))

    mpath = tmp_path / "metrics.json"
    allpairs_main(["--n-families", "4", "--family-size", "3",
                   "--n-singletons", "8", "--len-mean", "60",
                   "--min-pid", "30",
                   "--metrics-out", str(mpath),
                   "--metrics-merge", str(wpath)])
    merged = json.loads(mpath.read_text())["families"]
    assert merged["worker_pairs_total"]["children"][0][1] == 41
    hist = merged["worker_join_ms"]["children"][0][1]
    assert hist["counts"] == [1, 1, 0, 1] or sum(hist["counts"]) == 2
