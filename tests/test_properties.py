"""Property-based tests (hypothesis). The dependency is a dev extra
(`pip install -e .[dev]`); without it this module skips at collection while
the example-based suites keep running."""
import itertools

import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.alphabet import AMINO_ACIDS, BLOSUM62, encode_batch
from repro.core import simhash
from repro.core.hamming import hamming_distance
from repro.kernels import ops, ref


# ------------------------------------------------------------ python oracle
def naive_signature(seq: str, k: int, T: int, f: int) -> int:
    """Literal Algorithm 2: per-shingle neighbour enumeration, Java hashCode,
    weighted ±1 accumulation, sign bits. (Set semantics of the pseudocode's
    `neighwords` union is a known pseudocode artifact — Figure 3.1 semantics,
    one contribution per (shingle, neighbour word) occurrence, is used, which
    is what the matmul/table paths implement.)"""
    V = [0] * f
    for s in range(len(seq) - k + 1):
        sh = seq[s : s + k]
        for word in itertools.product(AMINO_ACIDS, repeat=k):
            score = sum(
                BLOSUM62[AMINO_ACIDS.index(sh[i]), AMINO_ACIDS.index(word[i])]
                for i in range(k)
            )
            if score >= T:
                h = 0
                for c in word:
                    h = (h * 31 + ord(c)) & 0xFFFFFFFF
                for j in range(f):
                    V[j] += score if (h >> j) & 1 else -score
    bits = [1 if v >= 0 else 0 for v in V]
    out = 0
    for j, b in enumerate(bits):
        out |= b << j
    return out


SEQ = st.text(alphabet=AMINO_ACIDS, min_size=4, max_size=24)


@settings(max_examples=10, deadline=None)
@given(seq=SEQ, T=st.integers(min_value=5, max_value=14))
def test_signature_matches_naive_oracle(seq, T):
    k, f = 2, 32  # k=2 keeps the 400-word oracle loop tractable
    ids, lens = encode_batch([seq])
    got_m = int(np.asarray(simhash.signatures_matmul(ids, lens, k=k, T=T, f=f))[0, 0])
    got_t = int(np.asarray(simhash.signatures_table(ids, lens, k=k, T=T, f=f))[0, 0])
    want = naive_signature(seq, k, T, f)
    assert got_m == want
    assert got_t == want


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_hamming_distance_matches_popcount(a, b):
    d = int(hamming_distance(jnp.uint32([a]), jnp.uint32([b])))
    assert d == bin(a ^ b).count("1")


@settings(max_examples=15, deadline=None)
@given(
    Q=st.integers(1, 40), R=st.integers(1, 70),
    nw=st.sampled_from([1, 2, 4]), d=st.integers(0, 64),
    seed=st.integers(0, 2**16),
)
def test_hamming_count_property(Q, R, nw, d, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 2**32, (Q, nw), dtype=np.uint32))
    r = jnp.asarray(rng.integers(0, 2**32, (R, nw), dtype=np.uint32))
    got = ops.hamming_counts(q, r, d, bq=8, br=16)
    want = ref.hamming_count_ref(q, r, d)[:, 0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    Lq=st.integers(1, 24), Lr=st.integers(1, 24),
    gap=st.integers(-12, -1), seed=st.integers(0, 2**16),
)
def test_gotoh_open_eq_extend_is_linear_sw_cell_exact(Lq, Lr, gap, seed):
    """Gotoh with open == extend degenerates to the linear-gap recurrence
    CELL-exactly: the oracle's full H matrix equals the linear SW DP
    matrix, not just the best score (the property the wavefront's E/F-lane
    zero-init correctness proof leans on)."""
    from repro.align.smith_waterman import _sw_dp
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 20, Lq, dtype=np.int8)
    r = rng.integers(0, 20, Lr, dtype=np.int8)
    best_a, H_a = ref.sw_affine_ref(q, r, gap_open=gap, gap_extend=gap)
    best_l, H_l = _sw_dp(jnp.asarray(q), jnp.asarray(r), return_matrix=True)
    # patched linear DP with the same gap penalty for the H comparison
    H = np.zeros((Lq + 1, Lr + 1), np.int64)
    sub = np.asarray(BLOSUM62)
    for i in range(1, Lq + 1):
        for j in range(1, Lr + 1):
            H[i, j] = max(0, H[i - 1, j - 1] + sub[q[i - 1], r[j - 1]],
                          H[i - 1, j] + gap, H[i, j - 1] + gap)
    np.testing.assert_array_equal(H_a, H)
    assert best_a == H.max()
    if gap == -4:               # the module default: jnp path agrees too
        np.testing.assert_array_equal(np.asarray(H_l), H)
        assert int(best_l) == int(best_a)
