"""Training runtime: optimizer math, grad accumulation invariance,
checkpoint/restart (fault tolerance), gradient compression numerics."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_params, loss_fn
from repro.train import (AdamWConfig, TrainConfig, adamw_init, adamw_update,
                         init_train_state, make_train_step, warmup_cosine)
from repro.train.compression import (quantize_int8, dequantize_int8,
                                     tree_to_vec, vec_to_tree)
from repro.checkpoint import CheckpointManager
from repro.data.lm_data import LMDataConfig, lm_batches


def _smoke_setup(n_micro=1):
    cfg = get_smoke_config("yi-9b")
    tc = TrainConfig(n_microbatches=n_micro,
                     opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    step = make_train_step(cfg, tc, mesh=None)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    dc = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    return cfg, step, state, dc


# ------------------------------------------------------------ optimizer
def test_adamw_decreases_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3, 1))}

    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_ratio=1.0)
    state = adamw_init(params)
    for _ in range(300):
        g = {"w": (params["w"][:, 0] - target)[:, None]}
        params, state, _ = adamw_update(g, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"])[:, 0], target,
                               atol=1e-2)


def test_warmup_cosine_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(warmup_cosine(cfg, s)) for s in range(101)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 1e-6
    assert abs(lrs[100] - 0.1) < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decaying


def test_master_weights_preserve_bf16_params_dtype():
    cfg = get_smoke_config("yi-9b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = adamw_init(params)
    g = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    new_params, state, _ = adamw_update(
        g, state, params, AdamWConfig(warmup_steps=0))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.dtype == b.dtype
    # masters stay fp32
    assert all(m.dtype == jnp.float32
               for m in jax.tree.leaves(state["master"]))


# ------------------------------------------------------------ grad accum
def test_grad_accum_matches_full_batch():
    """n_microbatches=4 must equal n_microbatches=1 up to fp tolerance."""
    cfg = get_smoke_config("yi-9b").scaled(dtype="float32")
    tc1 = TrainConfig(n_microbatches=1, opt=AdamWConfig(warmup_steps=0))
    tc4 = TrainConfig(n_microbatches=4, opt=AdamWConfig(warmup_steps=0))
    s1 = init_train_state(jax.random.PRNGKey(0), cfg)
    s4 = init_train_state(jax.random.PRNGKey(0), cfg)
    dc = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    x, y = lm_batches(dc, 0)
    batch = {"inputs": x, "targets": y}
    step1 = make_train_step(cfg, tc1, None)
    step4 = make_train_step(cfg, tc4, None)
    s1b, m1 = step1(s1, batch)
    s4b, m4 = step4(s4, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s4b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-4)


def test_loss_decreases_over_steps():
    cfg, step, state, dc = _smoke_setup()
    step = jax.jit(step)
    losses = []
    for s in range(12):
        x, y = lm_batches(dc, 0)  # same batch -> must memorize
        state, m = step(state, {"inputs": x, "targets": y})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_bitwise(tmp_path):
    cfg, step, state, dc = _smoke_setup()
    mgr = CheckpointManager(tmp_path, keep_last=2)
    mgr.save(3, state)
    restored, s = mgr.restore(state)
    assert s == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last_n(tmp_path):
    cfg, step, state, dc = _smoke_setup()
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(3) * s})
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_restart_continuation_is_bitwise(tmp_path):
    """Kill/restart invariant: train 6 steps straight == train 3, checkpoint,
    'crash', restore, train 3 more (deterministic stateless data)."""
    def run(n_start, n_end, state):
        cfg, step, _, dc = _smoke_setup()
        step = jax.jit(step)
        for s in range(n_start, n_end):
            x, y = lm_batches(dc, s)
            state, _ = step(state, {"inputs": x, "targets": y})
        return state

    cfg, step, state0, dc = _smoke_setup()
    straight = run(0, 6, state0)

    mgr = CheckpointManager(tmp_path)
    mid = run(0, 3, state0)
    mgr.save(3, mid)
    del mid                                 # "crash"
    restored, s = mgr.restore(straight)     # template only provides structure
    resumed = run(3, 6, restored)
    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_save_survives_partial_tmp(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.arange(4)})
    # simulate a crash mid-write of step 2: stale tmp dir, no manifest
    (tmp_path / "step_00000002.tmp").mkdir()
    assert mgr.latest_step() == 1
    restored, s = mgr.restore({"x": jnp.zeros(4, jnp.int32)})
    assert s == 1


# ------------------------------------------------------------ compression
def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=5000).astype(np.float32))
    q, scale, n = quantize_int8(g)
    back = dequantize_int8(q, scale, n)
    err = np.abs(np.asarray(back - g))
    per_block_bound = np.repeat(np.asarray(scale)[:, 0] * 0.5 + 1e-9, 2048)[:5000]
    assert (err <= per_block_bound).all()


def test_tree_vec_roundtrip():
    tree = {"a": jnp.ones((3, 2), jnp.bfloat16), "b": jnp.arange(5.0)}
    vec, meta = tree_to_vec(tree)
    back = vec_to_tree(vec, meta)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


_COMPRESSED_DP = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.train.compression import make_compressed_dp_step
    assert jax.device_count() == 4
    mesh = jax.make_mesh((4,), ('data',))
    # least squares: loss(w) = mean((x@w - y)^2), data sharded across devices
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    w_true = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    Y = X @ w_true
    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params['w'] - y) ** 2)
    step = make_compressed_dp_step(loss_fn, mesh, 'data', lr=0.1)
    params = {'w': jnp.zeros(8)}
    state = (params, step.init_residual(params))
    for i in range(200):
        state, loss = step(state, (X, Y))
    final = float(loss)
    assert final < 1e-3, final
    print('COMPRESSED_DP_OK', final)
""")


@pytest.mark.slow
def test_compressed_dp_convergence_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _COMPRESSED_DP],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COMPRESSED_DP_OK" in out.stdout
